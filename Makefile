PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast test-slow bench

# tier-1: the full suite (what the driver runs)
test:
	$(PYTHON) -m pytest -q

# fast split for CI runners with tight timeouts (~2 min on 1 core):
# excludes the multi-device subprocess tests and heavy arch smoke suites
test-fast:
	$(PYTHON) -m pytest -q -m "not slow"

test-slow:
	$(PYTHON) -m pytest -q -m slow

bench:
	$(PYTHON) -m benchmarks.run
