PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast test-slow test-multidev lint-plans bench \
	bench-sparse bench-sparse-scale bench-policy bench-metrics bench-ooo \
	bench-latency clean-bench

# tier-1: the full suite (what the driver runs)
test:
	$(PYTHON) -m pytest -q

# fast split for CI runners with tight timeouts (~2 min on 1 core):
# excludes the multi-device subprocess tests and heavy arch smoke suites
test-fast:
	$(PYTHON) -m pytest -q -m "not slow"

# static hot-path audit + temporal-plan verification over the full
# 16-point ExecPolicy lattice (repro.analysis); findings land in
# out/analysis.jsonl and any error-severity finding fails the target —
# the fast CI job runs this right after the fast test split
lint-plans:
	$(PYTHON) -m repro.analysis --fail-on=error
	$(PYTHON) -m repro.serve --smoke --cache-dir out/serve_cache

# --durations=20 so test/benchmark rot shows up in the CI log over time
test-slow:
	$(PYTHON) -m pytest -q -m slow --durations=20

# just the multi-device subprocess suite (halo exchange, mesh dry-run,
# elastic checkpoint) — the fastest loop when hacking on core/halo.py
test-multidev:
	$(PYTHON) -m pytest -q tests/test_parallel_multidev.py --durations=20

bench:
	$(PYTHON) -m benchmarks.run

# change-rate × segment-size sweep (dense vs sparse execution); writes
# BENCH_figsparse.json alongside the stdout table
bench-sparse:
	$(PYTHON) -m benchmarks.run figsparse

# production-scale point of the same sweep: 10^7 events, K up to 16384
# keyed sub-streams through the chunked runner — the crossover-curve
# artifact (BENCH_figsparse.json, uploaded by slow CI like the others)
bench-sparse-scale:
	REPRO_BENCH_EVENTS=10000000 $(PYTHON) -m benchmarks.run figsparse

# execution-policy matrix sweep (the unified runner across body × keys ×
# dag points); writes BENCH_figpolicy.json (uploaded as a CI artifact like
# the other sections)
bench-policy:
	$(PYTHON) -m benchmarks.run figpolicy

# telemetry export smoke: drives an instrumented sparse runner and
# validates the repro.obs/v1 snapshot schema plus the JSONL/Prometheus
# exporters (exits non-zero on schema problems — nightly CI gates on it);
# writes BENCH_metricssmoke.json
bench-metrics:
	$(PYTHON) -m benchmarks.run metricssmoke

# out-of-order ingestion sweep: disorder rate × lateness bound through the
# IngestRunner revise path (watermarks, reorder buffer, sparse re-runs);
# writes BENCH_figooo.json (uploaded by slow CI like the other sections)
bench-ooo:
	$(PYTHON) -m benchmarks.run figooo

# serving-latency sweep: AOT-compiled steps, p50/p99 per call over batch
# 1…1000 + cold-vs-warm first-result; writes BENCH_figlat.json (uploaded
# by slow CI like the other sections)
bench-latency:
	$(PYTHON) -m benchmarks.run figlat

# drop the gitignored machine-readable benchmark results
clean-bench:
	rm -f BENCH_*.json
