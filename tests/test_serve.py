"""Low-latency serving (ISSUE 10): AOT-compiled policy steps, persisted
warm start, double-buffered async ingestion.

The headline invariants:

* **AOT equivalence** — a runner whose staged steps are AOT-lowered and
  installed (``repro.serve.aot_compile``) produces bit-identical outputs
  to the plain lazy-jit runner on the same chunk sequence.
* **Warm start is compile-free** — a second service built over the same
  cache directory rebuilds the runner from the persisted plan artifact
  and loads every step executable from disk: ``plan_source == "warm"``,
  the tracer records **zero** compiles, and outputs stay bit-identical
  (the executable round-trip through
  ``jax.experimental.serialize_executable`` preserves semantics and the
  donation contract).
* **Transfer-guard-clean steady state** — after the first two calls, the
  double-buffered chunk path runs entirely under
  ``jax.transfer_guard("disallow")``: the only H2D is the loop's own
  explicit committed ``device_put``.
* **Admission ring properties** — FIFO order preserved under every shed
  policy, depth bounded by capacity, offered == admitted + shed,
  ``shed='block'`` raises :class:`Backpressure`.
* **Event path** — ring-admitted bursty arrival through the
  :class:`IngestRunner` keeps the watermark monotone and seals chunks in
  order.
* The ``serving`` analysis pass certifies a fully-AOT runner and flags a
  missing executable / empty steady-state donation.
* ``launch/serve.py`` compiles prefill exactly once per run (the fixed
  recompile-per-wave bug).
"""
import os
import pickle

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.audit import audit_runner
from repro.analysis.passes import pass_serving
from repro.core import compile as qc
from repro.core.frontend import TStream
from repro.core.stream import Event, SnapshotGrid
from repro.engine import ExecPolicy, Runner
from repro.serve import (AdmissionRing, Backpressure, ExecutableCache,
                         aot_compile, build_service)

SEG = 8          # out_len of the served runners
SPC = 2          # segments per chunk
SPAN = SEG * SPC
WIN = 8
N_CHUNKS = 5


def _query():
    s = TStream.source("in", prec=1)
    mu = s.window(WIN).mean().shift(1)
    sd = s.window(WIN).stddev().shift(1)
    thr = mu.join(sd, lambda m, d: m + 3.0 * d)
    return s.join(thr, lambda x, t: x - t).where(lambda e: e > 0)


def _chunks(n, seed=5, span=SPAN, host=True):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        v = rng.integers(0, 100, span).astype(np.float32)
        m = np.ones(span, bool)
        if not host:
            v, m = jnp.asarray(v), jnp.asarray(m)
        out.append({"in": SnapshotGrid(value=v, valid=m, t0=i * span,
                                       prec=1)})
    return out


def _np(out):
    return np.asarray(out.value), np.asarray(out.valid)


# ---------------------------------------------------------------------------
# AOT compilation
# ---------------------------------------------------------------------------

def test_aot_outputs_bit_identical():
    """AOT-installed executables are the same computation: chunk-by-chunk
    outputs match the lazy-jit runner exactly."""
    exe = qc.compile_query(_query().node, out_len=SEG, pallas=False,
                           sparse=True)
    r_ref = Runner(exe, ExecPolicy(body="sparse"), segs_per_chunk=SPC)
    r_aot = Runner(exe, ExecPolicy(body="sparse"), segs_per_chunk=SPC)
    report = aot_compile(r_aot)
    assert report and all(v == "compiled" for v in report.values())
    assert {label for label, _ in r_aot.aot_keys()} == set(report)
    for c in _chunks(N_CHUNKS, host=False):
        v0, m0 = _np(r_ref.step(c))
        v1, m1 = _np(r_aot.step(c))
        np.testing.assert_array_equal(m0, m1)
        np.testing.assert_array_equal(v0[m0], v1[m1])


def test_executable_cache_roundtrip_and_corruption(tmp_path):
    """Store → has → load round-trips (meta included); a torn entry
    degrades to a miss and is removed, never an error."""
    exe = qc.compile_query(_query().node, out_len=SEG, pallas=False,
                           sparse=True)
    r = Runner(exe, ExecPolicy(body="sparse"), segs_per_chunk=SPC)
    cache = ExecutableCache(str(tmp_path))
    aot_compile(r, cache)
    fps = [f[:-5] for f in os.listdir(tmp_path) if f.endswith(".aotx")]
    assert len(fps) == len(r.aot_keys())
    got = cache.load(fps[0])
    assert got is not None and isinstance(got[1], dict)
    # corrupt one entry: load misses, removes the file, and the next
    # aot_compile recompiles it rather than erroring
    with open(cache._file(fps[0]), "wb") as f:
        f.write(b"not a pickle")
    assert cache.load(fps[0]) is None
    assert not os.path.exists(cache._file(fps[0]))
    assert cache.load("missing-fingerprint") is None


# ---------------------------------------------------------------------------
# persisted warm start
# ---------------------------------------------------------------------------

def test_warm_start_zero_compiles_bit_identical(tmp_path):
    """The acceptance invariant: a fresh service over a warm cache
    directory plans nothing, traces nothing and compiles nothing — and
    still computes the same bits."""
    cache = str(tmp_path / "svc")
    svc1 = build_service(_query(), out_len=SEG, segs_per_chunk=SPC,
                         cache_dir=cache)
    assert svc1.plan_source == "cold"
    outs1 = [_np(o) for o in svc1.serve(iter(_chunks(N_CHUNKS)))]

    svc2 = build_service(_query(), out_len=SEG, segs_per_chunk=SPC,
                         cache_dir=cache)
    assert svc2.plan_source == "warm"
    assert all(v == "loaded" for v in svc2.aot_report.values())
    tracer = svc2.runner.metrics.tracer
    assert tracer.compiles() == {}, tracer.compiles()
    assert tracer.retraces() == {}, tracer.retraces()
    outs2 = [_np(o) for o in svc2.serve(iter(_chunks(N_CHUNKS)))]
    # still zero compiles after actually serving
    assert tracer.compiles() == {}, tracer.compiles()
    assert len(outs1) == len(outs2) == N_CHUNKS
    for (v1, m1), (v2, m2) in zip(outs1, outs2):
        np.testing.assert_array_equal(m1, m2)
        np.testing.assert_array_equal(v1[m1], v2[m2])


def test_warm_start_survives_missing_executable(tmp_path):
    """Deleting one persisted executable demotes the whole service to the
    cold path (plan may still be reused) — transparently, no error."""
    cache = str(tmp_path / "svc")
    build_service(_query(), out_len=SEG, segs_per_chunk=SPC,
                  cache_dir=cache)
    aot_dir = os.path.join(cache, "aot")
    victims = [f for f in os.listdir(aot_dir) if f.endswith(".aotx")]
    os.remove(os.path.join(aot_dir, victims[0]))
    svc = build_service(_query(), out_len=SEG, segs_per_chunk=SPC,
                        cache_dir=cache)
    assert svc.plan_source == "cold"
    out = svc.step(_chunks(1)[0])
    assert np.asarray(out.valid).shape == (SPAN,)


def test_plan_artifact_persists_across_cache_instances(tmp_path):
    from repro.core import ir
    from repro.multiquery import SharedPlanCache
    path = str(tmp_path / "plans.pkl")
    c1 = SharedPlanCache(persist=path)
    root = c1.intern(_query().node)
    fp = ir.fingerprint(root)
    c1.store_artifact(fp, SEG, {"solo": True, "probe": 7})
    c2 = SharedPlanCache(persist=path)
    assert c2.plan_artifact(fp, SEG) == {"solo": True, "probe": 7}
    assert c2.plan_artifact(fp, SEG + 1) is None
    # a torn store degrades to empty, never an error
    with open(path, "wb") as f:
        f.write(b"\x80garbage")
    assert SharedPlanCache(persist=path).plan_artifact(fp, SEG) is None


# ---------------------------------------------------------------------------
# double-buffered chunk path
# ---------------------------------------------------------------------------

def test_steady_state_is_transfer_guard_clean(tmp_path):
    """After warm-up, the serving generator runs under
    ``jax.transfer_guard("disallow")``: every H2D on the steady path is
    the loop's own explicit committed device_put."""
    svc = build_service(_query(), out_len=SEG, segs_per_chunk=SPC,
                        cache_dir=str(tmp_path / "svc"))
    gen = svc.serve(iter(_chunks(8)))
    next(gen)
    next(gen)
    with jax.transfer_guard("disallow"):
        served = sum(1 for _ in gen)
    assert served == 6
    snap = svc.runner.metrics.snapshot()
    assert snap["histograms"]["serve.call_seconds"]["count"] == 8
    assert snap["gauges"]["serve.first_result_seconds"]["value"] > 0


# ---------------------------------------------------------------------------
# admission ring
# ---------------------------------------------------------------------------

def _ev(i):
    return Event(i, i + 1, float(i))


def test_ring_fifo_and_tail_drop():
    ring = AdmissionRing(4, shed="newest")
    assert [ring.offer("in", _ev(i)) for i in range(6)] == [True] * 4 + \
        [False] * 2
    assert ring.depth == 4
    drained = ring.drain()
    assert [e.event.start for e in drained] == [0, 1, 2, 3]  # FIFO
    assert [e.t_admit for e in drained] == sorted(e.t_admit
                                                 for e in drained)
    snap = ring.metrics.snapshot()
    assert snap["counters"]["serve.admitted"]["value"] == 4
    assert snap["counters"]["serve.shed_events"]["value"] == 2
    assert snap["gauges"]["serve.ring_capacity"]["value"] == 4


def test_ring_oldest_evicts_head():
    ring = AdmissionRing(3, shed="oldest")
    assert all(ring.offer("in", _ev(i)) for i in range(5))  # always admits
    assert [e.event.start for e in ring.drain()] == [2, 3, 4]
    snap = ring.metrics.snapshot()
    assert snap["counters"]["serve.shed_events"]["value"] == 2


def test_ring_block_raises_backpressure():
    ring = AdmissionRing(2, shed="block")
    ring.offer("in", _ev(0))
    ring.offer("in", _ev(1))
    with pytest.raises(Backpressure):
        ring.offer("in", _ev(2))
    ring.drain(1)
    assert ring.offer("in", _ev(2))  # room again after a drain


def test_ring_property_bursty_random():
    """Randomized offers/drains against a plain-list model: FIFO order,
    bounded depth, offered == admitted + shed — under bursty arrival."""
    rng = np.random.default_rng(42)
    ring = AdmissionRing(8, shed="newest")
    model, drained, offered, admitted = [], [], 0, 0
    for _ in range(200):
        if rng.random() < 0.6:  # bursty: offer in runs
            for _ in range(int(rng.integers(1, 6))):
                ev = _ev(offered)
                offered += 1
                ok = ring.offer("in", ev)
                assert ok == (len(model) < 8)
                if ok:
                    model.append(ev)
                    admitted += 1
        else:
            k = int(rng.integers(1, 6))
            got = ring.drain(k)
            assert [e.event for e in got] == model[:len(got)]
            drained += [e.event.start for e in got]
            del model[:len(got)]
        assert ring.depth == len(model) <= 8
    snap = ring.metrics.snapshot()
    assert snap["counters"]["serve.admitted"]["value"] == admitted
    assert (snap["counters"]["serve.shed_events"]["value"]
            == offered - admitted)
    assert drained == sorted(drained)  # global FIFO across bursts


def test_ring_rejects_bad_args():
    with pytest.raises(ValueError):
        AdmissionRing(0)
    with pytest.raises(ValueError):
        AdmissionRing(4, shed="spill")


# ---------------------------------------------------------------------------
# event path: ring -> ingest, watermark monotone under bursty arrival
# ---------------------------------------------------------------------------

def test_event_path_watermark_monotone_bursty(tmp_path):
    svc = build_service(_query(), out_len=SEG, segs_per_chunk=SPC,
                        cache_dir=str(tmp_path / "svc"))
    svc.attach_events(lateness=8, policy="drop", capacity=1024)
    T = SPAN * 6
    rng = np.random.default_rng(9)
    events = [Event(t, t + 1, float(rng.integers(0, 100)))
              for t in range(T)]
    # bounded-disorder bursty arrival: sort by start + jitter < lateness
    jit = rng.integers(0, 8, size=T)
    order = np.argsort([e.start + j for e, j in zip(events, jit)],
                       kind="stable")
    wms, sealed_chunks = [], []
    for burst in np.array_split(order, 10):
        for i in burst:
            assert svc.offer("in", events[i])
        sealed, _ = svc.pump()
        sealed_chunks += [s.chunk for s in sealed]
        wms.append(svc.ingest.tracker.watermark)
    sealed, _ = svc.finish()
    sealed_chunks += [s.chunk for s in sealed]
    # watermark never regresses, chunks seal in order, stream covered
    assert all(a <= b for a, b in zip(wms, wms[1:])), wms
    assert sealed_chunks == sorted(sealed_chunks)
    assert sealed_chunks == list(range(6))
    snap = svc.runner.metrics.snapshot()
    assert snap["counters"]["serve.admitted"]["value"] == T
    assert (snap["histograms"]["serve.admit_to_result_seconds"]["count"]
            > 0)


# ---------------------------------------------------------------------------
# the serving analysis pass
# ---------------------------------------------------------------------------

def _aot_runner():
    exe = qc.compile_query(_query().node, out_len=SEG, pallas=False,
                           sparse=True)
    r = Runner(exe, ExecPolicy(body="sparse"), segs_per_chunk=SPC)
    aot_compile(r)
    return r


def test_pass_serving_certifies_aot_runner():
    r = _aot_runner()
    findings = audit_runner(r, passes={"serving": pass_serving})
    assert [f.code for f in findings] == ["serving-aot-complete"], findings


def test_pass_serving_flags_missing_step_and_donation():
    r = _aot_runner()
    # a step reachable by the policy point but never AOT-installed (the
    # real-world shape: a variant enabled after warm()) -> error
    label, key = r.aot_keys()[0]
    del r.aot_record[key]
    findings = audit_runner(r, passes={"serving": pass_serving})
    assert any(f.code == "serving-step-not-aot" and f.severity == "error"
               for f in findings), findings
    # empty steady-state donation contract -> error
    r2 = _aot_runner()
    steady = [k for la, k in r2.aot_keys()
              if la in ("sparse_fused(steady)", "dense")]
    assert steady
    r2.aot_record[steady[0]]["donate"] = ()
    findings = audit_runner(r2, passes={"serving": pass_serving})
    assert any(f.code == "serving-donation-missing"
               and f.severity == "error" for f in findings), findings


def test_pass_serving_noop_on_unserved_runner():
    exe = qc.compile_query(_query().node, out_len=SEG, pallas=False,
                           sparse=True)
    r = Runner(exe, ExecPolicy(body="sparse"), segs_per_chunk=SPC)
    assert audit_runner(r, passes={"serving": pass_serving}) == []


# ---------------------------------------------------------------------------
# launch/serve.py: prefill compiled once per run
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_launch_serve_prefill_compiles_once():
    from repro.configs.base import get_config
    from repro.launch.serve import _make_prefill
    from repro.models.model import build_model
    from repro.train.train_step import make_serve_steps
    cfg = get_config("qwen3-1.7b", smoke=True)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    prefill_fn, _ = make_serve_steps(model)
    prefill = _make_prefill(model, prefill_fn, cfg.family == "encdec", 12)
    tokens = jnp.zeros((2, 8), jnp.int32)
    prefill(params, tokens)
    prefill(params, tokens)  # second wave, same shapes: cache hit
    assert prefill._cache_size() == 1


@pytest.mark.slow
def test_launch_serve_main_continuous_batching():
    """More requests than batch slots: several waves through ONE hoisted
    prefill; every real request decodes to the full budget."""
    from repro.launch.serve import main
    done = main(["--arch", "qwen3-1.7b", "--smoke", "--batch", "2",
                 "--prompt-len", "8", "--gen", "4", "--requests", "5"])
    assert len(done) == 5
    assert all(len(seq) == 4 for seq in done)
