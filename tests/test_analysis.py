"""The static auditor audits itself: corpus fixtures must fire, the
shipped runner must stay silent.

Each ``tests/analysis_corpus`` module seeds exactly one known-bad pattern
— the two bug classes PRs 6–7 found by hand (eager ``x[0]`` strip, dead
donated ``prev``) plus the hazards the hot path is designed around
(collective under ``cond``, under-captured staging key, under-dilated
ChangePlan).  Zero false negatives on the corpus, zero findings on main:
that pair is what makes the ``make lint-plans`` CI gate meaningful.
"""
import json

import pytest

from repro.analysis import (Finding, SCHEMA, SEVERITIES, audit_runner,
                            export_jsonl, make_target, read_jsonl,
                            validate_finding, verdict)
from repro.analysis.passes import (pass_collectives, pass_donation,
                                   pass_recompile, pass_transfers)
from repro.analysis.planverify import derive_bounds, pass_plan
from repro.engine import ExecPolicy, Runner

from analysis_corpus import (cond_collective, dead_donation, eager_strip,
                             under_dilated, under_keyed)
from analysis_corpus._common import SPC, trend_exe, trend_query


def _codes(findings):
    return {f.code for f in findings}


# -- the corpus fires (zero false negatives) --------------------------------

def test_corpus_eager_strip_fires_transfer_pass():
    findings = pass_transfers(eager_strip.target())
    assert "eager-op-outside-staged-step" in _codes(findings)
    bad = [f for f in findings if f.code == "eager-op-outside-staged-step"]
    assert all(f.severity == "error" for f in bad)
    # the PR6 hint names the eager-indexing class
    assert any("PR6" in f.message for f in bad)


def test_corpus_dead_donation_fires_donation_pass():
    findings = pass_donation(dead_donation.target())
    dead = [f for f in findings if f.code == "donated-leaf-dead"]
    assert dead and all(f.severity == "error" for f in dead)
    # the dead leaves are exactly the prev snapshots of the halo-carrying
    # input (arg position 2 of the fused step)
    assert all("[2]" in f.provenance for f in dead)


def test_corpus_cond_collective_fires_collective_pass():
    findings = pass_collectives(cond_collective.target())
    hits = [f for f in findings if f.code == "collective-under-divergence"]
    assert hits and all(f.severity == "error" for f in hits)
    assert any("cond" in f.provenance for f in hits)


def test_corpus_under_keyed_fires_recompile_pass():
    findings = pass_recompile(under_keyed.target())
    hits = [f for f in findings if f.code == "staging-key-under-captures"]
    assert hits and all(f.severity == "error" for f in hits)
    assert any(f.target == "segs_per_chunk" for f in hits)


def test_corpus_under_dilated_fires_plan_verifier():
    findings = pass_plan(under_dilated.target())
    codes = _codes(findings)
    assert "changeplan-under-dilated" in codes
    # and the affine lowering at the runner's geometry really misses
    # segments a dilated scan window would have caught
    assert "dilation-misses-segments" in codes
    assert all(f.severity == "error" for f in findings
               if f.code in ("changeplan-under-dilated",
                             "dilation-misses-segments"))


# -- the shipped runner stays silent (zero findings on main) ----------------

def test_shipped_runner_audits_clean_at_corpus_point():
    r = Runner(trend_exe(), ExecPolicy(body="sparse"), segs_per_chunk=SPC)
    findings = audit_runner(r, policy="main:sparse-single-local-solo")
    assert [f for f in findings if f.severity in ("warning", "error")] == []


# -- the verifier's independent demand derivation ---------------------------

def test_derived_demand_matches_planned_halos_when_tight():
    """At prec=1 the boundary-resolution halos are exact, so the
    verifier's independently re-derived demand must agree bit-for-bit —
    two different traversals over two different edge-rule codebases
    landing on the same numbers."""
    exe = trend_exe()
    req = derive_bounds((trend_query(False).node,))
    s = exe.input_specs["in"]
    assert req["in"] == (s.left_halo * s.prec, s.right_halo * s.prec)


# -- findings schema + exporters --------------------------------------------

def test_finding_json_roundtrip_and_validation(tmp_path):
    f = Finding("warning", "plan", "halo-overwide", "msg",
                policy="dense×single×local×solo", target="in",
                provenance="left_halo=16")
    d = f.to_json()
    assert d["schema"] == SCHEMA and d["pass"] == "plan"
    assert validate_finding(d) == []
    assert Finding.from_json(d) == f

    path = export_jsonl([f, f], tmp_path / "a.jsonl")
    back = read_jsonl(path)
    assert back == [f, f]
    with open(path) as fh:
        lines = [json.loads(l) for l in fh]
    assert all(l["schema"] == SCHEMA for l in lines)


def test_validate_finding_flags_problems():
    assert validate_finding({"schema": "nope"})  # wrong schema + missing
    bad = Finding("error", "x", "c", "m").to_json()
    bad["severity"] = "fatal"
    assert any("severity" in p for p in validate_finding(bad))


def test_verdict_ladder():
    assert verdict([]) == "clean"
    assert verdict([Finding("info", "p", "c", "m")]) == "info"
    assert verdict([Finding("info", "p", "c", "m"),
                    Finding("error", "p", "c", "m")]) == "error"


# -- CLI --------------------------------------------------------------------

def test_cli_clean_point_exits_zero(tmp_path):
    from repro.analysis.__main__ import main
    out = tmp_path / "analysis.jsonl"
    rc = main(["--policy", "sparse×single×local×solo",
               "--passes", "plan,transfer", "--out", str(out)])
    assert rc == 0
    assert out.exists() and read_jsonl(out) == []


def test_cli_fail_on_threshold(tmp_path, monkeypatch):
    import repro.analysis.__main__ as m
    finding = Finding("warning", "plan", "c", "msg")
    monkeypatch.setattr(m, "audit_lattice",
                        lambda policies, passes=None: [finding])
    out = str(tmp_path / "f.jsonl")
    assert m.main(["--out", out]) == 0                      # fail-on error
    assert m.main(["--fail-on", "warning", "--out", out]) == 1
    assert m.main(["--fail-on", "never", "--out", out]) == 0
    assert m.main(["--json", "--fail-on", "info", "--out", out]) == 1
    assert read_jsonl(out) == [finding]


def test_cli_rejects_unknown_pass_and_policy(tmp_path):
    from repro.analysis.__main__ import main
    with pytest.raises(SystemExit):
        main(["--passes", "bogus", "--out", str(tmp_path / "x.jsonl")])
    with pytest.raises(SystemExit):
        main(["--policy", "no-such-point", "--out", str(tmp_path / "x.jsonl")])
