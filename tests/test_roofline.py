"""Unit tests for the HLO collective-bytes parser and roofline math."""
import numpy as np

from repro.roofline.analysis import (HW, RooflineReport, collective_bytes,
                                     roofline, _shape_bytes)


def test_shape_bytes():
    assert _shape_bytes("bf16[128,256]") == 128 * 256 * 2
    assert _shape_bytes("f32[16]") == 64
    assert _shape_bytes("(bf16[2,2], f32[4])") == 8 + 16
    assert _shape_bytes("pred[8]") == 8


HLO_FLAT = """
HloModule m

ENTRY %main (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024]{0} parameter(0)
  %ar = f32[1024]{0} all-reduce(%p0), replica_groups=[16,16]<=[256], to_apply=%add
  ROOT %ag = f32[1024]{0} all-gather(%ar), replica_groups={{0,1,2,3}}, dimensions={0}
}
"""


def test_collective_bytes_ring_factors():
    out = collective_bytes(HLO_FLAT)
    # all-reduce: 2 * 4096B * 15/16 ; all-gather: 4096B * 3/4
    assert abs(out["all-reduce"] - 2 * 4096 * 15 / 16) < 1
    assert abs(out["all-gather"] - 4096 * 3 / 4) < 1


HLO_WHILE = """
HloModule m

%body (x: (s32[], f32[64])) -> (s32[], f32[64]) {
  %x = (s32[], f32[64]) parameter(0)
  %g = f32[64]{0} get-tuple-element(%x), index=1
  %ar = f32[64]{0} all-reduce(%g), replica_groups=[2,8]<=[16], to_apply=%add
  ROOT %t = (s32[], f32[64]) tuple(%c, %ar)
}

%cond (x: (s32[], f32[64])) -> pred[] {
  %x = (s32[], f32[64]) parameter(0)
  %i = s32[] get-tuple-element(%x), index=0
  %n = s32[] constant(12)
  ROOT %cmp = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (p: f32[64]) -> f32[64] {
  %p = f32[64]{0} parameter(0)
  %w = (s32[], f32[64]) while(%init), condition=%cond, body=%body
  ROOT %r = f32[64]{0} get-tuple-element(%w), index=1
}
"""


def test_collective_bytes_while_multiplier():
    """Collectives inside a scan body count trip_count times — the fix for
    XLA cost_analysis counting while bodies once."""
    out = collective_bytes(HLO_WHILE)
    per_iter = 2 * 256 * 7 / 8
    assert abs(out["all-reduce"] - 12 * per_iter) < 1


def test_roofline_terms_and_dominant():
    hw = HW()
    rep = roofline({"flops": 197e12, "bytes accessed": 819e9 * 2},
                   HLO_FLAT, model_flops_per_device=98.5e12, hw=hw)
    assert abs(rep.compute_s - 1.0) < 1e-6
    assert abs(rep.memory_s - 2.0) < 1e-6
    assert rep.dominant == "memory"
    assert abs(rep.useful_ratio - 0.5) < 1e-6
    # roofline fraction = (model/peak) / bound = 0.5s / 2.0s
    assert abs(rep.roofline_fraction - 0.25) < 1e-6
