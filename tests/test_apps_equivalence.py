"""Cross-engine equivalence: every benchmark app must produce the same
stream from the TiLT compiler and from the event-centric EventSPE baseline.

This is the strongest correctness check in the suite: two independent
implementations (time-centric JAX vs event-centric numpy) of the paper's
eight applications + YSB + the four primitive ops.

Comparison semantics: outputs are compared as event sets (timestamp, value)
on the common timestamp domain.  f32-vs-f64 predicate-boundary flips (a
``Where`` whose operand is within tolerance of the threshold) are excluded
by a margin rule rather than counted as mismatches.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import compile as qc
from repro.core.parallel import partition_run
from repro.core.stream import SnapshotGrid
from repro.data import apps as A
from repro.spe import eventspe as es

N = 3000
BATCH = 500
REL_TOL = 2e-3          # value agreement
MARGIN = 1e-2           # |predicate operand| below this → flip excused


def _grids(data):
    out = {}
    for name, d in data.items():
        val = d["value"]
        v = ({k: jnp.asarray(a, jnp.float32) for k, a in val.items()}
             if isinstance(val, dict) else jnp.asarray(val, jnp.float32))
        out[name] = SnapshotGrid(value=v, valid=jnp.asarray(d["valid"]),
                                 t0=0, prec=1)
    return out


def _batches(data):
    for i in range(0, N, BATCH):
        sl = slice(i, i + BATCH)
        env = {}
        for nm, dd in data.items():
            v = dd["value"]
            v = ({k: a[sl] for k, a in v.items()} if isinstance(v, dict)
                 else v[sl])
            env[nm] = es.Batch(dd["ts"][sl], v, dd["valid"][sl])
        yield env


def _vals(v, i):
    if isinstance(v, dict):
        return {k: float(np.asarray(a)[i]) for k, a in v.items()}
    return float(np.asarray(v)[i])


def _compare(app):
    data = app.make_input(N, 42)
    exe = qc.compile_query(app.query.node, out_len=N // app.query.prec,
                           pallas=False)
    out = partition_run(exe, _grids(data), 0, 1)
    m = np.asarray(out.valid)
    t_ts = out.t0 + (np.arange(len(m)) + 1) * out.prec
    tilt_idx = {int(ts): i for i, ts in enumerate(t_ts)}

    spe_outs = app.spe.run(_batches(data))

    flips, checked, max_err = 0, 0, 0.0
    for o in spe_outs:
        for j in range(len(o.ts)):
            i = tilt_idx.get(int(o.ts[j]))
            if i is None:
                assert not o.valid[j], f"SPE event at {o.ts[j]} outside TiLT domain"
                continue
            if bool(m[i]) != bool(o.valid[j]):
                # predicate-boundary flip: excused when the visible value is
                # within MARGIN of zero (Where thresholds compare against 0
                # in every app; f32-vs-f64 rounding flips only those)
                tv, sv = _vals(out.value, i), _vals(o.value, j)
                mag = min(abs(v) for v in
                          ([sv] if not isinstance(sv, dict) else
                           list(sv.values()))
                          + ([tv] if not isinstance(tv, dict) else
                             list(tv.values())))
                if mag >= MARGIN:
                    flips += 1
                continue
            if not m[i]:
                continue
            checked += 1
            tv, sv = _vals(out.value, i), _vals(o.value, j)
            if isinstance(tv, dict):
                err = max(abs(tv[k] - sv[k]) / max(abs(sv[k]), 1.0)
                          for k in tv)
            else:
                err = abs(tv - sv) / max(abs(sv), 1.0)
            max_err = max(max_err, err)
    return flips, checked, max_err


@pytest.mark.parametrize("name", sorted(A.APPS))
def test_app_equivalence(name):
    app = A.make_app(name)
    flips, checked, max_err = _compare(app)
    assert checked > 10, f"{name}: only {checked} comparable events"
    # predicate-boundary flips: allow a small fraction (f32 vs f64 at the
    # Where threshold); everything else must agree.
    assert flips <= max(3, checked // 200), (
        f"{name}: {flips} validity mismatches over {checked} events")
    assert max_err < REL_TOL, f"{name}: max rel err {max_err:.2e}"


@pytest.mark.parametrize("op", A.TEMPORAL_OPS)
def test_temporal_op_equivalence(op):
    app = A.temporal_op(op)
    flips, checked, max_err = _compare(app)
    assert checked > 10
    assert flips == 0, f"{op}: {flips} validity mismatches"
    assert max_err < 1e-5, f"{op}: max rel err {max_err:.2e}"
