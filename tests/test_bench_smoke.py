"""Benchmark smoke tests (slow CI job): drive registered benchmark sections
through ``benchmarks/run.py`` at 1-chunk scale so they can't silently rot.

Runs exactly the entry point a user would (``python -m benchmarks.run
<section>``) with REPRO_BENCH_EVENTS shrunk to a few thousand events — a
compile-and-one-chunk pass, not a measurement.
"""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_section(section: str) -> str:
    env = dict(os.environ,
               REPRO_BENCH_EVENTS="4096",
               JAX_PLATFORMS="cpu",
               PYTHONPATH="src" + (
                   os.pathsep + os.environ["PYTHONPATH"]
                   if os.environ.get("PYTHONPATH") else ""))
    out = subprocess.run([sys.executable, "-m", "benchmarks.run", section],
                         cwd=REPO, env=env, capture_output=True, text=True,
                         timeout=900)
    assert out.returncode == 0, (section, out.stderr[-2000:])
    assert f"## section {section}" in out.stdout, out.stdout
    return out.stdout


def test_fig_multiquery_sharing_smoke():
    out = _run_section("figmq")
    # all three N points reported, shared and independent
    for n in (1, 4, 16):
        assert f"figmq_shared_n{n}," in out
        assert f"figmq_indep_n{n}," in out


def test_fig8_keyed_scaling_smoke():
    out = _run_section("fig8k")
    assert "fig8k_trend_k16," in out
    assert "fig8k_ysb_p4," in out


def test_fig_halo_depth_smoke():
    out = _run_section("fighalo")
    # all shard counts reported (run.py forces 8 host devices for fighalo)
    for s in (1, 2, 4, 8):
        assert f"_s{s}," in out, out
    # the deep-window multi-hop corner — rejected at seed — must run
    assert "hops=4" in out, out
