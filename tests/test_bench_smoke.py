"""Benchmark smoke tests (slow CI job): drive registered benchmark sections
through ``benchmarks/run.py`` at 1-chunk scale so they can't silently rot.

Runs exactly the entry point a user would (``python -m benchmarks.run
<section>``) with REPRO_BENCH_EVENTS shrunk to a few thousand events — a
compile-and-one-chunk pass, not a measurement.
"""
import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_section(section: str) -> str:
    env = dict(os.environ,
               REPRO_BENCH_EVENTS="4096",
               JAX_PLATFORMS="cpu",
               PYTHONPATH="src" + (
                   os.pathsep + os.environ["PYTHONPATH"]
                   if os.environ.get("PYTHONPATH") else ""))
    out = subprocess.run([sys.executable, "-m", "benchmarks.run", section],
                         cwd=REPO, env=env, capture_output=True, text=True,
                         timeout=900)
    assert out.returncode == 0, (section, out.stderr[-2000:])
    assert f"## section {section}" in out.stdout, out.stdout
    return out.stdout


def _assert_engine_telemetry(rows):
    """Every row carries a valid ``repro.obs/v1`` snapshot under
    ``metrics`` (validated with the library's own schema smoke)."""
    sys.path.insert(0, os.path.join(REPO, "src"))
    try:
        from repro.obs import SCHEMA, validate_snapshot
    finally:
        sys.path.pop(0)
    assert rows
    for r in rows:
        snap = r.get("metrics")
        assert isinstance(snap, dict) and snap.get("schema") == SCHEMA, r
        assert validate_snapshot(snap) == [], (r["name"],
                                               validate_snapshot(snap))


def test_fig_multiquery_sharing_smoke():
    out = _run_section("figmq")
    # all three N points reported, shared and independent
    for n in (1, 4, 16):
        assert f"figmq_shared_n{n}," in out
        assert f"figmq_indep_n{n}," in out


def test_fig8_keyed_scaling_smoke():
    out = _run_section("fig8k")
    assert "fig8k_trend_k16," in out
    assert "fig8k_ysb_p4," in out


def test_fig_halo_depth_smoke():
    out = _run_section("fighalo")
    # all shard counts reported (run.py forces 8 host devices for fighalo)
    for s in (1, 2, 4, 8):
        assert f"_s{s}," in out, out
    # the deep-window multi-hop corner — rejected at seed — must run
    assert "hops=4" in out, out


def test_fig_policy_smoke_and_json_results():
    """The policy-matrix sweep must report a dense and a sparse row for
    every covered keys×dag point and write BENCH_figpolicy.json with the
    compaction/speedup columns on the sparse rows."""
    path = os.path.join(REPO, "BENCH_figpolicy.json")
    if os.path.exists(path):
        os.remove(path)
    out = _run_section("figpolicy")
    for keys, dag in (("single", "solo"), ("vmapped", "solo"),
                      ("single", "union")):
        assert f"figpolicy_dense_{keys}_{dag}," in out, out
        assert f"figpolicy_sparse_{keys}_{dag}," in out, out
    doc = json.load(open(path))
    assert doc["section"] == "figpolicy"
    sparse_rows = [r for r in doc["rows"] if r.get("body") == "sparse"]
    assert sparse_rows and all("compact" in r and "speedup" in r
                               for r in sparse_rows), doc["rows"]
    # the ~2%-change workload must actually compact
    assert min(r["compact"] for r in sparse_rows) < 0.5, sparse_rows
    # sparse rows carry the runner's schema-versioned telemetry snapshot
    # (the compact column is read from it, not recomputed)
    _assert_engine_telemetry(sparse_rows)


def test_fig_sparse_smoke_and_json_results():
    """The change-rate sweep must report dense + sparse rows at every rate
    and write the machine-readable BENCH_figsparse.json next to the stdout
    table (rows with parsed derived columns + config)."""
    path = os.path.join(REPO, "BENCH_figsparse.json")
    if os.path.exists(path):
        os.remove(path)
    out = _run_section("figsparse")
    for r in (1, 10, 50, 100):
        assert f"figsparse_dense_r{r}," in out, out
        assert f"figsparse_sparse_r{r}_" in out, out
    assert os.path.exists(path), out
    doc = json.load(open(path))
    assert doc["section"] == "figsparse"
    assert doc["config"]["events"] == 4096
    rows = doc["rows"]
    one_shot = [r for r in rows if r.get("mode") == "sparse"
                and "scale" not in r]
    assert one_shot and all("compact" in r and "speedup" in r
                            for r in one_shot), rows
    # at 1% change rate the one-shot sweep must actually compact
    assert min(r["compact"] for r in one_shot
               if r["rate"] == 0.01) < 0.5, one_shot
    # the scale sweep (keyed runner crossover curve) rides in the same
    # JSON: dense+sparse rows per rate with the scale/compact/speedup
    # schema, and the interpolated crossover in the section config
    scale = [r for r in rows if r["name"].startswith("figsparse_scale_")]
    assert {r["mode"] for r in scale} == {"dense", "sparse"}, rows
    for r in scale:
        assert {"rate", "scale", "events", "keys", "chunks"} <= set(r), r
        if r["mode"] == "sparse":
            assert "compact" in r and "speedup" in r, r
            assert 0.0 < r["compact"] <= 1.0, r
    assert "scale_crossover_rate" in doc["config"], doc["config"]
    assert "scale_keys" in doc["config"], doc["config"]
    # compact/latency columns come from engine telemetry now: sparse rows
    # (one-shot and scale) carry the snapshot, and the anchor sweep records
    # its measured instrumentation overhead in the config
    _assert_engine_telemetry(one_shot)
    _assert_engine_telemetry([r for r in scale if r["mode"] == "sparse"])
    assert "metrics_overhead_pct" in doc["config"], doc["config"]
    # the headline overhead is clamped non-negative (a noise-level
    # negative A/B difference means "unmeasurable", not a speedup); the
    # raw signed value and the best-of repeat count ride alongside
    assert doc["config"]["metrics_overhead_pct"] >= 0.0, doc["config"]
    assert "metrics_overhead_raw_pct" in doc["config"], doc["config"]
    assert doc["config"]["metrics_overhead_repeats"] >= 3, doc["config"]


def test_fig_ooo_smoke_and_json_results():
    """The out-of-order ingestion sweep (``make bench-ooo``) must report
    every disorder-rate × lateness-bound cell and write BENCH_figooo.json
    with the revision-overhead columns; disordered cells must actually
    exercise the revise path (late events, sparse re-run units)."""
    path = os.path.join(REPO, "BENCH_figooo.json")
    if os.path.exists(path):
        os.remove(path)
    out = _run_section("figooo")
    for lateness in (16, 256):
        for rate in ("0", "0.02", "0.1"):
            assert f"figooo_r{rate}_l{lateness}," in out, out
    doc = json.load(open(path))
    assert doc["section"] == "figooo"
    rows = doc["rows"]
    assert all({"late", "revised", "rev_units", "corrections",
                "beyond_horizon", "sealed"} <= set(r) for r in rows), rows
    clean = [r for r in rows if r["rate"] == 0.0]
    dirty = [r for r in rows if r["rate"] > 0.0]
    assert clean and all(r["late"] == r["rev_units"] == 0 for r in clean)
    assert dirty and all(r["late"] > 0 and r["rev_units"] > 0
                         and r["corrections"] > 0 for r in dirty), rows


def test_fig_latency_smoke_and_json_results():
    """The serving-latency sweep (``make bench-latency``) must report a
    p50/p99 row per batch with zero steady-state compiles plus the
    cold/warm first-result pair, and write BENCH_figlat.json with the
    headline numbers in the section config."""
    path = os.path.join(REPO, "BENCH_figlat.json")
    if os.path.exists(path):
        os.remove(path)
    out = _run_section("figlat")
    for b in (1, 10, 100, 1000):
        assert f"figlat_serve_b{b}," in out, out
    assert "figlat_first_result_cold," in out, out
    assert "figlat_first_result_warm," in out, out
    doc = json.load(open(path))
    assert doc["section"] == "figlat"
    serve_rows = [r for r in doc["rows"]
                  if r["name"].startswith("figlat_serve_")]
    assert len(serve_rows) == 4
    for r in serve_rows:
        assert {"batch", "p50_us", "p99_us", "steady_compiles",
                "retraces"} <= set(r), r
        assert r["steady_compiles"] == 0 and r["retraces"] == 0, r
        assert 0 < r["p50_us"] <= r["p99_us"], r
    _assert_engine_telemetry(serve_rows)
    cfg = doc["config"]
    assert {"p99_batch100_us", "cold_first_result_s",
            "warm_first_result_s", "warm_speedup"} <= set(cfg), cfg
    # the persisted warm start must actually pay off, even at smoke scale
    assert cfg["warm_speedup"] > 1.0, cfg


def test_metrics_smoke_section_validates_exporters():
    """``bench-metrics`` (the nightly CI gate): the metrics_smoke section
    must pass its own schema/exporter validation (it exits non-zero on any
    problem) and write a BENCH json whose row embeds the snapshot."""
    path = os.path.join(REPO, "BENCH_metricssmoke.json")
    if os.path.exists(path):
        os.remove(path)
    out = _run_section("metricssmoke")
    assert "ok=1" in out, out
    doc = json.load(open(path))
    assert doc["config"]["schema"] == "repro.obs/v1"
    _assert_engine_telemetry(doc["rows"])
