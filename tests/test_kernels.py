"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp oracle
across a shape/dtype/window sweep, plus the fast jnp block fallback."""
import os

os.environ.setdefault("REPRO_PALLAS_INTERPRET", "1")

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels import window_reduce as wr

SHAPES = [(64, 1, 8), (257, 2, 16), (533, 3, 37), (1024, 4, 128),
          (100, 1, 100), (96, 2, 256)]  # window > T included
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("T,C,W", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_prefix_scan_kernel(T, C, W, dtype):
    rng = np.random.default_rng(T + C)
    x = jnp.asarray(rng.normal(size=(C, T)), dtype)
    out = wr.prefix_scan(x, block=64, interpret=True)
    want = np.cumsum(np.asarray(x, np.float32), axis=-1)
    np.testing.assert_allclose(np.asarray(out), want, rtol=2e-2, atol=1e-2)


@pytest.mark.parametrize("T,C,W", SHAPES)
def test_vanherk_kernel_max_min(T, C, W):
    rng = np.random.default_rng(T * 7 + W)
    x = jnp.asarray(rng.normal(size=(C, T)).astype(np.float32))
    valid = jnp.asarray(rng.random(T) > 0.3)
    for op, comb, ident in (("max", jnp.maximum, -jnp.inf),
                            ("min", jnp.minimum, jnp.inf)):
        v, a = ops.sliding_assoc(x, valid, W, op, pallas=True)
        xm = jnp.where(valid[None], x, ident)
        vr, ar = ref.sliding_assoc_ref(xm, valid, W, comb, ident)
        np.testing.assert_allclose(np.asarray(v), np.asarray(vr), rtol=1e-6)
        assert np.array_equal(np.asarray(a), np.asarray(ar)), op


@pytest.mark.parametrize("T,C,W", SHAPES)
@pytest.mark.parametrize("algo", ["block", "soe"])
@pytest.mark.parametrize("pallas", [True, False])
def test_sliding_sum(T, C, W, algo, pallas):
    rng = np.random.default_rng(T + W)
    x = jnp.asarray(rng.normal(size=(C, T)).astype(np.float32))
    valid = jnp.asarray(rng.random(T) > 0.2)
    s, n = ops.sliding_sum(x, valid, W, pallas=pallas, algo=algo)
    sr, nr = ref.sliding_sum_ref(x, valid, W)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(n), np.asarray(nr), atol=0.5)


def test_block_beats_soe_numerics():
    """The beyond-paper block algorithm must bound error by window content;
    SoE error grows with stream length (DESIGN.md §2)."""
    T, W = 200_000, 64
    rng = np.random.default_rng(0)
    xs = (rng.normal(1000.0, 1.0, T)).astype(np.float32)  # large DC offset
    x = jnp.asarray(xs)[None, :]
    valid = jnp.ones((T,), bool)
    want = ref.sliding_sum_ref(x, valid, W)[0]
    # float64 oracle
    c = np.concatenate([[0], np.cumsum(xs.astype(np.float64))])
    exact = c[W:] - c[:-W]
    s_block, _ = ops.sliding_sum(x, valid, W, pallas=False, algo="block")
    s_soe, _ = ops.sliding_sum(x, valid, W, pallas=False, algo="soe")
    err_block = np.abs(np.asarray(s_block)[0, W:] - exact[:-1 or None][:len(exact)])
    err_block = np.abs(np.asarray(s_block)[0, W - 1:] - exact).max()
    err_soe = np.abs(np.asarray(s_soe)[0, W - 1:] - exact).max()
    assert err_block < 0.5, err_block
    assert err_soe > err_block * 10, (err_soe, err_block)


# (T, C, n_segs, a0, step, width): negative window starts, width > T,
# step > width (strided outputs) and single-tick widths all included
SEG_DIRTY_GEOMS = [
    (256, 1, 8, 0, 32, 32),
    (256, 3, 8, -31, 32, 64),      # window runs off the left edge
    (200, 2, 4, 7, 48, 17),        # step > width: gaps between lineages
    (64, 1, 4, -5, 16, 128),       # width > T: every segment sees the end
    (512, 4, 16, 1, 32, 33),
    (96, 2, 12, -8, 8, 1),         # single-pair windows
]


@pytest.mark.parametrize("T,C,n_segs,a0,step,width", SEG_DIRTY_GEOMS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
def test_seg_dirty_kernel_matches_ref(T, C, n_segs, a0, step, width, dtype):
    """The fused change-detection kernel (interpret mode on CPU) must be
    bit-identical to the jnp oracle on piecewise-constant channel matrices
    across lineage geometries, including out-of-range and tick-0 pairs
    (which never count, by convention)."""
    from repro.kernels import sparse_compact
    rng = np.random.default_rng(T * 31 + n_segs)
    # piecewise-constant rows (~5% change rate) so flags actually vary
    change = rng.random((C, T)) < 0.05
    raw = rng.integers(0, 50, size=(C, T))
    idx = np.maximum.accumulate(np.where(change, np.arange(T)[None, :], -1),
                                axis=1)
    x = jnp.asarray(raw[np.arange(C)[:, None], np.clip(idx, 0, None)], dtype)
    geoms = [(a0, step, width)]
    got = sparse_compact.seg_dirty([x], geoms, n_segs, pallas=True)
    want = ref.seg_dirty_fused_ref([x], geoms, n_segs)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_seg_dirty_kernel_multiple_matrices_and_nan():
    """Several matrices of different dtypes OR into one flag set; NaN
    payloads compare unequal to themselves and are always dirty —
    conservative in kernel and oracle alike (padding must NOT leak in)."""
    from repro.kernels import sparse_compact
    T, n_segs = 128, 4
    a = np.zeros((1, T), np.float32)
    a[0, 60] = np.nan                      # NaN tick: always dirty
    b = np.zeros((2, T), np.int32)
    b[1, 100:] = 7                         # int change in the last segment
    geoms = [(0, 32, 32), (0, 32, 32)]
    mats = [jnp.asarray(a), jnp.asarray(b)]
    got = sparse_compact.seg_dirty(mats, geoms, n_segs, pallas=True)
    want = ref.seg_dirty_fused_ref(mats, geoms, n_segs)
    assert np.array_equal(np.asarray(got), np.asarray(want))
    # the NaN at tick 60 dirties segment 1 only (pairs (59,60) and (60,61)
    # both land in ticks 32..63); the int change dirties segment 3
    assert list(np.asarray(want)) == [False, True, False, True]


def test_vanherk_block_ref_matches_reduce_window():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(2, 300)).astype(np.float32))
    for W in (8, 33, 128):
        got = ref.sliding_assoc_block_ref(x, W, jnp.maximum, -jnp.inf)
        want = jnp.stack([ref.sliding_reduce_window_ref(
            x[c], W, -jnp.inf, jax_max) for c in range(2)])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want))


def jax_max(a, b):
    import jax.numpy as j
    return j.maximum(a, b)
