"""Multi-hop halo exchange: schedule math (core/halo.py), the repurposed
halo guard, and the shard_map_run satellites (absolute output origin,
real-exception input validation, alignment guard).

Everything here runs on the default single-device CPU config — a 1-device
mesh exercises the full shard_map/exchange code path (all halo ticks φ);
the true multi-device bit-identity checks live in the slow subprocess
suite (tests/test_parallel_multidev.py).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compile as qc
from repro.core import halo
from repro.core.frontend import TStream
from repro.core.parallel import (partition_run, shard_map_run,
                                 check_single_hop_halo, slice_grid)
from repro.core.plan import plan_query
from repro.core.stream import SnapshotGrid
from repro.launch.mesh import make_local_mesh


# ---------------------------------------------------------------------------
# schedule math
# ---------------------------------------------------------------------------

def test_hop_count_threshold():
    """Satellite pin: halo == core is single-hop; halo == core + 1 is the
    first config that needs the chain."""
    assert halo.hop_count(0, 64) == 0
    assert halo.hop_count(64, 64) == 1       # halo == core: passes 1 hop
    assert halo.hop_count(65, 64) == 2       # halo == core + 1: needs hops
    assert halo.hop_count(128, 64) == 2
    assert halo.hop_count(129, 64) == 3
    assert halo.hop_count(500, 128) == 4     # the acceptance config
    with pytest.raises(ValueError):
        halo.hop_count(1, 0)


def test_schedule_hop_contributions():
    s = halo.schedule(500, 0, 128)
    assert s.left_hops == (128, 128, 128, 116)   # full slabs + remainder
    assert s.right_hops == ()
    assert s.left_halo == 500 and s.right_halo == 0
    assert s.max_hops == 4
    # exact multiples: all hops are full slabs
    assert halo.schedule(256, 0, 128).left_hops == (128, 128)
    # both sides independent
    two = halo.schedule(10, 130, 64)
    assert two.left_hops == (10,)
    assert two.right_hops == (64, 64, 2)
    # schedules are cached planning artifacts
    assert halo.schedule(500, 0, 128) is s


def test_input_spec_carries_schedule():
    q = TStream.source("in", prec=1).window(100).sum()
    qp = plan_query(q.node, out_len=32)
    sched = qp.input_specs["in"].halo_schedule()
    assert sched.core == 32
    assert sum(sched.left_hops) == qp.input_specs["in"].left_halo
    assert len(sched.left_hops) == 4          # ceil(100 / 32)


def test_check_single_hop_halo_reports_instead_of_raising():
    """The old NotImplementedError is retired: any halo is servable, and
    the report keeps the min_out_len ceil-division formula."""
    q = TStream.source("in", prec=1).window(100).sum()
    for out_len, hops in ((100, 1), (99, 2), (50, 2), (33, 4), (32, 4)):
        exe = qc.compile_query(q.node, out_len=out_len, pallas=False)
        rep = check_single_hop_halo(exe.input_specs, exe.out_prec, n=8)
        assert rep["in"].left_hops == hops, out_len
        assert rep["in"].min_single_hop_out_len == 100
    # exactly at the threshold: halo == core is still single-hop
    exe = qc.compile_query(q.node, out_len=100, pallas=False)
    assert check_single_hop_halo(
        exe.input_specs, exe.out_prec, n=8)["in"].max_hops == 1


# ---------------------------------------------------------------------------
# shard_map_run satellites (1-device mesh)
# ---------------------------------------------------------------------------

def _grid(vals, valid, t0=0):
    return SnapshotGrid(value=jnp.asarray(vals), valid=jnp.asarray(valid),
                        t0=t0, prec=1)


def _int_data(n, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.integers(0, 100, n).astype(np.float32),
            rng.random(n) > 0.2)


def test_shard_map_run_nonzero_origin_matches_partition_run():
    """Regression: the sharded output grid must start where the inputs'
    core region starts, not at a hardcoded t0=0."""
    N, T0 = 64, 960
    vals, valid = _int_data(N, seed=3)
    g = {"in": _grid(vals, valid, t0=T0)}
    q = TStream.source("in", prec=1).window(8).sum()
    exe = qc.compile_query(q.node, out_len=N, pallas=False)
    ref = partition_run(exe, g, T0, 1)
    out = shard_map_run(exe, g, make_local_mesh(n_data=1))
    assert out.t0 == T0
    assert ref.t0 == T0
    assert np.array_equal(np.asarray(ref.valid), np.asarray(out.valid))
    m = np.asarray(ref.valid)
    assert np.array_equal(np.asarray(ref.value)[m], np.asarray(out.value)[m])


def test_shard_map_run_core_length_is_real_exception():
    vals, valid = _int_data(48, seed=4)
    q = TStream.source("in", prec=1).window(8).sum()
    exe = qc.compile_query(q.node, out_len=64, pallas=False)
    with pytest.raises(ValueError, match="core length"):
        shard_map_run(exe, {"in": _grid(vals, valid)},
                      make_local_mesh(n_data=1))


def test_shard_map_run_rejects_disagreeing_origins():
    N = 32
    a = TStream.source("a", prec=1)
    b = TStream.source("b", prec=1)
    q = a.window(4).sum().join(b.window(4).sum(), lambda x, y: x + y)
    exe = qc.compile_query(q.node, out_len=N, pallas=False)
    va, ma = _int_data(N, seed=5)
    vb, mb = _int_data(N, seed=6)
    with pytest.raises(ValueError, match="core-region origin"):
        shard_map_run(exe, {"a": _grid(va, ma, t0=0),
                            "b": _grid(vb, mb, t0=32)},
                      make_local_mesh(n_data=1))


def test_grid_window_misalignment_raises():
    """Satellite: a misaligned partition origin raises instead of
    floor-dividing into a time-shifted window."""
    N = 32
    vals, valid = _int_data(N, seed=7)
    g = {"in": SnapshotGrid(value=jnp.asarray(vals),
                            valid=jnp.asarray(valid), t0=0, prec=2)}
    q = TStream.source("in", prec=2).window(8).sum()
    exe = qc.compile_query(q.node, out_len=8, pallas=False)
    partition_run(exe, g, 0, 1)  # aligned: fine
    with pytest.raises(ValueError, match="misaligned"):
        partition_run(exe, g, 1, 1)


def test_slice_grid_misalignment_is_real_exception():
    vals, valid = _int_data(16, seed=8)
    g = SnapshotGrid(value=jnp.asarray(vals), valid=jnp.asarray(valid),
                     t0=0, prec=2)
    with pytest.raises(ValueError, match="misaligned"):
        slice_grid(g, 1, 9)
