"""Checkpoint + training-loop integration tests."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import registry
from repro.data.pipeline import TokenPipeline
from repro.models.model import build_model
from repro.train import checkpoint as ck
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step


def test_checkpoint_roundtrip_and_gc():
    with tempfile.TemporaryDirectory() as d:
        tree = {"a": jnp.arange(6.0).reshape(2, 3),
                "nest": {"b": jnp.ones(4, jnp.int32)}}
        mgr = ck.CheckpointManager(d, keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, tree, extra={"s": s}, blocking=True)
        steps = sorted(int(x.split("_")[1]) for x in os.listdir(d)
                       if x.startswith("step_"))
        assert steps == [3, 4]  # keep-last-2 rotation
        restored, manifest = mgr.restore_latest()
        assert manifest["step"] == 4 and manifest["extra"]["s"] == 4
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.arange(6.0).reshape(2, 3))
        assert restored["nest"]["b"].dtype == jnp.int32


def test_checkpoint_atomic_no_partial_dirs():
    with tempfile.TemporaryDirectory() as d:
        ck.save(d, 7, {"x": jnp.zeros(3)})
        assert not [f for f in os.listdir(d) if f.endswith(".tmp")]
        assert ck.latest_step(d) == 7


def test_train_resume_is_exact():
    """Train 6 steps straight vs 3 + checkpoint + restore + 3: identical."""
    cfg = registry()["granite-8b"][1]
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step_fn = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3)))

    def run(params, opt, pipe, n):
        for _ in range(n):
            params, opt, m = step_fn(params, opt, pipe.next())
        return params, opt, m

    pipe_a = TokenPipeline(cfg, 2, 32, seed=3)
    pa, oa, ma = run(params, opt, pipe_a, 6)

    pipe_b = TokenPipeline(cfg, 2, 32, seed=3)
    pb, ob, _ = run(params, opt, pipe_b, 3)
    with tempfile.TemporaryDirectory() as d:
        ck.save(d, 3, {"params": pb, "opt": ob},
                extra={"pipeline": pipe_b.state()})
        restored, manifest = ck.restore(d)
        pipe_c = TokenPipeline(cfg, 2, 32)
        pipe_c.restore(manifest["extra"]["pipeline"])
        pc, oc, mc = run(restored["params"], restored["opt"], pipe_c, 3)

    for la, lc in zip(jax.tree_util.tree_leaves(pa),
                      jax.tree_util.tree_leaves(pc)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lc))
    assert float(ma["loss"]) == pytest.approx(float(mc["loss"]), rel=1e-6)


def test_loss_decreases_on_learnable_data():
    cfg = registry()["qwen3-1.7b"][1]
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(1))
    opt = init_opt_state(params)
    step_fn = jax.jit(make_train_step(
        model, AdamWConfig(lr=5e-3, warmup_steps=2, total_steps=40)))
    pipe = TokenPipeline(cfg, 4, 64, seed=5)
    first = None
    for i in range(25):
        params, opt, m = step_fn(params, opt, pipe.next())
        if i == 0:
            first = float(m["loss"])
    assert float(m["loss"]) < first - 0.1, (first, float(m["loss"]))


def test_microbatched_grads_match_full_batch():
    cfg = registry()["granite-8b"][1]
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(2))
    opt = init_opt_state(params)
    pipe = TokenPipeline(cfg, 4, 32, seed=9)
    batch = pipe.next()
    _, _, m1 = jax.jit(make_train_step(model, AdamWConfig(), n_micro=1))(
        params, opt, batch)
    _, _, m2 = jax.jit(make_train_step(model, AdamWConfig(), n_micro=2))(
        params, opt, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=2e-3)
    assert float(m1["grad_norm"]) == pytest.approx(float(m2["grad_norm"]),
                                                   rel=2e-2)


def test_restore_falls_back_past_corrupt_latest():
    """Crash-mid-save residue: a truncated payload next to an intact
    ``latest`` pointer must restore the previous step with a warning,
    not raise (the rename is atomic, the pointer write is not — a crash
    between them, or a non-atomic filesystem, leaves exactly this)."""
    with tempfile.TemporaryDirectory() as d:
        ck.save(d, 1, {"x": jnp.arange(3.0)}, extra={"s": 1})
        ck.save(d, 2, {"x": jnp.arange(3.0) * 2}, extra={"s": 2})
        # truncate step_2's payload: half an npz is what a crash leaves
        npz = os.path.join(d, "step_2", "arrays.npz")
        with open(npz, "r+b") as f:
            f.truncate(os.path.getsize(npz) // 2)
        assert ck.latest_step(d) == 2  # the pointer still says 2
        with pytest.warns(RuntimeWarning, match="step_2"):
            tree, manifest = ck.restore(d)
        assert manifest["step"] == 1 and manifest["extra"]["s"] == 1
        np.testing.assert_array_equal(np.asarray(tree["x"]),
                                      np.arange(3.0))


def test_restore_falls_back_past_corrupt_manifest():
    with tempfile.TemporaryDirectory() as d:
        ck.save(d, 1, {"x": jnp.ones(2)}, extra={"s": 1})
        ck.save(d, 2, {"x": jnp.zeros(2)}, extra={"s": 2})
        with open(os.path.join(d, "step_2", "manifest.json"), "w") as f:
            f.write('{"step": 2, "keys"')  # truncated json
        with pytest.warns(RuntimeWarning):
            _, manifest = ck.restore(d)
        assert manifest["step"] == 1


def test_restore_explicit_step_still_raises_on_corruption():
    """An explicitly requested step must not be silently substituted."""
    with tempfile.TemporaryDirectory() as d:
        ck.save(d, 1, {"x": jnp.ones(2)})
        ck.save(d, 2, {"x": jnp.zeros(2)})
        npz = os.path.join(d, "step_2", "arrays.npz")
        with open(npz, "r+b") as f:
            f.truncate(8)
        with pytest.raises(Exception):
            ck.restore(d, step=2)


def test_restore_all_corrupt_raises():
    with tempfile.TemporaryDirectory() as d:
        ck.save(d, 1, {"x": jnp.ones(2)})
        npz = os.path.join(d, "step_1", "arrays.npz")
        with open(npz, "r+b") as f:
            f.truncate(4)
        with pytest.raises(RuntimeError, match="no restorable checkpoint"):
            ck.restore(d)
