"""TiLT core unit tests: IR semantics, boundary resolution, fusion
equivalence, grid conversions, continuous StreamRunner operation."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import boundary, compile as qc, fusion, ir
from repro.core.frontend import TStream
from repro.core.parallel import StreamRunner, partition_run
from repro.core.stream import (Event, EventStream, SnapshotGrid,
                               events_to_grid, grid_to_events)


def _grid(vals, valid=None, prec=1):
    v = jnp.asarray(vals, jnp.float32)
    m = jnp.ones(v.shape[0], bool) if valid is None else jnp.asarray(valid)
    return SnapshotGrid(value=v, valid=m, t0=0, prec=prec)


def _run(q, grids, out_len, **kw):
    exe = qc.compile_query(q.node, out_len=out_len, pallas=False, **kw)
    return partition_run(exe, grids, 0, 1)


# ---------------------------------------------------------------------------
# stream conversions
# ---------------------------------------------------------------------------

def test_events_to_grid_interval_semantics():
    # event (2, 5] is active at ticks 3,4,5 (prec 1)
    es = EventStream([Event(2, 5, 7.0)])
    g = events_to_grid(es, 0, 8, 1)
    assert np.asarray(g.valid).tolist() == [
        False, False, True, True, True, False, False, False]


def test_grid_roundtrip():
    es = EventStream([Event(0, 3, 1.0), Event(5, 6, 2.0), Event(6, 9, 3.0)])
    g = events_to_grid(es, 0, 10, 1)
    back = grid_to_events(g)
    assert [(e.start, e.end, e.payload) for e in back] == [
        (0, 3, 1.0), (5, 6, 2.0), (6, 9, 3.0)]


def test_overlapping_events_latest_wins():
    es = EventStream([Event(0, 10, 1.0), Event(3, 6, 2.0)])
    g = events_to_grid(es, 0, 10, 1)
    v = np.asarray(g.value)
    assert v[2] == 1.0 and v[4] == 2.0 and v[8] == 1.0


# ---------------------------------------------------------------------------
# boundary resolution (§5.1)
# ---------------------------------------------------------------------------

def test_boundary_trend_query():
    s = TStream.source("s", prec=1)
    q = (s.window(10).mean().join(s.window(20).mean(), lambda a, b: a - b)
         .where(lambda d: d > 0))
    b = boundary.resolve(q.node)
    assert b["s"].lookback == 20  # paper Fig. 3b: (Ts-20, Te]
    assert b["s"].lookahead == 0


def test_boundary_shift_and_lookahead():
    s = TStream.source("s", prec=1)
    q = s.shift(-5).join(s.shift(3), lambda a, b: a + b)
    b = boundary.resolve(q.node)
    assert b["s"].lookahead == 5
    assert b["s"].lookback == 3


def test_boundary_nested_windows_accumulate():
    s = TStream.source("s", prec=1)
    q = s.window(16).mean().window(32).max()
    b = boundary.resolve(q.node)
    assert b["s"].lookback == 48


# ---------------------------------------------------------------------------
# φ-semantics
# ---------------------------------------------------------------------------

def test_join_strict_overlap():
    a = _grid([1, 2, 3, 4], valid=[True, False, True, True])
    b = _grid([10, 20, 30, 40], valid=[True, True, False, True])
    q = TStream.source("a").join(TStream.source("b"), lambda x, y: x + y)
    out = _run(q, {"a": a, "b": b}, 4)
    assert np.asarray(out.valid).tolist() == [True, False, False, True]
    assert np.asarray(out.value)[[0, 3]].tolist() == [11.0, 44.0]


def test_where_nulls_not_filters_timeline():
    a = _grid([1, 2, 3, 4])
    q = TStream.source("a").where(lambda v: v % 2 == 0)
    out = _run(q, {"a": a}, 4)
    assert np.asarray(out.valid).tolist() == [False, True, False, True]


def test_reduce_empty_window_is_phi():
    a = _grid([1, 2, 3, 4], valid=[False, False, True, True])
    q = TStream.source("a").window(2).sum()
    out = _run(q, {"a": a}, 4)
    assert np.asarray(out.valid).tolist() == [False, False, True, True]
    assert np.asarray(out.value)[2] == 3.0   # only tick 3 valid in (1,3]
    assert np.asarray(out.value)[3] == 7.0


def test_coalesce_phi_aware():
    a = _grid([1, 2, 3, 4], valid=[True, False, True, False])
    b = _grid([9, 9, 9, 9])
    q = TStream.source("a").coalesce(TStream.source("b"))
    out = _run(q, {"a": a, "b": b}, 4)
    assert np.asarray(out.valid).all()
    assert np.asarray(out.value).tolist() == [1, 9, 3, 9]


# ---------------------------------------------------------------------------
# fusion (§5.2)
# ---------------------------------------------------------------------------

def test_fusion_preserves_semantics():
    rng = np.random.default_rng(5)
    a = _grid(rng.normal(size=64))
    s = TStream.source("a")
    q = (s.select(lambda v: v * 2).select(lambda v: v + 1)
         .where(lambda v: v > 0).select(lambda v: v * v))
    o1 = _run(q, {"a": a}, 64, opt=False)
    o2 = _run(q, {"a": a}, 64, opt=True)
    assert np.array_equal(np.asarray(o1.valid), np.asarray(o2.valid))
    np.testing.assert_allclose(
        np.asarray(o1.value)[np.asarray(o1.valid)],
        np.asarray(o2.value)[np.asarray(o2.valid)], rtol=1e-6)


def test_fusion_collapses_elemwise_chain():
    s = TStream.source("a")
    q = s.select(lambda v: v * 2).select(lambda v: v + 1).select(
        lambda v: -v)
    opt = fusion.optimize(q.node)
    maps = [n for n in ir.topo_order(opt) if isinstance(n, ir.Map)]
    assert len(maps) == 1, fusion.fusion_report(q.node, opt)


def test_cse_dedupes_shared_window():
    s = TStream.source("a")
    q1 = s.window(16).sum()
    q2 = s.window(16).sum()
    j = q1.join(q2, lambda x, y: x + y)
    opt = fusion.cse(j.node)
    reduces = [n for n in ir.topo_order(opt) if isinstance(n, ir.Reduce)]
    assert len(reduces) == 1


# ---------------------------------------------------------------------------
# continuous operation
# ---------------------------------------------------------------------------

def test_stream_runner_matches_batch():
    rng = np.random.default_rng(9)
    vals = rng.normal(size=256).astype(np.float32)
    s = TStream.source("a")
    q = s.window(20).mean().join(s.window(40).mean(), lambda x, y: x - y)

    exe_b = qc.compile_query(q.node, out_len=256, pallas=False)
    full = partition_run(exe_b, {"a": _grid(vals)}, 0, 1)

    exe_s = qc.compile_query(q.node, out_len=64, pallas=False)
    runner = StreamRunner(exe_s)
    outs = []
    for k in range(4):
        chunk = _grid(vals[k * 64:(k + 1) * 64])
        outs.append(runner.step({"a": chunk}))
    got_v = np.concatenate([np.asarray(o.value) for o in outs])
    got_m = np.concatenate([np.asarray(o.valid) for o in outs])
    assert np.array_equal(got_m, np.asarray(full.valid))
    np.testing.assert_allclose(got_v[got_m],
                               np.asarray(full.value)[np.asarray(full.valid)],
                               rtol=1e-5, atol=1e-5)


def test_stream_runner_checkpoint_resume():
    """Checkpoint a chunked run mid-stream, restore into a fresh runner:
    the continuation must be bit-identical (same jitted fn, same carried
    tail state — the host round-trip through state() must be lossless)."""
    rng = np.random.default_rng(11)
    vals = rng.normal(size=128).astype(np.float32)
    s = TStream.source("a")
    q = s.window(16).sum()
    exe = qc.compile_query(q.node, out_len=32, pallas=False)

    r1 = StreamRunner(exe)
    outs = [r1.step({"a": _grid(vals[:32])}),
            r1.step({"a": _grid(vals[32:64])})]
    state = r1.state()

    r2 = StreamRunner(exe)
    r2.restore(state)
    o_resumed = r2.step({"a": _grid(vals[64:96])})

    r3 = StreamRunner(exe)
    for k in range(3):
        o_straight = r3.step({"a": _grid(vals[k * 32:(k + 1) * 32])})
    assert o_resumed.t0 == o_straight.t0 == 64
    assert np.array_equal(np.asarray(o_resumed.valid),
                          np.asarray(o_straight.valid))
    assert np.array_equal(np.asarray(o_resumed.value),
                          np.asarray(o_straight.value))

    # restored runner keeps advancing identically past the checkpoint
    o4_resumed = r2.step({"a": _grid(vals[96:128])})
    o4_straight = r3.step({"a": _grid(vals[96:128])})
    assert np.array_equal(np.asarray(o4_resumed.value),
                          np.asarray(o4_straight.value))


def test_batch_run_multikey():
    """Per-key query execution (fraud per-user / YSB per-campaign): vmapped
    compiled query == per-key loop."""
    from repro.core.parallel import batch_run
    rng = np.random.default_rng(21)
    K, T = 5, 128
    vals = rng.normal(size=(K, T)).astype(np.float32)
    s = TStream.source("a")
    q = s.window(16).mean().join(s, lambda m, x: x - m).where(
        lambda d: d > 0)
    exe = qc.compile_query(q.node, out_len=T, pallas=False)

    g = {"a": SnapshotGrid(value=jnp.asarray(vals),
                           valid=jnp.ones((K, T), bool), t0=0, prec=1)}
    out = batch_run(exe, g)
    assert out.valid.shape == (K, T)

    for k in range(K):
        single = partition_run(
            exe, {"a": _grid(vals[k])}, 0, 1)
        assert np.array_equal(np.asarray(out.valid[k]),
                              np.asarray(single.valid)), k
        m = np.asarray(single.valid)
        np.testing.assert_allclose(np.asarray(out.value[k])[m],
                                   np.asarray(single.value)[m], rtol=1e-5)
