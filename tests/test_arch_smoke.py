"""Per-architecture smoke tests: reduced same-family config, one forward +
one train-grad step + one prefill/decode consistency check on CPU.

The FULL assigned configs are exercised only via the dry-run (lowering on
ShapeDtypeStructs, no allocation) — see launch/dryrun.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import registry
from repro.models.model import build_model

pytestmark = pytest.mark.slow  # ~2 min on 1 CPU core (all archs × steps)

ARCHS = sorted(registry())


def _smoke_cfg(arch):
    return registry()[arch][1]


def _batch(cfg, rng, B=2, S=32):
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            rng, (B, cfg.enc_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_shapes_and_finite(arch):
    cfg = _smoke_cfg(arch)
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params, axes = model.init(rng)
    # axes tree mirrors params tree
    p_leaves = jax.tree_util.tree_leaves(params)
    batch = _batch(cfg, rng)

    loss, grads = jax.jit(jax.value_and_grad(model.train_loss))(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss {loss}"
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch}: grad norm {gnorm}"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """Decoding token-by-token must match the full parallel forward."""
    cfg = _smoke_cfg(arch)
    model = build_model(cfg)
    rng = jax.random.PRNGKey(1)
    params, _ = model.init(rng)
    B, S = 2, 16
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab)

    if cfg.family == "encdec":
        frames = jax.random.normal(rng, (B, cfg.enc_seq, cfg.d_model),
                                   jnp.float32)
        from repro.models import encdec
        enc = encdec.forward_encoder(params, cfg, frames)
        full_logits, _ = encdec._decoder(params, cfg, tokens, enc)
        # prefill on the first half, decode the second half step by step
        half = S // 2
        logits_p, caches, enc_out = model.prefill(
            params, tokens[:, :half], frames, max_len=S)
        np.testing.assert_allclose(
            np.asarray(logits_p[:, -1]), np.asarray(full_logits[:, half - 1]),
            rtol=2e-2, atol=2e-2)
        for t in range(half, S):
            logits_d, caches = model.decode_step(
                params, caches, tokens[:, t:t + 1], jnp.int32(t), enc_out)
            np.testing.assert_allclose(
                np.asarray(logits_d[:, 0]), np.asarray(full_logits[:, t]),
                rtol=2e-2, atol=2e-2,
                err_msg=f"{arch}: decode step {t}")
        return

    from repro.models import transformer
    full_logits, _, _ = transformer.forward(params, cfg, tokens)
    half = S // 2
    logits_p, caches = model.prefill(params, tokens[:, :half], max_len=S)
    np.testing.assert_allclose(
        np.asarray(logits_p[:, -1]), np.asarray(full_logits[:, half - 1]),
        rtol=2e-2, atol=2e-2, err_msg=f"{arch}: prefill tail")
    for t in range(half, S):
        logits_d, caches = model.decode_step(
            params, caches, tokens[:, t:t + 1], jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0]), np.asarray(full_logits[:, t]),
            rtol=2e-2, atol=2e-2, err_msg=f"{arch}: decode step {t}")


def test_rwkv_chunked_matches_stepwise():
    """The chunk-parallel RWKV-6 form (EXPERIMENTS §Perf c.1) must be exact
    against the token-by-token recurrence, including the carried state."""
    import dataclasses
    import jax
    import jax.numpy as jnp
    from repro.models.recurrent import _rwkv_chunked

    rng = np.random.default_rng(0)
    B, T, H, K, L = 2, 96, 3, 8, 32
    mk = lambda: jnp.asarray(rng.normal(size=(B, T, H, K)), jnp.float32)
    r, k, v = mk(), mk(), mk()
    logw = jnp.asarray(-np.exp(rng.normal(-1.5, 1.0, (B, T, H, K))),
                       jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, K)), jnp.float32)
    S0 = jnp.asarray(rng.normal(size=(B, H, K, K)), jnp.float32) * 0.3

    S = S0
    outs = []
    for t in range(T):
        rt, kt, vt, wt = r[:, t], k[:, t], v[:, t], jnp.exp(logw[:, t])
        kv = kt[..., :, None] * vt[..., None, :]
        outs.append(jnp.einsum("bhk,bhkv->bhv", rt,
                               S + u[None, :, :, None] * kv))
        S = wt[..., :, None] * S + kv
    o_ref = jnp.stack(outs, 1)

    S_c, o_c = _rwkv_chunked(r, k, v, logw, S0, u, L)
    np.testing.assert_allclose(np.asarray(o_c), np.asarray(o_ref),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(S_c), np.asarray(S),
                               rtol=3e-4, atol=3e-4)


def test_seq_parallel_and_cache_dtype_smoke():
    """The §Perf levers must not change semantics (1-device mesh: hints are
    no-ops numerically; f8 cache quantization stays within tolerance)."""
    import dataclasses
    cfg0 = registry()["qwen3-1.7b"][1]
    model0 = build_model(cfg0)
    rng = jax.random.PRNGKey(0)
    params, _ = model0.init(rng)
    tokens = jax.random.randint(rng, (2, 16), 0, cfg0.vocab)
    from repro.models import transformer
    base, _, _ = transformer.forward(params, cfg0, tokens)

    cfg_sp = dataclasses.replace(cfg0, seq_parallel=True)
    sp, _, _ = transformer.forward(params, cfg_sp, tokens)
    np.testing.assert_allclose(np.asarray(base), np.asarray(sp),
                               rtol=1e-5, atol=1e-5)

    cfg_f8 = dataclasses.replace(cfg0, cache_dtype="float8_e4m3fn")
    model8 = build_model(cfg_f8)
    logits_p, caches = model8.prefill(params, tokens[:, :8], max_len=16)
    l8, caches = model8.decode_step(params, caches, tokens[:, 8:9],
                                    jnp.int32(8))
    # f8 cache: same argmax direction, looser numeric agreement
    lb, _, _ = transformer.forward(params, cfg0, tokens[:, :9])
    corr = np.corrcoef(np.asarray(l8[:, 0]).ravel(),
                       np.asarray(lb[:, -1]).ravel())[0, 1]
    assert corr > 0.98, corr
