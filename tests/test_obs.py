"""Unit tests for the telemetry substrate (repro/obs/).

The registry's one contract — accumulating never syncs, ``snapshot()`` is
the single device→host read — is asserted end-to-end in
tests/test_runner_hotpath.py under ``jax.transfer_guard``; here the metric
types, tracer and exporters are covered in isolation: get-or-create
semantics, histogram bucketing and quantile interpolation, device
fold/pending behaviour, the ``disabled()`` kill switch, span/compile
reports, and the schema-versioned JSONL/Prometheus round-trip.
"""
import json
import math
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.obs import (Metrics, counter_delta, disabled, export_jsonl,
                       export_prometheus, log_buckets, read_jsonl,
                       validate_snapshot)


# -- registry ---------------------------------------------------------------

def test_registry_get_or_create_and_type_guard():
    m = Metrics()
    c = m.counter("x.count", "help text", "items")
    assert m.counter("x.count") is c
    assert m.get("x.count") is c and m.get("missing") is None
    with pytest.raises(ValueError):
        m.gauge("x.count")
    m.drop("x.count")
    assert m.get("x.count") is None


def test_counter_host_device_and_pending_adds():
    m = Metrics()
    c = m.counter("c")
    c.add(2)
    c.add(3)
    assert c.value == 5
    # jax scalars queue as pending references (no eager device arithmetic)
    c.add(jnp.int32(7))
    c.add(jnp.int32(1))
    assert c._pending and c.value == 13
    c.fold_device()
    assert c._base == 13 and not c._pending and c._dev is None
    # set_device swaps in a jitted accumulator's running total
    c.set_device(jnp.int32(4))
    assert c.value == 17
    c.reset()
    assert c.value == 0


def test_counter_pending_collapse_stays_lazy():
    c = Metrics().counter("c")
    for _ in range(c._COLLAPSE + 5):
        c.add(jnp.int32(1))
    # collapsed into the lazy device part, remainder still pending
    assert c._dev is not None and len(c._pending) == 5
    assert c.value == c._COLLAPSE + 5


def test_gauge_and_vector():
    m = Metrics()
    g = m.gauge("g")
    g.set(2.5)
    assert g.value == 2.5
    v = m.vector("v", labels=["64", "128", "256"])
    v.add(1)
    v.add(1, 4)
    v.set_device(jnp.asarray([1, 0, 2]))
    assert v.values == [1, 5, 2]
    v.fold_device()
    assert v.values == [1, 5, 2]


def test_histogram_bucketing_and_quantiles():
    m = Metrics()
    h = m.histogram("h", edges=[1.0, 2.0, 4.0])
    for x in (0.5, 1.5, 1.5, 3.0, 100.0):
        h.observe(x)
    assert list(h.counts()) == [1, 2, 1, 1]
    snap = h.to_snapshot()
    assert snap["count"] == 5 and snap["sum"] == pytest.approx(106.5)
    # p99 falls in the overflow bucket → clamps to the top edge
    assert snap["p99"] == 4.0
    assert 1.0 <= snap["p50"] <= 2.0


def test_log_histogram_quantile_interpolates_geometrically():
    m = Metrics()
    edges = log_buckets(1e-4, 1.0, per_decade=1)
    h = m.histogram("lat", edges=edges, log_scale=True)
    for _ in range(100):
        h.observe(3e-3)  # all in the (1e-3, 1e-2] bucket
    p50 = h.quantile(0.5)
    assert 1e-3 <= p50 <= 1e-2
    # log interpolation: the quantile moves geometrically inside the bucket
    assert math.isclose(p50, 1e-3 * 10 ** 0.5, rel_tol=1e-6)
    assert m.histogram("lat", edges=edges) is h
    with pytest.raises(ValueError):
        Metrics().histogram("bad", edges=[2.0, 1.0])


def test_empty_histogram_quantiles_are_none():
    h = Metrics().histogram("h", edges=[1.0])
    assert h.quantile(0.5) is None
    assert h.to_snapshot()["p50"] is None


def test_disabled_makes_updates_noops():
    m = Metrics()
    c, g = m.counter("c"), m.gauge("g")
    h = m.histogram("h", edges=[1.0])
    with disabled():
        c.add(5)
        g.set(9)
        h.observe(0.5)
        assert not m.on
    assert c.value == 0 and g.value == 0 and int(h.counts().sum()) == 0
    assert m.on
    m.enabled = False
    assert not m.on


def test_counter_delta_between_snapshots():
    m = Metrics()
    c = m.counter("c")
    c.add(2)
    s0 = m.snapshot()
    c.add(5)
    s1 = m.snapshot()
    assert counter_delta(s0, s1, "c") == 5
    assert counter_delta(s0, s1, "absent") == 0


def test_collector_runs_before_snapshot():
    m = Metrics()
    m.register_collector("derived", lambda: m.gauge("d").set(42))
    assert m.snapshot()["gauges"]["d"]["value"] == 42
    # re-registering a name replaces the hook (session-rebuild path)
    m.register_collector("derived", lambda: m.gauge("d").set(7))
    assert m.snapshot()["gauges"]["d"]["value"] == 7


# -- tracer -----------------------------------------------------------------

def test_tracer_spans_nest_and_report():
    m = Metrics()
    t = m.tracer
    with t.span("rebuild"):
        with t.span("plan"):
            pass
        with t.span("plan"):
            pass
    rep = t.span_report()
    assert rep["rebuild"]["count"] == 1
    assert rep["rebuild/plan"]["count"] == 2
    assert rep["rebuild"]["total_s"] >= rep["rebuild/plan"]["total_s"]


def test_tracer_compile_counts_and_retraces():
    t = Metrics().tracer
    t.record_compile("step(a)")
    t.record_compile("step(b)")
    t.record_compile("step(b)")
    assert t.compiles() == {"step(a)": 1, "step(b)": 2}
    assert t.retraces() == {"step(b)": 1}
    rep = t.compile_report()
    assert rep["counts"]["step(b)"] == 2 and rep["retraces"] == {"step(b)": 1}


# -- snapshot + exporters ---------------------------------------------------

def _sample_metrics():
    m = Metrics()
    m.counter("runner.chunks", "chunks stepped").add(3)
    m.gauge("runner.compact").set(0.25)
    h = m.histogram("runner.step_seconds", log_buckets(1e-4, 1.0, 2),
                    "per-chunk latency", "s", log_scale=True)
    h.observe(2e-3)
    h.observe(8e-3)
    v = m.vector("runner.bucket_picks", labels=["1", "2", "4"])
    v.add(2, 5)
    with m.tracer.span("chunk"):
        pass
    m.tracer.record_compile("sparse_fused(K=1)")
    return m


def test_snapshot_schema_is_valid_and_sections_complete():
    snap = _sample_metrics().snapshot()
    assert snap["schema"] == obs.SCHEMA
    assert validate_snapshot(snap) == []
    assert snap["counters"]["runner.chunks"]["value"] == 3
    hist = snap["histograms"]["runner.step_seconds"]
    assert len(hist["counts"]) == len(hist["edges"]) + 1
    assert snap["vectors"]["runner.bucket_picks"]["values"][2] == 5
    assert snap["compiles"]["counts"] == {"sparse_fused(K=1)": 1}
    assert "chunk" in snap["spans"]


def test_validate_snapshot_flags_problems():
    snap = _sample_metrics().snapshot()
    assert validate_snapshot({"schema": "nope"})  # wrong schema + missing
    bad = json.loads(json.dumps(snap))
    bad["histograms"]["runner.step_seconds"]["counts"].append(1)
    assert any("counts" in p for p in validate_snapshot(bad))


def test_jsonl_round_trip(tmp_path):
    m = _sample_metrics()
    path = os.path.join(tmp_path, "metrics.jsonl")
    snap = m.snapshot()
    export_jsonl(snap, path)
    export_jsonl(m.snapshot(), path)
    back = read_jsonl(path)
    assert len(back) == 2
    assert back[0] == json.loads(json.dumps(snap))
    assert validate_snapshot(back[0]) == []


def test_prometheus_exposition_format():
    text = export_prometheus(_sample_metrics().snapshot())
    assert "# TYPE runner_chunks_total counter" in text
    assert "runner_chunks_total 3" in text
    assert "runner_compact 0.25" in text
    # histogram: cumulative buckets ending at +Inf, then _sum/_count
    assert 'runner_step_seconds_bucket{le="+Inf"} 2' in text
    assert "runner_step_seconds_count 2" in text
    assert 'runner_bucket_picks_total{slot="4"} 5' in text
    assert 'compiles_total{key="sparse_fused_K_1_"} 1' in text
    # buckets are cumulative (monotone non-decreasing)
    cum = [int(line.rsplit(" ", 1)[1]) for line in text.splitlines()
           if line.startswith("runner_step_seconds_bucket")]
    assert cum == sorted(cum)


def test_reset_clears_metrics_and_tracer():
    m = _sample_metrics()
    m.reset()
    snap = m.snapshot()
    assert snap["counters"]["runner.chunks"]["value"] == 0
    assert snap["histograms"]["runner.step_seconds"]["count"] == 0
    assert snap["compiles"]["counts"] == {} and snap["spans"] == {}


def test_reset_after_warmup_keeps_tracer_and_runs_hooks():
    """The post-warmup re-base zeroes every metric (so histograms window
    steady state only) and runs registered hooks, but must NOT clear the
    tracer: the warm-up compile counts are exactly the baseline the
    retrace detector compares steady state against."""
    m = _sample_metrics()
    calls = []
    m.register_warmup_reset("svc", lambda: calls.append("svc"))
    m.register_warmup_reset("svc", lambda: calls.append("svc2"))  # replaces
    m.reset_after_warmup()
    assert calls == ["svc2"]
    snap = m.snapshot()
    assert snap["counters"]["runner.chunks"]["value"] == 0
    assert snap["histograms"]["runner.step_seconds"]["count"] == 0
    assert snap["vectors"]["runner.bucket_picks"]["values"] == [0, 0, 0]
    assert snap["compiles"]["counts"] == {"sparse_fused(K=1)": 1}
    assert "chunk" in snap["spans"]
