"""KeyedEngine tests: K keyed sub-streams × time partitions must equal
per-key reference execution tick-for-tick (values and φ-validity), carry
halo state across partitions, and checkpoint/restore bit-identically."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compile as qc
from repro.core.frontend import TStream
from repro.core.parallel import batch_run, partition_run
from repro.core.stream import SnapshotGrid
from repro.data import apps as A
from repro.engine import KeyedEngine, keyed_grid

K, T, N_PARTS = 64, 256, 4

# keyed app variants sized so windows span partition boundaries (halo carry
# is actually exercised) and ysb's tumbling stride divides the part span
APP_PARAMS = {"trend": {}, "fraud": {"win": 60}, "ysb": {"win": 8}}


def _keyed_grids(app, seed=7):
    data = app.make_keyed_input(K, T, seed)
    out = {}
    for name, d in data.items():
        val = d["value"]
        v = ({k: jnp.asarray(a, jnp.float32) for k, a in val.items()}
             if isinstance(val, dict) else jnp.asarray(val, jnp.float32))
        out[name] = keyed_grid(v, d["valid"])
    return out


def _key_slice(grids, k):
    out = {}
    for name, g in grids.items():
        v = ({kk: vv[k] for kk, vv in g.value.items()}
             if isinstance(g.value, dict) else g.value[k])
        out[name] = SnapshotGrid(value=v, valid=g.valid[k], t0=g.t0,
                                 prec=g.prec)
    return out


@pytest.mark.parametrize("name", A.KEYED_APPS)
def test_keyed_engine_matches_per_key_partition_run(name):
    app = A.make_keyed_app(name, **APP_PARAMS[name])
    grids = _keyed_grids(app)
    out_len = (T // N_PARTS) // app.query.prec
    exe = qc.compile_query(app.query.node, out_len=out_len, pallas=False)

    eng = KeyedEngine(exe, n_keys=K)
    out = eng.run(grids, N_PARTS)
    assert out.valid.shape == (K, out_len * N_PARTS)

    for k in range(0, K, 7):  # spot-check keys across the range
        ref = partition_run(exe, _key_slice(grids, k), 0, N_PARTS)
        assert np.array_equal(np.asarray(out.valid[k]),
                              np.asarray(ref.valid)), (name, k)
        m = np.asarray(ref.valid)
        if isinstance(ref.value, dict):
            for kk in ref.value:
                np.testing.assert_allclose(
                    np.asarray(out.value[kk][k])[m],
                    np.asarray(ref.value[kk])[m], rtol=1e-5, atol=1e-5)
        else:
            np.testing.assert_allclose(np.asarray(out.value[k])[m],
                                       np.asarray(ref.value)[m],
                                       rtol=1e-5, atol=1e-5)


def test_keyed_engine_carries_halo_across_partitions():
    """Chunked keyed output must equal one-shot keyed output — only true
    when the per-key halo tails are carried correctly."""
    app = A.make_keyed_app("trend")
    grids = _keyed_grids(app)
    exe_chunk = qc.compile_query(app.query.node, out_len=T // N_PARTS,
                                 pallas=False)
    chunked = KeyedEngine(exe_chunk, n_keys=K).run(grids, N_PARTS)

    exe_full = qc.compile_query(app.query.node, out_len=T, pallas=False)
    oneshot = KeyedEngine(exe_full, n_keys=K).run(grids, 1)
    assert np.array_equal(np.asarray(chunked.valid), np.asarray(oneshot.valid))
    m = np.asarray(oneshot.valid)
    # float32 window sums over ~100-valued walks differ in association
    # between chunk sizes; the diff-of-means output cancels to ~1e-2, so
    # tolerance is absolute (exactness vs. the same-partitioning reference
    # is asserted tick-for-tick in the per-key test above)
    np.testing.assert_allclose(np.asarray(chunked.value)[m],
                               np.asarray(oneshot.value)[m],
                               rtol=2e-3, atol=2e-3)


def test_keyed_engine_checkpoint_restore_bit_identical():
    app = A.make_keyed_app("fraud", win=60)
    grids = _keyed_grids(app)
    core = T // N_PARTS
    exe = qc.compile_query(app.query.node, out_len=core, pallas=False)

    def chunk(j):
        return {name: SnapshotGrid(
            value=g.value[:, j * core:(j + 1) * core],
            valid=g.valid[:, j * core:(j + 1) * core],
            t0=j * core, prec=1) for name, g in grids.items()}

    r1 = KeyedEngine(exe, n_keys=K)
    r1.step(chunk(0))
    r1.step(chunk(1))
    state = r1.state()  # mid-stream checkpoint (host arrays)

    r2 = KeyedEngine(exe, n_keys=K)
    r2.restore(state)
    o_resumed = r2.step(chunk(2))
    o_straight = r1.step(chunk(2))
    assert o_resumed.t0 == o_straight.t0
    assert np.array_equal(np.asarray(o_resumed.valid),
                          np.asarray(o_straight.valid))
    assert np.array_equal(np.asarray(o_resumed.value),
                          np.asarray(o_straight.value))


def test_keyed_engine_matches_batch_run_single_partition():
    """One partition with zero carried state == the vmapped batch_run."""
    rng = np.random.default_rng(2)
    vals = rng.normal(size=(K, T)).astype(np.float32)
    s = TStream.source("a", keyed=True)
    q = s.window(16).mean().join(s, lambda m, x: x - m).where(lambda d: d > 0)
    exe = qc.compile_query(q.node, out_len=T, pallas=False)
    g = {"a": keyed_grid(vals, np.ones((K, T), bool))}
    out_e = KeyedEngine(exe, n_keys=K).run(g, 1)
    out_b = batch_run(exe, g)
    assert np.array_equal(np.asarray(out_e.valid), np.asarray(out_b.valid))
    m = np.asarray(out_b.valid)
    np.testing.assert_allclose(np.asarray(out_e.value)[m],
                               np.asarray(out_b.value)[m], rtol=1e-6)


def test_keyed_engine_rejects_mixed_keyed_unkeyed():
    a = TStream.source("a", keyed=True)
    b = TStream.source("b")  # unkeyed
    q = a.join(b, lambda x, y: x + y)
    exe = qc.compile_query(q.node, out_len=32, pallas=False)
    with pytest.raises(ValueError, match="keyed"):
        KeyedEngine(exe, n_keys=8)


def test_keyed_engine_step_shape_check_is_real_exception():
    """Chunk-shape validation must survive ``python -O`` (ValueError, not
    assert)."""
    s = TStream.source("a", keyed=True)
    exe = qc.compile_query(s.window(8).mean().node, out_len=16, pallas=False)
    eng = KeyedEngine(exe, n_keys=4)
    bad = {"a": keyed_grid(np.zeros((4, 15), np.float32),
                           np.ones((4, 15), bool))}
    with pytest.raises(ValueError, match="chunk validity shape"):
        eng.step(bad)


def test_keyed_engine_rejects_lookahead():
    s = TStream.source("a", keyed=True)
    q = s.shift(-4)  # lookahead
    exe = qc.compile_query(q.node, out_len=32, pallas=False)
    with pytest.raises(NotImplementedError, match="lookahead"):
        KeyedEngine(exe, n_keys=8)


# -- restore() validation: every checkpoint/engine mismatch must raise a
#    clear ValueError up front, not an opaque shape error in the next step


def _ckpt_engine(n_keys=8, sparse=False):
    s = TStream.source("a", keyed=True)
    exe = qc.compile_query(s.window(16).mean().node, out_len=32,
                           pallas=False, sparse=sparse)
    eng = KeyedEngine(exe, n_keys=n_keys, sparse=sparse)
    chunk = {"a": keyed_grid(np.ones((n_keys, 32), np.float32),
                             np.ones((n_keys, 32), bool))}
    eng.step(chunk)
    return exe, eng


def test_restore_rejects_wrong_key_count():
    exe, eng = _ckpt_engine(n_keys=8)
    other = KeyedEngine(exe, n_keys=4)
    with pytest.raises(ValueError, match=r"tail shape.*n_keys"):
        other.restore(eng.state())


def test_restore_rejects_unknown_input_names():
    exe, eng = _ckpt_engine()
    state = eng.state()
    state["bogus"] = state.pop("a")
    with pytest.raises(ValueError, match="unknown=\\['bogus'\\]"):
        KeyedEngine(exe, n_keys=8).restore(state)


def test_restore_rejects_wrong_tail_length():
    """A checkpoint from a different query plan (different halo) must be
    named as such, not fail later inside the jitted step."""
    exe, eng = _ckpt_engine()
    s = TStream.source("a", keyed=True)
    exe64 = qc.compile_query(s.window(64).mean().node, out_len=32,
                             pallas=False)
    with pytest.raises(ValueError, match="left_halo"):
        KeyedEngine(exe64, n_keys=8).restore(eng.state())


def test_restore_rejects_misaligned_stream_clock():
    exe, eng = _ckpt_engine()
    state = eng.state()
    state["__t"] = 17  # not a multiple of the 32-tick partition span
    with pytest.raises(ValueError, match="stream clock"):
        KeyedEngine(exe, n_keys=8).restore(state)
    state["__t"] = -32
    with pytest.raises(ValueError, match="stream clock"):
        KeyedEngine(exe, n_keys=8).restore(state)


def test_restore_rejects_sparse_dense_mismatch():
    exe_s, eng_s = _ckpt_engine(sparse=True)
    exe_d, eng_d = _ckpt_engine(sparse=False)
    with pytest.raises(ValueError, match="dense engine cannot restore"):
        KeyedEngine(exe_d, n_keys=8).restore(eng_s.state())
    with pytest.raises(ValueError, match="sparse engine cannot restore"):
        KeyedEngine(exe_s, n_keys=8, sparse=True).restore(eng_d.state())
