"""Shared geometry + query for the corpus fixtures (small: audits fast)."""
from repro.core import compile as qc
from repro.core.frontend import TStream

SEG = 16
SPC = 4


def trend_query(keyed: bool = False):
    s = TStream.source("in", prec=1, keyed=keyed)
    return (s.window(8).mean()
            .join(s.window(16).mean(), lambda a, b: a - b)
            .where(lambda d: d > 0))


def trend_exe(keyed: bool = False):
    return qc.compile_query(trend_query(keyed).node, out_len=SEG,
                            pallas=False, sparse=True)
