"""Known-bad: post-step K-axis strip via ``x[0]`` instead of reshape.

The PR6 bug class — eager indexing on the chunk path binds slice eqns
(with host-bound start scalars at runtime) outside the staged step, a
device→host sync per chunk.  The transfer pass must flag every such eqn
in the whole-chunk jaxpr as ``eager-op-outside-staged-step``.
"""
import jax

from repro.analysis import make_target
from repro.engine import ExecPolicy, Runner

from ._common import SPC, trend_exe

_tm = jax.tree_util.tree_map


class EagerStripRunner(Runner):
    """Shipped runner, except the single-key strip indexes instead of
    reshaping (exactly the pre-PR6 code)."""

    def _postprocess(self, outs):
        if self.policy.keyed:
            return outs
        return {o: (_tm(lambda x: x[0], v), m[0])
                for o, (v, m) in outs.items()}


def target():
    r = EagerStripRunner(trend_exe(), ExecPolicy(body="sparse"),
                         segs_per_chunk=SPC)
    return make_target(r, policy="corpus:eager_strip")
