"""Known-bad: 1-tick ``prev`` snapshots carried for halo-carrying inputs.

The pre-PR7 class — the fused step only ever reads ``prev[name]`` for
halo-free inputs (halo-carrying inputs get tick 0's change flag from the
dirty tail), so snapshots created for every input ride the donated state
pytree without a single read or a pass-through output.  The donation pass
must flag each such leaf as ``donated-leaf-dead``.
"""
import jax
import jax.numpy as jnp

from repro.analysis import make_target
from repro.engine import ExecPolicy, Runner

from ._common import SPC, trend_exe

_tm = jax.tree_util.tree_map


class DeadPrevRunner(Runner):
    """Shipped runner, except state init snapshots *every* input (the
    pre-PR7 behaviour), not just the halo-free ones that are read."""

    def _init_missing_tails(self, chunk_in):
        super()._init_missing_tails(chunk_in)
        if self._sparse is None:
            return
        K = self._K
        for name in self._names():
            if name in self._sparse["prev"]:
                continue
            cv, cm = chunk_in[name]
            self._sparse["prev"][name] = (
                _tm(lambda x: jnp.zeros((K, 1) + x.shape[2:], x.dtype), cv),
                jnp.zeros((K, 1), bool))


def target():
    r = DeadPrevRunner(trend_exe(), ExecPolicy(body="sparse"),
                       segs_per_chunk=SPC)
    return make_target(r, policy="corpus:dead_donation")
