"""Regression corpus for the static auditor (``repro.analysis``).

Each module seeds exactly one known-bad pattern — the bug classes earlier
PRs found by hand, plus the hazards the hot path is designed around — and
exposes ``target()`` returning an :class:`repro.analysis.AuditTarget`
ready for the pass under test:

* ``eager_strip``     — the PR6 class: post-step K-axis strip via ``x[0]``
  (eager slice) instead of a metadata-only reshape → transfer pass.
* ``dead_donation``   — the pre-PR7 class: 1-tick ``prev`` snapshots
  carried (and donated) for halo-carrying inputs that never read them
  → donation pass.
* ``cond_collective`` — ``ppermute`` under a ``lax.cond`` branch inside
  ``shard_map`` → collective pass.
* ``under_keyed``     — a staging-cache key that drops the ``n_segs``
  degree of freedom → recompile pass (DOF probe).
* ``under_dilated``   — a ChangePlan with halved lookback dilation
  → temporal-plan verifier.

``tests/test_analysis.py`` asserts each pass fires on its fixture and
stays silent on the shipped runner at the same policy point.
"""
