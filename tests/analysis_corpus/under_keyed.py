"""Known-bad: a staging-cache key missing a configuration DOF.

The key drops ``n_segs``: two runners over the same compiled query with
different chunk geometries land on the same cache slot, so the second
silently retraces (or worse, reuses an executable traced for the wrong
shapes).  The recompile pass's DOF probe — perturb ``segs_per_chunk`` on
a sibling, check the key moves — must flag
``staging-key-under-captures``."""
from repro.analysis import make_target
from repro.engine import ExecPolicy, Runner

from ._common import SPC, trend_exe


class UnderKeyedRunner(Runner):
    """Shipped runner, except the staging key forgets chunk geometry."""

    def _cache_key(self, kind, *extra):
        d = self.staging_key_dofs()
        return (kind, d["K"], d["mesh"], d["axis"], d["jit"]) + extra


def target():
    r = UnderKeyedRunner(trend_exe(), ExecPolicy(body="sparse"),
                         segs_per_chunk=SPC)
    return make_target(r, policy="corpus:under_keyed")
