"""Known-bad: a collective under divergent control.

A ``ppermute`` inside a ``lax.cond`` branch inside ``shard_map``: shards
whose predicates disagree take different branches, and the ones entering
the collective wait forever on the ones that didn't.  The collective pass
must flag it as ``collective-under-divergence`` (on any device count —
the divergence is structural, visible in the traced jaxpr)."""
import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.analysis import AuditTarget


def _body(flag, x):
    return lax.cond(flag,
                    lambda v: lax.ppermute(v, "data", [(0, 0)]),
                    lambda v: v, x)


def target():
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    step = jax.jit(shard_map(_body, mesh=mesh, in_specs=(P(), P("data")),
                             out_specs=P("data")))
    args = (jnp.array(True), jnp.ones((1, 8), jnp.float32))
    return AuditTarget(
        runner=None, policy="corpus:cond_collective",
        steps=[{"label": "exchange", "key": ("exchange",), "fn": step,
                "raw": _body, "donate": (), "args": args}],
        chunk_variants=())
