"""Known-bad: a ChangePlan whose lookback dilation is half what the IR
demands.

An input change inside the uncovered half of the lineage window never
marks the affected segment dirty — the sparse executor skips it and
serves a stale output marked clean.  The temporal-plan verifier, working
from its *independently re-derived* demand, must flag
``changeplan-under-dilated`` (and the affine lowering check
``dilation-misses-segments`` at the runner's geometry)."""
import dataclasses

from repro.analysis import AuditTarget
from repro.engine import ExecPolicy, Runner
from repro.engine.runner import body_spec_of

from ._common import SPC, trend_exe


def target():
    spec = body_spec_of(trend_exe())
    cp = spec.change_plan
    halved = dataclasses.replace(cp, specs={
        name: dataclasses.replace(sp, lookback=sp.lookback // 2)
        for name, sp in cp.specs.items()})
    bad = dataclasses.replace(spec, change_plan=halved, step_cache={})
    r = Runner(bad, ExecPolicy(body="sparse"), segs_per_chunk=SPC)
    # the plan verifier never traces steps — no need to stage any
    return AuditTarget(runner=r, policy="corpus:under_dilated",
                       steps=[], chunk_variants=())
