"""Property-based tests (hypothesis) on the system's invariants.

The central invariant of the paper: *partitioned execution over resolved
boundaries equals unpartitioned execution* — for arbitrary queries, data,
partition sizes.  Hypothesis generates random query DAGs and random
streams; we assert bit-level mask equality and tolerance-level value
equality between 1-partition and n-partition runs, and between optimized
and unoptimized IR.
"""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import compile as qc, fusion, ir
from repro.core.frontend import TStream
from repro.core.parallel import partition_run
from repro.core.stream import SnapshotGrid

MAX_EXAMPLES = 25


def _grid(vals, valid):
    return SnapshotGrid(value=jnp.asarray(vals, jnp.float32),
                        valid=jnp.asarray(valid), t0=0, prec=1)


@st.composite
def random_query(draw):
    """A random TiLT query over one input stream, depth ≤ 4."""
    s = TStream.source("in", prec=1)
    q = s
    depth = draw(st.integers(1, 4))
    for _ in range(depth):
        kind = draw(st.sampled_from(
            ["select", "where", "shift", "wsum", "wmean", "wmax", "join"]))
        if kind == "select":
            c = draw(st.floats(-2, 2, allow_nan=False))
            q = q.select(lambda v, c=c: v * c + 1.0)
        elif kind == "where":
            thr = draw(st.floats(-1, 1, allow_nan=False))
            q = q.where(lambda v, t=thr: v > t)
        elif kind == "shift":
            d = draw(st.integers(0, 7))
            q = q.shift(d)
        elif kind == "wsum":
            w = draw(st.integers(2, 24))
            q = q.window(w).sum()
        elif kind == "wmean":
            w = draw(st.integers(2, 24))
            q = q.window(w).mean()
        elif kind == "wmax":
            w = draw(st.integers(2, 24))
            q = q.window(w).max()
        else:  # join with a shifted copy of itself
            d = draw(st.integers(1, 5))
            q = q.join(s.shift(d), lambda a, b: a - b)
    return q


@st.composite
def random_stream(draw, n):
    vals = draw(st.lists(
        st.floats(-100, 100, allow_nan=False, width=32),
        min_size=n, max_size=n))
    valid = draw(st.lists(st.booleans(), min_size=n, max_size=n))
    return np.asarray(vals, np.float32), np.asarray(valid)


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(q=random_query(), data=random_stream(n=96),
       n_parts=st.sampled_from([2, 3, 4, 8]))
def test_partition_invariance(q, data, n_parts):
    """paper §5.1/§6.2: partitioning at resolved boundaries is exact."""
    vals, valid = data
    N = 96
    g = {"in": _grid(vals, valid)}
    full = partition_run(qc.compile_query(q.node, out_len=N, pallas=False),
                        g, 0, 1)
    part = partition_run(
        qc.compile_query(q.node, out_len=N // n_parts, pallas=False),
        g, 0, n_parts)
    m1, m2 = np.asarray(full.valid), np.asarray(part.valid)
    assert np.array_equal(m1, m2)
    v1, v2 = np.asarray(full.value), np.asarray(part.value)
    np.testing.assert_allclose(v1[m1], v2[m1], rtol=1e-4, atol=1e-4)


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(q=random_query(), data=random_stream(n=64))
def test_fusion_invariance(q, data):
    """§5.2 IR transformations are semantics-preserving."""
    vals, valid = data
    g = {"in": _grid(vals, valid)}
    o1 = partition_run(
        qc.compile_query(q.node, out_len=64, pallas=False, opt=False),
        g, 0, 1)
    o2 = partition_run(
        qc.compile_query(q.node, out_len=64, pallas=False, opt=True),
        g, 0, 1)
    assert np.array_equal(np.asarray(o1.valid), np.asarray(o2.valid))
    m = np.asarray(o1.valid)
    np.testing.assert_allclose(np.asarray(o1.value)[m],
                               np.asarray(o2.value)[m],
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(data=random_stream(n=128), w=st.integers(2, 32))
def test_sliding_sum_matches_convolve(data, w):
    vals, valid = data
    g = {"in": _grid(vals, valid)}
    q = TStream.source("in").window(w).sum()
    out = partition_run(qc.compile_query(q.node, out_len=128, pallas=False),
                        g, 0, 1)
    masked = np.where(valid, vals.astype(np.float64), 0.0)
    want = np.convolve(masked, np.ones(w))[:128]
    cnt = np.convolve(valid.astype(np.float64), np.ones(w))[:128]
    m = np.asarray(out.valid)
    assert np.array_equal(m, cnt > 0)
    np.testing.assert_allclose(np.asarray(out.value)[m], want[m],
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# structural fingerprints (multi-query sharing)
# ---------------------------------------------------------------------------
#
# Queries are generated from explicit *recipes* (step lists) so that
# structural equality is decidable by construction: by design every step
# kind/parameter combination maps to a distinct IR structure, so two DAGs
# built from recipes are structurally equal iff the recipes are equal.
# Parameters are compared by repr (so -0.0 vs 0.0 stays consistent with the
# fingerprint encoding).

def _step_select(q, s, c):
    return q.select(lambda v, c=c: v * c + 1.0)


def _step_where(q, s, t):
    return q.where(lambda v, t=t: v > t)


def _step_shift(q, s, d):
    return q.shift(d)


def _step_wsum(q, s, w):
    return q.window(w).sum()


def _step_wmean(q, s, w):
    return q.window(w).mean()


def _step_wmax(q, s, w):
    return q.window(w).max()


def _step_join(q, s, d):
    return q.join(s.shift(d), lambda a, b: a - b)


_STEPS = {"select": _step_select, "where": _step_where, "shift": _step_shift,
          "wsum": _step_wsum, "wmean": _step_wmean, "wmax": _step_wmax,
          "join": _step_join}


@st.composite
def query_recipe(draw):
    depth = draw(st.integers(1, 4))
    steps = []
    for _ in range(depth):
        kind = draw(st.sampled_from(sorted(_STEPS)))
        if kind in ("select", "where"):
            p = repr(draw(st.floats(-2, 2, allow_nan=False)))
        elif kind in ("wsum", "wmean", "wmax"):
            p = repr(draw(st.integers(2, 24)))
        else:
            p = repr(draw(st.integers(0, 7)))
        steps.append((kind, p))
    return tuple(steps)


def _build(recipe):
    s = TStream.source("in", prec=1)
    q = s
    for kind, p in recipe:
        q = _STEPS[kind](q, s, eval(p))
    return q.node


@settings(max_examples=60, deadline=None)
@given(r1=query_recipe(), r2=query_recipe())
def test_fingerprint_equality_iff_structural_equality(r1, r2):
    """fingerprint(a) == fingerprint(b)  ⇔  a, b structurally equal."""
    a, b = _build(r1), _build(r2)
    assert (ir.fingerprint(a) == ir.fingerprint(b)) == (r1 == r2)


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(r=query_recipe())
def test_fingerprint_deterministic_across_rebuilds(r):
    """Rebuilding the same recipe (fresh lambdas, fresh node ids, fresh
    auto-generated names) must reproduce the fingerprint exactly — no id()
    or construction-order leaks."""
    assert ir.fingerprint(_build(r)) == ir.fingerprint(_build(r))


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(data=random_stream(n=64), d=st.integers(0, 10))
def test_shift_identity(data, d):
    """shift(d) then compare against numpy roll with φ fill."""
    vals, valid = data
    g = {"in": _grid(vals, valid)}
    q = TStream.source("in").shift(d)
    out = partition_run(qc.compile_query(q.node, out_len=64, pallas=False),
                        g, 0, 1)
    m = np.asarray(out.valid)
    want_m = np.concatenate([np.zeros(d, bool), valid])[:64]
    assert np.array_equal(m, want_m)
    want_v = np.concatenate([np.zeros(d, np.float32), vals])[:64]
    np.testing.assert_allclose(np.asarray(out.value)[m], want_v[m])
