"""Sparse hot-path guarantees of the unified Runner.

Two properties the fused sparse step is built around, asserted directly:

* **Zero device→host transfers in steady state.**  Mask, dilation, the
  capacity-bucket pick (``searchsorted`` over the ladder + ``lax.switch``)
  and the compacted compute all run inside one jitted step, so once the
  stream is started a chunk dispatch never syncs — guarded here with
  ``jax.transfer_guard("disallow")`` around a steady-state step on
  device-resident chunks.
* **State donation.**  The steady-state step donates the carried state
  pytree (halo tails, dirty tails, 1-tick snapshots, hold seeds), so the
  buffers update in place: after a step, the previous state's arrays are
  deleted (consumed), not merely dereferenced.

Diagnostics stay device-resident too: the metrics registry accumulates
through the guarded steps without syncing (``Metrics.snapshot()`` /
``dirty_stats()`` are the explicit off-path reads), and the tracer's
compile counter pins exactly one compile per (policy, geometry) staging
key across repeated chunks — a retrace would show up as a count > 1.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compile as qc
from repro.core.frontend import TStream
from repro.core.stream import SnapshotGrid
from repro.engine import ExecPolicy, Runner, keyed_grid

SEG = 32
SPC = 4
SPAN = SEG * SPC


def _query(keyed: bool = False):
    s = TStream.source("in", prec=1, keyed=keyed)
    return (s.window(16).mean()
            .join(s.window(32).mean(), lambda a, b: a - b)
            .where(lambda d: d > 0))


def _exe(keyed: bool = False):
    return qc.compile_query(_query(keyed).node, out_len=SEG, pallas=False,
                            sparse=True)


def _device_chunks(n_chunks: int, seed: int = 3):
    """Pre-committed device-resident chunks (piecewise-constant stream) so
    stepping through them cannot require a host→device transfer."""
    rng = np.random.default_rng(seed)
    n = n_chunks * SPAN
    change = rng.random(n) < 0.03
    change[0] = True
    raw = np.floor(rng.random(n) * 100).astype(np.float32)
    vals = raw[np.maximum.accumulate(np.where(change, np.arange(n), -1))]
    chunks = []
    for c in range(n_chunks):
        sl = slice(c * SPAN, (c + 1) * SPAN)
        g = SnapshotGrid(value=jnp.asarray(vals[sl]),
                         valid=jnp.ones(SPAN, bool), t0=c * SPAN, prec=1)
        jax.block_until_ready((g.value, g.valid))
        chunks.append({"in": g})
    return chunks


def test_steady_state_sparse_chunk_issues_zero_transfers():
    r = Runner(_exe(), ExecPolicy(body="sparse"), segs_per_chunk=SPC)
    chunks = _device_chunks(4)
    # warm both step variants: chunk 0 runs the force-first (stream start)
    # trace, chunk 1 compiles the steady-state donating trace
    jax.block_until_ready(r.step(chunks[0]).valid)
    jax.block_until_ready(r.step(chunks[1]).valid)
    with jax.transfer_guard("disallow"):
        out = r.step(chunks[2])
        jax.block_until_ready(out.valid)
    # the diagnostics accumulated without syncing; reading them is the one
    # transfer, and it still reflects every chunk run
    stats = r.dirty_stats()
    assert stats["chunks"] == 3 and stats["units"] == 3 * SPC
    # same numbers through the metrics registry (snapshot = the one read),
    # plus the per-chunk latency histogram and capacity-bucket picks the
    # compat wrapper doesn't carry
    snap = r.metrics.snapshot()
    assert snap["counters"]["runner.chunks"]["value"] == 3
    assert snap["counters"]["runner.units"]["value"] == 3 * SPC
    assert (snap["counters"]["runner.dirty_units"]["value"]
            == stats["dirty_units"])
    assert snap["histograms"]["runner.step_seconds"]["count"] == 3
    assert sum(snap["vectors"]["runner.bucket_picks"]["values"]) == 3


def test_steady_state_sparse_chunk_zero_transfers_keyed():
    K = 8
    r = Runner(_exe(keyed=True), ExecPolicy(body="sparse", keys="vmapped"),
               n_keys=K, segs_per_chunk=SPC)
    rng = np.random.default_rng(5)
    vals = np.broadcast_to(
        rng.integers(0, 9, size=(K, 1)).astype(np.float32),
        (K, 3 * SPAN)).copy()
    vals[0] = np.floor(rng.random(3 * SPAN) * 100)  # one active key
    chunks = []
    for c in range(3):
        g = keyed_grid(vals[:, c * SPAN:(c + 1) * SPAN],
                       np.ones((K, SPAN), bool), t0=c * SPAN)
        jax.block_until_ready((g.value, g.valid))
        chunks.append({"in": g})
    jax.block_until_ready(r.step(chunks[0]).valid)
    jax.block_until_ready(r.step(chunks[1]).valid)
    with jax.transfer_guard("disallow"):
        out = r.step(chunks[2])
        jax.block_until_ready(out.valid)
    assert r.dirty_stats()["units"] == 3 * K * SPC


def _state_leaves(r):
    # everything the steady-state step donates: halo tails, dirty tails,
    # hold seeds, and the 1-tick `prev` snapshots (which exist exactly for
    # the halo-free inputs that read them, so donation always consumes)
    st = r._sparse
    return jax.tree_util.tree_leaves(
        (r._tails, st["dirty"], st["seed"], st["prev"]))


@pytest.mark.skipif(jax.default_backend() not in ("cpu", "tpu", "gpu"),
                    reason="needs a backend with buffer donation")
def test_steady_state_sparse_step_donates_state_buffers():
    r = Runner(_exe(), ExecPolicy(body="sparse"), segs_per_chunk=SPC)
    chunks = _device_chunks(4, seed=9)
    jax.block_until_ready(r.step(chunks[0]).valid)   # force-first (no donate)
    jax.block_until_ready(r.step(chunks[1]).valid)   # first steady-state step
    old = _state_leaves(r)
    jax.block_until_ready(r.step(chunks[2]).valid)   # consumes `old`
    assert all(x.is_deleted() for x in old), (
        "steady-state sparse step must donate the carried state pytree")
    # the runner's live state was rebuilt, not aliased to the dead buffers
    new = _state_leaves(r)
    assert all(not x.is_deleted() for x in new)
    jax.block_until_ready(r.step(chunks[3]).valid)


def test_dense_step_donates_tails():
    exe = qc.compile_query(_query().node, out_len=SEG, pallas=False)
    r = Runner(exe, ExecPolicy(body="dense"), segs_per_chunk=SPC)
    chunks = _device_chunks(3, seed=1)
    jax.block_until_ready(r.step(chunks[0]).valid)
    old = jax.tree_util.tree_leaves(r._tails)
    jax.block_until_ready(r.step(chunks[1]).valid)
    assert all(x.is_deleted() for x in old)


def test_exactly_one_compile_per_policy_geometry_key():
    """The recompile detector must see every staging key compiled exactly
    once across repeated chunks — the step_cache holds one step per
    (policy, geometry) point, so a second compile of any key means the
    cache was dropped and the step re-staged (a retrace)."""
    r = Runner(_exe(), ExecPolicy(body="sparse"), segs_per_chunk=SPC)
    for c in _device_chunks(6, seed=21):
        jax.block_until_ready(r.step(c).valid)
    snap = r.metrics.snapshot()
    counts = snap["compiles"]["counts"]
    # both sparse step variants staged (force-first + steady-state), the
    # capacity-ladder compute buckets, and the metric accumulator
    assert any(k.startswith("sparse_fused(") for k in counts), counts
    assert any(k.startswith("compute(") for k in counts), counts
    assert all(n == 1 for n in counts.values()), counts
    assert snap["compiles"]["retraces"] == {}, counts


def test_prev_snapshots_exist_and_donate_for_halo_free_inputs_only():
    """1-tick `prev` snapshots are kept exactly for halo-free inputs (the
    only ones whose change detection reads them — halo-carrying inputs
    diff tick 0 against the tail instead), and the steady-state step
    donates them through like the rest of the carried state."""
    a = TStream.source("a", prec=1)
    b = TStream.source("b", prec=1)
    q = a.window(16).mean().join(b, lambda m, x: x - m)
    exe = qc.compile_query(q.node, out_len=SEG, pallas=False, sparse=True)
    assert exe.input_specs["a"].left_halo > 0
    assert exe.input_specs["b"].left_halo == 0
    r = Runner(exe, ExecPolicy(body="sparse"), segs_per_chunk=SPC)

    rng = np.random.default_rng(17)

    def chunk(c):
        g = {}
        for nm in ("a", "b"):
            sg = SnapshotGrid(
                value=jnp.asarray(
                    np.floor(rng.random(SPAN) * 10).astype(np.float32)),
                valid=jnp.ones(SPAN, bool), t0=c * SPAN, prec=1)
            jax.block_until_ready((sg.value, sg.valid))
            g[nm] = sg
        return g

    chunks = [chunk(c) for c in range(4)]
    jax.block_until_ready(r.step(chunks[0]).valid)
    jax.block_until_ready(r.step(chunks[1]).valid)
    assert list(r._sparse["prev"]) == ["b"]
    old_prev = jax.tree_util.tree_leaves(r._sparse["prev"])
    with jax.transfer_guard("disallow"):   # prev upkeep can't sync either
        out = r.step(chunks[2])
        jax.block_until_ready(out.valid)
    if jax.default_backend() in ("cpu", "tpu", "gpu"):
        assert all(x.is_deleted() for x in old_prev), (
            "steady-state step must donate the prev snapshots through")
    # the carried prev really is b's last tick (next chunk diffs against it)
    np.testing.assert_array_equal(
        np.asarray(r._sparse["prev"]["b"][0]).ravel(),
        np.asarray(chunks[2]["b"].value)[-1:])
    jax.block_until_ready(r.step(chunks[3]).valid)


def test_warmup_reset_rebases_metrics_and_stays_transfer_free():
    """``Metrics.reset_after_warmup()`` re-bases the latency histogram and
    chunk counters after compilation warm-up without touching the compile
    record (the retrace detector's baseline is the warm-up), and the
    re-based device-resident accumulators must keep the very next
    steady-state chunk transfer-free."""
    r = Runner(_exe(), ExecPolicy(body="sparse"), segs_per_chunk=SPC)
    chunks = _device_chunks(4, seed=31)
    jax.block_until_ready(r.step(chunks[0]).valid)
    jax.block_until_ready(r.step(chunks[1]).valid)
    r.metrics.reset_after_warmup()
    snap = r.metrics.snapshot()
    assert snap["counters"]["runner.chunks"]["value"] == 0
    assert snap["histograms"]["runner.step_seconds"]["count"] == 0
    assert any(k.startswith("sparse_fused(")
               for k in snap["compiles"]["counts"])
    with jax.transfer_guard("disallow"):
        jax.block_until_ready(r.step(chunks[2]).valid)
    snap = r.metrics.snapshot()
    assert snap["counters"]["runner.chunks"]["value"] == 1
    assert snap["histograms"]["runner.step_seconds"]["count"] == 1
    assert r.dirty_stats()["chunks"] == 1
    jax.block_until_ready(r.step(chunks[3]).valid)


# -- satellite: the static auditor proves the hot path clean ----------------

def _audit_noise(policy):
    from repro.analysis import audit_runner, build_lattice_runner
    r = build_lattice_runner(policy)
    return [f for f in audit_runner(r)
            if f.severity in ("warning", "error")]


@pytest.mark.parametrize("body", ["dense", "sparse"])
@pytest.mark.parametrize("keys", ["single", "vmapped"])
def test_static_audit_clean_local_solo(body, keys):
    """Fast subset: the four local solo points must audit clean — the
    static complement of the transfer/donation/compile assertions above."""
    assert _audit_noise(ExecPolicy(body=body, keys=keys)) == []


@pytest.mark.slow
@pytest.mark.parametrize("idx", range(16))
def test_static_audit_clean_full_lattice(idx):
    """Every point of the 16-point ExecPolicy lattice audits clean (the
    same matrix ``python -m repro.analysis`` / ``make lint-plans`` gates
    in CI)."""
    from repro.analysis import lattice_policies
    assert _audit_noise(lattice_policies()[idx]) == []


def test_restore_copies_state_out_of_donation_reach():
    """restore() must deep-copy the checkpoint: the donating steady-state
    step consumes the runner's state buffers, and that must never reach
    arrays the caller still holds."""
    r1 = Runner(_exe(), ExecPolicy(body="sparse"), segs_per_chunk=SPC)
    chunks = _device_chunks(4, seed=13)
    jax.block_until_ready(r1.step(chunks[0]).valid)
    jax.block_until_ready(r1.step(chunks[1]).valid)
    ckpt = r1.state()

    r2 = Runner(_exe(), ExecPolicy(body="sparse"), segs_per_chunk=SPC)
    r2.restore(ckpt)
    a = r1.step(chunks[2])
    b = r2.step(chunks[2])          # donating step over the restored copy
    c = r2.step(chunks[3])
    jax.block_until_ready((a.valid, b.valid, c.valid))
    assert np.array_equal(np.asarray(a.valid), np.asarray(b.valid))
    assert np.array_equal(np.asarray(a.value), np.asarray(b.value))
    # the checkpoint the caller holds survived both donating steps intact
    for leaf in jax.tree_util.tree_leaves(ckpt):
        np.asarray(leaf)
