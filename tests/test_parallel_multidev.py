"""Multi-device tests via subprocess (the main pytest process must keep the
default 1-device CPU config; these spawn fresh interpreters with
``--xla_force_host_platform_device_count=8``)."""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow

TIMEOUT = 420


def _run(script: str) -> str:
    code = textwrap.dedent(script)
    # JAX_PLATFORMS must survive into the stripped env: without it jax
    # probes for a TPU backend and hangs until TIMEOUT on isolated hosts.
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=TIMEOUT,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root",
                            "JAX_PLATFORMS":
                                os.environ.get("JAX_PLATFORMS", "cpu")})
    assert p.returncode == 0, f"stdout={p.stdout}\nstderr={p.stderr[-3000:]}"
    return p.stdout


def test_shard_map_halo_exchange_matches_host_loop():
    """The ppermute halo exchange (paper Fig. 6 as SPMD) must reproduce the
    single-device result exactly, including across-shard windows."""
    out = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import compile as qc
        from repro.core.frontend import TStream
        from repro.core.parallel import partition_run, shard_map_run
        from repro.core.stream import SnapshotGrid
        from repro.launch.mesh import make_local_mesh

        assert len(jax.devices()) == 8
        rng = np.random.default_rng(0)
        N = 1024
        vals = rng.normal(size=N).astype(np.float32)
        valid = rng.random(N) > 0.2
        g = {"in": SnapshotGrid(value=jnp.asarray(vals),
                                valid=jnp.asarray(valid), t0=0, prec=1)}

        s = TStream.source("in", prec=1)
        q = (s.window(20).mean()
              .join(s.window(50).mean(), lambda a, b: a - b)
              .where(lambda d: d > 0))

        full = partition_run(
            qc.compile_query(q.node, out_len=N, pallas=False), g, 0, 1)

        mesh = make_local_mesh(n_data=8)
        exe = qc.compile_query(q.node, out_len=N // 8, pallas=False)
        shard = shard_map_run(exe, g, mesh, axis="data")

        m1, m2 = np.asarray(full.valid), np.asarray(shard.valid)
        assert np.array_equal(m1, m2), (m1.sum(), m2.sum())
        v1, v2 = np.asarray(full.value), np.asarray(shard.value)
        np.testing.assert_allclose(v1[m1], v2[m1], rtol=1e-5, atol=1e-5)
        print("HALO_OK")
    """)
    assert "HALO_OK" in out


def test_shard_map_multi_hop_bit_identical_to_partition_run():
    """Deep-lookback configs the seed rejected (halo > per-shard core) must
    run through the multi-hop ppermute chain and match the host loop
    *bit-for-bit* on integer-valued data (same partitioning ⇒ identical
    float association; see the float caveat in repro/multiquery).

    Covers 2-hop, 3-hop and the acceptance config (window 500 over 8
    shards of 128 core ticks ⇒ 4-hop left halo), non-zero origins, and the
    right-halo chain via multi-hop lookahead (shift(-d)) configs.
    """
    out = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import compile as qc
        from repro.core.frontend import TStream
        from repro.core.parallel import (partition_run, shard_map_run,
                                         check_single_hop_halo)
        from repro.core.stream import SnapshotGrid
        from repro.launch.mesh import make_local_mesh

        assert len(jax.devices()) == 8
        mesh = make_local_mesh(n_data=8)

        # lookback (left chain): (window, total ticks, hops, origin),
        # core = N // 8
        configs = [(100, 512, 2, 0),     # core 64  -> 2 hops
                   (100, 320, 3, 0),     # core 40  -> 3 hops
                   (500, 1024, 4, 0),    # core 128 -> 4 hops (acceptance)
                   (500, 1024, 4, 4096)] # ... at a non-zero origin
        # lookahead (right chain has its own trim direction, permutation
        # and segment order): shift(-d) needs ceil(d/core) right hops
        la_configs = [(150, 512, 3, 0),  # core 64 -> 3 right hops
                      (70, 256, 3, 128)] # core 32 -> 3 right hops, t0!=0
        for kind, W, N, hops, t0 in (
                [("lb",) + c for c in configs]
                + [("la",) + c for c in la_configs]):
            rng = np.random.default_rng(W + N)
            vals = rng.integers(0, 100, N).astype(np.float32)
            valid = rng.random(N) > 0.2
            g = {"in": SnapshotGrid(value=jnp.asarray(vals),
                                    valid=jnp.asarray(valid),
                                    t0=t0, prec=1)}
            s = TStream.source("in", prec=1)
            q = s.window(W).sum() if kind == "lb" else s.shift(-W)
            exe = qc.compile_query(q.node, out_len=N // 8, pallas=False)
            rep = check_single_hop_halo(exe.input_specs, exe.out_prec, 8)
            got = (rep["in"].left_hops if kind == "lb"
                   else rep["in"].right_hops)
            assert got == hops, (kind, W, N, rep)

            ref = partition_run(exe, g, t0, 8)
            shard = shard_map_run(exe, g, mesh, axis="data")
            assert shard.t0 == t0, (shard.t0, t0)
            m1, m2 = np.asarray(ref.valid), np.asarray(shard.valid)
            assert np.array_equal(m1, m2), (kind, W, N, m1.sum(), m2.sum())
            v1, v2 = np.asarray(ref.value), np.asarray(shard.value)
            assert np.array_equal(v1[m1], v2[m1]), (kind, W, N)
        print("MULTIHOP_OK")
    """)
    assert "MULTIHOP_OK" in out


def test_sparse_run_matches_shard_map_run():
    """Change-compressed execution vs SPMD time-sharded execution: dirty
    spans crossing shard boundaries (a multi-hop-deep window) must agree
    bit-for-bit on integer-valued data with both partition_run and
    shard_map_run over the same partitioning."""
    out = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import compile as qc
        from repro.core.frontend import TStream
        from repro.core.parallel import partition_run, shard_map_run
        from repro.core.sparse import segment_mask, sparse_run
        from repro.core.stream import SnapshotGrid

        assert len(jax.devices()) == 8
        N, n_shards = 512, 8
        # piecewise-constant integers; the change at tick 300 sits mid
        # shard 4 and its 100-tick lookback span crosses shard boundaries
        vals = np.full(N, 11.0, np.float32)
        vals[140:] = 4.0
        vals[300:] = 27.0
        valid = np.ones(N, bool)
        valid[200:230] = False
        g = {"in": SnapshotGrid(value=jnp.asarray(vals),
                                valid=jnp.asarray(valid), t0=0, prec=1)}
        s = TStream.source("in", prec=1)
        q = s.window(100).sum()   # halo 100 > core 64: 2-hop exchange
        exe = qc.compile_query(q.node, out_len=N // n_shards,
                               pallas=False, sparse=True)

        from jax.sharding import Mesh
        mesh = Mesh(np.array(jax.devices()), ("data",))
        ref = partition_run(exe, g, 0, n_shards)
        shard = shard_map_run(exe, g, mesh, axis="data")
        got = sparse_run(exe, g, 0, n_shards)
        mask = np.asarray(segment_mask(exe, g, 0, n_shards))
        assert 1 < mask.sum() < n_shards, mask.astype(int)  # real compaction
        for other, name in ((shard, "shard"), (got, "sparse")):
            m1, m2 = np.asarray(ref.valid), np.asarray(other.valid)
            assert np.array_equal(m1, m2), (name, m1.sum(), m2.sum())
            v1, v2 = np.asarray(ref.value), np.asarray(other.value)
            assert np.array_equal(v1[m1], v2[m1]), name
        print("SPARSE_SHARD_OK")
    """)
    assert "SPARSE_SHARD_OK" in out


def test_shard_union_run_deep_windows_match_session():
    """Time-sharded union execution: merged multi-query halo contracts
    deeper than the per-shard span (4-hop) must match the chunked
    MultiQuerySession bit-for-bit on integer-valued data."""
    out = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.frontend import TStream
        from repro.core.stream import SnapshotGrid
        from repro.launch.mesh import make_local_mesh
        from repro.multiquery import MultiQuerySession, shard_union_run

        N, n_shards = 512, 8
        span = N // n_shards                  # 64 per shard
        rng = np.random.default_rng(9)
        vals = rng.integers(0, 50, N).astype(np.float32)
        valid = rng.random(N) > 0.2
        g = {"in": SnapshotGrid(value=jnp.asarray(vals),
                                valid=jnp.asarray(valid), t0=0, prec=1)}
        s = TStream.source("in", prec=1)
        queries = {"shallow": s.window(16).mean(),   # 1 hop
                   "deep": s.window(200).sum()}      # merged halo: 4 hops

        mesh = make_local_mesh(n_data=n_shards)
        out = shard_union_run(queries, span, g, mesh, axis="data",
                              pallas=False)

        sess = MultiQuerySession(span, pallas=False)
        for name, q in queries.items():
            sess.attach(name, q)
        ref = sess.run(g, n_shards)
        for name in queries:
            m1 = np.asarray(ref[name].valid)
            m2 = np.asarray(out[name].valid)
            assert np.array_equal(m1, m2), name
            v1 = np.asarray(ref[name].value)
            v2 = np.asarray(out[name].value)
            assert np.array_equal(v1[m1], v2[m1]), name
        print("UNION_SHARD_OK")
    """)
    assert "UNION_SHARD_OK" in out


def test_policy_sparse_mesh_multidev_bit_identical():
    """Acceptance: ExecPolicy(body=sparse, placement=mesh) on an 8-device
    mesh — both keys='single' (segments shard, per-shard compaction over
    local segments) and keys='vmapped' (keys shard, per-shard compaction
    over local keys; the composition KeyedEngine(sparse=True) used to
    reject) — is bit-identical to the dense local reference on
    integer-valued data, and the compaction buckets stay per-shard sized.
    """
    out = _run("""
        import os, warnings
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        warnings.simplefilter("ignore", DeprecationWarning)
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import compile as qc
        from repro.core.frontend import TStream
        from repro.core.stream import SnapshotGrid
        from repro.engine import (ExecPolicy, KeyedEngine, Runner,
                                  keyed_grid, mesh_placement)

        assert len(jax.devices()) == 8
        mesh = jax.sharding.Mesh(np.array(jax.devices()), ("data",))

        def pw(shape, rate, seed):
            rng = np.random.default_rng(seed)
            ch = rng.random(shape) < rate
            ch[..., 0] = True
            raw = np.floor(rng.random(shape) * 100).astype(np.float32)
            idx = np.maximum.accumulate(
                np.where(ch, np.arange(shape[-1]), -1), axis=-1)
            vals = (np.take_along_axis(raw, idx, axis=-1)
                    if len(shape) > 1 else raw[idx])
            return vals, np.ones(shape, bool)

        def trend(s):
            return (s.window(16).mean()
                    .join(s.window(32).mean(), lambda a, b: a - b)
                    .where(lambda d: d > 0))

        def same(a, b, ctx):
            m1, m2 = np.asarray(a.valid), np.asarray(b.valid)
            assert np.array_equal(m1, m2), (ctx, m1.sum(), m2.sum())
            assert np.array_equal(np.asarray(a.value)[m1],
                                  np.asarray(b.value)[m1]), ctx

        # -- keys='single': segments shard over the mesh ------------------
        N = 512
        vals, valid = pw((N,), 0.02, seed=1)
        g = {"in": SnapshotGrid(value=jnp.asarray(vals),
                                valid=jnp.asarray(valid), t0=0, prec=1)}
        q = trend(TStream.source("in", prec=1))
        exe_d = qc.compile_query(q.node, out_len=32, pallas=False)
        exe_s = qc.compile_query(q.node, out_len=32, pallas=False,
                                 sparse=True)
        ref = Runner(exe_d, ExecPolicy()).run(g, N // 32)
        got = Runner(exe_s, ExecPolicy(body="sparse",
                                       placement=mesh_placement(mesh)),
                     segs_per_chunk=8).run(g, N // 256)
        same(ref, got, "single")
        caps = sorted(k[-1] for k in exe_s._runner_step_cache
                      if isinstance(k, tuple) and k[0] == "compute")
        assert caps and caps[0] <= 1, caps  # <=1 dirty segment per shard

        # -- keys='vmapped': keys shard, sparse x mesh composition --------
        K, T, P = 32, 256, 4
        kv, km = pw((K, T), 0.0, seed=2)       # idle keys...
        av, am = pw((4, T), 0.2, seed=3)
        kv[::8], km[::8] = av, am              # ...except every 8th
        gk = {"in": keyed_grid(kv, km)}
        qk = trend(TStream.source("in", keyed=True))
        exe_kd = qc.compile_query(qk.node, out_len=T // P, pallas=False)
        exe_ks = qc.compile_query(qk.node, out_len=T // P, pallas=False,
                                  sparse=True)
        refk = KeyedEngine(exe_kd, n_keys=K).run(gk, P)
        gotk = KeyedEngine(exe_ks, n_keys=K, mesh=mesh, sparse=True
                           ).run(gk, P)
        same(refk, gotk, "keyed-engine")
        rp = Runner(exe_ks, ExecPolicy(body="sparse", keys="vmapped",
                                       placement=mesh_placement(mesh)),
                    n_keys=K)
        same(refk, rp.run(gk, P), "keyed-runner")
        caps = sorted(k[-1] for k in exe_ks._runner_step_cache
                      if isinstance(k, tuple) and k[0] == "compute")
        # 4 active keys over 8 shards: per-shard buckets stay tiny (the
        # forced-dense first step uses the full local capacity K/8 = 4)
        assert caps and caps[0] <= 2, caps
        print("POLICY_MESH_OK")
    """)
    assert "POLICY_MESH_OK" in out


def test_sparse_union_session_mesh_multidev():
    """Acceptance: a sparse union session (merged ChangePlan, keyed × mesh)
    is bit-identical to its dense solo counterparts on integer data."""
    out = _run("""
        import os, warnings
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        warnings.simplefilter("ignore", DeprecationWarning)
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import compile as qc
        from repro.core.frontend import TStream
        from repro.engine import KeyedEngine, keyed_grid
        from repro.multiquery import MultiQuerySession

        assert len(jax.devices()) == 8
        mesh = jax.sharding.Mesh(np.array(jax.devices()), ("data",))
        K, T, SPAN = 16, 256, 64
        rng = np.random.default_rng(7)
        ch = rng.random((K, T)) < 0.03
        ch[:, 0] = True
        raw = np.floor(rng.random((K, T)) * 100).astype(np.float32)
        idx = np.maximum.accumulate(
            np.where(ch, np.arange(T), -1), axis=-1)
        vals = np.take_along_axis(raw, idx, axis=-1)
        valid = np.ones((K, T), bool)
        g = {"in": keyed_grid(vals, valid)}

        s = TStream.source("in", prec=1, keyed=True)
        queries = {"trend": (s.window(16).mean()
                             .join(s.window(32).mean(), lambda a, b: a - b)
                             .where(lambda d: d > 0)),
                   "bands": s.window(24).max().join(s, lambda h, x: h - x)}

        sess = MultiQuerySession(SPAN, n_keys=K, mesh=mesh, pallas=False,
                                 sparse=True)
        for name, q in queries.items():
            sess.attach(name, q)
        outs = sess.run(g, T // SPAN)
        for name, q in queries.items():
            exe = qc.compile_query(q.node, out_len=SPAN, pallas=False)
            ref = KeyedEngine(exe, n_keys=K).run(g, T // SPAN)
            m1, m2 = np.asarray(ref.valid), np.asarray(outs[name].valid)
            assert np.array_equal(m1, m2), (name, m1.sum(), m2.sum())
            assert np.array_equal(np.asarray(ref.value)[m1],
                                  np.asarray(outs[name].value)[m1]), name
        print("SPARSE_UNION_MESH_OK")
    """)
    assert "SPARSE_UNION_MESH_OK" in out


def test_dryrun_cell_small_mesh():
    """End-to-end dry-run machinery on an 8-device mesh (2 data × 4 model):
    lower+compile a smoke-size train step with the production sharding
    rules, verifying the sharding.py → pjit pipeline."""
    out = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from repro.configs.base import registry, Shape
        from repro.models.model import build_model
        from repro.models import shardctx
        from repro.launch import sharding as SH
        from repro.train.train_step import make_train_step
        from repro.train.optimizer import AdamWConfig, init_opt_state

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        shardctx.set_mesh_axes(mesh.axis_names)
        import dataclasses
        cfg = registry()["qwen3-1.7b"][1]
        cfg = dataclasses.replace(cfg, n_layers=4, d_ff=128, d_model=64,
                                  n_heads=4, n_kv_heads=4)
        model = build_model(cfg)
        params, axes = model.init(jax.random.PRNGKey(0))
        psh = SH.param_shardings(axes, cfg, mesh)
        params = jax.tree_util.tree_map(jax.device_put, params, psh)
        opt = init_opt_state(params)
        step = make_train_step(model, AdamWConfig())
        batch = {"tokens": jnp.zeros((8, 32), jnp.int32),
                 "labels": jnp.zeros((8, 32), jnp.int32)}
        with mesh:
            p2, o2, m = jax.jit(step)(params, opt, batch)
        assert jnp.isfinite(m["loss"])
        print("DRYRUN_SMALL_OK", float(m["loss"]))
    """)
    assert "DRYRUN_SMALL_OK" in out


def test_elastic_checkpoint_reshard():
    """Save on an 8-device mesh, restore onto a 4-device mesh (elastic
    downscale after simulated node loss)."""
    out = _run("""
        import os, tempfile
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.train import checkpoint as ck

        mesh8 = jax.make_mesh((8,), ("data",))
        sh8 = NamedSharding(mesh8, P("data"))
        tree = {"w": jax.device_put(jnp.arange(64.0).reshape(8, 8), sh8),
                "b": jax.device_put(jnp.ones(8), sh8),
                "opt": {"m": jax.device_put(jnp.zeros((8, 8)), sh8)}}
        d = tempfile.mkdtemp()
        ck.save(d, 3, tree, extra={"pipeline_pos": 1234})

        # restore on a smaller mesh (first 4 devices)
        mesh4 = jax.sharding.Mesh(np.array(jax.devices()[:4]), ("data",))
        sh4 = {"w": NamedSharding(mesh4, P("data")),
               "b": NamedSharding(mesh4, P("data")),
               "opt": {"m": NamedSharding(mesh4, P("data"))}}
        restored, manifest = ck.restore(d, shardings=sh4)
        assert manifest["extra"]["pipeline_pos"] == 1234
        assert restored["w"].sharding.mesh.devices.size == 4
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.arange(64.0).reshape(8, 8))
        print("ELASTIC_OK")
    """)
    assert "ELASTIC_OK" in out
