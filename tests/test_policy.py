"""Execution-policy layer tests (repro.engine.policy / repro.engine.runner).

The contract: the four policy axes (body × keys × placement × dag) compose
freely, and every point of the space is **bit-identical** to the dense
single-stream reference on integer-valued data — the same invariant each
silo used to assert on its own, now asserted across the whole matrix.  The
deprecated entry points (StreamRunner, SparseStreamRunner, KeyedEngine,
MultiQuerySession) are thin wrappers over the unified runner and must
produce bit-identical outputs to driving the runner directly.

The ≥4-device mesh compositions live in tests/test_parallel_multidev.py
(they need a multi-device subprocess); here mesh placement runs on the
trivial 1-device mesh, which exercises the per-shard compaction and
shard_map staging paths without SPMD.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compile as qc
from repro.core.frontend import TStream
from repro.core.parallel import (SparseStreamRunner, StreamRunner,
                                 partition_run)
from repro.core.stream import SnapshotGrid
from repro.engine import ExecPolicy, KeyedEngine, Runner, keyed_grid, \
    mesh_placement
from repro.multiquery import MultiQuerySession, union_runner

# the deprecated wrappers are under test here on purpose
pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")

N, K = 256, 4


def _mesh1():
    return jax.sharding.Mesh(np.array(jax.devices()), ("data",))


def pw_const(shape, rate, seed):
    """Piecewise-constant integer-valued stream(s): ``rate`` of ticks
    change, the rest hold — so sparse execution actually compacts."""
    rng = np.random.default_rng(seed)
    change = rng.random(shape) < rate
    change[..., 0] = True
    raw = np.floor(rng.random(shape) * 100).astype(np.float32)
    idx = np.maximum.accumulate(
        np.where(change, np.arange(shape[-1]), -1), axis=-1)
    return np.take_along_axis(raw, idx, axis=-1) if len(shape) > 1 \
        else raw[idx], np.ones(shape, bool)


def _grid(vals, valid, t0=0):
    return SnapshotGrid(value=jnp.asarray(vals), valid=jnp.asarray(valid),
                        t0=t0, prec=1)


def _trend(s):
    return (s.window(16).mean()
            .join(s.window(32).mean(), lambda a, b: a - b)
            .where(lambda d: d > 0))


def _bands(s):
    return s.window(24).max().join(s, lambda hi, x: hi - x)


def _assert_same(ref, got, ctx=""):
    m1, m2 = np.asarray(ref.valid), np.asarray(got.valid)
    assert np.array_equal(m1, m2), (ctx, m1.sum(), m2.sum())
    assert np.array_equal(np.asarray(ref.value)[m1],
                          np.asarray(got.value)[m1]), ctx


# ---------------------------------------------------------------------------
# ExecPolicy validation
# ---------------------------------------------------------------------------

def test_policy_rejects_unknown_axis_values():
    with pytest.raises(ValueError, match="body"):
        ExecPolicy(body="chunky")
    with pytest.raises(ValueError, match="keys"):
        ExecPolicy(keys="many")
    with pytest.raises(ValueError, match="dag"):
        ExecPolicy(dag="forest")
    with pytest.raises(ValueError, match="placement"):
        ExecPolicy(placement="cloud")


def test_policy_accessors_and_describe():
    p = ExecPolicy(body="sparse", keys="vmapped",
                   placement=mesh_placement(_mesh1()), dag="union")
    assert p.sparse and p.keyed and p.union
    assert p.mesh is not None and p.axis == "data" and p.n_shards == 1
    assert p.describe() == "sparse×vmapped×mesh1×union"
    assert ExecPolicy().describe() == "dense×single×local×solo"
    # a bare Mesh is accepted and normalized onto its first axis
    assert ExecPolicy(placement=_mesh1()).axis == "data"


def test_runner_requires_n_keys_for_vmapped():
    exe = qc.compile_query(
        TStream.source("in", keyed=True).window(8).mean().node,
        out_len=16, pallas=False)
    with pytest.raises(ValueError, match="n_keys"):
        Runner(exe, ExecPolicy(keys="vmapped"))


def test_runner_sparse_requires_change_plan():
    exe = qc.compile_query(TStream.source("in").window(8).mean().node,
                           out_len=16, pallas=False)
    with pytest.raises(ValueError, match="sparse=True"):
        Runner(exe, ExecPolicy(body="sparse"))


def test_runner_rejects_lookahead():
    exe = qc.compile_query(TStream.source("in").shift(-4).node,
                           out_len=16, pallas=False)
    with pytest.raises(NotImplementedError, match="lookahead"):
        Runner(exe, ExecPolicy())


# ---------------------------------------------------------------------------
# satellite: the old constructors are bit-identical to the unified runner
# ---------------------------------------------------------------------------

def test_stream_runner_wrapper_bit_identical_to_runner():
    vals, valid = pw_const((N,), 0.05, seed=1)
    q = _trend(TStream.source("in", prec=1))
    exe = qc.compile_query(q.node, out_len=32, pallas=False)
    old = StreamRunner(exe)
    new = Runner(exe, ExecPolicy())
    ref = partition_run(exe, {"in": _grid(vals, valid)}, 0, N // 32)
    for k in range(N // 32):
        sl = slice(k * 32, (k + 1) * 32)
        a = old.step({"in": _grid(vals[sl], valid[sl], t0=k * 32)})
        b = new.step({"in": _grid(vals[sl], valid[sl], t0=k * 32)})
        assert a.t0 == b.t0 == k * 32
        assert np.array_equal(np.asarray(a.valid), np.asarray(b.valid))
        assert np.array_equal(np.asarray(a.value), np.asarray(b.value))
        # ... and both equal the dense partition reference on this chunk
        _assert_same(SnapshotGrid(
            value=np.asarray(ref.value)[sl], valid=np.asarray(ref.valid)[sl],
            t0=k * 32, prec=1), a, f"chunk {k}")


def test_sparse_stream_runner_wrapper_bit_identical_to_runner():
    vals, valid = pw_const((N,), 0.03, seed=2)
    q = _trend(TStream.source("in", prec=1))
    exe = qc.compile_query(q.node, out_len=32, pallas=False, sparse=True)
    old = SparseStreamRunner(exe, segs_per_chunk=4)
    new = Runner(exe, ExecPolicy(body="sparse"), segs_per_chunk=4)
    for c in range(2):
        sl = slice(c * 128, (c + 1) * 128)
        a = old.step({"in": _grid(vals[sl], valid[sl], t0=c * 128)})
        b = new.step({"in": _grid(vals[sl], valid[sl], t0=c * 128)})
        assert np.array_equal(np.asarray(a.valid), np.asarray(b.valid))
        assert np.array_equal(np.asarray(a.value), np.asarray(b.value))


def test_keyed_engine_wrapper_bit_identical_to_runner():
    vals, valid = pw_const((K, N), 0.05, seed=3)
    q = _trend(TStream.source("in", keyed=True))
    exe = qc.compile_query(q.node, out_len=64, pallas=False)
    g = {"in": keyed_grid(vals, valid)}
    a = KeyedEngine(exe, n_keys=K).run(g, N // 64)
    b = Runner(exe, ExecPolicy(keys="vmapped"), n_keys=K).run(g, N // 64)
    _assert_same(a, b, "keyed")


# ---------------------------------------------------------------------------
# satellite: KeyedEngine(sparse=True, mesh=...) routes through the composed
# path instead of raising
# ---------------------------------------------------------------------------

def test_keyed_engine_sparse_mesh_no_longer_rejected():
    vals, valid = pw_const((K, N), 0.03, seed=4)
    q = _trend(TStream.source("in", keyed=True))
    exe_d = qc.compile_query(q.node, out_len=64, pallas=False)
    exe_s = qc.compile_query(q.node, out_len=64, pallas=False, sparse=True)
    g = {"in": keyed_grid(vals, valid)}
    ref = KeyedEngine(exe_d, n_keys=K).run(g, N // 64)
    # the composition the old engine rejected with NotImplementedError
    eng = KeyedEngine(exe_s, n_keys=K, mesh=_mesh1(), sparse=True)
    _assert_same(ref, eng.run(g, N // 64), "sparse+mesh")


def test_runner_sparse_mesh_single_keys_shards_segments():
    """The acceptance spelling: ExecPolicy(body=sparse, placement=mesh)
    with default keys='single' — segments shard over the mesh, per-shard
    compaction, bit-identical to the dense local reference."""
    vals, valid = pw_const((N,), 0.03, seed=5)
    q = _trend(TStream.source("in", prec=1))
    exe_d = qc.compile_query(q.node, out_len=32, pallas=False)
    exe_s = qc.compile_query(q.node, out_len=32, pallas=False, sparse=True)
    g = {"in": _grid(vals, valid)}
    ref = Runner(exe_d, ExecPolicy()).run(g, N // 32)
    got = Runner(exe_s,
                 ExecPolicy(body="sparse", placement=mesh_placement(_mesh1())),
                 segs_per_chunk=4).run(g, N // 128)
    _assert_same(ref, got, "sparse×single×mesh")


# ---------------------------------------------------------------------------
# sparse × union: the merged ChangePlan skips clean chunks/keys
# ---------------------------------------------------------------------------

def _union_queries(keyed=False):
    s = TStream.source("in", prec=1, keyed=keyed)
    return {"trend": _trend(s), "bands": _bands(s)}


def test_sparse_union_session_matches_dense_solo():
    """MultiQuerySession(sparse=True) ≡ the dense solo StreamRunner per
    query, bit-for-bit on integer-valued piecewise-constant data — and the
    union evaluation is actually skipped on clean chunks (compaction
    capacity below the chunk count appears in the staged-step cache)."""
    vals, valid = pw_const((N,), 0.02, seed=6)
    queries = _union_queries()
    sess = MultiQuerySession(64, pallas=False, sparse=True)
    for name, q in queries.items():
        sess.attach(name, q)
    outs = sess.run({"in": _grid(vals, valid)}, N // 64)
    for name, q in queries.items():
        exe = qc.compile_query(q.node, out_len=64, pallas=False)
        runner = StreamRunner(exe)
        ref_v, ref_m = [], []
        for k in range(N // 64):
            sl = slice(k * 64, (k + 1) * 64)
            o = runner.step({"in": _grid(vals[sl], valid[sl], t0=k * 64)})
            ref_v.append(np.asarray(o.value))
            ref_m.append(np.asarray(o.valid))
        want = SnapshotGrid(value=np.concatenate(ref_v),
                            valid=np.concatenate(ref_m), t0=0, prec=1)
        _assert_same(want, outs[name], name)


def test_sparse_union_session_skips_clean_chunks():
    """On an all-constant stream only the first chunk (hold-seed base case)
    computes; later chunks hold every query's previous output."""
    vals = np.full(N, 7.0, np.float32)
    queries = _union_queries()
    sess = MultiQuerySession(64, pallas=False, sparse=True)
    for name, q in queries.items():
        sess.attach(name, q)
    outs = sess.run({"in": _grid(vals, np.ones(N, bool))}, N // 64)
    caps = sorted(k[-1] for k in sess._runner.spec.step_cache
                  if isinstance(k, tuple) and k[0] == "compute")
    assert caps == [1], caps  # never more than the forced first segment
    for name, q in queries.items():
        exe = qc.compile_query(q.node, out_len=64, pallas=False)
        ref = partition_run(exe, {"in": _grid(vals, np.ones(N, bool))},
                            0, N // 64)
        _assert_same(ref, outs[name], name)


def test_sparse_union_session_keyed_attach_detach_deterministic():
    """Sparse keyed sessions re-fit change state across attach/detach the
    same way a fresh session restored from the checkpoint does."""
    vals, valid = pw_const((K, 4 * 64), 0.05, seed=7)
    g = keyed_grid(vals, valid)
    queries = _union_queries(keyed=True)
    names = list(queries)

    def chunk(j):
        sl = slice(j * 64, (j + 1) * 64)
        return {"in": keyed_grid(vals[:, sl], valid[:, sl], t0=j * 64)}

    live = MultiQuerySession(64, n_keys=K, pallas=False, sparse=True)
    live.attach(names[0], queries[names[0]])
    live.step(chunk(0))
    ckpt = live.state()
    assert "__sparse" in ckpt
    live.attach(names[1], queries[names[1]])      # attach mid-run
    o1 = live.step(chunk(1))
    o2 = live.step(chunk(2))

    fresh = MultiQuerySession(64, n_keys=K, pallas=False, sparse=True)
    for n in names:
        fresh.attach(n, queries[n])
    fresh.restore(ckpt)
    p1 = fresh.step(chunk(1))
    p2 = fresh.step(chunk(2))
    for n in names:
        _assert_same(o1[n], p1[n], ("attach", n))
        _assert_same(o2[n], p2[n], ("attach2", n))


def test_union_runner_direct_matches_session():
    vals, valid = pw_const((N,), 0.05, seed=8)
    queries = _union_queries()
    r = union_runner(queries, 64, ExecPolicy(dag="union"), pallas=False)
    outs = r.run({"in": _grid(vals, valid)}, N // 64)
    sess = MultiQuerySession(64, pallas=False)
    for name, q in queries.items():
        sess.attach(name, q)
    ref = sess.run({"in": _grid(vals, valid)}, N // 64)
    for name in queries:
        _assert_same(ref[name], outs[name], name)


def test_union_runner_rejects_solo_policy():
    with pytest.raises(ValueError, match="dag"):
        union_runner(_union_queries(), 64, ExecPolicy())


# ---------------------------------------------------------------------------
# unified checkpoint/restore/validate path
# ---------------------------------------------------------------------------

def test_runner_restore_validates_across_policies():
    q = _trend(TStream.source("in", prec=1))
    exe = qc.compile_query(q.node, out_len=32, pallas=False)
    exe_s = qc.compile_query(q.node, out_len=32, pallas=False, sparse=True)
    vals, valid = pw_const((N,), 0.05, seed=9)
    r = Runner(exe, ExecPolicy())
    r.step({"in": _grid(vals[:32], valid[:32])})
    state = r.state()
    # single-key tail shapes are validated too (not just the keyed engine)
    bad = dict(state)
    bad["in"] = (np.zeros((7,), np.float32), np.zeros((7,), bool))
    with pytest.raises(ValueError, match="left_halo"):
        Runner(exe, ExecPolicy()).restore(bad)
    with pytest.raises(ValueError, match="stream clock"):
        Runner(exe, ExecPolicy()).restore(dict(state, __t=17))
    with pytest.raises(ValueError, match="unknown="):
        Runner(exe, ExecPolicy()).restore(
            {"bogus": state["in"], "__t": state["__t"]})
    with pytest.raises(ValueError, match="sparse engine cannot restore"):
        Runner(exe_s, ExecPolicy(body="sparse")).restore(state)


def test_runner_restores_pre_policy_tuple_seed_checkpoint():
    """Checkpoints written by the pre-policy KeyedEngine stored the sparse
    hold seed as a bare (value, valid) tuple; the unified restore path must
    keep accepting them through the deprecation window (and reject them
    with a clear error for union runners, whose seeds are per-query)."""
    q = _trend(TStream.source("in", keyed=True))
    exe = qc.compile_query(q.node, out_len=32, pallas=False, sparse=True)
    vals, valid = pw_const((K, 64), 0.05, seed=10)
    e1 = KeyedEngine(exe, n_keys=K, sparse=True)
    e1.step({"in": keyed_grid(vals[:, :32], valid[:, :32])})
    state = e1.state()
    # rewrite the seed into the historical tuple format
    old = dict(state)
    old["__sparse"] = dict(state["__sparse"],
                           seed=state["__sparse"]["seed"]["__out"])
    e2 = KeyedEngine(exe, n_keys=K, sparse=True)
    e2.restore(old)
    a = e1.step({"in": keyed_grid(vals[:, 32:], valid[:, 32:], t0=32)})
    b = e2.step({"in": keyed_grid(vals[:, 32:], valid[:, 32:], t0=32)})
    assert np.array_equal(np.asarray(a.valid), np.asarray(b.valid))
    assert np.array_equal(np.asarray(a.value), np.asarray(b.value))
    # union runners have per-query seeds: the tuple format must be named
    r = union_runner(_union_queries(keyed=True), 32,
                     ExecPolicy(body="sparse", keys="vmapped", dag="union"),
                     n_keys=K, pallas=False)
    with pytest.raises(ValueError, match="bare tuple"):
        r.restore(old)


# ---------------------------------------------------------------------------
# satellite: the policy-matrix property (slow CI split)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_policy_matrix_exhaustive_bit_identity():
    """Every point of body × keys × placement × dag agrees bit-for-bit with
    the dense single-stream reference on integer-valued data."""
    n, k, seg = 128, K, 16
    data1, _ = pw_const((n,), 0.04, seed=11)
    datak, _ = pw_const((k, n), 0.04, seed=12)
    ones1, onesk = np.ones(n, bool), np.ones((k, n), bool)

    def reference(queries, keyed):
        refs = {}
        for name, q in queries.items():
            exe = qc.compile_query(q.node, out_len=n, pallas=False)
            if keyed:
                per_key = [partition_run(
                    exe, {"in": _grid(datak[i], onesk[i])}, 0, 1)
                    for i in range(k)]
                refs[name] = SnapshotGrid(
                    value=np.stack([np.asarray(p.value) for p in per_key]),
                    valid=np.stack([np.asarray(p.valid) for p in per_key]),
                    t0=0, prec=1)
            else:
                refs[name] = partition_run(
                    exe, {"in": _grid(data1, ones1)}, 0, 1)
        return refs

    for body in ("dense", "sparse"):
        for keys in ("single", "vmapped"):
            for placement in ("local", "mesh"):
                for dag in ("solo", "union"):
                    policy = ExecPolicy(
                        body=body, keys=keys,
                        placement=(mesh_placement(_mesh1())
                                   if placement == "mesh" else "local"),
                        dag=dag)
                    keyed = keys == "vmapped"
                    s = TStream.source("in", prec=1, keyed=keyed)
                    queries = ({"trend": _trend(s)} if dag == "solo"
                               else {"trend": _trend(s), "bands": _bands(s)})
                    if dag == "solo":
                        exe = qc.compile_query(
                            queries["trend"].node, out_len=seg, pallas=False,
                            sparse=(body == "sparse"))
                        r = Runner(exe, policy,
                                   n_keys=k if keyed else None,
                                   segs_per_chunk=2)
                    else:
                        r = union_runner(
                            queries, seg, policy,
                            n_keys=k if keyed else None,
                            segs_per_chunk=2, pallas=False)
                    g = {"in": (keyed_grid(datak, onesk) if keyed
                                else _grid(data1, ones1))}
                    out = r.run(g, n // (2 * seg))
                    refs = reference(queries, keyed)
                    outs = out if dag == "union" else {"trend": out}
                    for name in queries:
                        _assert_same(refs[name], outs[name],
                                     (policy.describe(), name))


@pytest.mark.slow
def test_policy_matrix_hypothesis_property():
    """Property: random policy points × random change patterns on a small
    query zoo never diverge from the dense single-stream reference
    (integer-valued data)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    n, seg = 128, 16
    zoo = {"trend": _trend, "bands": _bands,
           "tumbling": lambda s: s.window(8, stride=8).sum()}

    @settings(max_examples=20, deadline=None)
    @given(st.sampled_from(["dense", "sparse"]),
           st.sampled_from(["single", "vmapped"]),
           st.booleans(),
           st.sampled_from(sorted(zoo)),
           st.integers(0, 2 ** 31 - 1), st.floats(0.0, 1.0))
    def prop(body, keys, use_mesh, qname, seed, rate):
        keyed = keys == "vmapped"
        shape = (K, n) if keyed else (n,)
        vals, valid = pw_const(shape, rate, seed)
        s = TStream.source("in", prec=1, keyed=keyed)
        q = zoo[qname](s)
        out_len = seg // q.node.prec
        exe = qc.compile_query(q.node, out_len=out_len, pallas=False,
                               sparse=(body == "sparse"))
        policy = ExecPolicy(
            body=body, keys=keys,
            placement=mesh_placement(_mesh1()) if use_mesh else "local")
        r = Runner(exe, policy, n_keys=K if keyed else None,
                   segs_per_chunk=2)
        g = {"in": keyed_grid(vals, valid) if keyed else _grid(vals, valid)}
        got = r.run(g, n // (2 * seg))
        exe_ref = qc.compile_query(q.node, out_len=n // q.node.prec,
                                   pallas=False)
        if keyed:
            for i in range(0, K, 3):
                ref = partition_run(
                    exe_ref, {"in": _grid(vals[i], valid[i])}, 0, 1)
                _assert_same(ref, SnapshotGrid(
                    value=np.asarray(got.value)[i],
                    valid=np.asarray(got.valid)[i], t0=0, prec=q.node.prec),
                    (body, keys, use_mesh, qname, i))
        else:
            ref = partition_run(exe_ref, {"in": _grid(vals, valid)}, 0, 1)
            _assert_same(ref, got, (body, keys, use_mesh, qname))

    prop()
