"""Out-of-order ingestion: watermarks, reorder buffer, late-data revisions.

The headline invariant (ISSUE 9): **disorder-insensitivity** — for any
arrival permutation within the lateness bound plus revision horizon,
sealed outputs overlaid with the emitted corrections are bit-identical
to in-order execution on integer data.  Pinned here for unkeyed and
keyed runners, with event spans and change dilations crossing segment
and chunk boundaries (window lookback 24 over 16-tick chunks), plus:

* the reorder buffer's stamp-precedence rasterization reproduces
  ``events_to_grid`` exactly under any arrival permutation;
* the revision re-run goes through the compacted sparse path — the
  chunk counter does not move, revision units count only dilated
  segments, the staged revision step holds a capacity-ladder ``cond``
  and runs transfer-free on device-resident args with donated tails;
* beyond-horizon patches are refused whole (counted, never partially
  applied), and the ``revision`` analysis pass flags undersized rings;
* ``Runner.restore(strict=False)`` φ-re-init (the satellite): a
  checkpoint missing a halo-free input re-inits its change lineage to
  φ, forces the next first segment dense, and still continues
  bit-identically.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.jaxprs import walk
from repro.analysis.passes import make_target, pass_donation, pass_revision
from repro.core import compile as qc
from repro.core.frontend import TStream
from repro.core.sparse import retro_segment_mask
from repro.core.stream import (Event, EventStream, SnapshotGrid,
                               events_to_grid)
from repro.engine import ExecPolicy, Runner
from repro.ingest import IngestRunner, ReorderBuffer, WatermarkTracker

SEG = 8    # output ticks per segment
SPC = 2    # segments per chunk
CHUNK = SEG * SPC  # chunk span (out_prec = 1)

_EXE_CACHE = {}


def _exe(keyed: bool = False):
    """Join of a short and a long window: the 24-tick lookback dilates
    late changes across segment AND chunk boundaries (chunk = 16)."""
    if keyed not in _EXE_CACHE:
        s = TStream.source("in", prec=1, keyed=keyed)
        q = (s.window(4).mean()
             .join(s.window(24).mean(), lambda a, b: a - b))
        _EXE_CACHE[keyed] = qc.compile_query(q.node, out_len=SEG,
                                             pallas=False, sparse=True)
    return _EXE_CACHE[keyed]


def _int_events(rng, t_end: int, gap: bool = False) -> list:
    """Contiguous (or gapped) integer-payload events covering (0, t_end];
    the final one-tick event pins coverage of the last chunk so every
    stream spans the same chunk count."""
    events, t = [], 0
    while t < t_end - 1:
        d = int(rng.integers(1, 6))
        if not (gap and rng.random() < 0.2):
            events.append(Event(t, min(t + d, t_end - 1),
                                float(rng.integers(0, 10))))
        t += d
    events.append(Event(t_end - 1, t_end, float(rng.integers(0, 10))))
    return events


def _shuffled(rng, tagged, disorder: int):
    """Bounded-disorder arrival order: sort by start + jitter in [0, D)."""
    jit = rng.integers(0, max(disorder, 1), size=len(tagged))
    order = np.argsort([ev.start + j for (_k, ev), j in zip(tagged, jit)],
                       kind="stable")
    return [tagged[i] for i in order]


def _overlay(sealed, corrections, keyed: bool = False):
    """Fold corrections (version order) into the sealed outputs: only
    ticks inside dirty segments are taken from a correction."""
    final = {}
    for sc in sealed:
        final[sc.chunk] = (np.asarray(sc.outputs.value),
                           np.asarray(sc.outputs.valid))
    for co in sorted(corrections, key=lambda c: (c.chunk, c.version)):
        v, m = final[co.chunk]
        ov = np.asarray(co.outputs.value)
        om = np.asarray(co.outputs.valid)
        mask = np.asarray(co.seg_mask)
        tick = (np.repeat(mask, SEG, axis=1) if keyed
                else np.repeat(mask, SEG))
        final[co.chunk] = (np.where(tick, ov, v), np.where(tick, om, m))
    return final


def _assert_chunks_match(final, ref, n_chunks: int, keyed: bool = False):
    refv, refm = np.asarray(ref.value), np.asarray(ref.valid)
    assert sorted(final) == list(range(n_chunks))
    ax = 1 if keyed else 0
    for c in range(n_chunks):
        v, m = final[c]
        sl = [slice(None)] * refm.ndim
        sl[ax] = slice(c * CHUNK, (c + 1) * CHUNK)
        wv, wm = refv[tuple(sl)], refm[tuple(sl)]
        assert np.array_equal(m, wm), f"chunk {c}: validity differs"
        assert np.array_equal(v[m], wv[wm]), f"chunk {c}: values differ"


def _drive(ing, arrivals):
    sealed, corrections = [], []
    for name, ev, key in arrivals:
        ing.push(name, ev, key=key)
        s, c = ing.poll()
        sealed += s
        corrections += c
    s, c = ing.flush()
    return sealed + s, corrections + c


# ---------------------------------------------------------------------------
# watermark semantics
# ---------------------------------------------------------------------------

def test_watermark_tracker_semantics():
    wt = WatermarkTracker(lateness=5)
    assert wt.watermark is None and wt.frontier is None
    wt.observe(20, key="a")
    assert wt.frontier == 20 and wt.watermark == 15
    wt.observe(40, key="b")
    # the slowest key holds the stream back
    assert wt.frontier == 20 and wt.high == 40 and wt.lag() == 25
    wt.observe(10, key="a")  # per-key max is monotonic
    assert wt.frontier == 20
    wt.heartbeat(50)
    assert wt.frontier == 50 and wt.watermark == 45
    # declared key universe: strict — silent keys gate the watermark
    ws = WatermarkTracker(lateness=0, keys=["x", "y"])
    ws.observe(9, key="x")
    assert ws.watermark is None
    ws.observe(3, key="y")
    assert ws.watermark == 3
    with pytest.raises(KeyError):
        ws.observe(1, key="z")
    with pytest.raises(ValueError):
        WatermarkTracker(lateness=-1)


# ---------------------------------------------------------------------------
# reorder buffer ≡ events_to_grid under any arrival permutation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dict_payload", [False, True])
def test_reorder_buffer_matches_events_to_grid_any_order(dict_payload):
    rng = np.random.default_rng(11)
    T, CT = 64, 16
    events = []
    seen = set()
    for _ in range(40):  # overlapping spans, distinct (start, end)
        s = int(rng.integers(0, T - 1))
        e = min(T, s + int(rng.integers(1, 8)))
        if e <= s or (s, e) in seen:
            continue
        seen.add((s, e))
        p = float(rng.integers(0, 100))
        events.append(Event(s, e, {"x": p, "y": -p} if dict_payload else p))
    stream = EventStream(events)
    buf = ReorderBuffer(prec=1, chunk_ticks=CT, horizon_chunks=1)
    order = rng.permutation(len(events))  # fully arbitrary arrival
    for i in order:
        assert buf.push(events[i]) is None  # nothing sealed yet: never late
    sealed = buf.seal_all()
    assert [c for c, _ in sealed] == [0, 1, 2, 3]
    for c, got in sealed:
        want = events_to_grid(stream, c * CT, (c + 1) * CT, 1)
        assert np.array_equal(np.asarray(got.valid), np.asarray(want.valid))
        gv = jax.tree_util.tree_map(np.asarray, got.value)
        wv = jax.tree_util.tree_map(np.asarray, want.value)
        for g, w in zip(jax.tree_util.tree_leaves(gv),
                        jax.tree_util.tree_leaves(wv)):
            assert g.dtype == w.dtype == np.float32
            assert np.array_equal(g, w)


def test_reorder_patch_precedence_and_horizon_refusal():
    buf = ReorderBuffer(prec=1, chunk_ticks=8, horizon_chunks=2)
    buf.push(Event(0, 32, 1.0))
    buf.seal_all()  # chunks 0..3 sealed; rasters retained for 2, 3
    assert buf.sealed_upto == 4
    # later-starting event wins at its ticks; change reported as times
    times, beyond = buf.patch(Event(26, 28, 9.0))
    assert not beyond and list(times) == [27, 28]
    g = buf.sealed_grid(3)
    assert np.asarray(g.value)[[2, 3]].tolist() == [9.0, 9.0]
    # a losing event (same start, earlier end than the owner) changes nothing
    times, beyond = buf.patch(Event(26, 27, 5.0))
    assert not beyond and times.size == 0
    # a patch reaching past the horizon is refused WHOLE: the in-horizon
    # portion must not be applied either (partial state would fork from
    # anything a revision can reproduce)
    times, beyond = buf.patch(Event(10, 27, 5.0))
    assert beyond and times.size == 0
    assert np.asarray(buf.sealed_grid(3).value)[0] == 1.0
    with pytest.raises(KeyError):
        buf.sealed_grid(1)  # evicted


# ---------------------------------------------------------------------------
# disorder-insensitivity (the headline invariant)
# ---------------------------------------------------------------------------

def test_in_bound_disorder_needs_no_revisions():
    """Permutations within the watermark allowance: the reorder buffer
    alone restores order — sealed outputs are bit-identical with zero
    late events and zero corrections."""
    rng = np.random.default_rng(0)
    n_chunks, disorder = 6, 6
    events = _int_events(rng, n_chunks * CHUNK, gap=True)
    full = events_to_grid(EventStream(events), 0, n_chunks * CHUNK, 1)
    ref = Runner(_exe(), ExecPolicy(body="sparse"),
                 segs_per_chunk=SPC).run({"in": full}, n_chunks)

    r = Runner(_exe(), ExecPolicy(body="sparse"), segs_per_chunk=SPC)
    ing = IngestRunner(r, lateness=disorder + 6, policy="revise")
    arrivals = [("in", ev, None) for _k, ev in
                _shuffled(rng, [(0, e) for e in events], disorder)]
    sealed, corrections = _drive(ing, arrivals)
    assert corrections == []
    snap = r.metrics.snapshot()["counters"]
    assert snap["ingest.late_events"]["value"] == 0
    assert snap["ingest.sealed_chunks"]["value"] == n_chunks
    _assert_chunks_match(_overlay(sealed, corrections), ref, n_chunks)


def test_late_data_revision_exactness():
    """Disorder past the watermark allowance: late events patch sealed
    rasters and sparse revisions correct the outputs — sealed +
    corrections ≡ in-order execution, bit-identical."""
    rng = np.random.default_rng(1)
    n_chunks, disorder, lateness = 6, 24, 4
    events = _int_events(rng, n_chunks * CHUNK)
    full = events_to_grid(EventStream(events), 0, n_chunks * CHUNK, 1)
    ref = Runner(_exe(), ExecPolicy(body="sparse"),
                 segs_per_chunk=SPC).run({"in": full}, n_chunks)

    r = Runner(_exe(), ExecPolicy(body="sparse"), segs_per_chunk=SPC)
    ing = IngestRunner(r, lateness=lateness, policy="revise",
                       horizon_chunks=4)
    arrivals = [("in", ev, None) for _k, ev in
                _shuffled(rng, [(0, e) for e in events], disorder)]
    sealed, corrections = _drive(ing, arrivals)
    snap = r.metrics.snapshot()["counters"]
    assert snap["ingest.revised_events"]["value"] > 0
    assert snap["ingest.beyond_horizon"]["value"] == 0
    assert snap["ingest.dropped_events"]["value"] == 0
    assert len(corrections) > 0
    for co in corrections:  # versions count up from 1 per chunk
        assert co.version >= 1 and np.asarray(co.seg_mask).any()
    _assert_chunks_match(_overlay(sealed, corrections), ref, n_chunks)


def test_late_data_revision_exactness_keyed():
    """Keyed variant: per-key sub-streams shuffled together; a slow key
    gates sealing through the per-key watermark, revisions dirty only
    the patched keys' segments."""
    K, n_chunks, disorder, lateness = 4, 4, 20, 4
    rng = np.random.default_rng(2)
    per_key = [_int_events(rng, n_chunks * CHUNK) for _ in range(K)]
    full = SnapshotGrid(
        value=jnp.asarray(np.stack([
            np.asarray(events_to_grid(EventStream(evs), 0,
                                      n_chunks * CHUNK, 1).value)
            for evs in per_key])),
        valid=jnp.asarray(np.stack([
            np.asarray(events_to_grid(EventStream(evs), 0,
                                      n_chunks * CHUNK, 1).valid)
            for evs in per_key])),
        t0=0, prec=1)
    ref = Runner(_exe(keyed=True),
                 ExecPolicy(body="sparse", keys="vmapped"), n_keys=K,
                 segs_per_chunk=SPC).run({"in": full}, n_chunks)

    r = Runner(_exe(keyed=True), ExecPolicy(body="sparse", keys="vmapped"),
               n_keys=K, segs_per_chunk=SPC)
    ing = IngestRunner(r, lateness=lateness, policy="revise",
                       horizon_chunks=4)
    tagged = [(k, ev) for k, evs in enumerate(per_key) for ev in evs]
    arrivals = [("in", ev, k) for k, ev in _shuffled(rng, tagged, disorder)]
    sealed, corrections = _drive(ing, arrivals)
    snap = r.metrics.snapshot()["counters"]
    assert snap["ingest.revised_events"]["value"] > 0
    assert snap["ingest.beyond_horizon"]["value"] == 0
    assert len(corrections) > 0
    # keyed dirtiness: at least one correction leaves some key untouched
    assert any(not np.asarray(co.seg_mask).all(axis=1).all()
               for co in corrections)
    _assert_chunks_match(_overlay(sealed, corrections, keyed=True), ref,
                         n_chunks, keyed=True)


# ---------------------------------------------------------------------------
# the revision re-run is the sparse path, not a dense replay
# ---------------------------------------------------------------------------

def test_revision_is_compacted_and_transfer_free():
    exe = _exe()
    r = Runner(exe, ExecPolicy(body="sparse"), segs_per_chunk=SPC)
    r.enable_revision(3, revise_bound=16)
    rng = np.random.default_rng(7)
    events = _int_events(rng, 3 * CHUNK)
    grid = events_to_grid(EventStream(events), 0, 3 * CHUNK, 1)
    r.run({"in": grid}, 3)
    before = r.metrics.snapshot()["counters"]["runner.chunks"]["value"]

    # patch one tick of chunk 1, derive the dilated masks, revise 1..2
    v = np.asarray(grid.value).copy()
    m = np.asarray(grid.valid).copy()
    # patch chunk 1's LAST tick: its first segment stays clean (the
    # retro-dilation reaches backward only lookahead+prec), later
    # segments across the chunk boundary go dirty
    v[2 * CHUNK - 1] += 1.0
    t_patch = 2 * CHUNK  # tick index 2·CHUNK−1 lives at time 2·CHUNK

    def _chunk(c):
        sl = slice(c * CHUNK, (c + 1) * CHUNK)
        g = SnapshotGrid(value=jnp.asarray(v[sl]), valid=jnp.asarray(m[sl]),
                         t0=c * CHUNK, prec=1)
        jax.block_until_ready((g.value, g.valid))
        return g

    cp, sp = exe.change_plan, exe.change_plan.specs["in"]
    masks = [retro_segment_mask(sp.lookback, sp.lookahead, sp.prec,
                                c * CHUNK, cp.out_prec, cp.out_len, SPC,
                                [t_patch]) for c in (1, 2)]
    assert masks[0].any() and not all(mk.all() for mk in masks)
    outs = r.revise(1, [{"in": _chunk(1)}, {"in": _chunk(2)}], masks)

    snap = r.metrics.snapshot()["counters"]
    assert snap["runner.chunks"]["value"] == before  # no chunk re-stepped
    assert snap["runner.revision_runs"]["value"] == 1
    assert snap["runner.revision_chunks"]["value"] == 2
    n_units = sum(int(mk.sum()) for mk in masks)
    assert snap["runner.revision_units"]["value"] == n_units
    assert n_units < 2 * SPC  # compute-cap: strictly fewer than all units

    # dirty-segment outputs match a from-scratch run on the patched data
    ref = Runner(exe, ExecPolicy(body="sparse"), segs_per_chunk=SPC).run(
        {"in": SnapshotGrid(value=jnp.asarray(v), valid=jnp.asarray(m),
                            t0=0, prec=1)}, 3)
    for i, c in enumerate((1, 2)):
        tick = np.repeat(masks[i], SEG)
        sl = slice(c * CHUNK, (c + 1) * CHUNK)
        gm = np.asarray(outs[i].valid)[tick]
        assert np.array_equal(gm, np.asarray(ref.valid)[sl][tick])
        assert np.array_equal(np.asarray(outs[i].value)[tick][gm],
                              np.asarray(ref.value)[sl][tick][gm])

    # the staged revision step embeds the capacity-ladder switch (a cond:
    # device-side bucket pick), and its donation contract is clean
    steps = {s["label"]: s for s in r.staged_steps()}
    rev = steps["revise"]
    jpr = jax.make_jaxpr(lambda *a: rev["fn"](*a))(*rev["args"])
    assert any(site.prim == "cond" for site in walk(jpr))
    fs = pass_donation(make_target(r))
    assert not [f for f in fs if f.severity == "error"], fs

    # transfer-guard: on device-resident args the staged step dispatches
    # without a single host round-trip, and the donated tails are consumed
    st = next(e for e in r._rev_ring if e["chunk"] == 1)["state"]
    tails = {n: r._place(r._lift(jax.tree_util.tree_map(jnp.array, st[n])))
             for n in r._names()}
    chunk_in = r._ingest({"in": _chunk(1)})
    sd = jnp.asarray(masks[0].reshape(1, SPC))
    fn = r._revision_step()
    jax.block_until_ready((tails, chunk_in, sd))
    with jax.transfer_guard("disallow"):
        _outs, new_tails = fn(tails, chunk_in, sd)
        jax.block_until_ready(new_tails)
    assert all(x.is_deleted() for x in jax.tree_util.tree_leaves(tails))


def test_revise_validates_ring_and_extent():
    r = Runner(_exe(), ExecPolicy(body="sparse"), segs_per_chunk=SPC)
    with pytest.raises(ValueError, match="revision disabled"):
        r.revise(0, [], [])
    r.enable_revision(2, revise_bound=8)
    rng = np.random.default_rng(9)
    grid = events_to_grid(
        EventStream(_int_events(rng, 4 * CHUNK)), 0, 4 * CHUNK, 1)
    r.run({"in": grid}, 4)

    def _chunk(c):
        sl = slice(c * CHUNK, (c + 1) * CHUNK)
        return SnapshotGrid(value=grid.value[sl], valid=grid.valid[sl],
                            t0=c * CHUNK, prec=1)

    mk = np.ones(SPC, bool)
    with pytest.raises(ValueError, match="beyond the horizon"):
        r.revise(0, [{"in": _chunk(c)} for c in range(4)],
                 [mk] * 4)  # chunk 0's snapshot fell off the 2-deep ring
    with pytest.raises(ValueError, match="newest stepped chunk"):
        r.revise(2, [{"in": _chunk(2)}], [mk])  # stops short of chunk 3
    with pytest.raises(ValueError, match="one seg_dirty mask"):
        r.revise(2, [{"in": _chunk(2)}, {"in": _chunk(3)}], [mk])


# ---------------------------------------------------------------------------
# lateness policies + horizon refusal at the pipeline level
# ---------------------------------------------------------------------------

def _held_back_scenario(policy, lateness=2, horizon=1, seed=3):
    """Push everything in order except one early event held to the end."""
    rng = np.random.default_rng(seed)
    n_chunks = 4
    events = _int_events(rng, n_chunks * CHUNK)
    held = events[2]  # fully inside chunk 0
    assert held.end <= CHUNK
    rest = [e for i, e in enumerate(events) if i != 2]
    r = Runner(_exe(), ExecPolicy(body="sparse"), segs_per_chunk=SPC)
    ing = IngestRunner(r, lateness=lateness, policy=policy,
                       horizon_chunks=horizon)
    arrivals = ([("in", e, None) for e in rest]
                + [("in", held, None)])  # arrives after chunk 0 sealed
    sealed, corrections = _drive(ing, arrivals)
    ref = Runner(_exe(), ExecPolicy(body="sparse"), segs_per_chunk=SPC).run(
        {"in": events_to_grid(EventStream(rest), 0, n_chunks * CHUNK, 1)},
        n_chunks)
    return r, sealed, corrections, ref, n_chunks


def test_beyond_horizon_patch_refused_and_counted():
    r, sealed, corrections, ref, n = _held_back_scenario(
        "revise", lateness=2, horizon=1, seed=3)
    snap = r.metrics.snapshot()["counters"]
    assert snap["ingest.beyond_horizon"]["value"] == 1
    assert snap["ingest.dropped_events"]["value"] == 1
    # refused whole: outputs equal the in-order run WITHOUT that event
    _assert_chunks_match(_overlay(sealed, corrections), ref, n)


def test_policy_drop_discards_and_counts():
    r, sealed, corrections, ref, n = _held_back_scenario("drop")
    snap = r.metrics.snapshot()["counters"]
    assert snap["ingest.dropped_events"]["value"] == 1
    assert snap["ingest.late_events"]["value"] == 1
    assert corrections == []
    _assert_chunks_match(_overlay(sealed, corrections), ref, n)


def test_policy_buffer_readmits_and_counts():
    r, sealed, corrections, _ref, n = _held_back_scenario("buffer")
    snap = r.metrics.snapshot()["counters"]
    assert snap["ingest.buffered_events"]["value"] == 1
    assert corrections == []  # buffer never revises sealed outputs
    assert len(sealed) == n


def test_lateness_histogram_and_lag_gauge():
    r, *_ = _held_back_scenario("revise", horizon=4)
    snap = r.metrics.snapshot()
    assert snap["histograms"]["ingest.lateness"]["count"] == 1
    assert snap["gauges"]["ingest.watermark_lag"]["value"] >= 0


# ---------------------------------------------------------------------------
# analysis: revision-horizon coverage pass
# ---------------------------------------------------------------------------

def test_revision_pass_flags_undersized_horizon():
    exe = _exe()
    cp = exe.change_plan
    r = Runner(exe, ExecPolicy(body="sparse"), segs_per_chunk=SPC)
    assert pass_revision(make_target(r)) == []  # disabled: not applicable
    r.enable_revision(1, revise_bound=10 * CHUNK)
    fs = pass_revision(make_target(r))
    assert [f.code for f in fs] == ["revision-horizon-undersized"]
    assert fs[0].severity == "error" and fs[0].pass_name == "revision"

    need = cp.revision_horizon_chunks(16, SPC * SEG)
    r2 = Runner(exe, ExecPolicy(body="sparse"), segs_per_chunk=SPC)
    r2.enable_revision(need, revise_bound=16)
    fs2 = pass_revision(make_target(r2))
    assert [f.code for f in fs2] == ["revision-horizon-covered"]
    assert fs2[0].severity == "info"

    r3 = Runner(exe, ExecPolicy(body="sparse"), segs_per_chunk=SPC)
    r3.enable_revision(2)
    fs3 = pass_revision(make_target(r3))
    assert [f.code for f in fs3] == ["revision-bound-undeclared"]


def test_ingest_runner_default_horizon_satisfies_pass():
    """IngestRunner's derived horizon is the ChangePlan formula, so the
    analysis pass is green by construction."""
    r = Runner(_exe(), ExecPolicy(body="sparse"), segs_per_chunk=SPC)
    IngestRunner(r, lateness=40, policy="revise")
    fs = pass_revision(make_target(r))
    assert [f.code for f in fs] == ["revision-horizon-covered"]


def test_retro_span_and_horizon_arithmetic():
    cp = _exe().change_plan
    sp = cp.specs["in"]
    lo, hi = cp.retro_span("in", 10, 10)
    assert lo == 10 - sp.lookahead - sp.prec
    assert hi == 10 + sp.lookback + cp.out_prec
    # a bound that fits one chunk (minus slack) needs exactly one chunk
    slack = sp.lookahead + sp.prec
    assert cp.revision_horizon_chunks(CHUNK - slack, CHUNK) == 1
    assert cp.revision_horizon_chunks(CHUNK, CHUNK) == 2
    assert cp.revision_horizon_chunks(0, CHUNK) == 1
    # retro_segment_mask: a patched tick dirties the dilated segments of
    # LATER chunks too (lookback crosses the chunk boundary)
    mask_next = retro_segment_mask(sp.lookback, sp.lookahead, sp.prec,
                                   CHUNK, cp.out_prec, cp.out_len, SPC,
                                   [CHUNK - 2])
    assert mask_next[0]  # lookback 24 reaches into the following chunk
    assert retro_segment_mask(sp.lookback, sp.lookahead, sp.prec, 0,
                              cp.out_prec, cp.out_len, SPC, []).sum() == 0


# ---------------------------------------------------------------------------
# satellite: Runner.restore(strict=False) φ-re-init
# ---------------------------------------------------------------------------

def test_restore_strict_false_phi_reinit_matches_uninterrupted():
    """A checkpoint missing one (halo-free) input φ-re-inits its change
    lineage: the next chunk's first segment is forced dense (tick 0
    diffs against φ) and the continuation stays bit-identical — the
    conservative-dirtiness exactness contract, now pinned outside the
    session `_refit` path."""
    s1 = TStream.source("a", prec=1)
    s2 = TStream.source("b", prec=1)
    q = s1.window(8).mean().join(s2, lambda x, y: x + y)
    exe = qc.compile_query(q.node, out_len=SEG, pallas=False, sparse=True)
    assert exe.input_specs["b"].left_halo == 0  # raw source: halo-free

    rng = np.random.default_rng(4)
    n_chunks = 5
    T = n_chunks * CHUNK

    def _grid(seed):
        rr = np.random.default_rng(seed)
        change = rr.random(T) < 0.1
        change[0] = True
        raw = np.floor(rr.random(T) * 50).astype(np.float32)
        vals = raw[np.maximum.accumulate(
            np.where(change, np.arange(T), -1))]
        return SnapshotGrid(value=jnp.asarray(vals),
                            valid=jnp.ones(T, bool), t0=0, prec=1)

    ga, gb = _grid(10), _grid(11)

    def _chunks(c):
        sl = slice(c * CHUNK, (c + 1) * CHUNK)
        return {"a": SnapshotGrid(value=ga.value[sl], valid=ga.valid[sl],
                                  t0=c * CHUNK, prec=1),
                "b": SnapshotGrid(value=gb.value[sl], valid=gb.valid[sl],
                                  t0=c * CHUNK, prec=1)}

    r1 = Runner(exe, ExecPolicy(body="sparse"), segs_per_chunk=SPC)
    for c in range(3):
        r1.step(_chunks(c))
    st = r1.state()
    ref = [r1.step(_chunks(c)) for c in (3, 4)]

    # strict mode names the gap when the halo-free snapshot is missing
    st_no_prev = {**st, "__sparse": {**st["__sparse"],
                                     "prev": dict(st["__sparse"]["prev"]),
                                     "dirty": dict(st["__sparse"]["dirty"])}}
    del st_no_prev["__sparse"]["prev"]["b"]
    r2 = Runner(exe, ExecPolicy(body="sparse"), segs_per_chunk=SPC)
    with pytest.raises(ValueError, match="prev"):
        r2.restore(st_no_prev, strict=True)

    # drop input "b" from the checkpoint entirely: strict=False re-inits
    # its tail AND its 1-tick snapshot to φ
    st_missing = dict(st_no_prev)
    del st_missing["b"]
    del st_missing["__sparse"]["dirty"]["b"]
    r2.restore(st_missing, strict=False)
    assert r2._t == 3 * CHUNK
    got = [r2.step(_chunks(c)) for c in (3, 4)]
    # φ snapshot vs a valid tick 0: the first segment is forced dense
    r3 = Runner(exe, ExecPolicy(body="sparse"), segs_per_chunk=SPC)
    r3.restore(st_missing, strict=False)
    r3.step(_chunks(3))
    assert np.asarray(r3.last_seg_dirty)[:, 0].all()
    for g, w in zip(got, ref):
        gm, wm = np.asarray(g.valid), np.asarray(w.valid)
        assert np.array_equal(gm, wm)
        assert np.array_equal(np.asarray(g.value)[gm],
                              np.asarray(w.value)[wm])


# ---------------------------------------------------------------------------
# property: random bounded permutations (slow job)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_property_bounded_disorder_is_invisible():
    """Hypothesis sweep of the headline invariant: random event streams,
    random bounded arrival permutations, random lateness allowances —
    sealed outputs + revisions are always bit-identical to in-order
    execution."""
    hypothesis = pytest.importorskip("hypothesis")
    given, settings, st = (hypothesis.given, hypothesis.settings,
                           hypothesis.strategies)
    n_chunks = 5

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           disorder=st.sampled_from([0, 3, 9, 18, 27]),
           lateness=st.sampled_from([0, 3, 8]))
    def check(seed, disorder, lateness):
        rng = np.random.default_rng(seed)
        events = _int_events(rng, n_chunks * CHUNK, gap=True)
        if not events:
            return
        full = events_to_grid(EventStream(events), 0, n_chunks * CHUNK, 1)
        ref = Runner(_exe(), ExecPolicy(body="sparse"),
                     segs_per_chunk=SPC).run({"in": full}, n_chunks)
        r = Runner(_exe(), ExecPolicy(body="sparse"), segs_per_chunk=SPC)
        # horizon 4 chunks (64 time units) covers disorder+maxdur ≤ 33
        ing = IngestRunner(r, lateness=lateness, policy="revise",
                           horizon_chunks=4)
        arrivals = [("in", ev, None) for _k, ev in
                    _shuffled(rng, [(0, e) for e in events], disorder)]
        sealed, corrections = _drive(ing, arrivals)
        snap = r.metrics.snapshot()["counters"]
        assert snap["ingest.beyond_horizon"]["value"] == 0
        _assert_chunks_match(_overlay(sealed, corrections), ref, n_chunks)

    check()
