"""Change-compressed sparse execution tests (repro.core.sparse).

The whole subsystem rests on one invariant: sparse ≡ dense **bit-for-bit**
on integer-valued data (same partitioning ⇒ identical float association;
see the float caveat in repro/multiquery/__init__.py), whatever the change
pattern — including the all-clean and all-dirty extremes, dirty spans that
cross partition/chunk boundaries, chunked execution with carried change
state (SparseStreamRunner, KeyedEngine sparse mode) and explicit
change-event channels.  The shard_map comparison lives in
tests/test_parallel_multidev.py (needs a multi-device subprocess).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compile as qc
from repro.core import sparse as sp
from repro.core.frontend import TStream
from repro.core.parallel import (SparseStreamRunner, StreamRunner,
                                 partition_run)
from repro.core.stream import SnapshotGrid
from repro.engine import KeyedEngine, keyed_grid

N = 512


def pw_const(n, rate, seed, invalid_spans=()):
    """Piecewise-constant integer-valued stream: ``rate`` of ticks change;
    ``invalid_spans`` are (start, stop) φ gaps (validity changes count as
    changes too)."""
    rng = np.random.default_rng(seed)
    change = rng.random(n) < rate
    change[0] = True
    raw = np.floor(rng.random(n) * 100).astype(np.float32)
    idx = np.maximum.accumulate(np.where(change, np.arange(n), -1))
    vals = raw[idx]
    valid = np.ones(n, bool)
    for a, b in invalid_spans:
        valid[a:b] = False
    return vals, valid


def _grid(vals, valid, t0=0, prec=1):
    return SnapshotGrid(value=jnp.asarray(vals), valid=jnp.asarray(valid),
                        t0=t0, prec=prec)


def _assert_same(ref, got, ctx=""):
    m1, m2 = np.asarray(ref.valid), np.asarray(got.valid)
    assert np.array_equal(m1, m2), (ctx, m1.sum(), m2.sum())
    r, g = ref.value, got.value
    if isinstance(r, dict):
        for k in r:
            assert np.array_equal(np.asarray(r[k])[m1],
                                  np.asarray(g[k])[m1]), (ctx, k)
    else:
        assert np.array_equal(np.asarray(r)[m1], np.asarray(g)[m1]), ctx


# query zoo: (name, builder, segment out_len) — spans window/strided/shift/
# φ-aware/interp shapes so dirtiness dilation is exercised per edge rule
def _trend(s):
    return (s.window(16).mean()
            .join(s.window(32).mean(), lambda a, b: a - b)
            .where(lambda d: d > 0))


def _tumbling(s):
    return s.window(8, stride=8).sum()


def _shifted(s):
    return s.join(s.shift(3), lambda a, b: a - b)


def _coalesce_const(s):
    return s.coalesce(TStream.const(5.0))


def _interp(s):
    return s.interpolate(mode="linear", max_gap=8)  # lookahead query


QUERIES = {
    "trend": (_trend, 32),
    "tumbling": (_tumbling, 8),     # out_prec 8 -> span 64
    "shifted": (_shifted, 32),
    "coalesce_const": (_coalesce_const, 32),
    "interp": (_interp, 32),
}


@pytest.mark.parametrize("name", sorted(QUERIES))
def test_sparse_run_bit_identical_to_partition_run(name):
    fn, out_len = QUERIES[name]
    q = fn(TStream.source("in", prec=1))
    exe = qc.compile_query(q.node, out_len=out_len, pallas=False,
                           sparse=True)
    n_parts = N // (out_len * exe.out_prec)
    # bursty change pattern: value changes at {77, 78, 305}, φ gap
    # (100, 130) — most of the timeline holds, so every query shape must
    # leave some segments clean
    vals = np.full(N, 6.0, np.float32)
    vals[77] = 13.0
    vals[78:] = 2.0
    vals[305:] = 9.0
    valid = np.ones(N, bool)
    valid[100:130] = False
    g = {"in": _grid(vals, valid)}
    ref = partition_run(exe, g, 0, n_parts)
    got = sp.sparse_run(exe, g, 0, n_parts)
    _assert_same(ref, got, name)
    # the sparse path must actually compact on this ~2%-change stream
    n_dirty = int(np.asarray(sp.segment_mask(exe, g, 0, n_parts)).sum())
    assert n_dirty < n_parts, (name, n_dirty, n_parts)


@pytest.mark.parametrize("name", sorted(QUERIES))
def test_fused_run_bit_identical_to_three_phase(name):
    """The fused single-jit path (kernel mask + device-resident bucket pick
    + switch) must reproduce the three-phase staged path — the semantics of
    record — bit-for-bit, at a compacting change rate AND at the all-dirty
    extreme (which exercises the dense-all full-capacity switch branch
    against the staged gather/scatter/hold body)."""
    fn, out_len = QUERIES[name]
    q = fn(TStream.source("in", prec=1))
    exe = qc.compile_query(q.node, out_len=out_len, pallas=False,
                           sparse=True)
    n_parts = N // (out_len * exe.out_prec)
    for rate, seed in ((0.02, 3), (1.0, 5)):
        vals, valid = pw_const(N, rate, seed, invalid_spans=((40, 70),))
        g = {"in": _grid(vals, valid)}
        got = sp.sparse_run(exe, g, 0, n_parts, fused=True)
        ref = sp.sparse_run(exe, g, 0, n_parts, fused=False)
        _assert_same(ref, got, f"{name} rate={rate}")


@pytest.mark.parametrize("name", sorted(QUERIES))
def test_segment_mask_pallas_kernel_matches_staged(name):
    """The fused change-detection kernel (interpret mode on CPU) resolves
    the same per-segment dirty flags as the staged source_dirty +
    seg_ranges + range_any reference, across the query zoo's dilation
    shapes (window, strided output, shift, lookahead interp)."""
    fn, out_len = QUERIES[name]
    q = fn(TStream.source("in", prec=1))
    exe = qc.compile_query(q.node, out_len=out_len, pallas=False,
                           sparse=True)
    n_parts = N // (out_len * exe.out_prec)
    vals, valid = pw_const(N, 0.03, seed=17, invalid_spans=((200, 230),))
    g = {"in": _grid(vals, valid)}
    staged = np.asarray(sp.segment_mask(exe, g, 0, n_parts))
    kernel = np.asarray(sp.segment_mask(exe, g, 0, n_parts, pallas=True))
    oracle = np.asarray(sp.segment_mask(exe, g, 0, n_parts, pallas=False))
    assert np.array_equal(staged, kernel), (name, staged, kernel)
    assert np.array_equal(staged, oracle), (name, staged, oracle)


def test_strided_output_dilation_covers_stride_gap():
    """Regression: with out_prec > input prec the hold rule compares ticks
    one *output stride* apart, so the dilation must widen by
    ``out_prec − prec`` — a change landing just before a segment's lineage
    bound (tick 60 here) must still dirty the following segment."""
    q = _tumbling(TStream.source("in", prec=1))  # window 8, stride 8
    exe = qc.compile_query(q.node, out_len=8, pallas=False, sparse=True)
    n_parts = 256 // 64
    for pos in (57, 60, 63, 64):  # straddle the 8-wide stride gap
        vals = np.full(256, 3.0, np.float32)
        vals[pos:] = 8.0
        g = {"in": _grid(vals, np.ones(256, bool))}
        _assert_same(partition_run(exe, g, 0, n_parts),
                     sp.sparse_run(exe, g, 0, n_parts), f"pos={pos}")


def test_lookahead_grid_end_is_a_virtual_change():
    """Regression: the supplied grid's end flips lookahead lineages to φ;
    trailing outputs must compute (dense yields φ there), not hold the
    last valid value."""
    q = TStream.source("in", prec=1).shift(-5)
    exe = qc.compile_query(q.node, out_len=32, pallas=False, sparse=True)
    vals = np.full(256, 3.0, np.float32)  # fully constant: no real changes
    g = {"in": _grid(vals, np.ones(256, bool))}
    ref = partition_run(exe, g, 0, 8)
    got = sp.sparse_run(exe, g, 0, 8)
    assert not np.asarray(ref.valid)[-5:].any()  # dense: trailing φ
    _assert_same(ref, got, "grid-end")


def test_sparse_all_clean_and_all_dirty_extremes():
    q = _trend(TStream.source("in", prec=1))
    exe = qc.compile_query(q.node, out_len=32, pallas=False, sparse=True)
    # all-clean: constant stream — only the forced-dirty stream-start tick
    # (and its dilation into the next segment) computes
    g = {"in": _grid(np.full(N, 7.0, np.float32), np.ones(N, bool))}
    mask = np.asarray(sp.segment_mask(exe, g, 0, N // 32))
    assert mask[0] and not mask[2:].any(), mask.astype(int)
    _assert_same(partition_run(exe, g, 0, N // 32),
                 sp.sparse_run(exe, g, 0, N // 32), "all-clean")
    # all-dirty: every tick changes — every segment computes
    vals, valid = pw_const(N, 1.0, seed=5)
    g = {"in": _grid(vals, valid)}
    assert np.asarray(sp.segment_mask(exe, g, 0, N // 32)).all()
    _assert_same(partition_run(exe, g, 0, N // 32),
                 sp.sparse_run(exe, g, 0, N // 32), "all-dirty")


def test_dirty_span_crosses_partition_boundary():
    """A change just before a partition boundary dirties the *next*
    partition too (its lookback window reaches across); outputs must match
    dense and the dilation must be visible in the segment mask."""
    q = _trend(TStream.source("in", prec=1))  # lookback 32
    exe = qc.compile_query(q.node, out_len=32, pallas=False, sparse=True)
    vals = np.full(N, 4.0, np.float32)
    vals[95:] = 9.0  # change at tick 95: dirties segments 2 (64..95) and 3+
    g = {"in": _grid(vals, np.ones(N, bool))}
    mask = np.asarray(sp.segment_mask(exe, g, 0, N // 32))
    assert mask[2] and mask[3], mask.astype(int)  # span crosses 96-boundary
    # beyond the change's 32-tick lookback reach, segments stay clean
    assert not mask[4:].any(), mask.astype(int)
    _assert_same(partition_run(exe, g, 0, N // 32),
                 sp.sparse_run(exe, g, 0, N // 32), "boundary")


def test_sparse_stream_runner_matches_dense_chunked():
    """Chunked sparse execution with carried change state ≡ the dense
    StreamRunner on the same chunking, including an all-clean middle chunk
    and a change in the last ticks of a chunk (the carried dirty tail must
    dirty the next chunk's leading segment)."""
    q = _trend(TStream.source("in", prec=1))
    exe_s = qc.compile_query(q.node, out_len=32, pallas=False, sparse=True)
    exe_d = qc.compile_query(q.node, out_len=32, pallas=False)

    vals = np.full(N, 3.0, np.float32)
    vals[127:] = 8.0   # last tick of chunk 0 (chunks of 128): dirty tail
    vals[300:] = 2.0   # mid chunk 2
    valid = np.ones(N, bool)

    dense = StreamRunner(exe_d)
    runner = SparseStreamRunner(exe_s, segs_per_chunk=4)
    got_v, got_m, ref_v, ref_m = [], [], [], []
    for c in range(4):
        sl = slice(c * 128, (c + 1) * 128)
        chunk = _grid(vals[sl], valid[sl], t0=c * 128)
        o = runner.step({"in": chunk})
        got_v.append(np.asarray(o.value))
        got_m.append(np.asarray(o.valid))
        for k in range(4):  # dense runner steps one 32-tick partition
            ssl = slice(c * 128 + k * 32, c * 128 + (k + 1) * 32)
            od = dense.step({"in": _grid(vals[ssl], valid[ssl])})
            ref_v.append(np.asarray(od.value))
            ref_m.append(np.asarray(od.valid))
    gm, rm = np.concatenate(got_m), np.concatenate(ref_m)
    gv, rv = np.concatenate(got_v), np.concatenate(ref_v)
    assert np.array_equal(gm, rm)
    assert np.array_equal(gv[rm], rv[rm])


def test_sparse_stream_runner_checkpoint_resume_bit_identical():
    q = _trend(TStream.source("in", prec=1))
    exe = qc.compile_query(q.node, out_len=32, pallas=False, sparse=True)
    vals, valid = pw_const(N, 0.05, seed=11)

    r1 = SparseStreamRunner(exe, segs_per_chunk=4)
    r1.step({"in": _grid(vals[:128], valid[:128])})
    state = r1.state()

    r2 = SparseStreamRunner(exe, segs_per_chunk=4)
    r2.restore(state)
    a = r1.step({"in": _grid(vals[128:256], valid[128:256])})
    b = r2.step({"in": _grid(vals[128:256], valid[128:256])})
    assert a.t0 == b.t0 == 128
    assert np.array_equal(np.asarray(a.valid), np.asarray(b.valid))
    assert np.array_equal(np.asarray(a.value), np.asarray(b.value))


def test_keyed_engine_sparse_matches_dense():
    """Key-axis compaction: engines with mostly-idle keys must agree with
    dense keyed execution bit-for-bit, and only small compaction buckets
    may ever have been compiled."""
    K, T, P = 32, 256, 4
    rng = np.random.default_rng(2)
    vals = np.zeros((K, T), np.float32)
    valid = np.zeros((K, T), bool)
    for k in range(0, K, 4):  # 1 in 4 keys active
        v, m = pw_const(T, 0.03, seed=k)
        vals[k], valid[k] = v, m
    q = _trend(TStream.source("in", keyed=True))
    exe_d = qc.compile_query(q.node, out_len=T // P, pallas=False)
    exe_s = qc.compile_query(q.node, out_len=T // P, pallas=False,
                             sparse=True)
    g = {"in": keyed_grid(vals, valid)}
    ref = KeyedEngine(exe_d, n_keys=K).run(g, P)
    eng = KeyedEngine(exe_s, n_keys=K, sparse=True)
    got = eng.run(g, P)
    _assert_same(ref, got, "keyed")
    # after the forced-dense first step, later steps compact to <= 16 keys
    # (the staged compute steps live in the unified runner's cache, keyed
    # ("compute", ..., capacity))
    caps = sorted(k[-1] for k in exe_s._runner_step_cache
                  if isinstance(k, tuple) and k[0] == "compute")
    assert caps and caps[0] <= K // 2, caps


def test_keyed_engine_sparse_checkpoint_resume_bit_identical():
    K, T = 16, 128
    rng = np.random.default_rng(4)
    vals = np.stack([pw_const(T, 0.05, seed=k)[0] for k in range(K)])
    valid = np.ones((K, T), bool)
    q = _trend(TStream.source("in", keyed=True))
    exe = qc.compile_query(q.node, out_len=32, pallas=False, sparse=True)

    def chunk(j):
        sl = slice(j * 32, (j + 1) * 32)
        return {"in": keyed_grid(vals[:, sl], valid[:, sl], t0=j * 32)}

    e1 = KeyedEngine(exe, n_keys=K, sparse=True)
    e1.step(chunk(0))
    e1.step(chunk(1))
    state = e1.state()
    e2 = KeyedEngine(exe, n_keys=K, sparse=True)
    e2.restore(state)
    a = e1.step(chunk(2))
    b = e2.step(chunk(2))
    assert a.t0 == b.t0
    assert np.array_equal(np.asarray(a.valid), np.asarray(b.valid))
    assert np.array_equal(np.asarray(a.value), np.asarray(b.value))


def test_explicit_change_channel_overrides_diff():
    """An explicit change-event channel replaces the value diff: the true
    change mask reproduces the auto result; an all-true mask degrades to
    dense (all segments dirty) with identical output."""
    q = _trend(TStream.source("in", prec=1))
    exe = qc.compile_query(q.node, out_len=32, pallas=False, sparse=True)
    rng = np.random.default_rng(9)
    change = rng.random(N) < 0.02
    change[0] = True
    raw = np.floor(rng.random(N) * 100).astype(np.float32)
    vals = raw[np.maximum.accumulate(np.where(change, np.arange(N), -1))]
    g = {"in": _grid(vals, np.ones(N, bool))}
    ref = partition_run(exe, g, 0, N // 32)
    for d in (jnp.asarray(change), jnp.ones(N, bool)):
        got = sp.sparse_run(exe, g, 0, N // 32, dirty={"in": d})
        _assert_same(ref, got, "explicit")
    mask = sp.segment_mask(exe, g, 0, N // 32,
                           dirty={"in": jnp.ones(N, bool)})
    assert np.asarray(mask).all()


def test_sparse_run_requires_sparse_compile():
    q = _trend(TStream.source("in", prec=1))
    exe = qc.compile_query(q.node, out_len=32, pallas=False)  # no sparse
    g = {"in": _grid(np.zeros(N, np.float32), np.ones(N, bool))}
    with pytest.raises(ValueError, match="sparse=True"):
        sp.sparse_run(exe, g, 0, N // 32)


def test_bucket_capacity_policy():
    assert sp.bucket_capacity(0, 16) == 1
    assert sp.bucket_capacity(1, 16) == 1
    assert sp.bucket_capacity(3, 16) == 4
    assert sp.bucket_capacity(9, 16) == 16
    assert sp.bucket_capacity(100, 16) == 16  # clipped to the segment count


def test_hypothesis_random_change_masks_never_alter_outputs():
    """Property: for *any* change mask (and any φ gaps), sparse ≡ dense."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    n = 128
    q = _trend(TStream.source("in", prec=1))
    exe = qc.compile_query(q.node, out_len=16, pallas=False, sparse=True)

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.floats(0.0, 1.0),
           st.floats(0.0, 0.3))
    def prop(seed, rate, invalid_rate):
        rng = np.random.default_rng(seed)
        change = rng.random(n) < rate
        change[0] = True
        raw = np.floor(rng.random(n) * 100).astype(np.float32)
        vals = raw[np.maximum.accumulate(
            np.where(change, np.arange(n), -1))]
        valid = rng.random(n) >= invalid_rate
        g = {"in": _grid(vals, valid)}
        ref = partition_run(exe, g, 0, n // 16)
        got = sp.sparse_run(exe, g, 0, n // 16)
        _assert_same(ref, got, f"seed={seed}")

    prop()
