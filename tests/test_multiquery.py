"""Multi-query sharing tests (repro/multiquery).

The contract under test: a MultiQuerySession serving N queries from one
pass is *bit-identical* to running each query independently through the
per-query executors (StreamRunner unkeyed, KeyedEngine keyed), across
chunk boundaries; sharing is real (shared interior nodes evaluate once per
chunk); and attach/detach mid-run preserves the merged halo state exactly.

Test data is integer-valued (floor of uniforms): float32 window sums over
small integers are exact, so bit-identity is insensitive to the association
differences a wider union grid could otherwise introduce.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compile as qc, ir, plan as qplan
from repro.core.frontend import TStream
from repro.core.parallel import StreamRunner, check_single_hop_halo
from repro.core.stream import SnapshotGrid
from repro.data import apps as A
from repro.engine import KeyedEngine, keyed_grid
from repro.multiquery import MultiQuerySession, SharedPlanCache

SPAN, N_CHUNKS = 64, 3     # 3 chunks => 2 chunk boundaries
K = 8
N_DASH = 16


def _int_stream(shape, seed, p_valid=1.0):
    rng = np.random.default_rng(seed)
    vals = np.floor(rng.random(shape) * 100).astype(np.float32)
    valid = (rng.random(shape) < p_valid) if p_valid < 1.0 \
        else np.ones(shape, bool)
    return vals, valid


def _dash(keyed=False, n=N_DASH):
    # window sizes < SPAN so halo carry across chunks is exercised, and
    # windows span chunk boundaries
    return A.dashboard_queries(n, short=12, long=40, keyed=keyed)


def _assert_bit_identical(got: SnapshotGrid, want: SnapshotGrid, ctx):
    assert np.array_equal(np.asarray(got.valid), np.asarray(want.valid)), ctx
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=str(ctx)),
        got.value, want.value)


# ---------------------------------------------------------------------------
# equivalence: shared == independent, unkeyed and keyed
# ---------------------------------------------------------------------------

def test_session_matches_independent_streamrunner_unkeyed():
    queries = _dash(n=6)
    vals, valid = _int_stream(SPAN * N_CHUNKS, seed=3, p_valid=0.9)
    full = SnapshotGrid(value=jnp.asarray(vals), valid=jnp.asarray(valid),
                        t0=0, prec=1)

    sess = MultiQuerySession(SPAN, pallas=False)
    for name, q in queries.items():
        sess.attach(name, q)
    outs = sess.run({"in": full}, N_CHUNKS)

    for name, q in queries.items():
        runner = StreamRunner(qc.compile_query(q.node, out_len=SPAN,
                                               pallas=False))
        ref_v, ref_m = [], []
        for k in range(N_CHUNKS):
            chunk = {"in": SnapshotGrid(
                value=full.value[k * SPAN:(k + 1) * SPAN],
                valid=full.valid[k * SPAN:(k + 1) * SPAN],
                t0=k * SPAN, prec=1)}
            o = runner.step(chunk)
            ref_v.append(np.asarray(o.value))
            ref_m.append(np.asarray(o.valid))
        want = SnapshotGrid(value=np.concatenate(ref_v),
                            valid=np.concatenate(ref_m), t0=0, prec=1)
        _assert_bit_identical(outs[name], want, name)


def test_session_matches_independent_keyed_engine():
    queries = _dash(keyed=True, n=6)
    vals, valid = _int_stream((K, SPAN * N_CHUNKS), seed=4, p_valid=0.85)
    grids = {"in": keyed_grid(vals, valid)}

    sess = MultiQuerySession(SPAN, n_keys=K, pallas=False)
    for name, q in queries.items():
        sess.attach(name, q)
    outs = sess.run(grids, N_CHUNKS)

    for name, q in queries.items():
        exe = qc.compile_query(q.node, out_len=SPAN, pallas=False)
        want = KeyedEngine(exe, n_keys=K).run(grids, N_CHUNKS)
        _assert_bit_identical(outs[name], want, name)


def test_session_equivalence_with_mixed_windows():
    """Queries with *different* lookbacks share a source whose union grid is
    wider than any single query's plan; outputs must still match the
    per-query baselines exactly."""
    def variant(w, thr):
        s = TStream.source("in", prec=1)
        return (s.window(w).mean().join(s, lambda m, x: x - m)
                .where(lambda d, t=thr: d > t))

    queries = {"w16": variant(16, 0.0), "w48": variant(48, 1.0),
               "w24": variant(24, 2.0)}
    vals, valid = _int_stream(SPAN * N_CHUNKS, seed=9, p_valid=0.9)
    full = SnapshotGrid(value=jnp.asarray(vals), valid=jnp.asarray(valid),
                        t0=0, prec=1)
    sess = MultiQuerySession(SPAN, pallas=False)
    for name, q in queries.items():
        sess.attach(name, q)
    outs = sess.run({"in": full}, N_CHUNKS)
    for name, q in queries.items():
        runner = StreamRunner(qc.compile_query(q.node, out_len=SPAN,
                                               pallas=False))
        ref_v, ref_m = [], []
        for k in range(N_CHUNKS):
            o = runner.step({"in": SnapshotGrid(
                value=full.value[k * SPAN:(k + 1) * SPAN],
                valid=full.valid[k * SPAN:(k + 1) * SPAN],
                t0=k * SPAN, prec=1)})
            ref_v.append(np.asarray(o.value))
            ref_m.append(np.asarray(o.valid))
        want = SnapshotGrid(value=np.concatenate(ref_v),
                            valid=np.concatenate(ref_m), t0=0, prec=1)
        _assert_bit_identical(outs[name], want, name)


# ---------------------------------------------------------------------------
# sharing is real
# ---------------------------------------------------------------------------

def test_shared_aggregate_evaluates_once_per_chunk():
    """16 dashboard queries all read the same window aggregates; the
    instrumented evaluator must run each shared node once per chunk."""
    queries = _dash(n=N_DASH)
    vals, valid = _int_stream(SPAN * N_CHUNKS, seed=5)
    full = {"in": SnapshotGrid(value=jnp.asarray(vals),
                               valid=jnp.asarray(valid), t0=0, prec=1)}
    sess = MultiQuerySession(SPAN, pallas=False, instrument=True)
    for name, q in queries.items():
        sess.attach(name, q)
    sess.run(full, N_CHUNKS)

    s = TStream.source("in", prec=1)
    shared_fast = s.window(12).mean()
    shared_slow = s.window(40).mean()
    assert sess.eval_count(shared_fast) == N_CHUNKS
    assert sess.eval_count(shared_slow) == N_CHUNKS
    assert sess.eval_count(s) == N_CHUNKS  # the source read itself

    rep = sess.sharing_report()
    assert rep.n_queries == N_DASH
    assert rep.shared_nodes >= 4           # source + fast/slow mean + stddev
    assert rep.union_nodes < rep.independent_nodes
    assert rep.sharing_ratio > 2.0


def test_cache_interns_across_independently_built_queries():
    cache = SharedPlanCache()
    q1 = _dash(n=4)
    q2 = _dash(n=4)  # rebuilt from scratch: distinct objects, same structure
    r1 = {k: cache.intern(v.node) for k, v in q1.items()}
    r2 = {k: cache.intern(v.node) for k, v in q2.items()}
    for k in r1:
        assert r1[k] is r2[k]  # hash-consing: structural identity == identity


_SUBPROC_QUERY = textwrap.dedent("""
    import sys
    sys.path.insert(0, {src!r})
    from repro.core.frontend import TStream
    from repro.core import ir
    s = TStream.source("in", prec=1)
    fast = s.window(12).mean()
    slow = s.window(40).mean()
    q = (fast.join(slow, lambda a, b: a - b)
         .where(lambda d, t=0.25: d > t))
    print(ir.fingerprint(q.node))
""")


def test_fingerprint_stable_across_processes():
    """A plan cache keyed by fingerprint may outlive one interpreter: the
    digest must not depend on the process — same query, different
    processes, different hash seeds, same fingerprint (no id()/ordering
    leaks).  Cheap: the subprocess imports only frontend+ir, no jax."""
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    code = _SUBPROC_QUERY.format(src=src)
    digests = []
    for seed in ("0", "4242"):
        env = dict(os.environ, PYTHONHASHSEED=seed)
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=120)
        assert out.returncode == 0, out.stderr
        digests.append(out.stdout.strip())
    # in-process reference (lambdas compiled from this file, not from -c)
    s = TStream.source("in", prec=1)
    fast = s.window(12).mean()
    slow = s.window(40).mean()
    q = (fast.join(slow, lambda a, b: a - b)
         .where(lambda d, t=0.25: d > t))
    digests.append(ir.fingerprint(q.node))
    assert len(set(digests)) == 1, digests


# ---------------------------------------------------------------------------
# attach / detach mid-run
# ---------------------------------------------------------------------------

def _chunk(full, k, taxis=0):
    sl = slice(k * SPAN, (k + 1) * SPAN)
    if taxis:
        return {"in": SnapshotGrid(value=full.value[:, sl],
                                   valid=full.valid[:, sl],
                                   t0=k * SPAN, prec=1)}
    return {"in": SnapshotGrid(value=full.value[sl], valid=full.valid[sl],
                               t0=k * SPAN, prec=1)}


@pytest.mark.parametrize("keyed", [False, True])
def test_attach_detach_matches_fresh_replay_from_checkpoint(keyed):
    queries = _dash(keyed=keyed, n=6)
    names = list(queries)
    shape = (K, SPAN * (N_CHUNKS + 1)) if keyed else SPAN * (N_CHUNKS + 1)
    vals, valid = _int_stream(shape, seed=6, p_valid=0.9)
    full = (keyed_grid(vals, valid) if keyed else
            SnapshotGrid(value=jnp.asarray(vals), valid=jnp.asarray(valid),
                         t0=0, prec=1))
    taxis = 1 if keyed else 0
    kw = {"n_keys": K} if keyed else {}

    live = MultiQuerySession(SPAN, pallas=False, **kw)
    for n in names[:3]:
        live.attach(n, queries[n])
    live.step(_chunk(full, 0, taxis))
    ckpt1 = live.state()

    live.attach(names[3], queries[names[3]])      # attach mid-run
    o1 = live.step(_chunk(full, 1, taxis))
    ckpt2 = live.state()
    live.detach(names[0])                         # detach mid-run
    o2 = live.step(_chunk(full, 2, taxis))
    o3 = live.step(_chunk(full, 3, taxis))

    # fresh session with the post-attach query set, replayed from ckpt1
    fresh = MultiQuerySession(SPAN, pallas=False, **kw)
    for n in names[:4]:
        fresh.attach(n, queries[n])
    fresh.restore(ckpt1)
    p1 = fresh.step(_chunk(full, 1, taxis))
    for n in names[:4]:
        _assert_bit_identical(o1[n], p1[n], ("attach", n))

    # fresh session with the post-detach query set, replayed from ckpt2
    fresh2 = MultiQuerySession(SPAN, pallas=False, **kw)
    for n in names[1:4]:
        fresh2.attach(n, queries[n])
    fresh2.restore(ckpt2)
    p2 = fresh2.step(_chunk(full, 2, taxis))
    p3 = fresh2.step(_chunk(full, 3, taxis))
    for n in names[1:4]:
        _assert_bit_identical(o2[n], p2[n], ("detach", n))
        _assert_bit_identical(o3[n], p3[n], ("detach2", n))
    assert o3[names[1]].t0 == p3[names[1]].t0


# ---------------------------------------------------------------------------
# validation / guards
# ---------------------------------------------------------------------------

def test_session_rejects_conflicting_source_declarations():
    sess = MultiQuerySession(SPAN, pallas=False)
    sess.attach("a", TStream.source("in", prec=1).window(8).mean())
    sess.attach("b", TStream.source("in", prec=2).window(8).mean())
    with pytest.raises(ValueError, match="conflicting"):
        sess.step({"in": SnapshotGrid(value=jnp.zeros(SPAN),
                                      valid=jnp.ones(SPAN, bool),
                                      t0=0, prec=1)})


def test_session_rejects_keyed_unkeyed_mix():
    sess = MultiQuerySession(SPAN, n_keys=K, pallas=False)
    sess.attach("a", TStream.source("s1", keyed=True).window(8).mean())
    with pytest.raises(ValueError, match="keyed"):
        sess.attach("b", TStream.source("s2", keyed=False).window(8).mean())


def test_session_rejects_lookahead():
    sess = MultiQuerySession(SPAN, pallas=False)
    with pytest.raises(NotImplementedError, match="lookahead"):
        sess.attach("a", TStream.source("in").shift(-4))


def test_detach_clears_keyedness_and_validates_name():
    sess = MultiQuerySession(SPAN, n_keys=K, pallas=False)
    sess.attach("a", TStream.source("s1", keyed=True).window(8).mean())
    with pytest.raises(ValueError, match="no query"):
        sess.detach("nope")
    sess.detach("a")
    # emptied session accepts the other keyedness
    sess.attach("b", TStream.source("s2", keyed=False).window(8).mean())


def test_fingerprint_distinguishes_captured_globals():
    """Two bytecode-identical lambdas reading different module-level values
    by the same name must not collide (they compute different things)."""
    ns1 = {"THR": 1.0}
    ns2 = {"THR": 99.0}
    f1 = eval("lambda v: v > THR", ns1)
    f2 = eval("lambda v: v > THR", ns2)
    f3 = eval("lambda v: v > THR", dict(ns1))
    s = TStream.source("in", prec=1)
    a = ir.fingerprint(s.where(f1).node)
    b = ir.fingerprint(s.where(f2).node)
    c = ir.fingerprint(s.where(f3).node)
    assert a != b
    assert a == c


class _Thresh:
    def __init__(self, t):
        self.t = t

    def pred(self, v):
        return v > self.t


def test_fingerprint_distinguishes_bound_method_receivers():
    """Bound methods share bytecode but not behaviour: the receiver's state
    is part of the structural identity."""
    s = TStream.source("in", prec=1)
    a = ir.fingerprint(s.where(_Thresh(1.0).pred).node)
    b = ir.fingerprint(s.where(_Thresh(5.0).pred).node)
    c = ir.fingerprint(s.where(_Thresh(1.0).pred).node)
    assert a != b
    assert a == c


def test_fingerprint_ignores_attribute_name_collisions_with_globals():
    """co_names holds attribute names too; ``d["x"]``-style or method-call
    lambdas must not resolve those names against the defining module's
    namespace (which may hold unrelated, even unfingerprintable, values)."""
    ns1 = {"mean": open(os.devnull)}   # unrelated, unfingerprintable global
    ns2 = {}
    try:
        f1 = eval("lambda v: v.mean()", ns1)
        f2 = eval("lambda v: v.mean()", ns2)
        s = TStream.source("in", prec=1)
        assert (ir.fingerprint(s.select(f1).node)
                == ir.fingerprint(s.select(f2).node))
    finally:
        ns1["mean"].close()


def test_eval_counts_cleared_on_reset():
    queries = _dash(n=4)
    vals, valid = _int_stream(SPAN * 2, seed=8)
    full = {"in": SnapshotGrid(value=jnp.asarray(vals),
                               valid=jnp.asarray(valid), t0=0, prec=1)}
    sess = MultiQuerySession(SPAN, pallas=False, instrument=True)
    for name, q in queries.items():
        sess.attach(name, q)
    sess.run(full, 2)
    sess.reset()
    sess.run(full, 2)  # warmup-then-measure pattern must not double-count
    s = TStream.source("in", prec=1)
    assert sess.eval_count(s.window(12).mean()) == 2


def test_union_plan_merges_halo_contracts():
    a = TStream.source("in", prec=1).window(16).mean()
    b = TStream.source("in", prec=1).window(48).mean()
    up = qplan.plan_union([a.node, b.node], span=SPAN)
    assert up.input_specs["in"].left_halo == 48    # union of 16 and 48
    pa = qplan.plan_query(a.node, out_len=SPAN)
    assert pa.input_specs["in"].left_halo == 16


def test_halo_overflow_guard_reports_hop_geometry():
    """The halo guard is informational now: deep-lookback configs are
    served by the multi-hop exchange (core/halo.py), so nothing raises;
    the report keeps the old single-hop threshold formula."""
    q = TStream.source("in", prec=1).window(100).mean()
    exe = qc.compile_query(q.node, out_len=32, pallas=False)
    rep = check_single_hop_halo(exe.input_specs, exe.out_prec, n=4)
    assert rep["in"].min_single_hop_out_len == 100   # halo 100 ticks, prec 1
    assert rep["in"].left_hops == 4                  # ceil(100 / 32)
    assert rep["in"].right_hops == 0
    assert rep["in"].max_hops == 4
    # n=1 (no sharding): no exchange, zero hops
    rep1 = check_single_hop_halo(exe.input_specs, exe.out_prec, n=1)
    assert rep1["in"].max_hops == 0


def test_shard_union_run_single_device_matches_session():
    """Time-sharded union execution on a trivial 1-device mesh must match
    the chunked session bit-for-bit (integer-valued data)."""
    from repro.multiquery import shard_union_run
    from repro.launch.mesh import make_local_mesh

    N = 128
    vals, valid = _int_stream(N, seed=13)
    full = {"in": SnapshotGrid(value=jnp.asarray(vals),
                               valid=jnp.asarray(valid), t0=0, prec=1)}
    s = TStream.source("in", prec=1)
    queries = {"a": s.window(12).mean(), "b": s.window(40).sum()}
    out = shard_union_run(queries, N, full, make_local_mesh(n_data=1),
                          pallas=False)
    sess = MultiQuerySession(N, pallas=False)
    for name, q in queries.items():
        sess.attach(name, q)
    ref = sess.run(full, 1)
    for name in queries:
        assert np.array_equal(np.asarray(ref[name].valid),
                              np.asarray(out[name].valid))
        m = np.asarray(ref[name].valid)
        assert np.array_equal(np.asarray(ref[name].value)[m],
                              np.asarray(out[name].value)[m])


def test_session_step_shape_check_is_real_exception():
    """User-input validation must survive ``python -O`` (ValueError, not
    assert)."""
    vals, valid = _int_stream(SPAN, seed=14)
    sess = MultiQuerySession(SPAN, pallas=False)
    sess.attach("q", TStream.source("in", prec=1).window(8).mean())
    bad = {"in": SnapshotGrid(value=jnp.asarray(vals[:SPAN - 1]),
                              valid=jnp.asarray(valid[:SPAN - 1]),
                              t0=0, prec=1)}
    with pytest.raises(ValueError, match="chunk validity shape"):
        sess.step(bad)
