"""Quickstart: the paper's stock-trend query (Fig. 2a) on TiLT-X.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import boundary, compile as qc, fusion
from repro.core.frontend import TStream
from repro.core.parallel import partition_run
from repro.core.stream import SnapshotGrid, grid_to_events

# 1. Define the query with the event-centric surface API; it builds
#    time-centric TiLT IR underneath (paper Fig. 3a).
stock = TStream.source("stock", prec=1)
avg10 = stock.window(10).mean()
avg20 = stock.window(20).mean()
diff = avg10.join(avg20, lambda a, b: a - b)
uptrend = diff.where(lambda d: d > 0)

# 2. Boundary resolution (paper §5.1): the lookback contract that makes the
#    unbounded stream partitionable.
print("boundary contract:", boundary.resolve(uptrend.node))

# 3. IR optimization (paper §5.2): CSE + fusion across pipeline-breakers.
print("fusion:", fusion.fusion_report(uptrend.node,
                                      fusion.optimize(uptrend.node)))

# 4. Compile for 1000-tick partitions and run over a synthetic price stream.
exe = qc.compile_query(uptrend.node, out_len=1000)
prices = 100 + np.cumsum(np.random.default_rng(0).normal(0, 0.5, 4000))
grid = SnapshotGrid(value=jnp.asarray(prices, jnp.float32),
                    valid=jnp.ones(4000, bool), t0=0, prec=1)
out = partition_run(exe, {"stock": grid}, 0, 4)

events = grid_to_events(out)
print(f"{np.asarray(out.valid).sum()} uptrend ticks -> "
      f"{len(events.events)} merged uptrend intervals")
for e in events.events[:5]:
    print(f"  uptrend ({e.start:4d}, {e.end:4d}]  strength {e.payload:.3f}")
