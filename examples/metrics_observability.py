"""Runtime telemetry walkthrough: watch the sparse engine observe itself.

Drives a keyed fraud-style query through the chunked Runner with a
mostly-idle key population, then reads everything the engine recorded
about its own execution — without ever syncing on the hot path:

* compaction counters and the capacity-bucket pick distribution (which
  rung of the capacity ladder each chunk's dirty count landed on);
* the per-chunk latency histogram with p50/p90/p99;
* the recompile detector (every staging key must compile exactly once);
* phase spans (wall-time tree of the session-style rebuild phases);
* the JSONL + Prometheus exporters fed by the same snapshot.

Run:  PYTHONPATH=src python examples/metrics_observability.py [n_chunks]
"""
import sys

import jax
import numpy as np

from repro import obs
from repro.core import compile as qc
from repro.core.frontend import TStream
from repro.engine import ExecPolicy, Runner, keyed_grid

K = 64          # keyed sub-streams, ~1 in 16 active
SEG = 128
SPC = 4
SPAN = SEG * SPC


def make_chunks(n_chunks: int):
    rng = np.random.default_rng(0)
    T = n_chunks * SPAN
    vals = np.broadcast_to(rng.integers(0, 100, (K, 1)).astype(np.float32),
                           (K, T)).copy()
    for k in range(0, K, 16):                      # the active keys
        vals[k] = np.floor(rng.random(T) * 100)
    return [{"in": keyed_grid(vals[:, c * SPAN:(c + 1) * SPAN],
                              np.ones((K, SPAN), bool), t0=c * SPAN)}
            for c in range(n_chunks)]


def main(n_chunks: int = 12) -> None:
    s = TStream.source("in", prec=1, keyed=True)
    q = (s.window(32).mean().shift(1)
         .join(s, lambda m, x: x - m)
         .where(lambda d: d > 0))
    exe = qc.compile_query(q.node, out_len=SEG, pallas=False, sparse=True)
    r = Runner(exe, ExecPolicy(body="sparse", keys="vmapped"), n_keys=K,
               segs_per_chunk=SPC)

    for chunk in make_chunks(n_chunks):
        jax.block_until_ready(r.step(chunk).valid)

    # the single device→host read; everything above accumulated lazily
    snap = r.metrics.snapshot()
    assert obs.validate_snapshot(snap) == []

    c, g, h = snap["counters"], snap["gauges"], snap["histograms"]
    print(f"chunks={c['runner.chunks']['value']}  "
          f"work units={c['runner.units']['value']}  "
          f"dirty={c['runner.dirty_units']['value']}  "
          f"compact={g['runner.compact']['value']:.3f}  "
          f"donated steps={c['runner.donated_steps']['value']}")

    picks = snap["vectors"]["runner.bucket_picks"]
    print("capacity-bucket picks:",
          {lab: n for lab, n in zip(picks["labels"], picks["values"]) if n})

    lat = h["runner.step_seconds"]
    print(f"chunk latency: p50={lat['p50'] * 1e6:.0f}us  "
          f"p90={lat['p90'] * 1e6:.0f}us  p99={lat['p99'] * 1e6:.0f}us  "
          f"(n={lat['count']}; the tail is the compiling first chunks — "
          "benchmarks run a fresh runner on warm caches to scope the "
          "histogram to steady state)")

    comp = snap["compiles"]
    print(f"staged compiles: {comp['counts']}")
    print(f"retraces (must be empty): {comp['retraces']}")

    # exporters consume snapshots, never live metrics
    obs.export_jsonl(snap, "metrics.jsonl")
    prom = obs.export_prometheus(snap)
    print(f"\nwrote metrics.jsonl; prometheus exposition "
          f"({len(prom.splitlines())} lines), sample:")
    for line in prom.splitlines():
        if line.startswith(("runner_compact", "runner_chunks_total",
                            "runner_step_seconds_count")):
            print(" ", line)


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 12)
