"""Out-of-order ingestion demo: a fraud stream with 5% shuffled-late
events through the disorder-tolerant IngestRunner front end.

A credit-card anomaly query (trailing-window mean+3σ threshold, the
paper's fraud app shrunk to demo scale) consumes one transaction event
per tick — except 5% of them arrive up to two chunks late, well past the
watermark's lateness allowance.  The pipeline:

* rasterizes arrivals through a bounded reorder buffer (in-allowance
  disorder is invisible),
* seals + executes chunks as the per-key watermark passes them,
* patches sealed rasters with late events and re-runs ONLY the
  ChangePlan-dilated output segments (sparse revisions), emitting
  versioned corrections.

The demo ends by overlaying the corrections onto the sealed outputs and
asserting bit-identity with an in-order run — the disorder-insensitivity
invariant tests/test_ingest.py pins.

Run:  PYTHONPATH=src python examples/late_data.py
"""
import numpy as np

from repro.core import compile as qc
from repro.core.frontend import TStream
from repro.core.stream import Event, EventStream, events_to_grid
from repro.engine import ExecPolicy, Runner
from repro.ingest import IngestRunner

SEG = 64          # output ticks per segment
SPC = 4           # segments per chunk
CHUNK = SEG * SPC
N_CHUNKS = 8
N = CHUNK * N_CHUNKS
LATE_FRAC = 0.05
LATENESS = 32     # watermark allowance (time units)


def fraud_query(win: int = 64):
    s = TStream.source("in", prec=1)
    mu = s.window(win).mean().shift(1)
    sd = s.window(win).stddev().shift(1)
    thr = mu.join(sd, lambda m, d: m + 3.0 * d)
    return s.join(thr, lambda x, t: x - t).where(lambda e: e > 0)


def make_events(rng) -> list:
    amt = rng.lognormal(3.0, 1.0, N)
    amt[rng.random(N) < 0.002] *= 50.0  # injected fraud
    return [Event(t, t + 1, float(a)) for t, a in enumerate(amt)]


def shuffled(events, rng) -> list:
    """5% of events displaced by up to two chunks; the rest in order."""
    n = len(events)
    late = rng.random(n) < LATE_FRAC
    disp = np.where(late, rng.integers(LATENESS + 1, 2 * CHUNK, size=n), 0)
    order = np.argsort(np.arange(n) + disp, kind="stable")
    return [events[i] for i in order]


def main():
    rng = np.random.default_rng(7)
    events = make_events(rng)
    exe = qc.compile_query(fraud_query().node, out_len=SEG, pallas=False,
                           sparse=True)

    # in-order reference
    ref = Runner(exe, ExecPolicy(body="sparse"), segs_per_chunk=SPC).run(
        {"in": events_to_grid(EventStream(events), 0, N, 1)}, N_CHUNKS)

    # disorder-tolerant pipeline over the shuffled arrival order
    runner = Runner(exe, ExecPolicy(body="sparse"), segs_per_chunk=SPC)
    ing = IngestRunner(runner, lateness=LATENESS, policy="revise",
                       horizon_chunks=3)
    sealed, corrections = [], []
    for ev in shuffled(events, rng):
        ing.push("in", ev)
        s, c = ing.poll()
        sealed += s
        corrections += c
    s, c = ing.flush()
    sealed += s
    corrections += c

    snap = runner.metrics.snapshot()["counters"]
    print(f"events={len(events)}  sealed_chunks={len(sealed)}  "
          f"late={snap['ingest.late_events']['value']}  "
          f"revised={snap['ingest.revised_events']['value']}  "
          f"corrections={len(corrections)}")
    print(f"revision work: {snap['runner.revision_units']['value']} dirty "
          f"segments recomputed across "
          f"{snap['runner.revision_chunks']['value']} chunk revisions "
          f"(a dense replay would be "
          f"{snap['runner.revision_chunks']['value'] * SPC})")

    # overlay corrections (version order) and check bit-identity
    final = {sc.chunk: (np.asarray(sc.outputs.value),
                        np.asarray(sc.outputs.valid)) for sc in sealed}
    for co in sorted(corrections, key=lambda c: (c.chunk, c.version)):
        v, m = final[co.chunk]
        tick = np.repeat(np.asarray(co.seg_mask), SEG)
        final[co.chunk] = (np.where(tick, np.asarray(co.outputs.value), v),
                           np.where(tick, np.asarray(co.outputs.valid), m))
    refv, refm = np.asarray(ref.value), np.asarray(ref.valid)
    flagged = 0
    for c in range(N_CHUNKS):
        v, m = final[c]
        sl = slice(c * CHUNK, (c + 1) * CHUNK)
        assert np.array_equal(m, refm[sl])
        assert np.array_equal(v[m], refv[sl][m])
        flagged += int(m.sum())
    print(f"disorder-insensitivity OK: sealed+corrections bit-identical "
          f"to in-order ({flagged} fraud flags)")


if __name__ == "__main__":
    main()
