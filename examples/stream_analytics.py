"""End-to-end streaming-analytics driver: all eight real-world applications
(paper Table 2) running continuously over unbounded synthetic streams with
the checkpointable StreamRunner.

Run:  PYTHONPATH=src python examples/stream_analytics.py [n_chunks]
"""
import sys
import time

import jax
import numpy as np
import jax.numpy as jnp

from repro.core import compile as qc
from repro.core.parallel import StreamRunner
from repro.core.stream import SnapshotGrid
from repro.data import apps as A

CHUNK = 100_000


def run_app(name: str, n_chunks: int):
    app = A.make_app(name)
    try:
        exe = qc.compile_query(app.query.node, out_len=CHUNK // app.query.prec)
        runner = StreamRunner(exe)
    except NotImplementedError:
        # lookahead queries (znorm/impute/resample) run partitioned instead
        from repro.core.parallel import partition_run
        data = app.make_input(CHUNK * n_chunks, 1)
        grids = {k: SnapshotGrid(value=jnp.asarray(d["value"], jnp.float32),
                                 valid=jnp.asarray(d["valid"]), t0=0, prec=1)
                 for k, d in data.items()}
        exe = qc.compile_query(app.query.node, out_len=CHUNK // app.query.prec)
        t0 = time.perf_counter()
        out = partition_run(exe, grids, 0, n_chunks)
        jax.block_until_ready(out.valid)
        dt = time.perf_counter() - t0
        n = CHUNK * n_chunks
        print(f"{name:12s} {n/dt/1e6:7.2f}M ev/s  "
              f"{int(np.asarray(out.valid).sum()):8d} output events "
              f"(partitioned; lookahead query)")
        return

    t0 = time.perf_counter()
    total_out = 0
    for k in range(n_chunks):
        data = app.make_input(CHUNK, seed=k)
        chunks = {nm: SnapshotGrid(
            value=jnp.asarray(d["value"], jnp.float32)
            if not isinstance(d["value"], dict) else
            {kk: jnp.asarray(a, jnp.float32) for kk, a in d["value"].items()},
            valid=jnp.asarray(d["valid"]), t0=0, prec=1)
            for nm, d in data.items()}
        out = runner.step(chunks)
        total_out += int(np.asarray(out.valid).sum())
    dt = time.perf_counter() - t0
    n = CHUNK * n_chunks
    print(f"{name:12s} {n/dt/1e6:7.2f}M ev/s  {total_out:8d} output events "
          f"(continuous, state={len(runner.state())-1} tails)")


if __name__ == "__main__":
    n_chunks = int(sys.argv[1]) if len(sys.argv) > 1 else 5
    for name in A.APPS:
        run_app(name, n_chunks)
