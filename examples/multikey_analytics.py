"""Multi-key streaming analytics: per-user fraud detection over many
concurrent keyed sub-streams (paper §6.2's partitioned-stream parallelism,
composed with TiLT's time partitioning).

The KeyedEngine advances all users at once — one vmapped XLA computation
per time partition, carrying only each user's halo tail between chunks —
which is exactly how a long-running service would consume an unbounded
keyed stream.

Run:  PYTHONPATH=src python examples/multikey_analytics.py [n_users]
"""
import sys
import time

import jax
import numpy as np

from repro.core import compile as qc
from repro.core.frontend import TStream
from repro.engine import KeyedEngine, keyed_grid

N_TICKS = 50_000
N_PARTS = 10  # stream consumed in 5k-tick chunks with carried halo state


def main(n_users: int = 64):
    # per-user trailing-stats fraud rule (Table 2's banking app)
    s = TStream.source("amt", prec=1, keyed=True)
    mu = s.window(1000).mean().shift(1)
    sd = s.window(1000).stddev().shift(1)
    thr = mu.join(sd, lambda m, d: m + 3.0 * d)
    q = s.join(thr, lambda x, t: x - t).where(lambda e: e > 0)

    exe = qc.compile_query(q.node, out_len=N_TICKS // N_PARTS)

    rng = np.random.default_rng(0)
    amounts = rng.lognormal(3.0, 1.0, (n_users, N_TICKS)).astype(np.float32)
    fraud_mask = rng.random((n_users, N_TICKS)) < 0.001
    amounts[fraud_mask] *= 40.0

    grid = {"amt": keyed_grid(amounts, np.ones((n_users, N_TICKS), bool))}

    engine = KeyedEngine(exe, n_keys=n_users)
    out = engine.run(grid, N_PARTS)        # warmup (compile)
    jax.block_until_ready(out.valid)

    engine = KeyedEngine(exe, n_keys=n_users)
    t0 = time.perf_counter()
    out = engine.run(grid, N_PARTS)
    jax.block_until_ready(out.valid)
    dt = time.perf_counter() - t0

    hits = np.asarray(out.valid)
    injected = int(fraud_mask.sum())
    caught = int((hits & fraud_mask).sum())
    print(f"[multikey] {n_users} users x {N_TICKS} ticks "
          f"({N_PARTS} chunks) = {n_users*N_TICKS/dt/1e6:.1f}M ev/s")
    print(f"[multikey] flagged {int(hits.sum())} events; "
          f"caught {caught}/{injected} injected frauds "
          f"({100*caught/max(injected,1):.0f}% recall)")


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 64)
