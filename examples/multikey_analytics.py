"""Multi-key, multi-query streaming analytics.

Part 1 — per-user fraud detection over many concurrent keyed sub-streams
(paper §6.2's partitioned-stream parallelism): the unified policy runner
(``Runner`` + ``ExecPolicy(keys="vmapped")``, the successor of the
deprecated KeyedEngine) advances all users at once, one vmapped XLA
computation per time partition, carrying only each user's halo tail
between chunks.  Swapping ``body="sparse"`` or ``placement=mesh(...)``
into the policy composes change-compressed execution and key-axis
sharding onto the same runner — no separate entry points.

Part 2 — the serving scenario on top: a *dashboard fan-out* where several
queries (trend up/down, band breakout, momentum — differing only in their
final heads) watch the same keyed price source.  One MultiQuerySession
(the ``dag="union"`` corner of the policy space) serves all of them from a
single pass per chunk: the shared window aggregates are planned and
evaluated once, per-query heads fan out from them (repro/multiquery).

Run:  PYTHONPATH=src python examples/multikey_analytics.py [n_users]
"""
import sys
import time

import jax
import numpy as np

from repro.core import compile as qc
from repro.core.frontend import TStream
from repro.data import apps as A
from repro.engine import ExecPolicy, Runner, keyed_grid
from repro.multiquery import MultiQuerySession

N_TICKS = 50_000
N_PARTS = 10  # stream consumed in 5k-tick chunks with carried halo state


def fraud_demo(n_users: int = 64):
    # per-user trailing-stats fraud rule (Table 2's banking app)
    s = TStream.source("amt", prec=1, keyed=True)
    mu = s.window(1000).mean().shift(1)
    sd = s.window(1000).stddev().shift(1)
    thr = mu.join(sd, lambda m, d: m + 3.0 * d)
    q = s.join(thr, lambda x, t: x - t).where(lambda e: e > 0)

    exe = qc.compile_query(q.node, out_len=N_TICKS // N_PARTS)
    policy = ExecPolicy(keys="vmapped")    # dense × vmapped × local × solo

    rng = np.random.default_rng(0)
    amounts = rng.lognormal(3.0, 1.0, (n_users, N_TICKS)).astype(np.float32)
    fraud_mask = rng.random((n_users, N_TICKS)) < 0.001
    amounts[fraud_mask] *= 40.0

    grid = {"amt": keyed_grid(amounts, np.ones((n_users, N_TICKS), bool))}

    engine = Runner(exe, policy, n_keys=n_users)
    out = engine.run(grid, N_PARTS)        # warmup (compile)
    jax.block_until_ready(out.valid)

    engine = Runner(exe, policy, n_keys=n_users)
    t0 = time.perf_counter()
    out = engine.run(grid, N_PARTS)
    jax.block_until_ready(out.valid)
    dt = time.perf_counter() - t0

    hits = np.asarray(out.valid)
    injected = int(fraud_mask.sum())
    caught = int((hits & fraud_mask).sum())
    print(f"[multikey] {n_users} users x {N_TICKS} ticks "
          f"({N_PARTS} chunks) = {n_users*N_TICKS/dt/1e6:.1f}M ev/s")
    print(f"[multikey] flagged {int(hits.sum())} events; "
          f"caught {caught}/{injected} injected frauds "
          f"({100*caught/max(injected,1):.0f}% recall)")


def dashboard_demo(n_users: int = 64, n_queries: int = 8):
    """N dashboard queries × K keyed sub-streams, one session, one pass."""
    queries = A.dashboard_queries(n_queries, keyed=True)
    data = A.dashboard_keyed_input(n_users, N_TICKS, seed=3)["in"]
    grid = {"in": keyed_grid(np.asarray(data["value"], np.float32),
                             data["valid"])}

    span = N_TICKS // N_PARTS
    session = MultiQuerySession(span, n_keys=n_users)
    for name, q in queries.items():
        session.attach(name, q)
    rep = session.sharing_report()

    outs = session.run(grid, N_PARTS)      # warmup (compile)
    jax.block_until_ready(next(iter(outs.values())).valid)

    session.reset()
    t0 = time.perf_counter()
    outs = session.run(grid, N_PARTS)
    jax.block_until_ready(next(iter(outs.values())).valid)
    dt = time.perf_counter() - t0

    agg_ev = n_queries * n_users * N_TICKS
    print(f"[dashboard] {n_queries} queries x {n_users} symbols x "
          f"{N_TICKS} ticks ({N_PARTS} chunks) = "
          f"{agg_ev/dt/1e6:.1f}M query-events/s aggregate")
    print(f"[dashboard] union DAG: {rep.union_nodes} nodes "
          f"({rep.shared_nodes} shared) vs {rep.independent_nodes} "
          f"if run independently — sharing ratio {rep.sharing_ratio:.2f}x")
    for name, out in outs.items():
        m = np.asarray(out.valid)
        v = np.asarray(out.value)
        fired = int(m.sum())
        mean = float(v[m].mean()) if fired else float("nan")
        print(f"[dashboard]   {name}: {fired} valid ticks, "
              f"mean output {mean:.3f}")


def main(n_users: int = 64):
    fraud_demo(n_users)
    dashboard_demo(n_users)


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 64)
