"""Static hot-path audit walkthrough: prove a runner clean, then break it.

Builds a sparse keyed runner, audits it with every `repro.analysis` pass
(transfer-freedom, donation-consumption, collective-placement,
recompile-hazard, temporal-plan verification) and prints the clean
verdict.  Then deliberately under-dilates the query's ChangePlan — the
"silently stale outputs" bug class — and shows the temporal-plan
verifier catch it from the independently re-derived IR demand, with the
offending segments named.

The same machinery runs over the full 16-point ExecPolicy lattice as
`make lint-plans` / `python -m repro.analysis` (the CI gate); findings
land in `out/analysis.jsonl` as schema-versioned JSONL
(`repro.analysis/v1`).

Run:  PYTHONPATH=src python examples/plan_audit.py
"""
import dataclasses

from repro.analysis import AuditTarget, audit_runner, export_jsonl, verdict
from repro.analysis.planverify import derive_bounds, pass_plan
from repro.core import compile as qc
from repro.core.frontend import TStream
from repro.engine import ExecPolicy, Runner
from repro.engine.runner import body_spec_of

SEG = 32
SPC = 4
K = 8


def make_query():
    s = TStream.source("in", prec=1, keyed=True)
    return (s.window(16).mean()
            .join(s.window(32).mean(), lambda a, b: a - b)
            .where(lambda d: d > 0))


def main():
    exe = qc.compile_query(make_query().node, out_len=SEG, pallas=False,
                           sparse=True)
    r = Runner(exe, ExecPolicy(body="sparse", keys="vmapped"), n_keys=K,
               segs_per_chunk=SPC)

    # 1. the full audit: five passes over the runner's lowerable surface
    findings = audit_runner(r)
    print(f"shipped runner: verdict={verdict(findings)} "
          f"({len(findings)} findings)")
    for f in findings:
        print(f"  [{f.severity}] {f.pass_name}/{f.code}: {f.message}")

    # 2. the verifier's independent demand derivation (vs the planner's)
    req = derive_bounds(exe.root if isinstance(exe.root, tuple)
                        else (exe.root,))
    s = exe.input_specs["in"]
    print(f"derived demand for 'in': (lookback, lookahead) = {req['in']} "
          f"time units; planned halo contract serves {s.contract_t()}")

    # 3. break the plan: halve the dilation, watch the verifier object
    spec = body_spec_of(exe)
    cp = spec.change_plan
    halved = dataclasses.replace(cp, specs={
        name: dataclasses.replace(sp, lookback=sp.lookback // 2)
        for name, sp in cp.specs.items()})
    bad_spec = dataclasses.replace(spec, change_plan=halved, step_cache={})
    bad = Runner(bad_spec, ExecPolicy(body="sparse", keys="vmapped"),
                 n_keys=K, segs_per_chunk=SPC)
    bad_findings = pass_plan(AuditTarget(
        runner=bad, policy="example:under-dilated", steps=[],
        chunk_variants=()))
    print(f"\nunder-dilated plan: verdict={verdict(bad_findings)}")
    for f in bad_findings:
        print(f"  [{f.severity}] {f.pass_name}/{f.code}: {f.message}")

    path = export_jsonl(bad_findings, "out/example_audit.jsonl")
    print(f"\nfindings exported → {path}")


if __name__ == "__main__":
    main()
