"""Train a language model end to end (data pipeline → sharded train loop →
checkpoints), with TiLT stream preprocessing attached as the feature plane.

Default is a CPU-feasible ~10M-parameter qwen3-family model for 100 steps;
``--full-100m`` selects a ~100M config (the assignment's reference scale —
budget several hours on this 1-core container, minutes on real hardware).

Run:  PYTHONPATH=src python examples/train_lm.py [--full-100m] [--steps N]
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.configs.base import ModelConfig
from repro.launch import train as T


def config(full: bool) -> ModelConfig:
    if full:  # ~100M params
        return ModelConfig(
            name="demo-100m", family="dense", n_layers=12, d_model=768,
            n_heads=12, n_kv_heads=4, d_ff=2048, vocab=32_000,
            pattern=("global",), qk_norm=True, mlp_act="silu",
            tie_embeddings=True)
    return ModelConfig(  # ~10M params
        name="demo-10m", family="dense", n_layers=6, d_model=256,
        n_heads=8, n_kv_heads=4, d_ff=688, vocab=8_192,
        pattern=("global",), qk_norm=True, mlp_act="silu",
        tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-dir", default="/tmp/tiltx_lm_ckpt")
    args = ap.parse_args()

    cfg = config(args.full_100m)
    print(f"[example] {cfg.name}: {cfg.n_params()/1e6:.1f}M params")

    # register the demo config so the production driver can find it
    from repro.configs import base as cb
    cb.register(cfg.name, cfg, cfg)

    loss = T.main([
        "--arch", cfg.name, "--steps", str(args.steps),
        "--batch", "8", "--seq", "256", "--lr", "3e-3",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
    ])
    print(f"[example] final loss {loss:.4f}")


if __name__ == "__main__":
    main()
