"""Low-latency serving demo: the fraud app behind `repro.serve`.

A credit-card anomaly query (trailing-window mean+3σ threshold, the
paper's fraud app at demo scale) served two ways:

* **chunk path** — `build_service` wires the persisted plan + executable
  caches and returns a warmed `ServeLoop`; the generator double-buffers
  (chunk k+1's committed `device_put` overlaps chunk k's compute) and the
  steady-state tail runs under `jax.transfer_guard("disallow")` — every
  H2D is the loop's own explicit put.
* **event path** — per-transaction events go through a fixed-capacity
  FIFO admission ring (backpressure by shed policy, `serve.*` telemetry)
  into the disorder-tolerant `IngestRunner`; chunks seal as the
  watermark passes and admission→result latency is observed per seal.

Run it twice to see the persisted warm start: the first run plans,
traces, AOT-compiles and persists under ``out/serving_demo/``; the
second rebuilds the runner from the plan artifact and loads every step
executable from disk — first-result drops ~10×, and the tracer records
zero compiles.

Run:  PYTHONPATH=src python examples/serving_loop.py
"""
import time

import jax
import numpy as np

from repro.core.stream import Event, SnapshotGrid
from repro.core.frontend import TStream
from repro.serve import build_service

SEG = 64          # output ticks per segment
SPC = 4           # segments per chunk
CHUNK = SEG * SPC
N_CHUNKS = 8
CACHE = "out/serving_demo"


def fraud_query(win: int = 64):
    s = TStream.source("in", prec=1)
    mu = s.window(win).mean().shift(1)
    sd = s.window(win).stddev().shift(1)
    thr = mu.join(sd, lambda m, d: m + 3.0 * d)
    return s.join(thr, lambda x, t: x - t).where(lambda e: e > 0)


def amounts(rng, n):
    amt = rng.lognormal(3.0, 1.0, n).astype(np.float32)
    amt[rng.random(n) < 0.002] *= 50.0  # injected fraud
    return amt


def main():
    t0 = time.perf_counter()
    svc = build_service(fraud_query(), out_len=SEG, segs_per_chunk=SPC,
                        cache_dir=CACHE)
    print(f"build_service: plan={svc.plan_source} "
          f"aot={svc.aot_report} ({time.perf_counter() - t0:.2f}s)")

    # -- chunk path: double-buffered generator ------------------------------
    rng = np.random.default_rng(0)

    def requests():
        for i in range(N_CHUNKS):
            # host numpy on purpose: the loop's explicit device_put is
            # the only H2D on the steady-state path
            yield {"in": SnapshotGrid(value=amounts(rng, CHUNK),
                                      valid=np.ones(CHUNK, bool),
                                      t0=i * CHUNK, prec=1)}

    gen = svc.serve(requests())
    flagged = int(np.asarray(next(gen).valid).sum())
    first = time.perf_counter() - t0
    flagged += int(np.asarray(next(gen).valid).sum())
    with jax.transfer_guard("disallow"):  # steady state: explicit puts only
        outs = list(gen)
    flagged += int(sum(np.asarray(o.valid).sum() for o in outs))
    snap = svc.runner.metrics.snapshot()
    lat = snap["histograms"]["serve.call_seconds"]
    print(f"chunk path: {N_CHUNKS} chunks, {flagged} flagged ticks, "
          f"first result {first:.2f}s, p50 {lat['p50'] * 1e3:.2f}ms "
          f"p99 {lat['p99'] * 1e3:.2f}ms, "
          f"compiles={svc.runner.metrics.tracer.compiles() or '{}'}")

    # -- event path: admission ring -> watermark-sealed chunks --------------
    svc2 = build_service(fraud_query(), out_len=SEG, segs_per_chunk=SPC,
                         cache_dir=CACHE)
    svc2.attach_events(lateness=32, policy="drop", capacity=4096,
                       shed="newest")
    n_sealed = 0
    for t, a in enumerate(amounts(rng, 2 * CHUNK)):
        svc2.offer("in", Event(t, t + 1, float(a)))
        if (t + 1) % 256 == 0:
            sealed, _ = svc2.pump()
            n_sealed += len(sealed)
    sealed, _ = svc2.finish()
    n_sealed += len(sealed)
    snap = svc2.runner.metrics.snapshot()
    a2r = snap["histograms"]["serve.admit_to_result_seconds"]
    print(f"event path: {snap['counters']['serve.admitted']['value']:.0f} "
          f"events admitted, {n_sealed} chunks sealed, "
          f"admit→result p50 {a2r['p50'] * 1e3:.1f}ms "
          f"(shed={snap['counters']['serve.shed_events']['value']:.0f})")


if __name__ == "__main__":
    main()
