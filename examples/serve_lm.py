"""Serve a small model with batched requests (prefill + continuous-batching
decode loop) — the serving path the decode_* dry-run shapes compile.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch qwen3-1.7b]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch import serve as S


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    args = ap.parse_args()
    S.main(["--arch", args.arch, "--smoke", "--batch", "4",
            "--prompt-len", "32", "--gen", "16", "--requests", "8"])


if __name__ == "__main__":
    main()
