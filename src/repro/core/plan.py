"""Static query planning (paper §5.1 + §6): IR + partition size → QueryPlan.

TiLT's central systems claim is that a time-centric IR makes the query plan
a *static artifact*: grid extents, alignment index maps and halo contracts
are all resolved before execution, so the runtime is synchronization-free
and trivially parallel over both time partitions and keyed sub-streams.
This module is that artifact.  It owns, in exactly one place:

* :class:`GridPlan`  — the time grid ``(t0, length, prec)`` of every node,
  relative to the partition start (boundary.py supplies the extents).
* :class:`AlignSpec` — the static ``τ → index`` map used whenever a node
  reads an argument on a different grid (the snapshot *hold* rule,
  stream.py), including the affine-slice fast path that lowers common
  alignments (same precision, integer down-sampling) to strided slices
  instead of gathers.
* :class:`InputSpec` — the per-input halo contract: ``left_halo`` /
  ``right_halo`` / ``core`` ticks per partition (paper Fig. 6 shaded
  regions), plus the derived multi-hop exchange schedule
  (:meth:`InputSpec.halo_schedule` → halo.py) used when the timeline is
  sharded across devices.  Every executor in parallel.py and engine/
  consumes these fields instead of re-deriving the arithmetic.
* :class:`QueryPlan` — the whole bundle, built once per (query, out_len)
  by :func:`plan_query` and shared by the fused executable, the
  interpreted operator-at-a-time program, and all partitioned runners.

Grid/alignment conventions (shared with stream.py):

* A grid ``(t0, length, prec)`` holds tick ``i`` at time ``t0 + (i+1)·prec``
  and covers the half-open interval ``(t0, t0 + length·prec]``.
* The value of a temporal object at an arbitrary time ``τ`` is the value of
  the latest tick at or before ``τ``: index ``(τ - t0)//prec - 1``
  (< 0 ⇒ before the grid ⇒ φ).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import boundary, halo, ir

__all__ = ["GridPlan", "AlignSpec", "InputSpec", "QueryPlan", "UnionPlan",
           "ChangeSpec", "ChangePlan", "plan_query", "plan_union",
           "plan_change", "seg_range_affine"]


def _ceil_div(a, b):
    return -(-a // b)


@dataclasses.dataclass(frozen=True)
class GridPlan:
    """Grid extent of one node, relative to the partition start."""

    t0: int       # exclusive left edge (≤ 0: lookback halo)
    length: int   # ticks
    prec: int

    def tick_time(self, i):
        """Time of tick ``i`` (works on ints and integer arrays)."""
        return self.t0 + (i + 1) * self.prec

    def floor_index(self, tau):
        """Latest tick at or before ``τ`` (hold rule); may be out of range."""
        return (tau - self.t0) // self.prec - 1

    def ceil_index(self, tau):
        """Earliest tick at or after ``τ``; may be out of range."""
        return _ceil_div(tau - self.t0, self.prec) - 1


@dataclasses.dataclass(frozen=True)
class AlignSpec:
    """Static alignment of an argument grid onto an output grid.

    Reading argument ``a`` at output tick times ``τ_j − delta`` resolves, at
    plan time, to the numpy index map ``idx`` (hold rule).  ``in_range``
    marks output ticks whose read falls inside the argument grid — out-of-
    range reads are φ.  All arrays are trace-time constants.
    """

    arg: GridPlan
    out: GridPlan
    delta: int = 0

    def __post_init__(self):
        j = np.arange(self.out.length, dtype=np.int64)
        tau = self.out.tick_time(j) - self.delta
        idx = self.arg.floor_index(tau)
        object.__setattr__(self, "_tau", tau)
        object.__setattr__(self, "_idx", idx)

    @property
    def tau(self) -> np.ndarray:
        """Read times ``τ_j − delta`` (one per output tick)."""
        return self._tau

    @property
    def idx(self) -> np.ndarray:
        """Hold-rule argument index per output tick (may be out of range)."""
        return self._idx

    @property
    def ceil_idx(self) -> np.ndarray:
        """Earliest argument tick ≥ read time (linear-interp upper bound)."""
        return self.arg.ceil_index(self._tau)

    @property
    def in_range(self) -> np.ndarray:
        return (self._idx >= 0) & (self._idx < self.arg.length)

    @property
    def exact(self) -> bool:
        """True when every output tick reads inside the argument grid."""
        return bool(np.all(self.in_range))

    # -- application ---------------------------------------------------------
    def take(self, value):
        """Gather leaves of a value pytree along axis 0 with the static index
        map, lowering to a strided slice when the map is affine."""
        idx_np = self._idx
        n = idx_np.shape[0]
        if n > 1:
            d = np.diff(idx_np)
            affine = bool(np.all(d == d[0])) and d[0] > 0
            step = int(d[0])
        else:
            affine, step = True, 1
        start = int(idx_np[0]) if n else 0

        def one(leaf):
            if affine and start >= 0:
                lim = start + (n - 1) * step + 1
                if lim <= leaf.shape[0]:
                    return jax.lax.slice_in_dim(leaf, start, lim, stride=step)
            return jnp.take(
                leaf, jnp.asarray(np.clip(idx_np, 0, leaf.shape[0] - 1)),
                axis=0)

        return jax.tree_util.tree_map(one, value)

    def mask(self, ok):
        """AND a gathered validity mask with the in-range mask (φ outside)."""
        if self.exact:
            return ok
        return ok & jnp.asarray(self.in_range)

    def apply(self, value, valid):
        """Align a ``(value, valid)`` grid pair onto the output grid."""
        return self.take(value), self.mask(self.take(valid))


@dataclasses.dataclass(frozen=True)
class InputSpec:
    """Per-input partition contract (paper Fig. 6).

    For a partition whose output covers ``(P₀, P₀ + core·prec_out]`` the
    caller must supply this input on the grid ``(P₀ + t0, P₀ + t0 +
    length·prec]``.  The grid splits into ``left_halo`` lookback ticks,
    ``core`` fresh ticks, and ``right_halo`` lookahead ticks — computed once
    here and consumed by every executor (parallel.py, engine/).
    """

    t0: int       # grid start relative to partition start (≤ 0: lookback)
    length: int   # total ticks (left_halo + core + right_halo)
    prec: int
    core: int     # fresh ticks per partition (output span / prec)

    @property
    def left_halo(self) -> int:
        """Lookback ticks before the partition start."""
        return -self.t0 // self.prec

    @property
    def right_halo(self) -> int:
        """Lookahead ticks past the partition end."""
        return self.length - self.left_halo - self.core

    def grid_plan(self) -> GridPlan:
        return GridPlan(t0=self.t0, length=self.length, prec=self.prec)

    def contract_t(self) -> tuple:
        """The ``(lookback, lookahead)`` *time-unit* demand this contract
        serves: the halo tick counts un-rounded back to time.  The
        temporal-plan verifier (:mod:`repro.analysis`) re-derives a
        query's demand independently from the IR and compares it against
        this — an independently smaller demand means the halo is merely
        conservative (rounding), a larger one means the contract is
        undersized and the partitioned executors read garbage."""
        return self.left_halo * self.prec, self.right_halo * self.prec

    def halo_schedule(self) -> "halo.HaloSchedule":
        """The static multi-hop exchange schedule serving this contract
        when the timeline is sharded (one shard per ``core`` ticks): hop
        ``k`` pulls the slab ``k`` neighbours over, ``ceil(halo/core)``
        hops per side (see :mod:`repro.core.halo`).  Like the halo sizes
        themselves, this is a planning artifact — resolved once here,
        consumed by every sharded executor."""
        return halo.schedule(self.left_halo, self.right_halo, self.core)


@dataclasses.dataclass
class QueryPlan:
    """Everything static about one (query, partition size) pair."""

    root: ir.Node
    out_len: int
    out_prec: int
    node_plans: Dict[int, GridPlan]          # id(node) -> GridPlan
    input_specs: Dict[str, InputSpec]        # per input NAME (union of uses)
    _aligns: Dict[tuple, AlignSpec] = dataclasses.field(default_factory=dict)

    def plan_of(self, n: ir.Node) -> GridPlan:
        return self.node_plans[id(n)]

    def align(self, arg: ir.Node, out: ir.Node, delta: int = 0) -> AlignSpec:
        """AlignSpec for consumer ``out`` reading argument ``arg``."""
        key = (id(arg), id(out), delta)
        if key not in self._aligns:
            self._aligns[key] = AlignSpec(
                self.node_plans[id(arg)], self.node_plans[id(out)], delta)
        return self._aligns[key]

    def input_align(self, n: ir.Input) -> AlignSpec:
        """AlignSpec from the supplied NAME grid onto an Input node's grid."""
        key = ("input", n.name, id(n))
        if key not in self._aligns:
            self._aligns[key] = AlignSpec(
                self.input_specs[n.name].grid_plan(), self.node_plans[id(n)])
        return self._aligns[key]


@dataclasses.dataclass(frozen=True)
class ChangeSpec:
    """Per-input dirty-span dilation contract (change-compressed execution).

    Boundary resolution says output time ``τ`` reads this input inside
    ``[τ − lookback, τ + lookahead]``; the *reverse image* of that lineage
    interval is the dirty span: a changed input tick at time ``t`` can only
    alter outputs in ``[t − lookahead, t + lookback]``.  Both bounds are in
    time units and use the halo-rounded extents of :class:`InputSpec`, so
    the dilation is conservative exactly where the halo is.
    """

    lookback: int    # input change at t dirties outputs in [t, t+lookback]
    lookahead: int   # ... and in [t-lookahead, t]
    prec: int


@dataclasses.dataclass(frozen=True)
class ChangePlan:
    """Static change-propagation artifact for one (query, out_len) pair.

    The sparse executor (:mod:`repro.core.sparse`) needs exactly one fact
    per source to turn per-tick dirty masks into dirty *output segments*:
    how far a change spreads through the query DAG.  That is the halo
    contract read backwards — window/interp/shift ops widen dirty spans by
    the same lookback/lookahead extents they demand as halo — so the plan
    is derived entirely from :class:`InputSpec` (no second DAG walk).
    """

    out_len: int                      # segment length in output ticks
    out_prec: int
    specs: Dict[str, ChangeSpec]      # per input NAME

    def check_covers(self, required: Dict[str, tuple]) -> list:
        """Verifier hook: does every per-input dilation cover a required
        ``{name: (lookback_t, lookahead_t)}`` demand (time units)?
        Returns one ``(name, field, have, need)`` tuple per shortfall —
        empty means every change an input sees really reaches every
        output it can affect.  Used by the temporal-plan verifier
        (:mod:`repro.analysis`) with *independently re-derived* demands,
        so a bug in the :func:`plan_change` derivation (or a hand-built
        under-dilated plan) can't vouch for itself."""
        bad = []
        for name, (lb, la) in required.items():
            sp = self.specs.get(name)
            if sp is None:
                bad.append((name, "missing", None, (lb, la)))
                continue
            if sp.lookback < lb:
                bad.append((name, "lookback", sp.lookback, lb))
            if sp.lookahead < la:
                bad.append((name, "lookahead", sp.lookahead, la))
        return bad

    # -- retro-invalidation (late-data revision processing) ------------------
    def retro_span(self, name: str, t_lo: int, t_hi: int) -> tuple:
        """The *open* output-time interval ``(lo, hi)`` that changed input
        ticks of ``name`` at times in ``[t_lo, t_hi]`` can dirty — the
        reverse lineage image :func:`repro.core.sparse.seg_ranges` resolves
        per segment, as one interval.  A late event that patches sealed
        input ticks in ``[t_lo, t_hi]`` can only change outputs strictly
        inside this span; everything else is provably unchanged (the
        sparse exactness contract), which is what makes revision
        processing a sparse re-run rather than a chunk replay."""
        sp = self.specs[name]
        return (t_lo - sp.lookahead - sp.prec,
                t_hi + sp.lookback + self.out_prec)

    def revision_horizon_chunks(self, lateness_bound: int,
                                chunk_span: int) -> int:
        """Snapshot-ring depth (in chunks) that guarantees revisability of
        any event no more than ``lateness_bound`` time units behind the
        sealed frontier.

        A patched tick at time ``t ≥ F − lateness_bound`` (``F`` the
        sealed frontier) dirties outputs ``τ > t − lookahead − prec``
        (:meth:`retro_span`), so the earliest chunk a revision must
        restart from is the one containing
        ``F − lateness_bound − lookahead − prec + 1`` — the ring must
        reach ``ceil((bound + lookahead + prec) / chunk_span)`` chunks
        back.  The ingest layer sizes its ring (and the sealed-raster
        buffer) with this; the ``revision`` analysis pass re-checks a
        configured runner against it."""
        slack = max((sp.lookahead + sp.prec for sp in self.specs.values()),
                    default=1)
        return max(1, -(-(lateness_bound + slack) // chunk_span))


def plan_change(qp: "QueryPlan") -> ChangePlan:
    """Derive the change-propagation plan from a query's halo contracts.

    Works unchanged on a :class:`UnionPlan`: its ``input_specs`` are the
    *merged* per-source contracts (union of every attached query's
    bounds), so the derived dilations are the per-input union of the
    per-query dilations — exactly the merged ChangePlan sparse multi-query
    execution needs (every output of every query in a segment is clean iff
    no input changed inside the union-dilated lineage; the per-query
    stride widening cancels identically for every output precision, see
    :func:`repro.core.sparse.seg_ranges`).
    """
    specs = {name: ChangeSpec(lookback=s.left_halo * s.prec,
                              lookahead=s.right_halo * s.prec, prec=s.prec)
             for name, s in qp.input_specs.items()}
    return ChangePlan(out_len=qp.out_len, out_prec=qp.out_prec, specs=specs)


def seg_range_affine(lookback_t: int, lookahead_t: int, prec: int,
                     grid_t0: int, out_t0: int, out_prec: int,
                     seg_len: int) -> tuple:
    """Affine lowering of the dilated-lineage ranges: ``(a0, step, width)``
    such that segment ``k``'s dirty input-tick range is the half-open
    ``[a0 + k·step, a0 + k·step + width)``.

    This is :func:`repro.core.sparse.seg_ranges` specialized to the case
    every chunked executor already enforces (segment span a multiple of the
    input precision), in the closed form the fused change-detection kernel
    needs: a *fixed-width* window sliding by a *fixed stride* per segment,
    so a 1-D Pallas grid can map segment ``k`` straight to its input block.
    Raises ``ValueError`` when the span is not stride-aligned (callers fall
    back to the general per-segment ranges).
    """
    span = seg_len * out_prec
    if span % prec:
        raise ValueError(
            f"segment span {span} not a multiple of input precision {prec}"
            " — no affine lowering; use seg_ranges")
    step = span // prec
    lo_t = out_t0 + 1 - lookback_t
    hi_t = out_t0 + span + lookahead_t + prec - 1
    a0 = _ceil_div(lo_t - grid_t0, prec) - 1
    width = (hi_t - grid_t0) // prec - a0
    return a0, step, width


@dataclasses.dataclass
class UnionPlan(QueryPlan):
    """A :class:`QueryPlan` over the *union* DAG of several query roots.

    One shared static artifact serves N concurrent queries: every node of
    every query gets a grid sized for the union of all consumers' demands
    (:func:`boundary.node_bounds_multi`), and ``input_specs`` is the merged
    per-source halo contract.  ``root``/``out_len``/``out_prec`` describe
    the first root only; per-query output extents come from each root's own
    :class:`GridPlan` (see :mod:`repro.multiquery`).
    """

    roots: tuple = ()
    span: int = 0  # shared output span (0, span] in time units per chunk


def plan_query(root: ir.Node, out_len: int) -> QueryPlan:
    """Resolve every grid extent, alignment map and halo for one partition
    size.  Pure planning — no jax tracing happens here."""
    out_prec = root.prec
    span = out_len * out_prec  # output window (0, span]
    node_plans, input_specs = _plan_grids([root], span)
    return QueryPlan(root=root, out_len=out_len, out_prec=out_prec,
                     node_plans=node_plans, input_specs=input_specs)


def plan_union(roots, span: int) -> UnionPlan:
    """Plan the union DAG of several queries over one shared output span.

    All queries advance in lockstep: each chunk produces the output window
    ``(0, span]`` of every root (``span // root.prec`` ticks each), so
    ``span`` must be a multiple of every root's precision.  Shared nodes get
    a single grid covering every consumer; per-source contracts merge across
    queries.  Sources reached under the same name must agree on their grid
    declaration (prec / keyed).
    """
    roots = tuple(roots)
    if not roots:
        raise ValueError("plan_union needs at least one query root")
    for r in roots:
        if span % r.prec:
            raise ValueError(
                f"span {span} not a multiple of root {r.name} prec {r.prec}")
    decl: Dict[str, ir.Input] = {}
    for n in ir.topo_order_multi(list(roots)):
        if isinstance(n, ir.Input):
            prev = decl.get(n.name)
            if prev is not None and (prev.prec, prev.keyed) != (n.prec, n.keyed):
                raise ValueError(
                    f"source {n.name!r} declared with conflicting grids: "
                    f"prec={prev.prec}/keyed={prev.keyed} vs "
                    f"prec={n.prec}/keyed={n.keyed}")
            decl[n.name] = n
    node_plans, input_specs = _plan_grids(roots, span)
    return UnionPlan(root=roots[0], out_len=span // roots[0].prec,
                     out_prec=roots[0].prec, node_plans=node_plans,
                     input_specs=input_specs, roots=roots, span=span)


def _plan_grids(roots, span: int):
    """Grid extents + merged per-NAME input contracts for a (multi-)root DAG."""
    nb = boundary.node_bounds_multi(list(roots))
    node_plans: Dict[int, GridPlan] = {}
    name_bounds: Dict[str, boundary.Bounds] = {}
    name_prec: Dict[str, int] = {}
    for n in ir.topo_order_multi(list(roots)):
        b = nb[id(n)]
        t0 = -_ceil_div(b.lookback, n.prec) * n.prec
        t_hi = span + _ceil_div(b.lookahead, n.prec) * n.prec
        node_plans[id(n)] = GridPlan(t0=t0, length=(t_hi - t0) // n.prec,
                                     prec=n.prec)
        if isinstance(n, ir.Input):
            name_prec[n.name] = n.prec
            name_bounds[n.name] = (name_bounds[n.name].union(b)
                                   if n.name in name_bounds else b)
    input_specs: Dict[str, InputSpec] = {}
    for name, b in name_bounds.items():
        p = name_prec[name]
        t0 = -_ceil_div(b.lookback, p) * p
        t_hi = span + _ceil_div(b.lookahead, p) * p
        input_specs[name] = InputSpec(t0=t0, length=(t_hi - t0) // p, prec=p,
                                      core=span // p)
    return node_plans, input_specs
