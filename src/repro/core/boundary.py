"""Boundary resolution (paper §5.1).

The time-centric IR makes the *temporal lineage* of every node explicit:
the value of a node at time ``T`` depends on input values inside a statically
known interval ``[T - lookback, T + lookahead]``.  Boundary resolution walks
the DAG **top-down from the query output** and accumulates, per node, the
total (lookback, lookahead) in time units relative to the output domain.
Reading the bounds at the :class:`ir.Input` leaves yields the contract that
lets the runtime partition an unbounded stream into independent chunks with
halo overlap (paper Fig. 6) — the key to synchronization-free data
parallelism over *arbitrary* queries.  The contract places no ceiling on
depth: when the timeline is sharded across devices, halos deeper than the
per-shard span (including the merged multi-query contracts of
:func:`node_bounds_multi`) are served by the multi-hop exchange schedule
planned in plan.py/halo.py.  Reading them at interior nodes gives
compile.py the exact grid extent each intermediate temporal object needs.

Per-edge rules (consumer needs bounds ``B``; what does the argument need?):

* ``Map/Where``        ->  ``B`` widened by ``arg.prec`` when grids differ
                           (hold-alignment reads the latest tick ≤ τ).
* ``Shift(d)``         ->  ``B`` shifted by ``d`` (negative d → lookahead).
* ``Reduce(window=W)`` ->  ``B`` widened back by ``W``.
* ``Interp(max_gap=g)``->  ``B`` widened back by ``g`` (+ ahead ``g`` when
                           mode='linear').

The result is conservative (a superset of the exact lineage), which only
costs a few duplicated halo ticks, never correctness.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from . import ir

__all__ = ["Bounds", "node_bounds", "node_bounds_multi", "resolve",
           "halo_ticks"]


@dataclasses.dataclass(frozen=True)
class Bounds:
    """Temporal extent needed of a node, relative to the output domain."""

    lookback: int = 0
    lookahead: int = 0

    def shift(self, delta: int) -> "Bounds":
        # consumer reads in[t - delta]: positive delta reaches further back.
        return Bounds(max(self.lookback + delta, 0),
                      max(self.lookahead - delta, 0))

    def widen(self, back: int = 0, ahead: int = 0) -> "Bounds":
        return Bounds(self.lookback + back, self.lookahead + ahead)

    def union(self, other: "Bounds") -> "Bounds":
        return Bounds(max(self.lookback, other.lookback),
                      max(self.lookahead, other.lookahead))


def _edge(n: ir.Node, a: ir.Node, b: Bounds) -> Bounds:
    """Bounds needed of argument ``a`` when consumer ``n`` needs ``b``."""
    if isinstance(n, (ir.Map, ir.Where)):
        return b.widen(back=a.prec if a.prec != n.prec else 0)
    if isinstance(n, ir.Shift):
        return b.shift(n.delta)
    if isinstance(n, ir.Reduce):
        return b.widen(back=n.window)
    if isinstance(n, ir.Interp):
        ahead = n.max_gap if n.mode == "linear" else 0
        extra = a.prec if a.prec != n.prec else 0
        return b.widen(back=n.max_gap + extra, ahead=n.max_gap if ahead else 0)
    raise TypeError(f"unknown node {type(n)}")  # pragma: no cover


def node_bounds(root: ir.Node) -> Dict[int, Bounds]:
    """Bounds for every node in the DAG, keyed by ``id(node)``."""
    return node_bounds_multi([root])


def node_bounds_multi(roots) -> Dict[int, Bounds]:
    """Bounds over the *union* DAG of several query roots.

    Each root anchors ``Bounds()`` at the shared output domain; a node used
    by several queries (or that is one query's output and another's interior
    expression) accumulates the union of every consumer's demand — the halo
    contract of the multi-query shared plan.

    Reverse post-order guarantees every consumer is finalized before its
    arguments are visited, so a single pass suffices.
    """
    order = ir.topo_order_multi(list(roots))
    bounds: Dict[int, Bounds] = {id(r): Bounds() for r in roots}
    for n in reversed(order):
        b = bounds[id(n)]
        for a in n.args:
            eb = _edge(n, a, b)
            prev = bounds.get(id(a))
            bounds[id(a)] = eb if prev is None else prev.union(eb)
    return bounds


def resolve(root: ir.Node) -> Dict[str, Bounds]:
    """Map each source Input name to its (lookback, lookahead) contract."""
    nb = node_bounds(root)
    out: Dict[str, Bounds] = {}
    for n in ir.free_inputs(root):
        b = nb[id(n)]
        out[n.name] = out[n.name].union(b) if n.name in out else b
    return out


def halo_ticks(root: ir.Node) -> Dict[str, tuple[int, int]]:
    """Per-input halo sizes in *input ticks* (left, right), rounded up.

    This is what the partitioned executor materializes as duplicated
    snapshots at partition boundaries (paper Fig. 6 shaded regions).
    """
    inputs = {n.name: n for n in ir.free_inputs(root)}
    out = {}
    for name, b in resolve(root).items():
        p = inputs[name].prec
        out[name] = (-(-b.lookback // p), -(-b.lookahead // p))
    return out
