"""TiLT codegen: planned IR → staged JAX computation (paper §6).

The paper lowers TiLT IR to LLVM loops whose counters skip redundant work
(change-driven iteration).  On TPU we instead *vectorize over the time grid*
(DESIGN.md §2): every node evaluates to a ``(value, valid)`` pair of arrays
on its own statically-planned grid, and the whole query stages into a single
XLA computation (fused mode) or into one computation per operator
(interpreted mode — the event-centric operator-at-a-time baseline).

Layering: planning lives in plan.py (grid extents, alignment index maps,
halo contracts — all trace-time constants); this module is pure codegen over
a :class:`plan.QueryPlan`.  Both execution modes share the single node
evaluator :func:`_eval_op` — the fused trace calls it recursively over the
DAG, the interpreted program jits one ``functools.partial`` of it per node.

Execution contract (used by parallel.py and engine/):

* ``input_specs[name]`` is the :class:`plan.InputSpec` halo contract: the
  caller must supply a grid covering ``(P₀ + t0, P₀ + t0 + length·prec]``
  for a partition whose output covers ``(P₀, P₀ + out_len·out_prec]``.
* Ticks before the global stream start are supplied as ``valid=False`` —
  φ-semantics make partial leading windows exact.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import fusion, ir
from .plan import ChangePlan, InputSpec, QueryPlan, plan_change, plan_query
from .reduction import get_reduction
from ..kernels import ops as kops

__all__ = ["InputSpec", "CompiledQuery", "compile_query", "eval_op"]


# ---------------------------------------------------------------------------
# the node evaluator (shared by fused and interpreted modes)
# ---------------------------------------------------------------------------

def _eval_op(n: ir.Node, qp: QueryPlan, pallas: Optional[bool],
             sum_algo: str, *args):
    """Evaluate one node given its arguments' ``(value, valid)`` grids.

    This is the *only* node-evaluation implementation: the fused trace and
    the interpreted operator-at-a-time program both execute queries through
    it.  ``args`` are the argument grids in ``n.args`` order (for ``Input``,
    the single caller-supplied NAME grid).
    """
    out_plan = qp.plan_of(n)
    if isinstance(n, ir.Input):
        ((gv, gm),) = args
        return qp.input_align(n).apply(gv, gm)
    if isinstance(n, ir.Const):
        val = jax.tree_util.tree_map(
            lambda c: jnp.full((out_plan.length,), c), n.value)
        return val, jnp.ones((out_plan.length,), bool)
    if isinstance(n, ir.Map):
        vs, oks = [], []
        for a, (av, aok) in zip(n.args, args):
            av, aok = qp.align(a, n).apply(av, aok)
            vs.append(av)
            oks.append(aok)
        if n.phi_aware:
            return n.fn(*zip(vs, oks))
        return n.fn(*vs), functools.reduce(jnp.logical_and, oks)
    if isinstance(n, ir.Where):
        ((av, aok),) = args
        av, aok = qp.align(n.args[0], n).apply(av, aok)
        return av, aok & n.pred(av)
    if isinstance(n, ir.Shift):
        ((av, aok),) = args
        return qp.align(n.args[0], n, delta=n.delta).apply(av, aok)
    if isinstance(n, ir.Reduce):
        ((av, aok),) = args
        return _eval_reduce(n, av, aok, qp, pallas, sum_algo)
    if isinstance(n, ir.Interp):
        ((av, aok),) = args
        return _eval_interp(n, av, aok, qp)
    raise TypeError(type(n))  # pragma: no cover


# public alias: the multi-query shared-plan executor (repro.multiquery)
# evaluates the union DAG through the same single node evaluator, passing a
# plan.UnionPlan in place of the per-query QueryPlan.
eval_op = _eval_op


def _eval_reduce(n: ir.Reduce, aval, avalid, qp: QueryPlan,
                 pallas: Optional[bool], sum_algo: str = "block"):
    red = get_reduction(n.op)
    (arg,) = n.args
    aplan = qp.plan_of(arg)
    spec = qp.align(arg, n)  # window-end gather at output tick times
    payload = aval[n.field] if n.field is not None else aval
    w_ticks = n.window // aplan.prec

    if red.kind == "scan":
        chans = red.pre(payload)
        stacked = jnp.stack([c.astype(jnp.float32) for c in chans], axis=0)
        sums, count = kops.sliding_sum(
            stacked, avalid, w_ticks, algo=sum_algo,
            pallas=kops.use_pallas() if pallas is None else pallas)
        # gather at output ticks, then apply post (cheaper after striding)
        sums_g = spec.take(sums.T).T  # (C, out_len)
        count_g = spec.take(count)
        val = red.post(tuple(sums_g), count_g)
        ok = count_g > 0 if not red.empty_valid else jnp.ones_like(count_g, bool)
        return val, spec.mask(ok)

    if red.kind == "assoc":
        x = red.pre(payload)[0] if red.pre else payload
        vals, anyv = kops.sliding_assoc(
            x[None, :], avalid, w_ticks, red.name,
            pallas=kops.use_pallas() if pallas is None else pallas)
        val = spec.take(vals[0])
        ok = spec.mask(spec.take(anyv))
        return val, ok

    # generic template (paper §6.1.2): associative two-level fold via
    # lax.reduce_window over the Acc combine on (state-initialised) ticks.
    init, acc, result = red.init, red.acc, red.result
    state0 = init()
    states = jax.vmap(lambda v, ok: jax.lax.cond(
        ok, lambda: acc(state0, v), lambda: state0))(payload, avalid)
    comb = red.combine or (lambda a, b: acc(a, b))
    folded = jax.lax.reduce_window(
        states, state0, comb, window_dimensions=(w_ticks,),
        window_strides=(1,), padding=((w_ticks - 1, 0),))
    _, count = kops.sliding_sum(jnp.zeros((1, avalid.shape[0]), jnp.float32),
                                avalid, w_ticks, pallas=False)
    val = jax.vmap(result)(spec.take(folded))
    ok = spec.mask(spec.take(count) > 0)
    return val, ok


def _eval_interp(n: ir.Interp, aval, avalid, qp: QueryPlan):
    (arg,) = n.args
    aplan = qp.plan_of(arg)
    spec = qp.align(arg, n)
    Ta = aplan.length
    ar = jnp.arange(Ta)
    last_idx = jax.lax.cummax(jnp.where(avalid, ar, -1))
    next_idx = Ta - 1 - jax.lax.cummax(
        jnp.where(avalid[::-1], ar, -1))[::-1]
    nxt_valid = jax.lax.cummax(jnp.where(avalid[::-1], ar, -1))[::-1] >= 0

    tau = spec.tau                                    # output tick times
    ib = np.clip(spec.idx, 0, Ta - 1)                 # latest tick ≤ τ
    ia = np.clip(spec.ceil_idx, 0, Ta - 1)            # earliest tick ≥ τ
    ib_ok = spec.idx >= 0

    i0 = jnp.take(last_idx, jnp.asarray(ib))
    e0 = (i0 >= 0) & jnp.asarray(ib_ok)
    t0v = aplan.tick_time(i0)
    v0 = jax.tree_util.tree_map(
        lambda leaf: jnp.take(leaf, jnp.clip(i0, 0, Ta - 1)), aval)
    gap0 = jnp.asarray(tau) - t0v
    if n.mode == "hold":
        ok = e0 & (gap0 <= n.max_gap)
        return v0, ok

    i1 = jnp.take(next_idx, jnp.asarray(ia))
    e1 = jnp.take(nxt_valid, jnp.asarray(ia))
    t1v = aplan.tick_time(i1)
    v1 = jax.tree_util.tree_map(
        lambda leaf: jnp.take(leaf, jnp.clip(i1, 0, Ta - 1)), aval)
    gap1 = t1v - jnp.asarray(tau)
    denom = (t1v - t0v).astype(jnp.float32)
    w = jnp.where(denom > 0, gap0.astype(jnp.float32) / jnp.maximum(denom, 1), 0.0)
    out = jax.tree_util.tree_map(lambda a, b: a * (1 - w) + b * w, v0, v1)
    ok = e0 & e1 & (gap0 <= n.max_gap) & (gap1 <= n.max_gap)
    return out, ok


# ---------------------------------------------------------------------------
# compiler
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CompiledQuery:
    """A TiLT query compiled for a fixed partition size.

    ``fn(inputs)`` is the fused jitted executable; ``trace_fn`` the unjitted
    traceable body (used inside shard_map and under vmap in the keyed
    engine); ``run_interpreted`` evaluates operator-at-a-time with per-node
    jits and host round-trips (the event-centric execution model, for the
    Fig. 10 ablation).  ``plan`` is the static artifact everything shares.
    """

    root: ir.Node
    plan: QueryPlan
    trace_fn: Callable[[Dict[str, tuple]], tuple]
    fn: Callable[[Dict[str, tuple]], tuple]
    _node_fns: list  # [(name, jitted fn, arg node ids, node)]
    # change-propagation plan (compile_query(..., sparse=True)): enables the
    # change-compressed executors in sparse.py / parallel.py / engine
    change_plan: Optional[ChangePlan] = None

    @property
    def out_len(self) -> int:
        return self.plan.out_len

    @property
    def out_prec(self) -> int:
        return self.plan.out_prec

    @property
    def input_specs(self) -> Dict[str, InputSpec]:
        return self.plan.input_specs

    def run_interpreted(self, inputs: Dict[str, tuple]) -> tuple:
        env: Dict[int, tuple] = {}
        out = None
        for name, fn_i, arg_ids, node in self._node_fns:
            if isinstance(node, ir.Input):
                env[id(node)] = fn_i(inputs[node.name])
            else:
                args = [env[i] for i in arg_ids]
                env[id(node)] = fn_i(*args)
            jax.block_until_ready(env[id(node)])  # operator-at-a-time barrier
            out = env[id(node)]
        return out


def compile_query(root: ir.Node, out_len: int, *, opt: bool = True,
                  pallas: Optional[bool] = None, sum_algo: str = "block",
                  jit: bool = True, sparse: bool = False) -> CompiledQuery:
    """Compile a TiLT query for partitions of ``out_len`` output ticks.

    With ``sparse=True`` the executable additionally carries a
    :class:`plan.ChangePlan` (per-source dirty-span dilation contracts,
    derived from the halo contracts) enabling the change-compressed
    executors — :func:`repro.core.sparse.sparse_run`,
    :class:`repro.core.parallel.SparseStreamRunner` and
    ``KeyedEngine(..., sparse=True)`` — which skip partitions/keys whose
    inputs didn't change.  ``out_len`` is then the *segment* length the
    sparse executors compact over (pick it a few× the deepest window).
    """
    if opt:
        root = fusion.optimize(root)
    ir.validate(root)
    qp = plan_query(root, out_len)

    def eval_node(n: ir.Node, env_vals, memo):
        if id(n) in memo:
            return memo[id(n)]
        if isinstance(n, ir.Input):
            args = ((env_vals[n.name]),)
        else:
            args = tuple(eval_node(a, env_vals, memo) for a in n.args)
        out = _eval_op(n, qp, pallas, sum_algo, *args)
        memo[id(n)] = out
        return out

    def trace_fn(inputs: Dict[str, tuple]) -> tuple:
        return eval_node(root, inputs, {})

    fn = jax.jit(trace_fn) if jit else trace_fn

    # -- interpreted (operator-at-a-time) program: one jit per node, same
    #    evaluator ---------------------------------------------------------
    node_fns = []
    for n in ir.topo_order(root):
        node_fns.append((
            n.name,
            jax.jit(functools.partial(_eval_op, n, qp, pallas, sum_algo)),
            tuple(id(a) for a in n.args), n))

    return CompiledQuery(root=root, plan=qp, trace_fn=trace_fn, fn=fn,
                         _node_fns=node_fns,
                         change_plan=plan_change(qp) if sparse else None)
