"""TiLT codegen: IR → staged JAX computation (paper §6).

The paper lowers TiLT IR to LLVM loops whose counters skip redundant work
(change-driven iteration).  On TPU we instead *vectorize over the time grid*
(DESIGN.md §2): every node evaluates to a ``(value, valid)`` pair of arrays
on its own statically-planned grid, and the whole query stages into a single
XLA computation (fused mode) or into one computation per operator
(interpreted mode — the event-centric operator-at-a-time baseline).

Static planning:  given the output partition length ``out_len`` (in output
ticks), boundary resolution (boundary.py) fixes, for every node, the grid
extent ``(t0_rel, length)`` *relative to the partition start*.  All alignment
index maps are therefore trace-time numpy constants, and the common cases
(same precision, integer down-sampling) lower to strided slices, not gathers.

Execution contract (used by parallel.py):

* ``input_specs[name] = InputSpec(t0, length, prec)``: the caller must supply
  a grid covering ``(P₀ + t0, P₀ + t0 + length·prec]`` for a partition whose
  output covers ``(P₀, P₀ + out_len·out_prec]``.  ``-t0`` is the lookback
  halo (paper Fig. 6 shaded region).
* Ticks before the global stream start are supplied as ``valid=False`` —
  φ-semantics make partial leading windows exact.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import boundary, fusion, ir
from .reduction import get_reduction
from ..kernels import ops as kops

__all__ = ["InputSpec", "CompiledQuery", "compile_query"]


@dataclasses.dataclass(frozen=True)
class InputSpec:
    t0: int       # grid start relative to partition start (≤ 0: lookback halo)
    length: int   # ticks
    prec: int

    @property
    def left_halo(self) -> int:
        """Lookback ticks before the partition start."""
        return -self.t0 // self.prec

    @property
    def right_halo_ticks(self) -> int:
        return 0  # populated by planner when lookahead > 0


@dataclasses.dataclass(frozen=True)
class _NodePlan:
    t0: int
    length: int
    prec: int


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


# ---------------------------------------------------------------------------
# alignment
# ---------------------------------------------------------------------------

def _take(value, idx_np: np.ndarray):
    """Gather leaves of a value pytree along axis 0 with static indices,
    lowering to a strided slice when the index map is affine."""
    n = idx_np.shape[0]
    if n > 1:
        d = np.diff(idx_np)
        affine = bool(np.all(d == d[0])) and d[0] > 0
    else:
        affine = True
        d = np.array([1])
    start, step = int(idx_np[0]), int(d[0]) if n > 1 else 1

    def one(leaf):
        if affine and start >= 0:
            lim = start + (n - 1) * step + 1
            if lim <= leaf.shape[0]:
                return jax.lax.slice_in_dim(leaf, start, lim, stride=step)
        return jnp.take(leaf, jnp.asarray(np.clip(idx_np, 0, leaf.shape[0] - 1)),
                        axis=0)

    return jax.tree_util.tree_map(one, value)


def _align(value, valid, arg_plan: _NodePlan, out_plan: _NodePlan,
           delta: int = 0):
    """Read argument grid at output tick times τ_j − delta (hold rule)."""
    q, p = out_plan.prec, arg_plan.prec
    j = np.arange(out_plan.length, dtype=np.int64)
    tau = out_plan.t0 + (j + 1) * q - delta
    idx = (tau - arg_plan.t0) // p - 1
    in_range = (idx >= 0) & (idx < arg_plan.length)
    v = _take(value, idx)
    ok = _take(valid, idx)
    if not bool(np.all(in_range)):
        ok = ok & jnp.asarray(in_range)
    return v, ok


# ---------------------------------------------------------------------------
# per-node evaluation
# ---------------------------------------------------------------------------

def _eval_reduce(n: ir.Reduce, aval, avalid, aplan: _NodePlan,
                 oplan: _NodePlan, pallas: Optional[bool],
                 sum_algo: str = "block"):
    red = get_reduction(n.op)
    payload = aval[n.field] if n.field is not None else aval
    w_ticks = n.window // aplan.prec

    if red.kind == "scan":
        chans = red.pre(payload)
        stacked = jnp.stack([c.astype(jnp.float32) for c in chans], axis=0)
        sums, count = kops.sliding_sum(
            stacked, avalid, w_ticks, algo=sum_algo,
            pallas=kops.use_pallas() if pallas is None else pallas)
        # gather at output ticks, then apply post (cheaper after striding)
        j = np.arange(oplan.length, dtype=np.int64)
        tau = oplan.t0 + (j + 1) * oplan.prec
        idx = (tau - aplan.t0) // aplan.prec - 1
        sums_g = _take(sums.T, idx).T  # (C, out_len)
        count_g = _take(count, idx)
        val = red.post(tuple(sums_g), count_g)
        ok = count_g > 0 if not red.empty_valid else jnp.ones_like(count_g, bool)
        in_range = (idx >= 0) & (idx < aplan.length)
        if not bool(np.all(in_range)):
            ok = ok & jnp.asarray(in_range)
        return val, ok

    if red.kind == "assoc":
        x = red.pre(payload)[0] if red.pre else payload
        vals, anyv = kops.sliding_assoc(
            x[None, :], avalid, w_ticks, red.name,
            pallas=kops.use_pallas() if pallas is None else pallas)
        j = np.arange(oplan.length, dtype=np.int64)
        tau = oplan.t0 + (j + 1) * oplan.prec
        idx = (tau - aplan.t0) // aplan.prec - 1
        val = _take(vals[0], idx)
        ok = _take(anyv, idx)
        in_range = (idx >= 0) & (idx < aplan.length)
        if not bool(np.all(in_range)):
            ok = ok & jnp.asarray(in_range)
        return val, ok

    # generic template (paper §6.1.2): associative two-level fold via
    # lax.reduce_window over the Acc combine on (state-initialised) ticks.
    init, acc, result = red.init, red.acc, red.result
    state0 = init()
    states = jax.vmap(lambda v, ok: jax.lax.cond(
        ok, lambda: acc(state0, v), lambda: state0))(payload, avalid)
    comb = red.combine or (lambda a, b: acc(a, b))
    folded = jax.lax.reduce_window(
        states, state0, comb, window_dimensions=(w_ticks,),
        window_strides=(1,), padding=((w_ticks - 1, 0),))
    _, count = kops.sliding_sum(jnp.zeros((1, avalid.shape[0]), jnp.float32),
                                avalid, w_ticks, pallas=False)
    j = np.arange(oplan.length, dtype=np.int64)
    tau = oplan.t0 + (j + 1) * oplan.prec
    idx = (tau - aplan.t0) // aplan.prec - 1
    val = jax.vmap(result)(_take(folded, idx))
    ok = _take(count, idx) > 0
    return val, ok


def _eval_interp(n: ir.Interp, aval, avalid, aplan: _NodePlan,
                 oplan: _NodePlan):
    Ta = aplan.length
    p, q = aplan.prec, oplan.prec
    ar = jnp.arange(Ta)
    last_idx = jax.lax.cummax(jnp.where(avalid, ar, -1))
    next_idx = Ta - 1 - jax.lax.cummax(
        jnp.where(avalid[::-1], ar, -1))[::-1]
    nxt_valid = jax.lax.cummax(jnp.where(avalid[::-1], ar, -1))[::-1] >= 0

    j = np.arange(oplan.length, dtype=np.int64)
    tau = oplan.t0 + (j + 1) * q                       # output tick times
    ib = np.clip((tau - aplan.t0) // p - 1, 0, Ta - 1)  # latest tick ≤ τ
    ia = np.clip(_ceil_div_np(tau - aplan.t0, p) - 1, 0, Ta - 1)  # earliest ≥ τ
    ib_ok = ((tau - aplan.t0) // p - 1) >= 0

    i0 = jnp.take(last_idx, jnp.asarray(ib))
    e0 = (i0 >= 0) & jnp.asarray(ib_ok)
    t0v = aplan.t0 + (i0 + 1) * p
    v0 = jax.tree_util.tree_map(
        lambda leaf: jnp.take(leaf, jnp.clip(i0, 0, Ta - 1)), aval)
    gap0 = jnp.asarray(tau) - t0v
    if n.mode == "hold":
        ok = e0 & (gap0 <= n.max_gap)
        return v0, ok

    i1 = jnp.take(next_idx, jnp.asarray(ia))
    e1 = jnp.take(nxt_valid, jnp.asarray(ia))
    t1v = aplan.t0 + (i1 + 1) * p
    v1 = jax.tree_util.tree_map(
        lambda leaf: jnp.take(leaf, jnp.clip(i1, 0, Ta - 1)), aval)
    gap1 = t1v - jnp.asarray(tau)
    denom = (t1v - t0v).astype(jnp.float32)
    w = jnp.where(denom > 0, gap0.astype(jnp.float32) / jnp.maximum(denom, 1), 0.0)
    out = jax.tree_util.tree_map(lambda a, b: a * (1 - w) + b * w, v0, v1)
    ok = e0 & e1 & (gap0 <= n.max_gap) & (gap1 <= n.max_gap)
    return out, ok


def _ceil_div_np(a, b):
    return -(-a // b)


# ---------------------------------------------------------------------------
# compiler
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CompiledQuery:
    """A TiLT query compiled for a fixed partition size.

    ``fn(inputs)`` is the fused jitted executable; ``trace_fn`` the unjitted
    traceable body (used inside shard_map); ``run_interpreted`` evaluates
    operator-at-a-time with per-node jits and host round-trips (the
    event-centric execution model, for the Fig. 10 ablation).
    """

    root: ir.Node
    out_len: int
    out_prec: int
    input_specs: Dict[str, InputSpec]
    trace_fn: Callable[[Dict[str, tuple]], tuple]
    fn: Callable[[Dict[str, tuple]], tuple]
    _node_fns: list  # [(name, jitted fn, arg node ids)] for interpreted mode
    _plans: Dict[int, _NodePlan]

    def run_interpreted(self, inputs: Dict[str, tuple]) -> tuple:
        env: Dict[int, tuple] = {}
        out = None
        for name, fn_i, arg_ids, node in self._node_fns:
            if isinstance(node, ir.Input):
                env[id(node)] = fn_i(inputs[node.name])
            else:
                args = [env[i] for i in arg_ids]
                env[id(node)] = fn_i(*args)
            jax.block_until_ready(env[id(node)])  # operator-at-a-time barrier
            out = env[id(node)]
        return out


def compile_query(root: ir.Node, out_len: int, *, opt: bool = True,
                  pallas: Optional[bool] = None, sum_algo: str = "block",
                  jit: bool = True) -> CompiledQuery:
    """Compile a TiLT query for partitions of ``out_len`` output ticks."""
    if opt:
        root = fusion.optimize(root)
    ir.validate(root)

    nb = boundary.node_bounds(root)
    out_prec = root.prec
    span = out_len * out_prec  # output window (0, span]

    plans: Dict[int, _NodePlan] = {}
    for n in ir.topo_order(root):
        b = nb[id(n)]
        t0 = -_ceil_div(b.lookback, n.prec) * n.prec
        t_hi = span + _ceil_div(b.lookahead, n.prec) * n.prec
        plans[id(n)] = _NodePlan(t0=t0, length=(t_hi - t0) // n.prec,
                                 prec=n.prec)

    # per-NAME input grids (union over Input nodes sharing the name)
    name_bounds = boundary.resolve(root)
    name_prec = {n.name: n.prec for n in ir.free_inputs(root)}
    input_specs: Dict[str, InputSpec] = {}
    name_plans: Dict[str, _NodePlan] = {}
    for name, b in name_bounds.items():
        p = name_prec[name]
        t0 = -_ceil_div(b.lookback, p) * p
        t_hi = span + _ceil_div(b.lookahead, p) * p
        spec = InputSpec(t0=t0, length=(t_hi - t0) // p, prec=p)
        input_specs[name] = spec
        name_plans[name] = _NodePlan(t0=t0, length=spec.length, prec=p)

    def eval_node(n: ir.Node, env_vals, memo):
        if id(n) in memo:
            return memo[id(n)]
        plan = plans[id(n)]
        if isinstance(n, ir.Input):
            gv, gm = env_vals[n.name]
            out = _align(gv, gm, name_plans[n.name], plan)
        elif isinstance(n, ir.Const):
            val = jax.tree_util.tree_map(
                lambda c: jnp.full((plan.length,), c), n.value)
            out = (val, jnp.ones((plan.length,), bool))
        elif isinstance(n, ir.Map):
            vs, oks = [], []
            for a in n.args:
                av, aok = eval_node(a, env_vals, memo)
                av, aok = _align(av, aok, plans[id(a)], plan)
                vs.append(av)
                oks.append(aok)
            if n.phi_aware:
                out = n.fn(*zip(vs, oks))
            else:
                val = n.fn(*vs)
                ok = functools.reduce(jnp.logical_and, oks)
                out = (val, ok)
        elif isinstance(n, ir.Where):
            (a,) = n.args
            av, aok = eval_node(a, env_vals, memo)
            av, aok = _align(av, aok, plans[id(a)], plan)
            out = (av, aok & n.pred(av))
        elif isinstance(n, ir.Shift):
            (a,) = n.args
            av, aok = eval_node(a, env_vals, memo)
            out = _align(av, aok, plans[id(a)], plan, delta=n.delta)
        elif isinstance(n, ir.Reduce):
            (a,) = n.args
            av, aok = eval_node(a, env_vals, memo)
            out = _eval_reduce(n, av, aok, plans[id(a)], plan, pallas,
                               sum_algo)
        elif isinstance(n, ir.Interp):
            (a,) = n.args
            av, aok = eval_node(a, env_vals, memo)
            out = _eval_interp(n, av, aok, plans[id(a)], plan)
        else:  # pragma: no cover
            raise TypeError(type(n))
        memo[id(n)] = out
        return out

    def trace_fn(inputs: Dict[str, tuple]) -> tuple:
        return eval_node(root, inputs, {})

    fn = jax.jit(trace_fn) if jit else trace_fn

    # -- interpreted (operator-at-a-time) program ---------------------------
    node_fns = []
    for n in ir.topo_order(root):
        plan = plans[id(n)]
        if isinstance(n, ir.Input):
            node_fns.append((n.name, jax.jit(functools.partial(
                _input_op, name_plans[n.name], plan)), (), n))
        else:
            arg_plans = [plans[id(a)] for a in n.args]
            node_fns.append((n.name, jax.jit(functools.partial(
                _node_op, n, tuple(arg_plans), plan, pallas, sum_algo)),
                tuple(id(a) for a in n.args), n))

    return CompiledQuery(root=root, out_len=out_len, out_prec=out_prec,
                         input_specs=input_specs, trace_fn=trace_fn, fn=fn,
                         _node_fns=node_fns, _plans=plans)


def _input_op(name_plan, plan, grid):
    gv, gm = grid
    return _align(gv, gm, name_plan, plan)


def _node_op(n, arg_plans, plan, pallas, sum_algo, *args):
    if isinstance(n, ir.Map):
        vs, oks = [], []
        for (av, aok), ap in zip(args, arg_plans):
            av, aok = _align(av, aok, ap, plan)
            vs.append(av)
            oks.append(aok)
        if n.phi_aware:
            return n.fn(*zip(vs, oks))
        return n.fn(*vs), functools.reduce(jnp.logical_and, oks)
    if isinstance(n, ir.Where):
        ((av, aok),) = args
        av, aok = _align(av, aok, arg_plans[0], plan)
        return av, aok & n.pred(av)
    if isinstance(n, ir.Shift):
        ((av, aok),) = args
        return _align(av, aok, arg_plans[0], plan, delta=n.delta)
    if isinstance(n, ir.Reduce):
        ((av, aok),) = args
        return _eval_reduce(n, av, aok, arg_plans[0], plan, pallas, sum_algo)
    if isinstance(n, ir.Interp):
        ((av, aok),) = args
        return _eval_interp(n, av, aok, arg_plans[0], plan)
    raise TypeError(type(n))  # pragma: no cover
