"""Operator fusion and IR-level optimization passes (paper §5.2).

In the time-centric model, fusion is *expression inlining*: two successive
temporal expressions over the same time domain merge by substituting the
producer's defining expression into the consumer — including across soft
pipeline-breakers (window reductions, joins) that defeat fusion in
event-centric engines (paper §3, Fig. 2).

Passes implemented here:

* :func:`cse`            — common-subexpression elimination on the DAG
                           (structural hashing).  The paper's trend query
                           (two windows over one source) relies on the shared
                           ``~stock`` read being deduplicated so the fused
                           loop reads the source once.
* :func:`fuse_elemwise`  — single-pass *maximal-region* fusion: every
                           connected region of elementwise nodes (Map/Where)
                           over one time domain collapses into a single Map
                           whose closure evaluates the whole region; inlined
                           Where predicates compose into one AND-mask
                           (φ-semantics preserved exactly).  After this pass
                           the DAG alternates {Reduce/Shift/Interp} nodes and
                           single fused Maps.
* :func:`fusion_report`  — before/after node census for the Fig.10-style
                           ablation benchmark.

Because compile.py stages the *whole* DAG into one ``jax.jit`` region anyway,
the measurable effect of fusion on XLA is fewer materialized intermediates
and one traversal per source — the unfused ("interpreted") execution mode in
compile.py materializes every node output through separate jit calls,
reproducing the event-centric operator-at-a-time baseline.
"""
from __future__ import annotations

import functools

from . import ir

__all__ = ["cse", "fuse_elemwise", "optimize", "fusion_report"]


# ---------------------------------------------------------------------------
# structural CSE
# ---------------------------------------------------------------------------

def _structural_key(n: ir.Node, arg_keys: tuple) -> tuple:
    if isinstance(n, ir.Input):
        return ("input", n.name, n.prec, n.keyed)
    if isinstance(n, ir.Const):
        return ("const", repr(n.value), n.prec)
    if isinstance(n, ir.Map):
        return ("map", n.fn, n.prec, n.phi_aware, arg_keys)
    if isinstance(n, ir.Where):
        return ("where", n.pred, n.prec, arg_keys)
    if isinstance(n, ir.Shift):
        return ("shift", n.delta, n.prec, arg_keys)
    if isinstance(n, ir.Reduce):
        op_key = n.op if isinstance(n.op, str) else id(n.op)
        return ("reduce", op_key, n.window, n.prec, n.field, arg_keys)
    if isinstance(n, ir.Interp):
        return ("interp", n.mode, n.max_gap, n.prec, arg_keys)
    raise TypeError(type(n))


def cse(root: ir.Node) -> ir.Node:
    """Deduplicate structurally identical subexpressions."""
    canon: dict[tuple, ir.Node] = {}
    rewritten: dict[int, ir.Node] = {}
    keys: dict[int, tuple] = {}

    for n in ir.topo_order(root):
        new_args = tuple(rewritten[id(a)] for a in n.args)
        key = _structural_key(n, tuple(keys[id(a)] for a in n.args))
        if key in canon:
            rewritten[id(n)] = canon[key]
        else:
            m = n._replace_args(new_args) if n.args else n
            canon[key] = m
            rewritten[id(n)] = m
        keys[id(n)] = key
    return rewritten[id(root)]


# ---------------------------------------------------------------------------
# maximal-region elementwise fusion
# ---------------------------------------------------------------------------

def _is_elemwise(n: ir.Node) -> bool:
    if isinstance(n, ir.Map) and n.phi_aware:
        return False  # φ-aware closures keep their own validity logic
    return isinstance(n, (ir.Map, ir.Where))


def _use_counts(root: ir.Node) -> dict[int, int]:
    counts: dict[int, int] = {}
    for n in ir.topo_order(root):
        for a in n.args:
            counts[id(a)] = counts.get(id(a), 0) + 1
    counts[id(root)] = counts.get(id(root), 0) + 1
    return counts


def fuse_elemwise(root: ir.Node) -> ir.Node:
    """Collapse each maximal elementwise region into one fused Map.

    A node is *absorbable* into its consumer's region when it is elementwise,
    has a single use, and shares the consumer's time domain (equal precision
    — the paper's fusion precondition).  Region roots are elementwise nodes
    that are not absorbable themselves (multi-use, or consumed by a
    pipeline-breaker, or the query output).

    Inlined ``Where`` predicates compose into a single AND-mask: the fused
    region lowers to ``Map → Where(mask) → Map(unwrap)``, preserving
    φ-semantics exactly while the entire value pipeline runs in one closure.
    """
    counts = _use_counts(root)
    rewritten: dict[int, ir.Node] = {}

    def absorbable(x: ir.Node, region_prec: int) -> bool:
        return (_is_elemwise(x) and counts.get(id(x), 1) == 1
                and x.prec == region_prec)

    def rewrite(n: ir.Node) -> ir.Node:
        if id(n) in rewritten:
            return rewritten[id(n)]
        if _is_elemwise(n):
            m = build_region(n)
        else:
            new_args = tuple(rewrite(a) for a in n.args)
            same = all(a is b for a, b in zip(new_args, n.args))
            m = n if same else n._replace_args(new_args)
        rewritten[id(n)] = m
        return m

    def build_region(n: ir.Node) -> ir.Node:
        slots: list[ir.Node] = []          # fused Map arguments (rewritten)
        slot_of: dict[int, int] = {}       # id(original node) -> slot index
        region: set[int] = set()
        has_where = [isinstance(n, ir.Where)]

        def collect(x: ir.Node, is_root: bool = False):
            if not is_root and not absorbable(x, n.prec):
                if id(x) not in slot_of:
                    slot_of[id(x)] = len(slots)
                    slots.append(rewrite(x))
                return
            if id(x) in region:
                return
            region.add(id(x))
            if isinstance(x, ir.Where):
                has_where[0] = True
            for a in x.args:
                collect(a)

        collect(n, is_root=True)

        trivial = len(region) == 1 and isinstance(n, ir.Map)
        if trivial:
            new_args = tuple(rewrite(a) for a in n.args)
            same = all(a is b for a, b in zip(new_args, n.args))
            return n if same else n._replace_args(new_args)
        if len(region) == 1 and isinstance(n, ir.Where):
            (a0,) = n.args
            ra = rewrite(a0)
            return n if ra is a0 else n._replace_args((ra,))

        node_n = n

        def fused_fn(*vals):
            env: dict[int, object] = {}
            ok_terms: list = []

            def ev(x: ir.Node):
                if id(x) in env:
                    return env[id(x)]
                if id(x) in slot_of:
                    v = vals[slot_of[id(x)]]
                elif isinstance(x, ir.Map):
                    v = x.fn(*[ev(a) for a in x.args])
                elif isinstance(x, ir.Where):
                    v = ev(x.args[0])
                    ok_terms.append(x.pred(v))
                else:  # pragma: no cover
                    raise TypeError(type(x))
                env[id(x)] = v
                return v

            v = ev(node_n)
            if has_where[0]:
                import jax.numpy as jnp
                ok = functools.reduce(jnp.logical_and, ok_terms)
                return {"__v": v, "__ok": ok}
            return v

        fused = ir.Map.make(fused_fn, slots, prec=n.prec,
                            name=n.name + "_fused")
        if has_where[0]:
            gate = ir.Where.make(lambda d: d["__ok"], fused,
                                 name=n.name + "_gate")
            fused = ir.Map.make(lambda d: d["__v"], [gate], prec=n.prec,
                                name=n.name + "_unwrap")
        return fused

    return rewrite(root)


def optimize(root: ir.Node) -> ir.Node:
    """The default pass pipeline: CSE, then maximal-region fusion."""
    return fuse_elemwise(cse(root))


def fusion_report(before: ir.Node, after: ir.Node) -> dict:
    b, a = ir.topo_order(before), ir.topo_order(after)

    def census(nodes):
        out: dict[str, int] = {}
        for n in nodes:
            out[type(n).__name__] = out.get(type(n).__name__, 0) + 1
        return out

    def stages(nodes):
        """Materialization points: every op except the gate/unwrap
        bookkeeping a fused Where-region lowers to (one region == one
        stage regardless of its internal closure size)."""
        return sum(1 for n in nodes
                   if not n.name.endswith(("_gate", "_unwrap")))

    return {"nodes_before": len(b), "nodes_after": len(a),
            "stages_before": stages(b), "stages_after": stages(a),
            "census_before": census(b), "census_after": census(a)}
