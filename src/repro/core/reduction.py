"""Reduction functions (paper §4.1 ``⊕`` and §6.1.2 templates).

Every reduction is described by the paper's four-lambda template
(Init / Acc / Result / optional Deacc).  On TPU we exploit the template
algebraically instead of folding event-by-event:

* **Invertible** ops (Deacc exists: sum, count, product-of-nonzeros, mean,
  stddev, moment sums) lower to *prefix-scan + subtract-on-evict*:
  ``fold(x[t-W:t]) = P[t] - P[t-W]`` where ``P`` is an inclusive prefix sum.
  This is the Subtract-on-Evict algorithm [Hirzel et al., DEBS'17] the paper
  cites, vectorized over all ticks at once.

* **Non-invertible but associative** ops (max, min) lower to the
  Van Herk / Gil-Werman two-pass sliding reduction (O(1) per element).

The generic (Init, Acc, Result) template remains available for custom
reductions; compile.py folds those with an associative two-level combine.

A reduction may consume multiple *derived channels* of the input (e.g.
stddev needs Σx and Σx²).  ``pre`` maps the raw payload to the channel
tuple, ``post`` maps folded channel sums (+ valid count) to the result.
All channels of the built-ins are invertible, so a single fused prefix-scan
kernel serves them all.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax.numpy as jnp

__all__ = ["Reduction", "REDUCTIONS", "get_reduction"]


@dataclasses.dataclass(frozen=True)
class Reduction:
    name: str
    kind: str  # 'scan' (invertible, prefix-scan) | 'assoc' (van-herk) | 'generic'
    # -- scan kind ---------------------------------------------------------
    # pre: payload -> tuple of channel arrays to prefix-sum (invalid ticks
    #      contribute the additive identity 0).
    pre: Optional[Callable[[Any], tuple]] = None
    # post: (channel window-sums tuple, count of valid ticks) -> value
    post: Optional[Callable[[tuple, Any], Any]] = None
    # -- assoc kind --------------------------------------------------------
    combine: Optional[Callable[[Any, Any], Any]] = None
    identity: Any = None
    # -- generic kind (paper template) --------------------------------------
    init: Optional[Callable[[], Any]] = None
    acc: Optional[Callable[[Any, Any], Any]] = None
    result: Optional[Callable[[Any], Any]] = None
    deacc: Optional[Callable[[Any, Any], Any]] = None
    # empty-window validity: if False, a window with zero valid ticks is φ
    empty_valid: bool = False


def _sum_pre(x):
    return (x,)


def _sq(x):
    return x * x


REDUCTIONS: dict[str, Reduction] = {
    "sum": Reduction(
        name="sum", kind="scan",
        pre=lambda x: (x,),
        post=lambda sums, n: sums[0]),
    "count": Reduction(
        name="count", kind="scan",
        pre=lambda x: (jnp.ones_like(x),),
        post=lambda sums, n: n),
    "mean": Reduction(
        name="mean", kind="scan",
        pre=lambda x: (x,),
        post=lambda sums, n: sums[0] / jnp.maximum(n, 1)),
    # population stddev over the window: sqrt(E[x^2] - E[x]^2)
    "stddev": Reduction(
        name="stddev", kind="scan",
        pre=lambda x: (x, x * x),
        post=lambda sums, n: jnp.sqrt(jnp.maximum(
            sums[1] / jnp.maximum(n, 1)
            - _sq(sums[0] / jnp.maximum(n, 1)), 0.0))),
    # Vibration-analysis composite moments (paper Table 2): rms, kurtosis,
    # crest factor share the moment channels; max goes via 'assoc'.
    "rms": Reduction(
        name="rms", kind="scan",
        pre=lambda x: (x * x,),
        post=lambda sums, n: jnp.sqrt(sums[0] / jnp.maximum(n, 1))),
    "kurtosis": Reduction(
        name="kurtosis", kind="scan",
        pre=lambda x: (x, x**2, x**3, x**4),
        post=lambda s, n: _kurtosis_post(s, n)),
    "max": Reduction(
        name="max", kind="assoc",
        combine=jnp.maximum, identity=-jnp.inf),
    "min": Reduction(
        name="min", kind="assoc",
        combine=jnp.minimum, identity=jnp.inf),
    "absmax": Reduction(  # crest factor numerator; pre maps payload first
        name="absmax", kind="assoc", pre=lambda x: (jnp.abs(x),),
        combine=jnp.maximum, identity=-jnp.inf),
}


def _kurtosis_post(s, n):
    """Excess-free sample kurtosis from raw moment sums (m4 / m2^2)."""
    n = jnp.maximum(n, 1)
    m1 = s[0] / n
    m2 = s[1] / n - m1**2
    m3 = s[2] / n - 3 * m1 * (s[1] / n) + 2 * m1**3
    m4 = (s[3] / n - 4 * m1 * (s[2] / n) + 6 * m1**2 * (s[1] / n) - 3 * m1**4)
    return m4 / jnp.maximum(m2 * m2, 1e-30)


def get_reduction(op: Any) -> Reduction:
    if isinstance(op, Reduction):
        return op
    try:
        return REDUCTIONS[op]
    except KeyError:
        raise KeyError(f"unknown reduction {op!r}; register it in "
                       f"reduction.REDUCTIONS or pass a Reduction") from None
