"""Multi-hop halo exchange for time-sharded SPMD execution (paper §6.2).

When the timeline is sharded across devices, every shard holds only its
*core* ticks of each input; the lookback/lookahead halo (paper Fig. 6
shaded regions) lives on neighbouring shards.  A single ``ppermute`` can
only move data one neighbour over, so the per-shard span used to bound the
halo depth (``halo <= core`` or the config was rejected).  This module
removes that cliff: the halo is assembled by a *chain* of ``ppermute``
pulls — hop ``k`` forwards the slab that originated ``k`` neighbours away,
so after ``K = ceil(halo / core)`` hops every shard has its full halo,
whatever the window depth.

The chain is a *static planning artifact*: :func:`schedule` turns one
per-input halo contract (``plan.InputSpec``) into a :class:`HaloSchedule`
— per side, the tick count each hop contributes.  Hops ``1..K-1`` forward
the full core slab; the final hop is trimmed to the remainder before it is
sent, so no hop ever moves more ticks than the halo still needs.

φ at the edges: ``jax.lax.ppermute`` leaves non-participating receivers
with zeros, so edge shards (no neighbour ``k`` hops over) naturally receive
zero values and a ``False`` validity mask — exactly the φ encoding the rest
of the stack uses for "before the stream start" / "past the stream end".
Hops whose source would lie beyond the mesh on *every* shard (``k > n-1``)
are not sent at all; the slab is filled with φ locally.

Exchange invariant (what :func:`exchange` returns on every shard)::

    [ left_halo ticks | core ticks | right_halo ticks ]

with the left halo ordered oldest-first — identical, tick for tick, to the
window :func:`repro.core.parallel.partition_run` slices out of the global
arrays for the same partition, which is why the sharded and host-loop
executions agree bit-for-bit on identical partitionings.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["HaloSchedule", "HopReport", "schedule", "hop_count", "exchange",
           "exchange_cost"]


def hop_count(halo: int, core: int) -> int:
    """Number of ppermute hops needed to pull ``halo`` ticks when each
    shard holds ``core`` ticks: ``ceil(halo / core)`` (0 for no halo)."""
    if halo <= 0:
        return 0
    if core <= 0:
        raise ValueError(f"per-shard core must be positive, got {core}")
    return -(-halo // core)


@dataclasses.dataclass(frozen=True)
class HaloSchedule:
    """Static per-input hop schedule (a planning artifact, like the halo
    contract it derives from).

    ``left_hops`` / ``right_hops`` hold the tick count contributed by hop
    ``k`` (1-indexed: hop ``k`` delivers the slab that originated ``k``
    neighbours away).  Every hop but the last contributes the full core
    slab; the last contributes the remainder, so ``sum(left_hops) ==
    left_halo`` and likewise on the right.
    """

    core: int
    left_hops: Tuple[int, ...]
    right_hops: Tuple[int, ...]

    @property
    def left_halo(self) -> int:
        return sum(self.left_hops)

    @property
    def right_halo(self) -> int:
        return sum(self.right_hops)

    @property
    def max_hops(self) -> int:
        return max(len(self.left_hops), len(self.right_hops))


@dataclasses.dataclass(frozen=True)
class HopReport:
    """Hop geometry of one input for a given shard count (informational;
    see :func:`repro.core.parallel.check_single_hop_halo`)."""

    left_hops: int
    right_hops: int
    min_single_hop_out_len: int  # smallest per-shard out_len with 1 hop max

    @property
    def max_hops(self) -> int:
        return max(self.left_hops, self.right_hops)


def _hops(halo: int, core: int) -> Tuple[int, ...]:
    k = hop_count(halo, core)
    if k == 0:
        return ()
    return (core,) * (k - 1) + (halo - (k - 1) * core,)


@functools.lru_cache(maxsize=None)
def schedule(left_halo: int, right_halo: int, core: int) -> HaloSchedule:
    """The hop schedule serving a ``(left_halo, right_halo, core)`` halo
    contract.  Cached — schedules are tiny and shared across executors."""
    return HaloSchedule(core=core, left_hops=_hops(left_halo, core),
                        right_hops=_hops(right_halo, core))


def exchange_cost(sched: HaloSchedule, n: int) -> dict:
    """Static cost of one :func:`exchange` on an ``n``-shard axis:
    ``{"hops", "ticks"}`` — collectives issued and ticks moved *per
    shard* (every shard sends/receives the same slabs in SPMD).  Pure
    planning arithmetic, mirroring :func:`_pull`: hops beyond ``n - 1``
    have no possible source shard and are filled with φ locally (no
    collective), and every live hop forwards the current buffer — the
    full core slab until the final hop's pre-send trim."""
    hops = ticks = 0
    for side in (sched.left_hops, sched.right_hops):
        live = 0 if n <= 1 else min(len(side), n - 1)
        hops += live
        ticks += sum(side[:live])
    return {"hops": hops, "ticks": ticks}


def _phi(value, valid, take: int):
    """A φ slab of ``take`` ticks (zero values, all-False validity)."""
    zv = jax.tree_util.tree_map(
        lambda x: jnp.zeros((take,) + x.shape[1:], x.dtype), value)
    return zv, jnp.zeros((take,), bool)


def _pull(hops: Tuple[int, ...], value, valid, axis: str, n: int,
          left: bool):
    """Chained ppermute pulls for one side.

    Returns ``[(v, m), ...]`` with entry ``k-1`` holding the contribution
    of hop ``k`` (the slab that originated ``k`` neighbours away on the
    ``left``/right).  The buffer is re-permuted each hop, so hop ``k``
    costs one collective of at most ``core`` ticks; the final hop's buffer
    is trimmed to the remainder *before* it is sent.  Hops with no possible
    source shard (``k > n-1``) are filled with φ locally, no collective.
    """
    if not hops:
        return []
    perm = ([(i, i + 1) for i in range(n - 1)] if left
            else [(i + 1, i) for i in range(n - 1)])
    live = min(len(hops), n - 1)
    parts = []
    bv, bm = value, valid
    for k, take in enumerate(hops, start=1):
        if k > live:
            parts.append(_phi(value, valid, take))
            continue
        if k == len(hops) and take != bm.shape[0]:
            cut = (lambda x: x[-take:]) if left else (lambda x: x[:take])
            bv = jax.tree_util.tree_map(cut, bv)
            bm = cut(bm)
        bv = jax.tree_util.tree_map(
            lambda x: jax.lax.ppermute(x, axis, perm), bv)
        bm = jax.lax.ppermute(bm, axis, perm)
        parts.append((bv, bm))
    return parts


def exchange(sched: HaloSchedule, value, valid, axis: str, n: int):
    """Assemble one input's full ``left_halo + core + right_halo`` grid on
    every shard from core-only slabs (call inside ``shard_map``).

    ``value``/``valid`` are the local core slab (time axis 0); ``axis`` is
    the mesh axis name the timeline is sharded over, ``n`` its size.
    Returns the ``(value, valid)`` pair the compiled partition body expects
    — bit-identical to the host-loop window of the same partition.
    """
    lparts = _pull(sched.left_hops, value, valid, axis, n, left=True)
    rparts = _pull(sched.right_hops, value, valid, axis, n, left=False)
    # hop k is k neighbours away: the left halo reads oldest-first, so the
    # furthest hop comes first; the right halo reads nearest-first.
    segs = list(reversed(lparts)) + [(value, valid)] + rparts
    if len(segs) == 1:
        return value, valid
    fv = jax.tree_util.tree_map(
        lambda *xs: jnp.concatenate(xs, axis=0), *[s[0] for s in segs])
    fm = jnp.concatenate([s[1] for s in segs], axis=0)
    return fv, fm
