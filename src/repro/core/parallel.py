"""Partitioned / distributed TiLT query execution (paper §6.2, Fig. 6).

Boundary resolution gives a per-input halo contract; this module turns it
into three execution strategies:

* :func:`partition_run`    — host loop over time partitions (the paper's
  worker-thread model, one partition at a time).  Used by tests to assert
  partition invariance and by the latency-bounded-throughput benchmark
  (partition size == batch size knob of Fig. 9).

* :func:`shard_map_run`    — SPMD execution over a mesh axis: the timeline is
  sharded across devices, and each device fetches its lookback/lookahead halo
  from its neighbours with ``jax.lax.ppermute`` (a `collective-permute` on
  TPU ICI — the cheapest collective there is; one hop, no reduction tree).
  After the halo exchange the computation is embarrassingly parallel —
  exactly the paper's "synchronization-free worker" property, recast as SPMD.

* :class:`StreamRunner`    — continuous operation: consume unbounded streams
  chunk by chunk, carrying the halo *tail* of each input between calls as
  the only state.  The state size is the boundary contract — independent of
  stream length — which is what makes long-running queries restartable
  (the tail is checkpointable; see train/checkpoint.py integration).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import compile as qcompile
from .stream import SnapshotGrid

__all__ = ["partition_run", "shard_map_run", "batch_run", "StreamRunner",
           "slice_grid", "check_single_hop_halo"]


def _slice_pad(value, valid, lo: int, hi: int):
    """Slice ticks [lo, hi) of a grid, padding out-of-range with φ."""
    T = valid.shape[0]
    lo_c, hi_c = max(lo, 0), min(hi, T)
    pad_l, pad_r = lo_c - lo, hi - hi_c

    def one(leaf):
        s = jax.lax.slice_in_dim(leaf, lo_c, max(hi_c, lo_c), axis=0)
        if pad_l or pad_r:
            cfg = [(pad_l, pad_r)] + [(0, 0)] * (leaf.ndim - 1)
            s = jnp.pad(s, cfg)
        return s

    v = jax.tree_util.tree_map(one, value)
    m = one(valid) if not (pad_l or pad_r) else jnp.pad(
        jax.lax.slice_in_dim(valid, lo_c, max(hi_c, lo_c), axis=0),
        [(pad_l, pad_r)])
    return v, m


def slice_grid(grid: SnapshotGrid, t0: int, t_end: int) -> SnapshotGrid:
    """Grid restricted to (t0, t_end]; out-of-range ticks are φ."""
    p = grid.prec
    assert (t0 - grid.t0) % p == 0 and (t_end - t0) % p == 0
    lo = (t0 - grid.t0) // p
    hi = (t_end - grid.t0) // p
    v, m = _slice_pad(grid.value, grid.valid, lo, hi)
    return SnapshotGrid(value=v, valid=m, t0=t0, prec=p)


def partition_run(exe: qcompile.CompiledQuery,
                  inputs: Dict[str, SnapshotGrid],
                  out_t0: int, n_parts: int,
                  interpreted: bool = False) -> SnapshotGrid:
    """Run ``n_parts`` partitions of ``exe.out_len`` output ticks each,
    starting at ``out_t0``, stitching the outputs."""
    span = exe.out_len * exe.out_prec
    outs_v, outs_m = [], []
    for k in range(n_parts):
        p0 = out_t0 + k * span
        part_in = {}
        for name, spec in exe.input_specs.items():
            g = inputs[name]
            part_in[name] = _grid_window(g, p0 + spec.t0, spec.length)
        res = (exe.run_interpreted(part_in) if interpreted
               else exe.fn(part_in))
        outs_v.append(res[0])
        outs_m.append(res[1])
    value = jax.tree_util.tree_map(
        lambda *xs: jnp.concatenate(xs, axis=0), *outs_v)
    valid = jnp.concatenate(outs_m, axis=0)
    return SnapshotGrid(value=value, valid=valid, t0=out_t0,
                        prec=exe.out_prec)


def _grid_window(g: SnapshotGrid, t0: int, length: int):
    lo = (t0 - g.t0) // g.prec
    return _slice_pad(g.value, g.valid, lo, lo + length)


def check_single_hop_halo(specs: Dict[str, "qcompile.InputSpec"],
                          out_prec: int, n: int) -> None:
    """Validate the single-hop ppermute contract for ``n`` time shards.

    Each shard fetches its halo from its *immediate* neighbours only, so a
    halo larger than the per-shard core span would need multi-hop exchange
    (ROADMAP item) and currently returns wrong leading ticks.  Rather than
    just rejecting, report the minimum viable partition length for the
    offending input so callers know how to re-compile.
    """
    if n <= 1:
        return
    for name, s in specs.items():
        halo = max(s.left_halo, s.right_halo)
        if halo > s.core:
            # need core = out_len*out_prec // s.prec >= halo ticks
            min_out_len = -(-halo * s.prec // out_prec)
            raise NotImplementedError(
                f"input {name}: halo ({s.left_halo}/{s.right_halo} ticks) "
                f"exceeds the per-shard span ({s.core} ticks); the "
                "single-hop ppermute exchange would return wrong leading "
                f"ticks — recompile with out_len >= {min_out_len} output "
                f"ticks per shard ({min_out_len * out_prec} time units), "
                "or use fewer shards (multi-hop exchange is a ROADMAP item)")


def shard_map_run(exe: qcompile.CompiledQuery,
                  inputs: Dict[str, SnapshotGrid],
                  mesh: Mesh, axis: str = "data") -> SnapshotGrid:
    """SPMD partitioned execution: one partition per device along ``axis``.

    Each input's *core* region (no halo) is sharded along time; halos move
    between neighbours via ppermute.  ``exe`` must be compiled with
    ``out_len == global_out_len // mesh.shape[axis]``.
    """
    n = mesh.shape[axis]

    specs = exe.input_specs
    core_len = {name: s.core * n for name, s in specs.items()}
    check_single_hop_halo(specs, exe.out_prec, n)

    def local_body(*flat):
        local = dict(zip(sorted(specs), flat))
        full = {}
        for name in sorted(specs):
            v, m = local[name]
            hl, hr = specs[name].left_halo, specs[name].right_halo
            right_perm = [(i, i + 1) for i in range(n - 1)]
            left_perm = [(i + 1, i) for i in range(n - 1)]

            if hl:
                lv = jax.tree_util.tree_map(
                    lambda x: _xch_pad(x, hl, right_perm, True, axis, n), v)
                lm = _xch_pad(m, hl, right_perm, True, axis, n)
            else:
                lv = jax.tree_util.tree_map(
                    lambda x: x[:0], v)
                lm = m[:0]
            if hr:
                rv = jax.tree_util.tree_map(
                    lambda x: _xch_pad(x, hr, left_perm, False, axis, n), v)
                rm = _xch_pad(m, hr, left_perm, False, axis, n)
            else:
                rv = jax.tree_util.tree_map(lambda x: x[:0], v)
                rm = m[:0]
            fv = jax.tree_util.tree_map(
                lambda a, b, c: jnp.concatenate([a, b, c], axis=0), lv, v, rv)
            fm = jnp.concatenate([lm, m, rm], axis=0)
            full[name] = (fv, fm)
        return exe.trace_fn(full)

    from jax.experimental.shard_map import shard_map
    in_specs = tuple(P(axis) for _ in sorted(specs))
    flat_in = tuple(
        (inputs[name].value, inputs[name].valid) for name in sorted(specs))
    sharded = shard_map(local_body, mesh=mesh,
                        in_specs=in_specs,
                        out_specs=(P(axis), P(axis)),
                        check_rep=False)
    # shard the core inputs along time
    placed = []
    for name, (v, m) in zip(sorted(specs), flat_in):
        assert m.shape[0] == core_len[name], (
            f"input {name}: expected core length {core_len[name]}, "
            f"got {m.shape[0]} — supply exactly the output-span region")
        sh = NamedSharding(mesh, P(axis))
        placed.append((jax.tree_util.tree_map(
            lambda x: jax.device_put(x, sh), v), jax.device_put(m, sh)))
    val, msk = jax.jit(sharded)(*placed)
    return SnapshotGrid(value=val, valid=msk, t0=0, prec=exe.out_prec)


def _xch_pad(leaf, cnt, perm, take_tail, axis, n):
    """ppermute a halo slab; devices with no neighbour receive zeros (φ)."""
    part = leaf[-cnt:] if take_tail else leaf[:cnt]
    return jax.lax.ppermute(part, axis, perm)


def batch_run(exe: qcompile.CompiledQuery,
              inputs: Dict[str, SnapshotGrid]) -> SnapshotGrid:
    """Keyed/partitioned-stream execution (paper §6.2's *other* parallelism
    axis): input grids carry a leading key axis (K, T) — one sub-stream per
    stock symbol / user / campaign — and the compiled query is vmapped over
    it.  Composes with time partitioning (vmap outside, halo inside), and
    the key axis shards over the mesh exactly like a batch axis.
    """
    names = sorted(exe.input_specs)

    def one(*flat):
        return exe.trace_fn(dict(zip(names, flat)))

    flat_in = []
    for n in names:
        spec = exe.input_specs[n]
        g = inputs[n]
        hl, hr = spec.left_halo, spec.right_halo   # φ-padded halo ticks
        v = jax.tree_util.tree_map(
            lambda x: jnp.pad(x, [(0, 0), (hl, hr)]
                              + [(0, 0)] * (x.ndim - 2)), g.value)
        m = jnp.pad(g.valid, [(0, 0), (hl, hr)])
        flat_in.append((v, m))
    val, msk = jax.jit(jax.vmap(one))(*flat_in)
    return SnapshotGrid(value=val, valid=msk, t0=0, prec=exe.out_prec)


@dataclasses.dataclass
class StreamRunner:
    """Continuous chunked execution with carried halo state.

    The only cross-chunk state is, per input, the trailing ``left_halo``
    ticks of the previous chunk — i.e. exactly the boundary-resolution
    contract.  (Queries with lookahead delay their output by the lookahead;
    we keep lookahead-free operation the default and raise otherwise.)
    """

    exe: qcompile.CompiledQuery
    _tails: Dict[str, tuple] = dataclasses.field(default_factory=dict)
    _t: int = 0  # absolute time of the next output partition start

    def __post_init__(self):
        for name, s in self.exe.input_specs.items():
            if s.right_halo > 0:
                raise NotImplementedError(
                    "StreamRunner supports lookback-only queries "
                    f"(input {name} has lookahead)")

    def step(self, chunks: Dict[str, SnapshotGrid]) -> SnapshotGrid:
        """Feed exactly one partition's worth of new core ticks per input."""
        part_in = {}
        for name, spec in self.exe.input_specs.items():
            g = chunks[name]
            hl, core = spec.left_halo, spec.core
            assert g.valid.shape[0] == core, (name, g.valid.shape, core)
            if name in self._tails:
                tv, tm = self._tails[name]
            else:  # stream start: φ halo
                tv = jax.tree_util.tree_map(
                    lambda x: jnp.zeros((hl,) + x.shape[1:], x.dtype), g.value)
                tm = jnp.zeros((hl,), bool)
            fv = jax.tree_util.tree_map(
                lambda a, b: jnp.concatenate([a, b], axis=0), tv, g.value)
            fm = jnp.concatenate([tm, g.valid], axis=0)
            part_in[name] = (fv, fm)
            if hl:
                self._tails[name] = (
                    jax.tree_util.tree_map(lambda x: x[-hl:], fv), fm[-hl:])
        v, m = self.exe.fn(part_in)
        out = SnapshotGrid(value=v, valid=m, t0=self._t, prec=self.exe.out_prec)
        self._t += self.exe.out_len * self.exe.out_prec
        return out

    def state(self) -> Dict[str, tuple]:
        """Checkpointable runner state (host arrays)."""
        return {k: jax.tree_util.tree_map(np.asarray, v)
                for k, v in self._tails.items()} | {"__t": self._t}

    def restore(self, state: Dict) -> None:
        state = dict(state)  # don't consume the caller's checkpoint
        self._t = state.pop("__t")
        self._tails = {k: jax.tree_util.tree_map(jnp.asarray, v)
                       for k, v in state.items()}
