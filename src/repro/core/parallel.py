"""Partitioned / distributed TiLT query execution (paper §6.2, Fig. 6).

Boundary resolution gives a per-input halo contract; this module turns it
into three execution strategies:

* :func:`partition_run`    — host loop over time partitions (the paper's
  worker-thread model, one partition at a time).  Used by tests to assert
  partition invariance and by the latency-bounded-throughput benchmark
  (partition size == batch size knob of Fig. 9).

* :func:`shard_map_run`    — SPMD execution over a mesh axis: the timeline is
  sharded across devices, and each device assembles its lookback/lookahead
  halo through the multi-hop ``ppermute`` chain planned in halo.py
  (`collective-permute` on TPU ICI — the cheapest collective there is; hop
  ``k`` forwards the slab ``k`` neighbours over, ``ceil(halo/core)`` hops
  per side, so windows deeper than the per-shard span shard fine).  After
  the exchange the computation is embarrassingly parallel — exactly the
  paper's "synchronization-free worker" property, recast as SPMD.

* :class:`StreamRunner`    — continuous operation: consume unbounded streams
  chunk by chunk, carrying the halo *tail* of each input between calls as
  the only state.  The state size is the boundary contract — independent of
  stream length — which is what makes long-running queries restartable
  (the tail is checkpointable; see train/checkpoint.py integration).

:class:`StreamRunner` and :class:`SparseStreamRunner` are deprecated thin
wrappers over the unified policy runner (:mod:`repro.engine.runner`): the
tail-carry, staging and checkpoint machinery they used to duplicate lives
there exactly once, composed from the same planning artifacts
(``InputSpec`` halo contracts, ``ChangePlan`` dilation) these one-shot
entry points consume.
"""
from __future__ import annotations

import collections
import dataclasses
import warnings
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import compile as qcompile
from . import halo as halo_mod
from ..obs import default as _obs_default
from .stream import SnapshotGrid

__all__ = ["partition_run", "shard_map_run", "batch_run", "StreamRunner",
           "SparseStreamRunner", "slice_grid", "check_single_hop_halo",
           "place_core_inputs", "record_exchange"]

# per-CompiledQuery bound on cached (mesh, axis) SPMD steps — each retains
# a compiled executable (see shard_map_run)
_SHARD_STEP_CACHE_MAX = 8


def _slice_pad(value, valid, lo: int, hi: int):
    """Slice ticks [lo, hi) of a grid, padding out-of-range with φ."""
    T = valid.shape[0]
    lo_c, hi_c = max(lo, 0), min(hi, T)
    pad_l, pad_r = lo_c - lo, hi - hi_c

    def one(leaf):
        s = jax.lax.slice_in_dim(leaf, lo_c, max(hi_c, lo_c), axis=0)
        if pad_l or pad_r:
            cfg = [(pad_l, pad_r)] + [(0, 0)] * (leaf.ndim - 1)
            s = jnp.pad(s, cfg)
        return s

    v = jax.tree_util.tree_map(one, value)
    m = one(valid) if not (pad_l or pad_r) else jnp.pad(
        jax.lax.slice_in_dim(valid, lo_c, max(hi_c, lo_c), axis=0),
        [(pad_l, pad_r)])
    return v, m


def slice_grid(grid: SnapshotGrid, t0: int, t_end: int) -> SnapshotGrid:
    """Grid restricted to (t0, t_end]; out-of-range ticks are φ."""
    p = grid.prec
    if (t0 - grid.t0) % p or (t_end - t0) % p:
        raise ValueError(
            f"slice ({t0}, {t_end}] misaligned with grid "
            f"(t0={grid.t0}, prec={p})")
    lo = (t0 - grid.t0) // p
    hi = (t_end - grid.t0) // p
    v, m = _slice_pad(grid.value, grid.valid, lo, hi)
    return SnapshotGrid(value=v, valid=m, t0=t0, prec=p)


def partition_run(exe: qcompile.CompiledQuery,
                  inputs: Dict[str, SnapshotGrid],
                  out_t0: int, n_parts: int,
                  interpreted: bool = False) -> SnapshotGrid:
    """Run ``n_parts`` partitions of ``exe.out_len`` output ticks each,
    starting at ``out_t0``, stitching the outputs."""
    span = exe.out_len * exe.out_prec
    outs_v, outs_m = [], []
    for k in range(n_parts):
        p0 = out_t0 + k * span
        part_in = {}
        for name, spec in exe.input_specs.items():
            g = inputs[name]
            part_in[name] = _grid_window(g, p0 + spec.t0, spec.length)
        res = (exe.run_interpreted(part_in) if interpreted
               else exe.fn(part_in))
        outs_v.append(res[0])
        outs_m.append(res[1])
    value = jax.tree_util.tree_map(
        lambda *xs: jnp.concatenate(xs, axis=0), *outs_v)
    valid = jnp.concatenate(outs_m, axis=0)
    return SnapshotGrid(value=value, valid=valid, t0=out_t0,
                        prec=exe.out_prec)


def _grid_window(g: SnapshotGrid, t0: int, length: int):
    # same alignment guard as slice_grid: a misaligned partition origin
    # must raise, not floor-divide into a time-shifted window
    if (t0 - g.t0) % g.prec:
        raise ValueError(
            f"partition window start {t0} misaligned with input grid "
            f"(t0={g.t0}, prec={g.prec})")
    lo = (t0 - g.t0) // g.prec
    return _slice_pad(g.value, g.valid, lo, lo + length)


def check_single_hop_halo(specs: Dict[str, "qcompile.InputSpec"],
                          out_prec: int, n: int
                          ) -> Dict[str, "halo_mod.HopReport"]:
    """Report the halo/hop geometry of ``n`` time shards, per input.

    Historically this *rejected* any config whose halo exceeded the
    per-shard core span (the single-hop ppermute could not serve it and
    returned wrong leading ticks).  The multi-hop chain in halo.py now
    serves any halo, so nothing is rejected; the function instead reports,
    per input, the hops each side needs and the minimum per-shard
    ``out_len`` at which the exchange collapses to a single hop — the old
    rejection threshold, still useful to trade shard count against
    exchange depth.
    """
    report = {}
    for name, s in specs.items():
        halo = max(s.left_halo, s.right_halo)
        # single-hop needs core = out_len*out_prec // s.prec >= halo ticks
        min_out_len = -(-halo * s.prec // out_prec) if halo else 0
        report[name] = halo_mod.HopReport(
            left_hops=halo_mod.hop_count(s.left_halo, s.core) if n > 1 else 0,
            right_hops=(halo_mod.hop_count(s.right_halo, s.core)
                        if n > 1 else 0),
            min_single_hop_out_len=min_out_len)
    return report


def place_core_inputs(specs: Dict[str, "qcompile.InputSpec"],
                      inputs: Dict[str, SnapshotGrid],
                      mesh: Mesh, axis: str):
    """Validate and device-place core-only input grids for time-sharded
    execution: every input supplies exactly its core region (``n · core``
    ticks, no halo) at a common origin, sharded along ``axis``.

    Returns ``(placed, out_t0)``: the ``(value, valid)`` pairs in
    sorted-name order and the absolute output start.  Shared by
    :func:`shard_map_run` and :func:`repro.multiquery.shard_union_run` so
    the two SPMD entry points cannot drift on the input contract.
    """
    n = mesh.shape[axis]
    names = sorted(specs)
    t0s = {name: inputs[name].t0 for name in names}
    if len(set(t0s.values())) > 1:
        raise ValueError(
            f"inputs disagree on the core-region origin: {t0s} — every "
            "input supplies the same output-span window (P0, P0 + span]")
    out_t0 = t0s[names[0]] if names else 0

    sh = NamedSharding(mesh, P(axis))
    placed = []
    for name in names:
        g, s = inputs[name], specs[name]
        if g.prec != s.prec:
            raise ValueError(
                f"input {name}: grid precision {g.prec} != planned "
                f"precision {s.prec}")
        if g.valid.shape[0] != s.core * n:
            raise ValueError(
                f"input {name}: expected core length {s.core * n}, "
                f"got {g.valid.shape[0]} — supply exactly the "
                "output-span region")
        placed.append((jax.tree_util.tree_map(
            lambda x: jax.device_put(x, sh), g.value),
            jax.device_put(g.valid, sh)))
    return placed, out_t0


def record_exchange(specs: Dict[str, "qcompile.InputSpec"], placed,
                    mesh: Mesh, axis: str) -> None:
    """Accumulate halo-exchange telemetry for one time-sharded run into
    the default :class:`repro.obs.Metrics` registry: hop counts and moved
    ticks from the static :func:`repro.core.halo.exchange_cost` of every
    input's schedule, byte volume from the placed grids' dtypes.  Pure
    host arithmetic over planning artifacts — never touches device data.
    Shared by :func:`shard_map_run` and
    :func:`repro.multiquery.shard_union_run`."""
    m = _obs_default()
    n = mesh.shape[axis]
    hops = ticks = nbytes = 0
    for (v, _mk), name in zip(placed, sorted(specs)):
        cost = halo_mod.exchange_cost(specs[name].halo_schedule(), n)
        # bytes per exchanged tick: every value leaf's per-tick elements
        # plus the 1-byte validity flag
        bpt = 1 + sum(
            np.dtype(x.dtype).itemsize * int(np.prod(x.shape[1:], dtype=int))
            for x in jax.tree_util.tree_leaves(v))
        hops += cost["hops"]
        ticks += cost["ticks"]
        nbytes += cost["ticks"] * bpt
    m.counter("halo.runs", "time-sharded SPMD runs").add(1)
    m.counter("halo.hops", "ppermute collectives issued", "hops").add(hops)
    m.counter("halo.exchange_ticks", "halo ticks moved per shard",
              "ticks").add(ticks)
    m.counter("halo.exchange_bytes", "halo bytes moved per shard",
              "bytes").add(nbytes)


def stage_exchange_step(specs: Dict[str, "qcompile.InputSpec"], body,
                        mesh: Mesh, axis: str, out_specs):
    """Build the jitted SPMD step shared by both time-sharded entry points:
    assemble every input's halo via its planned hop chain
    (``InputSpec.halo_schedule`` → :func:`repro.core.halo.exchange`), then
    run ``body`` on the full ``{name: (value, valid)}`` grids.  Keeping the
    construction in one place means :func:`shard_map_run` and
    :func:`repro.multiquery.shard_union_run` cannot drift on it."""
    n = mesh.shape[axis]
    names = sorted(specs)
    scheds = {name: specs[name].halo_schedule() for name in names}
    _obs_default().counter(
        "halo.stagings", "SPMD exchange steps staged (trace+compile)").add(1)

    def local_body(*flat):
        full = {name: halo_mod.exchange(scheds[name], v, m, axis, n)
                for name, (v, m) in zip(names, flat)}
        return body(full)

    from jax.experimental.shard_map import shard_map
    return jax.jit(shard_map(
        local_body, mesh=mesh, in_specs=tuple(P(axis) for _ in names),
        out_specs=out_specs, check_rep=False))


def _hold_variant(exe: "qcompile.CompiledQuery") -> "qcompile.CompiledQuery":
    """The minimal-``out_len`` recompile of ``exe`` used by the clean-shard
    hold body: ``m`` is the smallest output count whose span is a multiple
    of every input precision (so the variant's windows stay tick-aligned).
    Because every input's left extent (``spec.t0``) is independent of
    ``out_len``, the variant's windows are exact *prefixes* of the full
    slab — same buffer origin, so scan/block decompositions associate
    identically and output tick 0 is bit-identical to the full body's.
    Cached on the CompiledQuery; raises ``ValueError`` when no smaller
    variant exists."""
    import math
    if "_hold_variant" not in exe.__dict__:
        q = exe.out_prec
        m = 1
        for s in exe.input_specs.values():
            need = s.prec // math.gcd(s.prec, q)
            m = m * need // math.gcd(m, need)
        if m >= exe.out_len:
            raise ValueError(
                f"hold variant out_len {m} is not smaller than {exe.out_len}")
        exe.__dict__["_hold_variant"] = qcompile.compile_query(
            exe.root, m, opt=False, jit=False)
    return exe.__dict__["_hold_variant"]


def _stage_sparse_step(exe: "qcompile.CompiledQuery",
                       vexe: "qcompile.CompiledQuery",
                       mesh: Mesh, axis: str):
    """The change-compressed SPMD step: same halo exchange as
    :func:`stage_exchange_step` (collectives stay unconditional — every
    shard participates in every hop), then a per-shard ``lax.cond`` on the
    precomputed dirty flag.  Dirty shards run the full partition body;
    clean shards run the hold body — the minimal-``out_len`` variant on the
    slab prefix, tick 0 broadcast over the shard's span (a clean shard's
    outputs provably all equal its first output; see
    :mod:`repro.core.sparse`)."""
    specs = exe.input_specs
    n = mesh.shape[axis]
    names = sorted(specs)
    scheds = {name: specs[name].halo_schedule() for name in names}
    _obs_default().counter(
        "halo.stagings", "SPMD exchange steps staged (trace+compile)").add(1)
    S = exe.out_len
    vspecs = vexe.input_specs

    def dense_body(full):
        return exe.trace_fn(full)

    def hold_body(full):
        pref = {}
        for name, (v, m) in full.items():
            L = vspecs[name].length
            pref[name] = (
                jax.tree_util.tree_map(
                    lambda x: jax.lax.slice_in_dim(x, 0, L, axis=0), v),
                jax.lax.slice_in_dim(m, 0, L, axis=0))
        ov, om = vexe.trace_fn(pref)
        bv = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[:1], (S,) + x.shape[1:]), ov)
        return bv, jnp.broadcast_to(om[:1], (S,))

    def local_body(flag, *flat):
        full = {name: halo_mod.exchange(scheds[name], v, m, axis, n)
                for name, (v, m) in zip(names, flat)}
        return jax.lax.cond(flag[0], dense_body, hold_body, full)

    from jax.experimental.shard_map import shard_map
    return jax.jit(shard_map(
        local_body, mesh=mesh,
        in_specs=(P(axis),) + tuple(P(axis) for _ in names),
        out_specs=(P(axis), P(axis)), check_rep=False))


def lru_step_get(cache: "collections.OrderedDict", key, build,
                 max_entries: int):
    """Bounded staged-step cache: move-to-front on hit, build + evict the
    least-recently-used entries past ``max_entries`` on miss.  Entries
    retain compiled executables, so long-lived processes that re-shard
    across changing meshes / query sets must stay bounded."""
    if key in cache:
        cache.move_to_end(key)
        return cache[key]
    cache[key] = hit = build()
    while len(cache) > max_entries:
        cache.popitem(last=False)
    return hit


def shard_map_run(exe: qcompile.CompiledQuery,
                  inputs: Dict[str, SnapshotGrid],
                  mesh: Mesh, axis: str = "data",
                  sparse: bool = None) -> SnapshotGrid:
    """SPMD partitioned execution: one partition per device along ``axis``.

    Each input supplies exactly its *core* region (no halo, one output
    span's worth of ticks per shard), sharded along time; every shard then
    assembles its full halo through the statically planned ppermute hop
    chain (``InputSpec.halo_schedule`` → :func:`repro.core.halo.exchange`)
    and runs the compiled partition body with no further communication.
    ``exe`` must be compiled with ``out_len == global_out_len //
    mesh.shape[axis]``.  The output grid starts where the inputs' core
    region starts (``inputs[*].t0``), so sharded outputs stitch against
    :func:`partition_run` at any origin.

    ``sparse`` selects the per-shard dirty fast path: shards whose dilated
    input lineage saw no change (fused change-detection mask of
    :func:`repro.core.sparse.segment_mask`, one flag per shard) skip the
    partition body and broadcast their locally computed first output tick
    instead — bit-identical, since a clean shard's outputs all equal its
    first output.  ``None`` (default) enables it automatically for queries
    compiled with ``sparse=True`` when a smaller hold variant exists;
    ``True`` requires it (raising when it cannot be built); ``False``
    forces the dense body.
    """
    specs = exe.input_specs
    placed, out_t0 = place_core_inputs(specs, inputs, mesh, axis)
    use_sparse = ((exe.change_plan is not None) if sparse is None
                  else bool(sparse))
    vexe = None
    if use_sparse:
        try:
            from .sparse import _change_plan
            _change_plan(exe)
            vexe = _hold_variant(exe)
        except ValueError:
            if sparse:
                raise
            use_sparse = False

    # the staged SPMD step depends only on (exe, mesh, axis, sparse) —
    # cache it on the CompiledQuery so repeated calls (streaming chunks,
    # benchmark repeats) reuse the traced+compiled computation
    cache = exe.__dict__.setdefault("_shard_step_cache",
                                    collections.OrderedDict())
    if not use_sparse:
        step = lru_step_get(
            cache, (mesh, axis),
            lambda: stage_exchange_step(specs, exe.trace_fn, mesh, axis,
                                        (P(axis), P(axis))),
            _SHARD_STEP_CACHE_MAX)
        record_exchange(specs, placed, mesh, axis)
        val, msk = step(*placed)
        return SnapshotGrid(value=val, valid=msk, t0=out_t0,
                            prec=exe.out_prec)

    from ..kernels import ops as kops
    from .sparse import segment_mask
    # per-shard flags resolve on the global grids (cross-shard lineage is
    # just index arithmetic there, no communication), then shard P(axis) —
    # no force_first: the hold body is locally self-sufficient
    flags = segment_mask(exe, inputs, out_t0, mesh.shape[axis],
                         force_first=False, pallas=kops.use_pallas())
    flags = jax.device_put(flags, NamedSharding(mesh, P(axis)))
    step = lru_step_get(
        cache, (mesh, axis, "sparse"),
        lambda: _stage_sparse_step(exe, vexe, mesh, axis),
        _SHARD_STEP_CACHE_MAX)
    record_exchange(specs, placed, mesh, axis)
    val, msk = step(flags, *placed)
    return SnapshotGrid(value=val, valid=msk, t0=out_t0, prec=exe.out_prec)


def batch_run(exe: qcompile.CompiledQuery,
              inputs: Dict[str, SnapshotGrid]) -> SnapshotGrid:
    """Keyed/partitioned-stream execution (paper §6.2's *other* parallelism
    axis): input grids carry a leading key axis (K, T) — one sub-stream per
    stock symbol / user / campaign — and the compiled query is vmapped over
    it.  Composes with time partitioning (vmap outside, halo inside), and
    the key axis shards over the mesh exactly like a batch axis.
    """
    names = sorted(exe.input_specs)

    def one(*flat):
        return exe.trace_fn(dict(zip(names, flat)))

    flat_in = []
    for n in names:
        spec = exe.input_specs[n]
        g = inputs[n]
        hl, hr = spec.left_halo, spec.right_halo   # φ-padded halo ticks
        v = jax.tree_util.tree_map(
            lambda x: jnp.pad(x, [(0, 0), (hl, hr)]
                              + [(0, 0)] * (x.ndim - 2)), g.value)
        m = jnp.pad(g.valid, [(0, 0), (hl, hr)])
        flat_in.append((v, m))
    val, msk = jax.jit(jax.vmap(one))(*flat_in)
    return SnapshotGrid(value=val, valid=msk, t0=0, prec=exe.out_prec)


@dataclasses.dataclass
class StreamRunner:
    """Continuous chunked execution with carried halo state (deprecated
    alias for ``repro.engine.Runner(exe, ExecPolicy())``).

    The only cross-chunk state is, per input, the trailing ``left_halo``
    ticks of the previous chunk — i.e. exactly the boundary-resolution
    contract.  (Queries with lookahead delay their output by the lookahead;
    we keep lookahead-free operation the default and raise otherwise.)
    """

    exe: qcompile.CompiledQuery
    _runner: object = dataclasses.field(default=None, repr=False)

    def __post_init__(self):
        from ..engine.policy import ExecPolicy
        from ..engine.runner import Runner
        warnings.warn(
            "StreamRunner is deprecated; use repro.engine.Runner with "
            "ExecPolicy()", DeprecationWarning, stacklevel=3)
        self._runner = Runner(self.exe, ExecPolicy())

    def step(self, chunks: Dict[str, SnapshotGrid]) -> SnapshotGrid:
        """Feed exactly one partition's worth of new core ticks per input."""
        return self._runner.step(chunks)

    def state(self) -> Dict[str, tuple]:
        """Checkpointable runner state (host arrays)."""
        return self._runner.state()

    def restore(self, state: Dict) -> None:
        self._runner.restore(state, strict=False)


@dataclasses.dataclass
class SparseStreamRunner:
    """Change-compressed continuous execution (deprecated alias for
    ``repro.engine.Runner(exe, ExecPolicy(body="sparse"), segs_per_chunk)``).

    Like :class:`StreamRunner`, but each step feeds ``segs_per_chunk``
    partitions' worth of fresh ticks and only the partitions whose dilated
    input lineage saw a change are computed — the rest hold the previous
    output (see :mod:`repro.core.sparse` for the semantics).  The carried
    cross-chunk state is the halo contract *plus its change metadata*: per
    input, the trailing ``left_halo`` value ticks (as in StreamRunner), the
    matching ``left_halo`` dirty flags (changes near a chunk's end dirty
    the next chunk's leading outputs — the dirty mask is stream state
    exactly like the halo), a 1-tick snapshot the next chunk's first tick
    diffs against, and the last emitted output tick as the hold seed.

    ``exe`` must be compiled with ``sparse=True``; queries must be
    lookback-only (same contract as StreamRunner).
    """

    exe: qcompile.CompiledQuery
    segs_per_chunk: int = 8
    _runner: object = dataclasses.field(default=None, repr=False)

    def __post_init__(self):
        from ..engine.policy import ExecPolicy
        from ..engine.runner import Runner
        warnings.warn(
            "SparseStreamRunner is deprecated; use repro.engine.Runner "
            "with ExecPolicy(body='sparse')", DeprecationWarning,
            stacklevel=3)
        if self.exe.change_plan is None:
            raise ValueError("SparseStreamRunner needs a query compiled "
                             "with sparse=True")
        self._runner = Runner(self.exe, ExecPolicy(body="sparse"),
                              segs_per_chunk=self.segs_per_chunk)

    def step(self, chunks: Dict[str, SnapshotGrid]) -> SnapshotGrid:
        """Feed ``segs_per_chunk`` partitions' worth of fresh core ticks
        per input; compute only the dirty ones."""
        return self._runner.step(chunks)

    # -- checkpointing (historical flat format, translated to the unified
    #    state pytree of the policy runner) ----------------------------------
    def state(self) -> Dict:
        """Checkpointable runner state (host arrays): halo tails + change
        metadata (dirty tails, 1-tick snapshots, hold seed)."""
        c = self._runner.state()
        sp = c.pop("__sparse")
        t = c.pop("__t")
        return {"tails": c, "dirty": sp["dirty"], "prev": sp["prev"],
                "seed": sp["seed"].get("__out"), "__t": t}

    def restore(self, state: Dict) -> None:
        seed = state["seed"]
        canonical = dict(state["tails"])
        canonical["__t"] = state["__t"]
        canonical["__sparse"] = {
            "dirty": state["dirty"], "prev": state["prev"],
            "seed": {} if seed is None else {"__out": seed},
            "started": True}
        self._runner.restore(canonical, strict=False)
