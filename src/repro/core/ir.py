"""TiLT intermediate representation (paper §4).

A streaming query is a DAG of :class:`Node` objects, each defining an output
*temporal object* as a functional transformation of its inputs over a time
domain ``TDom(Ts, Te, prec)`` (paper §4.1).  The node vocabulary is the
minimal set the paper identifies:

* :class:`Input`    — a source temporal object (``~stock``).
* :class:`Const`    — a constant temporal object (always valid).
* :class:`Map`      — elementwise functional transformation of one or more
                      temporal objects at the *same* time instant.  Covers
                      Select and temporal Join (binary Map with strict-overlap
                      φ semantics) from Fig. 1/4.
* :class:`Where`    — conditional nulling: value passes through, validity is
                      ANDed with a predicate (Fig. 4 ``~where``).
* :class:`Shift`    — time shift: ``out[t] = in[t - delta]``.
* :class:`Reduce`   — ``⊕(op, ~in[t-window : t])`` on a (possibly strided)
                      output domain: sliding/tumbling window aggregation.
* :class:`Interp`   — gap fill (imputation/resampling support): values at
                      invalid ticks are reconstructed from neighbours within
                      a bounded ``max_gap`` (hold / linear interpolation).

φ-semantics (paper eq. 1): every node computes a ``(value, valid)`` pair per
tick; arithmetic on φ yields φ, hence ``Map.valid = AND(arg valids)``;
``Reduce`` folds only valid ticks and yields φ on empty windows.

Precision & alignment: each node carries ``prec``.  A node with precision
``q`` reads an argument with precision ``p`` at output time ``τ`` using the
snapshot *hold* rule (stream.py): arg tick ``(τ - t0)//p - 1``.  The frontend
enforces ``p | q`` or ``q | p`` so alignment is a static gather.

Time is left symbolic: nodes never store ``Ts``/``Te``.  Boundary resolution
(boundary.py) turns the infinite domain into a partition contract, and
compile.py instantiates the query on concrete grids — this mirrors the
paper's Fig. 3(a→b) pipeline.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Optional, Sequence

__all__ = [
    "Node", "Input", "Const", "Map", "Where", "Shift", "Reduce", "Interp",
    "topo_order", "free_inputs", "validate",
]

_ids = itertools.count()


@dataclasses.dataclass(frozen=True, eq=False)
class Node:
    """Base temporal-expression node. Nodes are hashable by identity."""

    prec: int
    name: str

    @property
    def args(self) -> tuple["Node", ...]:
        return ()

    def _replace_args(self, new_args: Sequence["Node"]) -> "Node":
        assert not new_args
        return self


def _mk_name(prefix: str) -> str:
    return f"{prefix}_{next(_ids)}"


@dataclasses.dataclass(frozen=True, eq=False)
class Input(Node):
    """Source temporal object.  ``fields`` documents payload structure.

    ``keyed=True`` declares a *partitioned* stream (one independent
    sub-stream per key — user / stock symbol / campaign).  The time-centric
    semantics are per-key; the keyed engine (engine/) vectorizes execution
    over the key axis and shards it across devices.
    """

    fields: tuple[str, ...] = ()
    keyed: bool = False

    @staticmethod
    def make(name: str, prec: int = 1, fields: tuple[str, ...] = (),
             keyed: bool = False) -> "Input":
        return Input(prec=prec, name=name, fields=fields, keyed=keyed)


@dataclasses.dataclass(frozen=True, eq=False)
class Const(Node):
    value: Any = 0.0

    @staticmethod
    def make(value: Any, prec: int = 1) -> "Const":
        return Const(prec=prec, name=_mk_name("const"), value=value)


@dataclasses.dataclass(frozen=True, eq=False)
class Map(Node):
    """Elementwise transformation at aligned time instants.

    ``fn`` maps the argument *values* (pytrees) to the output value.  It must
    be a pure jnp-traceable function.  Validity is the AND of argument
    validities (strict-overlap Join semantics for arity ≥ 2).

    With ``phi_aware=True`` the function instead receives ``(value, valid)``
    pairs and returns a ``(value, valid)`` pair — this expresses φ-sensitive
    expressions like the paper's ``(~x[t] != φ) ? ~x[t] : ~avg[t]``
    (imputation / coalesce / left-join patterns).
    """

    fn: Callable[..., Any] = None
    phi_aware: bool = False
    _args: tuple[Node, ...] = ()

    @property
    def args(self) -> tuple[Node, ...]:
        return self._args

    def _replace_args(self, new_args):
        return dataclasses.replace(self, _args=tuple(new_args))

    @staticmethod
    def make(fn: Callable[..., Any], args: Sequence[Node],
             prec: Optional[int] = None, name: Optional[str] = None,
             phi_aware: bool = False) -> "Map":
        args = tuple(args)
        q = prec if prec is not None else max(a.prec for a in args)
        for a in args:
            if q % a.prec != 0 and a.prec % q != 0:
                raise ValueError(
                    f"precision mismatch: arg {a.name} prec={a.prec} vs out prec={q}")
        return Map(prec=q, name=name or _mk_name("map"), fn=fn,
                   phi_aware=phi_aware, _args=args)


@dataclasses.dataclass(frozen=True, eq=False)
class Where(Node):
    """``out[t] = pred(in[t]) ? in[t] : φ``."""

    pred: Callable[[Any], Any] = None
    _args: tuple[Node, ...] = ()

    @property
    def args(self) -> tuple[Node, ...]:
        return self._args

    def _replace_args(self, new_args):
        return dataclasses.replace(self, _args=tuple(new_args))

    @staticmethod
    def make(pred: Callable[[Any], Any], arg: Node,
             name: Optional[str] = None) -> "Where":
        return Where(prec=arg.prec, name=name or _mk_name("where"),
                     pred=pred, _args=(arg,))


@dataclasses.dataclass(frozen=True, eq=False)
class Shift(Node):
    """``out[t] = in[t - delta]`` (delta in time units, multiple of prec)."""

    delta: int = 0
    _args: tuple[Node, ...] = ()

    @property
    def args(self) -> tuple[Node, ...]:
        return self._args

    def _replace_args(self, new_args):
        return dataclasses.replace(self, _args=tuple(new_args))

    @staticmethod
    def make(arg: Node, delta: int, name: Optional[str] = None,
             prec: Optional[int] = None) -> "Shift":
        # delta need not be a multiple of the precision: the hold-alignment
        # rule (latest tick ≤ τ−delta) gives sub-precision shifts exact
        # snapshot semantics.  ``prec`` re-domains the result (e.g. shifting
        # a strided aggregate onto the fine grid to broadcast window stats
        # over the window's own ticks).
        return Shift(prec=prec or arg.prec, name=name or _mk_name("shift"),
                     delta=delta, _args=(arg,))


@dataclasses.dataclass(frozen=True, eq=False)
class Reduce(Node):
    """``out[t] = ⊕(op, ~in[t - window : t])`` on an output domain of
    precision ``prec`` (== stride).  ``window`` is in time units and must be
    a multiple of the input precision.

    ``op`` is a key into reduction.REDUCTIONS (sum/count/mean/max/min/...)
    or a custom :class:`reduction.Reduction`.
    """

    op: Any = "sum"
    window: int = 0
    field: Optional[str] = None  # reduce a single payload field of a dict stream
    _args: tuple[Node, ...] = ()

    @property
    def args(self) -> tuple[Node, ...]:
        return self._args

    def _replace_args(self, new_args):
        return dataclasses.replace(self, _args=tuple(new_args))

    @staticmethod
    def make(op: Any, arg: Node, window: int, stride: Optional[int] = None,
             field: Optional[str] = None, name: Optional[str] = None) -> "Reduce":
        stride = stride if stride is not None else arg.prec
        if window % arg.prec != 0:
            raise ValueError("window must be a multiple of input precision")
        if stride % arg.prec != 0:
            raise ValueError("stride must be a multiple of input precision")
        return Reduce(prec=stride, name=name or _mk_name(f"{op}w{window}"),
                      op=op, window=window, field=field, _args=(arg,))


@dataclasses.dataclass(frozen=True, eq=False)
class Interp(Node):
    """Gap reconstruction for signal imputation / resampling.

    mode='hold':   last valid value within max_gap ticks.
    mode='linear': linear interpolation between the nearest valid neighbours
                   within ±max_gap ticks (paper's resampling app [55]).
    Output precision may differ from input precision (resampling).
    """

    mode: str = "hold"
    max_gap: int = 0  # time units; bounds the lookback/lookahead
    _args: tuple[Node, ...] = ()

    @property
    def args(self) -> tuple[Node, ...]:
        return self._args

    def _replace_args(self, new_args):
        return dataclasses.replace(self, _args=tuple(new_args))

    @staticmethod
    def make(arg: Node, mode: str, max_gap: int, prec: Optional[int] = None,
             name: Optional[str] = None) -> "Interp":
        return Interp(prec=prec or arg.prec, name=name or _mk_name(f"interp_{mode}"),
                      mode=mode, max_gap=max_gap, _args=(arg,))


# ---------------------------------------------------------------------------
# DAG utilities
# ---------------------------------------------------------------------------

def topo_order(root: Node) -> list[Node]:
    """Post-order (deps first) topological order of the expression DAG."""
    seen: dict[int, Node] = {}
    order: list[Node] = []

    def visit(n: Node):
        if id(n) in seen:
            return
        seen[id(n)] = n
        for a in n.args:
            visit(a)
        order.append(n)

    visit(root)
    return order


def free_inputs(root: Node) -> list[Input]:
    return [n for n in topo_order(root) if isinstance(n, Input)]


def validate(root: Node) -> None:
    """Sanity-check precisions and windows along the DAG."""
    for n in topo_order(root):
        if isinstance(n, Reduce):
            (a,) = n.args
            assert n.window % a.prec == 0, n.name
            assert n.prec % a.prec == 0, (
                f"{n.name}: stride {n.prec} not a multiple of input prec {a.prec}")
        for a in n.args:
            assert (n.prec % a.prec == 0) or (a.prec % n.prec == 0), (
                f"{n.name}: unalignable precisions {n.prec} vs {a.prec}")
