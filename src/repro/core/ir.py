"""TiLT intermediate representation (paper §4).

A streaming query is a DAG of :class:`Node` objects, each defining an output
*temporal object* as a functional transformation of its inputs over a time
domain ``TDom(Ts, Te, prec)`` (paper §4.1).  The node vocabulary is the
minimal set the paper identifies:

* :class:`Input`    — a source temporal object (``~stock``).
* :class:`Const`    — a constant temporal object (always valid).
* :class:`Map`      — elementwise functional transformation of one or more
                      temporal objects at the *same* time instant.  Covers
                      Select and temporal Join (binary Map with strict-overlap
                      φ semantics) from Fig. 1/4.
* :class:`Where`    — conditional nulling: value passes through, validity is
                      ANDed with a predicate (Fig. 4 ``~where``).
* :class:`Shift`    — time shift: ``out[t] = in[t - delta]``.
* :class:`Reduce`   — ``⊕(op, ~in[t-window : t])`` on a (possibly strided)
                      output domain: sliding/tumbling window aggregation.
* :class:`Interp`   — gap fill (imputation/resampling support): values at
                      invalid ticks are reconstructed from neighbours within
                      a bounded ``max_gap`` (hold / linear interpolation).

φ-semantics (paper eq. 1): every node computes a ``(value, valid)`` pair per
tick; arithmetic on φ yields φ, hence ``Map.valid = AND(arg valids)``;
``Reduce`` folds only valid ticks and yields φ on empty windows.

Precision & alignment: each node carries ``prec``.  A node with precision
``q`` reads an argument with precision ``p`` at output time ``τ`` using the
snapshot *hold* rule (stream.py): arg tick ``(τ - t0)//p - 1``.  The frontend
enforces ``p | q`` or ``q | p`` so alignment is a static gather.

Time is left symbolic: nodes never store ``Ts``/``Te``.  Boundary resolution
(boundary.py) turns the infinite domain into a partition contract, and
compile.py instantiates the query on concrete grids — this mirrors the
paper's Fig. 3(a→b) pipeline.
"""
from __future__ import annotations

import dataclasses
import dis
import functools
import hashlib
import itertools
import types
from typing import Any, Callable, Optional, Sequence

__all__ = [
    "Node", "Input", "Const", "Map", "Where", "Shift", "Reduce", "Interp",
    "topo_order", "topo_order_multi", "free_inputs", "validate",
    "fingerprint",
]

_ids = itertools.count()


@dataclasses.dataclass(frozen=True, eq=False)
class Node:
    """Base temporal-expression node. Nodes are hashable by identity."""

    prec: int
    name: str

    @property
    def args(self) -> tuple["Node", ...]:
        return ()

    def _replace_args(self, new_args: Sequence["Node"]) -> "Node":
        assert not new_args
        return self


def _mk_name(prefix: str) -> str:
    return f"{prefix}_{next(_ids)}"


@dataclasses.dataclass(frozen=True, eq=False)
class Input(Node):
    """Source temporal object.  ``fields`` documents payload structure.

    ``keyed=True`` declares a *partitioned* stream (one independent
    sub-stream per key — user / stock symbol / campaign).  The time-centric
    semantics are per-key; the keyed engine (engine/) vectorizes execution
    over the key axis and shards it across devices.
    """

    fields: tuple[str, ...] = ()
    keyed: bool = False

    @staticmethod
    def make(name: str, prec: int = 1, fields: tuple[str, ...] = (),
             keyed: bool = False) -> "Input":
        return Input(prec=prec, name=name, fields=fields, keyed=keyed)


@dataclasses.dataclass(frozen=True, eq=False)
class Const(Node):
    value: Any = 0.0

    @staticmethod
    def make(value: Any, prec: int = 1) -> "Const":
        return Const(prec=prec, name=_mk_name("const"), value=value)


@dataclasses.dataclass(frozen=True, eq=False)
class Map(Node):
    """Elementwise transformation at aligned time instants.

    ``fn`` maps the argument *values* (pytrees) to the output value.  It must
    be a pure jnp-traceable function.  Validity is the AND of argument
    validities (strict-overlap Join semantics for arity ≥ 2).

    With ``phi_aware=True`` the function instead receives ``(value, valid)``
    pairs and returns a ``(value, valid)`` pair — this expresses φ-sensitive
    expressions like the paper's ``(~x[t] != φ) ? ~x[t] : ~avg[t]``
    (imputation / coalesce / left-join patterns).
    """

    fn: Callable[..., Any] = None
    phi_aware: bool = False
    _args: tuple[Node, ...] = ()

    @property
    def args(self) -> tuple[Node, ...]:
        return self._args

    def _replace_args(self, new_args):
        return dataclasses.replace(self, _args=tuple(new_args))

    @staticmethod
    def make(fn: Callable[..., Any], args: Sequence[Node],
             prec: Optional[int] = None, name: Optional[str] = None,
             phi_aware: bool = False) -> "Map":
        args = tuple(args)
        q = prec if prec is not None else max(a.prec for a in args)
        for a in args:
            if q % a.prec != 0 and a.prec % q != 0:
                raise ValueError(
                    f"precision mismatch: arg {a.name} prec={a.prec} vs out prec={q}")
        return Map(prec=q, name=name or _mk_name("map"), fn=fn,
                   phi_aware=phi_aware, _args=args)


@dataclasses.dataclass(frozen=True, eq=False)
class Where(Node):
    """``out[t] = pred(in[t]) ? in[t] : φ``."""

    pred: Callable[[Any], Any] = None
    _args: tuple[Node, ...] = ()

    @property
    def args(self) -> tuple[Node, ...]:
        return self._args

    def _replace_args(self, new_args):
        return dataclasses.replace(self, _args=tuple(new_args))

    @staticmethod
    def make(pred: Callable[[Any], Any], arg: Node,
             name: Optional[str] = None) -> "Where":
        return Where(prec=arg.prec, name=name or _mk_name("where"),
                     pred=pred, _args=(arg,))


@dataclasses.dataclass(frozen=True, eq=False)
class Shift(Node):
    """``out[t] = in[t - delta]`` (delta in time units, multiple of prec)."""

    delta: int = 0
    _args: tuple[Node, ...] = ()

    @property
    def args(self) -> tuple[Node, ...]:
        return self._args

    def _replace_args(self, new_args):
        return dataclasses.replace(self, _args=tuple(new_args))

    @staticmethod
    def make(arg: Node, delta: int, name: Optional[str] = None,
             prec: Optional[int] = None) -> "Shift":
        # delta need not be a multiple of the precision: the hold-alignment
        # rule (latest tick ≤ τ−delta) gives sub-precision shifts exact
        # snapshot semantics.  ``prec`` re-domains the result (e.g. shifting
        # a strided aggregate onto the fine grid to broadcast window stats
        # over the window's own ticks).
        return Shift(prec=prec or arg.prec, name=name or _mk_name("shift"),
                     delta=delta, _args=(arg,))


@dataclasses.dataclass(frozen=True, eq=False)
class Reduce(Node):
    """``out[t] = ⊕(op, ~in[t - window : t])`` on an output domain of
    precision ``prec`` (== stride).  ``window`` is in time units and must be
    a multiple of the input precision.

    ``op`` is a key into reduction.REDUCTIONS (sum/count/mean/max/min/...)
    or a custom :class:`reduction.Reduction`.
    """

    op: Any = "sum"
    window: int = 0
    field: Optional[str] = None  # reduce a single payload field of a dict stream
    _args: tuple[Node, ...] = ()

    @property
    def args(self) -> tuple[Node, ...]:
        return self._args

    def _replace_args(self, new_args):
        return dataclasses.replace(self, _args=tuple(new_args))

    @staticmethod
    def make(op: Any, arg: Node, window: int, stride: Optional[int] = None,
             field: Optional[str] = None, name: Optional[str] = None) -> "Reduce":
        stride = stride if stride is not None else arg.prec
        if window % arg.prec != 0:
            raise ValueError("window must be a multiple of input precision")
        if stride % arg.prec != 0:
            raise ValueError("stride must be a multiple of input precision")
        return Reduce(prec=stride, name=name or _mk_name(f"{op}w{window}"),
                      op=op, window=window, field=field, _args=(arg,))


@dataclasses.dataclass(frozen=True, eq=False)
class Interp(Node):
    """Gap reconstruction for signal imputation / resampling.

    mode='hold':   last valid value within max_gap ticks.
    mode='linear': linear interpolation between the nearest valid neighbours
                   within ±max_gap ticks (paper's resampling app [55]).
    Output precision may differ from input precision (resampling).
    """

    mode: str = "hold"
    max_gap: int = 0  # time units; bounds the lookback/lookahead
    _args: tuple[Node, ...] = ()

    @property
    def args(self) -> tuple[Node, ...]:
        return self._args

    def _replace_args(self, new_args):
        return dataclasses.replace(self, _args=tuple(new_args))

    @staticmethod
    def make(arg: Node, mode: str, max_gap: int, prec: Optional[int] = None,
             name: Optional[str] = None) -> "Interp":
        return Interp(prec=prec or arg.prec, name=name or _mk_name(f"interp_{mode}"),
                      mode=mode, max_gap=max_gap, _args=(arg,))


# ---------------------------------------------------------------------------
# DAG utilities
# ---------------------------------------------------------------------------

def topo_order(root: Node) -> list[Node]:
    """Post-order (deps first) topological order of the expression DAG."""
    return topo_order_multi([root])


def topo_order_multi(roots: Sequence[Node]) -> list[Node]:
    """Post-order over the *union* DAG of several roots (shared nodes once).

    Within each root's subtree, and across roots, every node appears after
    all of its arguments — the property the multi-query planner and the
    boundary-resolution reverse pass rely on.
    """
    seen: dict[int, Node] = {}
    order: list[Node] = []

    def visit(n: Node):
        if id(n) in seen:
            return
        seen[id(n)] = n
        for a in n.args:
            visit(a)
        order.append(n)

    for r in roots:
        visit(r)
    return order


def free_inputs(root: Node) -> list[Input]:
    return [n for n in topo_order(root) if isinstance(n, Input)]


def validate(root: Node) -> None:
    """Sanity-check precisions and windows along the DAG."""
    for n in topo_order(root):
        if isinstance(n, Reduce):
            (a,) = n.args
            assert n.window % a.prec == 0, n.name
            assert n.prec % a.prec == 0, (
                f"{n.name}: stride {n.prec} not a multiple of input prec {a.prec}")
        for a in n.args:
            assert (n.prec % a.prec == 0) or (a.prec % n.prec == 0), (
                f"{n.name}: unalignable precisions {n.prec} vs {a.prec}")


# ---------------------------------------------------------------------------
# canonical structural fingerprints (multi-query sharing)
# ---------------------------------------------------------------------------
#
# Two sub-DAGs may be evaluated once and shared between concurrent queries
# iff they are *structurally* identical: same node kinds, same static
# parameters, same user functions, same inputs.  ``fingerprint`` hashes
# exactly that — a hash-consing key over (op, params, argument fingerprints),
# with source nodes keyed by (name, prec, keyed), i.e. by their grid.
#
# The digest must be stable across processes (a plan cache keyed by it may
# outlive one interpreter), so the encoding never uses ``id()`` or Python's
# randomized ``hash()``: callables are tokenized by their bytecode, constants,
# names, defaults and closure *values* (not cells), and everything is folded
# through sha256.  Auto-generated node names (``map_17``) carry a global
# counter and are deliberately excluded — only ``Input`` names are identity.

def _value_token(v, seen=None) -> tuple:
    """Deterministic, process-stable token for a Python value."""
    if seen is None:
        seen = set()
    if v is None or isinstance(v, (bool, int, str, bytes)):
        return ("prim", type(v).__name__, repr(v))
    if isinstance(v, float):
        return ("float", repr(v))  # repr distinguishes -0.0, round-trips
    if isinstance(v, (tuple, list)):
        return ("seq", type(v).__name__,
                tuple(_value_token(x, seen) for x in v))
    if isinstance(v, dict):
        return ("dict", tuple(sorted(
            (_value_token(k, seen), _value_token(x, seen))
            for k, x in v.items())))
    if isinstance(v, types.ModuleType):
        return ("module", v.__name__)
    if isinstance(v, types.CodeType):
        return _code_token(v, seen)
    if dataclasses.is_dataclass(v) and not isinstance(v, type):
        return ("dataclass", type(v).__qualname__, tuple(
            (f.name, _value_token(getattr(v, f.name), seen))
            for f in dataclasses.fields(v)))
    # numpy scalars / small arrays (window params, thresholds)
    tobytes = getattr(v, "tobytes", None)
    dtype = getattr(v, "dtype", None)
    if tobytes is not None and dtype is not None:
        return ("ndarray", str(dtype), tuple(getattr(v, "shape", ())),
                v.tobytes())
    if callable(v):
        return _callable_token(v, seen)
    # generic parameter object: identity is its type + attribute state
    state = getattr(v, "__dict__", None)
    if state is not None:
        if id(v) in seen:
            return ("cycle",)
        seen.add(id(v))
        return ("obj", type(v).__qualname__, tuple(sorted(
            (k, _value_token(x, seen)) for k, x in state.items())))
    raise ValueError(
        f"cannot fingerprint value of type {type(v).__name__} ({v!r}); "
        "query closures must hold primitives, arrays or functions")


def _code_token(code: types.CodeType, seen) -> tuple:
    # co_filename / lineno / varnames excluded: renaming locals or moving a
    # lambda between files does not change what it computes.
    return ("code", code.co_code,
            tuple(_value_token(c, seen) for c in code.co_consts),
            code.co_names, code.co_argcount, code.co_kwonlyargcount,
            code.co_flags & 0x0c)  # *args / **kwargs flags only


def _referenced_names(code: types.CodeType) -> set:
    """Global names a code object (or its nested lambdas) actually loads.

    Only LOAD_GLOBAL/LOAD_NAME targets count — ``co_names`` also holds
    attribute/method names (``v.mean()``), which must not be resolved
    against the defining module's namespace.
    """
    names = {ins.argval for ins in dis.get_instructions(code)
             if ins.opname in ("LOAD_GLOBAL", "LOAD_NAME")}
    for c in code.co_consts:
        if isinstance(c, types.CodeType):
            names |= _referenced_names(c)
    return names


def _callable_token(fn, seen=None) -> tuple:
    if seen is None:
        seen = set()
    if id(fn) in seen:
        # back-edge (mutually recursive helpers) or re-reference: traversal
        # order is deterministic, so the marker is too
        return ("cycle",)
    seen.add(id(fn))
    # bound method: the receiver's state is part of what it computes
    # (Thresh(1.0).pred vs Thresh(5.0).pred share bytecode, not behaviour)
    self_obj = getattr(fn, "__self__", None)
    func = getattr(fn, "__func__", None)
    if self_obj is not None and func is not None:
        return ("bound", _callable_token(func, seen),
                _value_token(self_obj, seen))
    if isinstance(fn, functools.partial):
        return ("partial", _callable_token(fn.func, seen),
                tuple(_value_token(a, seen) for a in fn.args),
                tuple(sorted((k, _value_token(v, seen))
                             for k, v in fn.keywords.items())))
    code = getattr(fn, "__code__", None)
    if code is not None:
        defaults = tuple(_value_token(d, seen)
                         for d in (fn.__defaults__ or ()))
        kwdefaults = tuple(sorted(
            (k, _value_token(v, seen))
            for k, v in (fn.__kwdefaults__ or {}).items()))
        cells = fn.__closure__ or ()
        closure = tuple(_value_token(c.cell_contents, seen) for c in cells)
        # captured globals: a lambda reading module-level state by name
        # computes different things in different namespaces even with equal
        # bytecode, so the referenced values are part of the structure
        glob = getattr(fn, "__globals__", None) or {}
        gtoks = tuple((nm, _value_token(glob[nm], seen))
                      for nm in sorted(_referenced_names(code))
                      if nm in glob)
        return ("fn", _code_token(code, seen), defaults, kwdefaults,
                closure, gtoks)
    # builtins / ufuncs / C functions: identified by qualified name
    name = getattr(fn, "__qualname__", None) or getattr(fn, "__name__", None)
    if name is not None:
        return ("named_callable", getattr(fn, "__module__", None), name)
    call = getattr(type(fn), "__call__", None)
    if call is not None and getattr(call, "__code__", None) is not None:
        state = getattr(fn, "__dict__", {})
        return ("obj_call", type(fn).__qualname__, _callable_token(call, seen),
                tuple(sorted((k, _value_token(v, seen))
                             for k, v in state.items())))
    raise ValueError(f"cannot fingerprint callable {fn!r}")


def _node_token(n: Node, arg_fps: tuple) -> tuple:
    if isinstance(n, Input):
        return ("input", n.name, n.prec, n.keyed, n.fields)
    if isinstance(n, Const):
        return ("const", _value_token(n.value), n.prec)
    if isinstance(n, Map):
        return ("map", _callable_token(n.fn), n.prec, n.phi_aware, arg_fps)
    if isinstance(n, Where):
        return ("where", _callable_token(n.pred), n.prec, arg_fps)
    if isinstance(n, Shift):
        return ("shift", n.delta, n.prec, arg_fps)
    if isinstance(n, Reduce):
        op = n.op if isinstance(n.op, str) else _value_token(n.op)
        return ("reduce", op, n.window, n.prec, n.field, arg_fps)
    if isinstance(n, Interp):
        return ("interp", n.mode, n.max_gap, n.prec, arg_fps)
    raise TypeError(type(n))  # pragma: no cover


def fingerprint(root: Node) -> str:
    """Canonical structural fingerprint (sha256 hex) of a node's sub-DAG.

    ``fingerprint(a) == fingerprint(b)`` iff ``a`` and ``b`` are
    structurally equal: same DAG shape, node kinds, static parameters and
    user functions (compared by bytecode + captured values).  Stable across
    processes and hash seeds; cached on the node.
    """
    memo: dict[int, str] = {}

    def fp(n: Node) -> str:
        cached = n.__dict__.get("_fingerprint")
        if cached is not None:
            return cached
        if id(n) in memo:
            return memo[id(n)]
        token = _node_token(n, tuple(fp(a) for a in n.args))
        digest = hashlib.sha256(repr(token).encode()).hexdigest()
        memo[id(n)] = digest
        object.__setattr__(n, "_fingerprint", digest)
        return digest

    return fp(root)
