"""User-facing temporal query builder.

The surface API mirrors the familiar event-centric operator vocabulary of
Fig. 1 (Select / Where / Join / Window-aggregates / Shift / Chop), but every
call constructs time-centric IR (ir.py) — this is the translation stage of
the paper's Fig. 3, done eagerly.

Example (the paper's running stock-trend query, §2 / Fig. 2a)::

    stock = TStream.source("stock", prec=1)
    avg10 = stock.window(10).mean()
    avg20 = stock.window(20).mean()
    diff  = avg10.join(avg20, lambda a, b: a - b)
    query = diff.where(lambda d: d > 0)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence

from . import ir

__all__ = ["TStream", "WindowSpec"]


@dataclasses.dataclass(frozen=True)
class TStream:
    """A temporal object under construction (wraps an IR node)."""

    node: ir.Node

    # -- sources ------------------------------------------------------------
    @staticmethod
    def source(name: str, prec: int = 1, fields: Sequence[str] = (),
               keyed: bool = False) -> "TStream":
        """Declare a source stream.  ``keyed=True`` marks a partitioned
        stream of independent per-key sub-streams (fraud per-user, YSB
        per-campaign); execute it with :class:`repro.engine.KeyedEngine`."""
        return TStream(ir.Input.make(name, prec=prec, fields=tuple(fields),
                                     keyed=keyed))

    @staticmethod
    def const(value: Any, prec: int = 1) -> "TStream":
        return TStream(ir.Const.make(value, prec=prec))

    # -- per-event ops (Fig. 1a/1b) ------------------------------------------
    def select(self, fn: Callable[[Any], Any], name: Optional[str] = None
               ) -> "TStream":
        return TStream(ir.Map.make(fn, [self.node], name=name))

    map = select

    def field(self, key: str) -> "TStream":
        return self.select(lambda v, _k=key: v[_k], name=f"field_{key}")

    def where(self, pred: Callable[[Any], Any],
              name: Optional[str] = None) -> "TStream":
        return TStream(ir.Where.make(pred, self.node, name=name))

    # -- temporal join (Fig. 1c) ----------------------------------------------
    def join(self, other: "TStream", fn: Callable[[Any, Any], Any] = None,
             name: Optional[str] = None) -> "TStream":
        fn = fn or (lambda a, b: (a, b))
        return TStream(ir.Map.make(fn, [self.node, other.node], name=name))

    @staticmethod
    def zip(streams: Sequence["TStream"], fn: Callable[..., Any],
            prec: Optional[int] = None,
            name: Optional[str] = None) -> "TStream":
        return TStream(ir.Map.make(fn, [s.node for s in streams], prec=prec,
                                   name=name))

    def coalesce(self, other: "TStream",
                 name: Optional[str] = None) -> "TStream":
        """``self[t] != φ ? self[t] : other[t]`` (φ-aware left-join /
        imputation pattern, paper Table 2)."""
        import jax
        import jax.numpy as jnp

        def fn(a, b):
            (av, aok), (bv, bok) = a, b
            v = jax.tree_util.tree_map(
                lambda x, y: jnp.where(aok, x, y), av, bv)
            return v, aok | bok

        return TStream(ir.Map.make(fn, [self.node, other.node],
                                   phi_aware=True, prec=self.node.prec,
                                   name=name or "coalesce"))

    # -- time manipulation -----------------------------------------------------
    def shift(self, delta: int, name: Optional[str] = None,
              prec: Optional[int] = None) -> "TStream":
        return TStream(ir.Shift.make(self.node, delta, name=name, prec=prec))

    def interpolate(self, mode: str = "linear", max_gap: int = 0,
                    prec: Optional[int] = None,
                    name: Optional[str] = None) -> "TStream":
        """Gap fill / frequency change (imputation & resampling apps)."""
        return TStream(ir.Interp.make(self.node, mode=mode, max_gap=max_gap,
                                      prec=prec, name=name))

    def resample(self, new_prec: int, max_gap: int) -> "TStream":
        """Linear-interpolation resampling (paper's Chop+Select pipeline)."""
        return self.interpolate(mode="linear", max_gap=max_gap, prec=new_prec)

    # -- windows (Fig. 1d) -------------------------------------------------------
    def window(self, size: int, stride: Optional[int] = None) -> "WindowSpec":
        return WindowSpec(self, size, stride)

    @property
    def prec(self) -> int:
        return self.node.prec


@dataclasses.dataclass(frozen=True)
class WindowSpec:
    stream: TStream
    size: int
    stride: Optional[int] = None

    def reduce(self, op: Any, field: Optional[str] = None,
               name: Optional[str] = None) -> TStream:
        return TStream(ir.Reduce.make(op, self.stream.node, self.size,
                                      stride=self.stride, field=field,
                                      name=name))

    def sum(self, **kw) -> TStream:
        return self.reduce("sum", **kw)

    def count(self, **kw) -> TStream:
        return self.reduce("count", **kw)

    def mean(self, **kw) -> TStream:
        return self.reduce("mean", **kw)

    def avg(self, **kw) -> TStream:
        return self.reduce("mean", **kw)

    def stddev(self, **kw) -> TStream:
        return self.reduce("stddev", **kw)

    def max(self, **kw) -> TStream:
        return self.reduce("max", **kw)

    def min(self, **kw) -> TStream:
        return self.reduce("min", **kw)

    def rms(self, **kw) -> TStream:
        return self.reduce("rms", **kw)

    def kurtosis(self, **kw) -> TStream:
        return self.reduce("kurtosis", **kw)

    def absmax(self, **kw) -> TStream:
        return self.reduce("absmax", **kw)
