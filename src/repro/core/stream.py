"""Stream representations for TiLT-X.

Two representations of a temporal object (paper §4.1, §6.1.1):

* :class:`EventStream` — host-side sequence of events ``(start, end, payload]``.
  This is the ingestion format and the oracle-side representation used by the
  event-centric baseline SPE and by tests.

* :class:`SnapshotGrid` — device-side dense materialization of the temporal
  object on the ``TDom`` precision grid.  This is the TPU-native adaptation of
  the paper's snapshot buffer (see DESIGN.md §2): instead of storing only
  change points with data-dependent loop counters, we store the value at every
  grid tick together with a validity mask (``valid == False`` encodes the null
  value φ) and vectorize over time.

Grid convention (used consistently across the package):

* All times are integers in an abstract base unit.
* A grid is parametrized by ``t0`` (exclusive left edge), precision ``p`` and
  length ``T``.  Tick ``i`` carries the value of the temporal object at time
  ``t0 + (i + 1) * p``; i.e. the grid covers the half-open interval
  ``(t0, t0 + T*p]`` sampled at multiples of ``p``.
* An event ``(s, e, v]`` is active at time ``τ`` iff ``s < τ <= e``.
* Snapshot-buffer *hold* semantics: the value of a temporal object with
  precision ``p`` at an arbitrary time ``τ`` is the value of the latest tick at
  or before ``τ``, i.e. tick ``i = (τ - t0)//p - 1`` (invalid if ``i < 0``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["Event", "EventStream", "SnapshotGrid", "events_to_grid", "grid_to_events"]


@dataclasses.dataclass(frozen=True)
class Event:
    """A single event: payload valid on the half-open interval ``(start, end]``."""

    start: int
    end: int
    payload: Any  # scalar or dict-of-scalars

    def active_at(self, t: int) -> bool:
        return self.start < t <= self.end


class EventStream:
    """Host-side, time-ordered sequence of events (the paper's input format)."""

    def __init__(self, events: Sequence[Event], name: str = "stream"):
        self.events = sorted(events, key=lambda e: (e.start, e.end))
        self.name = name

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self):
        return iter(self.events)

    def value_at(self, t: int):
        """Oracle: payload of the event active at ``t`` or None (φ).

        With overlapping events, the *latest-starting* active event wins
        (deterministic tie-break; matches events_to_grid which writes events
        in start order so later starts overwrite).
        """
        hit = None
        for e in self.events:
            if e.active_at(t):
                hit = e.payload
        return hit

    @staticmethod
    def regular(values: Sequence[Any], period: int = 1, t0: int = 0,
                name: str = "stream") -> "EventStream":
        """Fixed-frequency signal: event ``k`` covers ``(t0+k*p, t0+(k+1)*p]``."""
        evs = [Event(t0 + k * period, t0 + (k + 1) * period, v)
               for k, v in enumerate(values)]
        return EventStream(evs, name=name)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SnapshotGrid:
    """Dense on-grid materialization of a temporal object.

    ``value`` is a pytree of arrays whose leading axis is time (length T);
    ``valid`` is a bool[T] mask (False == φ).  ``t0`` and ``prec`` are static.
    """

    value: Any           # pytree of jnp arrays, leading axis T
    valid: jax.Array     # bool[T]
    t0: int              # static
    prec: int            # static

    # -- pytree plumbing ---------------------------------------------------
    def tree_flatten(self):
        return (self.value, self.valid), (self.t0, self.prec)

    @classmethod
    def tree_unflatten(cls, aux, children):
        value, valid = children
        t0, prec = aux
        return cls(value=value, valid=valid, t0=t0, prec=prec)

    # -- helpers -----------------------------------------------------------
    @property
    def length(self) -> int:
        return int(self.valid.shape[0])

    @property
    def t_end(self) -> int:
        return self.t0 + self.length * self.prec

    def tick_time(self, i: int) -> int:
        return self.t0 + (i + 1) * self.prec

    def leaves(self):
        return jax.tree_util.tree_leaves(self.value)

    def replace(self, **kw) -> "SnapshotGrid":
        return dataclasses.replace(self, **kw)


def events_to_grid(stream: EventStream, t0: int, t_end: int, prec: int,
                   fill: float = 0.0, dtype=jnp.float32) -> SnapshotGrid:
    """Grid-snap an event stream onto ``TDom(t0, t_end, prec)``.

    Ticks with no active event get ``valid=False`` (φ).  Overlapping events:
    the latest-starting event wins (bounded-capacity multi-value snapshots are
    handled by the K_overlap variant in data/streams.py where needed).
    """
    assert (t_end - t0) % prec == 0, "grid extent must be a multiple of prec"
    T = (t_end - t0) // prec

    # Determine payload structure from the first event.
    sample = stream.events[0].payload if stream.events else 0.0
    is_dict = isinstance(sample, dict)
    keys = list(sample.keys()) if is_dict else None

    vals = {k: np.full((T,), fill, dtype=np.float64) for k in (keys or ["v"])}
    valid = np.zeros((T,), dtype=bool)

    for e in stream.events:
        # Tick i lives at time τ_i = t0 + (i+1)p; the event is active at τ_i
        # iff  s < τ_i <= e.  Hence (integer floor division, valid for
        # negatives via Python's //):
        #   first active tick:  i+1 > (s-t0)/p  ->  i = floor((s-t0)/p)
        #   last  active tick:  i+1 <= (e-t0)/p ->  i = floor((e-t0)/p) - 1
        first_i = (e.start - t0) // prec
        last_i = (e.end - t0) // prec - 1
        a = max(0, first_i)
        b = min(T - 1, last_i)
        if b < a:
            continue
        if is_dict:
            for k in keys:
                vals[k][a:b + 1] = e.payload[k]
        else:
            vals["v"][a:b + 1] = e.payload
        valid[a:b + 1] = True

    value = ({k: jnp.asarray(v, dtype=dtype) for k, v in vals.items()}
             if is_dict else jnp.asarray(vals["v"], dtype=dtype))
    return SnapshotGrid(value=value, valid=jnp.asarray(valid), t0=t0, prec=prec)


def grid_to_events(grid: SnapshotGrid) -> EventStream:
    """Change-compress a grid back into events (inverse of events_to_grid).

    Consecutive ticks with equal payload and valid=True merge into one event —
    this is the paper's snapshot-buffer compression, applied on egress.
    """
    valid = np.asarray(grid.valid)
    value = jax.tree_util.tree_map(np.asarray, grid.value)
    is_dict = isinstance(value, dict)
    T = valid.shape[0]
    events: list[Event] = []
    i = 0
    while i < T:
        if not valid[i]:
            i += 1
            continue
        j = i
        def payload_at(k):
            return ({kk: vv[k].item() for kk, vv in value.items()}
                    if is_dict else value[k].item())
        pi = payload_at(i)
        while j + 1 < T and valid[j + 1] and payload_at(j + 1) == pi:
            j += 1
        # ticks i..j  ->  times (t0 + i*p, t0 + (j+1)*p]
        events.append(Event(grid.t0 + i * grid.prec,
                            grid.t0 + (j + 1) * grid.prec, pi))
        i = j + 1
    return EventStream(events)
