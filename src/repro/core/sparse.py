"""Change-compressed sparse execution (paper §5's loop-counter trick on TPU).

TiLT's LLVM backend skips redundant work with data-dependent loop counters:
temporal expressions are only evaluated where the underlying signal actually
*changed*.  Data-dependent control flow doesn't exist on TPU, so this module
recasts the trick as a **static-shape segment gather** over the dense
snapshot grids the rest of the stack uses:

1. **Dirty masks.**  Per source, :func:`source_dirty` diffs every tick's
   ``(value, valid)`` snapshot against the previous tick (the first tick
   diffs against a carried 1-tick snapshot of the previous chunk, or is
   forced dirty at stream start).  A tick is *clean* iff the temporal
   object held its value — the same change-compression
   :func:`repro.core.stream.grid_to_events` applies on egress.  Callers may
   instead supply an explicit change-event channel (``dirty=`` argument).
2. **Dilation.**  A changed input tick at time ``t`` can only alter outputs
   in ``[t − lookahead, t + lookback]`` — the reverse image of the lineage
   interval boundary resolution computes.  :class:`repro.core.plan.ChangePlan`
   derives these spans from the existing halo contracts
   (:class:`repro.core.plan.InputSpec`), so window/interp/shift ops widen
   dirty spans by exactly the extents they demand as halo.
3. **Segment compaction.**  The chunk timeline is cut into segments of
   ``exe.out_len`` output ticks (one partition each).  A segment is dirty
   iff any dirty input tick lands in its dilated lineage — a static-index
   range query over a cumulative sum of the dirty mask.  Dirty segments are
   gathered — with their full halo windows, via the planned
   :class:`~repro.core.plan.InputSpec` contract — into a compacted buffer
   whose capacity is **bucketed to the next power of two**
   (:func:`bucket_capacity`), so at most ``log2(n_segments)+1`` distinct
   shapes ever reach jit and the executable cache stays warm however the
   change rate fluctuates between chunks.
4. **Compute + scatter.**  The fused partition body runs ``vmap``-ped over
   the compacted segments only — bit-identical inputs to what
   :func:`repro.core.parallel.partition_run` would slice for the same
   partitions — and results scatter back over the chunk.  Clean segments
   take the *hold* value: every tick of a clean segment provably equals the
   previous output tick (its whole lineage window saw zero changes, so the
   window content is shift-invariant there), hence the last tick of the
   nearest preceding dirty segment — or the carried last output at a chunk
   boundary — fills them.

Exactness: dirty segments are computed by the same traced body on
bit-identical inputs, and clean-segment holds are implied by φ-semantics,
so sparse ≡ dense *bit-for-bit on the same partitioning*; across different
partitionings the usual float-association caveat applies (exact for
integer-valued data — see repro/multiquery/__init__.py).  NaN payloads
compare unequal to themselves and are therefore always dirty
(conservative, never wrong).

When dense still wins: the sparse path adds O(T) mask/cumsum work, a
gather, and a halo's worth of recomputation per dirty segment
(``(out_len + halo) / out_len`` overhead).  At high change rates (≳50% of
segments dirty) or for halo-dominated segments (window ≫ out_len) the
compaction saves nothing and the overhead makes dense execution faster —
pick ``out_len`` a few× the deepest window and keep sparse mode for the
<10%-dirty streams it is built for (fraud, dashboards, sensor fan-out).

Layering: this module owns the change *mechanics* (dirty masks,
dilation-range arithmetic, bucketing) and the one-shot
:func:`sparse_run`; the chunked executors consume :func:`source_dirty` /
:func:`seg_ranges` / :func:`range_any` / :func:`bucket_capacity` from the
unified policy runner (:mod:`repro.engine.runner`), which composes them
with keyed vmapping, per-shard mesh compaction and union DAGs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import sparse_compact
from ..obs import default as _obs_default
from .plan import seg_range_affine
from .stream import SnapshotGrid

__all__ = ["source_dirty", "bucket_capacity", "capacity_ladder",
           "segment_mask", "sparse_run", "seg_ranges", "range_any",
           "affine_covers", "retro_segment_mask"]


# ---------------------------------------------------------------------------
# dirty masks
# ---------------------------------------------------------------------------

def source_dirty(value, valid, prev: Optional[tuple] = None) -> jax.Array:
    """Per-tick dirty mask of one source grid (time axis 0).

    Tick ``i`` is dirty iff its ``(value, valid)`` snapshot differs from
    tick ``i-1``'s.  ``prev`` is a 1-tick ``(value, valid)`` snapshot the
    first tick diffs against (the carried last tick of the previous chunk);
    with ``prev=None`` the first tick is unconditionally dirty (stream
    start).  Value comparison is raw — garbage at φ ticks counts as change
    — which is conservative and keeps the mask independent of φ encoding.
    """
    if prev is None:
        pv = jax.tree_util.tree_map(lambda x: jnp.zeros_like(x[:1]), value)
        pm = jnp.zeros((1,), bool)
    else:
        pv, pm = prev
    d = valid != jnp.concatenate([pm, valid[:-1]])
    for x, p in zip(jax.tree_util.tree_leaves(value),
                    jax.tree_util.tree_leaves(pv)):
        neq = x != jnp.concatenate([p.astype(x.dtype), x[:-1]], axis=0)
        if neq.ndim > 1:
            neq = neq.reshape(neq.shape[0], -1).any(axis=1)
        d = d | neq
    if prev is None:
        d = d.at[0].set(True)
    return d


def bucket_capacity(n: int, n_max: int) -> int:
    """Power-of-two compaction capacity ≥ ``max(n, 1)``, clipped to
    ``n_max`` — the bucketing policy that bounds the number of distinct
    shapes the jitted sparse step is traced for."""
    return min(1 << max(n - 1, 0).bit_length(), max(n_max, 1))


def capacity_ladder(n_max: int) -> list:
    """All capacities :func:`bucket_capacity` can return for ``n_max`` work
    units, ascending: ``[1, 2, 4, ..., n_max]`` (≤ log2+1 entries).

    This is the branch table of the device-resident bucket pick: with
    ``caps = capacity_ladder(n_max)``, ``jnp.searchsorted(caps, count,
    side='left')`` indexes the same bucket ``bucket_capacity(count, n_max)``
    names — but as a traced scalar, so a ``lax.switch`` over per-capacity
    branches replaces the host round-trip that used to resolve the count.
    """
    n_max = max(n_max, 1)
    caps, c = [], 1
    while c < n_max:
        caps.append(c)
        c <<= 1
    caps.append(n_max)
    return caps


# ---------------------------------------------------------------------------
# dirty-segment resolution (static index ranges + one cumsum range query)
# ---------------------------------------------------------------------------

def seg_ranges(lookback_t: int, lookahead_t: int, prec: int, grid_t0: int,
               out_t0: int, out_prec: int, seg_len: int, n_segs: int):
    """Half-open input-tick ranges ``[i_lo, i_hi1)`` per output segment: the
    input ticks whose change can dirty that segment (dilated lineage).
    Pure planning arithmetic — numpy, affine in the segment index.

    The hold rule compares each output tick to the *previous output tick*,
    one ``out_prec`` stride back, so clean ticks need the input constant
    over their whole lineage **shifted back one stride**: a dirty input
    tick at time ``t`` (its held value changes inside ``(t − prec, t]``)
    can alter outputs ``τ`` with ``t − lookahead − prec < τ <
    t + lookback + out_prec`` — both bounds open, which is what keeps the
    carried dirty tail of the chunked runners at exactly ``left_halo``
    ticks.  With integer times the open bounds become the ``±1`` below;
    for ``out_prec == prec`` this reduces to the plain lineage interval.
    """
    k = np.arange(n_segs, dtype=np.int64)
    # first output time of segment k is out_t0 + (k·S+1)·q; a dirty tick
    # affects it iff t > τ_min − lookback − q, i.e. t ≥ τ_min+1−lookback−q
    lo_t = out_t0 + k * seg_len * out_prec + 1 - lookback_t
    # last output time is out_t0 + (k+1)·S·q; affected iff t < τ_max +
    # lookahead + p, i.e. t ≤ τ_max + lookahead + p − 1
    hi_t = out_t0 + (k + 1) * seg_len * out_prec + lookahead_t + prec - 1
    i_lo = -(-(lo_t - grid_t0) // prec) - 1          # ceil_index
    i_hi1 = (hi_t - grid_t0) // prec                 # floor_index + 1
    return i_lo, i_hi1


def retro_segment_mask(lookback_t: int, lookahead_t: int, prec: int,
                       out_t0: int, out_prec: int, seg_len: int,
                       n_segs: int, times) -> np.ndarray:
    """Bool per output segment: which segments of the chunk starting at
    ``out_t0`` a *retroactive* input change at tick times ``times`` can
    dirty — :func:`seg_ranges` read the other way around, for late-data
    revision.  A changed input tick at time ``t`` (held value changes
    inside ``(t − prec, t]``) can alter outputs ``τ`` with
    ``t − lookahead − prec < τ < t + lookback + out_prec`` (both bounds
    open — the same ±1 arithmetic as :func:`seg_ranges` and the grid-edge
    hits in :func:`segment_mask`).  Pure host-side planning arithmetic:
    the revision driver resolves *which* segments to re-run with numpy,
    so the device dispatch stays transfer-free."""
    k = np.arange(n_segs, dtype=np.int64)
    tau_min = out_t0 + k * seg_len * out_prec + out_prec
    tau_max = out_t0 + (k + 1) * seg_len * out_prec
    t = np.asarray(times, dtype=np.int64).reshape(-1, 1)
    if t.size == 0:
        return np.zeros((n_segs,), bool)
    hit = ((tau_max[None, :] > t - lookahead_t - prec)
           & (tau_min[None, :] < t + lookback_t + out_prec))
    return hit.any(axis=0)


def affine_covers(affine: tuple, i_lo, i_hi1) -> np.ndarray:
    """Verifier hook: does the affine lowering ``(a0, step, width)`` (the
    form the fused change-detection kernel consumes — see
    :func:`repro.core.plan.seg_range_affine`) cover the required per-
    segment ranges ``[i_lo, i_hi1)``?  Returns a bool per segment; any
    ``False`` means some input tick whose change can dirty that segment is
    *outside* the window the kernel scans — silently stale outputs.  The
    temporal-plan verifier (:mod:`repro.analysis`) calls this with ranges
    recomputed from independently re-derived bounds."""
    a0, step, width = affine
    k = np.arange(len(np.atleast_1d(i_lo)), dtype=np.int64)
    lo = a0 + k * step
    return (lo <= np.asarray(i_lo)) & (lo + width >= np.asarray(i_hi1))


@jax.jit
def range_any(dirty: jax.Array, i_lo: jax.Array, i_hi1: jax.Array):
    """``any(dirty[i_lo[k]:i_hi1[k]])`` per segment, via one cumsum."""
    c = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                         jnp.cumsum(dirty.astype(jnp.int32))])
    L = dirty.shape[0]
    a = jnp.clip(i_lo, 0, L)
    b = jnp.clip(i_hi1, 0, L)
    return (c[b] - c[jnp.minimum(a, b)]) > 0


def _gather_starts(exe, inputs: Dict[str, SnapshotGrid], out_t0: int,
                   n_parts: int) -> Dict[str, jax.Array]:
    """Per-input start index of every segment's halo window in the supplied
    grid (may run off either end: the gather φ-pads, like ``_slice_pad``)."""
    span = exe.out_len * exe.out_prec
    starts = {}
    for name, spec in exe.input_specs.items():
        g = inputs[name]
        if g.prec != spec.prec:
            raise ValueError(f"input {name}: grid precision {g.prec} != "
                             f"planned precision {spec.prec}")
        if (out_t0 + spec.t0 - g.t0) % spec.prec:
            raise ValueError(
                f"partition window start {out_t0 + spec.t0} misaligned with "
                f"input grid (t0={g.t0}, prec={g.prec})")
        if span % spec.prec:
            # same guard partition_run hits on its k>=1 windows
            raise ValueError(
                f"input {name}: segment span {span} not a multiple of "
                f"input precision {spec.prec}")
        k = np.arange(n_parts, dtype=np.int64)
        starts[name] = jnp.asarray(
            (out_t0 + k * span + spec.t0 - g.t0) // spec.prec)
    return starts


def segment_mask(exe, inputs: Dict[str, SnapshotGrid], out_t0: int,
                 n_parts: int, dirty: Optional[Dict[str, jax.Array]] = None,
                 force_first: bool = True,
                 pallas: Optional[bool] = None) -> jax.Array:
    """Dirty mask over ``n_parts`` output segments of ``exe.out_len`` ticks.

    ``dirty`` optionally supplies explicit per-input change masks (aligned
    to each supplied grid) — the change-event-channel path; otherwise masks
    come from :func:`source_dirty` on the grids themselves.  With
    ``force_first`` the first segment is always dirty (the hold-fill base
    case when no carried output seeds the chunk).

    ``pallas`` routes the value-diff inputs through the fused
    change-detection kernel (:func:`repro.kernels.sparse_compact.seg_dirty`):
    ``None`` keeps the staged :func:`source_dirty` + :func:`range_any`
    reference, ``True``/``False`` forces the Pallas kernel / its jnp oracle.
    Bit-identical either way (asserted by the kernel tests); explicit-dirty
    inputs and non-affine lineages (segment span not a multiple of the
    input precision) always take the staged path.
    """
    cp = _change_plan(exe)
    S, q = exe.out_len, exe.out_prec
    seg = jnp.zeros((n_parts,), bool)
    k = np.arange(n_parts, dtype=np.int64)
    tau_min = out_t0 + k * S * q + q        # first output time per segment
    tau_max = out_t0 + (k + 1) * S * q      # last output time per segment
    for name, spec in exe.input_specs.items():
        g = inputs[name]
        sp = cp.specs[name]
        explicit = dirty is not None and name in dirty
        if explicit or pallas is None or (S * q) % spec.prec:
            d = dirty[name] if explicit else source_dirty(g.value, g.valid)
            i_lo, i_hi1 = seg_ranges(sp.lookback, sp.lookahead, spec.prec,
                                     g.t0, out_t0, q, S, n_parts)
            seg = seg | range_any(d, jnp.asarray(i_lo), jnp.asarray(i_hi1))
        else:
            a0, stp, width = seg_range_affine(
                sp.lookback, sp.lookahead, spec.prec, g.t0, out_t0, q, S)
            mats = sparse_compact.grid_mats(g.value, g.valid)
            seg = seg | sparse_compact.seg_dirty(
                mats, [(a0, stp, width)] * len(mats), n_parts, pallas=pallas)
            # the kernel never counts tick 0 (no diff partner); stream
            # start makes it unconditionally dirty, so the segments whose
            # dilated lineage covers index 0 flip statically
            lo = a0 + k * stp
            seg = seg | jnp.asarray((lo <= 0) & (lo + width > 0))
        # the supplied grid's edges are virtual changes: beyond-grid reads
        # are φ, so the real→φ transition one tick past the end (and the
        # φ→real transition at tick 0) enters nearby lineages — outputs
        # whose dilated lineage (open interval, as in seg_ranges) covers
        # an edge must compute, or lookahead queries would hold stale
        # values where dense execution yields φ
        for t_edge in (g.t0 + spec.prec,
                       g.t0 + (g.valid.shape[0] + 1) * spec.prec):
            hit = ((tau_max > t_edge - sp.lookahead - spec.prec)
                   & (tau_min < t_edge + sp.lookback + q))
            seg = seg | jnp.asarray(hit)
    if not exe.input_specs:
        seg = jnp.ones((n_parts,), bool)  # input-free (const) query: dense
    if force_first:
        seg = seg.at[0].set(True)
    return seg


def _change_plan(exe):
    cp = getattr(exe, "change_plan", None)
    if cp is None:
        raise ValueError(
            "query was not compiled for sparse execution — pass "
            "sparse=True to compile_query to attach a ChangePlan")
    return cp


# ---------------------------------------------------------------------------
# the staged gather → vmapped body → scatter/hold step
# ---------------------------------------------------------------------------

def _bc(mask, x):
    """Broadcast a leading-axis mask over the trailing dims of ``x``."""
    return mask.reshape(mask.shape + (1,) * (x.ndim - mask.ndim))


def _step_body(exe, n_segs: int, capacity: int):
    """The raw (unjitted) staged-step closure — see :func:`staged_step` for
    the signature.  The fused one-shot runner embeds one of these per
    capacity bucket as ``lax.switch`` branches inside a single jit."""
    names = sorted(exe.input_specs)
    specs = exe.input_specs
    S = exe.out_len

    def step(flat, starts, seg_dirty, seed_v, seed_m):
        seg_ids = jnp.nonzero(seg_dirty, size=capacity, fill_value=0)[0]
        gath = []
        for name, (v, m) in zip(names, flat):
            L = specs[name].length
            st = jnp.take(starts[name], seg_ids)            # (C,)
            idx = st[:, None] + jnp.arange(L)[None, :]      # (C, L)
            T = m.shape[0]
            ok = (idx >= 0) & (idx < T)
            idxc = jnp.clip(idx, 0, T - 1)
            gm = jnp.take(m, idxc) & ok

            def gather(x, ok=ok, idxc=idxc):
                gx = jnp.take(x, idxc, axis=0)
                return jnp.where(_bc(ok, gx), gx, jnp.zeros((), x.dtype))

            gath.append((jax.tree_util.tree_map(gather, v), gm))

        def one(*f):
            return exe.trace_fn(dict(zip(names, f)))

        out_v, out_m = jax.vmap(one)(*gath)                 # (C, S, ...)

        # scatter compacted results back over the segment axis
        pos = jnp.clip(jnp.cumsum(seg_dirty) - 1, 0, capacity - 1)
        full_v = jax.tree_util.tree_map(
            lambda x: jnp.take(x, pos, axis=0), out_v)      # (n_segs, S, ...)
        full_m = jnp.take(out_m, pos, axis=0)

        # hold fill: clean segments take the last tick of the nearest
        # preceding dirty segment, or the carried seed before any
        prev_d = jax.lax.cummax(
            jnp.where(seg_dirty, jnp.arange(n_segs), -1))
        src = jnp.clip(prev_d, 0, n_segs - 1)
        has = prev_d >= 0

        def hold(x, sv):
            hx = jnp.take(x[:, -1], src, axis=0)     # (n_segs, ...)
            return jnp.where(_bc(has, hx), hx, sv[None].astype(x.dtype))

        hv = jax.tree_util.tree_map(hold, full_v, seed_v)
        hm = jnp.where(has, jnp.take(full_m[:, -1], src), seed_m)

        ov = jax.tree_util.tree_map(
            lambda f, h: jnp.where(_bc(seg_dirty, f), f, h[:, None]),
            full_v, hv)
        om = jnp.where(seg_dirty[:, None], full_m, hm[:, None])

        ov = jax.tree_util.tree_map(
            lambda x: x.reshape((n_segs * S,) + x.shape[2:]), ov)
        om = om.reshape(n_segs * S)
        new_seed = (jax.tree_util.tree_map(lambda x: x[-1], ov), om[-1])
        return ov, om, new_seed

    return step


def _dense_body(exe, n_segs: int):
    """The full-capacity ``lax.switch`` branch: every segment computes.

    At ``capacity == n_segs`` the compaction machinery (``nonzero`` gather,
    cumsum scatter, hold fill) is pure overhead — the bucket already pays
    for every segment.  Computing the clean segments directly is
    bit-identical to holding them (a clean segment's output provably equals
    the previous output tick, which is exactly what dense evaluation of its
    unchanged lineage yields — the module-level exactness contract), so this
    branch returns the same bits as :func:`_step_body` at full capacity
    while skipping the data movement.
    """
    names = sorted(exe.input_specs)
    specs = exe.input_specs
    S = exe.out_len

    def step(flat, starts, seg_dirty, seed_v, seed_m):
        del seg_dirty, seed_v, seed_m      # every segment computes
        gath = []
        for name, (v, m) in zip(names, flat):
            L = specs[name].length
            idx = starts[name][:, None] + jnp.arange(L)[None, :]
            T = m.shape[0]
            ok = (idx >= 0) & (idx < T)
            idxc = jnp.clip(idx, 0, T - 1)
            gm = jnp.take(m, idxc) & ok

            def gather(x, ok=ok, idxc=idxc):
                gx = jnp.take(x, idxc, axis=0)
                return jnp.where(_bc(ok, gx), gx, jnp.zeros((), x.dtype))

            gath.append((jax.tree_util.tree_map(gather, v), gm))

        def one(*f):
            return exe.trace_fn(dict(zip(names, f)))

        out_v, out_m = jax.vmap(one)(*gath)                 # (n_segs, S, ...)
        ov = jax.tree_util.tree_map(
            lambda x: x.reshape((n_segs * S,) + x.shape[2:]), out_v)
        om = out_m.reshape(n_segs * S)
        new_seed = (jax.tree_util.tree_map(lambda x: x[-1], ov), om[-1])
        return ov, om, new_seed

    return step


def staged_step(exe, n_segs: int, capacity: int):
    """The jitted sparse chunk step for a fixed (segment count, compaction
    capacity) geometry — cached on the CompiledQuery so repeated chunks with
    the same bucket reuse the compiled executable.

    ``step(flat, starts, seg_dirty, seed_v, seed_m)`` takes the full input
    grids (``(value, valid)`` in sorted-name order), per-input segment start
    indices, the dirty-segment mask and a 1-tick hold seed; it returns the
    chunk output ``(value, valid)`` plus the new seed (the chunk's last
    output tick).
    """
    cache = exe.__dict__.setdefault("_sparse_step_cache", {})
    key = (n_segs, capacity)
    if key not in cache:
        cache[key] = jax.jit(_step_body(exe, n_segs, capacity))
    return cache[key]


def zero_seed(exe, flat):
    """A φ hold seed shaped like one output tick (used when no carried
    output exists; the forced-dirty first segment makes it unread)."""
    names = sorted(exe.input_specs)
    leaves, treedef = jax.tree_util.tree_flatten(flat)
    shapes = (str(treedef),
              tuple((x.shape, str(x.dtype)) for x in leaves))
    cache = exe.__dict__.setdefault("_sparse_seed_cache", {})
    if shapes not in cache:
        avals = {}
        for name, (v, m) in zip(names, flat):
            L = exe.input_specs[name].length
            avals[name] = (
                jax.tree_util.tree_map(
                    lambda x: jax.ShapeDtypeStruct((L,) + x.shape[1:],
                                                   x.dtype), v),
                jax.ShapeDtypeStruct((L,), jnp.bool_))
        out_v, out_m = jax.eval_shape(exe.trace_fn, avals)
        cache[shapes] = (
            jax.tree_util.tree_map(
                lambda a: jnp.zeros(a.shape[1:], a.dtype), out_v),
            jnp.asarray(False))
    return cache[shapes]


# ---------------------------------------------------------------------------
# entry point: the change-compressed mirror of partition_run
# ---------------------------------------------------------------------------

def _fused_run(exe, n_parts: int, out_t0: int, meta: tuple,
               dirty_names: tuple):
    """One jit for the whole one-shot sparse run: fused change detection
    (:mod:`repro.kernels.sparse_compact`), device-resident bucket pick
    (``searchsorted`` over :func:`capacity_ladder` + ``lax.switch`` over
    per-capacity staged-step bodies), gather/compute/scatter/hold — zero
    host round-trips between mask and compute.  Cached on the CompiledQuery
    per static geometry: ``meta`` is the per-input ``(t0, n_ticks, prec)``
    of the supplied grids in sorted-name order, ``dirty_names`` the inputs
    whose change masks the caller supplies explicitly."""
    cache = exe.__dict__.setdefault("_sparse_run_cache", {})
    key = (n_parts, out_t0, meta, dirty_names)
    if key in cache:
        return cache[key]
    _obs_default().tracer.record_compile(
        f"sparse_run(n_parts={n_parts},t0={out_t0})")

    cp = _change_plan(exe)
    names = sorted(exe.input_specs)
    specs = exe.input_specs
    S, q = exe.out_len, exe.out_prec

    # segment start indices are pure geometry — fold them into the cached
    # closure as jit constants instead of re-deriving them per call
    span = S * q
    starts = {}
    for name, (g_t0, T, g_prec) in zip(names, meta):
        spec = specs[name]
        if g_prec != spec.prec:
            raise ValueError(f"input {name}: grid precision {g_prec} != "
                             f"planned precision {spec.prec}")
        if (out_t0 + spec.t0 - g_t0) % spec.prec:
            raise ValueError(
                f"partition window start {out_t0 + spec.t0} misaligned with "
                f"input grid (t0={g_t0}, prec={g_prec})")
        if span % spec.prec:
            raise ValueError(
                f"input {name}: segment span {span} not a multiple of "
                f"input precision {spec.prec}")
        kk = np.arange(n_parts, dtype=np.int64)
        starts[name] = jnp.asarray(
            (out_t0 + kk * span + spec.t0 - g_t0) // spec.prec)

    k = np.arange(n_parts, dtype=np.int64)
    tau_min = out_t0 + k * S * q + q        # first output time per segment
    tau_max = out_t0 + (k + 1) * S * q      # last output time per segment
    # everything data-independent folds into one static mask: the forced
    # first segment (hold base case), grid-edge virtual changes (see
    # segment_mask), and — for value-diff inputs — stream start's
    # unconditionally-dirty tick 0, which the kernel never counts
    static = np.zeros((n_parts,), bool)
    static[0] = True
    geom, ranges = {}, {}
    for name, (g_t0, T, _prec) in zip(names, meta):
        spec, sp = specs[name], cp.specs[name]
        for t_edge in (g_t0 + spec.prec, g_t0 + (T + 1) * spec.prec):
            static |= ((tau_max > t_edge - sp.lookahead - spec.prec)
                       & (tau_min < t_edge + sp.lookback + q))
        if name in dirty_names:
            i_lo, i_hi1 = seg_ranges(sp.lookback, sp.lookahead, spec.prec,
                                     g_t0, out_t0, q, S, n_parts)
            ranges[name] = (jnp.asarray(i_lo), jnp.asarray(i_hi1))
        else:
            a0, stp, width = seg_range_affine(
                sp.lookback, sp.lookahead, spec.prec, g_t0, out_t0, q, S)
            geom[name] = (a0, stp, width)
            lo = a0 + k * stp
            static |= (lo <= 0) & (lo + width > 0)

    ladder = capacity_ladder(n_parts)
    caps = np.asarray(ladder, np.int32)
    # the full-capacity bucket (count > n_parts/2) takes the dense-all body:
    # at that point compaction saves nothing, so skip its data movement
    branches = [_step_body(exe, n_parts, c) for c in ladder[:-1]]
    branches.append(_dense_body(exe, n_parts))

    def run(flat, dmasks, seed_v, seed_m):
        seg = jnp.asarray(static)
        for name, (v, m) in zip(names, flat):
            if name in dirty_names:
                seg = seg | range_any(dmasks[name], *ranges[name])
            else:
                mats = sparse_compact.grid_mats(v, m)
                seg = seg | sparse_compact.seg_dirty(
                    mats, [geom[name]] * len(mats), n_parts)
        if not names:
            seg = jnp.ones((n_parts,), bool)  # input-free query: dense
        cnt = jnp.sum(seg.astype(jnp.int32))
        b = jnp.searchsorted(jnp.asarray(caps), cnt, side="left")
        ov, om, _ = jax.lax.switch(b, branches, flat, starts, seg,
                                   seed_v, seed_m)
        # cnt rides along as a device scalar so callers can accumulate
        # compaction telemetry without a sync
        return ov, om, cnt

    cache[key] = jax.jit(run)
    return cache[key]


def sparse_run(exe, inputs: Dict[str, SnapshotGrid], out_t0: int,
               n_parts: int, dirty: Optional[Dict[str, jax.Array]] = None,
               fused: bool = True) -> SnapshotGrid:
    """Run ``n_parts`` partitions of ``exe.out_len`` output ticks starting
    at ``out_t0`` — the change-compressed mirror of
    :func:`repro.core.parallel.partition_run`: only partitions whose dilated
    input lineage saw a change are computed; the rest hold.

    ``exe`` must be compiled with ``sparse=True``.  ``dirty`` optionally
    supplies explicit per-input change masks (one bool per tick of the
    supplied grid) in place of the value diff.

    ``fused=True`` (default) runs mask, bucket pick and compute as one jit
    with the single data-dependent decision — how many segments are dirty —
    resolved on-device (``lax.switch`` over the :func:`capacity_ladder`
    buckets), so the call issues no device→host transfer.  ``fused=False``
    keeps the three-phase staged path (mask → host-resolved
    :func:`bucket_capacity` → :func:`staged_step`) — the semantics of
    record the kernel tests assert bit-identity against.
    """
    _change_plan(exe)
    names = sorted(exe.input_specs)
    flat = [(inputs[nm].value, inputs[nm].valid) for nm in names]
    seed_v, seed_m = zero_seed(exe, flat)
    m = _obs_default()
    m.counter("sparse.runs", "one-shot sparse_run calls").add(1)
    m.counter("sparse.segments", "segments presented to sparse_run",
              "segments").add(n_parts)
    dirty_c = m.counter("sparse.dirty_segments",
                        "segments that actually computed", "segments")
    if not fused:
        starts = _gather_starts(exe, inputs, out_t0, n_parts)
        seg_dirty = segment_mask(exe, inputs, out_t0, n_parts, dirty=dirty)
        n = int(jnp.sum(seg_dirty))
        dirty_c.add(n)
        step = staged_step(exe, n_parts, bucket_capacity(n, n_parts))
        ov, om, _ = step(flat, starts, seg_dirty, seed_v, seed_m)
        return SnapshotGrid(value=ov, valid=om, t0=out_t0,
                            prec=exe.out_prec)
    meta = tuple((inputs[nm].t0, int(inputs[nm].valid.shape[0]),
                  inputs[nm].prec) for nm in names)
    dnames = tuple(sorted(set(dirty or ()) & set(names)))
    run = _fused_run(exe, n_parts, out_t0, meta, dnames)
    dmasks = {nm: dirty[nm] for nm in dnames}
    ov, om, cnt = run(flat, dmasks, seed_v, seed_m)
    dirty_c.add(cnt)  # lazy device add — no sync until snapshot()
    return SnapshotGrid(value=ov, valid=om, t0=out_t0, prec=exe.out_prec)
