"""The paper's benchmark applications (Table 2 + Appendix A) in both engines.

Each application factory returns an :class:`App` carrying:
* ``query``      — the TiLT query (frontend → IR),
* ``spe``        — the equivalent EventSPE pipeline (Trill stand-in),
* ``make_input`` — synthetic data generator matching the paper's datasets
  (random floats at fixed frequency; random-walk prices for NYSE; synthetic
  ECG; etc.),
* dataset/time-scale metadata.

Window sizes follow the paper's descriptions (Appendix A); time unit = one
input tick (the generators produce fixed-frequency streams, e.g. the paper's
1000 Hz synthetic signal ⇒ 1 tick = 1 ms).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from ..core.frontend import TStream
from ..spe import eventspe as es

__all__ = ["App", "APPS", "KEYED_APPS", "make_app", "make_keyed_app",
           "temporal_op", "TEMPORAL_OPS", "dashboard_queries",
           "dashboard_input", "dashboard_keyed_input"]


@dataclasses.dataclass
class App:
    name: str
    query: TStream               # TiLT IR
    spe: es.Pipeline             # event-centric baseline
    make_input: Callable[[int, int], dict]   # (n_events, seed) -> {name: np arrays}
    input_prec: int = 1
    description: str = ""
    # keyed variant: (n_keys, n_ticks, seed) -> {name: {"value": (K,T[,...]),
    # "valid": (K,T)}} — the per-key sub-stream scenario (engine/keyed.py);
    # query sources then carry keyed=True.
    make_keyed_input: Optional[Callable[[int, int, int], dict]] = None


def _randwalk(n, seed, mu=100.0, sigma=0.05):
    rng = np.random.default_rng(seed)
    return (mu + np.cumsum(rng.normal(0, sigma, n))).astype(np.float64)


def _signal(n, seed, missing=0.0):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, n)
    valid = rng.random(n) >= missing
    return x, valid


def _dense_input(x, valid=None):
    n = len(x)
    return {"ts": np.arange(1, n + 1, dtype=np.int64),
            "value": np.asarray(x, np.float64),
            "valid": np.ones(n, bool) if valid is None else valid}


# ---------------------------------------------------------------------------
# 1. Trend-based trading (Fig. 2a): Avg(2), Join, Where
# ---------------------------------------------------------------------------

def trend_app(short: int = 20, long: int = 50, keyed: bool = False) -> App:
    s = TStream.source("in", prec=1, keyed=keyed)
    q = (s.window(short).mean()
         .join(s.window(long).mean(), lambda a, b: a - b, name="diff")
         .where(lambda d: d > 0, name="uptrend"))

    spe = es.Pipeline([
        (es.WindowAgg("mean", short), ("in",), "a_s"),
        (es.WindowAgg("mean", long), ("in",), "a_l"),
        (es.Join(lambda a, b: a - b), ("a_s", "a_l"), "diff"),
        (es.Where(lambda d: d > 0), ("diff",), "out"),
    ])

    def mk_keyed(n_keys, n_ticks, seed):
        rng = np.random.default_rng(seed)
        walks = 100.0 + np.cumsum(
            rng.normal(0, 0.05, (n_keys, n_ticks)), axis=1)
        return {"in": {"value": walks.astype(np.float64),
                       "valid": np.ones((n_keys, n_ticks), bool)}}

    return App("trend", q, spe,
               lambda n, seed: {"in": _dense_input(_randwalk(n, seed))},
               description="moving-average trend, NYSE-style prices",
               make_keyed_input=mk_keyed)


# ---------------------------------------------------------------------------
# 2. Relative strength index: Shift, Join, Avg(2)
# ---------------------------------------------------------------------------

def rsi_app(period: int = 14) -> App:
    s = TStream.source("in", prec=1)
    delta = s.join(s.shift(1), lambda x, px: x - px, name="delta")
    gain = delta.select(lambda d: jnp.maximum(d, 0.0), name="gain")
    loss = delta.select(lambda d: jnp.maximum(-d, 0.0), name="loss")
    ag = gain.window(period).mean()
    al = loss.window(period).mean()
    q = ag.join(al, lambda g, l: 100.0 - 100.0 / (1.0 + g / jnp.maximum(l, 1e-9)),
                name="rsi")

    spe = es.Pipeline([
        (es.ShiftOp(1), ("in",), "prev"),
        (es.Join(lambda x, p: x - p), ("in", "prev"), "delta"),
        (es.Select(lambda d: np.maximum(d, 0.0)), ("delta",), "gain"),
        (es.Select(lambda d: np.maximum(-d, 0.0)), ("delta",), "loss"),
        (es.WindowAgg("mean", period), ("gain",), "ag"),
        (es.WindowAgg("mean", period), ("loss",), "al"),
        (es.Join(lambda g, l: 100.0 - 100.0 / (1.0 + g / np.maximum(l, 1e-9))),
         ("ag", "al"), "out"),
    ])
    return App("rsi", q, spe,
               lambda n, seed: {"in": _dense_input(_randwalk(n, seed))},
               description="relative strength index momentum")


# ---------------------------------------------------------------------------
# 3. Normalization: Avg, StdDev, Join (z-score per tumbling window)
# ---------------------------------------------------------------------------

def znorm_app(win: int = 10) -> App:
    s = TStream.source("in", prec=1)
    # shift(-(win-1)) + hold-alignment broadcasts each tumbling window's
    # stats onto the ticks of that same window (t+win-1 floors to the
    # window-end tick for every t in the window).
    mu = s.window(win, stride=win).mean().shift(-(win - 1), prec=1)
    sd = s.window(win, stride=win).stddev().shift(-(win - 1), prec=1)
    q = TStream.zip([s, mu, sd],
                    lambda x, m, d: (x - m) / jnp.maximum(d, 1e-9),
                    prec=1, name="znorm")

    spe = es.Pipeline([
        (es.WindowAgg("mean", win, stride=win), ("in",), "mu"),
        (es.WindowAgg("stddev", win, stride=win), ("in",), "sd"),
        (_SpeZnormJoin(win), ("in", "mu", "sd"), "out"),
    ])
    return App("znorm", q, spe,
               lambda n, seed: {"in": _dense_input(_signal(n, seed)[0])},
               description="z-score normalization, 10-tick tumbling window")


class _SpeZnormJoin(es.Operator):
    """3-way join assigning each event the stats of its own window.

    (The event-centric engine needs a *custom* operator here — the exact
    kind of inflexibility §3 attributes to fixed operator vocabularies.)
    """

    def __init__(self, win: int):
        self.win = win

    def __call__(self, xb, mub, sdb):
        # window containing tick t ends at ceil(t/win)*win
        wend = ((xb.ts + self.win - 1) // self.win) * self.win
        idx = np.searchsorted(mub.ts, wend)
        idx_ok = idx < len(mub.ts)
        idx_c = np.clip(idx, 0, max(len(mub.ts) - 1, 0))
        mu = np.asarray(mub.value)[idx_c]
        sd = np.asarray(sdb.value)[idx_c]
        ok = xb.valid & idx_ok & mub.valid[idx_c] & sdb.valid[idx_c]
        val = (np.asarray(xb.value) - mu) / np.maximum(sd, 1e-9)
        return es.Batch(xb.ts, val, ok)


# ---------------------------------------------------------------------------
# 4. Signal imputation: Avg, Shift, Join (fill gaps with window mean)
# ---------------------------------------------------------------------------

def impute_app(win: int = 10) -> App:
    s = TStream.source("in", prec=1)
    mu = s.window(win, stride=win).mean().shift(-(win - 1), prec=1)
    q = s.coalesce(mu, name="imputed")

    spe = es.Pipeline([
        (es.WindowAgg("mean", win, stride=win), ("in",), "mu"),
        (_SpeImputeJoin(win), ("in", "mu"), "out"),
    ])

    def mk(n, seed):
        x, valid = _signal(n, seed, missing=0.1)
        return {"in": _dense_input(x, valid)}

    return App("impute", q, spe, mk,
               description="fill missing samples with window mean (1000 Hz)")


class _SpeImputeJoin(es.Operator):
    def __init__(self, win: int):
        self.win = win

    def __call__(self, xb, mub):
        wend = ((xb.ts + self.win - 1) // self.win) * self.win
        idx = np.searchsorted(mub.ts, wend)
        idx_ok = idx < len(mub.ts)
        idx_c = np.clip(idx, 0, max(len(mub.ts) - 1, 0))
        mu = np.asarray(mub.value)[idx_c]
        mu_ok = idx_ok & mub.valid[idx_c]
        val = np.where(xb.valid, np.asarray(xb.value), mu)
        return es.Batch(xb.ts, val, xb.valid | mu_ok)


# ---------------------------------------------------------------------------
# 5. Resampling: Select, Join, Shift, Chop  (linear interpolation)
# ---------------------------------------------------------------------------

def resample_app(out_prec: int = 4, max_gap: int = 16) -> App:
    # e.g. 1000 Hz -> 250 Hz with linear interpolation
    s = TStream.source("in", prec=1)
    q = s.resample(out_prec, max_gap=max_gap)

    spe = es.Pipeline([
        (es.InterpOp(1, out_prec, max_gap), ("in",), "out"),
    ])

    def mk(n, seed):
        x, valid = _signal(n, seed, missing=0.05)
        return {"in": _dense_input(x, valid)}

    return App("resample", q, spe, mk,
               description="linear-interpolation resampling 1000→250 Hz")


# ---------------------------------------------------------------------------
# 6. Pan-Tompkins QRS detection: Custom-Agg(3), Select, Avg
# ---------------------------------------------------------------------------

def pantomkins_app(fs: int = 200) -> App:
    """Streaming Pan-Tompkins (derivative → square → MWI → adaptive
    threshold via trailing-max custom agg; see Appendix A)."""
    mwi_w = int(0.150 * fs)   # 150 ms moving-window integration
    thr_w = 2 * fs            # 2 s trailing max for the adaptive threshold
    s = TStream.source("in", prec=1)
    deriv = s.join(s.shift(1), lambda x, px: x - px, name="deriv")
    sq = deriv.select(lambda d: d * d, name="square")
    mwi = sq.window(mwi_w).mean()
    thr = mwi.window(thr_w).max().select(lambda m: 0.5 * m, name="thr")
    q = mwi.join(thr, lambda sig, th: sig - th, name="qrs") \
           .where(lambda d: d > 0, name="qrs_hit")

    spe = es.Pipeline([
        (es.ShiftOp(1), ("in",), "prev"),
        (es.Join(lambda x, p: x - p), ("in", "prev"), "deriv"),
        (es.Select(lambda d: d * d), ("deriv",), "sq"),
        (es.WindowAgg("mean", mwi_w), ("sq",), "mwi"),
        (es.WindowAgg("max", thr_w), ("mwi",), "mx"),
        (es.Select(lambda m: 0.5 * m), ("mx",), "thr"),
        (es.Join(lambda s_, t: s_ - t), ("mwi", "thr"), "d"),
        (es.Where(lambda d: d > 0), ("d",), "out"),
    ])

    def mk(n, seed):
        rng = np.random.default_rng(seed)
        t = np.arange(n) / fs
        ecg = (0.1 * np.sin(2 * np.pi * 1.0 * t)
               + 1.2 * (np.sin(2 * np.pi * 1.2 * t) ** 63)  # QRS-ish spikes
               + 0.05 * rng.normal(0, 1, n))
        return {"in": _dense_input(ecg)}

    return App("pantomkins", q, spe, mk,
               description="QRS detection on synthetic ECG (MIMIC-III style)")


# ---------------------------------------------------------------------------
# 7. Vibration analysis: Max, Avg(2), Join(2), Custom-Agg
# ---------------------------------------------------------------------------

def vibration_app(win: int = 100) -> App:
    """kurtosis + RMS + crest factor over a tumbling window (100 ticks =
    100 ms at the paper's bearing-sensor rates)."""
    s = TStream.source("in", prec=1)
    kurt = s.window(win, stride=win).kurtosis()
    rms = s.window(win, stride=win).rms()
    amax = s.window(win, stride=win).absmax()
    crest = amax.join(rms, lambda a, r: a / jnp.maximum(r, 1e-9), name="crest")
    q = TStream.zip([kurt, rms, crest],
                    lambda k, r, c: {"kurtosis": k, "rms": r, "crest": c},
                    name="vib")

    spe = es.Pipeline([
        (es.WindowAgg("kurtosis", win, stride=win), ("in",), "k"),
        (es.WindowAgg("rms", win, stride=win), ("in",), "r"),
        (es.WindowAgg("absmax", win, stride=win), ("in",), "m"),
        (es.Join(lambda a, r: a / np.maximum(r, 1e-9)), ("m", "r"), "c"),
        (_SpeZip3(), ("k", "r", "c"), "out"),
    ])

    def mk(n, seed):
        rng = np.random.default_rng(seed)
        x = rng.normal(0, 1, n) + 0.5 * np.sin(np.arange(n) * 0.1)
        x[rng.random(n) < 0.001] *= 8.0  # bearing impacts
        return {"in": _dense_input(x)}

    return App("vibration", q, spe, mk,
               description="kurtosis/RMS/crest-factor machine monitoring")


# ---------------------------------------------------------------------------
# 8. Fraud detection: Avg, StdDev, Shift, Join
# ---------------------------------------------------------------------------

class _SpeZip3(es.Operator):
    def __call__(self, kb, rb, cb):
        return es.Batch(kb.ts, {"kurtosis": np.asarray(kb.value),
                                "rms": np.asarray(rb.value),
                                "crest": np.asarray(cb.value)},
                        kb.valid & rb.valid & cb.valid)


def fraud_app(win: int = 1000, keyed: bool = False) -> App:
    """Flag transactions above μ+3σ of the *trailing* window (shifted one
    tick so current transactions don't mask themselves)."""
    s = TStream.source("in", prec=1, keyed=keyed)
    mu = s.window(win).mean().shift(1)
    sd = s.window(win).stddev().shift(1)
    thr = mu.join(sd, lambda m, d: m + 3.0 * d, name="thr")
    q = s.join(thr, lambda x, t: x - t, name="excess") \
         .where(lambda e: e > 0, name="fraud")

    spe = es.Pipeline([
        (es.WindowAgg("mean", win), ("in",), "mu"),
        (es.WindowAgg("stddev", win), ("in",), "sd"),
        (es.ShiftOp(1), ("mu",), "mu1"),
        (es.ShiftOp(1), ("sd",), "sd1"),
        (es.Join(lambda m, d: m + 3.0 * d), ("mu1", "sd1"), "thr"),
        (es.Join(lambda x, t: x - t), ("in", "thr"), "ex"),
        (es.Where(lambda e: e > 0), ("ex",), "out"),
    ])

    def mk(n, seed):
        rng = np.random.default_rng(seed)
        amt = rng.lognormal(3.0, 1.0, n)
        amt[rng.random(n) < 0.002] *= 50.0  # injected fraud
        return {"in": _dense_input(amt)}

    def mk_keyed(n_keys, n_ticks, seed):
        rng = np.random.default_rng(seed)
        amt = rng.lognormal(3.0, 1.0, (n_keys, n_ticks))
        amt[rng.random((n_keys, n_ticks)) < 0.002] *= 50.0  # per-user fraud
        # sparse per-user activity: not every user transacts every tick
        valid = rng.random((n_keys, n_ticks)) > 0.3
        return {"in": {"value": amt, "valid": valid}}

    return App("fraud", q, spe, mk,
               description="credit-card anomaly flagging (Kaggle-style)",
               make_keyed_input=mk_keyed)


# ---------------------------------------------------------------------------
# Yahoo Streaming Benchmark: Select, Where, tumbling-window count
# ---------------------------------------------------------------------------

def ysb_app(win: int = 10, keyed: bool = False) -> App:
    s = TStream.source("in", prec=1, keyed=keyed)
    views = s.where(lambda v: v["etype"] == 1.0, name="views")
    q = views.window(win, stride=win).count(field="etype", name="cnt")

    spe = es.Pipeline([
        (es.Where(lambda v: v["etype"] == 1.0), ("in",), "views"),
        (_SpeDictCount(win), ("views",), "out"),
    ])

    def mk(n, seed):
        rng = np.random.default_rng(seed)
        etype = (rng.integers(0, 3, n) == 1).astype(np.float64)
        camp = rng.integers(0, 100, n).astype(np.float64)
        return {"in": {"ts": np.arange(1, n + 1, dtype=np.int64),
                       "value": {"etype": etype, "camp": camp},
                       "valid": np.ones(n, bool)}}

    def mk_keyed(n_keys, n_ticks, seed):
        # one sub-stream per ad campaign (the benchmark's natural key)
        rng = np.random.default_rng(seed)
        sh = (n_keys, n_ticks)
        etype = (rng.integers(0, 3, sh) == 1).astype(np.float64)
        camp = np.broadcast_to(
            np.arange(n_keys, dtype=np.float64)[:, None], sh).copy()
        return {"in": {"value": {"etype": etype, "camp": camp},
                       "valid": np.ones(sh, bool)}}

    return App("ysb", q, spe, mk,
               description="Yahoo streaming benchmark (filter+project+count)",
               make_keyed_input=mk_keyed)


class _SpeDictCount(es.Operator):
    def __init__(self, win):
        self.agg = es.WindowAgg("count", win, stride=win)

    def reset(self):
        self.agg.reset()

    def __call__(self, b):
        return self.agg(es.Batch(b.ts, np.asarray(b.value["etype"]), b.valid))


APPS = {
    "trend": trend_app,
    "rsi": rsi_app,
    "znorm": znorm_app,
    "impute": impute_app,
    "resample": resample_app,
    "pantomkins": pantomkins_app,
    "vibration": vibration_app,
    "fraud": fraud_app,
    "ysb": ysb_app,
}


def make_app(name: str, **kw) -> App:
    return APPS[name](**kw)


# apps with a keyed (partitioned-stream) variant: engine/keyed.py scenario
KEYED_APPS = ("trend", "fraud", "ysb")


def make_keyed_app(name: str, **kw) -> App:
    """App with sources marked keyed=True and a (K, T) input generator."""
    if name not in KEYED_APPS:
        raise KeyError(f"{name} has no keyed variant (have {KEYED_APPS})")
    return APPS[name](keyed=True, **kw)


# ---------------------------------------------------------------------------
# dashboard fan-out: N query variants over shared windowed aggregates
# (the multi-query sharing workload — repro.multiquery)
# ---------------------------------------------------------------------------

def _dash_trend_up(fast, slow, thr):
    return (fast.join(slow, lambda a, b: a - b, name="dash_diff")
            .where(lambda d, t=thr: d > t, name=f"up_{thr}"))


def _dash_trend_down(fast, slow, thr):
    return (fast.join(slow, lambda a, b: a - b, name="dash_diff")
            .where(lambda d, t=thr: d < -t, name=f"down_{thr}"))


def _dash_breakout(s, slow, vol, k):
    """Fraud-style band breakout: price above μ_long + k·σ_long."""
    return (TStream.zip([s, slow, vol],
                        lambda x, m, v, k=k: x - (m + k * v),
                        name=f"excess_{k}")
            .where(lambda e: e > 0, name="breakout"))


def _dash_momentum(fast, slow, vol, scale):
    """Projection head: volatility-normalized momentum (no threshold)."""
    return TStream.zip([fast, slow, vol],
                       lambda a, b, v, s=scale: s * (a - b)
                       / jnp.maximum(v, 1e-6),
                       name=f"momentum_{scale}")


def dashboard_queries(n: int = 16, short: int = 20, long: int = 50,
                      keyed: bool = False) -> dict:
    """``n`` concurrent dashboard variants over one source: every query
    reads the same short/long sliding means and long sliding stddev and
    differs only in its final threshold / projection head — the
    serving-layer fan-out scenario where multi-query sharing collapses N
    passes over the stream into one.

    Returns ``{query_name: TStream}``.  Note the aggregates are deliberately
    rebuilt *per query* — structural fingerprinting (ir.fingerprint), not
    object sharing, is what the session relies on to deduplicate them.
    """
    out = {}
    for i in range(n):
        # fresh sub-expressions per query: sharing must be discovered
        s = TStream.source("in", prec=1, keyed=keyed)
        fast = s.window(short).mean()
        slow = s.window(long).mean()
        vol = s.window(long).stddev()
        thr = 0.05 * (i // 4)
        kind = i % 4
        if kind == 0:
            q = _dash_trend_up(fast, slow, thr)
        elif kind == 1:
            q = _dash_trend_down(fast, slow, thr)
        elif kind == 2:
            q = _dash_breakout(s, slow, vol, 1.0 + thr)
        else:
            q = _dash_momentum(fast, slow, vol, 1.0 + thr)
        out[f"q{i:02d}"] = q
    return out


def dashboard_input(n_ticks: int, seed: int) -> dict:
    """Random-walk price stream for the dashboard fan-out (unkeyed)."""
    return {"in": _dense_input(_randwalk(n_ticks, seed))}


def dashboard_keyed_input(n_keys: int, n_ticks: int, seed: int) -> dict:
    """Per-symbol random walks, (K, T) — the keyed dashboard scenario."""
    rng = np.random.default_rng(seed)
    walks = 100.0 + np.cumsum(
        rng.normal(0, 0.05, (n_keys, n_ticks)), axis=1)
    return {"in": {"value": walks.astype(np.float64),
                   "valid": np.ones((n_keys, n_ticks), bool)}}


# ---------------------------------------------------------------------------
# the four primitive temporal operations (Fig. 1 / Fig. 7a)
# ---------------------------------------------------------------------------

def temporal_op(name: str) -> App:
    s = TStream.source("in", prec=1)
    if name == "select":
        q = s.select(lambda v: v + 1.0)
        spe = es.Pipeline([(es.Select(lambda v: v + 1.0), ("in",), "out")])
    elif name == "where":
        q = s.where(lambda v: v % 2 == 0)
        spe = es.Pipeline([(es.Where(lambda v: v % 2 == 0), ("in",), "out")])
    elif name == "wsum":
        q = s.window(10, stride=5).sum()
        spe = es.Pipeline([(es.WindowAgg("sum", 10, 5), ("in",), "out")])
    elif name == "join":
        t = TStream.source("in2", prec=1)
        q = s.join(t, lambda a, b: a + b)
        spe = es.Pipeline([(es.Join(lambda a, b: a + b), ("in", "in2"), "out")])
    else:  # pragma: no cover
        raise KeyError(name)

    def mk(n, seed):
        rng = np.random.default_rng(seed)
        d = {"in": _dense_input(np.floor(rng.random(n) * 100))}
        if name == "join":
            e = _dense_input(np.floor(rng.random(n) * 100))
            e["valid"] = rng.random(n) > 0.3  # irregular second stream
            d["in2"] = e
        return d

    return App(name, q, spe, mk, description=f"primitive op {name}")


TEMPORAL_OPS = ("select", "where", "wsum", "join")
