"""Deterministic, resumable synthetic data pipeline.

Production shape: the pipeline is a pure function of (seed, step), so

* any worker can regenerate any batch (no coordination state),
* restart-exactly is trivial: the checkpoint stores only ``step``,
* elastic re-sharding changes nothing (batches are generated globally and
  sharded by device_put, matching how a real tokenized-shard reader would
  hand out per-host slices).

Also hosts the TiLT-preprocessing integration: ``StreamFeaturePipeline``
runs a compiled TiLT query as the feature extractor over a raw signal
stream and emits model-ready batches — the paper's engine as the data
plane of the training framework (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig

__all__ = ["TokenPipeline", "StreamFeaturePipeline"]


@dataclasses.dataclass
class TokenPipeline:
    """Synthetic LM token batches (B, S) with next-token labels."""

    cfg: ModelConfig
    batch: int
    seq: int
    seed: int = 0
    step: int = 0

    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def restore(self, state: dict):
        self.step = int(state["step"])
        self.seed = int(state["seed"])

    def next(self) -> Dict[str, jax.Array]:
        rng = np.random.default_rng((self.seed << 20) + self.step)
        # every token is emitted twice in a row: the second occurrence is
        # exactly predictable, so CE has a clean learnable floor ≈ ½·ln V
        base = rng.integers(0, self.cfg.vocab,
                            (self.batch, self.seq // 2 + 1))
        toks = np.repeat(base, 2, axis=1)[:, :self.seq + 1]
        self.step += 1
        batch = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
                 "labels": jnp.asarray(toks[:, 1:], jnp.int32)}
        if self.cfg.family == "encdec":
            frames = rng.normal(
                0, 1, (self.batch, self.cfg.enc_seq, self.cfg.d_model))
            batch["frames"] = jnp.asarray(frames, jnp.float32)
        return batch


@dataclasses.dataclass
class StreamFeaturePipeline:
    """TiLT query as a training-data feature extractor.

    Wraps a compiled TiLT query + a raw-signal generator; each ``next()``
    advances the continuous StreamRunner one partition and returns the
    (values, validity) features.  The runner tail state is checkpointable
    (train/checkpoint.py stores it in the manifest) so feature extraction
    resumes exactly after restart.
    """

    exe: object          # core.compile.CompiledQuery
    gen_seed: int = 0
    step: int = 0

    def __post_init__(self):
        from ..core.parallel import StreamRunner
        self.runner = StreamRunner(self.exe)

    def state(self) -> dict:
        return {"step": self.step, "runner": self.runner.state()}

    def restore(self, state: dict):
        self.step = int(state["step"])
        self.runner.restore(state["runner"])

    def next(self):
        from ..core.stream import SnapshotGrid
        rng = np.random.default_rng((self.gen_seed << 20) + self.step)
        chunks = {}
        for name, spec in self.exe.input_specs.items():
            core = (self.exe.out_len * self.exe.out_prec) // spec.prec
            vals = rng.normal(0, 1, core).astype(np.float32)
            chunks[name] = SnapshotGrid(
                value=jnp.asarray(vals),
                valid=jnp.ones((core,), bool), t0=0, prec=spec.prec)
        self.step += 1
        return self.runner.step(chunks)
