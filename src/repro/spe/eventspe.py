"""Event-centric interpreted SPE baseline (the paper's comparison target).

A faithful stand-in for Trill's design [Chandramouli et al., VLDB'15] at the
granularity this reproduction needs:

* **event-centric**: operators transform batches of discrete events
  ``(ts, payload, valid)``; the time semantics live in runtime event
  timestamps, not in the representation (paper §3's core criticism).
* **columnar micro-batches**: payload columns are numpy arrays, and each
  operator is vectorized *within* a batch (Trill's columnar batching) but
  materializes its full output before the next operator runs
  (operator-at-a-time, message-queue hand-off).
* **interpreted**: the query is a DAG of operator objects walked at runtime;
  no cross-operator fusion, no codegen.

Operators keep per-instance state across batches (window ring buffers, shift
carries) exactly like a streaming iterator-model engine.  Batch size is the
latency/throughput knob measured in the paper's Fig. 9.

Fidelity notes (recorded for the benchmark write-up): Trill is C# with
managed-runtime overhead; our baseline is numpy, which is *faster* than an
event-at-a-time managed loop — so measured TiLT/EventSPE ratios are a
conservative *lower bound* on the paper's Trill speedups.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

__all__ = [
    "Batch", "Operator", "Source", "Select", "Where", "ShiftOp", "WindowAgg",
    "Join", "Coalesce", "InterpOp", "Pipeline",
]


@dataclasses.dataclass
class Batch:
    """A columnar micro-batch of events on a regular time grid.

    ``ts`` are the event *end* timestamps (grid convention: tick time), and
    ``valid`` marks null events (φ) — Trill likewise carries deleted rows in
    its batches via a bitvector.
    """

    ts: np.ndarray      # int64[n]
    value: object       # np.ndarray[n] or dict[str, np.ndarray[n]]
    valid: np.ndarray   # bool[n]

    def __len__(self):
        return len(self.ts)


class Operator:
    """Base: stateful stream operator consuming/producing batches."""

    def reset(self):
        pass

    def __call__(self, batch: Batch) -> Batch:  # pragma: no cover
        raise NotImplementedError


class Source(Operator):
    def __call__(self, batch: Batch) -> Batch:
        return batch


class Select(Operator):
    def __init__(self, fn: Callable):
        self.fn = fn

    def __call__(self, b: Batch) -> Batch:
        return Batch(b.ts, self.fn(b.value), b.valid.copy())


class Where(Operator):
    def __init__(self, pred: Callable):
        self.pred = pred

    def __call__(self, b: Batch) -> Batch:
        keep = np.asarray(self.pred(b.value)) & b.valid
        return Batch(b.ts, b.value, keep)


class ShiftOp(Operator):
    """Delay events by ``delta`` ticks (carries a cross-batch tail)."""

    def __init__(self, delta_ticks: int):
        assert delta_ticks >= 0
        self.d = delta_ticks
        self.reset()

    def reset(self):
        self._tail_v: Optional[object] = None
        self._tail_m: Optional[np.ndarray] = None

    def __call__(self, b: Batch) -> Batch:
        d = self.d
        if d == 0:
            return b
        n = len(b)
        if self._tail_v is None:
            self._tail_v = _zeros_like_cols(b.value, d)
            self._tail_m = np.zeros(d, bool)
        v = _concat_cols(self._tail_v, b.value)
        m = np.concatenate([self._tail_m, b.valid])
        out_v = _slice_cols(v, 0, n)
        out_m = m[:n]
        self._tail_v = _slice_cols(v, n, n + d)
        self._tail_m = m[n:n + d]
        return Batch(b.ts, out_v, out_m)


class WindowAgg(Operator):
    """Sliding/tumbling window aggregate over a regular stream.

    Maintains a ring of the trailing ``W-1`` ticks; per batch, aggregates are
    computed columnar over ``sliding_window_view`` (max/min/kurtosis) or
    cumulative sums (sum/mean/stddev/rms) — the typical incremental-agg
    implementations of event-centric engines, vectorized per batch.
    Emits one event per ``stride`` ticks (event ts = window end).
    """

    def __init__(self, op: str, window: int, stride: int = 1):
        self.op, self.W, self.stride = op, window, stride
        self.reset()

    def reset(self):
        self._tail_v: Optional[np.ndarray] = None
        self._tail_m: Optional[np.ndarray] = None
        self._tick = 0  # absolute tick index of next input element

    def __call__(self, b: Batch) -> Batch:
        W = self.W
        x = np.asarray(b.value, dtype=np.float64)
        m = b.valid
        if self._tail_v is None:
            self._tail_v = np.zeros(W - 1)
            self._tail_m = np.zeros(W - 1, bool)
        xa = np.concatenate([self._tail_v, np.where(m, x, 0.0)])
        ma = np.concatenate([self._tail_m, m])
        n = len(b)
        # output positions: absolute ticks t in [tick, tick+n) with
        # (t+1) % stride == 0
        t0 = self._tick
        pos = np.arange(n)[(t0 + np.arange(n) + 1) % self.stride == 0]
        out_ts = b.ts[pos]
        win = np.lib.stride_tricks.sliding_window_view(xa, W)[pos]
        wm = np.lib.stride_tricks.sliding_window_view(ma, W)[pos]
        cnt = wm.sum(axis=1)
        ok = cnt > 0
        cntc = np.maximum(cnt, 1)
        if self.op == "sum":
            val = win.sum(axis=1)
        elif self.op == "mean":
            val = win.sum(axis=1) / cntc
        elif self.op == "stddev":
            mu = win.sum(axis=1) / cntc
            val = np.sqrt(np.maximum((win**2).sum(axis=1) / cntc - mu**2, 0))
        elif self.op == "rms":
            val = np.sqrt((win**2).sum(axis=1) / cntc)
        elif self.op == "max":
            val = np.where(wm, win, -np.inf).max(axis=1)
        elif self.op == "min":
            val = np.where(wm, win, np.inf).min(axis=1)
        elif self.op == "absmax":
            val = np.where(wm, np.abs(win), -np.inf).max(axis=1)
        elif self.op == "kurtosis":
            mu1 = win.sum(1) / cntc
            m2 = (win**2).sum(1) / cntc - mu1**2
            m4 = ((win**4).sum(1) / cntc - 4 * mu1 * (win**3).sum(1) / cntc
                  + 6 * mu1**2 * (win**2).sum(1) / cntc - 3 * mu1**4)
            val = m4 / np.maximum(m2 * m2, 1e-30)
        elif self.op == "count":
            val = cnt.astype(np.float64)
        else:  # pragma: no cover
            raise KeyError(self.op)
        self._tail_v = xa[len(xa) - (W - 1):]
        self._tail_m = ma[len(ma) - (W - 1):]
        self._tick += n
        return Batch(out_ts, val, ok)


class Join(Operator):
    """Strict-overlap temporal join of two aligned regular streams.

    Events join when both sides are valid at the same tick (searchsorted
    timestamp alignment — the hash-on-interval equivalent for grid streams).
    """

    def __init__(self, fn: Callable):
        self.fn = fn

    def __call__(self, left: Batch, right: Batch) -> Batch:
        # align right events onto left timestamps (hold semantics)
        idx = np.searchsorted(right.ts, left.ts, side="right") - 1
        ok_idx = idx >= 0
        idx_c = np.clip(idx, 0, len(right.ts) - 1)
        rv = _take_cols(right.value, idx_c)
        rm = right.valid[idx_c] & ok_idx
        ok = left.valid & rm
        return Batch(left.ts, self.fn(left.value, rv), ok)


class Coalesce(Operator):
    def __call__(self, left: Batch, right: Batch) -> Batch:
        idx = np.clip(np.searchsorted(right.ts, left.ts, side="right") - 1,
                      0, len(right.ts) - 1)
        rv = _take_cols(right.value, idx)
        rm = right.valid[idx]
        val = np.where(left.valid, np.asarray(left.value), np.asarray(rv))
        return Batch(left.ts, val, left.valid | rm)


class InterpOp(Operator):
    """Linear-interpolation resampling onto a new tick period.

    Lookahead operator: output ticks within ``max_gap`` of the watermark
    (latest seen timestamp) are withheld until the next batch (or
    :meth:`flush`) provides their right-hand neighbour — the cross-batch
    state an event-centric engine must hand-manage for every such operator.
    """

    def __init__(self, in_prec: int, out_prec: int, max_gap: int):
        self.p, self.q, self.g = in_prec, out_prec, max_gap
        self.reset()

    def reset(self):
        self._tail_ts = np.zeros(0, np.int64)   # valid events ≤ g behind hi
        self._tail_x = np.zeros(0)
        self._next_out = self.q                 # next output tick to emit

    def _emit(self, ts_v, xs, upto: int) -> Batch:
        out_ts = np.arange(self._next_out, upto + 1, self.q)
        self._next_out = (out_ts[-1] + self.q) if len(out_ts) else self._next_out
        if len(ts_v) == 0:
            return Batch(out_ts, np.zeros(len(out_ts)),
                         np.zeros(len(out_ts), bool))
        val = np.interp(out_ts, ts_v, xs)
        i0 = np.clip(np.searchsorted(ts_v, out_ts, "right") - 1, 0,
                     len(ts_v) - 1)
        i1 = np.clip(np.searchsorted(ts_v, out_ts, "left"), 0, len(ts_v) - 1)
        ok = ((out_ts - ts_v[i0] <= self.g) & (ts_v[i1] - out_ts <= self.g)
              & (ts_v[i0] <= out_ts) & (ts_v[i1] >= out_ts))
        return Batch(out_ts, val, ok)

    def __call__(self, b: Batch) -> Batch:
        ts_v = np.concatenate([self._tail_ts, b.ts[b.valid]])
        xs = np.concatenate([self._tail_x, np.asarray(b.value)[b.valid]])
        hi = b.ts[-1] if len(b.ts) else (
            self._tail_ts[-1] if len(self._tail_ts) else 0)
        out = self._emit(ts_v, xs, hi - self.g)
        keep = ts_v >= hi - 2 * self.g  # enough left-context for held ticks
        self._tail_ts, self._tail_x = ts_v[keep], xs[keep]
        return out

    def flush(self) -> Optional[Batch]:
        if len(self._tail_ts) == 0:
            return None
        return self._emit(self._tail_ts, self._tail_x, self._tail_ts[-1])


class Pipeline:
    """Interpreted operator DAG runner (operator-at-a-time per micro-batch).

    ``steps`` is a list of (op, input names, output name); 'in' is the source
    batch.  Every intermediate batch materializes into ``env`` — the
    message-queue hand-off the paper's §3 identifies as the interpreted-SPE
    bottleneck.
    """

    def __init__(self, steps: Sequence[tuple]):
        self.steps = steps

    def reset(self):
        for op, _, _ in self.steps:
            op.reset()

    def run_batch(self, env: dict) -> Batch:
        out = None
        for op, ins, name in self.steps:
            args = [env[i] for i in ins]
            out = op(*args)
            env[name] = out
        return out

    def run(self, batches, key: str = "in") -> list[Batch]:
        self.reset()
        outs = []
        for b in batches:
            env = {key: b} if isinstance(b, Batch) else dict(b)
            outs.append(self.run_batch(env))
        # flush lookahead operators (tail emission at stream end)
        for op, _, _ in self.steps:
            fl = getattr(op, "flush", None)
            if fl is not None:
                tail = fl()
                if tail is not None and len(tail):
                    outs.append(tail)
        return outs


# ---------------------------------------------------------------------------
# column helpers (payload may be an array or a dict of arrays)
# ---------------------------------------------------------------------------

def _zeros_like_cols(v, n):
    if isinstance(v, dict):
        return {k: np.zeros((n,) + a.shape[1:], a.dtype) for k, a in v.items()}
    return np.zeros((n,) + np.asarray(v).shape[1:], np.asarray(v).dtype)


def _concat_cols(a, b):
    if isinstance(a, dict):
        return {k: np.concatenate([a[k], b[k]]) for k in a}
    return np.concatenate([a, b])


def _slice_cols(v, lo, hi):
    if isinstance(v, dict):
        return {k: a[lo:hi] for k, a in v.items()}
    return v[lo:hi]


def _take_cols(v, idx):
    if isinstance(v, dict):
        return {k: a[idx] for k, a in v.items()}
    return np.asarray(v)[idx]
