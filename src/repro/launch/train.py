"""End-to-end training driver with checkpoint/restart fault tolerance.

Usage (CPU-scale example; production would launch one process per host with
the same code — jax.distributed picks up the mesh):

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Fault-tolerance contract (DESIGN.md §4):
* atomic checkpoints every ``--ckpt-every`` steps (async write);
* on start, the latest checkpoint (params, opt state, pipeline cursor) is
  restored if present — crash/preemption recovery is just re-launching;
* restore re-shards onto the *current* mesh, so recovery works after
  elastic downscale (fewer hosts than the run that wrote the checkpoint);
* step-level exceptions trigger a restore-and-retry once before aborting
  (transient-failure mitigation; persistent failures abort loudly).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs.base import get_config
from ..data.pipeline import TokenPipeline
from ..models import shardctx
from ..models.model import build_model
from ..train import checkpoint as ck
from ..train.optimizer import AdamWConfig, init_opt_state
from ..train.train_step import make_train_step
from . import sharding as SH
from .mesh import make_local_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    mesh = make_local_mesh()
    shardctx.set_mesh_axes(mesh.axis_names)

    params, axes = model.init(jax.random.PRNGKey(0))
    psh = SH.param_shardings(axes, cfg, mesh)
    params = jax.tree_util.tree_map(jax.device_put, params, psh)
    opt_state = init_opt_state(params)
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(args.steps // 20, 1))
    pipe = TokenPipeline(cfg, args.batch, args.seq)

    mgr = ck.CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if mgr is not None:
        restored, manifest = mgr.restore_latest(
            shardings={"params": psh,
                       "opt": {"m": psh, "v": psh,
                               "step": jax.sharding.NamedSharding(
                                   mesh, jax.sharding.PartitionSpec())}})
        if restored is not None:
            params = restored["params"]
            opt_state = restored["opt"]
            pipe.restore(manifest["extra"]["pipeline"])
            start = manifest["step"]
            print(f"[train] restored step {start} from {args.ckpt_dir}")

    step_fn = jax.jit(make_train_step(model, opt_cfg, n_micro=args.n_micro),
                      donate_argnums=(0, 1))

    t0 = time.time()
    step = start
    retried = False
    with mesh:
        while step < args.steps:
            batch = pipe.next()
            try:
                params, opt_state, metrics = step_fn(params, opt_state,
                                                     batch)
            except Exception as e:  # transient-failure path: restore, retry
                if retried or mgr is None:
                    raise
                print(f"[train] step {step} failed ({e}); restoring")
                restored, manifest = mgr.restore_latest(
                    shardings={"params": psh, "opt": {
                        "m": psh, "v": psh,
                        "step": jax.sharding.NamedSharding(
                            mesh, jax.sharding.PartitionSpec())}})
                params, opt_state = restored["params"], restored["opt"]
                pipe.restore(manifest["extra"]["pipeline"])
                step = manifest["step"]
                retried = True
                continue
            step += 1
            if step % args.log_every == 0 or step == args.steps:
                loss = float(metrics["loss"])
                dt = (time.time() - t0) / max(step - start, 1)
                print(f"[train] step {step} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"({dt*1e3:.0f} ms/step)", flush=True)
            if mgr is not None and step % args.ckpt_every == 0:
                mgr.save(step, {"params": params, "opt": opt_state},
                         extra={"pipeline": pipe.state()})
    if mgr is not None:
        mgr.save(args.steps, {"params": params, "opt": opt_state},
                 extra={"pipeline": pipe.state()}, blocking=True)
        mgr.wait()
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
