"""Logical-axis → mesh-axis mapping (the GSPMD sharding rulebook).

Model code annotates every parameter with *logical* axis names
(models/layers.py); this module maps them onto the physical mesh per
architecture, with divisibility-aware fallbacks:

* ``heads``/``kv_heads`` shard over ``model`` only when the head count
  divides the axis — otherwise they fall back to replication and the MLP
  carries the tensor parallelism (gemma2-2b's 8 heads / whisper's 20 heads
  on a 16-way model axis; recorded per-arch in the dry-run report, and the
  subject of a §Perf iteration).
* ``vocab``/``mlp``/``expert`` shard over ``model`` (vocab is pre-padded to
  a multiple of 128, so always divisible).
* ``embed`` (the d_model axis of weight matrices) shards over ``data`` —
  ZeRO-3/FSDP: parameters and optimizer state live sharded and are
  all-gathered layer-by-layer inside the scan (XLA's latency-hiding
  scheduler overlaps the gathers with compute).
* activations: batch over ``("pod","data")``, model-parallel axes over
  ``model``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig

__all__ = ["axis_rules", "param_shardings", "batch_sharding",
           "tree_map_axes"]


def tree_map_axes(fn, tree):
    """Map over an axes tree where tuples are leaves."""
    if isinstance(tree, dict):
        return {k: tree_map_axes(fn, v) for k, v in tree.items()}
    return fn(tree)


def axis_rules(cfg: ModelConfig, mesh: Mesh) -> Dict[str, Optional[str]]:
    """Logical axis name -> mesh axis (or None = replicate)."""
    tp = mesh.shape.get("model", 1)
    fsdp = "data" if "data" in mesh.shape else None

    def div(n):  # shard only when evenly divisible
        return "model" if n % tp == 0 else None

    W = cfg.lru_width or cfg.d_model
    rules = {
        "vocab": div(cfg.vocab_padded),
        "embed": fsdp,
        "mlp": div(cfg.d_ff),
        "mlp_moe": div(cfg.d_ff),
        "heads": div(cfg.n_heads),
        "kv_heads": div(cfg.n_kv_heads),
        "expert": div(cfg.n_experts) if cfg.n_experts else None,
        "lru": div(W),
        "lru_in": fsdp,
        "heads_rw": div(cfg.d_model),
        "layers": None,
    }
    # MoE: experts take the model axis; expert-internal mlp must not reuse it
    if cfg.n_experts and rules["expert"] == "model":
        rules["mlp_moe"] = None
        rules["embed_moe"] = fsdp
    # avoid double-assignment: if kv_heads replicated but heads sharded, fine
    return rules


def spec_for(axes: tuple, rules: Dict[str, Optional[str]]) -> P:
    used = set()
    parts = []
    for ax in axes:
        m = rules.get(ax) if ax is not None else None
        if m in used:  # a mesh axis may appear only once per spec
            m = None
        if m is not None:
            used.add(m)
        parts.append(m)
    return P(*parts)


def param_shardings(axes_tree, cfg: ModelConfig, mesh: Mesh):
    rules = axis_rules(cfg, mesh)
    return tree_map_axes(
        lambda ax: NamedSharding(mesh, spec_for(ax, rules)), axes_tree)


def batch_sharding(mesh: Mesh):
    """Tokens/labels: batch over all DP axes."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return NamedSharding(mesh, P(dp))


def batch_sharding_for(mesh: Mesh, leaf):
    """Batch sharding with a divisibility guard (global_batch=1 decode
    shapes replicate rather than over-shard)."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    deg = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    shape = getattr(leaf, "shape", ())
    if not shape or shape[0] % deg != 0:
        # try the inner 'data' axis alone before full replication
        if shape and "data" in mesh.shape and shape[0] % mesh.shape["data"] == 0:
            return NamedSharding(mesh, P("data"))
        return NamedSharding(mesh, P())
    return NamedSharding(mesh, P(dp))


def cache_shardings(axes_tree, struct_tree, cfg: ModelConfig, mesh: Mesh):
    """Decode-state shardings from the model's cache_axes strings.

    ``batch`` → all DP axes; ``kv_heads``/``heads``/``lru`` → model when
    divisible; everything else replicated.  (The KV cache is the dominant
    decode-shape buffer — ~TBs at decode_32k — so batch sharding here is
    what makes those cells fit; leaving it implicit replicates it, which is
    how §Perf iteration 0 discovered this.)"""
    rules = axis_rules(cfg, mesh)
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape) or None
    rules = dict(rules)
    rules["batch"] = dp
    # context-parallel fallback: when kv-heads cannot take the model axis
    # (whisper's 20 heads on a 16-way axis), shard the cache TIME axis over
    # it instead — GSPMD turns the attention contraction into a partial
    # softmax + all-reduce, and the per-device cache shrinks ×tp.
    rules["time"] = "model" if rules.get("kv_heads") is None else None
    rules["none"] = None

    def one(ax_str, leaf):
        # 'scalar' marks a rank-0 base leaf: it contributes no spec entry
        names = [None if a in ("none", "") else a
                 for a in ax_str.split(",") if a != "scalar"]
        shape = getattr(leaf, "shape", ())
        used = set()
        parts = []
        for d, ax in enumerate(names):
            m = rules.get(ax) if ax is not None else None
            dim = shape[d] if d < len(shape) else 0

            def degree(mm):
                if mm is None:
                    return 1
                if isinstance(mm, tuple):
                    return int(np.prod([mesh.shape[a] for a in mm]))
                return mesh.shape[mm]

            if isinstance(m, tuple):
                m = tuple(x for x in m if x not in used) or None
                if m is not None and dim % degree(m) != 0:
                    m = None  # e.g. batch=1 long-context decode
                if m is not None:
                    used.update(m)
            elif m is not None:
                if m in used or dim % degree(m) != 0:
                    m = None
                else:
                    used.add(m)
            parts.append(m)
        return NamedSharding(mesh, P(*parts))

    import jax as _jax
    return _jax.tree_util.tree_map(one, axes_tree, struct_tree)
