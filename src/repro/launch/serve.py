"""Batched serving driver: prefill + decode loop with continuous batching.

CPU-scale demo of the production serving path the decode_* dry-run shapes
lower:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --batch 4 --prompt-len 32 --gen 16

Continuous batching: a request queue feeds fixed-batch decode slots;
finished slots (EOS or budget) are refilled from the queue between decode
steps — the scheduler is host-side, the step functions are the jitted
prefill/decode the dry-run compiles.
"""
from __future__ import annotations

import argparse
import collections
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import get_config
from ..models.model import build_model
from ..train.train_step import make_serve_steps


def _make_prefill(model, prefill_fn, is_encdec: bool, max_len: int):
    """One jitted prefill for the whole run (max_len closed over as a
    static).  Built once, outside the wave loop — a fresh ``jax.jit``
    per wave is a fresh compile cache, so every wave would recompile."""
    if is_encdec:
        return jax.jit(prefill_fn)
    return jax.jit(lambda p, t: model.prefill(p, t, max_len))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    prefill_fn, decode_fn = make_serve_steps(model)

    max_len = args.prompt_len + args.gen
    rng = np.random.default_rng(0)
    queue = collections.deque(
        rng.integers(0, cfg.vocab, args.prompt_len)
        for _ in range(args.requests))
    done = []

    is_encdec = cfg.family == "encdec"
    frames = (jnp.zeros((args.batch, cfg.enc_seq, cfg.d_model), jnp.float32)
              if is_encdec else None)
    prefill = _make_prefill(model, prefill_fn, is_encdec, max_len)

    t0 = time.time()
    while queue:
        # FIFO: serve in arrival order (popleft — pop() would starve the
        # oldest requests behind every newer arrival)
        wave = [queue.popleft() for _ in range(min(args.batch, len(queue)))]
        n_real = len(wave)
        while len(wave) < args.batch:  # pad the batch
            wave.append(np.zeros(args.prompt_len, np.int64))
        tokens = jnp.asarray(np.stack(wave), jnp.int32)
        if is_encdec:
            logits, caches, enc = prefill(params, tokens, frames)
        else:
            logits, caches = prefill(params, tokens)
        out = [jnp.argmax(logits[:, -1], axis=-1)]
        pos = args.prompt_len
        for _ in range(args.gen - 1):
            tok = out[-1][:, None].astype(jnp.int32)
            if is_encdec:
                logits, caches = decode_fn(params, caches, tok,
                                           jnp.int32(pos), enc)
            else:
                logits, caches = decode_fn(params, caches, tok,
                                           jnp.int32(pos))
            out.append(jnp.argmax(logits[:, 0], axis=-1))
            pos += 1
        gen = np.stack([np.asarray(o) for o in out], axis=1)
        done.extend(gen[:n_real].tolist())  # padding slots are not work
    dt = time.time() - t0
    n_tok = len(done) * args.gen
    print(f"[serve] {len(done)} sequences, {n_tok} tokens, "
          f"{n_tok/dt:.1f} tok/s, sample: {done[0][:8]}")
    return done


if __name__ == "__main__":
    main()
