"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — required for the
dry-run's 512-placeholder-device bootstrap (dryrun.py sets XLA_FLAGS before
any jax import; everything else must stay lazy).

Axis semantics:
* ``pod``   — slowest axis, crosses the inter-pod DCN/ICI boundary; only
              data parallelism is mapped here (gradient all-reduce once per
              step; no layer-wise collectives cross pods).
* ``data``  — intra-pod data parallel + FSDP parameter/optimizer sharding +
              TiLT stream-time sharding.
* ``model`` — tensor parallel (attention heads / MLP hidden / MoE experts /
              vocab).
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "make_local_mesh", "DP_AXES", "TP_AXIS"]

DP_AXES = ("pod", "data")   # batch / FSDP axes (pod present when multi-pod)
TP_AXIS = "model"


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(n_data: Optional[int] = None, n_model: int = 1) -> Mesh:
    """Mesh over whatever devices exist (tests / CPU smoke)."""
    n = len(jax.devices())
    n_data = n_data or (n // n_model)
    return jax.make_mesh((n_data, n_model), ("data", "model"))
