import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

# Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.
#
# The two lines above MUST run before any jax import (jax locks the device
# count at first init) — hence their position at the very top.  The flag is
# set ONLY here: smoke tests and benchmarks see 1 device.
#
# Per cell this driver:
#   1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
#   2. builds the jitted step (train_step for train shapes; prefill / decode
#      for serve shapes) with NamedSharding in/out specs from sharding.py,
#   3. ``.lower(**ShapeDtypeStructs).compile()`` — no arrays allocated,
#   4. records ``memory_analysis()`` (fits-per-device proof) from the
#      production (layer-scanned) lowering, and ``cost_analysis()`` +
#      the HLO collective scrape from a layer-UNROLLED lowering — XLA's
#      cost_analysis counts while bodies once, so the scanned module would
#      undercount FLOPs by ~n_layers (the collective scrape is while-aware,
#      but flops cannot be re-attributed; see roofline/analysis.py).
#
# Usage:
#   python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k \
#       --mesh single --json out/cell.json
#   python -m repro.launch.dryrun --all --out-dir out/dryrun --mesh both
import argparse
import dataclasses


def jnp_int32_placeholder():
    import jax.numpy as jnp
    return jnp.int32
import json
import subprocess
import sys
import time
import traceback


def _lower_step(cfg, shape, mesh, n_micro=1):
    """Build and lower the cell's step function. Returns jax.stages.Lowered."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..models.model import build_model
    from ..train.optimizer import AdamWConfig, init_opt_state
    from ..train.train_step import make_serve_steps, make_train_step
    from . import sharding as SH

    model = build_model(cfg)
    specs = model.input_specs(shape)
    box = {}

    def _shapes_only(rng):
        p, a = model.init(rng)
        box["axes"] = a
        return p

    params_s = jax.eval_shape(_shapes_only, jax.random.PRNGKey(0))
    axes = box["axes"]
    param_sh = SH.param_shardings(axes, cfg, mesh)
    batch_sh = SH.batch_sharding(mesh)
    repl = NamedSharding(mesh, P())

    def shard_like_batch(tree):
        return jax.tree_util.tree_map(
            lambda x: SH.batch_sharding_for(mesh, x)
            if getattr(x, "ndim", 0) >= 1 else repl, tree)

    with mesh:
        if shape.kind == "train":
            step = make_train_step(model, AdamWConfig(), n_micro=n_micro)
            opt_s = jax.eval_shape(init_opt_state, params_s)
            opt_sh = {"m": param_sh, "v": param_sh, "step": repl}
            return jax.jit(
                step,
                in_shardings=(param_sh, opt_sh, shard_like_batch(specs)),
                out_shardings=(param_sh, opt_sh, repl),
                donate_argnums=(0, 1),
            ).lower(params_s, opt_s, specs)
        if shape.kind == "prefill":
            prefill_fn, _ = make_serve_steps(model)
            args = [params_s, specs["tokens"]]
            in_sh = [param_sh, SH.batch_sharding_for(mesh, specs["tokens"])]
            if "frames" in specs:
                args.append(specs["frames"])
                in_sh.append(SH.batch_sharding_for(mesh, specs["frames"]))
            # the returned KV cache dominates prefill memory: without an
            # out_sharding it materializes replicated (§Perf: dbrx prefill
            # 18.3 GB temp was almost entirely the cache)
            out_caches = jax.eval_shape(
                lambda *a: prefill_fn(*a), *args)[1]
            cache_out_sh = SH.cache_shardings(
                model.cache_axes(shape.seq_len), out_caches, cfg, mesh)
            logits_sh = SH.batch_sharding_for(
                mesh, jax.ShapeDtypeStruct(
                    (shape.global_batch, 1), jnp_int32_placeholder()))
            out_sh = [logits_sh, cache_out_sh]
            n_out = len(jax.tree_util.tree_structure(
                jax.eval_shape(lambda *a: prefill_fn(*a), *args)).children())
            if "frames" in specs:  # encdec prefill also returns enc_out
                out_sh.append(SH.batch_sharding_for(mesh, specs["frames"]))
            return jax.jit(prefill_fn, in_shardings=tuple(in_sh),
                           out_shardings=tuple(out_sh)).lower(*args)
        # decode
        _, decode_fn = make_serve_steps(model)
        cache_sh = SH.cache_shardings(
            model.cache_axes(shape.seq_len), specs["caches"], cfg, mesh)
        args = [params_s, specs["caches"], specs["tokens"], specs["pos"]]
        in_sh = [param_sh, cache_sh,
                 SH.batch_sharding_for(mesh, specs["tokens"]), repl]
        if "enc_out" in specs:
            args.append(specs["enc_out"])
            in_sh.append(SH.batch_sharding_for(mesh, specs["enc_out"]))
        return jax.jit(decode_fn, in_shardings=tuple(in_sh),
                       donate_argnums=(1,)).lower(*args)


def _parse_override(kv: str):
    k, v = kv.split("=", 1)
    if v.lower() in ("true", "false"):
        v = v.lower() == "true"
    else:
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                pass
    return k, v


def _cell(arch: str, shape_name: str, mesh_kind: str, hlo_dir=None,
          skip_unrolled=False, overrides=(), micro=None) -> dict:
    import jax

    from ..configs.base import SHAPES, get_config
    from ..models import shardctx
    from ..roofline.analysis import roofline
    from .mesh import make_production_mesh

    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **dict(overrides))
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    shardctx.set_mesh_axes(mesh.axis_names)
    n_dev = mesh.size

    # -- production (scanned) lowering: compile proof + memory -------------
    # train shapes run with gradient accumulation (4 microbatches) — the
    # production memory configuration the fits-per-device proof is about.
    n_micro = micro or (4 if shape.kind == "train" else 1)
    t0 = time.time()
    lowered = _lower_step(cfg, shape, mesh, n_micro=n_micro)
    lower_s = time.time() - t0
    t1 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t1
    mem = compiled.memory_analysis()
    mem_d = {k: getattr(mem, k) for k in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "generated_code_size_in_bytes")
        if hasattr(mem, k)}

    res = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "ok": True,
        "devices": n_dev, "n_micro": n_micro, "lower_s": round(lower_s, 1),
        "compile_s": round(compile_s, 1), "memory": mem_d,
        "per_device_bytes": (mem_d.get("argument_size_in_bytes", 0)
                             + mem_d.get("temp_size_in_bytes", 0)),
    }

    # collective bytes from the production (scanned) HLO — the scrape is
    # while-aware, so this is valid without the unrolled lowering and is
    # what hillclimb iterations (--skip-unrolled) compare on
    try:
        from ..roofline.analysis import collective_bytes
        coll_scanned = collective_bytes(compiled.as_text())
        res["coll_scanned"] = coll_scanned
        res["collective_s_scanned"] = coll_scanned["total"] / 50e9
    except Exception as e:  # pragma: no cover
        res["coll_scanned_error"] = str(e)[:200]

    # -- cost accounting (single-pod only: the roofline table mesh) --------
    if mesh_kind == "single" and not skip_unrolled:
        # cost lowering: layers unrolled, no microbatch scan — every flop
        # visible to cost_analysis exactly once per step
        cfg_u = dataclasses.replace(cfg, scan_layers=False)
        t2 = time.time()
        compiled_u = _lower_step(cfg_u, shape, mesh).compile()
        res["unrolled_compile_s"] = round(time.time() - t2, 1)
        cost = compiled_u.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        cost = dict(cost or {})
        hlo = compiled_u.as_text()
        if hlo_dir:
            os.makedirs(hlo_dir, exist_ok=True)
            with open(os.path.join(
                    hlo_dir, f"{arch}_{shape_name}_{mesh_kind}.hlo"),
                    "w") as f:
                f.write(hlo)

        n_active = cfg.n_active_params()
        tokens = shape.global_batch * (
            shape.seq_len if shape.kind != "decode" else 1)
        mult = 6.0 if shape.kind == "train" else 2.0
        model_flops = mult * n_active * tokens / n_dev
        rep = roofline(cost, hlo, model_flops)
        res["cost"] = {k: v for k, v in cost.items()
                       if k in ("flops", "bytes accessed")}
        res["roofline"] = rep.to_dict()
    return res


def run_cell(arch, shape, mesh_kind, json_path=None, hlo_dir=None,
             skip_unrolled=False, overrides=(), micro=None):
    try:
        res = _cell(arch, shape, mesh_kind, hlo_dir, skip_unrolled,
                    overrides, micro)
        if overrides:
            res["overrides"] = dict(overrides)
    except Exception as e:  # record failures as data, not crashes
        res = {"arch": arch, "shape": shape, "mesh": mesh_kind, "ok": False,
               "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-2000:]}
    if json_path:
        os.makedirs(os.path.dirname(json_path) or ".", exist_ok=True)
        with open(json_path, "w") as f:
            json.dump(res, f, indent=1)
    return res


def all_cells():
    """The assigned (arch × shape) grid, minus documented skips
    (DESIGN.md §Arch-applicability: long_500k needs sub-quadratic)."""
    from ..configs.base import SHAPES, get_config, registry
    cells = []
    for arch in sorted(registry()):
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if shape.name == "long_500k" and not cfg.sub_quadratic:
                continue  # quadratic attention at 500k — documented skip
            cells.append((arch, shape.name))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--json")
    ap.add_argument("--hlo-dir")
    ap.add_argument("--skip-unrolled", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (hillclimb knobs)")
    ap.add_argument("--micro", type=int, default=None,
                    help="gradient-accumulation microbatches (train)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out-dir", default="out/dryrun")
    ap.add_argument("--timeout", type=int, default=2400)
    args = ap.parse_args()

    if not args.all:
        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        ov = tuple(_parse_override(kv) for kv in args.set)
        for mk in meshes:
            res = run_cell(args.arch, args.shape, mk, args.json,
                           args.hlo_dir, args.skip_unrolled, ov, args.micro)
            print(json.dumps(
                {k: v for k, v in res.items() if k != "trace"}, indent=1))
            if not res["ok"]:
                sys.exit(1)
        return

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    os.makedirs(args.out_dir, exist_ok=True)
    failures = 0
    for arch, shape in all_cells():
        for mk in meshes:
            out = os.path.join(args.out_dir, f"{arch}_{shape}_{mk}.json")
            if os.path.exists(out):
                with open(out) as f:
                    prev = json.load(f)
                if prev.get("ok"):
                    print(f"SKIP (cached) {arch} {shape} {mk}", flush=True)
                    continue
            # subprocess per cell: isolates compile memory + failures
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mk,
                   "--json", out]
            if args.hlo_dir:
                cmd += ["--hlo-dir", args.hlo_dir]
            t0 = time.time()
            try:
                p = subprocess.run(cmd, capture_output=True, text=True,
                                   timeout=args.timeout)
                ok = p.returncode == 0
            except subprocess.TimeoutExpired:
                ok = False
                with open(out, "w") as f:
                    json.dump({"arch": arch, "shape": shape, "mesh": mk,
                               "ok": False, "error": "compile timeout"}, f)
            failures += (not ok)
            print(f"{'OK  ' if ok else 'FAIL'} {arch:24s} {shape:12s} {mk} "
                  f"({time.time() - t0:.0f}s)", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
