"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics of record: each kernel's test sweeps shapes/dtypes
and asserts allclose against these functions.  They are also the fallback
execution path on backends without Pallas support (ops.py dispatch).

Window convention: ``out[t]`` aggregates input ticks ``[t-W+1, t]`` clipped
to the start of the array (partial leading windows — matching φ-semantics
where ticks before the stream simply do not exist).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["prefix_sum_ref", "sliding_sum_ref", "sliding_assoc_ref",
           "seg_dirty_fused_ref"]


def prefix_sum_ref(x: jax.Array) -> jax.Array:
    """Inclusive prefix sum along the last axis, accumulated in f32."""
    acc = x.astype(jnp.float32) if x.dtype != jnp.float64 else x
    return jnp.cumsum(acc, axis=-1).astype(x.dtype)


def sliding_sum_ref(x: jax.Array, valid: jax.Array, window: int) -> tuple:
    """Masked sliding-window sum + valid count.

    Args:
      x:      (C, T) channel values.
      valid:  (T,) bool mask; invalid ticks contribute 0.
      window: W ticks.

    Returns:
      sums (C, T) f32, count (T,) f32.
    """
    xm = jnp.where(valid[None, :], x, 0).astype(jnp.float32)
    p = jnp.cumsum(xm, axis=-1)
    pw = jnp.pad(p, ((0, 0), (window, 0)))[:, : p.shape[-1]]
    sums = p - pw
    c = jnp.cumsum(valid.astype(jnp.float32))
    cw = jnp.pad(c, (window, 0))[: c.shape[-1]]
    return sums, c - cw


def sliding_assoc_ref(x: jax.Array, valid: jax.Array, window: int,
                      combine, identity) -> tuple:
    """Masked sliding-window associative reduce (max/min family).

    Args/returns as sliding_sum_ref but with a generic combine; returns
    (values (C, T), any_valid (T,) bool).
    """
    C, T = x.shape
    xm = jnp.where(valid[None, :], x, identity)
    # O(W) shift-combine reference — simple and obviously correct.
    out = xm
    anyv = valid
    for d in range(1, window):
        shifted = jnp.pad(xm, ((0, 0), (d, 0)),
                          constant_values=identity)[:, :T]
        out = combine(out, shifted)
        vs = jnp.pad(valid, (d, 0))[:T]
        anyv = anyv | vs
    return out, anyv


def sliding_reduce_window_ref(x: jax.Array, window: int, init, combine):
    """lax.reduce_window cross-check oracle (single channel)."""
    return jax.lax.reduce_window(
        x, init, combine, window_dimensions=(window,),
        window_strides=(1,), padding=((window - 1, 0),))


def sliding_assoc_block_ref(x: jax.Array, window: int, combine, identity,
                            scan_fn=None) -> jax.Array:
    """Vectorized Van Herk / Gil-Werman in pure jnp (no Pallas).

    Same striped-row decomposition as kernels/window_reduce.sliding_assoc —
    O(1) combines per element — but expressed on a (rows, W) reshape so the
    jnp fallback path is fast on any backend.  Semantics identical to
    ``sliding_assoc_ref`` (masking handled by the caller via ``identity``).
    """
    C, T = x.shape
    W = int(window)
    if W <= 1:
        return x
    Tp = -(-T // W) * W
    xp = jnp.pad(x, ((0, 0), (W, Tp - T)), constant_values=identity)
    rows = xp.reshape(C, Tp // W + 1, W)
    scan = scan_fn or (lambda a, rev: jax.lax.associative_scan(
        combine, a, axis=2, reverse=rev))
    prefix = scan(rows, False)[:, 1:]           # rows 1..K (current rows)
    suffix = scan(rows, True)[:, :-1]           # rows 0..K-1 (prev rows)
    suf = jnp.concatenate(
        [suffix[:, :, 1:],
         jnp.full((C, suffix.shape[1], 1), identity, x.dtype)], axis=2)
    out = combine(suf, prefix).reshape(C, Tp)
    return out[:, :T]


def seg_dirty_fused_ref(mats, geoms, n_segs: int) -> jax.Array:
    """Oracle for kernels/sparse_compact.seg_dirty: fused per-source tick
    diff → dilated-lineage range reduction → per-segment dirty flags.

    Args:
      mats:   list of (C, T) channel matrices (one or more per source —
              value leaves flattened to rows, validity folded in as a row).
      geoms:  matching list of static ``(a0, step, width)`` triples
              (:func:`repro.core.plan.seg_range_affine`): segment ``k`` is
              dirty iff any tick in ``[a0 + k·step, a0 + k·step + width)``
              changed.
      n_segs: number of output segments.

    Tick ``t`` of a mat *changed* iff any row differs from tick ``t-1``;
    tick 0 never changed (diffs against carried state are the caller's to
    OR in — see the position-0 contract in engine/runner).  Out-of-range
    ticks never changed.
    """
    seg = jnp.zeros((n_segs,), bool)
    k = jnp.arange(n_segs)
    for x, (a0, step, width) in zip(mats, geoms):
        if width <= 0:
            continue
        T = x.shape[-1]
        d = (x[:, 1:] != x[:, :-1]).any(axis=0)          # d[t-1] = tick t
        c = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                             jnp.cumsum(d.astype(jnp.int32))])
        lo = jnp.clip(a0 + k * step - 1, 0, T - 1)       # d index of tick
        hi = jnp.clip(a0 + k * step + width - 1, 0, T - 1)
        seg = seg | ((c[hi] - c[jnp.minimum(lo, hi)]) > 0)
    return seg
