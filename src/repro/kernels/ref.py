"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics of record: each kernel's test sweeps shapes/dtypes
and asserts allclose against these functions.  They are also the fallback
execution path on backends without Pallas support (ops.py dispatch).

Window convention: ``out[t]`` aggregates input ticks ``[t-W+1, t]`` clipped
to the start of the array (partial leading windows — matching φ-semantics
where ticks before the stream simply do not exist).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["prefix_sum_ref", "sliding_sum_ref", "sliding_assoc_ref"]


def prefix_sum_ref(x: jax.Array) -> jax.Array:
    """Inclusive prefix sum along the last axis, accumulated in f32."""
    acc = x.astype(jnp.float32) if x.dtype != jnp.float64 else x
    return jnp.cumsum(acc, axis=-1).astype(x.dtype)


def sliding_sum_ref(x: jax.Array, valid: jax.Array, window: int) -> tuple:
    """Masked sliding-window sum + valid count.

    Args:
      x:      (C, T) channel values.
      valid:  (T,) bool mask; invalid ticks contribute 0.
      window: W ticks.

    Returns:
      sums (C, T) f32, count (T,) f32.
    """
    xm = jnp.where(valid[None, :], x, 0).astype(jnp.float32)
    p = jnp.cumsum(xm, axis=-1)
    pw = jnp.pad(p, ((0, 0), (window, 0)))[:, : p.shape[-1]]
    sums = p - pw
    c = jnp.cumsum(valid.astype(jnp.float32))
    cw = jnp.pad(c, (window, 0))[: c.shape[-1]]
    return sums, c - cw


def sliding_assoc_ref(x: jax.Array, valid: jax.Array, window: int,
                      combine, identity) -> tuple:
    """Masked sliding-window associative reduce (max/min family).

    Args/returns as sliding_sum_ref but with a generic combine; returns
    (values (C, T), any_valid (T,) bool).
    """
    C, T = x.shape
    xm = jnp.where(valid[None, :], x, identity)
    # O(W) shift-combine reference — simple and obviously correct.
    out = xm
    anyv = valid
    for d in range(1, window):
        shifted = jnp.pad(xm, ((0, 0), (d, 0)),
                          constant_values=identity)[:, :T]
        out = combine(out, shifted)
        vs = jnp.pad(valid, (d, 0))[:T]
        anyv = anyv | vs
    return out, anyv


def sliding_reduce_window_ref(x: jax.Array, window: int, init, combine):
    """lax.reduce_window cross-check oracle (single channel)."""
    return jax.lax.reduce_window(
        x, init, combine, window_dimensions=(window,),
        window_strides=(1,), padding=((window - 1, 0),))


def sliding_assoc_block_ref(x: jax.Array, window: int, combine, identity,
                            scan_fn=None) -> jax.Array:
    """Vectorized Van Herk / Gil-Werman in pure jnp (no Pallas).

    Same striped-row decomposition as kernels/window_reduce.sliding_assoc —
    O(1) combines per element — but expressed on a (rows, W) reshape so the
    jnp fallback path is fast on any backend.  Semantics identical to
    ``sliding_assoc_ref`` (masking handled by the caller via ``identity``).
    """
    C, T = x.shape
    W = int(window)
    if W <= 1:
        return x
    Tp = -(-T // W) * W
    xp = jnp.pad(x, ((0, 0), (W, Tp - T)), constant_values=identity)
    rows = xp.reshape(C, Tp // W + 1, W)
    scan = scan_fn or (lambda a, rev: jax.lax.associative_scan(
        combine, a, axis=2, reverse=rev))
    prefix = scan(rows, False)[:, 1:]           # rows 1..K (current rows)
    suffix = scan(rows, True)[:, :-1]           # rows 0..K-1 (prev rows)
    suf = jnp.concatenate(
        [suffix[:, :, 1:],
         jnp.full((C, suffix.shape[1], 1), identity, x.dtype)], axis=2)
    out = combine(suf, prefix).reshape(C, Tp)
    return out[:, :T]
