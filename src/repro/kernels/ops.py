"""Jit'd dispatch wrappers around the Pallas window-reduction kernels.

``ops`` is the only kernel entry point the rest of the package uses; it
chooses between the Pallas kernel and the pure-jnp reference according to
backend and problem size:

* On TPU: Pallas (interpret=False).
* On CPU (this container): Pallas with interpret=True when
  ``REPRO_PALLAS_INTERPRET=1`` (tests force this), else the jnp reference —
  interpret mode executes the kernel body per-block in Python and is far too
  slow for the 10⁸-event benchmark runs, while the jnp path lowers to the
  same XLA ops the TPU kernels implement manually.
* Tiny windows (< _SMALL_W) skip Van Herk for a direct shift-combine; the
  striping overhead exceeds the O(W) cost there.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from . import ref as _ref
from . import window_reduce as _wr

__all__ = ["sliding_sum", "sliding_assoc", "use_pallas"]

_SMALL_W = 8


def use_pallas() -> bool:
    if os.environ.get("REPRO_PALLAS_INTERPRET") == "1":
        return True
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("window", "pallas", "algo"))
def sliding_sum(x: jax.Array, valid: jax.Array, window: int,
                pallas: bool | None = None,
                algo: str = "block") -> tuple[jax.Array, jax.Array]:
    """Masked sliding-window sums for (C, T) channels + (T,) valid count.

    Two algorithms, same O(1)-per-tick asymptotics:

    * ``algo='soe'``   — the paper-faithful Subtract-on-Evict: global prefix
      scan (Pallas kernel on TPU), then ``P[t] - P[t-W]`` as an XLA slice.
      FP32 CAVEAT: the cancellation error grows like ``eps·t·mean`` with
      stream position — unusable beyond ~10⁶ ticks of O(100) values.
    * ``algo='block'`` — beyond-paper numerical fix (DESIGN.md): block-local
      prefix/suffix sums with block size = W (the Van Herk structure with
      ``combine=+``).  Error is bounded by the *window* content
      (``eps·W·mean``), independent of stream length.  Default.
    """
    pallas = use_pallas() if pallas is None else pallas
    C, T = x.shape
    xm = jnp.where(valid[None, :], x, 0).astype(jnp.float32)
    stacked = jnp.concatenate([xm, valid[None, :].astype(jnp.float32)], axis=0)
    if algo == "block" and window >= _SMALL_W:
        if pallas:
            s = _wr.sliding_assoc(stacked, window, jnp.add, 0.0,
                                  interpret=_interpret())
        else:
            s = _ref.sliding_assoc_block_ref(
                stacked, window, jnp.add, 0.0,
                scan_fn=lambda a, rev: (
                    jnp.flip(jnp.cumsum(jnp.flip(a, 2), axis=2), 2)
                    if rev else jnp.cumsum(a, axis=2)))
        return s[:C], s[C]
    if pallas:
        p = _wr.prefix_scan(stacked, interpret=_interpret())
    else:
        p = _ref.prefix_sum_ref(stacked)
    pw = jnp.pad(p, ((0, 0), (window, 0)))[:, :T]
    s = p - pw
    return s[:C], s[C]


@functools.partial(jax.jit, static_argnames=("window", "op", "pallas"))
def sliding_assoc(x: jax.Array, valid: jax.Array, window: int, op: str,
                  pallas: bool | None = None) -> tuple[jax.Array, jax.Array]:
    """Masked sliding-window max/min for (C, T) channels.

    Returns (values (C, T), any_valid (T,) bool).  Validity rides along as
    an extra channel (sliding any == sliding max of the mask).
    """
    pallas = use_pallas() if pallas is None else pallas
    combine = jnp.maximum if op in ("max", "absmax") else jnp.minimum
    identity = -jnp.inf if op in ("max", "absmax") else jnp.inf
    C, T = x.shape
    xm = jnp.where(valid[None, :], x, identity).astype(jnp.float32)
    vch = valid[None, :].astype(jnp.float32)
    if op == "min":
        # any-valid via max even when the payload combine is min
        stacked = jnp.concatenate([xm, -vch], axis=0)
    else:
        stacked = jnp.concatenate([xm, vch], axis=0)
    if window < _SMALL_W:
        out, anyv = _ref.sliding_assoc_ref(xm, valid, window, combine,
                                           identity)
        return out, anyv
    if not pallas:
        out = _ref.sliding_assoc_block_ref(stacked, window, combine,
                                           identity)
        vals = out[:C]
        anyv = (out[C] < -0.5) if op == "min" else (out[C] > 0.5)
        return vals, anyv
    out = _wr.sliding_assoc(stacked, window, combine, identity,
                            interpret=_interpret())
    vals = out[:C]
    # mask channel: sliding-OR via max(v) for max-ops, min(-v) for min-ops
    anyv = (out[C] < -0.5) if op == "min" else (out[C] > 0.5)
    return vals, anyv
