"""Fully-fused trend-query Pallas kernel (paper §5.2's end state).

The optimized TiLT IR for the stock-trend query is a single expression:

    ~filter[t] = { s10 = ⊕(+, ~stock[t-W1:t]);  s20 = ⊕(+, ~stock[t-W2:t])
                   j = s10/W1 - s20/W2;  return (j > 0) ? j : φ }

This kernel IS that expression as one ``pallas_call``: each grid step loads
two W2-wide rows of the timeline into VMEM, computes *both* window sums
from one prefix/suffix scan pair (any ≤W2 trailing-window sum over two
adjacent rows is ``suffix_prev[... ] + prefix_cur[j] − prefix_cur[j−w]``),
applies the join and the predicate, and writes (value, validity) — the
source is read exactly once per tick, intermediates never leave VMEM.

Dense-stream fast path: assumes all input ticks valid (the trend app's
price stream); leading partial windows divide by the available count
(derived from the absolute position, no mask channel needed).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["fused_trend", "fused_trend_ref"]


def _kernel(prev_ref, cur_ref, val_ref, ok_ref, *, w1, w2):
    prev = prev_ref[...].astype(jnp.float32)   # (1, W2) row k-1 (padded idx)
    cur = cur_ref[...].astype(jnp.float32)     # (1, W2) row k
    W2 = cur.shape[-1]
    k = pl.program_id(0)

    prefix = jnp.cumsum(cur, axis=-1)
    suffix = jnp.cumsum(prev[:, ::-1], axis=-1)[:, ::-1]
    j = jax.lax.broadcasted_iota(jnp.int32, cur.shape, 1)   # lane in row
    pos = k * W2 + j                                        # global tick

    def wsum(w):
        # trailing-w sum ending at lane j (window spans ≤ 2 rows)
        intra = prefix - jnp.where(j >= w, _shift_r(prefix, w), 0.0)
        # contribution of row k-1: last (w-1-j) elements, when j < w-1
        need = w - 1 - j
        tail = jnp.where(need > 0, _gather_suffix(suffix, W2 - need), 0.0)
        return intra + tail

    def _shift_r(a, w):
        return jnp.where(j - w >= 0,
                         jnp.take_along_axis(a, jnp.maximum(j - w, 0),
                                             axis=1), 0.0)

    def _gather_suffix(s, idx):
        return jnp.take_along_axis(s, jnp.clip(idx, 0, W2 - 1), axis=1)

    s1, s2 = wsum(w1), wsum(w2)
    c1 = jnp.minimum(pos + 1, w1).astype(jnp.float32)
    c2 = jnp.minimum(pos + 1, w2).astype(jnp.float32)
    diff = s1 / c1 - s2 / c2
    val_ref[...] = diff
    ok_ref[...] = diff > 0


def fused_trend(x: jax.Array, w1: int, w2: int,
                interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """x: (T,) dense stream.  Returns (diff (T,) f32, uptrend (T,) bool)."""
    assert w1 < w2, "short window first"
    T = x.shape[0]
    W2 = int(w2)
    Tp = -(-T // W2) * W2
    xp = jnp.pad(x.astype(jnp.float32), (W2, Tp - T))[None, :]  # lead pad row
    rows = Tp // W2

    kern = functools.partial(_kernel, w1=int(w1), w2=W2)
    val, ok = pl.pallas_call(
        kern,
        grid=(rows,),
        in_specs=[
            pl.BlockSpec((1, W2), lambda k: (0, k)),      # row k-1 (padded)
            pl.BlockSpec((1, W2), lambda k: (0, k + 1)),  # row k
        ],
        out_specs=[pl.BlockSpec((1, W2), lambda k: (0, k)),
                   pl.BlockSpec((1, W2), lambda k: (0, k))],
        out_shape=[jax.ShapeDtypeStruct((1, Tp), jnp.float32),
                   jax.ShapeDtypeStruct((1, Tp), jnp.bool_)],
        interpret=interpret,
    )(xp, xp)
    return val[0, :T], ok[0, :T]


def fused_trend_ref(x: jax.Array, w1: int, w2: int):
    """Pure-jnp oracle (float64-free but algebraically direct)."""
    xf = x.astype(jnp.float32)
    T = xf.shape[0]
    p = jnp.cumsum(xf)

    def wmean(w):
        pw = jnp.pad(p, (w, 0))[:T]
        cnt = jnp.minimum(jnp.arange(T) + 1, w).astype(jnp.float32)
        return (p - pw) / cnt

    diff = wmean(w1) - wmean(w2)
    return diff, diff > 0
