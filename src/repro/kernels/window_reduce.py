"""Pallas TPU kernels for TiLT window reductions (DESIGN.md §2).

Two kernels cover every built-in reduction:

* :func:`prefix_scan` — multi-block inclusive prefix sum with a VMEM carry
  across the (sequential) grid.  Invertible reductions (sum/count/mean/
  stddev/moments) become ``P[t] - P[t-W]`` — Subtract-on-Evict vectorized
  over all ticks; the subtract itself is a cheap XLA slice, so the kernel is
  the bandwidth-bound scan.

* :func:`sliding_assoc` — Van Herk / Gil-Werman sliding reduce for
  non-invertible associative ops (max/min): the timeline is striped into
  rows of width W (lane axis); a prefix scan of the current row and a suffix
  scan of the previous row combine into the exact W-window reduce with O(1)
  work per element and 2 reads per element.

TPU mapping notes (kernels are *validated* with ``interpret=True`` on CPU —
this container has no TPU — and *targeted* at TPU):

* Blocks are ``(C, B)`` with C = channel count on the sublane axis and B on
  the lane axis; wrappers pad B to a multiple of 128 (MXU/VPU lane width)
  and C to 8 sublanes when C > 1.
* The grid is 1-D and sequential on TPU, which makes the VMEM carry scratch
  legal (scratch persists across grid steps).
* ``associative_scan``/``cumsum`` inside the kernel body lower to
  log-depth vector ops on the VPU; window widths that are not multiples of
  128 relayout (performance, not correctness).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU scratch memory spaces; present in jax 0.8
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None

__all__ = ["prefix_scan", "sliding_assoc", "DEFAULT_BLOCK"]

DEFAULT_BLOCK = 1024  # lanes per grid step for the prefix scan


# ---------------------------------------------------------------------------
# Kernel 1: multi-block prefix scan with carry
# ---------------------------------------------------------------------------

def _prefix_scan_kernel(x_ref, out_ref, carry_ref):
    g = pl.program_id(0)

    @pl.when(g == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    x = x_ref[...].astype(jnp.float32)          # (C, B)
    p = jnp.cumsum(x, axis=-1) + carry_ref[...]  # carry (C, 1) broadcasts
    out_ref[...] = p
    carry_ref[...] = p[:, -1:]


def prefix_scan(x: jax.Array, block: int = DEFAULT_BLOCK,
                interpret: bool = True) -> jax.Array:
    """Inclusive f32 prefix sum along the last axis of ``x: (C, T)``.

    T is padded to a multiple of ``block``; the pad region is zeros so the
    carry is unaffected, and the wrapper slices the result back.
    """
    C, T = x.shape
    Tp = -(-T // block) * block
    xp = jnp.pad(x, ((0, 0), (0, Tp - T)))
    grid = Tp // block

    out = pl.pallas_call(
        _prefix_scan_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((C, block), lambda k: (0, k))],
        out_specs=pl.BlockSpec((C, block), lambda k: (0, k)),
        out_shape=jax.ShapeDtypeStruct((C, Tp), jnp.float32),
        scratch_shapes=[_VMEM((C, 1), jnp.float32)] if _VMEM else None,
        interpret=interpret,
    )(xp)
    return out[:, :T]


# ---------------------------------------------------------------------------
# Kernel 2: Van Herk / Gil-Werman sliding associative reduce
# ---------------------------------------------------------------------------

def _vanherk_kernel(prev_ref, cur_ref, out_ref, *, combine, identity):
    prev = prev_ref[...]   # (C, W) — row k-1 of the striped timeline
    cur = cur_ref[...]     # (C, W) — row k
    C, W = cur.shape
    prefix = jax.lax.associative_scan(combine, cur, axis=1)
    suffix = jax.lax.associative_scan(combine, prev, axis=1, reverse=True)
    # out[t = kW + j] reduces [t-W+1, t] = prev[j+1:] ∪ cur[:j+1]
    #               = combine(suffix[j+1] (identity when j = W-1), prefix[j])
    suf = jnp.concatenate(
        [suffix[:, 1:], jnp.full((C, 1), identity, cur.dtype)], axis=-1)
    out_ref[...] = combine(suf, prefix)


def sliding_assoc(x: jax.Array, window: int, combine, identity,
                  interpret: bool = True) -> jax.Array:
    """Sliding-window associative reduce along the last axis of ``x: (C, T)``.

    ``out[:, t] = combine over x[:, max(0, t-window+1) : t+1]``.

    The wrapper left-pads one full row of ``identity`` (so row k-1 always
    exists and leading partial windows are exact) and right-pads T to a
    multiple of W.
    """
    C, T = x.shape
    W = int(window)
    if W <= 1:
        return x
    Tp = -(-T // W) * W
    xp = jnp.pad(x, ((0, 0), (W, Tp - T)), constant_values=identity)
    rows = Tp // W  # output rows; padded input has rows+1 rows

    kern = functools.partial(_vanherk_kernel, combine=combine,
                             identity=identity)
    out = pl.pallas_call(
        kern,
        grid=(rows,),
        in_specs=[
            pl.BlockSpec((C, W), lambda k: (0, k)),      # prev row (padded idx k)
            pl.BlockSpec((C, W), lambda k: (0, k + 1)),  # cur row (padded idx k+1)
        ],
        out_specs=pl.BlockSpec((C, W), lambda k: (0, k)),
        out_shape=jax.ShapeDtypeStruct((C, Tp), x.dtype),
        interpret=interpret,
    )(xp, xp)
    return out[:, :T]
