"""Fused change-detection kernel (paper §5's skip test off the critical
path).

Sparse execution resolves, per output segment, one bit: *did any input tick
in this segment's dilated lineage change?*  The staged implementation
(engine/runner phases, core/sparse one-shot) answers it in three jitted
passes — per-source tick diff, `ChangePlan` dilation via cumsum range
queries, per-segment reduction — materializing a full-length dirty mask
between them.  This kernel fuses all three into a single ``pallas_call``:

* Every source grid is flattened into per-dtype channel matrices ``(C, T)``
  (:func:`grid_mats`): value leaves become rows, the validity mask is cast
  in as one more row, so "any leaf or validity changed" is one vectorized
  ``!=`` across rows.
* The dilated lineage of segment ``k`` is the *affine* input range
  ``[a0 + k·step, a0 + k·step + width)``
  (:func:`repro.core.plan.seg_range_affine`) — a fixed-width window
  sliding a fixed stride per segment.  The 1-D grid maps segment ``k``
  straight onto its input blocks (``⌈(width+1)/step⌉`` consecutive
  ``step``-wide blocks of the same padded matrix, the multi-``in_specs``
  idiom of kernels/window_reduce), diffs adjacent ticks in registers and
  reduces to the segment's flag — the tick-level mask never exists in
  memory.
* Out-of-range and tick-0 pairs are masked by position (NaN-safe: padding
  content is never compared), matching the reference convention that tick
  0 never changed — carried cross-chunk flags are the caller's to OR in.

Semantics of record: :func:`repro.kernels.ref.seg_dirty_fused_ref` (the
dispatcher's jnp fallback on non-TPU backends, and what CI asserts
bit-identity against in interpret mode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ops, ref

__all__ = ["grid_mats", "seg_dirty"]


def grid_mats(value, valid) -> list:
    """Flatten one source grid's ``(value, valid)`` into channel matrices
    ``(C, T)`` for :func:`seg_dirty` — one matrix per value dtype (rows
    can only be compared vectorized within a dtype), validity cast in as a
    row of the first.  Time axis 0 in, time axis last out; bool leaves are
    widened to int32 (exact).  Traceable (vmap-safe over a leading key
    axis)."""
    groups: dict = {}
    for leaf in jax.tree_util.tree_leaves(value):
        x = leaf.astype(jnp.int32) if leaf.dtype == jnp.bool_ else leaf
        rows = x.reshape(x.shape[0], -1).T if x.ndim > 1 else x[None, :]
        groups.setdefault(str(rows.dtype), []).append(rows)
    if not groups:
        return [valid[None, :].astype(jnp.int32)]
    first = next(iter(groups))
    groups[first].append(valid[None, :].astype(groups[first][0].dtype))
    return [rows[0] if len(rows) == 1 else jnp.concatenate(rows, axis=0)
            for rows in groups.values()]


def _lower(a0: int, step: int, width: int, T: int, n_segs: int):
    """Static block geometry for one matrix: segment ``k`` must see ticks
    ``[a0 - 1 + k·step, a0 + k·step + width)`` (the extra leading tick is
    the diff partner).  Returns ``(pad_left, pad_to, m, NB, B)``: left-pad
    so that window start lands exactly on block ``k + m`` of ``B``-wide
    blocks, ``NB`` consecutive blocks cover the window."""
    B = max(int(step), 1)
    shift = a0 - 1
    pad_left = (-shift) % B
    m = (pad_left + shift) // B
    if m < 0:
        pad_left += -m * B
        m = 0
    NB = -(-(width + 1) // B)
    need = (n_segs + m + NB - 1) * B
    pad_to = -(-max(need, pad_left + T) // B) * B
    return pad_left, pad_to, m, NB, B


def _kernel(*refs, geoms):
    """One grid step = one segment: per matrix, concatenate its blocks,
    diff adjacent ticks, mask to the in-range pairs, reduce, OR across
    matrices."""
    out_ref = refs[-1]
    k = pl.program_id(0)
    flag = jnp.zeros((1, 1), jnp.int32)
    i = 0
    for a0, step, width, T, NB in geoms:
        x = jnp.concatenate([refs[i + j][...] for j in range(NB)], axis=-1)
        i += NB
        d = x[:, 1:] != x[:, :-1]
        p = jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
        t = a0 + k * step + p            # global tick index of pair p
        ok = (p < width) & (t >= 1) & (t <= T - 1)
        flag = flag | jnp.any(d & ok).astype(jnp.int32).reshape(1, 1)
    out_ref[...] = flag


def _seg_dirty_pallas(mats, geoms, n_segs: int, interpret: bool):
    args, in_specs, kgeoms = [], [], []
    for x, (a0, step, width) in zip(mats, geoms):
        if width <= 0:
            continue
        C, T = x.shape
        pad_left, pad_to, m, NB, B = _lower(a0, step, width, T, n_segs)
        xp = jnp.pad(x, ((0, 0), (pad_left, pad_to - pad_left - T)))
        for j in range(NB):
            args.append(xp)
            in_specs.append(pl.BlockSpec(
                (C, B), functools.partial(lambda k, b: (0, k + b), b=m + j)))
        kgeoms.append((a0, step, width, T, NB))
    if not args:
        return jnp.zeros((n_segs,), bool)
    out = pl.pallas_call(
        functools.partial(_kernel, geoms=tuple(kgeoms)),
        grid=(n_segs,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1), lambda k: (0, k)),
        out_shape=jax.ShapeDtypeStruct((1, n_segs), jnp.int32),
        interpret=interpret,
    )(*args)
    return out[0] > 0


def seg_dirty(mats, geoms, n_segs: int, pallas: bool | None = None
              ) -> jax.Array:
    """Per-segment dirty flags ``(n_segs,) bool``: segment ``k`` is dirty
    iff any tick in ``[a0 + k·step, a0 + k·step + width)`` of any matrix
    differs from its predecessor tick (tick 0 and out-of-range ticks never
    count — carried flags are the caller's to OR in).

    ``mats``/``geoms`` are parallel lists — (C, T) channel matrices
    (:func:`grid_mats`) and their static ``(a0, step, width)`` lineage
    triples (:func:`repro.core.plan.seg_range_affine`); a source with
    several dtype matrices repeats its triple.  Dispatch follows
    kernels/ops: the Pallas kernel on TPU (or under
    ``REPRO_PALLAS_INTERPRET=1``), the jnp oracle
    :func:`repro.kernels.ref.seg_dirty_fused_ref` elsewhere.
    """
    if pallas is None:
        pallas = ops.use_pallas()
    if pallas:
        return _seg_dirty_pallas(mats, geoms, n_segs, ops._interpret())
    return ref.seg_dirty_fused_ref(mats, geoms, n_segs)
