"""Render the dry-run/roofline markdown tables into EXPERIMENTS.md.

Usage: PYTHONPATH=src python -m repro.roofline.report [out/dryrun]
Replaces the <!-- DRYRUN_SUMMARY --> and <!-- ROOFLINE_TABLE --> markers.
"""
from __future__ import annotations

import glob
import json
import os
import sys

HBM = 16e9


def load(out_dir):
    cells = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(f) as fh:
            cells.append(json.load(fh))
    return cells


def dryrun_summary(cells):
    ok = [c for c in cells if c.get("ok")]
    fail = [c for c in cells if not c.get("ok")]
    single = [c for c in ok if c["mesh"] == "single"]
    multi = [c for c in ok if c["mesh"] == "multi"]
    fits = [c for c in ok if c.get("per_device_bytes", 0) <= HBM]
    lines = [
        f"Compiled OK: **{len(ok)}/{len(cells)}** runs "
        f"({len(single)} single-pod + {len(multi)} multi-pod); "
        f"{len(fits)}/{len(ok)} fit in 16 GB HBM per device.",
        "",
        "| arch | shape | mesh | devices | GB/device | fits | compile s |",
        "|---|---|---|---|---|---|---|",
    ]
    for c in ok:
        gb = c.get("per_device_bytes", 0) / 1e9
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | {c['devices']} "
            f"| {gb:.2f} | {'✓' if gb * 1e9 <= HBM else '✗'} "
            f"| {c.get('compile_s', 0)}"
            f"{'+' + str(c['unrolled_compile_s']) if 'unrolled_compile_s' in c else ''} |")
    for c in fail:
        lines.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | - | - | "
                     f"FAIL: {c.get('error', '?')[:60]} | - |")
    return "\n".join(lines)


def roofline_table(cells):
    rows = [c for c in cells if c.get("ok") and c.get("roofline")]
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant "
        "| useful | roofline frac | one-line next step |",
        "|---|---|---|---|---|---|---|---|---|",
    ]

    def nextstep(c):
        r = c["roofline"]
        d = r["dominant"]
        if d == "collective":
            kinds = r.get("coll_breakdown", {})
            top = max(kinds, key=kinds.get) if kinds else "?"
            return (f"cut {top} bytes (seq-parallel/RS+AG or wider TP "
                    f"divisibility)")
        if d == "memory":
            if c["shape"].startswith("decode") or c["shape"].startswith(
                    "long"):
                return "quantize KV cache (cache_dtype=f8) / fuse reads"
            return "fewer materializations: fused attention kernel, narrower dtypes"
        return "MXU-align tiles; raise arithmetic intensity per pass"

    for c in rows:
        r = c["roofline"]
        lines.append(
            f"| {c['arch']} | {c['shape']} | {r['compute_s']:.3f} "
            f"| {r['memory_s']:.3f} | {r['collective_s']:.3f} "
            f"| **{r['dominant']}** | {r['useful_ratio']:.2f} "
            f"| {r['roofline_fraction']:.4f} | {nextstep(c)} |")
    return "\n".join(lines)


def main(out_dir="out/dryrun", exp="EXPERIMENTS.md"):
    cells = load(out_dir)
    with open(exp) as f:
        text = f.read()
    text = text.replace("<!-- DRYRUN_SUMMARY -->", dryrun_summary(cells))
    text = text.replace("<!-- ROOFLINE_TABLE -->", roofline_table(cells))
    with open(exp, "w") as f:
        f.write(text)
    print(f"updated {exp} with {len(cells)} cells")


if __name__ == "__main__":
    main(*sys.argv[1:])
