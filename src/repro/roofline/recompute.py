"""Re-derive roofline terms from saved dry-run HLO (no recompilation).

Parser improvements (while-trip multipliers, ring factors) can be replayed
over out/hlo/*.hlo; cost_analysis flops/bytes are taken from the cell JSON.

Usage: PYTHONPATH=src python -m repro.roofline.recompute out/dryrun out/hlo
"""
from __future__ import annotations

import glob
import json
import os
import sys

from .analysis import roofline


def main(out_dir: str = "out/dryrun", hlo_dir: str = "out/hlo"):
    for jpath in sorted(glob.glob(os.path.join(out_dir, "*_single.json"))):
        with open(jpath) as f:
            d = json.load(f)
        if not d.get("ok") or "roofline" not in d:
            continue
        hpath = os.path.join(
            hlo_dir, f"{d['arch']}_{d['shape']}_{d['mesh']}.hlo")
        if not os.path.exists(hpath):
            continue
        with open(hpath) as f:
            hlo = f.read()
        rep = roofline(d["cost"] | {"bytes accessed":
                                    d["cost"].get("bytes accessed", 0.0)},
                       hlo, d["roofline"]["model_flops"])
        d["roofline"] = rep.to_dict()
        with open(jpath, "w") as f:
            json.dump(d, f, indent=1)
        print(f"recomputed {os.path.basename(jpath)}: "
              f"dom={rep.dominant} rf={rep.roofline_fraction:.4f}")


if __name__ == "__main__":
    main(*sys.argv[1:])
