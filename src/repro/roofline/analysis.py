"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch × shape × mesh) cell, all in seconds-per-step on the
TARGET hardware (TPU v5e-like constants; this container is CPU-only so the
terms are *derived from the compiled HLO*, not measured):

    compute    = HLO_FLOPs / (peak_FLOP/s)           [per device]
    memory     = HLO_bytes / HBM_bw                  [per device]
    collective = Σ collective bytes-on-wire / link_bw [per device]

``compiled.cost_analysis()`` supplies FLOPs and bytes accessed of the
per-device SPMD module.  Collective bytes are NOT in cost_analysis — we
parse the optimized HLO text and sum operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, converted to
bytes-on-wire with the standard ring-algorithm factors:

    all-gather      out × (g-1)/g        reduce-scatter  in × (g-1)/g
    all-reduce      2 × size × (g-1)/g   all-to-all      size × (g-1)/g
    collective-permute  size

The dominant term is the bottleneck the §Perf loop iterates on.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

__all__ = ["HW", "collective_bytes", "roofline", "RooflineReport"]


@dataclasses.dataclass(frozen=True)
class HW:
    """TPU v5e-flavored target constants (per chip)."""

    peak_flops: float = 197e12     # bf16
    hbm_bw: float = 819e9          # B/s
    link_bw: float = 50e9          # B/s per ICI link (per the assignment)
    hbm_bytes: float = 16e9


_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape or tuple-of-shapes string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_COMP_HDR_RE = re.compile(
    r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$", re.M)
_REF_RE = re.compile(
    r"(?:condition|body|to_apply|calls)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TC_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _split_computations(hlo_text: str):
    """name -> (body text, is_entry). HLO printer emits one computation per
    top-level ``name (...) -> ... { ... }`` block."""
    comps = {}
    entry = None
    pos = 0
    for m in _COMP_HDR_RE.finditer(hlo_text):
        start = m.end()
        # find matching closing brace at column 0
        end = hlo_text.find("\n}", start)
        if end < 0:
            end = len(hlo_text)
        name = m.group(1)
        comps[name] = hlo_text[start:end]
        if m.group(0).startswith("ENTRY"):
            entry = name
        pos = end
    return comps, entry


def _line_collectives(body: str) -> Dict[str, float]:
    out: Dict[str, float] = {}
    for m in _COLL_RE.finditer(body):
        shape_str, kind = m.group(1), m.group(2)
        if "-done" in m.group(0):
            continue  # async pairs counted at -start
        size = _shape_bytes(shape_str)
        line_end = body.find("\n", m.start())
        line = body[m.start():line_end if line_end > 0 else None]
        g = None
        mv2 = _GROUPS_V2_RE.search(line)
        if mv2:
            g = int(mv2.group(2))
        else:
            mg = _GROUPS_RE.search(line)
            if mg:
                g = len([x for x in mg.group(1).split(",") if x.strip()])
        if g is None or g <= 1:
            g = 2  # conservative: at least a pair
        frac = (g - 1) / g
        if kind == "all-gather":
            wire = size * frac            # size == gathered output
        elif kind == "all-reduce":
            wire = 2 * size * frac
        elif kind == "reduce-scatter":
            wire = size * (g - 1)         # size == scattered output (in/g)
        elif kind == "all-to-all":
            wire = size * frac
        else:  # collective-permute
            wire = size
        out[kind] = out.get(kind, 0.0) + wire
    return out


def _trip_count(cond_body: str) -> int:
    """Scan-style loop condition: iteration counter compared to a constant.
    Heuristic: the largest s32 scalar constant in the condition."""
    consts = [int(c) for c in _TC_CONST_RE.findall(cond_body)]
    return max(consts) if consts else 1


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device bytes-on-wire by collective kind (ring-algorithm model).

    While-loop aware: collectives inside a ``lax.scan``/``while`` body are
    multiplied by the loop trip count (XLA's cost_analysis does NOT do this
    — bodies are counted once — so neither would a naive text scrape)."""
    comps, entry = _split_computations(hlo_text)
    if entry is None:
        return {"total": 0.0, **_line_collectives(hlo_text)} | {
            "total": sum(_line_collectives(hlo_text).values())}

    memo: Dict[str, Dict[str, float]] = {}

    def cost(name: str, stack=()) -> Dict[str, float]:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return {}
        body = comps[name]
        total = dict(_line_collectives(body))

        # while ops: condition=%c, body=%b → multiply body cost by trips
        for wm in re.finditer(
                r"while\([^)]*\),\s*condition=%?([\w.\-]+),\s*"
                r"body=%?([\w.\-]+)", body):
            cond, wbody = wm.group(1), wm.group(2)
            trips = _trip_count(comps.get(cond, ""))
            sub = cost(wbody, stack + (name,))
            for k, v in sub.items():
                total[k] = total.get(k, 0.0) + trips * v

        # calls / fusions / appliers: multiplier 1
        seen_refs = set()
        for rm in _REF_RE.finditer(body):
            ref = rm.group(1)
            # body/condition already handled above
            if f"body=%{ref}" in body or f"body={ref}" in body:
                continue
            if f"condition=%{ref}" in body or f"condition={ref}" in body:
                continue
            if ref in seen_refs:
                continue
            seen_refs.add(ref)
            sub = cost(ref, stack + (name,))
            for k, v in sub.items():
                total[k] = total.get(k, 0.0) + v
        for bm in _BRANCH_RE.finditer(body):
            for ref in bm.group(1).replace("%", "").split(","):
                ref = ref.strip()
                sub = cost(ref, stack + (name,))
                for k, v in sub.items():
                    total[k] = total.get(k, 0.0) + v
        memo[name] = total
        return total

    out = cost(entry)
    out["total"] = sum(out.values())
    return out


@dataclasses.dataclass
class RooflineReport:
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device bytes accessed
    coll_bytes: float            # per-device bytes on wire
    coll_breakdown: Dict[str, float]
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float           # 6·N·D useful flops (per device)
    hw: HW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/redundancy waste detector."""
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achievable if the step runs at
        the dominant-term time: useful_compute_time / bound_time."""
        useful_s = self.model_flops / self.hw.peak_flops
        return useful_s / self.bound_s if self.bound_s else 0.0

    def to_dict(self) -> dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes,
            "coll_breakdown": self.coll_breakdown,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def roofline(cost: dict, hlo_text: str, model_flops_per_device: float,
             hw: Optional[HW] = None) -> RooflineReport:
    hw = hw or HW()
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text)
    return RooflineReport(
        flops=flops, hbm_bytes=hbm, coll_bytes=coll["total"],
        coll_breakdown={k: v for k, v in coll.items() if k != "total"},
        compute_s=flops / hw.peak_flops,
        memory_s=hbm / hw.hbm_bw,
        collective_s=coll["total"] / hw.link_bw,
        model_flops=model_flops_per_device, hw=hw)
