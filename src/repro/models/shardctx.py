"""Activation-sharding hints, decoupled from model code.

Drivers (dryrun / train / serve launchers) declare the mesh axes once via
:func:`set_mesh_axes`; model code sprinkles :func:`hint` on the activations
whose layout GSPMD tends to get wrong without help (logits over vocab,
hidden states over batch).  With no axes declared (CPU smoke tests) hints
are no-ops, so the model runs anywhere.

``"dp"`` in a hint expands to the declared data-parallel axis group
(("pod","data") on the multi-pod mesh); ``"model"`` passes through when the
mesh has a model axis.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
from jax.sharding import PartitionSpec as P

__all__ = ["set_mesh_axes", "clear", "hint"]

_DP: Optional[tuple] = None
_AXES: Optional[set] = None


def set_mesh_axes(axes: Sequence[str]):
    """Declare physical mesh axis names, e.g. ("pod","data","model")."""
    global _DP, _AXES
    _AXES = set(axes)
    _DP = tuple(a for a in ("pod", "data") if a in _AXES) or None


def clear():
    global _DP, _AXES
    _DP = None
    _AXES = None


def hint(x, *names):
    """Constrain ``x``'s sharding; names are mesh axes, "dp", or None."""
    if _AXES is None:
        return x
    parts = []
    for n in names:
        if n == "dp":
            parts.append(_DP)
        elif n in _AXES if n is not None else False:
            parts.append(n)
        else:
            parts.append(None)
    return jax.lax.with_sharding_constraint(x, P(*parts))
