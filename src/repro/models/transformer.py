"""Decoder-only LM stack covering dense / MoE / Griffin / RWKV-6 families.

Layer patterns (``cfg.pattern``) cycle through block kinds; the stack is
compiled as a ``lax.scan`` over *superblocks* (one pattern period each) with
stacked parameters — compile time is O(pattern period), not O(n_layers),
which is what makes the 512-device dry-run of 40+-layer models tractable
(and is the standard MaxText-style production trick).  Remainder layers
(n_layers % period) are unrolled.

``remat``: the scan body is wrapped in ``jax.checkpoint`` for training so
activation memory is O(1) in depth (recomputed in backward).

Public surface (consumed by model.py / launch):
  init(rng, cfg)                      -> (params, axes)
  forward(params, cfg, tokens, ...)   -> logits (train/prefill path)
  train_loss(params, cfg, batch)      -> scalar loss
  init_cache(cfg, B, S_max)           -> cache pytree
  decode_step(params, cfg, cache, tokens, pos) -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from . import layers as L
from . import recurrent as R
from .shardctx import hint

__all__ = ["init", "forward", "train_loss", "init_cache", "decode_step",
           "prefill"]


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _init_block(rng, cfg: ModelConfig, kind: str):
    D = cfg.d_model
    r = jax.random.split(rng, 3)
    p: dict = {"ln1": jnp.zeros((D,), jnp.float32),
               "ln2": jnp.zeros((D,), jnp.float32)}
    a: dict = {"ln1": (None,), "ln2": (None,)}
    if kind in ("global", "local", "bidir"):
        p["attn"], a["attn"] = L.init_attention(r[0], cfg)
    elif kind == "rec":
        p["rec"], a["rec"] = R.init_rglru_block(r[0], cfg)
    elif kind == "rwkv":
        p["mix"], a["mix"] = R.init_rwkv_mix(r[0], cfg)
    else:  # pragma: no cover
        raise KeyError(kind)

    if kind == "rwkv":
        p["chan"], a["chan"] = R.init_rwkv_channel(r[1], cfg)
    elif cfg.is_moe:
        p["moe"], a["moe"] = L.init_moe(r[1], cfg)
    else:
        p["mlp"], a["mlp"] = L.init_mlp(r[1], cfg)

    if cfg.softcap_attn:  # gemma2 sandwich norms
        p["ln1_post"] = jnp.zeros((D,), jnp.float32)
        p["ln2_post"] = jnp.zeros((D,), jnp.float32)
        a["ln1_post"] = (None,)
        a["ln2_post"] = (None,)
    return p, a


def _block(p, x, cfg: ModelConfig, kind: str, pos, state):
    """One block. state: kind-specific decode state or None. Returns
    (x, new_state, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.seq_parallel:
        # residual stream sharded over (dp, model-on-T): norms run local,
        # attention/MLP boundaries become all-gather / reduce-scatter pairs
        # instead of full activation all-reduces (Megatron-SP recast)
        x = hint(x, "dp", "model", None)
    h = L.rms_norm(x, p["ln1"])
    if kind in ("global", "local", "bidir"):
        h, new_state = L.attention(p["attn"], h, cfg, kind, pos, cache=state)
    elif kind == "rec":
        h, new_state = R.rglru_block(p["rec"], h, cfg, state)
    else:  # rwkv
        h, new_state = R.rwkv_mix(p["mix"], h, cfg, state)
    if cfg.softcap_attn:
        h = L.rms_norm(h, p["ln1_post"])
    x = x + h

    h = L.rms_norm(x, p["ln2"])
    if kind == "rwkv":
        h, cstate = R.rwkv_channel(p["chan"], h, cfg, state)
        if new_state is not None:
            new_state = {**new_state, **cstate}
    elif cfg.is_moe:
        h, aux = L.moe_ffn(p["moe"], h, cfg)
    else:
        h = L.mlp(p["mlp"], h, cfg)
    if cfg.softcap_attn:
        h = L.rms_norm(h, p["ln2_post"])
    return x + h, new_state, aux


# ---------------------------------------------------------------------------
# stack planning: scan superblocks + unrolled remainder
# ---------------------------------------------------------------------------

def _map_axes(fn, tree):
    """Map over an axes tree (dicts of tuple leaves — tuples are leaves
    here, unlike in jax.tree_util)."""
    if isinstance(tree, dict):
        return {k: _map_axes(fn, v) for k, v in tree.items()}
    return fn(tree)


def _plan(cfg: ModelConfig):
    P = len(cfg.pattern)
    n_sb = cfg.n_layers // P if cfg.scan_layers else 0
    if n_sb < 2:  # not worth scanning
        n_sb = 0
    rest = cfg.n_layers - n_sb * P
    rest_kinds = tuple(cfg.pattern[(n_sb * P + i) % P] for i in range(rest))
    return P, n_sb, rest_kinds


def init(rng, cfg: ModelConfig):
    D, Vp = cfg.d_model, cfg.vocab_padded
    P, n_sb, rest_kinds = _plan(cfg)
    r = jax.random.split(rng, 4 + len(rest_kinds))

    params: dict = {}
    axes: dict = {}

    params["embed"] = L._init(r[0], (Vp, D), D ** -0.5,
                              jnp.dtype(cfg.param_dtype))
    axes["embed"] = ("vocab", "embed")
    if not cfg.tie_embeddings:
        params["head"] = L._init(r[1], (D, Vp), D ** -0.5,
                                 jnp.dtype(cfg.param_dtype))
        axes["head"] = ("embed", "vocab")
    params["ln_f"] = jnp.zeros((D,), jnp.float32)
    axes["ln_f"] = (None,)

    if n_sb:
        def init_sb(rr):
            ps, as_ = {}, {}
            rs = jax.random.split(rr, P)
            for i, kind in enumerate(cfg.pattern):
                ps[f"b{i}"], as_[f"b{i}"] = _init_block(rs[i], cfg, kind)
            return ps, as_

        sb_rngs = jax.random.split(r[2], n_sb)
        stacked = [init_sb(rr)[0] for rr in sb_rngs]
        params["scan"] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs, axis=0), *stacked)
        _, sb_axes = init_sb(sb_rngs[0])
        axes["scan"] = _map_axes(lambda ax: ("layers",) + ax, sb_axes)
    rest_rngs = r[4:]
    for i, kind in enumerate(rest_kinds):
        params[f"rest{i}"], axes[f"rest{i}"] = _init_block(
            rest_rngs[i], cfg, kind)
    return params, axes


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _embed(params, cfg: ModelConfig, tokens):
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    if cfg.tie_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return hint(x, "dp", None, None)


def _unembed(params, cfg: ModelConfig, x):
    x = L.rms_norm(x, params["ln_f"])
    w = (params["embed"].T if cfg.tie_embeddings else params["head"])
    logits = jnp.einsum("btd,dv->btv", x, w.astype(x.dtype))
    logits = hint(logits.astype(jnp.float32), "dp", None, "model")
    if cfg.softcap_final:
        c = cfg.softcap_final
        logits = c * jnp.tanh(logits / c)
    return logits


def forward(params, cfg: ModelConfig, tokens, caches=None, pos0=None):
    """Full forward.  tokens (B, T).  caches/pos0 given → decode/prefill
    with state.  Returns (logits, new_caches, aux)."""
    B, T = tokens.shape
    P, n_sb, rest_kinds = _plan(cfg)
    if pos0 is None:
        pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    else:
        pos = pos0 + jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None],
                                      (B, T))
    x = _embed(params, cfg, tokens)
    aux_total = jnp.zeros((), jnp.float32)
    new_caches: dict = {}

    if n_sb:
        def body(carry, xs):
            xc, auxc = carry
            ps, st = xs
            new_st = {}
            for i, kind in enumerate(cfg.pattern):
                s_i = st[f"b{i}"] if st is not None else None
                xc, ns, aux = _block(ps[f"b{i}"], xc, cfg, kind, pos, s_i)
                new_st[f"b{i}"] = ns if ns is not None else 0
                auxc = auxc + aux
            return (xc, auxc), new_st

        if cfg.remat and caches is None:
            body = jax.checkpoint(body)
        st = caches["scan"] if caches is not None else None
        if st is None:
            (x, aux_total), _ = jax.lax.scan(
                lambda c, p_: (body(c, (p_, None))[0], None),
                (x, aux_total), params["scan"])
        else:
            (x, aux_total), new_scan_st = jax.lax.scan(
                body, (x, aux_total), (params["scan"], st))
            new_caches["scan"] = new_scan_st

    for i, kind in enumerate(rest_kinds):
        st = caches[f"rest{i}"] if caches is not None else None
        x, ns, aux = _block(params[f"rest{i}"], x, cfg, kind, pos, st)
        aux_total = aux_total + aux
        if ns is not None:
            new_caches[f"rest{i}"] = ns

    logits = _unembed(params, cfg, x)
    return logits, (new_caches if caches is not None else None), aux_total


# ---------------------------------------------------------------------------
# training loss
# ---------------------------------------------------------------------------

def train_loss(params, cfg: ModelConfig, batch, aux_weight: float = 0.01):
    """Causal LM cross-entropy + MoE aux loss.  batch: tokens/labels (B,S)."""
    logits, _, aux = forward(params, cfg, batch["tokens"])
    logz = jax.nn.logsumexp(logits, axis=-1)
    # label pick via masked reduce (NOT take_along_axis): the gather would
    # force GSPMD to all-gather the vocab-sharded logits; the iota-compare
    # fuses into the reduction and keeps every buffer sharded.
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    gold = jnp.sum(jnp.where(iota == batch["labels"][..., None], logits,
                             0.0), axis=-1)
    mask = (batch["labels"] >= 0).astype(jnp.float32)
    ce = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return ce + aux_weight * aux


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def _init_block_state(cfg: ModelConfig, kind: str, B: int, S_max: int):
    N, K = cfg.n_kv_heads, cfg.hd
    dt = jnp.dtype(cfg.cache_dtype or cfg.dtype)
    if kind == "global":
        return L.KVCache(jnp.zeros((B, S_max, N, K), dt),
                         jnp.zeros((B, S_max, N, K), dt),
                         jnp.zeros((), jnp.int32), 0)
    if kind == "local":
        W = min(cfg.window, S_max)
        return L.KVCache(jnp.zeros((B, W, N, K), dt),
                         jnp.zeros((B, W, N, K), dt),
                         jnp.zeros((), jnp.int32), W)
    if kind == "rec":
        return R.init_rglru_state(cfg, B)
    if kind == "rwkv":
        return R.init_rwkv_state(cfg, B)
    raise KeyError(kind)  # pragma: no cover


def init_cache(cfg: ModelConfig, B: int, S_max: int):
    P, n_sb, rest_kinds = _plan(cfg)
    caches: dict = {}
    if n_sb:
        def one_sb():
            return {f"b{i}": _init_block_state(cfg, kind, B, S_max)
                    for i, kind in enumerate(cfg.pattern)}
        caches["scan"] = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (n_sb,) + x.shape),
            one_sb())
    for i, kind in enumerate(rest_kinds):
        caches[f"rest{i}"] = _init_block_state(cfg, kind, B, S_max)
    return caches


def _block_state_axes(cfg: ModelConfig, kind: str, S_max: int):
    """Logical axes for decode state, as comma-joined strings (leaves).
    The KVCache ``window`` aux must match init_cache's (pytree metadata)."""
    if kind in ("global", "local"):
        kv = "batch,time,kv_heads,none"
        w = min(cfg.window, S_max) if kind == "local" else 0
        return L.KVCache(kv, kv, "scalar", w)
    if kind == "rec":
        return {"h": "batch,lru", "conv": "batch,none,lru"}
    if kind == "rwkv":
        return {"S": "batch,heads,none,none", "x_tail": "batch,none",
                "c_tail": "batch,none"}
    raise KeyError(kind)  # pragma: no cover


def cache_axes(cfg: ModelConfig, S_max: int):
    """Mirror of init_cache carrying logical-axis strings — consumed by
    launch/sharding.cache_shardings for decode-cell in_shardings."""
    P, n_sb, rest_kinds = _plan(cfg)
    axes: dict = {}
    if n_sb:
        one = {f"b{i}": _block_state_axes(cfg, kind, S_max)
               for i, kind in enumerate(cfg.pattern)}
        axes["scan"] = jax.tree_util.tree_map(lambda s: "layers," + s, one)
    for i, kind in enumerate(rest_kinds):
        axes[f"rest{i}"] = _block_state_axes(cfg, kind, S_max)
    return axes


def decode_step(params, cfg: ModelConfig, caches, tokens, pos):
    """One decode step.  tokens (B, 1); pos int32 scalar (context length so
    far).  Returns (logits (B,1,V), new_caches)."""
    logits, new_caches, _ = forward(params, cfg, tokens, caches=caches,
                                    pos0=pos)
    return logits, new_caches


def prefill(params, cfg: ModelConfig, tokens, max_len: int = None):
    """Prefill: run the full prompt through the model building caches sized
    for ``max_len`` total tokens (prompt + decode budget)."""
    B, S = tokens.shape
    caches = init_cache(cfg, B, max_len or S)
    logits, new_caches, _ = forward(params, cfg, tokens, caches=caches,
                                    pos0=jnp.zeros((), jnp.int32))
    return logits[:, -1:], new_caches
