"""Unified model façade: ``build_model(cfg)`` → init / loss / serve fns and
dry-run input specs for every architecture family."""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, Shape
from . import encdec, transformer

__all__ = ["Model", "build_model"]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable                  # rng -> (params, axes)
    train_loss: Callable            # (params, batch) -> scalar
    prefill: Callable               # (params, **inputs) -> (logits, cache, ...)
    decode_step: Callable           # (params, cache, tokens, pos, ...) -> ...
    init_cache: Callable            # (B, S_max) -> cache
    input_specs: Callable           # Shape -> dict of ShapeDtypeStruct
    cache_axes: Callable = None     # () -> logical-axis strings tree

    def param_count(self, params) -> int:
        return sum(x.size for x in jax.tree_util.tree_leaves(params))


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == "encdec":
        return _build_encdec(cfg)
    return _build_lm(cfg)


def _token_specs(cfg: ModelConfig, shape: Shape) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if shape.kind == "prefill":
        return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    # decode: one new token against an S-long cache
    cache = jax.eval_shape(
        lambda: transformer.init_cache(cfg, B, S))
    return {"caches": cache,
            "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}


def _build_lm(cfg: ModelConfig) -> Model:
    return Model(
        cfg=cfg,
        init=functools.partial(transformer.init, cfg=cfg),
        train_loss=lambda params, batch: transformer.train_loss(
            params, cfg, batch),
        prefill=lambda params, tokens, max_len=None: transformer.prefill(
            params, cfg, tokens, max_len),
        decode_step=lambda params, caches, tokens, pos: (
            transformer.decode_step(params, cfg, caches, tokens, pos)),
        init_cache=functools.partial(transformer.init_cache, cfg),
        input_specs=functools.partial(_token_specs, cfg),
        cache_axes=functools.partial(transformer.cache_axes, cfg),
    )


def _encdec_specs(cfg: ModelConfig, shape: Shape) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    frames = jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model),
                                  jnp.dtype(cfg.dtype))
    if shape.kind == "train":
        return {"frames": frames,
                "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if shape.kind == "prefill":
        return {"frames": frames,
                "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    cache = jax.eval_shape(lambda: encdec.init_cache(cfg, B, S))
    return {"caches": cache,
            "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
            "enc_out": jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model),
                                            jnp.dtype(cfg.dtype))}


def _build_encdec(cfg: ModelConfig) -> Model:
    return Model(
        cfg=cfg,
        init=functools.partial(encdec.init, cfg=cfg),
        train_loss=lambda params, batch: encdec.train_loss(
            params, cfg, {"frames": batch["frames"],
                          "tokens": batch["tokens"],
                          "labels": batch["labels"]}),
        prefill=lambda params, tokens, frames, max_len=None: encdec.prefill(
            params, cfg, tokens, frames, max_len),
        decode_step=lambda params, caches, tokens, pos, enc_out: (
            encdec.decode_step(params, cfg, caches, tokens, pos, enc_out)),
        init_cache=functools.partial(encdec.init_cache, cfg),
        input_specs=functools.partial(_encdec_specs, cfg),
        cache_axes=functools.partial(encdec.cache_axes, cfg),
    )
