"""Whisper-style encoder-decoder backbone.

Per the assignment, the conv/mel frontend is a STUB: ``input_specs()``
supplies precomputed audio-frame embeddings (B, enc_seq, D) — what the two
conv layers would produce — and the encoder adds sinusoidal positions.
The decoder is a standard causal self-attn + cross-attn stack.  Whisper's
learned absolute positions cap at 448 decoder tokens; the assigned shapes
drive the decoder to 32k, so positions use RoPE on self-attention instead
(recorded hardware/shape adaptation — lets the backbone honor the assigned
shape grid without a 32k learned table).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from . import layers as L
from .shardctx import hint
from .transformer import _map_axes

__all__ = ["init", "forward_encoder", "train_loss", "init_cache",
           "decode_step", "prefill"]


def _init_enc_block(rng, cfg):
    r = jax.random.split(rng, 2)
    p = {"ln1": jnp.zeros((cfg.d_model,), jnp.float32),
         "ln2": jnp.zeros((cfg.d_model,), jnp.float32)}
    a = {"ln1": (None,), "ln2": (None,)}
    p["attn"], a["attn"] = L.init_attention(r[0], cfg)
    p["mlp"], a["mlp"] = L.init_mlp(r[1], cfg)
    return p, a


def _init_dec_block(rng, cfg):
    r = jax.random.split(rng, 3)
    p = {"ln1": jnp.zeros((cfg.d_model,), jnp.float32),
         "lnx": jnp.zeros((cfg.d_model,), jnp.float32),
         "ln2": jnp.zeros((cfg.d_model,), jnp.float32)}
    a = {"ln1": (None,), "lnx": (None,), "ln2": (None,)}
    p["attn"], a["attn"] = L.init_attention(r[0], cfg)
    p["xattn"], a["xattn"] = L.init_attention(r[1], cfg)
    p["mlp"], a["mlp"] = L.init_mlp(r[2], cfg)
    return p, a


def init(rng, cfg: ModelConfig):
    D, Vp = cfg.d_model, cfg.vocab_padded
    r = jax.random.split(rng, 4)
    params = {"embed": L._init(r[0], (Vp, D), D ** -0.5,
                               jnp.dtype(cfg.param_dtype)),
              "ln_f": jnp.zeros((D,), jnp.float32),
              "ln_enc": jnp.zeros((D,), jnp.float32)}
    axes = {"embed": ("vocab", "embed"), "ln_f": (None,), "ln_enc": (None,)}

    def stack(rr, n, init_fn):
        rs = jax.random.split(rr, n)
        ps = [init_fn(x, cfg)[0] for x in rs]
        _, ax = init_fn(rs[0], cfg)
        return (jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ps),
                _map_axes(lambda t: ("layers",) + t, ax))

    params["enc"], axes["enc"] = stack(r[1], cfg.n_enc_layers,
                                       _init_enc_block)
    params["dec"], axes["dec"] = stack(r[2], cfg.n_layers, _init_dec_block)
    return params, axes


def _sinusoid(T: int, D: int):
    pos = np.arange(T)[:, None]
    i = np.arange(D // 2)[None, :]
    ang = pos / (10_000 ** (2 * i / D))
    return jnp.asarray(np.concatenate([np.sin(ang), np.cos(ang)], -1),
                       jnp.float32)


def forward_encoder(params, cfg: ModelConfig, frames):
    """frames: (B, S_audio, D) precomputed frame embeddings (frontend stub)."""
    B, S, D = frames.shape
    x = frames.astype(jnp.dtype(cfg.dtype)) + _sinusoid(S, D).astype(
        jnp.dtype(cfg.dtype))
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(xc, ps):
        if cfg.seq_parallel:  # head-unshardable fallback (DESIGN.md §4)
            xc = hint(xc, "dp", "model", None)
        h = L.rms_norm(xc, ps["ln1"])
        h, _ = L.attention(ps["attn"], h, cfg, "bidir", pos)
        xc = xc + h
        h = L.rms_norm(xc, ps["ln2"])
        xc = xc + L.mlp(ps["mlp"], h, cfg)
        return xc, None

    if cfg.remat:
        body = jax.checkpoint(body)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(lambda c, p: body(c, p), x, params["enc"])
    else:  # unrolled (dry-run cost accounting)
        for i in range(cfg.n_enc_layers):
            x, _ = body(x, jax.tree_util.tree_map(
                lambda a: a[i], params["enc"]))
    return L.rms_norm(x, params["ln_enc"])


def _decoder(params, cfg: ModelConfig, tokens, enc_out, caches=None,
             pos0=None):
    B, T = tokens.shape
    if pos0 is None:
        pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    else:
        pos = pos0 + jnp.broadcast_to(
            jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))

    def body(carry, xs):
        xc = carry
        ps, st = xs
        if cfg.seq_parallel and xc.shape[1] > 1:
            xc = hint(xc, "dp", "model", None)
        h = L.rms_norm(xc, ps["ln1"])
        h, ns = L.attention(ps["attn"], h, cfg, "global", pos, cache=st)
        xc = xc + h
        h = L.rms_norm(xc, ps["lnx"])
        h, _ = L.attention(ps["xattn"], h, cfg, "cross", pos, kv_x=enc_out)
        xc = xc + h
        h = L.rms_norm(xc, ps["ln2"])
        xc = xc + L.mlp(ps["mlp"], h, cfg)
        return xc, (ns if ns is not None else 0)

    if cfg.remat and caches is None:
        body = jax.checkpoint(body)
    if caches is None:
        if cfg.scan_layers:
            x, _ = jax.lax.scan(lambda c, p: (body(c, (p, None))[0], None),
                                x, params["dec"])
        else:  # unrolled (dry-run cost accounting)
            for i in range(cfg.n_layers):
                x, _ = body(x, (jax.tree_util.tree_map(
                    lambda a: a[i], params["dec"]), None))
        new_caches = None
    elif cfg.scan_layers:
        x, new_st = jax.lax.scan(body, x, (params["dec"], caches["dec"]))
        new_caches = {"dec": new_st}
    else:  # unrolled with per-layer cache slices (dry-run cost accounting)
        sts = []
        for i in range(cfg.n_layers):
            sl = jax.tree_util.tree_map(lambda a: a[i], caches["dec"])
            x, ns = body(x, (jax.tree_util.tree_map(
                lambda a: a[i], params["dec"]), sl))
            sts.append(ns)
        new_caches = {"dec": jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *sts)}

    x = L.rms_norm(x, params["ln_f"])
    logits = jnp.einsum("btd,dv->btv", x,
                        params["embed"].T.astype(x.dtype))
    logits = hint(logits.astype(jnp.float32), "dp", None, "model")
    return logits, new_caches


def train_loss(params, cfg: ModelConfig, batch, aux_weight: float = 0.0):
    """batch: frames (B,S_audio,D), tokens (B,S), labels (B,S)."""
    enc = forward_encoder(params, cfg, batch["frames"])
    logits, _ = _decoder(params, cfg, batch["tokens"], enc)
    logz = jax.nn.logsumexp(logits, axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    gold = jnp.sum(jnp.where(iota == batch["labels"][..., None], logits,
                             0.0), axis=-1)
    mask = (batch["labels"] >= 0).astype(jnp.float32)
    return jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def init_cache(cfg: ModelConfig, B: int, S_max: int):
    N, K = cfg.n_kv_heads, cfg.hd
    dt = jnp.dtype(cfg.cache_dtype or cfg.dtype)
    one = L.KVCache(jnp.zeros((B, S_max, N, K), dt),
                    jnp.zeros((B, S_max, N, K), dt),
                    jnp.zeros((), jnp.int32), 0)
    return {"dec": jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_layers,) + x.shape), one)}


def cache_axes(cfg: ModelConfig, S_max: int):
    kv = "layers,batch,time,kv_heads,none"
    return {"dec": L.KVCache(kv, kv, "layers,scalar", 0)}


def prefill(params, cfg: ModelConfig, tokens, frames, max_len: int = None):
    enc = forward_encoder(params, cfg, frames)
    caches = init_cache(cfg, tokens.shape[0], max_len or tokens.shape[1])
    logits, new_caches = _decoder(params, cfg, tokens, enc, caches=caches,
                                  pos0=jnp.zeros((), jnp.int32))
    return logits[:, -1:], new_caches, enc


def decode_step(params, cfg: ModelConfig, caches, tokens, pos, enc_out):
    logits, new_caches = _decoder(params, cfg, tokens, enc_out,
                                  caches=caches, pos0=pos)
    return logits, new_caches
