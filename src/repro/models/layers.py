"""Model-layer primitives shared by all 10 assigned architectures.

Pure-function JAX (no flax): every layer is ``init(rng, cfg) -> (params,
axes)`` + ``apply(params, x, ...)``.  ``axes`` mirrors ``params`` with
logical-axis name tuples used by launch/sharding.py to build NamedShardings
(("embed", "mlp") → P("data", "model") etc.) — the standard logical/physical
split production frameworks use so one model definition serves every mesh.

Conventions: B batch, T query time, S key time, D d_model, F d_ff,
H q-heads, N kv-heads, G = H//N group size, K head_dim, E experts, C expert
capacity.  Params are ``param_dtype``; activations ``dtype``; softmax/norm
statistics in f32.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig

__all__ = [
    "rms_norm", "layer_norm", "rope", "init_attention", "attention",
    "init_mlp", "mlp", "init_moe", "moe_ffn", "KVCache",
]


def _dt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _pdt(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def _init(rng, shape, scale, dtype):
    return (jax.random.normal(rng, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms & rope
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + w.astype(jnp.float32))
            ).astype(x.dtype)


def layer_norm(x, w, b, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def rope(x, positions, theta: float):
    """Rotary embedding. x: (B, T, n, K); positions: (B, T) or (T,)."""
    K = x.shape[-1]
    half = K // 2
    freq = theta ** (-np.arange(0, half, dtype=np.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freq  # (B, T, half)
    sin, cos = jnp.sin(ang)[:, :, None, :], jnp.cos(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA; global / sliding-local / bidirectional / cross)
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class KVCache:
    """Decode-time KV cache.

    Global layers: ``k``/``v`` are (B, S_max, N, K), absolute slots.
    Local layers:  (B, window, N, K) rolling buffers (oldest first).
    ``pos`` is the number of tokens already cached.
    """

    k: jax.Array
    v: jax.Array
    pos: jax.Array  # int32 scalar
    window: int = 0  # 0 == global

    def tree_flatten(self):
        return (self.k, self.v, self.pos), (self.window,)

    @classmethod
    def tree_unflatten(cls, aux, ch):
        return cls(ch[0], ch[1], ch[2], aux[0])


def init_attention(rng, cfg: ModelConfig):
    D, H, N, K = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    r = jax.random.split(rng, 4)
    s = D ** -0.5
    p = {
        "wq": _init(r[0], (D, H, K), s, _pdt(cfg)),
        "wk": _init(r[1], (D, N, K), s, _pdt(cfg)),
        "wv": _init(r[2], (D, N, K), s, _pdt(cfg)),
        "wo": _init(r[3], (H, K, D), (H * K) ** -0.5, _pdt(cfg)),
    }
    a = {
        "wq": ("embed", "heads", None),
        "wk": ("embed", "kv_heads", None),
        "wv": ("embed", "kv_heads", None),
        "wo": ("heads", None, "embed"),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((K,), _pdt(cfg))
        p["k_norm"] = jnp.zeros((K,), _pdt(cfg))
        a["q_norm"] = (None,)
        a["k_norm"] = (None,)
    return p, a


def _mask(kind: str, q_pos, k_pos, window: int):
    """Additive mask from absolute positions. q_pos (B,T), k_pos (B,S)."""
    ok = k_pos[:, None, :] >= 0
    if kind in ("global", "local"):
        ok = ok & (k_pos[:, None, :] <= q_pos[:, :, None])
    if kind == "local":
        ok = ok & (k_pos[:, None, :] > q_pos[:, :, None] - window)
    return jnp.where(ok, 0.0, -1e30)  # (B, T, S)


def attention(p, x, cfg: ModelConfig, kind: str, q_pos,
              cache: Optional[KVCache] = None,
              kv_x: Optional[jax.Array] = None,
              kv_pos: Optional[jax.Array] = None):
    """GQA attention.

    kind: 'global' (causal) | 'local' (causal sliding window) |
          'bidir' (encoder) | 'cross' (decoder→encoder, needs kv_x).
    q_pos: (B, T) absolute positions of the query tokens.
    cache: decode-time KV cache (self-attention kinds only); updated
           functionally and returned.
    """
    B, T, D = x.shape
    H, N, K = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    G = H // N

    q = jnp.einsum("btd,dhk->bthk", x, p["wq"].astype(x.dtype))
    src = x if kv_x is None else kv_x
    k = jnp.einsum("bsd,dnk->bsnk", src, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dnk->bsnk", src, p["wv"].astype(x.dtype))

    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])

    use_rope = kind in ("global", "local")
    if use_rope:
        q = rope(q, q_pos, cfg.rope_theta)
        k = rope(k, q_pos if kv_pos is None else kv_pos, cfg.rope_theta)

    cache_dt = jnp.dtype(cfg.cache_dtype or cfg.dtype)
    new_cache = None
    if cache is not None and T > 1:
        # one-shot prefill from an empty cache: attend over the chunk's own
        # k/v (full context), then write the cache tail.  Local caches are
        # RING buffers (slot = position % W) so that decode-time writes are
        # O(1) aliasable dynamic_update_slices, never full-buffer rolls.
        kq, vq = k.astype(cache_dt), v.astype(cache_dt)
        if cache.window:
            W = cache.window
            if T >= W:
                ck = jnp.roll(kq[:, -W:], (T - W) % W, axis=1)
                cv = jnp.roll(vq[:, -W:], (T - W) % W, axis=1)
            else:
                ck = cache.k.at[:, :T].set(kq)
                cv = cache.v.at[:, :T].set(vq)
            new_cache = KVCache(ck, cv, cache.pos + T, W)
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(cache.k, kq, cache.pos,
                                                     1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache.v, vq, cache.pos,
                                                     1)
            new_cache = KVCache(ck, cv, cache.pos + T, 0)
        k_pos = q_pos if kv_pos is None else kv_pos
    elif cache is not None:  # T == 1: decode against the cache
        if cache.window:  # ring buffer: write slot pos % W (in-place alias)
            W = cache.window
            slot = cache.pos % W
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache.k, k.astype(cache_dt), slot, 1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache.v, v.astype(cache_dt), slot, 1)
            new_cache = KVCache(ck, cv, cache.pos + 1, W)
            k, v = ck, cv
            # slot i holds the latest position ≡ i (mod W) that is ≤ pos
            i = jnp.arange(W)[None, :]
            k_pos = (cache.pos - ((cache.pos - i) % W)) * jnp.ones(
                (B, 1), jnp.int32)
        else:
            S = cache.k.shape[1]
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache.k, k.astype(cache_dt), cache.pos, 1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache.v, v.astype(cache_dt), cache.pos, 1)
            new_cache = KVCache(ck, cv, cache.pos + T, 0)
            k, v = ck, cv
            k_pos = jnp.arange(S)[None, :] * jnp.ones((B, 1), jnp.int32)
            k_pos = jnp.where(k_pos < cache.pos + T, k_pos, -1)
    else:
        if kind == "cross":
            S = src.shape[1]
            k_pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        else:
            k_pos = q_pos if kv_pos is None else kv_pos

    qg = q.reshape(B, T, N, G, K)
    k, v = k.astype(x.dtype), v.astype(x.dtype)  # upcast quantized cache
    mask_kind = "bidir" if kind in ("cross", "bidir") else kind
    S = k.shape[1]
    if S > _CHUNKED_KV_THRESHOLD and T > 1:
        out = _attn_chunked(qg, k, v, cfg, mask_kind, q_pos, k_pos)
    else:
        scores = jnp.einsum("btngk,bsnk->bntgs", qg, k).astype(jnp.float32)
        scores = scores * (K ** -0.5)
        if cfg.softcap_attn:
            c = cfg.softcap_attn
            scores = c * jnp.tanh(scores / c)
        m = _mask(mask_kind, q_pos, k_pos, cfg.window)
        scores = scores + m[:, None, :, None, :]  # (B,N,T,G,S)
        w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = jnp.einsum("bntgs,bsnk->btngk", w, v)
    out = out.reshape(B, T, H, K)
    out = jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(x.dtype))
    return out, new_cache


_CHUNKED_KV_THRESHOLD = 2048   # dense scores up to 2k keys; flash beyond
_KV_CHUNK = 1024


def _attn_chunked(qg, k, v, cfg: ModelConfig, mask_kind: str, q_pos, k_pos,
                  chunk: int = _KV_CHUNK):
    """Online-softmax (flash-style) attention over KV chunks.

    Never materializes the (T, S) score matrix: a ``lax.scan`` over key
    chunks carries the running max ``m``, normalizer ``l`` and accumulator —
    the standard memory-efficient attention, in pure JAX so it lowers on any
    backend (the Pallas TPU kernel version is a recorded §Perf candidate;
    this formulation already bounds memory to O(T·chunk)).
    """
    B, T, N, G, K = qg.shape
    S = k.shape[1]
    assert S % chunk == 0, (S, chunk)
    nC = S // chunk
    kc = k.reshape(B, nC, chunk, N, K).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nC, chunk, N, K).transpose(1, 0, 2, 3, 4)
    kpc = k_pos.reshape(B, nC, chunk).transpose(1, 0, 2)
    scale = K ** -0.5
    q32 = qg.astype(jnp.float32)

    def step(carry, xs):
        m_prev, l_prev, acc = carry
        kb, vb, kpb = xs
        s = jnp.einsum("btngk,bsnk->bntgs", q32,
                       kb.astype(jnp.float32)) * scale
        if cfg.softcap_attn:
            c = cfg.softcap_attn
            s = c * jnp.tanh(s / c)
        mask = _mask(mask_kind, q_pos, kpb, cfg.window)
        s = s + mask[:, None, :, None, :]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p_ = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p_, axis=-1)
        acc = (acc * corr[..., None]
               + jnp.einsum("bntgs,bsnk->bntgk", p_,
                            vb.astype(jnp.float32)))
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, N, T, G), -1e30, jnp.float32)
    l0 = jnp.zeros((B, N, T, G), jnp.float32)
    acc0 = jnp.zeros((B, N, T, G, K), jnp.float32)
    (m_f, l_f, acc), _ = jax.lax.scan(step, (m0, l0, acc0), (kc, vc, kpc))
    out = acc / jnp.maximum(l_f, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3, 4).astype(qg.dtype)  # (B,T,N,G,K)


# ---------------------------------------------------------------------------
# dense MLP
# ---------------------------------------------------------------------------

_ACTS = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu_sq": lambda x: jnp.square(jax.nn.relu(x)),
}


def init_mlp(rng, cfg: ModelConfig):
    D, F = cfg.d_model, cfg.d_ff
    r = jax.random.split(rng, 3)
    p = {"wi": _init(r[0], (D, F), D ** -0.5, _pdt(cfg)),
         "wo": _init(r[1], (F, D), F ** -0.5, _pdt(cfg))}
    a = {"wi": ("embed", "mlp"), "wo": ("mlp", "embed")}
    if cfg.mlp_gated:
        p["wg"] = _init(r[2], (D, F), D ** -0.5, _pdt(cfg))
        a["wg"] = ("embed", "mlp")
    return p, a


def mlp(p, x, cfg: ModelConfig):
    act = _ACTS[cfg.mlp_act]
    h = jnp.einsum("btd,df->btf", x, p["wi"].astype(x.dtype))
    if cfg.mlp_gated:
        g = jnp.einsum("btd,df->btf", x, p["wg"].astype(x.dtype))
        h = act(g) * h
    else:
        h = act(h)
    return jnp.einsum("btf,fd->btd", h, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# Mixture of Experts (top-k, capacity-dropped, sort-based dispatch)
# ---------------------------------------------------------------------------

def init_moe(rng, cfg: ModelConfig):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    r = jax.random.split(rng, 4)
    p = {
        "router": _init(r[0], (D, E), D ** -0.5, jnp.float32),
        "wi": _init(r[1], (E, D, F), D ** -0.5, _pdt(cfg)),
        "wg": _init(r[2], (E, D, F), D ** -0.5, _pdt(cfg)),
        "wo": _init(r[3], (E, F, D), F ** -0.5, _pdt(cfg)),
    }
    a = {
        "router": ("embed", None),
        "wi": ("expert", "embed", "mlp_moe"),
        "wg": ("expert", "embed", "mlp_moe"),
        "wo": ("expert", "mlp_moe", "embed"),
    }
    return p, a


_MOE_GROUPS = 32  # dispatch groups; a multiple of every DP degree we run


def moe_ffn(p, x, cfg: ModelConfig):
    """Grouped sort-based top-k MoE (GShard-style capacity drops, MegaBlocks
    style sorted dispatch).  Returns (y, aux_loss).

    Tokens are split into G dispatch groups (G a multiple of the DP degree,
    so each group is shard-local under pjit): sort/positioning/scatter are
    vmapped per group — WITHOUT grouping, the argsort/cumsum would be over
    the globally-sharded token axis and GSPMD would all-gather every
    activation to one giant sort (§Perf iteration 0's 81 GB/device MoE
    temp).  The grouped (G, E, C, D) buffer is sharding-hinted
    (dp over G, model over E), which makes the dispatch an all-to-all —
    the canonical TPU MoE pattern.  Per-group capacity drops are exactly
    GShard semantics; groups with ≤64 tokens (decode) get dropless capacity
    so step-by-step decode stays bit-consistent with parallel prefill.
    """
    from .shardctx import hint

    B, T, D = x.shape
    E, K = cfg.n_experts, cfg.topk
    N = B * T
    G = math.gcd(_MOE_GROUPS, N)
    Ng = N // G
    xf = x.reshape(G, Ng, D)

    logits = jnp.einsum("gnd,de->gne", xf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, sel = jax.lax.top_k(probs, K)                        # (G, Ng, K)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    # aux load-balance loss (Switch): E * Σ_e f_e · P_e (global)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.zeros((E,), jnp.float32).at[sel.reshape(-1)].add(
        jnp.ones((N * K,), jnp.float32)) / (N * K)
    aux = E * jnp.sum(me * ce)

    if Ng <= 64:
        C = Ng * K              # dropless (decode-scale groups)
    else:
        C = max(int(cfg.capacity_factor * Ng * K / E), 1)

    def dispatch_combine(xg, selg, gateg):
        """One group: (Ng, D), (Ng, K), (Ng, K) -> (E, C, D) buffer + meta."""
        sel_f = selg.reshape(-1)                               # (Ng*K,)
        order = jnp.argsort(sel_f)
        sorted_e = sel_f[order]
        token_of = order // K
        counts = jnp.bincount(sel_f, length=E)
        starts = jnp.cumsum(counts) - counts
        pos_in_e = jnp.arange(Ng * K) - starts[sorted_e]
        keep = pos_in_e < C
        slot = jnp.where(keep, sorted_e * C + pos_in_e, E * C)
        buf = jnp.zeros((E * C + 1, D), xg.dtype).at[slot].set(
            xg[token_of] * keep[:, None].astype(xg.dtype))
        w = gateg.reshape(-1)[order].astype(xg.dtype)
        return buf[:-1].reshape(E, C, D), (token_of, slot, keep, w)

    buf, meta = jax.vmap(dispatch_combine)(xf, sel, gate)      # (G, E, C, D)
    buf = hint(buf, "dp", "model", None, None)                 # all-to-all

    h = jnp.einsum("gecd,edf->gecf", buf, p["wi"].astype(x.dtype))
    g = jnp.einsum("gecd,edf->gecf", buf, p["wg"].astype(x.dtype))
    h = _ACTS[cfg.mlp_act](g) * h
    y = jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(x.dtype))
    y = hint(y, "dp", "model", None, None)

    def combine(yg, m):
        token_of, slot, keep, w = m
        y_tok = yg.reshape(E * C, D)
        gathered = jnp.where(keep[:, None],
                             y_tok[jnp.clip(slot, 0, E * C - 1)], 0.0)
        return jnp.zeros((Ng, D), x.dtype).at[token_of].add(
            gathered * w[:, None])

    out = jax.vmap(combine)(y, meta)                           # (G, Ng, D)
    return out.reshape(B, T, D), aux
