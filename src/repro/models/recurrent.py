"""Recurrent token-mix blocks: RG-LRU (Griffin/recurrentgemma) and RWKV-6.

Both are *time recurrences* — the same mathematical shape as TiLT's
partitioned stream execution: a chunk of timeline plus a carried boundary
state.  The RG-LRU uses a log-depth ``associative_scan`` (diagonal linear
recurrence → TPU-friendly); RWKV-6's matrix-state recurrence with
data-dependent per-channel decay runs as a sequential ``lax.scan`` over
time with the state carried per chunk (the numerically-stable form; the
chunk-parallel GLA decomposition is a recorded hillclimb candidate —
see EXPERIMENTS.md §Perf).

Decode-time state:
* RG-LRU:  ``h`` (B, W) recurrent state + ``conv`` (B, cw-1, W) tail.
* RWKV-6:  ``S`` (B, H, K, K) matrix state + token-shift tail (B, D).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import _init, _pdt, rms_norm

__all__ = ["init_rglru_block", "rglru_block", "init_rwkv_mix", "rwkv_mix",
           "init_rwkv_channel", "rwkv_channel"]

_C_RGLRU = 8.0  # Griffin's fixed recurrence sharpness constant


# ---------------------------------------------------------------------------
# RG-LRU recurrent block (Griffin):  conv1d → gated diagonal linear RNN
# ---------------------------------------------------------------------------

def init_rglru_block(rng, cfg: ModelConfig):
    D = cfg.d_model
    W = cfg.lru_width or D
    cw = cfg.conv_width
    r = jax.random.split(rng, 7)
    p = {
        "wx": _init(r[0], (D, W), D ** -0.5, _pdt(cfg)),    # branch in-proj
        "wy": _init(r[1], (D, W), D ** -0.5, _pdt(cfg)),    # gate branch
        "conv_w": _init(r[2], (cw, W), cw ** -0.5, _pdt(cfg)),
        "conv_b": jnp.zeros((W,), _pdt(cfg)),
        "wa": _init(r[3], (W, W), W ** -0.5, _pdt(cfg)),    # recurrence gate
        "wi": _init(r[4], (W, W), W ** -0.5, _pdt(cfg)),    # input gate
        # Λ init so a = σ(Λ)^c spreads over (0.9, 0.999) as in the paper
        "lam": (jax.random.uniform(r[5], (W,), jnp.float32,
                                   0.9 ** (1 / _C_RGLRU),
                                   0.999 ** (1 / _C_RGLRU))),
        "wo": _init(r[6], (W, D), W ** -0.5, _pdt(cfg)),
    }
    a = {
        "wx": ("embed", "lru"), "wy": ("embed", "lru"),
        "conv_w": (None, "lru"), "conv_b": ("lru",),
        "wa": ("lru_in", "lru"), "wi": ("lru_in", "lru"),
        "lam": ("lru",), "wo": ("lru", "embed"),
    }
    return p, a


def _causal_conv(x, w, b, tail: Optional[jax.Array]):
    """Depthwise causal conv along time. x (B,T,W); w (cw,W); tail (B,cw-1,W)."""
    cw = w.shape[0]
    if tail is None:
        xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
              for i in range(cw))
    return out + b.astype(x.dtype), xp[:, -(cw - 1):]


def rglru_block(p, x, cfg: ModelConfig, state: Optional[dict] = None):
    """Griffin recurrent block.  Returns (y, new_state)."""
    B, T, D = x.shape
    u = jnp.einsum("btd,dw->btw", x, p["wx"].astype(x.dtype))
    gate = jax.nn.gelu(jnp.einsum("btd,dw->btw", x, p["wy"].astype(x.dtype)))

    tail = state["conv"] if state is not None else None
    u, new_tail = _causal_conv(u, p["conv_w"], p["conv_b"], tail)

    r = jax.nn.sigmoid(jnp.einsum(
        "btw,wv->btv", u, p["wa"].astype(u.dtype)).astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum(
        "btw,wv->btv", u, p["wi"].astype(u.dtype)).astype(jnp.float32))
    log_lam = jnp.log(jnp.clip(p["lam"], 1e-6, 1 - 1e-6))  # log σ-free param
    log_a = _C_RGLRU * r * log_lam[None, None, :]            # (B,T,W) ≤ 0
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) computed stably via expm1
    mult = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    b = (mult * i * u.astype(jnp.float32))

    if T == 1 and state is not None:
        h = a[:, 0] * state["h"] + b[:, 0]
        hs = h[:, None, :]
        new_h = h
    else:
        def comb(l, rt):
            return (l[0] * rt[0], l[1] * rt[0] + rt[1])
        a0, b0 = a, b
        if state is not None:  # inject carried state via the first step
            b0 = b0.at[:, 0].add(a0[:, 0] * state["h"])
        _, hs = jax.lax.associative_scan(comb, (a0, b0), axis=1)
        new_h = hs[:, -1]

    y = hs.astype(x.dtype) * gate
    out = jnp.einsum("btw,wd->btd", y, p["wo"].astype(x.dtype))
    return out, {"h": new_h, "conv": new_tail}


def init_rglru_state(cfg: ModelConfig, batch: int):
    W = cfg.lru_width or cfg.d_model
    return {"h": jnp.zeros((batch, W), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, W),
                              jnp.dtype(cfg.dtype))}


# ---------------------------------------------------------------------------
# RWKV-6 token mix (Finch): matrix state, data-dependent per-channel decay
# ---------------------------------------------------------------------------

def init_rwkv_mix(rng, cfg: ModelConfig):
    D, H, K = cfg.d_model, cfg.n_heads, cfg.hd
    assert H * K == D, "rwkv6 head_dim * heads must equal d_model"
    r = jax.random.split(rng, 9)
    lora = 64
    p = {
        "mu_r": jnp.full((D,), 0.5, _pdt(cfg)),
        "mu_k": jnp.full((D,), 0.5, _pdt(cfg)),
        "mu_v": jnp.full((D,), 0.5, _pdt(cfg)),
        "mu_w": jnp.full((D,), 0.5, _pdt(cfg)),
        "mu_g": jnp.full((D,), 0.5, _pdt(cfg)),
        "wr": _init(r[0], (D, D), D ** -0.5, _pdt(cfg)),
        "wk": _init(r[1], (D, D), D ** -0.5, _pdt(cfg)),
        "wv": _init(r[2], (D, D), D ** -0.5, _pdt(cfg)),
        "wg": _init(r[3], (D, D), D ** -0.5, _pdt(cfg)),
        # data-dependent decay LoRA: w_t = exp(-exp(w0 + tanh(x A) B))
        "w0": _init(r[4], (D,), 0.5, jnp.float32) - 5.0,
        "wA": _init(r[5], (D, lora), D ** -0.5, _pdt(cfg)),
        "wB": _init(r[6], (lora, D), lora ** -0.5, _pdt(cfg)),
        "u": _init(r[7], (H, K), 0.5, jnp.float32),  # bonus for current token
        "ln_w": jnp.ones((D,), _pdt(cfg)),           # per-head group norm
        "wo": _init(r[8], (D, D), D ** -0.5, _pdt(cfg)),
    }
    a = {k: (("embed", "heads_rw") if v.ndim == 2 and v.shape == (D, D)
             else tuple([None] * v.ndim)) for k, v in p.items()}
    a["wo"] = ("heads_rw", "embed")
    return p, a


_RWKV_CHUNK = 32


def _rwkv_chunked(r, k, v, logw, S0, u, L: int):
    """Chunk-parallel RWKV-6 recurrence (GLA-style, stable form).

    The token-by-token scan reads+writes the (B,H,K,K) matrix state from
    HBM every step — ~2·B·H·K²·4 bytes × T per layer, the dominant memory
    term of rwkv6 prefill (§Perf cell c).  This form carries the state once
    per L-token chunk (HBM traffic ÷L) and computes within-chunk
    interactions as dense attention-like contractions (MXU work):

        A[t,s] = Σ_c r[t,c]·k[s,c]·exp(LW[t−1,c] − LW[s,c])   (s < t)
        A[t,t] = r_t·(u ⊙ k_t)
        o      = A @ v
        S'     = exp(LW[L]) ⊙ S + Σ_s (k_s ⊙ exp(LW[L]−LW[s])) v_sᵀ

    Numerical stability: every exponent is a *difference* of cumulative
    log-decays over a suffix of the chunk, hence ≤ 0 — no overflow, unlike
    the separable exp(LW_t)·exp(−LW_s) factorization.  This mirrors TiLT's
    partitioned streams: the chunk is the partition, S is the carried
    boundary state.
    """
    B, T, H, K = r.shape
    nC = T // L

    def resh(x):  # (B,T,H,K) -> (nC, B, L, H, K)
        return jnp.moveaxis(x.reshape(B, nC, L, H, K), 1, 0)

    rc, kc, vc, wc = resh(r), resh(k), resh(v), resh(logw)
    tri = jnp.tril(jnp.ones((L, L), jnp.float32), k=-1)     # strict s < t

    def chunk(S, xs):
        rb, kb, vb, wb = xs                                  # (B,L,H,K)
        lw = jnp.cumsum(wb, axis=1)                          # LW_t inclusive
        lw_prev = lw - wb                                    # LW_{t-1}
        # pairwise decayed scores (exponent ≤ 0 by construction)
        diff = lw_prev[:, :, None] - lw[:, None, :]          # (B,L,L,H,K)
        pair = (rb[:, :, None] * kb[:, None, :]) * jnp.exp(
            jnp.minimum(diff, 0.0))
        A = jnp.einsum("blmhk->bhlm", pair)                  # sum over K
        A = A * tri[None, None]
        diag = jnp.einsum("blhk,hk,blhk->blh", rb, u, kb)    # bonus term
        o = (jnp.einsum("bhlm,bmhv->blhv", A, vb)
             + diag[..., None] * vb)
        # cross-chunk contribution from the carried state
        o = o + jnp.einsum("blhk,bhkv->blhv",
                           rb * jnp.exp(lw_prev), S)
        # state update
        lwL = lw[:, -1:]                                     # (B,1,H,K)
        S = (jnp.exp(lwL[:, 0])[..., None] * S
             + jnp.einsum("blhk,blhv->bhkv",
                          kb * jnp.exp(jnp.minimum(lwL - lw, 0.0)), vb))
        return S, o

    S_new, os = jax.lax.scan(chunk, S0, (rc, kc, vc, wc))
    return S_new, jnp.moveaxis(os, 0, 1).reshape(B, T, H, K)


def _token_shift(x, mu, tail):
    """lerp(x_{t-1}, x_t, mu); tail is x_{-1} (B, D) from the prev chunk."""
    prev = jnp.concatenate([tail[:, None, :].astype(x.dtype), x[:, :-1]],
                           axis=1)
    return prev + mu.astype(x.dtype) * (x - prev)


def rwkv_mix(p, x, cfg: ModelConfig, state: Optional[dict] = None):
    """RWKV-6 time mix.  Returns (y, new_state).

    state = {"S": (B,H,K,K) f32, "x_tail": (B,D)}.
    """
    B, T, D = x.shape
    H, K = cfg.n_heads, cfg.hd
    tail = (state["x_tail"] if state is not None
            else jnp.zeros((B, D), x.dtype))

    def proj(mu_key, w_key):
        xs = _token_shift(x, p[mu_key], tail)
        return jnp.einsum("btd,de->bte", xs, p[w_key].astype(x.dtype))

    r = proj("mu_r", "wr").reshape(B, T, H, K)
    k = proj("mu_k", "wk").reshape(B, T, H, K)
    v = proj("mu_v", "wv").reshape(B, T, H, K)
    g = jax.nn.silu(proj("mu_g", "wg"))

    xw = _token_shift(x, p["mu_w"], tail)
    ww = (p["w0"].astype(jnp.float32)
          + jnp.einsum("btd,dl,le->bte", xw.astype(jnp.float32),
                       p["wA"].astype(jnp.float32),
                       p["wB"].astype(jnp.float32)))
    logw = -jnp.exp(ww)                        # log decay ≤ 0, (B,T,D)
    w = jnp.exp(logw).reshape(B, T, H, K)

    S0 = (state["S"] if state is not None
          else jnp.zeros((B, H, K, K), jnp.float32))

    r32, k32, v32 = (z.astype(jnp.float32) for z in (r, k, v))
    u = p["u"].astype(jnp.float32)
    logw = logw.reshape(B, T, H, K)

    L = cfg.rwkv_chunk
    if L and T >= 2 * L and T % L == 0:
        S_new, o = _rwkv_chunked(r32, k32, v32, logw, S0, u, L)
    else:
        def step(S, inp):
            rt, kt, vt, wt = inp  # (B,H,K) each
            # o_t = r·(S + u⊙k v^T);  S' = diag(w) S + k v^T
            kv = kt[..., :, None] * vt[..., None, :]       # (B,H,K,K)
            o = jnp.einsum("bhk,bhkv->bhv", rt,
                           S + u[None, :, :, None] * kv)
            S = wt[..., :, None] * S + kv
            return S, o

        xs = tuple(jnp.moveaxis(z, 1, 0) for z in
                   (r32, k32, v32, w.astype(jnp.float32)))
        S_new, os = jax.lax.scan(step, S0, xs)
        o = jnp.moveaxis(os, 0, 1)
    o = o.reshape(B, T, D)                                 # (B,T,D) f32

    # per-head group norm then gate
    o = o.reshape(B, T, H, K)
    o = o * jax.lax.rsqrt(jnp.mean(o * o, axis=-1, keepdims=True) + 1e-5)
    o = (o.reshape(B, T, D) * p["ln_w"].astype(jnp.float32)).astype(x.dtype)
    o = o * g
    out = jnp.einsum("btd,de->bte", o, p["wo"].astype(x.dtype))
    return out, {"S": S_new, "x_tail": x[:, -1]}


def init_rwkv_state(cfg: ModelConfig, batch: int):
    H, K = cfg.n_heads, cfg.hd
    return {"S": jnp.zeros((batch, H, K, K), jnp.float32),
            "x_tail": jnp.zeros((batch, cfg.d_model), jnp.dtype(cfg.dtype)),
            "c_tail": jnp.zeros((batch, cfg.d_model), jnp.dtype(cfg.dtype))}


# ---------------------------------------------------------------------------
# RWKV-6 channel mix
# ---------------------------------------------------------------------------

def init_rwkv_channel(rng, cfg: ModelConfig):
    D, F = cfg.d_model, cfg.d_ff
    r = jax.random.split(rng, 3)
    p = {
        "mu_k": jnp.full((D,), 0.5, _pdt(cfg)),
        "mu_r": jnp.full((D,), 0.5, _pdt(cfg)),
        "wk": _init(r[0], (D, F), D ** -0.5, _pdt(cfg)),
        "wv": _init(r[1], (F, D), F ** -0.5, _pdt(cfg)),
        "wr": _init(r[2], (D, D), D ** -0.5, _pdt(cfg)),
    }
    a = {"mu_k": (None,), "mu_r": (None,),
         "wk": ("embed", "mlp"), "wv": ("mlp", "embed"),
         "wr": ("embed", "heads_rw")}
    return p, a


def rwkv_channel(p, x, cfg: ModelConfig, state: Optional[dict] = None):
    B, T, D = x.shape
    tail = (state["c_tail"] if state is not None
            else jnp.zeros((B, D), x.dtype))
    xk = _token_shift(x, p["mu_k"], tail)
    xr = _token_shift(x, p["mu_r"], tail)
    k = jnp.square(jax.nn.relu(
        jnp.einsum("btd,df->btf", xk, p["wk"].astype(x.dtype))))
    kv = jnp.einsum("btf,fd->btd", k, p["wv"].astype(x.dtype))
    r = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr,
                                  p["wr"].astype(x.dtype)))
    return r * kv, {"c_tail": x[:, -1]}
