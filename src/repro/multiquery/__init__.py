"""Multi-query sharing: serve N concurrent queries from one stream pass.

TiLT's planner (plan.py) makes a query's grids, alignments and halos a
*static artifact*; this package exploits the consequence the per-query
layers cannot: two sub-DAGs from *different* queries are interchangeable
iff their structural fingerprints match — same ops, same static parameters,
same sources-by-grid (ir.fingerprint).  The serving scenario (thousands of
dashboards watching the same sources) then reduces to classic shared-
operator execution, resolved entirely at plan time.  The subsystem owns, in
exactly one place:

* :class:`~repro.multiquery.shared.SharedPlanCache` — cross-query CSE by
  hash-consing: interned queries share IR node objects for structurally
  equal sub-plans, and the union DAG of N roots partitions into *shared
  interior nodes* (reachable from ≥ 2 queries; evaluated once per chunk)
  and *per-query heads* (final thresholds / projections).
* :func:`repro.core.plan.plan_union` — one static plan for the union DAG:
  every node's grid covers the union of all consumers' demands, and the
  per-source halo contracts merge across queries into a single partition
  contract.
* :class:`~repro.multiquery.session.MultiQuerySession` — the serving
  layer: registered queries advance through the unified policy runner
  (``repro.engine.Runner`` with ``ExecPolicy(dag="union")``) — one staged
  step per chunk evaluates the whole union DAG through the same node
  evaluator the per-query executors use (compile.eval_op), the runner's
  state pytree under the merged halo contract is the only cross-chunk
  state, attach/detach between chunks re-fits it deterministically, and
  the policy axes compose: keyed (K sub-streams × N queries vmapped,
  optionally mesh-sharded) and sparse (``sparse=True`` — the merged
  ChangePlan of the union DAG, the per-input union of per-query
  dilations, lets clean chunks/keys skip the whole union evaluation).
  :func:`~repro.multiquery.session.union_runner` exposes the same
  composition without the attach/detach machinery.
* :func:`~repro.multiquery.session.shard_union_run` — the *time*-sharded
  union executor: the shared timeline is partitioned across mesh devices
  and the merged halo contracts — which get deeper as queries pile on —
  are assembled by the multi-hop ppermute chain (core/halo.py), so union
  plans with windows deeper than the per-shard span still scale out.

Sharing model in one line: *fingerprint-equal ⇒ plan-equal ⇒ evaluate
once* — correctness rests on fingerprints implying structural equality
(property-tested), and on the union plan widening grids only conservatively
(extra φ-padded halo ticks are semantically invisible).  Numerically,
widening is exact for φ-masking, alignment and associative-exact reductions
(max/min, integer-valued sums); for inexact float reductions the blocked
sliding-sum may associate differently on a union-widened grid than on the
query's solo grid, so shared-vs-independent agreement is bitwise for
exactly-representable data and within the kernel's documented
window-bounded error otherwise (see kernels/ops.py; offset-invariant
blocking is a ROADMAP follow-on).
"""
from .session import (MultiQuerySession, shard_union_run, union_body_spec,
                      union_runner)
from .shared import SharedPlanCache, SharingReport

__all__ = ["MultiQuerySession", "SharedPlanCache", "SharingReport",
           "shard_union_run", "union_body_spec", "union_runner"]
