"""Shared-plan cache: structural interning + union-DAG sharing analysis.

The multi-query layer's CSE happens here, *across* queries: every node of
every registered query is interned by its canonical structural fingerprint
(:func:`repro.core.ir.fingerprint`), so two dashboards that each build
``source.window(50).mean()`` from scratch end up holding the *same* IR node
object.  The union DAG of N query roots then partitions into

* **shared interior nodes** — reachable from ≥ 2 query roots; evaluated
  exactly once per chunk and fanned out to every consumer, and
* **per-query heads** — nodes private to one query (final thresholds,
  projections); evaluated per query.

The cache also memoizes per-``(fingerprint, span)`` planning artifacts so
attaching a query whose sub-plans are already resident costs no planning
work for the shared prefix.

With ``persist=<path>`` the artifact store round-trips to disk (one
pickle, atomic writes): plan artifacts are keyed by ``(structural
fingerprint, out_len)`` — pure-data planning products only
(:class:`~repro.core.plan.InputSpec` halo contracts,
:class:`~repro.core.plan.ChangePlan`, output geometry, φ seed shapes),
never live IR or closures — so a *fresh process* serving an
already-planned query skips planning entirely.  This is the
cross-session plan sharing the serving warm start
(:func:`repro.serve.build_service`) builds on; the executables
themselves persist separately (:class:`repro.serve.aot.ExecutableCache`
+ the jax compilation cache).
"""
from __future__ import annotations

import dataclasses
import os
import pickle
import tempfile
from typing import Dict, List, Optional, Sequence, Set

from ..core import ir

__all__ = ["SharedPlanCache", "SharingReport"]

_PLAN_SCHEMA = "repro.plans/v1"


@dataclasses.dataclass(frozen=True)
class SharingReport:
    """How much work the union DAG saves over independent execution."""

    n_queries: int
    union_nodes: int          # nodes evaluated once per chunk, total
    independent_nodes: int    # sum of per-query DAG sizes (no sharing)
    shared_nodes: int         # union nodes reachable from >= 2 queries
    head_nodes: Dict[str, int]  # per query: nodes private to it

    @property
    def sharing_ratio(self) -> float:
        """independent / union node evaluations (1.0 = nothing shared)."""
        return self.independent_nodes / max(self.union_nodes, 1)


class SharedPlanCache:
    """Interns query IR by structural fingerprint (cross-query hash-consing).

    ``intern`` rebuilds a query bottom-up, replacing every sub-DAG whose
    fingerprint is already resident with the cached canonical node — after
    which structural identity *is* object identity, and the union DAG of any
    set of interned roots shares sub-plans maximally.  A cache instance may
    serve many sessions; it only ever grows.
    """

    def __init__(self, persist: Optional[str] = None):
        self._canon: Dict[str, ir.Node] = {}   # fingerprint -> canonical node
        # (fingerprint, out_len) -> pure-data plan artifact (module
        # docstring); round-trips to ``persist`` when given
        self._plans: Dict[tuple, dict] = {}
        self._persist = persist
        if persist and os.path.exists(persist):
            try:
                with open(persist, "rb") as f:
                    doc = pickle.load(f)
                if isinstance(doc, dict) and doc.get("schema") == _PLAN_SCHEMA:
                    self._plans = dict(doc["plans"])
            except Exception:
                # a torn/stale store degrades to planning, never an error
                self._plans = {}

    def __len__(self) -> int:
        return len(self._canon)

    # -- persisted plan artifacts --------------------------------------------
    def plan_artifact(self, fp: str, out_len: int) -> Optional[dict]:
        """The memoized (possibly persisted) plan artifact for one
        ``(structural fingerprint, out_len)`` point, or ``None``."""
        return self._plans.get((fp, int(out_len)))

    def store_artifact(self, fp: str, out_len: int, artifact: dict) -> None:
        """Memoize a plan artifact and (when persisting) write through."""
        self._plans[(fp, int(out_len))] = artifact
        self.save()

    def save(self) -> None:
        """Atomically write the artifact store to the ``persist`` path
        (no-op for in-memory caches)."""
        if not self._persist:
            return
        d = os.path.dirname(os.path.abspath(self._persist))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                pickle.dump({"schema": _PLAN_SCHEMA, "plans": self._plans}, f)
            os.replace(tmp, self._persist)
        except BaseException:
            try:
                os.remove(tmp)
            except OSError:
                pass
            raise

    def intern(self, root: ir.Node) -> ir.Node:
        """Canonical (interned) equivalent of ``root``; subsumes per-query
        CSE and deduplicates against every previously interned query."""
        out: Dict[int, ir.Node] = {}
        for n in ir.topo_order(root):
            args = tuple(out[id(a)] for a in n.args)
            m = n._replace_args(args) if n.args else n
            fp = ir.fingerprint(m)
            if fp not in self._canon:
                self._canon[fp] = m
            out[id(n)] = self._canon[fp]
        return out[id(root)]

    def node_for(self, fp: str) -> ir.Node:
        return self._canon[fp]

    # -- union-DAG analysis --------------------------------------------------
    @staticmethod
    def reachable(root: ir.Node) -> Set[int]:
        return {id(n) for n in ir.topo_order(root)}

    @classmethod
    def partition(cls, roots: Dict[str, ir.Node]
                  ) -> tuple[List[ir.Node], Dict[str, List[ir.Node]]]:
        """Split the union DAG into (shared interior nodes, per-query heads).

        ``roots`` maps query name -> interned root.  A node is *shared* when
        it is reachable from at least two roots; every other node belongs to
        exactly one query's head.  Returns nodes in union topo order.
        """
        reach = {q: cls.reachable(r) for q, r in roots.items()}
        order = ir.topo_order_multi(list(roots.values()))
        shared: List[ir.Node] = []
        heads: Dict[str, List[ir.Node]] = {q: [] for q in roots}
        for n in order:
            owners = [q for q, ids in reach.items() if id(n) in ids]
            if len(owners) >= 2:
                shared.append(n)
            else:
                heads[owners[0]].append(n)
        return shared, heads

    @classmethod
    def report(cls, roots: Dict[str, ir.Node]) -> SharingReport:
        shared, heads = cls.partition(roots)
        union = len(ir.topo_order_multi(list(roots.values())))
        indep = sum(len(ir.topo_order(r)) for r in roots.values())
        return SharingReport(
            n_queries=len(roots), union_nodes=union,
            independent_nodes=indep, shared_nodes=len(shared),
            head_nodes={q: len(h) for q, h in heads.items()})
