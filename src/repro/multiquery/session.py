"""Multi-query session: N concurrent queries, one pass over the stream.

:class:`MultiQuerySession` is the serving-layer counterpart of
:class:`repro.core.parallel.StreamRunner` / :class:`repro.engine.KeyedEngine`
for *many* queries at once: registered queries are interned into a
:class:`repro.multiquery.shared.SharedPlanCache`, planned together as one
union DAG (:func:`repro.core.plan.plan_union`), and advanced chunk by chunk
through a single staged step — every shared interior node is evaluated once
per chunk regardless of how many queries read it.

Cross-chunk state is one *merged* halo dict: per source name, the trailing
``left_halo`` ticks demanded by the union contract (the per-input halo
contract of plan.py, generalized to the union of all attached queries).
Queries may attach/detach between chunks; the carried halo is re-fitted to
the new merged contract deterministically (crop from the left when it
shrinks, φ-pad on the left when it grows), so a session that changes its
query set stays bit-identical to a fresh session restored from the same
checkpoint.

Keyed sources compose exactly as in the keyed engine: chunks carry a leading
key axis, the union step is vmapped over it, and an optional mesh shards the
key axis via :func:`repro.engine.wrap_keyed_step` — K keyed sub-streams ×
N queries advance as a single XLA computation per chunk.
"""
from __future__ import annotations

import collections
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core import boundary, compile as qcompile, ir, parallel
from ..core.plan import plan_union
from ..core.stream import SnapshotGrid
from ..engine import wrap_keyed_step
from .shared import SharedPlanCache, SharingReport

__all__ = ["MultiQuerySession", "shard_union_run"]


def _union_body(plan, queries, order, pallas, sum_algo, span,
                counts=None, fps=None):
    """The union-DAG chunk evaluator (single-key view, time axis 0):
    every node once through the shared evaluator, then per-query output
    windows sliced off each root's (possibly union-widened) grid.  Shared
    by the session's staged step and :func:`shard_union_run`."""

    def body(full: Dict[str, tuple]) -> Dict[str, tuple]:
        env: Dict[int, tuple] = {}
        for n in order:
            if isinstance(n, ir.Input):
                args = (full[n.name],)
            else:
                args = tuple(env[id(a)] for a in n.args)
            if fps:
                counts[fps[id(n)]] = counts.get(fps[id(n)], 0) + 1
            env[id(n)] = qcompile.eval_op(n, plan, pallas, sum_algo, *args)
        outs = {}
        for qname, root in queries.items():
            gp = plan.plan_of(root)
            lo = -gp.t0 // gp.prec        # skip any union-widened halo
            out_len = span // gp.prec
            v, m = env[id(root)]
            outs[qname] = (
                jax.tree_util.tree_map(
                    lambda x: jax.lax.slice_in_dim(
                        x, lo, lo + out_len, axis=0), v),
                jax.lax.slice_in_dim(m, lo, lo + out_len, axis=0))
        return outs

    return body


def shard_union_run(queries: Dict[str, object], span: int,
                    inputs: Dict[str, SnapshotGrid], mesh: Mesh,
                    axis: str = "data", *, pallas: Optional[bool] = None,
                    sum_algo: str = "block") -> Dict[str, SnapshotGrid]:
    """SPMD execution of N queries' union DAG with the *timeline* sharded
    along ``mesh[axis]`` — the multi-query counterpart of
    :func:`repro.core.parallel.shard_map_run`.

    ``span`` is the per-shard output span (time units); each input supplies
    exactly the core region of the global window (``n · span`` time units,
    shared by all queries).  The merged per-source halo contracts of the
    union plan — which get *deeper* as queries pile on — are assembled by
    the same multi-hop ppermute chain as the per-query path
    (``InputSpec.halo_schedule`` → :func:`repro.core.halo.exchange`), so
    union plans whose windows exceed the per-shard span shard fine.
    Unkeyed sources only (the keyed session shards the key axis instead).
    """
    queries = {name: getattr(q, "node", q) for name, q in queries.items()}
    for name, root in queries.items():
        ir.validate(root)
        if any(n.keyed for n in ir.free_inputs(root)):
            raise NotImplementedError(
                f"query {name!r}: shard_union_run time-shards unkeyed "
                "sources; keyed query sets shard the key axis via "
                "MultiQuerySession(mesh=...)")

    # plan + staged step depend only on the query-set structure and the
    # execution knobs — cache both so chunked/repeated calls reuse the
    # traced+compiled computation (same pattern as shard_map_run's cache,
    # keyed structurally because callers rebuild query dicts per call)
    qkey = tuple(sorted((name, ir.fingerprint(root))
                        for name, root in queries.items()))

    def build():
        plan = plan_union(list(queries.values()), span)
        order = ir.topo_order_multi(list(queries.values()))
        body = _union_body(plan, queries, order, pallas, sum_algo, span)
        return plan, parallel.stage_exchange_step(
            plan.input_specs, body, mesh, axis,
            {qname: (P(axis), P(axis)) for qname in queries})

    plan, sharded = parallel.lru_step_get(
        _union_step_cache, (qkey, span, mesh, axis, pallas, sum_algo),
        build, _UNION_STEP_CACHE_MAX)

    placed, out_t0 = parallel.place_core_inputs(
        plan.input_specs, inputs, mesh, axis)
    outs = sharded(*placed)
    return {qname: SnapshotGrid(value=v, valid=m, t0=out_t0,
                                prec=queries[qname].prec)
            for qname, (v, m) in outs.items()}


# (qkey, span, mesh, axis, pallas, sum_algo) -> (UnionPlan, jitted step);
# structural fingerprints make the key process-stable, so rebuilding the
# same dashboard set every chunk never re-traces.  LRU-bounded: each entry
# retains a compiled executable, and a long-lived server with an evolving
# query set must not grow resident memory without bound.
_UNION_STEP_CACHE_MAX = 16
_union_step_cache: "collections.OrderedDict[tuple, tuple]" = \
    collections.OrderedDict()


class MultiQuerySession:
    """Serve N concurrent queries from one pass over shared sources.

    Parameters
    ----------
    span:
        Output time units per chunk, shared by all queries (each query
        emits ``span // root.prec`` ticks per step).
    n_keys / mesh / axis:
        Keyed execution: required key count when sources are ``keyed=True``;
        optional mesh shards the key axis (as in KeyedEngine).
    pallas / sum_algo:
        Kernel knobs, passed through to the node evaluator.
    jit:
        Stage the union step with ``jax.jit`` (default).  Forced off by
        ``instrument=True``, which counts per-chunk node evaluations in
        ``node_eval_counts`` (keyed by structural fingerprint) — the sharing
        test hook.
    cache:
        A shared :class:`SharedPlanCache`; sessions may share one so interned
        plans persist across sessions.  A private cache by default.
    """

    def __init__(self, span: int, *, n_keys: Optional[int] = None,
                 mesh: Optional[Mesh] = None, axis: str = "data",
                 pallas: Optional[bool] = None, sum_algo: str = "block",
                 jit: bool = True, instrument: bool = False,
                 cache: Optional[SharedPlanCache] = None):
        self.span = span
        self.n_keys = n_keys
        self.mesh = mesh
        self.axis = axis
        self.pallas = pallas
        self.sum_algo = sum_algo
        self.jit = jit and not instrument
        self.instrument = instrument
        self.cache = cache if cache is not None else SharedPlanCache()
        self.node_eval_counts: Dict[str, int] = {}
        self._queries: Dict[str, ir.Node] = {}   # name -> interned root
        self._plan = None
        self._order: list = []
        self._step_fn = None
        self._dirty = True
        self._keyed: Optional[bool] = None
        self._tails: Dict[str, tuple] = {}
        self._t = 0  # absolute time of the next chunk's output start

    # -- query registry ------------------------------------------------------
    def attach(self, name: str, query) -> ir.Node:
        """Register a query (TStream or IR node) under ``name``; takes
        effect at the next chunk.  Returns the interned canonical root."""
        root = getattr(query, "node", query)
        if name in self._queries:
            raise ValueError(f"query {name!r} already attached")
        ir.validate(root)
        if self.span % root.prec:
            raise ValueError(
                f"query {name!r}: span {self.span} not a multiple of "
                f"output precision {root.prec}")
        for src, b in boundary.resolve(root).items():
            if b.lookahead > 0:
                raise NotImplementedError(
                    f"query {name!r}: MultiQuerySession supports "
                    f"lookback-only queries (input {src} has lookahead)")
        keyed_flags = {n.keyed for n in ir.free_inputs(root)}
        if len(keyed_flags) > 1:
            raise ValueError(
                f"query {name!r} mixes keyed and unkeyed sources")
        q_keyed = keyed_flags.pop() if keyed_flags else None
        if q_keyed is not None:
            if self._keyed is not None and q_keyed != self._keyed:
                raise ValueError(
                    f"query {name!r}: keyed={q_keyed} conflicts with "
                    f"already-attached queries (keyed={self._keyed})")
            self._keyed = q_keyed
        if self._keyed and self.n_keys is None:
            raise ValueError("keyed sources need n_keys")
        if self.mesh is not None and not self._keyed:
            raise ValueError("mesh sharding requires keyed sources")
        canon = self.cache.intern(root)
        self._queries[name] = canon
        self._dirty = True
        return canon

    def detach(self, name: str) -> None:
        """Drop a query; unaffected shared nodes keep their cached plans and
        the merged halo state is re-fitted at the next chunk."""
        if name not in self._queries:
            raise ValueError(f"no query {name!r} attached "
                             f"(have {sorted(self._queries)})")
        del self._queries[name]
        # recompute keyedness from what's left so a session that empties
        # out can be repopulated with either kind
        flags = {n.keyed for root in self._queries.values()
                 for n in ir.free_inputs(root)}
        self._keyed = flags.pop() if len(flags) == 1 else None
        self._dirty = True

    @property
    def queries(self) -> Dict[str, ir.Node]:
        return dict(self._queries)

    def sharing_report(self) -> SharingReport:
        return self.cache.report(self._queries)

    def eval_count(self, query_or_node) -> int:
        """Instrumented evaluation count of a node (by structural
        fingerprint) accumulated since session creation or the last
        ``reset()``; requires ``instrument=True``.  A shared node evaluates
        once per chunk however many queries read it."""
        node = getattr(query_or_node, "node", query_or_node)
        return self.node_eval_counts.get(ir.fingerprint(node), 0)

    # -- planning / staging --------------------------------------------------
    def _rebuild(self) -> None:
        if not self._queries:
            raise ValueError("no queries attached")
        roots = list(self._queries.values())
        plan = plan_union(roots, self.span)
        for name, s in plan.input_specs.items():
            if s.right_halo > 0:  # pragma: no cover - guarded per-attach
                raise NotImplementedError(
                    f"input {name} has lookahead; lookback-only sessions")
        self._plan = plan
        self._order = ir.topo_order_multi(roots)
        self._step_fn = self._build_step()
        self._dirty = False

    @property
    def _taxis(self) -> int:
        return 1 if self._keyed else 0

    def _build_step(self):
        plan = self._plan
        names = sorted(plan.input_specs)
        specs = plan.input_specs
        order = list(self._order)
        queries = dict(self._queries)
        fps = {id(n): ir.fingerprint(n) for n in order} if self.instrument \
            else {}
        taxis = self._taxis
        body = _union_body(plan, queries, order, self.pallas, self.sum_algo,
                           self.span, counts=self.node_eval_counts, fps=fps)

        def step(tails, chunks):
            full = {}
            for name in names:
                tv, tm = tails[name]
                cv, cm = chunks[name]
                full[name] = (
                    jax.tree_util.tree_map(
                        lambda a, b: jnp.concatenate([a, b], axis=taxis),
                        tv, cv),
                    jnp.concatenate([tm, cm], axis=taxis))
            if taxis:
                flat = [full[name] for name in names]
                outs = jax.vmap(
                    lambda *f: body(dict(zip(names, f))))(*flat)
            else:
                outs = body(full)
            new_tails = {}
            for name in names:
                s = specs[name]
                fv, fm = full[name]
                new_tails[name] = (
                    jax.tree_util.tree_map(
                        lambda x: jax.lax.slice_in_dim(
                            x, s.core, s.core + s.left_halo, axis=taxis), fv),
                    jax.lax.slice_in_dim(fm, s.core, s.core + s.left_halo,
                                         axis=taxis))
            return outs, new_tails

        if not self.jit:
            return step
        return wrap_keyed_step(step, self.mesh if self._keyed else None,
                               self.axis)

    # -- halo-state plumbing -------------------------------------------------
    def _fit_tail(self, tail, hl: int):
        """Re-fit a carried tail to the current merged contract: keep the
        trailing ``hl`` ticks, φ-padding on the left when history is short.
        The rule is deterministic, so a live session whose contract changed
        and a fresh session restored from the same checkpoint agree."""
        tv, tm = tail
        taxis = self._taxis
        cur = tm.shape[taxis]
        if cur == hl:
            return tail
        if cur > hl:
            lo = cur - hl
            return (jax.tree_util.tree_map(
                lambda x: jax.lax.slice_in_dim(x, lo, cur, axis=taxis), tv),
                jax.lax.slice_in_dim(tm, lo, cur, axis=taxis))
        pad = hl - cur
        cfg_m = [(0, 0)] * taxis + [(pad, 0)]

        def one(x):
            cfg = cfg_m + [(0, 0)] * (x.ndim - taxis - 1)
            return jnp.pad(x, cfg)

        return (jax.tree_util.tree_map(one, tv), one(tm))

    def _blank_tail(self, hl: int, proto):
        pv, pm = proto
        taxis = self._taxis
        lead = (self.n_keys, hl) if taxis else (hl,)

        def one(x):
            return jnp.zeros(lead + x.shape[taxis + 1:], x.dtype)

        return (jax.tree_util.tree_map(one, pv),
                jnp.zeros(lead, bool))

    def _place(self, tree):
        if self.mesh is None:
            return tree
        sh = NamedSharding(self.mesh, P(self.axis))
        return jax.tree_util.tree_map(lambda x: jax.device_put(x, sh), tree)

    # -- execution -----------------------------------------------------------
    def step(self, chunks: Dict[str, SnapshotGrid]
             ) -> Dict[str, SnapshotGrid]:
        """Advance every attached query by one chunk of ``span`` time units.

        Each chunk grid supplies exactly ``spec.core`` fresh ticks per source
        (leading key axis first when keyed).  Returns one output grid per
        query name."""
        if self._dirty:
            self._rebuild()
        specs = self._plan.input_specs
        taxis = self._taxis
        chunk_in, tails = {}, {}
        for name, spec in specs.items():
            g = chunks[name]
            want = ((self.n_keys, spec.core) if taxis else (spec.core,))
            if tuple(g.valid.shape) != want:
                raise ValueError(
                    f"input {name}: chunk validity shape "
                    f"{tuple(g.valid.shape)} != expected {want}")
            chunk_in[name] = self._place((g.value, g.valid))
            if name in self._tails:
                tails[name] = self._fit_tail(self._tails[name],
                                             spec.left_halo)
            else:
                tails[name] = self._place(
                    self._blank_tail(spec.left_halo, chunk_in[name]))
        outs, new_tails = self._step_fn(tails, chunk_in)
        self._tails = new_tails
        results = {}
        for qname, (v, m) in outs.items():
            results[qname] = SnapshotGrid(
                value=v, valid=m, t0=self._t,
                prec=self._queries[qname].prec)
        self._t += self.span
        return results

    def run(self, inputs: Dict[str, SnapshotGrid], n_chunks: int
            ) -> Dict[str, SnapshotGrid]:
        """Slice ``n_chunks`` chunks from full streams, step through them and
        stitch each query's outputs along time."""
        if self._dirty:
            self._rebuild()
        specs = self._plan.input_specs
        taxis = self._taxis
        outs: Dict[str, list] = {}
        for k in range(n_chunks):
            chunk = {}
            for name, spec in specs.items():
                g = inputs[name]
                lo = k * spec.core
                chunk[name] = SnapshotGrid(
                    value=jax.tree_util.tree_map(
                        lambda x: jax.lax.slice_in_dim(
                            x, lo, lo + spec.core, axis=taxis), g.value),
                    valid=jax.lax.slice_in_dim(
                        g.valid, lo, lo + spec.core, axis=taxis),
                    t0=g.t0 + lo * spec.prec, prec=spec.prec)
            for qname, out in self.step(chunk).items():
                outs.setdefault(qname, []).append(out)
        stitched = {}
        for qname, parts in outs.items():
            value = jax.tree_util.tree_map(
                lambda *xs: jnp.concatenate(xs, axis=taxis),
                *[p.value for p in parts])
            valid = jnp.concatenate([p.valid for p in parts], axis=taxis)
            stitched[qname] = SnapshotGrid(value=value, valid=valid,
                                           t0=parts[0].t0,
                                           prec=parts[0].prec)
        return stitched

    def reset(self) -> None:
        """Drop carried state (and instrumentation counters); the next
        chunk starts a fresh stream at t=0."""
        self._tails = {}
        self._t = 0
        self.node_eval_counts.clear()

    # -- checkpointing -------------------------------------------------------
    def state(self) -> Dict:
        """Checkpointable session state (host arrays): the merged halo dict
        plus the stream clock.  Restoring into a session with a different
        query set is well-defined — tails re-fit to the new contract."""
        return {k: jax.tree_util.tree_map(np.asarray, v)
                for k, v in self._tails.items()} | {"__t": self._t}

    def restore(self, state: Dict) -> None:
        state = dict(state)
        self._t = state.pop("__t")
        self._tails = {k: self._place(
            jax.tree_util.tree_map(jnp.asarray, v))
            for k, v in state.items()}
