"""Multi-query session: N concurrent queries, one pass over the stream.

:class:`MultiQuerySession` is the serving-layer counterpart of the chunked
runners for *many* queries at once: registered queries are interned into a
:class:`repro.multiquery.shared.SharedPlanCache`, planned together as one
union DAG (:func:`repro.core.plan.plan_union`), and advanced chunk by chunk
through the unified policy runner (:class:`repro.engine.Runner` with
``ExecPolicy(dag="union")``) — every shared interior node is evaluated once
per chunk regardless of how many queries read it.

Cross-chunk state is the runner's unified pytree under the *merged* halo
contract: per source name, the trailing ``left_halo`` ticks demanded by the
union of all attached queries.  Queries may attach/detach between chunks;
the carried halo is re-fitted to the new merged contract deterministically
(crop from the left when it shrinks, φ-pad on the left when it grows), so a
session that changes its query set stays bit-identical to a fresh session
restored from the same checkpoint.

Keyed sources compose exactly as in the keyed engine: chunks carry a
leading key axis, the union step is vmapped over it, and an optional mesh
shards the key axis — K keyed sub-streams × N queries advance as a single
XLA computation per chunk.

``sparse=True`` composes change-compressed execution with multi-query
sharing: the merged :class:`~repro.core.plan.ChangePlan` of the union DAG
is the per-input union of the per-query dilations (derived from the merged
halo contracts — the same artifact, read backwards), so chunks (and, for
keyed sessions, keys) whose dilated lineage saw no change skip the whole
union evaluation and hold every query's previous output.
"""
from __future__ import annotations

import collections
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..core import boundary, compile as qcompile, ir, parallel
from ..core.plan import plan_change, plan_union
from ..core.stream import SnapshotGrid
from ..engine.policy import ExecPolicy, MeshPlacement
from ..engine.runner import BodySpec, Runner
from ..obs import Metrics
from .shared import SharedPlanCache, SharingReport

__all__ = ["MultiQuerySession", "shard_union_run", "union_body_spec",
           "union_runner"]


def _union_body(plan, queries, order, pallas, sum_algo, span,
                counts=None, fps=None):
    """The union-DAG chunk evaluator (single-key view, time axis 0):
    every node once through the shared evaluator, then per-query output
    windows sliced off each root's (possibly union-widened) grid.  Shared
    by the session's per-segment body and :func:`shard_union_run`."""

    def body(full: Dict[str, tuple]) -> Dict[str, tuple]:
        env: Dict[int, tuple] = {}
        for n in order:
            if isinstance(n, ir.Input):
                args = (full[n.name],)
            else:
                args = tuple(env[id(a)] for a in n.args)
            if fps:
                counts[fps[id(n)]] = counts.get(fps[id(n)], 0) + 1
            env[id(n)] = qcompile.eval_op(n, plan, pallas, sum_algo, *args)
        outs = {}
        for qname, root in queries.items():
            gp = plan.plan_of(root)
            lo = -gp.t0 // gp.prec        # skip any union-widened halo
            out_len = span // gp.prec
            v, m = env[id(root)]
            outs[qname] = (
                jax.tree_util.tree_map(
                    lambda x: jax.lax.slice_in_dim(
                        x, lo, lo + out_len, axis=0), v),
                jax.lax.slice_in_dim(m, lo, lo + out_len, axis=0))
        return outs

    return body


def union_body_spec(plan, queries: Dict[str, ir.Node], *,
                    pallas: Optional[bool] = None, sum_algo: str = "block",
                    jit: bool = True, counts: Optional[dict] = None,
                    sparse: bool = False) -> BodySpec:
    """The :class:`repro.engine.runner.BodySpec` of a union DAG: one body
    evaluating every node once and fanning out per-query output windows.

    With ``sparse=True`` the spec carries the *merged* ChangePlan — the
    per-input union of the per-query dilations, obtained by reading the
    union plan's merged halo contracts backwards
    (:func:`repro.core.plan.plan_change` on the
    :class:`~repro.core.plan.UnionPlan`).  ``counts`` (a mutable dict)
    enables per-fingerprint node-evaluation counting — the sharing test
    hook; pair it with ``jit=False``.
    """
    order = ir.topo_order_multi(list(plan.roots))
    fps = ({id(n): ir.fingerprint(n) for n in order}
           if counts is not None else None)
    outs_fn = _union_body(plan, queries, order, pallas, sum_algo, plan.span,
                          counts=counts, fps=fps)
    return BodySpec(
        input_specs=plan.input_specs, out_len=plan.out_len,
        out_prec=plan.out_prec, outs_fn=outs_fn,
        out_precs={q: root.prec for q, root in queries.items()},
        change_plan=plan_change(plan) if sparse else None,
        root=None, jit=jit, solo=False,
        roots=tuple(queries[q] for q in sorted(queries)))


def union_runner(queries: Dict[str, object], span: int,
                 policy: Optional[ExecPolicy] = None, *,
                 n_keys: Optional[int] = None, segs_per_chunk: int = 1,
                 pallas: Optional[bool] = None, sum_algo: str = "block"
                 ) -> Runner:
    """Build a unified :class:`repro.engine.Runner` over the union DAG of
    ``queries`` (name → TStream or IR node) — the ``dag='union'`` corner of
    the policy space, without the session's attach/detach machinery."""
    queries = {name: getattr(q, "node", q) for name, q in queries.items()}
    for root in queries.values():
        ir.validate(root)
    policy = policy if policy is not None else ExecPolicy(dag="union")
    if not policy.union:
        raise ValueError(
            f"union_runner needs ExecPolicy(dag='union'), got {policy.dag!r}")
    plan = plan_union(list(queries.values()), span)
    spec = union_body_spec(plan, queries, pallas=pallas, sum_algo=sum_algo,
                           sparse=policy.sparse)
    return Runner(spec, policy, n_keys=n_keys, segs_per_chunk=segs_per_chunk)


def shard_union_run(queries: Dict[str, object], span: int,
                    inputs: Dict[str, SnapshotGrid], mesh: Mesh,
                    axis: str = "data", *, pallas: Optional[bool] = None,
                    sum_algo: str = "block") -> Dict[str, SnapshotGrid]:
    """SPMD execution of N queries' union DAG with the *timeline* sharded
    along ``mesh[axis]`` — the multi-query counterpart of
    :func:`repro.core.parallel.shard_map_run`.

    ``span`` is the per-shard output span (time units); each input supplies
    exactly the core region of the global window (``n · span`` time units,
    shared by all queries).  The merged per-source halo contracts of the
    union plan — which get *deeper* as queries pile on — are assembled by
    the same multi-hop ppermute chain as the per-query path
    (``InputSpec.halo_schedule`` → :func:`repro.core.halo.exchange`), so
    union plans whose windows exceed the per-shard span shard fine.
    Unkeyed sources only (the keyed session shards the key axis instead).
    """
    queries = {name: getattr(q, "node", q) for name, q in queries.items()}
    for name, root in queries.items():
        ir.validate(root)
        if any(n.keyed for n in ir.free_inputs(root)):
            raise NotImplementedError(
                f"query {name!r}: shard_union_run time-shards unkeyed "
                "sources; keyed query sets shard the key axis via "
                "MultiQuerySession(mesh=...)")

    # plan + staged step depend only on the query-set structure and the
    # execution knobs — cache both so chunked/repeated calls reuse the
    # traced+compiled computation (same pattern as shard_map_run's cache,
    # keyed structurally because callers rebuild query dicts per call)
    qkey = tuple(sorted((name, ir.fingerprint(root))
                        for name, root in queries.items()))

    def build():
        plan = plan_union(list(queries.values()), span)
        order = ir.topo_order_multi(list(queries.values()))
        body = _union_body(plan, queries, order, pallas, sum_algo, span)
        return plan, parallel.stage_exchange_step(
            plan.input_specs, body, mesh, axis,
            {qname: (P(axis), P(axis)) for qname in queries})

    plan, sharded = parallel.lru_step_get(
        _union_step_cache, (qkey, span, mesh, axis, pallas, sum_algo),
        build, _UNION_STEP_CACHE_MAX)

    placed, out_t0 = parallel.place_core_inputs(
        plan.input_specs, inputs, mesh, axis)
    parallel.record_exchange(plan.input_specs, placed, mesh, axis)
    outs = sharded(*placed)
    return {qname: SnapshotGrid(value=v, valid=m, t0=out_t0,
                                prec=queries[qname].prec)
            for qname, (v, m) in outs.items()}


# (qkey, span, mesh, axis, pallas, sum_algo) -> (UnionPlan, jitted step);
# structural fingerprints make the key process-stable, so rebuilding the
# same dashboard set every chunk never re-traces.  LRU-bounded: each entry
# retains a compiled executable, and a long-lived server with an evolving
# query set must not grow resident memory without bound.
_UNION_STEP_CACHE_MAX = 16
_union_step_cache: "collections.OrderedDict[tuple, tuple]" = \
    collections.OrderedDict()


class MultiQuerySession:
    """Serve N concurrent queries from one pass over shared sources.

    Parameters
    ----------
    span:
        Output time units per chunk, shared by all queries (each query
        emits ``span // root.prec`` ticks per step).
    n_keys / mesh / axis:
        Keyed execution: required key count when sources are ``keyed=True``;
        optional mesh shards the key axis (as in the keyed engine).
    sparse:
        Change-compressed stepping: chunks — and, when keyed, individual
        keys — whose dilated input lineage saw no change skip the union
        evaluation entirely and hold every query's previous output (the
        merged ChangePlan of the union DAG; see the module docstring).
    pallas / sum_algo:
        Kernel knobs, passed through to the node evaluator.
    jit:
        Stage the union step with ``jax.jit`` (default).  Forced off by
        ``instrument=True``, which counts per-chunk node evaluations in
        ``node_eval_counts`` (keyed by structural fingerprint) — the sharing
        test hook.
    cache:
        A shared :class:`SharedPlanCache`; sessions may share one so interned
        plans persist across sessions.  A private cache by default.
    metrics:
        An :class:`repro.obs.Metrics` registry for session + runner
        telemetry (``session.*`` and ``runner.*`` metric names).  The
        session passes it through every runner it builds, so counters and
        histograms survive attach/detach rebuilds; private by default.
    """

    def __init__(self, span: int, *, n_keys: Optional[int] = None,
                 mesh: Optional[Mesh] = None, axis: str = "data",
                 pallas: Optional[bool] = None, sum_algo: str = "block",
                 jit: bool = True, instrument: bool = False,
                 sparse: bool = False,
                 cache: Optional[SharedPlanCache] = None,
                 metrics: Optional[Metrics] = None):
        self.span = span
        self.n_keys = n_keys
        self.mesh = mesh
        self.axis = axis
        self.pallas = pallas
        self.sum_algo = sum_algo
        self.jit = jit and not instrument
        self.instrument = instrument
        self.sparse = sparse
        self.cache = cache if cache is not None else SharedPlanCache()
        self.metrics = metrics if metrics is not None else Metrics()
        self.node_eval_counts: Dict[str, int] = {}
        self._queries: Dict[str, ir.Node] = {}   # name -> interned root
        self._plan = None
        self._runner: Optional[Runner] = None
        self._pending: Optional[Dict] = None  # state awaiting next rebuild
        self._dirty = True
        self._keyed: Optional[bool] = None

    # -- query registry ------------------------------------------------------
    def attach(self, name: str, query) -> ir.Node:
        """Register a query (TStream or IR node) under ``name``; takes
        effect at the next chunk.  Returns the interned canonical root."""
        root = getattr(query, "node", query)
        if name in self._queries:
            raise ValueError(f"query {name!r} already attached")
        ir.validate(root)
        if self.span % root.prec:
            raise ValueError(
                f"query {name!r}: span {self.span} not a multiple of "
                f"output precision {root.prec}")
        for src, b in boundary.resolve(root).items():
            if b.lookahead > 0:
                raise NotImplementedError(
                    f"query {name!r}: MultiQuerySession supports "
                    f"lookback-only queries (input {src} has lookahead)")
        keyed_flags = {n.keyed for n in ir.free_inputs(root)}
        if len(keyed_flags) > 1:
            raise ValueError(
                f"query {name!r} mixes keyed and unkeyed sources")
        q_keyed = keyed_flags.pop() if keyed_flags else None
        if q_keyed is not None:
            if self._keyed is not None and q_keyed != self._keyed:
                raise ValueError(
                    f"query {name!r}: keyed={q_keyed} conflicts with "
                    f"already-attached queries (keyed={self._keyed})")
            self._keyed = q_keyed
        if self._keyed and self.n_keys is None:
            raise ValueError("keyed sources need n_keys")
        if self.mesh is not None and not self._keyed:
            raise ValueError("mesh sharding requires keyed sources")
        canon = self.cache.intern(root)
        self._queries[name] = canon
        self._dirty = True
        self.metrics.counter("session.attaches", "queries attached").add(1)
        return canon

    def detach(self, name: str) -> None:
        """Drop a query; unaffected shared nodes keep their cached plans and
        the merged halo state is re-fitted at the next chunk."""
        if name not in self._queries:
            raise ValueError(f"no query {name!r} attached "
                             f"(have {sorted(self._queries)})")
        del self._queries[name]
        # recompute keyedness from what's left so a session that empties
        # out can be repopulated with either kind
        flags = {n.keyed for root in self._queries.values()
                 for n in ir.free_inputs(root)}
        self._keyed = flags.pop() if len(flags) == 1 else None
        self._dirty = True
        self.metrics.counter("session.detaches", "queries detached").add(1)

    @property
    def queries(self) -> Dict[str, ir.Node]:
        return dict(self._queries)

    def sharing_report(self) -> SharingReport:
        return self.cache.report(self._queries)

    def eval_count(self, query_or_node) -> int:
        """Instrumented evaluation count of a node (by structural
        fingerprint) accumulated since session creation or the last
        ``reset()``; requires ``instrument=True``.  A shared node evaluates
        once per chunk however many queries read it."""
        node = getattr(query_or_node, "node", query_or_node)
        return self.node_eval_counts.get(ir.fingerprint(node), 0)

    # -- planning / staging --------------------------------------------------
    @property
    def _taxis(self) -> int:
        return 1 if self._keyed else 0

    def _rebuild(self) -> None:
        if not self._queries:
            raise ValueError("no queries attached")
        roots = list(self._queries.values())
        tracer = self.metrics.tracer
        with tracer.span("session.rebuild"):
            with tracer.span("plan"):
                plan = plan_union(roots, self.span)
            for name, s in plan.input_specs.items():
                if s.right_halo > 0:  # pragma: no cover - guarded per-attach
                    raise NotImplementedError(
                        f"input {name} has lookahead; lookback-only sessions")
            carry = self._pending
            if carry is None and self._runner is not None:
                carry = self._runner.state()
            spec = union_body_spec(
                plan, self._queries, pallas=self.pallas,
                sum_algo=self.sum_algo, jit=self.jit,
                counts=self.node_eval_counts if self.instrument else None,
                sparse=self.sparse)
            policy = ExecPolicy(
                body="sparse" if self.sparse else "dense",
                keys="vmapped" if self._keyed else "single",
                # the mesh shards the key axis only (attach() rejects
                # unkeyed mesh sessions; keep the guard local too so the
                # policy always mirrors what the old keyed step staged)
                placement=(MeshPlacement(self.mesh, self.axis)
                           if self.mesh is not None and self._keyed
                           else "local"),
                dag="union")
            runner = Runner(spec, policy,
                            n_keys=self.n_keys if self._keyed else None,
                            metrics=self.metrics)
            if carry is not None:
                with tracer.span("refit"):
                    runner.restore(self._refit(carry, plan), strict=False)
                self.metrics.counter(
                    "session.refits",
                    "carried state re-fits onto a changed contract").add(1)
        self._plan, self._runner = plan, runner
        self._pending = None
        self._dirty = False
        m = self.metrics
        m.counter("session.rebuilds", "plan+runner rebuilds").add(1)
        m.gauge("session.queries", "attached queries").set(len(self._queries))
        rep = self.sharing_report()
        m.gauge("session.union_nodes", "nodes in the union DAG").set(
            rep.union_nodes)
        m.gauge("session.shared_nodes",
                "union nodes read by more than one query").set(
            rep.shared_nodes)
        m.gauge("session.sharing_ratio",
                "independent-plan nodes per union node").set(
            float(rep.sharing_ratio))

    # -- halo-state re-fitting (attach/detach between chunks) ----------------
    def _fit_tail(self, tail, hl: int):
        """Re-fit a carried tail to the current merged contract: keep the
        trailing ``hl`` ticks, φ-padding on the left when history is short.
        The rule is deterministic, so a live session whose contract changed
        and a fresh session restored from the same checkpoint agree."""
        tv, tm = tail
        taxis = self._taxis
        cur = np.shape(tm)[taxis]
        if cur == hl:
            return tail
        if cur > hl:
            lo = cur - hl
            return (jax.tree_util.tree_map(
                lambda x: jax.lax.slice_in_dim(
                    jnp.asarray(x), lo, cur, axis=taxis), tv),
                jax.lax.slice_in_dim(jnp.asarray(tm), lo, cur, axis=taxis))
        pad = hl - cur
        cfg_m = [(0, 0)] * taxis + [(pad, 0)]

        def one(x):
            x = jnp.asarray(x)
            cfg = cfg_m + [(0, 0)] * (x.ndim - taxis - 1)
            return jnp.pad(x, cfg)

        return (jax.tree_util.tree_map(one, tv), one(tm))

    def _fit_dirty(self, d, hl: int):
        """Re-fit a carried dirty tail: crop from the left, or pad with
        *True* (unknown history is conservatively dirty — the φ-padded halo
        it describes must be recomputed, exactly what dense does there)."""
        d = jnp.asarray(d)
        taxis = self._taxis
        cur = d.shape[taxis]
        if cur == hl:
            return d
        if cur > hl:
            return jax.lax.slice_in_dim(d, cur - hl, cur, axis=taxis)
        cfg = [(0, 0)] * taxis + [(hl - cur, 0)]
        return jnp.pad(d, cfg, constant_values=True)

    def _refit(self, state: Dict, plan) -> Dict:
        """Translate a carried/checkpointed state onto a (possibly
        different) union contract: tails re-fit per source, sparse change
        state filtered to the surviving sources/queries.  Outputs or inputs
        absent from the state simply start fresh (their first segment is
        forced to compute), which keeps the rule deterministic."""
        st = dict(state)
        t = st.pop("__t")
        sp = st.pop("__sparse", None)
        out = {name: self._fit_tail(st[name], spec.left_halo)
               for name, spec in plan.input_specs.items() if name in st}
        out["__t"] = t
        if sp is not None and self.sparse:
            # 1-tick snapshots exist only for halo-free inputs.  When the
            # merged contract *shrinks* an input to halo-free (its deepest
            # reader detached), derive the snapshot from the old tail's
            # last tick — that is the tick the next chunk's tick 0 must
            # diff against — instead of dropping the history.
            prev = {}
            for n, s in plan.input_specs.items():
                if s.left_halo != 0:
                    continue
                if n in sp["prev"]:
                    prev[n] = sp["prev"][n]
                elif n in st and np.shape(st[n][1])[self._taxis] >= 1:
                    prev[n] = self._fit_tail(st[n], 1)
            out["__sparse"] = {
                "dirty": {n: self._fit_dirty(sp["dirty"][n],
                                             plan.input_specs[n].left_halo)
                          for n in plan.input_specs if n in sp["dirty"]},
                "prev": prev,
                "seed": {q: v for q, v in sp["seed"].items()
                         if q in self._queries},
                "started": sp["started"]}
        elif sp is not None:
            out["__sparse"] = sp  # let the runner's validator reject it
        return out

    # -- execution -----------------------------------------------------------
    def step(self, chunks: Dict[str, SnapshotGrid]
             ) -> Dict[str, SnapshotGrid]:
        """Advance every attached query by one chunk of ``span`` time units.

        Each chunk grid supplies exactly ``spec.core`` fresh ticks per source
        (leading key axis first when keyed).  Returns one output grid per
        query name."""
        if self._dirty:
            self._rebuild()
        return self._runner.step(chunks)

    def run(self, inputs: Dict[str, SnapshotGrid], n_chunks: int
            ) -> Dict[str, SnapshotGrid]:
        """Slice ``n_chunks`` chunks from full streams, step through them and
        stitch each query's outputs along time."""
        if self._dirty:
            self._rebuild()
        return self._runner.run(inputs, n_chunks)

    def reset(self) -> None:
        """Drop carried state (and instrumentation counters); the next
        chunk starts a fresh stream at t=0."""
        self._pending = None
        if self._runner is not None:
            self._runner.reset()
        self.node_eval_counts.clear()

    # -- checkpointing -------------------------------------------------------
    def state(self) -> Dict:
        """Checkpointable session state (host arrays): the merged halo dict
        plus the stream clock (and change metadata when sparse).  Restoring
        into a session with a different query set is well-defined — tails
        re-fit to the new contract."""
        if self._pending is not None:  # restored but not yet re-staged
            return dict(self._pending)
        if self._runner is not None:
            return self._runner.state()
        return {"__t": 0}

    def restore(self, state: Dict) -> None:
        self._pending = dict(state)
        self._dirty = True
