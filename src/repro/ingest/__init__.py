"""Out-of-order ingestion: watermarks, bounded-lateness reorder
buffering, and late-data revision processing (docs/architecture.md
"Out-of-order ingestion").

The execution engine assumes in-order tick grids; this package is the
boundary that makes that assumption true against disordered feeds.
:class:`IngestRunner` wraps a :class:`repro.engine.runner.Runner` with a
:class:`WatermarkTracker` (per-key low-watermark, bounded lateness), one
:class:`ReorderBuffer` per query input (static-shape eager rasterization
with deterministic overlap precedence), and a lateness policy
(``buffer | revise | drop``) for events behind the sealed frontier —
``revise`` re-runs only the ChangePlan-dilated output segments through
the runner's sparse revision path and emits versioned
:class:`Correction` rows.
"""
from .pipeline import Correction, IngestRunner, SealedChunk
from .reorder import ReorderBuffer
from .watermark import WatermarkTracker

__all__ = ["Correction", "IngestRunner", "ReorderBuffer", "SealedChunk",
           "WatermarkTracker"]
