"""Per-key low-watermarks over event times with configurable bounded
lateness.

The watermark is the ingestion pipeline's progress contract: it asserts
that no future event will carry a tick at or before it, so everything up
to the watermark can be sealed and executed.  Following the standard
low-watermark construction (MillWheel / Flink lineage; see
docs/architecture.md "Out-of-order ingestion"):

* each key tracks the maximum event (end-)time observed so far
  (``max_seen``);
* the **frontier** is the minimum of ``max_seen`` over keys — the
  slowest key holds the whole stream back, which is what makes keyed
  disorder safe: a key whose events lag never has its chunks sealed out
  from under it;
* the **watermark** is ``frontier - lateness``: events are allowed to
  arrive up to ``lateness`` time units behind the newest event of their
  key and still land in an unsealed chunk.

Keys are discovered on first observation by default, so an idle key
never stalls the stream; pass ``keys=`` to declare the key universe up
front, in which case the watermark stays ``None`` until every declared
key has reported (the strict variant).  ``None`` watermarks mean "no
progress guarantee yet" — nothing seals.
"""
from __future__ import annotations

from typing import Hashable, Iterable, Optional

__all__ = ["WatermarkTracker"]


class WatermarkTracker:
    """Low-watermark over per-key maximum event times.

    Parameters
    ----------
    lateness:
        Bounded lateness in time units: how far behind its key's newest
        event an event may arrive and still be on time.
    keys:
        Optional declared key universe.  Without it, keys are discovered
        on first :meth:`observe` and only observed keys constrain the
        frontier.
    """

    def __init__(self, lateness: int,
                 keys: Optional[Iterable[Hashable]] = None):
        if lateness < 0:
            raise ValueError(f"lateness must be >= 0 (got {lateness})")
        self.lateness = int(lateness)
        self._declared = keys is not None
        self._max_seen: dict = (
            {k: None for k in keys} if keys is not None else {})

    def observe(self, t: int, key: Hashable = None) -> None:
        """Record an event time for ``key`` (monotonic max per key)."""
        if self._declared and key not in self._max_seen:
            raise KeyError(
                f"key {key!r} not in the declared key universe")
        cur = self._max_seen.get(key)
        if cur is None or t > cur:
            self._max_seen[key] = int(t)

    def heartbeat(self, t: int) -> None:
        """Advance every known key's clock to at least ``t`` — an empty
        punctuation event, for feeds that signal progress without data."""
        for k, cur in self._max_seen.items():
            if cur is None or t > cur:
                self._max_seen[k] = int(t)

    @property
    def frontier(self) -> Optional[int]:
        """min over keys of the max event time seen; ``None`` before any
        observation (or while a declared key is still silent)."""
        if not self._max_seen:
            return None
        vals = list(self._max_seen.values())
        if any(v is None for v in vals):
            return None
        return min(vals)

    @property
    def high(self) -> Optional[int]:
        """max over keys of the max event time seen (the newest event)."""
        vals = [v for v in self._max_seen.values() if v is not None]
        return max(vals) if vals else None

    @property
    def watermark(self) -> Optional[int]:
        """``frontier - lateness``: every tick at or before this is
        sealed-safe — no on-time event can still write it."""
        f = self.frontier
        return None if f is None else f - self.lateness

    def lag(self) -> Optional[int]:
        """``high - watermark``: how far the newest observed event runs
        ahead of the sealing point (skew across keys + the lateness
        allowance)."""
        h, w = self.high, self.watermark
        return None if (h is None or w is None) else h - w
