"""Static-shape reorder buffer: out-of-order events → in-order tick grids.

The buffer owns the grid timeline of one input stream, cut into chunks of
``chunk_ticks`` ticks at precision ``prec`` starting at t=0 (the runner's
stream origin).  Events are rasterized **eagerly** on arrival into
per-chunk numpy rasters; arrival order never matters because every tick
carries the ``(start, end)`` stamp of the event that currently owns it,
and a write only lands where the new event wins the same deterministic
precedence :func:`repro.core.stream.events_to_grid` resolves overlaps
with:

    new wins at a tick  iff  (start, end) >=_lex (owner.start, owner.end)

``events_to_grid`` writes events in sorted ``(start, end)`` order with
later writes overwriting, so the winner at any tick is the covering event
with the lexicographically largest ``(start, end)`` — exactly the stamp
rule above, under **any** arrival permutation.  (Two distinct events with
identical ``(start, end)`` spans and different payloads are ambiguous in
the in-order semantics too — don't do that.)  Values are staged in
float64 and cast to float32 at grid build, the same two-step
``events_to_grid`` performs, so sealed grids are bit-identical to
in-order rasterization.

Chunks **seal** in order once the caller's watermark passes their span
(:meth:`seal_ready`); sealed rasters are retained in a bounded horizon
deque so late events can still **patch** them (:meth:`patch`) with the
same precedence rule — the patch reports exactly which tick times
changed, which is what the revision path dirties.  A patch that reaches
ticks older than the retained horizon is refused whole (nothing applied)
so sealed state never forks from what revisions can reproduce.
"""
from __future__ import annotations

import collections
from typing import Optional

import numpy as np

from ..core.stream import Event, SnapshotGrid

__all__ = ["ReorderBuffer"]

_STAMP_MIN = np.iinfo(np.int64).min


class ReorderBuffer:
    """Reorder buffer for one input stream.

    Parameters
    ----------
    prec:
        Tick precision of this input's grid (time units per tick).
    chunk_ticks:
        Ticks per chunk (``input_spec.core * segs_per_chunk`` for the
        runner this feeds).
    n_keys / keyed:
        Key-axis geometry.  ``keyed=True`` builds ``(n_keys, T)`` grids
        (the runner's ``keys='vmapped'`` layout); otherwise grids are
        ``(T,)`` and ``n_keys`` must be 1.
    horizon_chunks:
        Sealed rasters retained for late patches (the revision horizon).
    """

    def __init__(self, prec: int, chunk_ticks: int, *, n_keys: int = 1,
                 keyed: bool = False, horizon_chunks: int = 1):
        if not keyed and n_keys != 1:
            raise ValueError("unkeyed buffers carry exactly one key")
        self.prec, self.T = int(prec), int(chunk_ticks)
        self.K, self.keyed = int(n_keys), keyed
        self.chunk_span = self.T * self.prec
        self.sealed_upto = 0            # chunks [0, sealed_upto) are sealed
        self._open: dict = {}           # chunk -> raster
        self._sealed: collections.deque = collections.deque(
            maxlen=int(horizon_chunks))  # (chunk, raster), oldest first
        self._pkeys = None              # payload structure (set on 1st event)
        self._is_dict = False
        self._last_tick = -1            # newest global tick any event wrote

    # -- payload structure ---------------------------------------------------
    def _register(self, ev: Event) -> None:
        if self._pkeys is None:
            self._is_dict = isinstance(ev.payload, dict)
            self._pkeys = (list(ev.payload.keys()) if self._is_dict
                           else ["v"])
        elif self._is_dict != isinstance(ev.payload, dict) or (
                self._is_dict and list(ev.payload.keys()) != self._pkeys):
            raise ValueError(
                f"event payload structure changed mid-stream "
                f"(expected fields {self._pkeys})")

    def _payload_vals(self, ev: Event) -> dict:
        return ev.payload if self._is_dict else {"v": ev.payload}

    # -- rasters -------------------------------------------------------------
    def _new_raster(self) -> dict:
        K, T = self.K, self.T
        return {
            "vals": {pk: np.zeros((K, T), np.float64)
                     for pk in (self._pkeys or ["v"])},
            "valid": np.zeros((K, T), bool),
            "s": np.full((K, T), _STAMP_MIN, np.int64),
            "e": np.full((K, T), _STAMP_MIN, np.int64),
        }

    def _open_raster(self, c: int) -> dict:
        r = self._open.get(c)
        if r is None:
            r = self._open[c] = self._new_raster()
        return r

    def _sealed_raster(self, c: int) -> Optional[dict]:
        for cc, r in self._sealed:
            if cc == c:
                return r
        return None

    def _write(self, raster: dict, k: int, lo: int, hi: int,
               ev: Event) -> np.ndarray:
        """Apply ``ev`` to in-chunk ticks ``lo..hi`` (inclusive) of key
        ``k`` under stamp precedence; returns the in-chunk indices that
        actually took the write."""
        s, e = raster["s"][k, lo:hi + 1], raster["e"][k, lo:hi + 1]
        win = (ev.start > s) | ((ev.start == s) & (ev.end >= e))
        idx = np.nonzero(win)[0] + lo
        if idx.size:
            raster["s"][k, idx] = ev.start
            raster["e"][k, idx] = ev.end
            raster["valid"][k, idx] = True
            for pk, val in self._payload_vals(ev).items():
                raster["vals"][pk][k, idx] = val
        return idx

    # -- ingest --------------------------------------------------------------
    def push(self, ev: Event, key: int = 0) -> Optional[tuple]:
        """Rasterize ``ev`` into the open (unsealed) chunks.

        Returns ``None`` when the event lies entirely at or past the
        sealed frontier, else the global tick-index range ``(a, b)``
        (inclusive) of the event's ticks that fall in **sealed** chunks —
        the late portion the caller must route through a lateness policy
        (:meth:`patch` / drop / re-admit).  The open portion is written
        either way."""
        p = self.prec
        a, b = ev.start // p, ev.end // p - 1
        if b < a:
            return None  # spans no tick
        self._register(ev)
        if b > self._last_tick:
            self._last_tick = b
        f = self.sealed_upto * self.T
        for c in range(max(a, f) // self.T, b // self.T + 1):
            lo = max(a, f, c * self.T)
            hi = min(b, (c + 1) * self.T - 1)
            if hi >= lo:
                self._write(self._open_raster(c), key,
                            lo - c * self.T, hi - c * self.T, ev)
        return (a, min(b, f - 1)) if a < f else None

    def patch(self, ev: Event, key: int = 0) -> tuple:
        """Apply the sealed portion of a late event to the retained
        sealed rasters.  Returns ``(times, beyond)``: the global tick
        **times** whose owner actually changed (the revision path's dirty
        set — empty when the event loses precedence everywhere), and
        ``beyond=True`` when any covered sealed tick is older than the
        retained horizon, in which case **nothing** is applied (refused
        whole: a partial patch would fork sealed state from anything a
        revision can reproduce)."""
        p, T = self.prec, self.T
        self._register(ev)
        a = ev.start // p
        b = min(ev.end // p - 1, self.sealed_upto * T - 1)
        if b < a or a < 0:
            if a < 0 and b >= 0:
                a = 0  # ticks before the stream origin don't exist
            else:
                return np.empty((0,), np.int64), False
        oldest = self.sealed_upto - len(self._sealed)
        if a // T < oldest:
            return np.empty((0,), np.int64), True
        times: list = []
        for c in range(a // T, b // T + 1):
            raster = self._sealed_raster(c)
            lo = max(a, c * T)
            hi = min(b, (c + 1) * T - 1)
            idx = self._write(raster, key, lo - c * T, hi - c * T, ev)
            times.extend((c * T + i + 1) * p for i in idx)
        return np.asarray(times, np.int64), False

    # -- sealing -------------------------------------------------------------
    def _grid(self, c: int, raster: Optional[dict]) -> SnapshotGrid:
        if raster is None:
            raster = self._new_raster()
        vals = {pk: v.astype(np.float32)
                for pk, v in raster["vals"].items()}
        valid = raster["valid"]
        if not self.keyed:
            vals = {pk: v[0] for pk, v in vals.items()}
            valid = valid[0]
        value = vals if self._is_dict else vals["v"]
        return SnapshotGrid(value=value, valid=valid,
                            t0=c * self.chunk_span, prec=self.prec)

    def _seal_next(self) -> tuple:
        c = self.sealed_upto
        raster = self._open.pop(c, None)
        if raster is None:
            raster = self._new_raster()
        self._sealed.append((c, raster))
        self.sealed_upto = c + 1
        return c, self._grid(c, raster)

    def seal_ready(self, watermark: Optional[int]) -> list:
        """Seal (in order) every chunk whose span the watermark has fully
        passed; returns ``[(chunk_index, SnapshotGrid), ...]``."""
        out = []
        if watermark is None:
            return out
        while (self.sealed_upto + 1) * self.chunk_span <= watermark:
            out.append(self._seal_next())
        return out

    def seal_all(self, through_chunk: Optional[int] = None) -> list:
        """End-of-stream: seal through ``through_chunk`` (default: the
        last chunk any event wrote), watermark notwithstanding."""
        target = (self._last_tick // self.T if through_chunk is None
                  else through_chunk)
        out = []
        while self.sealed_upto <= target:
            out.append(self._seal_next())
        return out

    @property
    def last_chunk(self) -> int:
        """Chunk index of the newest tick any event wrote (-1: none)."""
        return self._last_tick // self.T if self._last_tick >= 0 else -1

    def sealed_grid(self, c: int) -> SnapshotGrid:
        """Rebuild the (possibly patched) grid of a sealed chunk still in
        the horizon — the revision walk's input."""
        raster = self._sealed_raster(c)
        if raster is None:
            raise KeyError(
                f"chunk {c} not retained (sealed horizon holds "
                f"{[cc for cc, _ in self._sealed]})")
        return self._grid(c, raster)
