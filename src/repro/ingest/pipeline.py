"""Out-of-order ingestion pipeline: watermarks + reorder buffer + lateness
policy over a :class:`repro.engine.runner.Runner`.

:class:`IngestRunner` is the disorder-tolerant front end of a chunked
runner: events are :meth:`push`\\ ed in any arrival order, rasterized
eagerly by one :class:`~repro.ingest.reorder.ReorderBuffer` per query
input, and :meth:`poll` seals + executes every chunk the watermark has
passed.  Events that arrive behind the sealed frontier go through the
configured lateness policy:

``drop``
    Count the late portion and discard it (the open portion, if any, is
    kept — it is not late).
``revise``
    Patch the sealed rasters (precedence-checked), mark the changed tick
    times dirty, and on the next :meth:`poll` re-run **only** the
    ChangePlan-dilated output segments through the runner's revision
    path (:meth:`Runner.revise` — the compacted sparse compute, never a
    dense chunk replay), emitting versioned :class:`Correction` rows.
``buffer``
    Re-admit the value at the sealed frontier (a one-tick event) when
    the event is entirely late; approximate by construction — sealed
    outputs are *not* corrected — but bounded and cheap.

The headline invariant (pinned in tests/test_ingest.py): with
``revise``, for any arrival permutation within the lateness bound plus
revision horizon, sealed outputs overlaid with corrections are
bit-identical to in-order execution on integer data.

Every decision is counted in the runner's ``obs`` metrics registry
under ``ingest.*`` (see docs/architecture.md "Observability").
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import numpy as np

from ..core import sparse as sparse_mod
from ..core.stream import Event
from ..obs import log_buckets
from .reorder import ReorderBuffer
from .watermark import WatermarkTracker

__all__ = ["Correction", "IngestRunner", "SealedChunk"]

_POLICIES = ("buffer", "revise", "drop")


@dataclasses.dataclass
class SealedChunk:
    """One executed chunk: the runner's output grid(s) at version 0."""

    chunk: int
    t0: int
    version: int
    outputs: Any  # output grid (solo) or {query_name: grid} (union)


@dataclasses.dataclass
class Correction:
    """A versioned revision of an already-sealed chunk's outputs.

    ``seg_mask`` flags the output segments that late data could have
    changed (ChangePlan retro-dilation); only ticks inside flagged
    segments are meaningful in ``outputs`` — everything else is provably
    unchanged from the previous version (clean segments carry scatter
    residue, not recomputed values).  Versions count up from 1 per
    chunk; consumers overlay corrections in version order.
    """

    chunk: int
    t0: int
    version: int
    seg_mask: np.ndarray  # bool (n_segs,) or (n_keys, n_segs)
    outputs: Any


class IngestRunner:
    """Disorder-tolerant ingestion front end over a chunked runner.

    Parameters
    ----------
    runner:
        The :class:`repro.engine.runner.Runner` to feed.  With
        ``policy='revise'`` its revision ring is enabled here
        (:meth:`~repro.engine.runner.Runner.enable_revision`) at the
        derived horizon.
    lateness:
        Bounded lateness in time units (the watermark allowance): events
        up to this far behind their key's newest event land in unsealed
        chunks.  Events later than that hit the lateness policy.
    policy:
        ``'buffer' | 'revise' | 'drop'`` (module docstring).
    horizon_chunks:
        Snapshot/raster retention depth for the revision path.  Default:
        ``ChangePlan.revision_horizon_chunks(lateness, chunk_span)`` —
        the smallest ring that guarantees any in-bound late event is
        revisable (the ``revision`` analysis pass checks this).
    watermark_keys:
        Optional declared key universe for the watermark tracker
        (strict mode — see :class:`WatermarkTracker`).
    stage:
        Optional chunk-staging hook ``{name: grid} -> {name: grid}``
        applied before execution — the serving loop passes its committed
        ``jax.device_put`` here, and when a poll seals several chunks at
        once the next chunk is staged *before* the current one's compute
        dispatch, so its H2D transfer overlaps (the double-buffered
        async data path).  Default: identity.
    """

    def __init__(self, runner, *, lateness: int, policy: str = "revise",
                 horizon_chunks: Optional[int] = None, watermark_keys=None,
                 stage=None):
        if policy not in _POLICIES:
            raise ValueError(
                f"unknown lateness policy {policy!r} (one of {_POLICIES})")
        self.runner = runner
        self.lateness = int(lateness)
        self.policy = policy
        spec = runner.spec
        self.chunk_span = runner.n_segs * spec.span
        cp = spec.change_plan
        if horizon_chunks is None:
            if cp is not None:
                horizon_chunks = cp.revision_horizon_chunks(
                    self.lateness, self.chunk_span)
            else:
                horizon_chunks = max(
                    1, -(-(self.lateness + 1) // self.chunk_span))
        self.horizon_chunks = int(horizon_chunks)
        if policy == "revise":
            runner.enable_revision(self.horizon_chunks,
                                   revise_bound=self.lateness)
        self._stage = stage
        self.tracker = WatermarkTracker(self.lateness, keys=watermark_keys)
        self._bufs = {
            name: ReorderBuffer(
                prec=s.prec, chunk_ticks=s.core * runner.n_segs,
                n_keys=runner.n_keys, keyed=runner.policy.keyed,
                horizon_chunks=self.horizon_chunks)
            for name, s in spec.input_specs.items()}
        # policy='revise' bookkeeping: patched tick times awaiting a
        # revision pass, per input per key
        self._pending: Dict[str, Dict[int, set]] = {}
        self._versions: Dict[int, int] = {}
        self._obs_init()

    # -- telemetry -----------------------------------------------------------
    def _obs_init(self) -> None:
        m = self.metrics = self.runner.metrics
        self._m_events = m.counter(
            "ingest.events", "events admitted", "events")
        self._m_late = m.counter(
            "ingest.late_events",
            "events (partially) behind the sealed frontier", "events")
        self._m_dropped = m.counter(
            "ingest.dropped_events",
            "late portions discarded (policy=drop or beyond horizon)",
            "events")
        self._m_revised = m.counter(
            "ingest.revised_events",
            "late events whose patch changed sealed ticks", "events")
        self._m_buffered = m.counter(
            "ingest.buffered_events",
            "late events re-admitted at the sealed frontier", "events")
        self._m_beyond = m.counter(
            "ingest.beyond_horizon",
            "late events refused: older than the revision horizon",
            "events")
        self._m_sealed = m.counter(
            "ingest.sealed_chunks", "chunks sealed and executed", "chunks")
        self._m_corr = m.counter(
            "ingest.corrections", "versioned correction rows emitted",
            "rows")
        self._m_lat = m.histogram(
            "ingest.lateness", log_buckets(1.0, 1e6, per_decade=1),
            "lateness of late events behind the sealed frontier",
            "time", log_scale=True)
        self._m_lag = m.gauge(
            "ingest.watermark_lag",
            "newest observed event time minus the watermark", "time")

    # -- ingest --------------------------------------------------------------
    def push(self, name: str, ev: Event, key: Optional[int] = None) -> None:
        """Admit one event for input ``name`` (sub-stream ``key`` when the
        runner is keyed), any arrival order.  Late portions go through
        the lateness policy; results surface on the next :meth:`poll`."""
        buf = self._bufs.get(name)
        if buf is None:
            raise KeyError(
                f"unknown input {name!r} (query inputs: "
                f"{sorted(self._bufs)})")
        k = 0 if key is None else int(key)
        late = buf.push(ev, k)
        on = self.metrics.on
        if on:
            self._m_events.add(1)
        if late is not None:
            a, _b = late
            frontier_t = buf.sealed_upto * buf.chunk_span
            if on:
                self._m_late.add(1)
                self._m_lat.observe(max(1, frontier_t - (a + 1) * buf.prec))
            if self.policy == "drop":
                if on:
                    self._m_dropped.add(1)
            elif self.policy == "revise":
                times, beyond = buf.patch(ev, k)
                if beyond:
                    if on:
                        self._m_beyond.add(1)
                        self._m_dropped.add(1)
                elif times.size:
                    if on:
                        self._m_revised.add(1)
                    self._pending.setdefault(name, {}).setdefault(
                        k, set()).update(int(t) for t in times)
            else:  # buffer: re-time a fully-late event to the frontier
                if on:
                    self._m_buffered.add(1)
                if ev.end <= frontier_t:
                    buf.push(Event(frontier_t, frontier_t + buf.prec,
                                   ev.payload), k)
        self.tracker.observe(ev.end, key=(name, k))
        if on:
            lag = self.tracker.lag()
            if lag is not None:
                self._m_lag.set(lag)

    def heartbeat(self, t: int) -> None:
        """Advance every observed key's clock to ``t`` (empty
        punctuation): lets the watermark pass quiet spans so chunks seal
        without new data."""
        self.tracker.heartbeat(t)

    # -- execution -----------------------------------------------------------
    def _execute(self, rows, names) -> list:
        """Step a batch of sealed chunk rows, double-buffered through the
        staging hook: chunk i+1 is staged (its H2D transfer issued, when
        the hook is the serving loop's committed ``device_put``) before
        chunk i's compute dispatch, so transfer and compute overlap."""
        stage = self._stage if self._stage is not None else (lambda c: c)
        sealed = []
        staged = None
        for i, row in enumerate(rows):
            c = row[0][0]
            cur = (staged if staged is not None
                   else stage({n: g for n, (_c, g) in zip(names, row)}))
            if i + 1 < len(rows):
                staged = stage({n: g for n, (_c, g)
                                in zip(names, rows[i + 1])})
            else:
                staged = None
            out = self.runner.step(cur)
            sealed.append(SealedChunk(
                chunk=c, t0=c * self.chunk_span, version=0, outputs=out))
        return sealed

    def poll(self) -> tuple:
        """Run pending revisions, then seal + execute every chunk the
        watermark has passed.  Returns ``(sealed, corrections)`` — lists
        of :class:`SealedChunk` / :class:`Correction`, in order.

        Revisions run *before* sealing: the runner's revision commit must
        extend through its newest stepped chunk, so patched history is
        folded in first and freshly sealed chunks then compute on it."""
        corrections = self._run_revisions()
        sealed = []
        wm = self.tracker.watermark
        if wm is not None:
            per_input = {name: buf.seal_ready(wm)
                         for name, buf in self._bufs.items()}
            names = sorted(per_input)
            sealed = self._execute(
                list(zip(*(per_input[n] for n in names))), names)
            if self.metrics.on and sealed:
                self._m_sealed.add(len(sealed))
        return sealed, corrections

    def flush(self) -> tuple:
        """End of stream: run pending revisions, then seal every chunk
        any event wrote (watermark notwithstanding) and execute them.
        Returns ``(sealed, corrections)`` like :meth:`poll`."""
        corrections = self._run_revisions()
        target = max((buf.last_chunk for buf in self._bufs.values()),
                     default=-1)
        sealed = []
        if target >= 0:
            per_input = {name: buf.seal_all(target)
                         for name, buf in self._bufs.items()}
            names = sorted(per_input)
            sealed = self._execute(
                list(zip(*(per_input[n] for n in names))), names)
            if self.metrics.on and sealed:
                self._m_sealed.add(len(sealed))
        return sealed, corrections

    def _run_revisions(self) -> list:
        """Fold every pending late patch into one revision walk: restore
        the earliest patched chunk's snapshot, re-run the
        ChangePlan-dilated segments of every chunk from there through the
        newest stepped one (committing the patched state), and emit one
        :class:`Correction` per chunk that had dirty segments."""
        if not self._pending:
            return []
        runner = self.runner
        span = self.chunk_span
        cur = runner._t // span
        K, n_segs = runner.n_keys, runner.n_segs
        cp = runner.spec.change_plan
        all_times = [t for per_key in self._pending.values()
                     for ts in per_key.values() for t in ts]
        c_first = min((t - 1) // span for t in all_times)
        chunks, masks = [], []
        for c in range(c_first, cur):
            chunks.append({name: buf.sealed_grid(c)
                           for name, buf in self._bufs.items()})
            mask = np.zeros((K, n_segs), bool)
            for name, per_key in self._pending.items():
                if cp is None:
                    mask[:] = True  # no plan: conservatively all-dirty
                    continue
                sp = cp.specs[name]
                for k, ts in per_key.items():
                    mask[k] |= sparse_mod.retro_segment_mask(
                        sp.lookback, sp.lookahead, sp.prec,
                        c * span, cp.out_prec, cp.out_len, n_segs,
                        sorted(ts))
            masks.append(mask if runner.policy.keyed else mask[0])
        outs = runner.revise(c_first, chunks, masks, commit=True)
        corrections = []
        for i, out in enumerate(outs):
            mk = np.asarray(masks[i]).reshape(K, n_segs)
            if not mk.any():
                continue
            c = c_first + i
            v = self._versions.get(c, 0) + 1
            self._versions[c] = v
            corrections.append(Correction(
                chunk=c, t0=c * span, version=v,
                seg_mask=np.asarray(masks[i]), outputs=out))
        self._pending = {}
        if self.metrics.on and corrections:
            self._m_corr.add(len(corrections))
        return corrections
