"""AdamW with fully-sharded optimizer state (ZeRO-style).

No optax dependency — the three-tree (m, v, params) update is explicit so
state sharding is trivially the parameter sharding, and so the dry-run's
memory analysis reflects exactly what a production deployment would hold:
bf16 params + f32 m/v sharded over the FSDP axis.

Includes global-norm gradient clipping and a weight-decay mask (no decay on
norms/scalars), plus optional bf16 gradient compression for the cross-pod
all-reduce (cast-before-reduce; see DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "init_opt_state", "adamw_update"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    grad_compress_bf16: bool = True  # cast grads to bf16 before all-reduce


def schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_opt_state(params):
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree_util.tree_map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def _decay_mask(path: tuple, leaf) -> bool:
    """Decay everything except vectors/scalars (norm weights, biases)."""
    return leaf.ndim >= 2


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule(cfg, step)

    grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
    gsq = sum(jnp.sum(jnp.square(g))
              for g in jax.tree_util.tree_leaves(grads))
    gnorm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    def upd(p, g, m, v):
        g = g * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m2 / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v2 / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if _decay_mask((), p):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return (new_p, {"m": new_m, "v": new_v, "step": step},
            {"grad_norm": gnorm, "lr": lr})
