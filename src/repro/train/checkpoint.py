"""Fault-tolerant checkpointing with resharding restore (elastic meshes).

Design for 1000+-node operation (see DESIGN.md §4):

* **Atomicity** — checkpoints are written to ``step_N.tmp/`` and renamed to
  ``step_N/`` only after an integrity manifest is fsync'd; a crash mid-write
  never corrupts the latest checkpoint.  ``latest`` is a pointer file
  updated after the rename.
* **Resharding restore** — arrays are stored as full logical tensors (npz
  per top-level bucket); restore places them under *any* mesh/sharding, so
  a job can restart on a smaller or larger mesh after node loss (elastic
  downscale) — ``jax.device_put(array, sharding)`` re-shards on load.
  At real scale each host would write only its local shards (tensorstore-
  style); the manifest/layout here is format-compatible with that extension
  and the write path is factored so the per-host variant only swaps
  ``_save_arrays``.
* **Pipeline state** — the data-pipeline cursor and TiLT StreamRunner tails
  ride in the manifest, so restart is bitwise-resumable.
* **Async** — ``save(..., blocking=False)`` hands the host copy to a writer
  thread; training continues (standard checkpoint-overlap trick).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import warnings
from typing import Any, Dict, Optional

import jax
import ml_dtypes
import numpy as np

# numpy has no native bf16 etc.: persist exotic dtypes via a same-width
# integer view + the logical dtype name in the manifest
_EXOTIC = {"bfloat16": (ml_dtypes.bfloat16, np.uint16),
           "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
           "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8)}


def _to_storable(a: np.ndarray) -> np.ndarray:
    name = a.dtype.name if a.dtype.names is None else str(a.dtype)
    for logical, (dt, view) in _EXOTIC.items():
        if a.dtype == dt:
            return a.view(view)
    return a


def _from_storable(a: np.ndarray, logical: str) -> np.ndarray:
    if logical in _EXOTIC:
        return a.view(_EXOTIC[logical][0])
    return a

__all__ = ["save", "restore", "latest_step", "CheckpointManager"]


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, Any]):
    root: dict = {}
    for key, val in flat.items():
        parts = key.split("/")
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = val
    return root


def save(ckpt_dir: str, step: int, tree: Dict[str, Any],
         extra: Optional[dict] = None, blocking: bool = True) -> str:
    """Save a pytree checkpoint atomically.  Returns the final path."""
    flat = _flatten(tree)
    host = {k: np.asarray(v) for k, v in flat.items()}
    logical_dtypes = {k: (v.dtype.name if hasattr(v.dtype, "name")
                          else str(v.dtype)) for k, v in host.items()}
    host = {k: _to_storable(v) for k, v in host.items()}

    def write():
        tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
        final = os.path.join(ckpt_dir, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k.replace("/", "::"): v for k, v in host.items()})
        manifest = {
            "step": step,
            "keys": sorted(host),
            "shapes": {k: list(v.shape) for k, v in host.items()},
            "dtypes": logical_dtypes,
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        with open(os.path.join(ckpt_dir, "latest.tmp"), "w") as f:
            f.write(str(step))
            f.flush()
            os.fsync(f.fileno())
        os.replace(os.path.join(ckpt_dir, "latest.tmp"),
                   os.path.join(ckpt_dir, "latest"))

    if blocking:
        write()
    else:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t  # caller may join
    return os.path.join(ckpt_dir, f"step_{step}")


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = os.path.join(ckpt_dir, "latest")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


def _available_steps(ckpt_dir: str) -> list:
    """Finalized checkpoint steps on disk, newest first."""
    try:
        entries = os.listdir(ckpt_dir)
    except OSError:
        return []
    steps = []
    for d in entries:
        if d.startswith("step_") and not d.endswith(".tmp"):
            try:
                steps.append(int(d.split("_")[1]))
            except ValueError:
                continue
    return sorted(steps, reverse=True)


def _load_step(ckpt_dir: str, step: int,
               shardings: Optional[Dict[str, Any]]):
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    flat_sh = _flatten(shardings) if isinstance(shardings, dict) else None

    flat = {}
    for k in manifest["keys"]:
        arr = _from_storable(data[k.replace("/", "::")],
                             manifest["dtypes"].get(k, ""))
        if flat_sh and k in flat_sh:
            flat[k] = jax.device_put(arr, flat_sh[k])
        elif shardings is not None and not isinstance(shardings, dict):
            flat[k] = jax.device_put(arr, shardings)
        else:
            flat[k] = jax.numpy.asarray(arr)
    return _unflatten(flat), manifest


def restore(ckpt_dir: str, step: Optional[int] = None,
            shardings: Optional[Dict[str, Any]] = None):
    """Restore a checkpoint; ``shardings`` (flat or tree) re-shards onto the
    current mesh (elastic restart).

    With ``step=None`` (restart discovery), a corrupt or partially
    written newest checkpoint — a truncated ``arrays.npz`` or
    ``manifest.json`` next to an intact ``latest`` pointer, the
    crash-mid-save residue the atomic rename cannot fully rule out on
    non-atomic filesystems — falls back to the next older finalized
    checkpoint with a warning instead of raising.  An explicitly
    requested ``step`` still raises: the caller asked for *that* state,
    and silently handing back another would corrupt the resume."""
    if step is not None:
        return _load_step(ckpt_dir, step, shardings)
    newest = latest_step(ckpt_dir)
    candidates = _available_steps(ckpt_dir)
    if newest is not None:
        # the pointer leads; older finalized dirs follow, newest first
        candidates = [newest] + [s for s in candidates if s != newest]
    if not candidates:
        return None, None
    errors = []
    for s in candidates:
        try:
            tree, manifest = _load_step(ckpt_dir, s, shardings)
        except Exception as e:  # truncated npz/json, missing file, ...
            errors.append((s, e))
            continue
        for prev, err in errors:
            warnings.warn(
                f"checkpoint step_{prev} is corrupt or incomplete "
                f"({type(err).__name__}: {err}); restored step_{s} instead",
                RuntimeWarning, stacklevel=2)
        return tree, manifest
    raise RuntimeError(
        f"no restorable checkpoint in {ckpt_dir!r}: "
        + "; ".join(f"step_{s}: {type(e).__name__}: {e}"
                    for s, e in errors))


class CheckpointManager:
    """Keep-last-K rotation + async writes + restart discovery."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.dir = ckpt_dir
        self.keep = keep
        os.makedirs(ckpt_dir, exist_ok=True)
        self._pending: Optional[threading.Thread] = None

    def save(self, step: int, tree, extra=None, blocking=False):
        if self._pending is not None:
            self._pending.join()  # one in flight at a time
            self._pending = None
        res = save(self.dir, step, tree, extra, blocking=blocking)
        if not blocking:
            self._pending = res
        self._gc()
        return res

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def restore_latest(self, shardings=None):
        self.wait()
        return restore(self.dir, None, shardings)

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.dir)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)
