"""The production train/serve step functions that get pjit-compiled.

Distributed-optimization notes (DESIGN.md §4):

* **Gradient compression**: parameters are bf16, so the DP gradient
  all-reduce XLA inserts is a *bf16* collective — half the cross-pod bytes
  of f32 master-grad training.  Optimizer state stays f32 (m/v), sharded.
* **Compute/comm overlap**: FSDP all-gathers and grad reduce-scatters are
  scheduled by XLA's latency-hiding scheduler inside the layer scan; the
  dry-run HLO is checked for the expected schedule (roofline/analysis.py).
* **Microbatching**: optional gradient accumulation via ``lax.scan`` over
  microbatches (activation memory ∝ 1/n_micro at constant global batch).
* **Donation**: params/opt-state buffers are donated so the update is
  in-place (no 2× parameter peak).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..models.model import Model
from ..models.shardctx import hint
from .optimizer import AdamWConfig, adamw_update, init_opt_state

__all__ = ["make_train_step", "make_serve_steps"]


def make_train_step(model: Model, opt_cfg: Optional[AdamWConfig] = None,
                    n_micro: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    ``batch`` leaves are (B, ...); with n_micro > 1 they are reshaped to
    (n_micro, B/n_micro, ...) and grad-accumulated.
    """
    opt_cfg = opt_cfg or AdamWConfig()

    def loss_fn(params, batch):
        return model.train_loss(params, batch)

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def to_micro(x):
                y = x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:])
                # keep the BATCH axis on the dp mesh axes — without this,
                # GSPMD may shard the microbatch axis instead (catastrophic:
                # devices would own different accumulation steps)
                return hint(y, None, "dp", *([None] * (y.ndim - 2)))

            mb = jax.tree_util.tree_map(to_micro, batch)

            def acc(carry, micro):
                l, g = jax.value_and_grad(loss_fn)(params, micro)
                return (carry[0] + l,
                        jax.tree_util.tree_map(jnp.add, carry[1], g)), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, p.dtype), params)
            (loss, grads), _ = jax.lax.scan(acc, (jnp.zeros(()), zeros), mb)
            loss = loss / n_micro
            grads = jax.tree_util.tree_map(lambda g: g / n_micro, grads)

        new_params, new_opt, metrics = adamw_update(
            params, grads, opt_state, opt_cfg)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def make_serve_steps(model: Model):
    """Returns (prefill_fn, decode_fn) matching the model family's
    signatures (see models/model.py input_specs)."""
    cfg = model.cfg

    if cfg.family == "encdec":
        def prefill_fn(params, tokens, frames):
            return model.prefill(params, tokens, frames)

        def decode_fn(params, caches, tokens, pos, enc_out):
            return model.decode_step(params, caches, tokens, pos, enc_out)
        return prefill_fn, decode_fn

    def prefill_fn(params, tokens):
        return model.prefill(params, tokens)

    def decode_fn(params, caches, tokens, pos):
        return model.decode_step(params, caches, tokens, pos)
    return prefill_fn, decode_fn
