"""recurrentgemma-9b [hybrid]: RG-LRU + local attention, (rec,rec,local)
pattern [arXiv:2402.19427; unverified]."""
from .base import ModelConfig, register

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="griffin",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, d_ff=12288,
    vocab=256_000, head_dim=256, pattern=("rec", "rec", "local"),
    window=2048, mlp_act="gelu", mlp_gated=True, tie_embeddings=True,
    conv_width=4, lru_width=4096,
)

SMOKE = ModelConfig(
    name="recurrentgemma-smoke", family="griffin",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128,
    vocab=512, head_dim=16, pattern=("rec", "rec", "local"),
    window=32, mlp_act="gelu", tie_embeddings=True,
    conv_width=4, lru_width=64, scan_layers=True,
)

register("recurrentgemma-9b", CONFIG, SMOKE)
