"""gemma2-2b [dense]: local/global alternating attention, logit softcaps
[arXiv:2408.00118; hf]."""
from .base import ModelConfig, register

CONFIG = ModelConfig(
    name="gemma2-2b", family="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, d_ff=9216,
    vocab=256_000, head_dim=256, pattern=("local", "global"),
    window=4096, softcap_attn=50.0, softcap_final=30.0,
    mlp_act="gelu", tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma2-smoke", family="dense",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=512, head_dim=16, pattern=("local", "global"),
    window=32, softcap_attn=50.0, softcap_final=30.0,
    mlp_act="gelu", tie_embeddings=True,
)

register("gemma2-2b", CONFIG, SMOKE)
