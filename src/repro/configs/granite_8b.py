"""granite-8b [dense]: llama-architecture code model [arXiv:2405.04324; hf]."""
from .base import ModelConfig, register

CONFIG = ModelConfig(
    name="granite-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=49_152, pattern=("global",), mlp_act="silu",
)

SMOKE = ModelConfig(
    name="granite-smoke", family="dense",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=512, pattern=("global",), mlp_act="silu",
)

register("granite-8b", CONFIG, SMOKE)
