"""Model configuration system for the 10 assigned architectures.

One frozen dataclass describes every architecture family the assignment
covers (dense / MoE / SSM / hybrid / enc-dec / VLM backbone).  Per-arch
modules live next to this file (``<arch>.py``), each exporting ``CONFIG``
(the full assigned configuration) and ``SMOKE`` (a reduced same-family
configuration for CPU smoke tests).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig", "SHAPES", "Shape", "registry", "get_config"]


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | rwkv6 | griffin | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None   # defaults to d_model // n_heads

    # layer pattern, cycled: e.g. ("local","global") for gemma2,
    # ("rec","rec","local") for recurrentgemma, ("global",) for llama-likes
    pattern: Tuple[str, ...] = ("global",)
    window: int = 4096               # local-attention window
    softcap_attn: float = 0.0        # gemma2 attn logit soft cap
    softcap_final: float = 0.0       # gemma2 final logit soft cap
    qk_norm: bool = False            # qwen3 / chameleon
    mlp_act: str = "silu"            # silu (SwiGLU) | gelu (GeGLU / plain)
    mlp_gated: bool = True
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0

    # MoE
    n_experts: int = 0
    topk: int = 0
    capacity_factor: float = 1.25

    # encoder-decoder (whisper): n_layers is the decoder depth
    n_enc_layers: int = 0
    enc_seq: int = 1500              # precomputed audio-frame positions (stub)

    # recurrent families
    conv_width: int = 4              # griffin temporal conv
    lru_width: Optional[int] = None  # griffin RG-LRU width (default d_model)

    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True

    # perf levers (hillclimb knobs; see EXPERIMENTS.md §Perf)
    cache_dtype: str = ""        # "" = dtype; "float8_e4m3fn" halves KV bytes
    seq_parallel: bool = False   # shard residual-stream T over model axis
    rwkv_chunk: int = 0          # 0 = token-by-token scan (faster where the
                                 # state fits cache — CPU-measured; see §Perf
                                 # cell c); L = chunk-parallel (MXU form)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded to a multiple of 128 (MXU lane alignment + even
        model-axis sharding)."""
        return _round_up(self.vocab, 128)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def sub_quadratic(self) -> bool:
        """True when no layer attends over unbounded context (long_500k OK)."""
        return all(k in ("rec", "local", "rwkv") for k in self.pattern)

    def n_params(self) -> int:
        """Approximate parameter count (embeddings included once)."""
        D, F, V = self.d_model, self.d_ff, self.vocab_padded
        H, KV, hd = self.n_heads, self.n_kv_heads, self.hd
        attn = D * H * hd + 2 * D * KV * hd + H * hd * D
        mlp = D * F * (3 if self.mlp_gated else 2)
        if self.is_moe:
            mlp = mlp * self.n_experts + D * self.n_experts  # + router
        rec = 0
        if self.family == "griffin":
            W = self.lru_width or D
            rec = 2 * D * W + W * D + self.conv_width * W + 3 * W
        if self.family == "rwkv6":
            rec = 6 * D * D
        per_layer = {"global": attn + mlp, "local": attn + mlp,
                     "rec": rec + mlp, "rwkv": rec + mlp}
        total = 0
        for i in range(self.n_layers):
            total += per_layer[self.pattern[i % len(self.pattern)]]
        if self.family == "encdec":
            total += self.n_enc_layers * (attn + mlp) + self.n_layers * attn
        total += V * D * (1 if self.tie_embeddings else 2)
        return total

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only routed experts)."""
        if not self.is_moe:
            return self.n_params()
        D, F = self.d_model, self.d_ff
        dense_mlp = D * F * (3 if self.mlp_gated else 2)
        return (self.n_params()
                - self.n_layers * dense_mlp * (self.n_experts - self.topk))


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": Shape("train_4k", "train", 4_096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32_768, 128),
    "long_500k": Shape("long_500k", "decode", 524_288, 1),
}


_REGISTRY: dict[str, tuple] = {}


def register(arch_id: str, config: ModelConfig, smoke: ModelConfig):
    _REGISTRY[arch_id] = (config, smoke)


def registry() -> dict:
    _ensure_loaded()
    return dict(_REGISTRY)


def get_config(arch_id: str, smoke: bool = False) -> ModelConfig:
    _ensure_loaded()
    cfg, sm = _REGISTRY[arch_id]
    return sm if smoke else cfg


_ARCHS = [
    "recurrentgemma_9b", "whisper_large_v3", "gemma2_2b", "granite_8b",
    "qwen3_1_7b", "gemma2_27b", "chameleon_34b", "dbrx_132b",
    "granite_moe_1b", "rwkv6_7b",
]


def _ensure_loaded():
    if _REGISTRY:
        return
    import importlib
    for a in _ARCHS:
        importlib.import_module(f"repro.configs.{a}")
