"""qwen3-1.7b [dense]: GQA with per-head q/k RMSNorm [hf:Qwen/Qwen3-8B; hf]."""
from .base import ModelConfig, register

CONFIG = ModelConfig(
    name="qwen3-1.7b", family="dense",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8, d_ff=6144,
    vocab=151_936, pattern=("global",), qk_norm=True, mlp_act="silu",
    tie_embeddings=True, rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen3-smoke", family="dense",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=512, pattern=("global",), qk_norm=True, mlp_act="silu",
    tie_embeddings=True,
)

register("qwen3-1.7b", CONFIG, SMOKE)
