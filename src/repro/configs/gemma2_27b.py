"""gemma2-27b [dense]: local/global alternating, logit softcaps
[arXiv:2408.00118; hf]."""
from .base import ModelConfig, register

CONFIG = ModelConfig(
    name="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, d_ff=36864,
    vocab=256_000, head_dim=128, pattern=("local", "global"),
    window=4096, softcap_attn=50.0, softcap_final=30.0,
    mlp_act="gelu", tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma2-27b-smoke", family="dense",
    n_layers=4, d_model=96, n_heads=4, n_kv_heads=2, d_ff=192,
    vocab=512, head_dim=24, pattern=("local", "global"),
    window=32, softcap_attn=50.0, softcap_final=30.0,
    mlp_act="gelu", tie_embeddings=True,
)

register("gemma2-27b", CONFIG, SMOKE)
