"""whisper-large-v3 [audio]: encoder-decoder backbone; conv frontend is a
STUB — input_specs() provides precomputed frame embeddings
[arXiv:2212.04356; unverified]."""
from .base import ModelConfig, register

CONFIG = ModelConfig(
    name="whisper-large-v3", family="encdec",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, d_ff=5120,
    vocab=51_866, pattern=("global",), mlp_act="gelu", mlp_gated=False,
    n_enc_layers=32, enc_seq=1500, tie_embeddings=True,
    # 20 heads cannot shard a 16-way model axis: without T-sharding the
    # attention replicates per rank (§Perf cell b's diagnosis) — ship the
    # proven fix as this arch's default
    seq_parallel=True,
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="encdec",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab=512, pattern=("global",), mlp_act="gelu", mlp_gated=False,
    n_enc_layers=2, enc_seq=64, tie_embeddings=True,
)

register("whisper-large-v3", CONFIG, SMOKE)
