"""chameleon-34b [vlm]: early-fusion token LM; VQ image-token frontend is a
STUB — input_specs() provides fused token ids [arXiv:2405.09818;
unverified]."""
from .base import ModelConfig, register

CONFIG = ModelConfig(
    name="chameleon-34b", family="dense",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22016,
    vocab=65_536, pattern=("global",), qk_norm=True, mlp_act="silu",
)

SMOKE = ModelConfig(
    name="chameleon-smoke", family="dense",
    n_layers=3, d_model=64, n_heads=8, n_kv_heads=2, d_ff=128,
    vocab=512, pattern=("global",), qk_norm=True, mlp_act="silu",
)

register("chameleon-34b", CONFIG, SMOKE)
