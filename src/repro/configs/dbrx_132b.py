"""dbrx-132b [moe]: 16 experts top-4 fine-grained MoE
[hf:databricks/dbrx-base; unverified]."""
from .base import ModelConfig, register

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=10752,
    vocab=100_352, pattern=("global",), mlp_act="silu",
    n_experts=16, topk=4, rope_theta=500_000.0,
)

SMOKE = ModelConfig(
    name="dbrx-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
    vocab=512, pattern=("global",), mlp_act="silu",
    n_experts=4, topk=2,
)

register("dbrx-132b", CONFIG, SMOKE)
