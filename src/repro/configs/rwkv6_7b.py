"""rwkv6-7b [ssm]: Finch — attention-free, data-dependent per-channel decay
[arXiv:2404.05892; hf]."""
from .base import ModelConfig, register

CONFIG = ModelConfig(
    name="rwkv6-7b", family="rwkv6",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64, d_ff=14336,
    vocab=65_536, head_dim=64, pattern=("rwkv",), mlp_act="relu_sq",
    mlp_gated=False,
)

SMOKE = ModelConfig(
    name="rwkv6-smoke", family="rwkv6",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab=512, head_dim=16, pattern=("rwkv",), mlp_act="relu_sq",
    mlp_gated=False,
)

register("rwkv6-7b", CONFIG, SMOKE)
