"""granite-moe-1b-a400m [moe]: 32 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
from .base import ModelConfig, register

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, d_ff=512,
    vocab=49_155, pattern=("global",), mlp_act="silu",
    n_experts=32, topk=8, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="granite-moe-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=64,
    vocab=512, pattern=("global",), mlp_act="silu",
    n_experts=8, topk=2, tie_embeddings=True,
)

register("granite-moe-1b-a400m", CONFIG, SMOKE)
