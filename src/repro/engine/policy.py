"""Composable execution policies (TiLT's thesis applied to our own stack).

The paper's central systems claim is that a time-centric IR plus a static
planning layer lets optimization and parallelization strategies *compose*
instead of being baked into per-strategy executors.  Our stack had drifted
the other way: every capability grew a sibling entry point (``StreamRunner``,
``SparseStreamRunner``, ``KeyedEngine``, ``MultiQuerySession``, …), and the
pairings those silos could not express (sparse × mesh, sparse × union,
keyed × multi-segment) were exactly the ROADMAP's remaining items.

:class:`ExecPolicy` names the four orthogonal axes of chunked execution —
each resolved by its own *planning artifact*, all consumed by the single
unified runner (:mod:`repro.engine.runner`):

====================  ======================  ===========================
axis                  values                  planning artifact
====================  ======================  ===========================
``body``              ``dense`` | ``sparse``  :class:`repro.core.plan.ChangePlan`
``keys``              ``single`` | ``vmapped``  key-axis vmap (paper §6.2)
``placement``         ``local`` | mesh(axis)  shard_map over the work axis
``dag``               ``solo`` | ``union``    :func:`repro.core.plan.plan_union`
====================  ======================  ===========================

``placement`` shards the *work-unit* axis: the key axis for
``keys='vmapped'`` (keys never communicate — no collectives), the segment
axis for ``keys='single'`` (segments within a chunk are distributed, with
the chunk buffer replicated; the multi-hop ppermute chain of
:mod:`repro.core.halo` remains the one-shot time-sharded path,
:func:`repro.core.parallel.shard_map_run`).

The old entry points survive as thin deprecated wrappers over
``Runner(exe, ExecPolicy(...))`` — see docs/architecture.md.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

from jax.sharding import Mesh

__all__ = ["ExecPolicy", "MeshPlacement", "mesh_placement",
           "BODIES", "KEYS", "PLACEMENTS", "DAGS"]

BODIES = ("dense", "sparse")
KEYS = ("single", "vmapped")
PLACEMENTS = ("local", "mesh")
DAGS = ("solo", "union")


@dataclasses.dataclass(frozen=True)
class MeshPlacement:
    """``placement=mesh(axis)``: shard the policy's work axis along one
    named mesh axis (the key axis when ``keys='vmapped'``, the segment
    axis when ``keys='single'``)."""

    mesh: Mesh
    axis: str = "data"

    def __repr__(self) -> str:  # keep policy repr readable in test output
        return f"mesh(axis={self.axis!r}, n={self.mesh.shape[self.axis]})"


def mesh_placement(mesh: Mesh, axis: str = "data") -> MeshPlacement:
    """The ``mesh(axes)`` constructor for :class:`ExecPolicy.placement`."""
    return MeshPlacement(mesh, axis)


@dataclasses.dataclass(frozen=True)
class ExecPolicy:
    """One point in the execution-policy space ``body × keys × placement ×
    dag``.  Pure configuration — validation against a concrete query
    (lookahead, divisibility, ChangePlan presence) happens when a
    :class:`repro.engine.runner.Runner` is built from it."""

    body: str = "dense"
    keys: str = "single"
    placement: Union[str, MeshPlacement] = "local"
    dag: str = "solo"

    def __post_init__(self):
        if self.body not in BODIES:
            raise ValueError(f"body={self.body!r} not in {BODIES}")
        if self.keys not in KEYS:
            raise ValueError(f"keys={self.keys!r} not in {KEYS}")
        if self.dag not in DAGS:
            raise ValueError(f"dag={self.dag!r} not in {DAGS}")
        if isinstance(self.placement, Mesh):
            # accept a bare Mesh for convenience: mesh over its default axis
            object.__setattr__(
                self, "placement", MeshPlacement(self.placement,
                                                 self.placement.axis_names[0]))
        if self.placement != "local" and not isinstance(self.placement,
                                                        MeshPlacement):
            raise ValueError(
                f"placement={self.placement!r} must be 'local', a Mesh, or "
                "mesh_placement(mesh, axis)")

    # -- accessors -----------------------------------------------------------
    @property
    def sparse(self) -> bool:
        return self.body == "sparse"

    @property
    def keyed(self) -> bool:
        return self.keys == "vmapped"

    @property
    def union(self) -> bool:
        return self.dag == "union"

    @property
    def mesh(self) -> Optional[Mesh]:
        return (self.placement.mesh
                if isinstance(self.placement, MeshPlacement) else None)

    @property
    def axis(self) -> str:
        return (self.placement.axis
                if isinstance(self.placement, MeshPlacement) else "data")

    @property
    def n_shards(self) -> int:
        m = self.mesh
        return m.shape[self.axis] if m is not None else 1

    def describe(self) -> str:
        """Compact ``dense×single×local×solo``-style label (benchmarks)."""
        placement = ("local" if self.mesh is None
                     else f"mesh{self.n_shards}")
        return f"{self.body}×{self.keys}×{placement}×{self.dag}"
