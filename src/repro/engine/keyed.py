"""Keyed multi-stream engine: K sub-streams × time partitions (paper §6.2).

The paper's second parallelism axis — *partitioned streams* — composes with
time partitioning: each key (user, symbol, campaign) owns an independent
timeline, and the static plan (plan.py) makes every partition of every key
synchronization-free.  :class:`KeyedEngine` exploits both axes at once:

* **key axis**: the compiled query's traceable body is ``vmap``-ped over a
  leading key dimension — one fused XLA computation advances all K keys.
* **time axis**: like :class:`repro.core.parallel.StreamRunner`, the engine
  carries, per input, only the trailing ``left_halo`` ticks of the previous
  chunk — now shaped ``(K, left_halo, ...)``.  State size is the boundary
  contract × K, independent of stream length, and checkpointable.
* **devices**: with a mesh, the key axis shards along a named mesh axis via
  ``shard_map`` — keys never communicate, so the SPMD body needs no
  collectives at all (cheaper than even the time-sharded ppermute path).

Ingestion convention: every input grid carries a leading key axis — value
leaves are ``(K, T, ...)``, validity is ``(K, T)``.  ``SnapshotGrid.t0`` /
``prec`` refer to the shared time grid (keys are time-aligned; ragged
arrival is expressed per key through the validity mask, which φ-semantics
handle exactly).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core import compile as qcompile
from ..core import ir
from ..core import sparse as sparse_mod
from ..core.stream import SnapshotGrid

__all__ = ["KeyedEngine", "keyed_grid", "wrap_keyed_step"]


def keyed_grid(value, valid, t0: int = 0, prec: int = 1) -> SnapshotGrid:
    """Build a keyed SnapshotGrid from ``(K, T, ...)`` arrays."""
    v = jax.tree_util.tree_map(jnp.asarray, value)
    return SnapshotGrid(value=v, valid=jnp.asarray(valid), t0=t0, prec=prec)


def wrap_keyed_step(step, mesh: Optional[Mesh], axis: str = "data"):
    """Stage a ``(tails, chunks) -> (out, new_tails)`` step for keyed
    execution: shard the leading key axis along ``axis`` when a mesh is
    given (keys never communicate, so the SPMD body needs no collectives),
    then jit.  Shared by :class:`KeyedEngine` and the multi-query session
    (repro.multiquery), so both layers stage their chunk step identically.
    """
    if mesh is not None:
        from jax.experimental.shard_map import shard_map
        step = shard_map(step, mesh=mesh,
                         in_specs=(P(axis), P(axis)),
                         out_specs=(P(axis), P(axis)),
                         check_rep=False)
    return jax.jit(step)


@dataclasses.dataclass
class KeyedEngine:
    """Continuous keyed execution with carried per-key halo state.

    ``exe`` must be compiled for the per-partition ``out_len``; queries must
    be lookback-only (lookahead would delay output — same contract as
    StreamRunner).  ``mesh`` (optional) shards the key axis along ``axis``;
    ``n_keys`` must then be divisible by the axis size.

    ``sparse=True`` (requires ``compile_query(..., sparse=True)``) enables
    change-compressed stepping: each step, only the keys whose inputs
    changed — per-key dirty masks carried across partitions exactly like
    the halo tails, dilated by the :class:`~repro.core.plan.ChangePlan`
    contract — are gathered into a power-of-two-bucketed compaction buffer
    and computed; idle keys hold their previous output tick (see
    :mod:`repro.core.sparse`).  This is the fraud/dashboard fan-out
    scenario where >95% of keys are idle per partition.  Sparse mode does
    not compose with ``mesh`` yet (the key-compaction gather is global
    across the key axis).
    """

    exe: qcompile.CompiledQuery
    n_keys: int
    mesh: Optional[Mesh] = None
    axis: str = "data"
    sparse: bool = False
    _tails: Dict[str, tuple] = dataclasses.field(default_factory=dict)
    _t: int = 0  # absolute time of the next output partition start
    _step_fn: object = dataclasses.field(default=None, repr=False)
    # sparse-mode state: per-key change metadata carried like the halo
    _dirty_tails: Dict[str, jax.Array] = dataclasses.field(
        default_factory=dict)
    _prev: Dict[str, tuple] = dataclasses.field(default_factory=dict)
    _seed: Optional[tuple] = dataclasses.field(default=None, repr=False)
    _started: bool = False

    def __post_init__(self):
        for name, s in self.exe.input_specs.items():
            if s.right_halo > 0:
                raise NotImplementedError(
                    "KeyedEngine supports lookback-only queries "
                    f"(input {name} has lookahead)")
        if self.mesh is not None and self.n_keys % self.mesh.shape[self.axis]:
            raise ValueError(
                f"n_keys={self.n_keys} not divisible by mesh axis "
                f"'{self.axis}' of size {self.mesh.shape[self.axis]}")
        if self.sparse:
            if self.exe.change_plan is None:
                raise ValueError(
                    "KeyedEngine(sparse=True) needs a query compiled with "
                    "sparse=True")
            if self.mesh is not None:
                raise NotImplementedError(
                    "sparse keyed execution does not compose with mesh "
                    "sharding yet (the key-compaction gather is global)")
        keyed_inputs = [n.name for n in ir.free_inputs(self.exe.root)
                        if n.keyed]
        if keyed_inputs and set(keyed_inputs) != set(self.exe.input_specs):
            raise ValueError(
                "query mixes keyed and unkeyed sources: "
                f"keyed={keyed_inputs}, all={sorted(self.exe.input_specs)}")
        # the jitted step is cached on the CompiledQuery so that fresh
        # engine instances (new stream epochs, benchmark repeats) reuse the
        # traced+compiled computation instead of re-jitting a new closure
        cache = self.exe.__dict__.setdefault("_keyed_step_cache", {})
        key = (self.mesh, self.axis)
        if key not in cache:
            cache[key] = self._build_step()
        self._step_fn = cache[key]

    # -- staged step ---------------------------------------------------------
    def _build_step(self):
        exe = self.exe
        names = sorted(exe.input_specs)
        specs = exe.input_specs

        def step(tails, chunks):
            full = []
            for name in names:
                tv, tm = tails[name]
                cv, cm = chunks[name]
                fv = jax.tree_util.tree_map(
                    lambda a, b: jnp.concatenate([a, b], axis=1), tv, cv)
                fm = jnp.concatenate([tm, cm], axis=1)
                full.append((fv, fm))

            def one(*flat):
                return exe.trace_fn(dict(zip(names, flat)))

            out = jax.vmap(one)(*full)
            new_tails = {}
            for name, (fv, fm) in zip(names, full):
                s = specs[name]
                # the trailing left_halo ticks start at index `core`
                new_tails[name] = (
                    jax.tree_util.tree_map(
                        lambda x: jax.lax.slice_in_dim(
                            x, s.core, s.core + s.left_halo, axis=1), fv),
                    jax.lax.slice_in_dim(fm, s.core, s.core + s.left_halo,
                                         axis=1))
            return out, new_tails

        return wrap_keyed_step(step, self.mesh, self.axis)

    def _init_tails(self, chunks: Dict[str, SnapshotGrid]):
        for name, spec in self.exe.input_specs.items():
            g = chunks[name]
            hl = spec.left_halo
            tv = jax.tree_util.tree_map(
                lambda x: jnp.zeros((self.n_keys, hl) + x.shape[2:], x.dtype),
                g.value)
            tm = jnp.zeros((self.n_keys, hl), bool)
            self._tails[name] = self._place((tv, tm))
            if self.sparse:
                self._dirty_tails[name] = jnp.zeros((self.n_keys, hl), bool)
                self._prev[name] = (
                    jax.tree_util.tree_map(
                        lambda x: jnp.zeros((self.n_keys, 1) + x.shape[2:],
                                            x.dtype), g.value),
                    jnp.zeros((self.n_keys, 1), bool))

    # -- sparse (change-compressed) stepping ---------------------------------
    def _sparse_mask_fn(self):
        """Jitted phase 1: assemble per-key buffers, diff the chunk against
        the carried snapshots, dilate dirtiness through the DAG and reduce
        to one dirty flag per key; also advances the carried change state."""
        exe = self.exe
        names = sorted(exe.input_specs)
        specs = exe.input_specs
        cp = exe.change_plan
        S, q = exe.out_len, exe.out_prec

        def mask(tails, dirty_tails, prev, chunks):
            bufs, new_tails, new_dt, new_prev = {}, {}, {}, {}
            key_dirty = None
            for name in names:
                s = specs[name]
                hl = s.left_halo
                tv, tm = tails[name]
                cv, cm = chunks[name]
                bv = jax.tree_util.tree_map(
                    lambda a, b: jnp.concatenate([a, b], axis=1), tv, cv)
                bm = jnp.concatenate([tm, cm], axis=1)
                bufs[name] = (bv, bm)
                pv, pm = prev[name]
                d_chunk = jax.vmap(
                    lambda v, m, p0, p1: sparse_mod.source_dirty(
                        v, m, (p0, p1)))(cv, cm, pv, pm)
                full_d = jnp.concatenate([dirty_tails[name], d_chunk], axis=1)
                sp = cp.specs[name]
                i_lo, i_hi1 = sparse_mod.seg_ranges(
                    sp.lookback, sp.lookahead, s.prec, grid_t0=-hl * s.prec,
                    out_t0=0, out_prec=q, seg_len=S, n_segs=1)
                lo = int(np.clip(i_lo[0], 0, full_d.shape[1]))
                hi = int(np.clip(i_hi1[0], 0, full_d.shape[1]))
                kd = full_d[:, lo:hi].any(axis=1)
                key_dirty = kd if key_dirty is None else key_dirty | kd
                L = full_d.shape[1]
                new_tails[name] = (
                    jax.tree_util.tree_map(
                        lambda x: jax.lax.slice_in_dim(
                            x, s.core, s.core + hl, axis=1), bv),
                    jax.lax.slice_in_dim(bm, s.core, s.core + hl, axis=1))
                new_dt[name] = jax.lax.slice_in_dim(full_d, L - hl, L, axis=1)
                new_prev[name] = (
                    jax.tree_util.tree_map(lambda x: x[:, -1:], cv),
                    cm[:, -1:])
            return bufs, key_dirty, new_tails, new_dt, new_prev

        return mask

    def _sparse_compute_fn(self, capacity: int):
        """Jitted phase 2 for one compaction capacity: gather the dirty
        keys' buffers, run the vmapped body on them only, scatter back with
        the per-key hold seed filling idle keys."""
        exe = self.exe
        names = sorted(exe.input_specs)

        def compute(bufs, key_dirty, seed_v, seed_m):
            key_ids = jnp.nonzero(key_dirty, size=capacity, fill_value=0)[0]
            gath = []
            for name in names:
                bv, bm = bufs[name]
                gath.append((
                    jax.tree_util.tree_map(
                        lambda x: jnp.take(x, key_ids, axis=0), bv),
                    jnp.take(bm, key_ids, axis=0)))

            def one(*flat):
                return exe.trace_fn(dict(zip(names, flat)))

            out_v, out_m = jax.vmap(one)(*gath)          # (C, S, ...)
            pos = jnp.clip(jnp.cumsum(key_dirty) - 1, 0, capacity - 1)
            full_v = jax.tree_util.tree_map(
                lambda x: jnp.take(x, pos, axis=0), out_v)  # (K, S, ...)
            full_m = jnp.take(out_m, pos, axis=0)

            def bc(mask, x):
                return mask.reshape(mask.shape + (1,) * (x.ndim - 1))

            ov = jax.tree_util.tree_map(
                lambda f, sv: jnp.where(bc(key_dirty, f), f,
                                        sv[:, None].astype(f.dtype)),
                full_v, seed_v)
            om = jnp.where(key_dirty[:, None], full_m, seed_m[:, None])
            new_seed = (
                jax.tree_util.tree_map(lambda x: x[:, -1], ov), om[:, -1])
            return (ov, om), new_seed

        return compute

    def _sparse_zero_seed(self, bufs):
        """φ hold seed, one output tick per key (unused before the forced
        all-dirty first step, but the jitted step needs the arrays)."""
        names = sorted(self.exe.input_specs)
        avals = {}
        for name in names:
            bv, bm = bufs[name]
            avals[name] = (
                jax.tree_util.tree_map(
                    lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), bv),
                jax.ShapeDtypeStruct(bm.shape[1:], jnp.bool_))
        out_v, out_m = jax.eval_shape(self.exe.trace_fn, avals)
        return (jax.tree_util.tree_map(
            lambda a: jnp.zeros((self.n_keys,) + a.shape[1:], a.dtype),
            out_v), jnp.zeros((self.n_keys,), bool))

    def _sparse_step(self, chunk_in: Dict[str, tuple]) -> tuple:
        exe = self.exe
        cache = exe.__dict__.setdefault("_keyed_sparse_cache", {})
        if "mask" not in cache:
            cache["mask"] = jax.jit(self._sparse_mask_fn())
        bufs, key_dirty, new_tails, new_dt, new_prev = cache["mask"](
            self._tails, self._dirty_tails, self._prev, chunk_in)
        if key_dirty is None:  # input-free query: nothing to skip
            key_dirty = jnp.ones((self.n_keys,), bool)
        if not self._started:
            key_dirty = jnp.ones((self.n_keys,), bool)  # hold-seed base case
            self._started = True
        n = int(jnp.sum(key_dirty))
        cap = sparse_mod.bucket_capacity(n, self.n_keys)
        if ("compute", cap) not in cache:
            cache[("compute", cap)] = jax.jit(self._sparse_compute_fn(cap))
        seed = (self._seed if self._seed is not None
                else self._sparse_zero_seed(bufs))
        out, self._seed = cache[("compute", cap)](bufs, key_dirty, *seed)
        self._tails, self._dirty_tails, self._prev = (
            new_tails, new_dt, new_prev)
        return out

    def _place(self, tree):
        if self.mesh is None:
            return tree
        sh = NamedSharding(self.mesh, P(self.axis))
        return jax.tree_util.tree_map(lambda x: jax.device_put(x, sh), tree)

    # -- public API ----------------------------------------------------------
    def step(self, chunks: Dict[str, SnapshotGrid]) -> SnapshotGrid:
        """Advance every key by one partition of fresh core ticks.

        Each chunk grid must be ``(n_keys, spec.core, ...)``; returns the
        ``(n_keys, out_len)`` output partition."""
        for name, spec in self.exe.input_specs.items():
            g = chunks[name]
            # a real exception, not an assert: this is user-input
            # validation and must survive ``python -O``
            if tuple(g.valid.shape) != (self.n_keys, spec.core):
                raise ValueError(
                    f"input {name}: chunk validity shape "
                    f"{tuple(g.valid.shape)} != (n_keys, core) = "
                    f"{(self.n_keys, spec.core)}")
        if not self._tails:
            self._init_tails(chunks)
        chunk_in = {name: self._place((chunks[name].value,
                                       chunks[name].valid))
                    for name in self.exe.input_specs}
        if self.sparse:
            v, m = self._sparse_step(chunk_in)
        else:
            (v, m), self._tails = self._step_fn(self._tails, chunk_in)
        out = SnapshotGrid(value=v, valid=m, t0=self._t,
                           prec=self.exe.out_prec)
        self._t += self.exe.out_len * self.exe.out_prec
        return out

    def run(self, inputs: Dict[str, SnapshotGrid],
            n_parts: int) -> SnapshotGrid:
        """Feed ``n_parts`` partitions sliced from full keyed streams and
        stitch the outputs along time (axis 1)."""
        outs = []
        for k in range(n_parts):
            chunk = {}
            for name, spec in self.exe.input_specs.items():
                g = inputs[name]
                lo = k * spec.core
                chunk[name] = SnapshotGrid(
                    value=jax.tree_util.tree_map(
                        lambda x: jax.lax.slice_in_dim(
                            x, lo, lo + spec.core, axis=1), g.value),
                    valid=jax.lax.slice_in_dim(
                        g.valid, lo, lo + spec.core, axis=1),
                    t0=g.t0 + lo * spec.prec, prec=spec.prec)
            outs.append(self.step(chunk))
        value = jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs, axis=1),
            *[o.value for o in outs])
        valid = jnp.concatenate([o.valid for o in outs], axis=1)
        return SnapshotGrid(value=value, valid=valid, t0=outs[0].t0,
                            prec=self.exe.out_prec)

    def reset(self) -> None:
        """Drop carried state; the next step starts a fresh stream at t=0."""
        self._tails = {}
        self._dirty_tails = {}
        self._prev = {}
        self._seed = None
        self._started = False
        self._t = 0

    # -- checkpointing -------------------------------------------------------
    def state(self) -> Dict:
        """Checkpointable engine state (host arrays)."""
        to_np = lambda t: jax.tree_util.tree_map(np.asarray, t)  # noqa: E731
        out = {k: to_np(v) for k, v in self._tails.items()} | {"__t": self._t}
        if self.sparse:
            out["__sparse"] = {
                "dirty": {k: np.asarray(v)
                          for k, v in self._dirty_tails.items()},
                "prev": {k: to_np(v) for k, v in self._prev.items()},
                "seed": None if self._seed is None else to_np(self._seed),
                "started": self._started}
        return out

    def restore(self, state: Dict) -> None:
        """Restore a :meth:`state` checkpoint, validating it against this
        engine's configuration first.

        Every inconsistency — wrong input names, wrong key count, wrong
        tail length (a checkpoint from a different query/plan), a stream
        clock misaligned with the partition span, missing or unexpected
        sparse change state — raises a ``ValueError`` naming the mismatch,
        instead of surfacing later as an opaque shape error inside the
        jitted step.
        """
        state = dict(state)
        if "__t" not in state:
            raise ValueError("checkpoint has no '__t' stream clock")
        t = state.pop("__t")
        span = self.exe.out_len * self.exe.out_prec
        if not isinstance(t, (int, np.integer)) or t < 0 or t % span:
            raise ValueError(
                f"checkpoint stream clock __t={t!r} is not a non-negative "
                f"multiple of the partition span {span} — was this saved "
                "from an engine with a different out_len/out_prec?")
        sparse_state = state.pop("__sparse", None)
        if self.sparse and sparse_state is None:
            raise ValueError(
                "sparse engine cannot restore a dense checkpoint: no "
                "'__sparse' change state (dirty tails / snapshots / seed)")
        if not self.sparse and sparse_state is not None:
            raise ValueError(
                "dense engine cannot restore a sparse checkpoint "
                "(carries '__sparse' change state)")
        names = set(self.exe.input_specs)
        if state and set(state) != names:
            unknown = sorted(set(state) - names)
            missing = sorted(names - set(state))
            raise ValueError(
                f"checkpoint inputs {sorted(state)} != query inputs "
                f"{sorted(names)} (unknown={unknown}, missing={missing})")
        for name, (tv, tm) in state.items():
            hl = self.exe.input_specs[name].left_halo
            got = tuple(np.shape(tm))
            if got != (self.n_keys, hl):
                raise ValueError(
                    f"input {name}: checkpoint tail shape {got} != "
                    f"(n_keys, left_halo) = {(self.n_keys, hl)}")
            for leaf in jax.tree_util.tree_leaves(tv):
                if tuple(np.shape(leaf)[:2]) != (self.n_keys, hl):
                    raise ValueError(
                        f"input {name}: checkpoint tail value leaf shape "
                        f"{tuple(np.shape(leaf))} does not lead with "
                        f"(n_keys, left_halo) = {(self.n_keys, hl)}")
        self._t = t
        self._tails = {k: self._place(
            jax.tree_util.tree_map(jnp.asarray, v))
            for k, v in state.items()}
        if self.sparse and sparse_state is not None:
            dirty = sparse_state["dirty"]
            for name in state:
                hl = self.exe.input_specs[name].left_halo
                got = tuple(np.shape(dirty.get(name, ())))
                if got != (self.n_keys, hl):
                    raise ValueError(
                        f"input {name}: checkpoint dirty-tail shape {got} "
                        f"!= (n_keys, left_halo) = {(self.n_keys, hl)}")
            self._dirty_tails = {k: jnp.asarray(v) for k, v in dirty.items()}
            self._prev = {k: jax.tree_util.tree_map(jnp.asarray, v)
                          for k, v in sparse_state["prev"].items()}
            seed = sparse_state["seed"]
            self._seed = (None if seed is None
                          else jax.tree_util.tree_map(jnp.asarray, seed))
            self._started = bool(sparse_state["started"])
