"""Keyed multi-stream engine: K sub-streams × time partitions (paper §6.2).

The paper's second parallelism axis — *partitioned streams* — composes with
time partitioning: each key (user, symbol, campaign) owns an independent
timeline, and the static plan (plan.py) makes every partition of every key
synchronization-free.  :class:`KeyedEngine` exploits both axes at once:

* **key axis**: the compiled query's traceable body is ``vmap``-ped over a
  leading key dimension — one fused XLA computation advances all K keys.
* **time axis**: like :class:`repro.core.parallel.StreamRunner`, the engine
  carries, per input, only the trailing ``left_halo`` ticks of the previous
  chunk — now shaped ``(K, left_halo, ...)``.  State size is the boundary
  contract × K, independent of stream length, and checkpointable.
* **devices**: with a mesh, the key axis shards along a named mesh axis via
  ``shard_map`` — keys never communicate, so the SPMD body needs no
  collectives at all (cheaper than even the time-sharded ppermute path).

Ingestion convention: every input grid carries a leading key axis — value
leaves are ``(K, T, ...)``, validity is ``(K, T)``.  ``SnapshotGrid.t0`` /
``prec`` refer to the shared time grid (keys are time-aligned; ragged
arrival is expressed per key through the validity mask, which φ-semantics
handle exactly).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core import compile as qcompile
from ..core import ir
from ..core.stream import SnapshotGrid

__all__ = ["KeyedEngine", "keyed_grid", "wrap_keyed_step"]


def keyed_grid(value, valid, t0: int = 0, prec: int = 1) -> SnapshotGrid:
    """Build a keyed SnapshotGrid from ``(K, T, ...)`` arrays."""
    v = jax.tree_util.tree_map(jnp.asarray, value)
    return SnapshotGrid(value=v, valid=jnp.asarray(valid), t0=t0, prec=prec)


def wrap_keyed_step(step, mesh: Optional[Mesh], axis: str = "data"):
    """Stage a ``(tails, chunks) -> (out, new_tails)`` step for keyed
    execution: shard the leading key axis along ``axis`` when a mesh is
    given (keys never communicate, so the SPMD body needs no collectives),
    then jit.  Shared by :class:`KeyedEngine` and the multi-query session
    (repro.multiquery), so both layers stage their chunk step identically.
    """
    if mesh is not None:
        from jax.experimental.shard_map import shard_map
        step = shard_map(step, mesh=mesh,
                         in_specs=(P(axis), P(axis)),
                         out_specs=(P(axis), P(axis)),
                         check_rep=False)
    return jax.jit(step)


@dataclasses.dataclass
class KeyedEngine:
    """Continuous keyed execution with carried per-key halo state.

    ``exe`` must be compiled for the per-partition ``out_len``; queries must
    be lookback-only (lookahead would delay output — same contract as
    StreamRunner).  ``mesh`` (optional) shards the key axis along ``axis``;
    ``n_keys`` must then be divisible by the axis size.
    """

    exe: qcompile.CompiledQuery
    n_keys: int
    mesh: Optional[Mesh] = None
    axis: str = "data"
    _tails: Dict[str, tuple] = dataclasses.field(default_factory=dict)
    _t: int = 0  # absolute time of the next output partition start
    _step_fn: object = dataclasses.field(default=None, repr=False)

    def __post_init__(self):
        for name, s in self.exe.input_specs.items():
            if s.right_halo > 0:
                raise NotImplementedError(
                    "KeyedEngine supports lookback-only queries "
                    f"(input {name} has lookahead)")
        if self.mesh is not None and self.n_keys % self.mesh.shape[self.axis]:
            raise ValueError(
                f"n_keys={self.n_keys} not divisible by mesh axis "
                f"'{self.axis}' of size {self.mesh.shape[self.axis]}")
        keyed_inputs = [n.name for n in ir.free_inputs(self.exe.root)
                        if n.keyed]
        if keyed_inputs and set(keyed_inputs) != set(self.exe.input_specs):
            raise ValueError(
                "query mixes keyed and unkeyed sources: "
                f"keyed={keyed_inputs}, all={sorted(self.exe.input_specs)}")
        # the jitted step is cached on the CompiledQuery so that fresh
        # engine instances (new stream epochs, benchmark repeats) reuse the
        # traced+compiled computation instead of re-jitting a new closure
        cache = self.exe.__dict__.setdefault("_keyed_step_cache", {})
        key = (self.mesh, self.axis)
        if key not in cache:
            cache[key] = self._build_step()
        self._step_fn = cache[key]

    # -- staged step ---------------------------------------------------------
    def _build_step(self):
        exe = self.exe
        names = sorted(exe.input_specs)
        specs = exe.input_specs

        def step(tails, chunks):
            full = []
            for name in names:
                tv, tm = tails[name]
                cv, cm = chunks[name]
                fv = jax.tree_util.tree_map(
                    lambda a, b: jnp.concatenate([a, b], axis=1), tv, cv)
                fm = jnp.concatenate([tm, cm], axis=1)
                full.append((fv, fm))

            def one(*flat):
                return exe.trace_fn(dict(zip(names, flat)))

            out = jax.vmap(one)(*full)
            new_tails = {}
            for name, (fv, fm) in zip(names, full):
                s = specs[name]
                # the trailing left_halo ticks start at index `core`
                new_tails[name] = (
                    jax.tree_util.tree_map(
                        lambda x: jax.lax.slice_in_dim(
                            x, s.core, s.core + s.left_halo, axis=1), fv),
                    jax.lax.slice_in_dim(fm, s.core, s.core + s.left_halo,
                                         axis=1))
            return out, new_tails

        return wrap_keyed_step(step, self.mesh, self.axis)

    def _init_tails(self, chunks: Dict[str, SnapshotGrid]):
        for name, spec in self.exe.input_specs.items():
            g = chunks[name]
            hl = spec.left_halo
            tv = jax.tree_util.tree_map(
                lambda x: jnp.zeros((self.n_keys, hl) + x.shape[2:], x.dtype),
                g.value)
            tm = jnp.zeros((self.n_keys, hl), bool)
            self._tails[name] = self._place((tv, tm))

    def _place(self, tree):
        if self.mesh is None:
            return tree
        sh = NamedSharding(self.mesh, P(self.axis))
        return jax.tree_util.tree_map(lambda x: jax.device_put(x, sh), tree)

    # -- public API ----------------------------------------------------------
    def step(self, chunks: Dict[str, SnapshotGrid]) -> SnapshotGrid:
        """Advance every key by one partition of fresh core ticks.

        Each chunk grid must be ``(n_keys, spec.core, ...)``; returns the
        ``(n_keys, out_len)`` output partition."""
        for name, spec in self.exe.input_specs.items():
            g = chunks[name]
            # a real exception, not an assert: this is user-input
            # validation and must survive ``python -O``
            if tuple(g.valid.shape) != (self.n_keys, spec.core):
                raise ValueError(
                    f"input {name}: chunk validity shape "
                    f"{tuple(g.valid.shape)} != (n_keys, core) = "
                    f"{(self.n_keys, spec.core)}")
        if not self._tails:
            self._init_tails(chunks)
        chunk_in = {name: self._place((chunks[name].value,
                                       chunks[name].valid))
                    for name in self.exe.input_specs}
        (v, m), self._tails = self._step_fn(self._tails, chunk_in)
        out = SnapshotGrid(value=v, valid=m, t0=self._t,
                           prec=self.exe.out_prec)
        self._t += self.exe.out_len * self.exe.out_prec
        return out

    def run(self, inputs: Dict[str, SnapshotGrid],
            n_parts: int) -> SnapshotGrid:
        """Feed ``n_parts`` partitions sliced from full keyed streams and
        stitch the outputs along time (axis 1)."""
        outs = []
        for k in range(n_parts):
            chunk = {}
            for name, spec in self.exe.input_specs.items():
                g = inputs[name]
                lo = k * spec.core
                chunk[name] = SnapshotGrid(
                    value=jax.tree_util.tree_map(
                        lambda x: jax.lax.slice_in_dim(
                            x, lo, lo + spec.core, axis=1), g.value),
                    valid=jax.lax.slice_in_dim(
                        g.valid, lo, lo + spec.core, axis=1),
                    t0=g.t0 + lo * spec.prec, prec=spec.prec)
            outs.append(self.step(chunk))
        value = jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs, axis=1),
            *[o.value for o in outs])
        valid = jnp.concatenate([o.valid for o in outs], axis=1)
        return SnapshotGrid(value=value, valid=valid, t0=outs[0].t0,
                            prec=self.exe.out_prec)

    def reset(self) -> None:
        """Drop carried state; the next step starts a fresh stream at t=0."""
        self._tails = {}
        self._t = 0

    # -- checkpointing -------------------------------------------------------
    def state(self) -> Dict:
        """Checkpointable engine state (host arrays)."""
        return {k: jax.tree_util.tree_map(np.asarray, v)
                for k, v in self._tails.items()} | {"__t": self._t}

    def restore(self, state: Dict) -> None:
        state = dict(state)
        self._t = state.pop("__t")
        self._tails = {k: self._place(
            jax.tree_util.tree_map(jnp.asarray, v))
            for k, v in state.items()}
