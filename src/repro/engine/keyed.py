"""Keyed multi-stream engine: K sub-streams × time partitions (paper §6.2).

.. deprecated::
    :class:`KeyedEngine` is now a thin wrapper over the unified policy
    runner — ``Runner(exe, ExecPolicy(keys="vmapped", ...), n_keys=K)``
    (:mod:`repro.engine.runner`).  It is kept as a deprecated alias for one
    release; new code should construct the policy directly, which also
    unlocks the combinations this class historically rejected
    (``sparse=True`` with ``mesh`` now routes through the per-shard
    compaction path instead of raising).

The execution model is unchanged: the compiled query's traceable body is
vmapped over a leading key dimension, the only cross-chunk state is the
per-key halo tail (boundary contract × K, independent of stream length,
checkpointable), and an optional mesh shards the key axis — keys never
communicate, so the SPMD body needs no collectives at all.

Ingestion convention: every input grid carries a leading key axis — value
leaves are ``(K, T, ...)``, validity is ``(K, T)``.  ``SnapshotGrid.t0`` /
``prec`` refer to the shared time grid (keys are time-aligned; ragged
arrival is expressed per key through the validity mask, which φ-semantics
handle exactly).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..core import compile as qcompile
from ..core.stream import SnapshotGrid
from .policy import ExecPolicy, MeshPlacement
from .runner import Runner

__all__ = ["KeyedEngine", "keyed_grid", "wrap_keyed_step"]


def keyed_grid(value, valid, t0: int = 0, prec: int = 1) -> SnapshotGrid:
    """Build a keyed SnapshotGrid from ``(K, T, ...)`` arrays."""
    v = jax.tree_util.tree_map(jnp.asarray, value)
    return SnapshotGrid(value=v, valid=jnp.asarray(valid), t0=t0, prec=prec)


def wrap_keyed_step(step, mesh: Optional[Mesh], axis: str = "data"):
    """Stage a ``(tails, chunks) -> (out, new_tails)`` step for keyed
    execution: shard the leading key axis along ``axis`` when a mesh is
    given (keys never communicate, so the SPMD body needs no collectives),
    then jit.  Deprecated: the unified runner stages its own steps; kept
    for external callers building custom keyed steps.
    """
    if mesh is not None:
        from jax.experimental.shard_map import shard_map
        step = shard_map(step, mesh=mesh,
                         in_specs=(P(axis), P(axis)),
                         out_specs=(P(axis), P(axis)),
                         check_rep=False)
    return jax.jit(step)


@dataclasses.dataclass
class KeyedEngine:
    """Continuous keyed execution with carried per-key halo state
    (deprecated alias for ``Runner(exe, ExecPolicy(keys='vmapped'))``).

    ``exe`` must be compiled for the per-partition ``out_len``; queries must
    be lookback-only (lookahead would delay output — same contract as every
    chunked runner).  ``mesh`` (optional) shards the key axis along
    ``axis``; ``n_keys`` must then be divisible by the axis size.

    ``sparse=True`` (requires ``compile_query(..., sparse=True)``) enables
    change-compressed stepping: only the keys whose inputs changed are
    gathered into a power-of-two-bucketed compaction buffer and computed;
    idle keys hold their previous output tick (see
    :mod:`repro.core.sparse`).  Sparse mode now composes with ``mesh``:
    the compaction is resolved *per shard* (local nonzero + per-shard
    capacity buckets), so the gather never crosses devices.
    """

    exe: qcompile.CompiledQuery
    n_keys: int
    mesh: Optional[Mesh] = None
    axis: str = "data"
    sparse: bool = False
    _runner: Runner = dataclasses.field(default=None, repr=False)

    def __post_init__(self):
        warnings.warn(
            "KeyedEngine is deprecated; use repro.engine.Runner with "
            "ExecPolicy(keys='vmapped', ...)", DeprecationWarning,
            stacklevel=3)
        policy = ExecPolicy(
            body="sparse" if self.sparse else "dense", keys="vmapped",
            placement=(MeshPlacement(self.mesh, self.axis)
                       if self.mesh is not None else "local"))
        self._runner = Runner(self.exe, policy, n_keys=self.n_keys)

    # -- public API ----------------------------------------------------------
    def step(self, chunks: Dict[str, SnapshotGrid]) -> SnapshotGrid:
        """Advance every key by one partition of fresh core ticks.

        Each chunk grid must be ``(n_keys, spec.core, ...)``; returns the
        ``(n_keys, out_len)`` output partition."""
        return self._runner.step(chunks)

    def run(self, inputs: Dict[str, SnapshotGrid],
            n_parts: int) -> SnapshotGrid:
        """Feed ``n_parts`` partitions sliced from full keyed streams and
        stitch the outputs along time (axis 1)."""
        return self._runner.run(inputs, n_parts)

    def reset(self) -> None:
        """Drop carried state; the next step starts a fresh stream at t=0."""
        self._runner.reset()

    # -- checkpointing (delegated to the unified state/validate path) --------
    def state(self) -> Dict:
        """Checkpointable engine state (host arrays)."""
        return self._runner.state()

    def restore(self, state: Dict) -> None:
        """Restore a :meth:`state` checkpoint, validating it against this
        engine's configuration first (see :meth:`Runner.restore`)."""
        self._runner.restore(state)
