"""Keyed multi-stream execution engine.

The third layer of the query pipeline (frontend/IR → plan → codegen →
**engine**): runs a compiled TiLT query over *K keyed sub-streams ×
time partitions* — millions of independent per-key timelines (users,
stock symbols, ad campaigns) advancing chunk by chunk with carried halo
state, vectorized over the key axis and sharded across a device mesh.
"""
from .keyed import KeyedEngine, keyed_grid, wrap_keyed_step

__all__ = ["KeyedEngine", "keyed_grid", "wrap_keyed_step"]
