"""Execution engine: one policy-driven runner for every chunked strategy.

The third layer of the query pipeline (frontend/IR → plan → codegen →
**engine**): :class:`Runner` advances a compiled TiLT query (or a
multi-query union DAG) chunk by chunk under an :class:`ExecPolicy` — the
four orthogonal axes ``body`` (dense | sparse), ``keys`` (single |
vmapped), ``placement`` (local | mesh) and ``dag`` (solo | union) compose
freely around a single carried state pytree with one
checkpoint/restore/validate path.  :class:`KeyedEngine` survives as a
deprecated alias for ``Runner(exe, ExecPolicy(keys="vmapped"))``.
"""
from .keyed import KeyedEngine, keyed_grid, wrap_keyed_step
from .policy import ExecPolicy, MeshPlacement, mesh_placement
from .runner import BodySpec, Runner, body_spec_of

__all__ = ["KeyedEngine", "keyed_grid", "wrap_keyed_step",
           "ExecPolicy", "MeshPlacement", "mesh_placement",
           "BodySpec", "Runner", "body_spec_of"]
