"""One chunked runner for every execution policy (body × keys × placement ×
dag).

Every chunked executor in the stack — ``StreamRunner``,
``SparseStreamRunner``, ``KeyedEngine``, ``MultiQuerySession`` — used to
carry its own copy of the same machinery: concatenate carried halo tails
with the fresh chunk, stage a per-partition body, slice new tails off the
buffer, advance a stream clock, checkpoint it all.  :class:`Runner` owns
that machinery exactly once, parameterized by an
:class:`repro.engine.policy.ExecPolicy`; the old entry points are thin
deprecated wrappers over it.

Execution model (one ``step`` = one chunk):

* The chunk timeline is cut into ``segs_per_chunk`` **segments** of
  ``out_len`` output ticks each (one planned partition per segment).  Work
  units are ``keys × segments``; a dense body computes every unit, a sparse
  body only the units whose dilated input lineage saw a change
  (:class:`repro.core.plan.ChangePlan`), the rest *hold* their previous
  output (see :mod:`repro.core.sparse` for the semantics and exactness
  argument).
* ``keys='vmapped'`` adds a leading key axis to every grid; internally the
  runner always carries the key axis (``K=1`` for ``keys='single'``), so
  there is exactly one code path.
* ``placement=mesh(axis)`` shards the *work-unit* axis over the mesh: whole
  keys when keyed (buffers and carried state shard with them — no
  collectives, keys never communicate), segments when single-keyed (the
  chunk buffer is replicated).  Sparse compaction is **per shard**: each
  device resolves its local dirty units with a local ``nonzero`` into a
  per-shard power-of-two capacity bucket, so the gather never crosses
  devices — this is what lets sparse execution compose with mesh sharding
  (the global-gather limitation ``KeyedEngine(sparse=True)`` used to reject).
* ``dag='union'`` runs the union DAG of N queries (one
  :class:`repro.core.plan.UnionPlan`) and returns one grid per query; the
  merged :class:`~repro.core.plan.ChangePlan` of the union is the per-input
  union of the per-query dilations, so sparse execution composes with
  multi-query sharing too.

State pytree (the *only* cross-chunk state, host-roundtrippable through
:meth:`Runner.state` / :meth:`Runner.restore` with one validation path)::

    { input_name: (value_tail, valid_tail),   # trailing left_halo ticks
      "__t": int,                             # stream clock
      "__sparse": {                           # body='sparse' only
         "dirty": {input_name: dirty_tail},   # change flags for those ticks
         "prev":  {input_name: 1-tick snapshot},  # halo-free inputs only:
                                              # next chunk's tick 0 diffs
                                              # vs this (halo-carrying
                                              # inputs read the dirty tail)
         "seed":  {out_name: last output tick},   # hold seed per output
         "started": bool } }
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

import time

from ..core import ir
from ..core import sparse as sparse_mod
from ..core.plan import ChangePlan, InputSpec, seg_range_affine
from ..core.stream import SnapshotGrid
from ..kernels import sparse_compact
from ..obs import Metrics, log_buckets
from .policy import ExecPolicy

__all__ = ["BodySpec", "Runner", "body_spec_of"]

_tm = jax.tree_util.tree_map


@dataclasses.dataclass
class BodySpec:
    """Everything the unified runner needs to know about a per-segment body.

    A body evaluates one planned partition: given ``{input_name: (value,
    valid)}`` grids covering one segment plus halo (``input_specs``), it
    returns ``{out_name: (value, valid)}`` output grids of ``span //
    out_precs[name]`` ticks each.  Solo queries are the single-output case
    (``out_name == "__out"``); union DAGs return one entry per query.

    ``step_cache`` holds the staged (traced + jitted) chunk steps, keyed by
    execution geometry — share it across Runner instances over the same
    compiled query so fresh runners (new stream epochs, benchmark repeats)
    reuse compiled executables.
    """

    input_specs: Dict[str, InputSpec]
    out_len: int     # segment length in ticks of the reference output grid
    out_prec: int
    outs_fn: Callable[[Dict[str, tuple]], Dict[str, tuple]]
    out_precs: Dict[str, int]
    change_plan: Optional[ChangePlan] = None
    root: Optional[ir.Node] = None
    jit: bool = True
    solo: bool = True
    step_cache: dict = dataclasses.field(default_factory=dict)
    # IR roots backing outs_fn, for static verification (repro.analysis):
    # solo bodies carry (root,); union bodies one root per query.  Empty
    # means the body is opaque (hand-built outs_fn) and the temporal-plan
    # verifier can only check internal plan consistency, not re-derive it.
    roots: tuple = ()

    @property
    def span(self) -> int:
        return self.out_len * self.out_prec


def body_spec_of(exe) -> BodySpec:
    """The :class:`BodySpec` of a :class:`repro.core.compile.CompiledQuery`
    (the ``dag='solo'`` case).  The step cache lives on the CompiledQuery,
    so every Runner over the same executable shares staged steps."""

    def outs_fn(inputs: Dict[str, tuple]) -> Dict[str, tuple]:
        return {"__out": exe.trace_fn(inputs)}

    return BodySpec(
        input_specs=exe.input_specs, out_len=exe.out_len,
        out_prec=exe.out_prec, outs_fn=outs_fn,
        out_precs={"__out": exe.out_prec},
        change_plan=getattr(exe, "change_plan", None), root=exe.root,
        jit=True, solo=True,
        step_cache=exe.__dict__.setdefault("_runner_step_cache", {}),
        roots=(exe.root,) if exe.root is not None else ())


def _bc(mask, x):
    """Broadcast a leading-axes mask over the trailing dims of ``x``."""
    return mask.reshape(mask.shape + (1,) * (x.ndim - mask.ndim))


class Runner:
    """Chunked streaming execution under one :class:`ExecPolicy`.

    Parameters
    ----------
    exe_or_spec:
        A :class:`~repro.core.compile.CompiledQuery` (``dag='solo'``; pass
        ``sparse=True`` to :func:`~repro.core.compile.compile_query` for a
        sparse body) or a prebuilt :class:`BodySpec` (the union path —
        see :func:`repro.multiquery.union_runner`).
    policy:
        The execution policy.  ``keys='vmapped'`` requires ``n_keys``;
        ``placement=mesh`` shards keys (vmapped) or segments (single) and
        requires the respective count to divide the mesh axis size.
    segs_per_chunk:
        Segments consumed per :meth:`step`; each chunk supplies
        ``segs_per_chunk · spec.core`` fresh ticks per input.
    metrics:
        An :class:`repro.obs.Metrics` registry to accumulate runtime
        telemetry into (``runner.*`` metric names — see
        docs/architecture.md "Observability").  Default: a fresh private
        registry on ``self.metrics``.  Pass a shared registry to pool
        telemetry across runners (e.g. a session rebuilding its runner
        across attach/detach): device-resident accumulations of the
        previous owner are folded to host first, so nothing is lost.
    """

    def __init__(self, exe_or_spec, policy: ExecPolicy = ExecPolicy(), *,
                 n_keys: Optional[int] = None, segs_per_chunk: int = 1,
                 metrics: Optional[Metrics] = None):
        spec = (exe_or_spec if isinstance(exe_or_spec, BodySpec)
                else body_spec_of(exe_or_spec))
        if policy.union != (not spec.solo):
            raise ValueError(
                f"policy dag={policy.dag!r} does not match the body "
                f"(solo={spec.solo}); union runners need a union BodySpec "
                "(see repro.multiquery.union_runner)")
        if segs_per_chunk < 1:
            raise ValueError("segs_per_chunk must be >= 1")
        self.spec, self.policy = spec, policy
        self.n_segs = segs_per_chunk
        if policy.keyed:
            if n_keys is None:
                raise ValueError("keys='vmapped' needs n_keys")
            self.n_keys = n_keys
        else:
            if n_keys not in (None, 1):
                raise ValueError(
                    f"keys='single' runs one stream (got n_keys={n_keys}); "
                    "use ExecPolicy(keys='vmapped') for keyed sub-streams")
            self.n_keys = 1

        span = spec.span
        for name, s in spec.input_specs.items():
            if s.right_halo > 0:
                raise NotImplementedError(
                    "chunked runners support lookback-only queries "
                    f"(input {name} has lookahead)")
            if s.core * s.prec != span:
                raise ValueError(
                    f"input {name}: segment span {span} not a multiple of "
                    f"input precision {s.prec}")
        if policy.sparse and spec.change_plan is None:
            raise ValueError(
                "ExecPolicy(body='sparse') needs a query compiled with "
                "sparse=True (no ChangePlan attached)")
        if spec.root is not None and policy.keyed:
            keyed_inputs = [n.name for n in ir.free_inputs(spec.root)
                            if n.keyed]
            if keyed_inputs and set(keyed_inputs) != set(spec.input_specs):
                raise ValueError(
                    "query mixes keyed and unkeyed sources: "
                    f"keyed={keyed_inputs}, all={sorted(spec.input_specs)}")
        if policy.mesh is not None:
            n = policy.n_shards
            if policy.keyed and self.n_keys % n:
                raise ValueError(
                    f"n_keys={self.n_keys} not divisible by mesh axis "
                    f"'{policy.axis}' of size {n}")
            if not policy.keyed and self.n_segs % n:
                raise ValueError(
                    f"segs_per_chunk={self.n_segs} not divisible by mesh "
                    f"axis '{policy.axis}' of size {n}")

        # -- the unified state pytree ---------------------------------------
        self._tails: Dict[str, tuple] = {}
        self._sparse: Optional[dict] = (
            {"dirty": {}, "prev": {}, "seed": {}, "started": False}
            if policy.sparse else None)
        self._t = 0
        # -- sparse-body diagnostics (device-resident: reading them via
        # dirty_stats() syncs, accumulating them does not) ------------------
        self.last_seg_dirty = None
        self._dirty_units = None
        self._total_units = 0
        self._chunks_run = 0
        self._mstate = None  # (dirty_total, bucket_picks, frac_counts)
        # -- late-data revision ring (off unless enable_revision) -----------
        self._rev_ring: Optional[collections.deque] = None
        self.revision_horizon = 0
        self.revise_bound: Optional[int] = None
        # -- AOT serving record (populated by install_executable) -----------
        # staging key -> {"label", "how": "loaded"|"compiled", "donate"}:
        # the serving analysis pass reads this to prove every step a served
        # policy point dispatches is backed by an AOT executable
        self.aot_record: Dict[tuple, dict] = {}
        self._obs_init(metrics)

    # -- telemetry -----------------------------------------------------------
    def _obs_init(self, metrics: Optional[Metrics]) -> None:
        """Create/bind the runner's metric handles (see the metric-names
        reference in docs/architecture.md).  Device-resident metrics hold
        references into ``self._mstate``, the per-runner device
        accumulator state updated by one jitted dispatch per sparse chunk
        (:meth:`_obs_accum`); host metrics are plain Python arithmetic."""
        self.metrics = m = metrics if metrics is not None else Metrics()
        self._m_chunks = m.counter(
            "runner.chunks", "chunks stepped", "chunks")
        self._m_units = m.counter(
            "runner.units", "work units (keys x segments) presented",
            "units")
        self._m_keys = m.gauge("runner.keys", "keyed sub-streams", "keys")
        self._m_keys.set(self.n_keys)
        self._m_donated = m.counter(
            "runner.donated_steps",
            "steps run through a buffer-donating jitted step", "steps")
        self._m_lat = m.histogram(
            "runner.step_seconds", log_buckets(1e-5, 10.0, per_decade=3),
            "per-chunk step wall time (dispatch, not device completion)",
            "s", log_scale=True)
        self._m_rev_runs = m.counter(
            "runner.revision_runs", "late-data revision re-runs", "runs")
        self._m_rev_chunks = m.counter(
            "runner.revision_chunks",
            "sealed chunks re-stepped by revisions", "chunks")
        self._m_rev_units = m.counter(
            "runner.revision_units",
            "work units recomputed by revisions (ChangePlan-dilated dirty "
            "segments only)", "units")
        # device-resident handles: fold any previous owner's device refs
        # into the host base before this runner's mstate takes over
        self._m_dirty = m.counter(
            "runner.dirty_units", "work units that actually computed",
            "units")
        self._m_dirty.fold_device()
        ladder = (sparse_mod.capacity_ladder(self._U // self.policy.n_shards)
                  if self.policy.sparse else [])
        self._obs_caps = np.asarray(ladder, np.int32)
        if ladder:
            labels = [str(c) for c in ladder]
            prior = m.get("runner.bucket_picks")
            if prior is not None and prior.labels != labels:
                # a rebuilt runner at a new geometry has a new ladder —
                # the old slots don't mean anything anymore
                m.drop("runner.bucket_picks")
            self._m_picks = m.vector(
                "runner.bucket_picks", labels,
                "per-shard capacity-bucket selections (slot = capacity)",
                "picks")
            self._m_picks.fold_device()
        else:
            self._m_picks = None
        self._obs_frac_edges = np.linspace(1 / 16, 1.0, 16)
        self._m_frac = m.histogram(
            "runner.dirty_fraction", [round(float(e), 6)
                                      for e in self._obs_frac_edges],
            "per-chunk dirty work-unit fraction", "fraction")
        self._m_frac.fold_device()
        m.register_collector("runner", self._obs_collect)
        m.register_warmup_reset("runner", self._obs_warmup_reset)

    def _obs_warmup_reset(self) -> None:
        """Registry warmup-reset hook (:meth:`repro.obs.Metrics.
        reset_after_warmup`): re-base this runner's device accumulator and
        compaction window so long-lived services scope percentiles past
        the compiling first chunks.  The stream state itself (tails,
        clock, sparse change state) is untouched — only measurements
        reset.  The fresh mstate is created eagerly here (off the hot
        path) so the next chunk's accumulator dispatch stays
        transfer-free, and static gauges are re-asserted."""
        if self.policy.sparse:
            self._mstate = (jnp.zeros((), jnp.int32),
                            jnp.zeros((len(self._obs_caps),), jnp.int32),
                            jnp.zeros((len(self._obs_frac_edges) + 1,),
                                      jnp.int32))
        else:
            self._mstate = None
        self._dirty_units = None
        self._total_units = 0
        self._chunks_run = 0
        self._m_keys.set(self.n_keys)

    def _obs_collect(self) -> None:
        """Pre-snapshot hook: derived gauges (syncs — off the hot path)."""
        m = self.metrics
        entries = 0
        for f in self.spec.step_cache.values():
            size = getattr(f, "_cache_size", None)
            if callable(size):
                entries += size()
        # jax's own jit-cache entry count across this query's staged
        # steps: together with the tracer's per-key compile counts this
        # catches shape-driven retraces *inside* one staged step
        m.gauge("runner.jit_entries",
                "live jax jit-cache entries across staged steps").set(entries)
        stats = self.dirty_stats()
        if stats is not None:
            m.gauge("runner.compact",
                    "dirty fraction since construction/reset",
                    "fraction").set(stats["compact"])

    def _obs_accum(self):
        """The per-chunk device metric accumulator: ONE jitted dispatch
        folds every device-resident metric update (dirty total, per-shard
        bucket picks, dirty-fraction histogram) into the running mstate.
        Donates mstate, so the buffers update in place; the metric
        handles then just re-point at the new leaves (no dispatch, no
        transfer)."""
        key = self._cache_key("obs_accum")
        cache = self.spec.step_cache
        if key in cache:
            return cache[key]
        caps = self._obs_caps
        edges = self._obs_frac_edges
        U = self._U
        n_shards = self.policy.n_shards
        U_loc = U // n_shards

        def accum(mstate, seg_dirty):
            total, picks, frac = mstate
            # exact per-shard counts: the unit axis splits contiguously
            # over shards, so this mirrors the fused step's in-shard pick
            per_shard = seg_dirty.reshape(n_shards, U_loc).sum(
                axis=1, dtype=jnp.int32)
            cnt = per_shard.sum()
            b = jnp.clip(jnp.searchsorted(jnp.asarray(caps), per_shard,
                                          side="left"),
                         0, len(caps) - 1)
            f = cnt.astype(jnp.float32) / U
            fi = jnp.searchsorted(jnp.asarray(edges, jnp.float32), f,
                                  side="left")
            return (total + cnt,
                    picks.at[b].add(1),
                    frac.at[fi].add(1))

        self.metrics.tracer.record_compile(self._compile_label(key))
        return self._stage(key, accum, donate=(0,))

    def _obs_sparse_chunk(self, seg_dirty) -> None:
        """Per-sparse-chunk device metric update: one jitted accumulator
        dispatch plus reference re-binds — zero device→host transfers."""
        if self._mstate is None:
            self._mstate = (jnp.zeros((), jnp.int32),
                            jnp.zeros((len(self._obs_caps),), jnp.int32),
                            jnp.zeros((len(self._obs_frac_edges) + 1,),
                                      jnp.int32))
        self._mstate = self._obs_accum()(self._mstate, seg_dirty)
        total, picks, frac = self._mstate
        self._m_dirty.set_device(total)
        self._m_picks.set_device(picks)
        self._m_frac.set_device(frac)
        # dirty_stats() reads the same accumulator (runner-local view)
        self._dirty_units = total

    # -- geometry ------------------------------------------------------------
    @property
    def _K(self) -> int:
        return self.n_keys

    @property
    def _U(self) -> int:
        return self.n_keys * self.n_segs

    def _names(self):
        return sorted(self.spec.input_specs)

    def _place(self, tree):
        """Device placement of carried per-key state (key-axis sharding)."""
        if self.policy.mesh is None or not self.policy.keyed:
            return tree
        sh = NamedSharding(self.policy.mesh, P(self.policy.axis))
        return _tm(lambda x: jax.device_put(x, sh), tree)

    # every configuration degree of freedom the staged steps close over;
    # _cache_key is built from exactly these (in this order) so the staging
    # cache can never be keyed on less than the traces depend on.  The
    # recompile-hazard pass (repro.analysis) probes this contract: perturb
    # one DOF on a sibling runner, check the key really moves.
    _KEY_DOFS = ("K", "n_segs", "mesh", "axis", "jit")

    def staging_key_dofs(self) -> Dict:
        """The staging-cache key's degrees of freedom, by name."""
        return {"K": self._K, "n_segs": self.n_segs,
                "mesh": self.policy.mesh, "axis": self.policy.axis,
                "jit": self.spec.jit}

    def _cache_key(self, kind, *extra):
        dofs = self.staging_key_dofs()
        return (kind,) + tuple(dofs[k] for k in self._KEY_DOFS) + extra

    def _stage(self, key, fn, donate=()):
        """Jit + cache one staged step; the raw traced fn and its donation
        contract stay inspectable at ``("raw",) + key`` for the static
        auditor (repro.analysis), which re-traces them under
        ``jax.make_jaxpr`` instead of guessing from the compiled form."""
        cache = self.spec.step_cache
        cache[("raw",) + key] = (fn, tuple(donate))
        cache[key] = (jax.jit(fn, donate_argnums=tuple(donate))
                      if self.spec.jit else fn)
        return cache[key]

    def _compile_label(self, key) -> str:
        """Human-readable compile-counter key for a step_cache key (the
        recompile detector's unit of accounting)."""
        kind, K, n_segs, mesh, axis = key[0], key[1], key[2], key[3], key[4]
        parts = [f"K={K}", f"segs={n_segs}"]
        if mesh is not None:
            parts.append(f"mesh={axis}")
        parts += [str(x) for x in key[6:]]
        return f"{kind}({','.join(parts)})"

    def _shard_body(self, fn, n_buf_args: int, unit_bufs: bool = False):
        """Wrap the per-unit compute ``fn(w, bufs...)`` in shard_map over
        the work-unit axis when a mesh is placed.  ``unit_bufs`` marks the
        buffer args as already per-unit (dense path: gathered windows shard
        with the units); otherwise they are the raw chunk buffers, which
        shard with the keys when keyed and replicate when single-keyed
        (each shard gathers its own segments from the full buffer)."""
        mesh, axis = self.policy.mesh, self.policy.axis
        if mesh is None:
            return fn
        from jax.experimental.shard_map import shard_map
        buf_spec = P(axis) if (unit_bufs or self.policy.keyed) else P()
        return shard_map(
            fn, mesh=mesh,
            in_specs=(P(axis),) + (buf_spec,) * n_buf_args,
            out_specs=P(axis), check_rep=False)

    # -- chunk ingest --------------------------------------------------------
    def _ingest(self, chunks: Dict[str, SnapshotGrid]) -> Dict[str, tuple]:
        chunk_in = {}
        for name in self._names():
            s = self.spec.input_specs[name]
            g = chunks[name]
            want = ((self.n_keys, s.core * self.n_segs) if self.policy.keyed
                    else (s.core * self.n_segs,))
            if tuple(g.valid.shape) != want:
                raise ValueError(
                    f"input {name}: chunk validity shape "
                    f"{tuple(g.valid.shape)} != expected {want}")
            v, m = g.value, g.valid
            if not self.policy.keyed:  # internal layout always carries K
                v, m = _tm(lambda x: x[None], v), m[None]
            chunk_in[name] = self._place((v, m))
        return chunk_in

    def _init_missing_tails(self, chunk_in: Dict[str, tuple]) -> None:
        K = self._K
        for name in self._names():
            if name in self._tails:
                continue
            hl = self.spec.input_specs[name].left_halo
            cv, cm = chunk_in[name]
            tv = _tm(lambda x: jnp.zeros((K, hl) + x.shape[2:], x.dtype), cv)
            self._tails[name] = self._place((tv, jnp.zeros((K, hl), bool)))
            if self._sparse is not None and name not in self._sparse["dirty"]:
                self._sparse["dirty"][name] = jnp.zeros((K, hl), bool)
                if hl == 0:
                    # the 1-tick snapshot is only ever read for halo-free
                    # inputs (tick 0's diff partner); halo-carrying inputs
                    # get their position-0 flag from the dirty tail, so
                    # carrying a snapshot for them would be dead state
                    self._sparse["prev"][name] = (
                        _tm(lambda x: jnp.zeros((K, 1) + x.shape[2:],
                                                x.dtype), cv),
                        jnp.zeros((K, 1), bool))

    # -- dense step ----------------------------------------------------------
    def _dense_step(self):
        key = self._cache_key("dense")
        cache = self.spec.step_cache
        if key in cache:
            return cache[key]
        self.metrics.tracer.record_compile(self._compile_label(key))
        names, specs = self._names(), self.spec.input_specs
        outs_fn = self.spec.outs_fn
        K, n_segs, U = self._K, self.n_segs, self._U
        # static per-input gather map: segment k's halo window starts at
        # buffer tick k·core (the carried tail supplies segment 0's halo)
        idx_maps = {
            name: np.arange(n_segs)[:, None] * specs[name].core
            + np.arange(specs[name].length)[None, :] for name in names}

        def units_body(*flat):
            def one(*f):
                return outs_fn(dict(zip(names, f)))
            return jax.vmap(one)(*flat)

        def units_sharded(w, *flat):  # w unused: dense computes every unit
            return units_body(*flat)

        sharded = self._shard_body(units_sharded, len(names), unit_bufs=True)

        def step(tails, chunks):
            full, units = {}, []
            for name in names:
                tv, tm = tails[name]
                cv, cm = chunks[name]
                fv = _tm(lambda a, b: jnp.concatenate([a, b], axis=1), tv, cv)
                fm = jnp.concatenate([tm, cm], axis=1)
                full[name] = (fv, fm)
                L = specs[name].length
                idx = jnp.asarray(idx_maps[name])
                gv = _tm(lambda x: jnp.take(x, idx, axis=1).reshape(
                    (U, L) + x.shape[2:]), fv)
                gm = jnp.take(fm, idx, axis=1).reshape(U, L)
                units.append((gv, gm))
            outs = sharded(jnp.ones((U,), bool), *units)
            outs = {o: (_tm(lambda x: x.reshape(
                        (K, n_segs * x.shape[1]) + x.shape[2:]), ov),
                        om.reshape(K, -1))
                    for o, (ov, om) in outs.items()}
            new_tails = {}
            for name in names:
                s = specs[name]
                lo = s.core * n_segs
                fv, fm = full[name]
                new_tails[name] = (
                    _tm(lambda x: jax.lax.slice_in_dim(
                        x, lo, lo + s.left_halo, axis=1), fv),
                    jax.lax.slice_in_dim(fm, lo, lo + s.left_halo, axis=1))
            return outs, new_tails

        # the carried tails are runner-owned (step outputs, or zeros /
        # restore-copies) — donate them so steady-state chunks update the
        # halo buffers in place instead of reallocating
        return self._stage(key, step, donate=(0,))

    # -- sparse body (one fused jitted step per chunk) -----------------------
    #
    # The three phases that used to run as separate jitted calls — mask
    # (diff + ChangePlan dilation + per-unit reduction), compute (per-shard
    # compaction gather → vmapped body → scatter) and hold — are traced into
    # ONE step: the capacity bucket is picked on device (`searchsorted` over
    # the ladder + `lax.switch`), so a steady-state chunk issues zero
    # device→host transfers, and the carried state pytree is donated so
    # tails/snapshots/seeds update in place.

    def _compute_local(self, cap: int):
        """Per-shard compute body for one compaction capacity: resolve the
        local dirty units (local ``nonzero`` into the power-of-two bucket),
        gather their halo windows, run the vmapped body on them only,
        scatter the results back over the local unit axis.  Cached per
        capacity — these are the branches of the fused step's
        ``lax.switch`` ladder (and the observable record of which buckets
        this geometry can run)."""
        key = self._cache_key("compute", cap)
        cache = self.spec.step_cache
        if key in cache:
            return cache[key]
        self.metrics.tracer.record_compile(self._compile_label(key))
        names, specs = self._names(), self.spec.input_specs
        outs_fn = self.spec.outs_fn
        n_segs = self.n_segs
        keyed = self.policy.keyed
        mesh, axis = self.policy.mesh, self.policy.axis
        U_loc = self._U // self.policy.n_shards

        full_cap = cap == U_loc

        def local(w, *flat):
            if full_cap:
                # full-capacity bucket (count > U_loc/2): compaction saves
                # nothing, so compute every unit in place — static ids, no
                # nonzero, identity scatter.  Bit-identical: computing a
                # clean unit yields exactly its hold value (the sparse
                # exactness contract), and the hold fill downstream still
                # overwrites clean units from the dirty chain.
                ids = jnp.arange(cap)
            else:
                ids = jnp.nonzero(w, size=cap, fill_value=0)[0]
            if keyed:
                k_ids, s_ids = ids // n_segs, ids % n_segs
            else:
                base = (jax.lax.axis_index(axis) * U_loc
                        if mesh is not None else 0)
                k_ids, s_ids = jnp.zeros_like(ids), ids + base
            gath = []
            for name, (bv, bm) in zip(names, flat):
                s = specs[name]
                tidx = s_ids[:, None] * s.core + jnp.arange(s.length)[None, :]
                gath.append((
                    _tm(lambda x: x[k_ids[:, None], tidx], bv),
                    bm[k_ids[:, None], tidx]))

            def one(*f):
                return outs_fn(dict(zip(names, f)))

            outs = jax.vmap(one)(*gath)                  # {o: (cap, S_o, …)}
            if full_cap:
                return outs
            pos = jnp.clip(jnp.cumsum(w) - 1, 0, cap - 1)
            return {o: (_tm(lambda x: jnp.take(x, pos, axis=0), ov),
                        jnp.take(om, pos, axis=0))
                    for o, (ov, om) in outs.items()}     # {o: (U_loc, S_o, …)}

        cache[key] = local
        return cache[key]

    def _hold_local(self):
        """Hold fill (global): clean units take the last tick of the
        nearest preceding dirty segment of the same key, or the key's
        carried hold seed; dirty units keep their computed results."""
        K, n_segs = self._K, self.n_segs

        def hold(full_outs, seg_dirty, seeds):
            ar = jnp.arange(n_segs)
            prev_d = jax.lax.cummax(
                jnp.where(seg_dirty, ar[None, :], -1), axis=1)
            src = jnp.clip(prev_d, 0, n_segs - 1)        # (K, n_segs)
            has = prev_d >= 0
            take_seg = jax.vmap(lambda x, s: jnp.take(x, s, axis=0))
            outs, new_seeds = {}, {}
            for o, (fv, fm) in full_outs.items():        # fv (K, n_segs, S, …)
                sv, sm = seeds[o]

                def hold_leaf(x, seed):
                    hx = take_seg(x[:, :, -1], src)      # (K, n_segs, …)
                    hx = jnp.where(_bc(has, hx), hx,
                                   jnp.expand_dims(seed, 1).astype(x.dtype))
                    return jnp.where(_bc(seg_dirty, x), x,
                                     jnp.expand_dims(hx, 2))

                ov = _tm(hold_leaf, fv, sv)
                hm = jnp.where(has, take_seg(fm[:, :, -1], src), sm[:, None])
                om = jnp.where(seg_dirty[:, :, None], fm, hm[:, :, None])
                ov = _tm(lambda x: x.reshape(
                    (K, n_segs * x.shape[2]) + x.shape[3:]), ov)
                om = om.reshape(K, -1)
                outs[o] = (ov, om)
                new_seeds[o] = (_tm(lambda x: x[:, -1], ov), om[:, -1])
            return outs, new_seeds

        return hold

    def _fused_sparse_step(self, force_first: bool):
        """The whole sparse chunk as one traced step: mask → device-side
        bucket pick → per-shard compacted compute → hold.

        ``step(tails, dirty, prev, seeds, chunks)`` returns ``(outs,
        new_tails, new_dirty, new_prev, new_seeds, seg_dirty)``.  Two
        variants per geometry: ``force_first=True`` (stream start / missing
        hold seed: segment 0 of every key is forced dirty, nothing is
        donated because the zero seeds are cached) and the steady-state
        variant, which donates the carried state pytree — every donated
        argument is an output of the previous step (or a restore-time
        copy), so the tails, dirty tails, snapshots and hold seeds update
        in place.
        """
        key = self._cache_key("sparse_fused", force_first)
        cache = self.spec.step_cache
        if key in cache:
            return cache[key]
        self.metrics.tracer.record_compile(self._compile_label(key))
        names, specs = self._names(), self.spec.input_specs
        cp = self.spec.change_plan
        S, q = self.spec.out_len, self.spec.out_prec
        K, n_segs, U = self._K, self.n_segs, self._U

        # static per-input lineage geometry (the ChangePlan lowered to the
        # affine form the fused kernel consumes) + the segments a carried
        # position-0 change flag dirties (tick 0 is outside the kernel's
        # convention: its diff partner lives before the buffer)
        geom, hits0 = {}, {}
        ks = np.arange(n_segs)
        for name in names:
            s, sp = specs[name], cp.specs[name]
            a0, stp, width = seg_range_affine(
                sp.lookback, sp.lookahead, s.prec,
                grid_t0=-s.left_halo * s.prec, out_t0=0, out_prec=q,
                seg_len=S)
            geom[name] = (a0, stp, width)
            lo = a0 + ks * stp
            hits0[name] = (lo <= 0) & (lo + width > 0)

        ladder = sparse_mod.capacity_ladder(U // self.policy.n_shards)
        branches = [self._compute_local(c) for c in ladder]
        caps = np.asarray(ladder, np.int32)
        hold = self._hold_local()

        def switched(w, *flat):
            cnt = jnp.sum(w.astype(jnp.int32))
            b = jnp.searchsorted(jnp.asarray(caps), cnt, side="left")
            return jax.lax.switch(b, branches, w, *flat)

        sharded = self._shard_body(switched, len(names))

        def tick0_diff(cv, cm, pv, pm):
            d = cm[:, 0] != pm[:, 0]
            for x, p in zip(jax.tree_util.tree_leaves(cv),
                            jax.tree_util.tree_leaves(pv)):
                neq = x[:, 0] != p[:, 0].astype(x.dtype)
                if neq.ndim > 1:
                    neq = neq.reshape(neq.shape[0], -1).any(axis=1)
                d = d | neq
            return d

        def adj_diff(sv, sm):
            nd = sm[:, 1:] != sm[:, :-1]
            for x in jax.tree_util.tree_leaves(sv):
                neq = x[:, 1:] != x[:, :-1]
                if neq.ndim > 2:
                    neq = neq.reshape(neq.shape[:2] + (-1,)).any(axis=2)
                nd = nd | neq
            return nd

        def step(tails, dirty, prev, seeds, chunks):
            bufs, new_tails, new_dirty, new_prev = {}, {}, {}, {}
            seg_dirty = jnp.zeros((K, n_segs), bool)
            for name in names:
                s = specs[name]
                hl = s.left_halo
                tv, tm = tails[name]
                cv, cm = chunks[name]
                fv = _tm(lambda a, b: jnp.concatenate([a, b], axis=1), tv, cv)
                fm = jnp.concatenate([tm, cm], axis=1)
                bufs[name] = (fv, fm)
                g = geom[name]

                def one_key(v, m, g=g):
                    mats = sparse_compact.grid_mats(v, m)
                    return sparse_compact.seg_dirty(
                        mats, [g] * len(mats), n_segs)

                sd = jax.vmap(one_key)(fv, fm)           # (K, n_segs)
                # buffer position 0: carried change flag (its diff partner
                # is one tick before the buffer); with no tail the carried
                # 1-tick snapshot supplies the partner
                d0 = (dirty[name][:, 0] if hl
                      else tick0_diff(cv, cm, *prev[name]))
                seg_dirty = (seg_dirty | sd
                             | (d0[:, None] & jnp.asarray(hits0[name])))
                lo = s.core * n_segs
                new_tails[name] = (
                    _tm(lambda x: jax.lax.slice_in_dim(
                        x, lo, lo + hl, axis=1), fv),
                    jax.lax.slice_in_dim(fm, lo, lo + hl, axis=1))
                if hl:
                    # carried dirty tail = adjacent diffs of the buffer's
                    # last hl+1 ticks (identical to the flags a full-length
                    # mask would carry: every tail position has its diff
                    # partner in the buffer, since lo >= 1)
                    new_dirty[name] = adj_diff(
                        _tm(lambda x: jax.lax.slice_in_dim(
                            x, lo - 1, lo + hl, axis=1), fv),
                        jax.lax.slice_in_dim(fm, lo - 1, lo + hl, axis=1))
                else:
                    new_dirty[name] = dirty[name]
                if not hl:
                    # snapshot carried (and donated in-place) only where it
                    # will be read: halo-free inputs' next tick-0 diff
                    new_prev[name] = (_tm(lambda x: x[:, -1:], cv),
                                      cm[:, -1:])
            if not names:
                seg_dirty = jnp.ones((K, n_segs), bool)  # input-free: dense
            if force_first:
                seg_dirty = seg_dirty.at[:, 0].set(True)
            full = sharded(seg_dirty.reshape(U),
                           *[bufs[nm] for nm in names])
            full = {o: (_tm(lambda x: x.reshape(
                            (K, n_segs) + x.shape[1:]), fv),
                        fm.reshape((K, n_segs) + fm.shape[1:]))
                    for o, (fv, fm) in full.items()}
            outs, new_seeds = hold(full, seg_dirty, seeds)
            return outs, new_tails, new_dirty, new_prev, new_seeds, seg_dirty

        return self._stage(key, step,
                           donate=() if force_first else (0, 1, 2, 3))

    def _zero_seeds(self, chunk_in):
        """φ hold seeds shaped like one output tick per key (unread: any
        output missing a carried seed forces its first segment dirty)."""
        if getattr(self, "_zero_seed_cache", None) is not None:
            return self._zero_seed_cache
        avals = {}
        for name in self._names():
            s = self.spec.input_specs[name]
            cv, cm = chunk_in[name]
            avals[name] = (
                _tm(lambda x: jax.ShapeDtypeStruct(
                    (s.length,) + x.shape[2:], x.dtype), cv),
                jax.ShapeDtypeStruct((s.length,), jnp.bool_))
        shapes = jax.eval_shape(self.spec.outs_fn, avals)
        K = self._K
        self._zero_seed_cache = {
            o: (_tm(lambda a: jnp.zeros((K,) + a.shape[1:], a.dtype), ov),
                jnp.zeros((K,), bool))
            for o, (ov, om) in shapes.items()}
        return self._zero_seed_cache

    def _sparse_chunk(self, chunk_in):
        st = self._sparse
        missing_seed = any(o not in st["seed"] for o in self.spec.out_precs)
        force_first = (not st["started"]) or missing_seed
        if force_first:
            seeds = dict(self._zero_seeds(chunk_in))
            seeds.update(st["seed"])
        else:
            seeds = st["seed"]
        outs, new_tails, new_dirty, new_prev, new_seeds, seg_dirty = \
            self._fused_sparse_step(force_first)(
                self._tails, st["dirty"], st["prev"], seeds, chunk_in)
        # device-resident diagnostics: no transfer, no dispatch stall
        self.last_seg_dirty = seg_dirty
        if self.metrics.on:
            self._obs_sparse_chunk(seg_dirty)
            if not force_first:
                self._m_donated.add(1)
        else:
            cnt = seg_dirty.sum(dtype=jnp.int32)
            self._dirty_units = (cnt if self._dirty_units is None
                                 else self._dirty_units + cnt)
        self._total_units += self._U
        self._chunks_run += 1

        def commit():
            self._tails = new_tails
            st["dirty"], st["prev"] = new_dirty, new_prev
            st["seed"], st["started"] = new_seeds, True

        return outs, commit

    def _postprocess(self, outs):
        """The eager per-chunk result assembly between the staged step and
        the returned grids: drop the internal K axis for single-key
        runners.  reshape, not x[0]: eager indexing binds a dynamic_slice
        whose start-index scalars are host→device transfers on every
        chunk — reshape is metadata-only.  This is the only eager array
        code on the chunk path, and the transfer-freedom pass
        (repro.analysis) lints exactly that: any non-metadata eqn outside
        the staged step in the whole-chunk jaxpr is a finding."""
        if self.policy.keyed:
            return outs
        return {o: (_tm(lambda x: x.reshape(x.shape[1:]), v),
                    m.reshape(m.shape[1:]))
                for o, (v, m) in outs.items()}

    # -- static audit surface (repro.analysis) -------------------------------
    def audit_example_chunks(self) -> Dict[str, SnapshotGrid]:
        """Zero-filled example chunks in the external :meth:`step` layout,
        sized to this runner's geometry — concrete arguments for tracing
        the chunk path without data."""
        chunks = {}
        for name in self._names():
            s = self.spec.input_specs[name]
            shape = ((self.n_keys, s.core * self.n_segs) if self.policy.keyed
                     else (s.core * self.n_segs,))
            chunks[name] = SnapshotGrid(
                value=jnp.zeros(shape, jnp.float32),
                valid=jnp.zeros(shape, bool), t0=0, prec=s.prec)
        return chunks

    def _audit_state(self, chunk_in):
        """Fresh-stream carried state (tails / dirty / prev / seeds) for
        audit tracing, built without touching the live stream state."""
        saved = self._tails, self._sparse
        self._tails = {}
        if self.policy.sparse:
            self._sparse = {"dirty": {}, "prev": {}, "seed": {},
                            "started": False}
        try:
            self._init_missing_tails(chunk_in)
            tails, sparse = self._tails, self._sparse
        finally:
            self._tails, self._sparse = saved
        seeds = self._zero_seeds(chunk_in) if self.policy.sparse else None
        return tails, sparse, seeds

    def staged_steps(self, chunks: Optional[Dict] = None):
        """The staged (jitted) steps one chunk dispatches, with concrete
        example arguments — the lowerable audit surface
        ``repro.analysis`` traces under ``jax.make_jaxpr``.

        Returns a list of dicts ``{label, key, fn, raw, donate, args}``:
        ``fn`` is the cached jitted step, ``raw`` the untraced function it
        was staged from, ``donate`` its ``donate_argnums`` contract and
        ``args`` a concrete argument tuple matching the real chunk-path
        call.  Building these populates the shared step cache exactly like
        a real first chunk would (cache hits thereafter — no extra
        compiles are recorded)."""
        chunks = chunks if chunks is not None else self.audit_example_chunks()
        chunk_in = self._ingest(chunks)
        tails, sparse, seeds = self._audit_state(chunk_in)
        cache = self.spec.step_cache

        def entry(label, key, fn, args):
            raw, donate = cache.get(("raw",) + key, (None, ()))
            return {"label": label, "key": key, "fn": fn, "raw": raw,
                    "donate": donate, "args": args}

        steps = []
        if self.policy.sparse:
            for force_first in (True, False):
                fn = self._fused_sparse_step(force_first)
                key = self._cache_key("sparse_fused", force_first)
                label = ("sparse_fused(first)" if force_first
                         else "sparse_fused(steady)")
                steps.append(entry(label, key, fn,
                                   (tails, sparse["dirty"], sparse["prev"],
                                    seeds, chunk_in)))
            if self.metrics.on:
                fn = self._obs_accum()
                key = self._cache_key("obs_accum")
                mstate = (jnp.zeros((), jnp.int32),
                          jnp.zeros((len(self._obs_caps),), jnp.int32),
                          jnp.zeros((len(self._obs_frac_edges) + 1,),
                                    jnp.int32))
                steps.append(entry(
                    "obs_accum", key, fn,
                    (mstate, jnp.zeros((self._K, self.n_segs), bool))))
        else:
            fn = self._dense_step()
            key = self._cache_key("dense")
            steps.append(entry("dense", key, fn, (tails, chunk_in)))
        if self._rev_ring is not None:
            fn = self._revision_step()
            key = self._cache_key("revise")
            steps.append(entry("revise", key, fn,
                               (tails, chunk_in,
                                jnp.zeros((self._K, self.n_segs), bool))))
        return steps

    def chunk_fn(self, variant: str = "steady", chunks: Optional[Dict] = None):
        """A pure whole-chunk function plus concrete example args: the
        staged step dispatch *and* the eager post-step result assembly,
        exactly as :meth:`step` composes them.  Tracing this under
        ``jax.make_jaxpr`` shows every op a chunk binds outside the staged
        step — the transfer-freedom pass's audit surface.

        ``variant``: ``"steady"`` / ``"first"`` (sparse bodies) or
        ``"dense"``.
        """
        chunks = chunks if chunks is not None else self.audit_example_chunks()
        chunk_in = self._ingest(chunks)
        tails, sparse, seeds = self._audit_state(chunk_in)
        if self.policy.sparse:
            if variant not in ("steady", "first"):
                raise ValueError(
                    f"sparse body has chunk variants 'steady'/'first', "
                    f"not {variant!r}")
            staged = self._fused_sparse_step(variant == "first")

            def fn(tails, dirty, prev, seeds, chunk_in):
                outs, *new_state = staged(tails, dirty, prev, seeds, chunk_in)
                return self._postprocess(outs), tuple(new_state)

            args = (tails, sparse["dirty"], sparse["prev"], seeds, chunk_in)
        else:
            if variant not in ("steady", "dense"):
                raise ValueError(
                    f"dense body has chunk variant 'dense', not {variant!r}")
            staged = self._dense_step()

            def fn(tails, chunk_in):
                outs, new_tails = staged(tails, chunk_in)
                return self._postprocess(outs), new_tails

            args = (tails, chunk_in)
        return fn, args

    # -- AOT serving surface (repro.serve) -----------------------------------
    def aot_keys(self) -> List[tuple]:
        """``(label, staging-cache key)`` of every staged step one serving
        process dispatches at this policy point — the AOT compilation
        surface :func:`repro.serve.aot.aot_compile` covers.  Enumerable
        without staging anything, so a warm start can probe the persisted
        executable cache before any getter records a compile."""
        keys = []
        if self.policy.sparse:
            keys.append(("sparse_fused(first)",
                         self._cache_key("sparse_fused", True)))
            keys.append(("sparse_fused(steady)",
                         self._cache_key("sparse_fused", False)))
            if self.metrics.on:
                keys.append(("obs_accum", self._cache_key("obs_accum")))
        else:
            keys.append(("dense", self._cache_key("dense")))
        if self._rev_ring is not None:
            keys.append(("revise", self._cache_key("revise")))
        return keys

    def install_executable(self, key, fn, *, label: str = "",
                           how: str = "loaded", donate=()) -> None:
        """Executable-serialization hook: put an AOT executable (a
        ``jax.stages.Compiled`` / deserialized ``Loaded``) into the step
        cache under its staging key.  Installing *before* the step getters
        run makes them cache hits, so a warm start records zero compiles
        (the tracer-verified warm-start proof) and never traces the body.
        The donation contract is baked into the executable at lowering
        time; ``donate`` just records it for the serving analysis pass."""
        if not self.spec.jit:
            raise ValueError(
                "AOT executables need a jitted body (spec.jit=True)")
        self.spec.step_cache[key] = fn
        self.aot_record[key] = {"label": label or key[0], "how": how,
                                "donate": tuple(donate)}
        self.metrics.tracer.record_aot(self._compile_label(key), how)

    def seed_shape_spec(self):
        """``jax.ShapeDtypeStruct`` tree of the φ hold seeds (sparse
        bodies; ``None`` for dense) — pickles, so a persisted plan
        artifact lets a fresh process :meth:`prime_seed_shapes` and skip
        the one remaining trace on the warm path (``jax.eval_shape`` of
        ``outs_fn`` in :meth:`_zero_seeds`)."""
        if not self.policy.sparse:
            return None
        seeds = self._zero_seeds(self._ingest(self.audit_example_chunks()))
        return {o: (_tm(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        ov),
                    jax.ShapeDtypeStruct(om.shape, om.dtype))
                for o, (ov, om) in seeds.items()}

    def prime_seed_shapes(self, shapes) -> None:
        """Install persisted seed shapes (:meth:`seed_shape_spec` of a
        previous process) so the first sparse chunk skips the
        ``eval_shape`` trace of ``outs_fn`` — with AOT-installed steps
        this makes first-result completely trace-free."""
        if shapes is None or not self.policy.sparse:
            return
        self._zero_seed_cache = {
            o: (_tm(lambda a: jnp.zeros(a.shape, a.dtype), ov),
                jnp.zeros(om.shape, om.dtype))
            for o, (ov, om) in shapes.items()}

    # -- public API ----------------------------------------------------------
    def step(self, chunks: Dict[str, SnapshotGrid]):
        """Advance the stream by one chunk (``segs_per_chunk`` segments).

        Each chunk grid supplies ``segs_per_chunk · spec.core`` fresh ticks
        per input (leading key axis first when ``keys='vmapped'``).  Returns
        one output grid (solo) or ``{query_name: grid}`` (union).  Carried
        state commits only after the step succeeded, so a raise leaves the
        runner exactly as it was.
        """
        t0 = time.perf_counter()
        snap = None
        if self._rev_ring is not None:
            # pre-chunk state snapshot for the revision ring: captured
            # before dispatch (the donating step consumes the tails), as a
            # host pytree — one device sync per chunk, the documented cost
            # of revisability (docs/architecture.md "Out-of-order
            # ingestion"); hot paths that never see late data leave the
            # ring disabled and keep the zero-sync steady state
            snap = {"chunk": self._t // (self.n_segs * self.spec.span),
                    "state": self.state()}
        chunk_in = self._ingest(chunks)
        self._init_missing_tails(chunk_in)
        if self.policy.sparse:
            outs, commit = self._sparse_chunk(chunk_in)
        else:
            outs, new_tails = self._dense_step()(self._tails, chunk_in)
            if self.metrics.on and self.spec.jit:
                self._m_donated.add(1)

            def commit(new_tails=new_tails):
                self._tails = new_tails

        result = {}
        for o, (v, m) in self._postprocess(outs).items():
            result[o] = SnapshotGrid(value=v, valid=m, t0=self._t,
                                     prec=self.spec.out_precs[o])
        commit()
        if snap is not None:
            self._rev_ring.append(snap)
        self._t += self.n_segs * self.spec.span
        if self.metrics.on:
            # host-side arithmetic only (perf_counter + numpy bisect):
            # wall time around the async dispatch, never a device read
            self._m_chunks.add(1)
            self._m_units.add(self._U)
            self._m_lat.observe(time.perf_counter() - t0)
        return result["__out"] if self.spec.solo else result

    def run(self, inputs: Dict[str, SnapshotGrid], n_chunks: int):
        """Slice ``n_chunks`` chunks from full streams, step through them
        and stitch the outputs along time."""
        taxis = 1 if self.policy.keyed else 0
        outs = []
        for c in range(n_chunks):
            chunk = {}
            for name in self._names():
                s = self.spec.input_specs[name]
                g = inputs[name]
                lo = c * s.core * self.n_segs
                chunk[name] = SnapshotGrid(
                    value=_tm(lambda x: jax.lax.slice_in_dim(
                        x, lo, lo + s.core * self.n_segs, axis=taxis),
                        g.value),
                    valid=jax.lax.slice_in_dim(
                        g.valid, lo, lo + s.core * self.n_segs, axis=taxis),
                    t0=g.t0 + lo * s.prec, prec=s.prec)
            outs.append(self.step(chunk))

        def stitch(parts):
            value = _tm(lambda *xs: jnp.concatenate(xs, axis=taxis),
                        *[p.value for p in parts])
            valid = jnp.concatenate([p.valid for p in parts], axis=taxis)
            return SnapshotGrid(value=value, valid=valid, t0=parts[0].t0,
                                prec=parts[0].prec)

        if self.spec.solo:
            return stitch(outs)
        return {o: stitch([c[o] for c in outs]) for o in outs[0]}

    def reset(self) -> None:
        """Drop carried state; the next step starts a fresh stream at t=0."""
        self._tails = {}
        if self._sparse is not None:
            self._sparse = {"dirty": {}, "prev": {}, "seed": {},
                            "started": False}
        self._t = 0
        self.last_seg_dirty = None
        self._dirty_units = None
        self._total_units = 0
        self._chunks_run = 0
        if self._rev_ring is not None:
            self._rev_ring.clear()
        if self._mstate is not None:
            # preserve the registry's running totals (syncs — off-path),
            # then drop this runner's device accumulator state
            self._m_dirty.fold_device()
            if self._m_picks is not None:
                self._m_picks.fold_device()
            self._m_frac.fold_device()
            self._mstate = None

    def dirty_stats(self) -> Optional[Dict]:
        """Measured compaction of the sparse body since construction/reset:
        ``{chunks, units, dirty_units, compact}`` where ``compact`` is the
        fraction of (key × segment) work units that actually computed
        (forced-dirty first segments included).  ``None`` for dense bodies
        or before the first chunk.

        Compat wrapper over the runner-local view of the metrics
        registry's device accumulator (``runner.dirty_units`` et al. —
        prefer ``runner.metrics.snapshot()``, which carries the same
        numbers plus bucket picks, dirty-fraction and latency
        histograms).  Reading syncs the device-resident counter — a
        diagnostic call, not part of the steady-state path
        (``last_seg_dirty`` holds the raw per-unit flags of the newest
        chunk, also device-resident)."""
        if self._sparse is None or self._total_units == 0:
            return None
        dirty = int(self._dirty_units)
        return {"chunks": self._chunks_run, "units": self._total_units,
                "dirty_units": dirty,
                "compact": dirty / self._total_units}

    # -- checkpointing (the one state/validate path) -------------------------
    def _strip(self, tree):
        """Drop the internal K axis for single-key runners (host layout)."""
        if self.policy.keyed:
            return tree
        return _tm(lambda x: x[0], tree)

    def _lift(self, tree):
        if self.policy.keyed:
            return tree
        return _tm(lambda x: jnp.asarray(x)[None], tree)

    def state(self) -> Dict:
        """Checkpointable runner state (host arrays); see the module
        docstring for the pytree layout."""
        to_np = lambda t: _tm(np.asarray, t)  # noqa: E731
        out = {k: to_np(self._strip(v)) for k, v in self._tails.items()}
        out["__t"] = self._t
        if self._sparse is not None:
            st = self._sparse
            out["__sparse"] = {
                "dirty": {k: np.asarray(self._strip(v))
                          for k, v in st["dirty"].items()},
                "prev": {k: to_np(self._strip(v))
                         for k, v in st["prev"].items()},
                "seed": {o: to_np(self._strip(v))
                         for o, v in st["seed"].items()},
                "started": st["started"]}
        return out

    def restore(self, state: Dict, *, strict: bool = True) -> None:
        """Restore a :meth:`state` checkpoint, validating it against this
        runner's configuration first.

        Every inconsistency — wrong input names, wrong key count, wrong
        tail length (a checkpoint from a different query/plan), a stream
        clock misaligned with the partition span, missing or unexpected
        sparse change state — raises a ``ValueError`` naming the mismatch,
        instead of surfacing later as an opaque shape error inside the
        jitted step.  ``strict=False`` additionally tolerates inputs absent
        from the checkpoint (their tails re-initialize to φ) — the
        session's attach/detach re-fit path.
        """
        state = dict(state)
        if "__t" not in state:
            raise ValueError("checkpoint has no '__t' stream clock")
        t = state.pop("__t")
        span = self.spec.span
        if not isinstance(t, (int, np.integer)) or t < 0 or t % span:
            raise ValueError(
                f"checkpoint stream clock __t={t!r} is not a non-negative "
                f"multiple of the partition span {span} — was this saved "
                "from an engine with a different out_len/out_prec?")
        sparse_state = state.pop("__sparse", None)
        if self.policy.sparse and sparse_state is None:
            raise ValueError(
                "sparse engine cannot restore a dense checkpoint: no "
                "'__sparse' change state (dirty tails / snapshots / seed)")
        if not self.policy.sparse and sparse_state is not None:
            raise ValueError(
                "dense engine cannot restore a sparse checkpoint "
                "(carries '__sparse' change state)")
        specs = self.spec.input_specs
        names = set(specs)
        unknown = sorted(set(state) - names)
        missing = sorted(n for n in names - set(state)
                         if specs[n].left_halo > 0) if strict else []
        if state and (unknown or missing):
            raise ValueError(
                f"checkpoint inputs {sorted(state)} != query inputs "
                f"{sorted(names)} (unknown={unknown}, missing={missing})")
        K = self._K
        lead = ((K,) if self.policy.keyed else ())

        def check_lead(name, got, what):
            want = lead + (specs[name].left_halo,)
            label = ("(n_keys, left_halo)" if self.policy.keyed
                     else "(left_halo,)")
            if tuple(got) != want:
                raise ValueError(
                    f"input {name}: checkpoint {what} shape {tuple(got)} != "
                    f"{label} = {want}")

        for name, (tv, tm) in state.items():
            check_lead(name, np.shape(tm), "tail")
            for leaf in jax.tree_util.tree_leaves(tv):
                want = lead + (specs[name].left_halo,)
                if tuple(np.shape(leaf)[:len(lead) + 1]) != want:
                    label = ("(n_keys, left_halo)" if self.policy.keyed
                             else "(left_halo,)")
                    raise ValueError(
                        f"input {name}: checkpoint tail value leaf shape "
                        f"{tuple(np.shape(leaf))} does not lead with "
                        f"{label} = {want}")
        if sparse_state is not None:
            for name in state:
                got = np.shape(sparse_state["dirty"].get(name, ()))
                check_lead(name, got, "dirty-tail")
            if strict:
                # halo-free inputs carry their whole change lineage in the
                # 1-tick snapshot; restoring one without it would silently
                # treat an unchanged tick 0 as clean against φ
                no_prev = sorted(
                    n for n in state if specs[n].left_halo == 0
                    and n not in (sparse_state.get("prev") or {}))
                if no_prev:
                    raise ValueError(
                        f"checkpoint is missing the 1-tick 'prev' snapshot "
                        f"for halo-free inputs {no_prev}")

        self._t = int(t)
        # jnp.array (copy), not asarray: restored state feeds the donating
        # steady-state step, which must never consume the caller's buffers.
        self._tails = {k: self._place(self._lift(_tm(jnp.array, v)))
                       for k, v in state.items()}
        if self._sparse is not None:
            st = {"dirty": {}, "prev": {}, "seed": {}, "started": True}
            if sparse_state is not None:
                st["dirty"] = {
                    k: self._place(self._lift(jnp.array(v)))
                    for k, v in sparse_state["dirty"].items()
                    if k in names}
                # older checkpoints carried (dead) snapshots for
                # halo-carrying inputs too — drop them on the way in
                st["prev"] = {
                    k: self._place(self._lift(_tm(jnp.array, v)))
                    for k, v in sparse_state["prev"].items()
                    if k in names and specs[k].left_halo == 0}
                seed = sparse_state.get("seed") or {}
                if not isinstance(seed, dict):
                    # pre-policy-runner checkpoints (old KeyedEngine format)
                    # stored the solo hold seed as a bare (value, valid)
                    # tuple rather than a per-output dict
                    if not self.spec.solo:
                        raise ValueError(
                            "checkpoint hold seed is a bare tuple (single-"
                            "output format) but this runner serves a union "
                            "DAG with outputs "
                            f"{sorted(self.spec.out_precs)}")
                    seed = {"__out": seed}
                st["seed"] = {o: self._lift(_tm(jnp.array, v))
                              for o, v in seed.items()
                              if o in self.spec.out_precs}
                st["started"] = bool(sparse_state.get("started", True))
            # φ-init any halo-free snapshot the checkpoint didn't carry
            # (strict mode rejected this above): the next chunk's tick 0
            # then diffs against φ, the stream-start rule
            for name, (tv, tm) in self._tails.items():
                if specs[name].left_halo == 0 and name not in st["prev"]:
                    st["prev"][name] = (
                        _tm(lambda x: jnp.zeros((x.shape[0], 1)
                                                + x.shape[2:], x.dtype), tv),
                        jnp.zeros((tm.shape[0], 1), bool))
            self._sparse = st

    # -- late-data revision processing ---------------------------------------
    def enable_revision(self, horizon_chunks: int,
                        revise_bound: Optional[int] = None) -> None:
        """Keep a ring of the last ``horizon_chunks`` pre-chunk state
        snapshots (the :meth:`state` pytree), so sealed chunks inside the
        horizon can be revised through :meth:`revise` when late data
        patches their inputs.  ``revise_bound`` declares the maximum
        lateness (time units behind the newest stepped chunk) the ring is
        meant to cover; the ``revision`` analysis pass
        (:func:`repro.analysis.passes.pass_revision`) checks it against
        :meth:`repro.core.plan.ChangePlan.revision_horizon_chunks`.

        Enabling the ring trades the zero-sync steady state for
        revisability: every :meth:`step` round-trips the carried state to
        host once.  Hot paths that never see late data should leave this
        off (the 16-point policy lattice does, so the static passes and
        perf tests are unaffected)."""
        if horizon_chunks < 1:
            raise ValueError("horizon_chunks must be >= 1")
        self._rev_ring = collections.deque(maxlen=int(horizon_chunks))
        self.revision_horizon = int(horizon_chunks)
        self.revise_bound = (None if revise_bound is None
                             else int(revise_bound))

    def _revision_step(self):
        """The staged late-data revision step: ``step(tails, chunks,
        seg_dirty) -> (outs, new_tails)``.

        Like the fused sparse step, the compute is the per-shard compacted
        ``capacity_ladder`` switch (:meth:`_compute_local`) — never a
        dense chunk replay — but the dirty mask arrives as an argument
        (host-derived from :func:`repro.core.sparse.retro_segment_mask`
        over the patched tick times) instead of being diffed on device,
        and there is no hold fill: ChangePlan dilation proves every
        output outside the dirty segments unchanged, so only dirty
        segments' output ticks are read back (clean segments carry
        scatter residue)."""
        key = self._cache_key("revise")
        cache = self.spec.step_cache
        if key in cache:
            return cache[key]
        self.metrics.tracer.record_compile(self._compile_label(key))
        names, specs = self._names(), self.spec.input_specs
        K, n_segs, U = self._K, self.n_segs, self._U
        ladder = sparse_mod.capacity_ladder(U // self.policy.n_shards)
        branches = [self._compute_local(c) for c in ladder]
        caps = np.asarray(ladder, np.int32)

        def switched(w, *flat):
            cnt = jnp.sum(w.astype(jnp.int32))
            b = jnp.searchsorted(jnp.asarray(caps), cnt, side="left")
            return jax.lax.switch(b, branches, w, *flat)

        sharded = self._shard_body(switched, len(names))

        def step(tails, chunks, seg_dirty):
            bufs, new_tails = {}, {}
            for name in names:
                s = specs[name]
                tv, tm = tails[name]
                cv, cm = chunks[name]
                fv = _tm(lambda a, b: jnp.concatenate([a, b], axis=1), tv, cv)
                fm = jnp.concatenate([tm, cm], axis=1)
                bufs[name] = (fv, fm)
                lo = s.core * n_segs
                new_tails[name] = (
                    _tm(lambda x: jax.lax.slice_in_dim(
                        x, lo, lo + s.left_halo, axis=1), fv),
                    jax.lax.slice_in_dim(fm, lo, lo + s.left_halo, axis=1))
            full = sharded(seg_dirty.reshape(U), *[bufs[nm] for nm in names])
            outs = {o: (_tm(lambda x: x.reshape(
                            (K, n_segs * x.shape[1]) + x.shape[2:]), fv),
                        fm.reshape(K, -1))
                    for o, (fv, fm) in full.items()}
            return outs, new_tails

        # the walked-forward tails are revision-owned (ring-entry copies,
        # then step outputs) — donate them like the chunk steps do
        return self._stage(key, step, donate=(0,))

    def revise(self, from_chunk: int, chunks, seg_dirty, *,
               commit: bool = True):
        """Re-run sealed chunks ``from_chunk .. from_chunk+len(chunks)-1``
        on patched inputs, computing only the flagged segments.

        ``chunks`` is one ``{input: SnapshotGrid}`` dict per revised chunk
        (the patched sealed grids, full chunk layout exactly as for
        :meth:`step`); ``seg_dirty`` one host bool mask per chunk, shaped
        ``(n_segs,)`` (single) or ``(n_keys, n_segs)`` (vmapped) —
        derived from :func:`repro.core.sparse.retro_segment_mask` over the
        patched tick times.  Returns one output result per chunk in
        :meth:`step`'s layout; only ticks inside dirty segments are
        meaningful (callers emit corrections for those segments only —
        see :class:`repro.ingest.IngestRunner`).

        With ``commit=True`` (required to keep live state consistent) the
        revision must extend through the newest stepped chunk; the
        walked-forward tails then replace the live carried tails, the
        change state goes conservative (all-dirty tails — a superset of
        true dirtiness, still bit-exact by the sparse exactness
        contract), and ring entries passed en route are refreshed with
        the patched tails so later revisions restore patched history.
        ``commit=False`` is a read-only what-if replay."""
        if self._rev_ring is None:
            raise ValueError(
                "revision disabled — call enable_revision() first")
        if len(chunks) != len(seg_dirty):
            raise ValueError("one seg_dirty mask per revised chunk required")
        span = self.n_segs * self.spec.span
        cur = self._t // span
        if commit and from_chunk + len(chunks) != cur:
            raise ValueError(
                f"commit=True revisions must extend through the newest "
                f"stepped chunk {cur - 1} (got chunks {from_chunk}.."
                f"{from_chunk + len(chunks) - 1})")
        entry = next((e for e in self._rev_ring
                      if e["chunk"] == from_chunk), None)
        if entry is None:
            have = sorted(e["chunk"] for e in self._rev_ring)
            raise ValueError(
                f"no state snapshot for chunk {from_chunk} in the revision "
                f"ring (have {have}) — the patch is beyond the horizon")
        st, specs, K = entry["state"], self.spec.input_specs, self._K

        step = self._revision_step()
        tails = None
        results = []
        n_units = 0
        last_in = last_sd = last_outs = None
        for i, (ch, sd) in enumerate(zip(chunks, seg_dirty)):
            chunk_in = self._ingest(ch)
            if tails is None:
                tails = {}
                for name in self._names():
                    if name in st:
                        # jnp.array (copy): the ring entry stays intact and
                        # the donating revision step never consumes it
                        tails[name] = self._place(
                            self._lift(_tm(jnp.array, st[name])))
                    else:  # pre-stream snapshot: φ tails (the restore rule)
                        hl = specs[name].left_halo
                        cv, cm = chunk_in[name]
                        tails[name] = self._place((
                            _tm(lambda x: jnp.zeros(
                                (K, hl) + x.shape[2:], x.dtype), cv),
                            jnp.zeros((K, hl), bool)))
            else:
                # the ring entry for this chunk captured pre-patch tails —
                # refresh it with the walked (patched) ones so a later
                # revision restoring from here sees patched history
                for e in self._rev_ring:
                    if e["chunk"] == from_chunk + i:
                        for name in self._names():
                            e["state"][name] = _tm(
                                np.asarray, self._strip(tails[name]))
            sd = np.asarray(sd, bool).reshape(K, self.n_segs)
            n_units += int(sd.sum())
            outs, tails = step(tails, chunk_in, jnp.asarray(sd))
            last_in, last_sd, last_outs = chunk_in, sd, outs
            res = {}
            for o, (v, m) in self._postprocess(outs).items():
                res[o] = SnapshotGrid(value=v, valid=m,
                                      t0=(from_chunk + i) * span,
                                      prec=self.spec.out_precs[o])
            results.append(res["__out"] if self.spec.solo else res)

        if commit and chunks:
            self._tails = tails
            if self._sparse is not None:
                stt = self._sparse
                ld = jnp.asarray(last_sd[:, -1])
                for name in self._names():
                    hl = specs[name].left_halo
                    if hl:
                        # conservative: the patched tail is marked fully
                        # dirty — dirtiness only ever widens, and extra
                        # computed segments are bit-identical by the
                        # sparse exactness contract
                        stt["dirty"][name] = self._place(
                            jnp.ones((K, hl), bool))
                    else:
                        cv, cm = last_in[name]
                        stt["prev"][name] = (_tm(lambda x: x[:, -1:], cv),
                                             cm[:, -1:])
                for o, (sv, sm) in list(stt["seed"].items()):
                    ov, om = last_outs[o]
                    stt["seed"][o] = (
                        _tm(lambda x, s: jnp.where(_bc(ld, x[:, -1]),
                                                   x[:, -1], s), ov, sv),
                        jnp.where(ld, om[:, -1], sm))
        if self.metrics.on:
            self._m_rev_runs.add(1)
            self._m_rev_chunks.add(len(chunks))
            self._m_rev_units.add(n_units)
        return results
