"""Low-latency serving on the policy runner (ROADMAP open item 2).

Three pieces, composable but separable:

* :mod:`repro.serve.aot` — AOT compilation of every staged step through
  the runner's ``staged_steps()`` surface, with a persisted executable
  cache (``jax.experimental.serialize_executable``) so a fresh process
  reaches first-result without tracing or compiling.
* :mod:`repro.serve.ring` — fixed-capacity FIFO admission ring with
  explicit shed policies and ``serve.*`` telemetry.
* :mod:`repro.serve.loop` — :class:`ServeLoop` (double-buffered async
  chunk path + ring-fed event path over
  :class:`repro.ingest.IngestRunner`) and :func:`build_service`, the
  one-call constructor wiring the persisted plan + executable caches.

``python -m repro.serve --smoke`` runs a small end-to-end serving loop
and gates it with the ``serving`` analysis pass (the ``make lint-plans``
hook).
"""
from .aot import (ExecutableCache, aot_compile, enable_jax_compilation_cache,
                  step_fingerprint)
from .loop import (ServeLoop, body_spec_from_artifact, build_service,
                   plan_artifact_of)
from .ring import AdmissionRing, Backpressure, RingEntry

__all__ = ["AdmissionRing", "Backpressure", "ExecutableCache", "RingEntry",
           "ServeLoop", "aot_compile", "body_spec_from_artifact",
           "build_service", "enable_jax_compilation_cache",
           "plan_artifact_of", "step_fingerprint"]
