"""AOT compilation + persisted executable cache for the serving loop.

A served :class:`repro.engine.Runner` dispatches a small, fully
enumerable set of staged steps (``Runner.aot_keys``).  This module lowers
each through the runner's existing audit surface —
``jax.jit(step).lower(*example_args).compile()`` over the concrete
arguments ``staged_steps()`` already builds — and installs the resulting
executables back into the shared step cache
(:meth:`~repro.engine.runner.Runner.install_executable`), so the first
real chunk is a cache hit: no tracing, no compile, no retrace recorded.

Persistence uses ``jax.experimental.serialize_executable``: each compiled
step serializes to ``(payload, in_tree, out_tree)`` (all picklable) keyed
by a structural fingerprint over everything the executable depends on —
query IR fingerprints, geometry, policy point, metrics mode, backend and
jax version.  A fresh process with a warm :class:`ExecutableCache` (plus
a persisted plan artifact for the seed shapes — see
:mod:`repro.multiquery.shared`) reaches first-result without tracing,
planning or compiling anything.

The complementary :func:`enable_jax_compilation_cache` turns on jax's own
persistent compilation cache (HLO-hash keyed): it does not skip tracing,
but makes genuinely cold starts cheaper too.  Both are best-effort — a
backend that cannot cache degrades to plain compilation.
"""
from __future__ import annotations

import hashlib
import os
import pickle
import tempfile
from typing import Dict, Optional

import jax

from ..core import ir

__all__ = ["ExecutableCache", "aot_compile", "enable_jax_compilation_cache",
           "step_fingerprint"]


def enable_jax_compilation_cache(path: str = "out/jax_cache") -> bool:
    """Best-effort enable of jax's persistent compilation cache at
    ``path`` (min-size/min-time thresholds dropped so CPU-scale entries
    qualify).  Returns whether the config took."""
    try:
        os.makedirs(path, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", os.path.abspath(path))
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
        return True
    except Exception:
        return False


def _backend_tag() -> tuple:
    devs = jax.devices()
    return (jax.__version__, devs[0].platform, len(devs),
            devs[0].device_kind)


def step_fingerprint(runner, label: str, *,
                     query_fp: Optional[str] = None) -> str:
    """Process-stable content key of one staged step's executable: the
    query structure, the execution geometry (the staging-key DOFs with the
    mesh reduced to its shape), the metrics mode and the backend.  Two
    processes that would compile byte-equivalent steps agree on it; any
    drift (new jax, different device count, changed geometry) misses."""
    spec = runner.spec
    if query_fp is None:
        if spec.roots:
            query_fp = "|".join(ir.fingerprint(r) for r in spec.roots)
        else:
            # opaque body: fall back to the planning artifacts (pure-data
            # dataclass reprs are deterministic)
            query_fp = repr((sorted(spec.input_specs.items()),
                             spec.change_plan))
    p = runner.policy
    payload = repr((query_fp, label, spec.out_len, spec.out_prec,
                    sorted(spec.out_precs.items()), spec.solo,
                    p.body, p.keys, p.dag,
                    p.axis if p.mesh is not None else None, p.n_shards,
                    runner.n_keys, runner.n_segs, runner.metrics.on,
                    runner.revision_horizon, _backend_tag()))
    return hashlib.sha256(payload.encode()).hexdigest()


class ExecutableCache:
    """Directory of serialized step executables, one pickle per
    fingerprint: ``(payload, in_tree, out_tree, meta)`` as produced by
    ``jax.experimental.serialize_executable.serialize`` plus the step's
    donation contract.  Writes are atomic (tempfile + rename) so
    concurrent servers warming the same cache never read a torn entry."""

    def __init__(self, path: str):
        self.path = os.path.abspath(path)
        os.makedirs(self.path, exist_ok=True)

    def _file(self, fp: str) -> str:
        return os.path.join(self.path, f"{fp}.aotx")

    def has(self, fp: str) -> bool:
        return os.path.exists(self._file(fp))

    def load(self, fp: str):
        """``(loaded_executable, meta)`` or ``None`` on miss/corruption."""
        try:
            with open(self._file(fp), "rb") as f:
                payload, in_tree, out_tree, meta = pickle.load(f)
            from jax.experimental import serialize_executable as se
            return se.deserialize_and_load(payload, in_tree, out_tree), meta
        except FileNotFoundError:
            return None
        except Exception:
            # a torn/stale entry (interrupted writer, jax upgrade mid-key)
            # degrades to a compile, never an error
            try:
                os.remove(self._file(fp))
            except OSError:
                pass
            return None

    def store(self, fp: str, compiled, meta: Optional[dict] = None) -> None:
        from jax.experimental import serialize_executable as se
        payload, in_tree, out_tree = se.serialize(compiled)
        fd, tmp = tempfile.mkstemp(dir=self.path, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                pickle.dump((payload, in_tree, out_tree, dict(meta or {})),
                            f)
            os.replace(tmp, self._file(fp))
        except BaseException:
            try:
                os.remove(tmp)
            except OSError:
                pass
            raise


def aot_compile(runner, cache: Optional[ExecutableCache] = None, *,
                chunks: Optional[Dict] = None,
                query_fp: Optional[str] = None) -> Dict[str, str]:
    """AOT-prepare every staged step ``runner`` dispatches.

    Warm path first: every persisted-cache hit installs its deserialized
    executable under the staging key *before* any step getter runs — a
    pre-populated cache slot is a hit, so the tracer records no compile
    (the warm-start proof) and the body is never traced.  Whatever is
    still missing is then staged normally, lowered against the runner's
    own concrete example arguments (``staged_steps()``), compiled, swapped
    into the step cache in place of the lazy jit wrapper (so the first
    real chunk doesn't compile a second time through the jit path) and
    persisted.

    Returns ``{step label: "loaded" | "compiled"}``.
    """
    if not runner.spec.jit:
        raise ValueError("AOT serving needs a jitted body (spec.jit=True)")
    report: Dict[str, str] = {}
    if cache is not None:
        for label, key in runner.aot_keys():
            got = cache.load(step_fingerprint(runner, label,
                                              query_fp=query_fp))
            if got is not None:
                loaded, meta = got
                runner.install_executable(
                    key, loaded, label=label, how="loaded",
                    donate=meta.get("donate", ()))
                report[label] = "loaded"
    if len(report) == len(runner.aot_keys()):
        return report  # fully warm: zero staging work
    for step in runner.staged_steps(chunks):
        label = step["label"]
        if label in report:
            continue
        compiled = step["fn"].lower(*step["args"]).compile()
        runner.install_executable(step["key"], compiled, label=label,
                                  how="compiled", donate=step["donate"])
        report[label] = "compiled"
        if cache is not None:
            cache.store(step_fingerprint(runner, label, query_fp=query_fp),
                        compiled, meta={"donate": tuple(step["donate"])})
    return report
