"""The serving loop: AOT-warmed runner + double-buffered async data path
+ ring-buffer event admission.

:class:`ServeLoop` wraps one :class:`repro.engine.Runner`:

* :meth:`warm` AOT-prepares every staged step (:mod:`repro.serve.aot`) —
  loaded from the persisted executable cache when warm, compiled and
  persisted when cold.
* :meth:`serve` is the chunk path: chunk k+1's ``jax.device_put``
  (committed, non-blocking) is issued *before* chunk k's compute
  dispatch, so the H2D transfer of the next request overlaps the current
  step.  Every transfer on the steady-state path is explicit, so the
  whole loop runs under ``jax.transfer_guard("disallow")`` (pinned in
  tests/test_serve.py); the staged step's donation contract recycles the
  carried state buffers in place.
* :meth:`attach_events` + :meth:`offer` / :meth:`pump` is the event
  path: a fixed-capacity :class:`repro.serve.ring.AdmissionRing` feeds
  the disorder-tolerant :class:`repro.ingest.IngestRunner` (watermarks
  and lateness policies compose unchanged), with the same staged-put
  double buffering applied to sealed chunk batches and
  admission→result latency observed per sealed chunk.

:func:`build_service` is the one-call constructor that wires the
persisted caches: plan artifacts by structural fingerprint
(:class:`repro.multiquery.SharedPlanCache`) + serialized executables
(:class:`repro.serve.aot.ExecutableCache`).  A fresh process whose
caches are warm reaches first-result with zero planning, zero tracing
and zero compiles.
"""
from __future__ import annotations

import os
import time
from typing import Dict, Iterable, Optional

import jax

from ..core import compile as qc
from ..core import ir
from ..core.stream import SnapshotGrid
from ..engine import ExecPolicy, Runner
from ..engine.runner import BodySpec
from ..ingest import IngestRunner
from ..multiquery import SharedPlanCache
from ..obs import Metrics, log_buckets
from .aot import (ExecutableCache, aot_compile, enable_jax_compilation_cache,
                  step_fingerprint)
from .ring import AdmissionRing

__all__ = ["ServeLoop", "build_service", "plan_artifact_of",
           "body_spec_from_artifact"]

_tm = jax.tree_util.tree_map


def plan_artifact_of(runner: Runner) -> Dict:
    """The pure-data planning artifact of a runner's body — everything a
    warm process needs to rebuild an equivalent :class:`BodySpec` without
    planning: per-input halo contracts, output geometry, the ChangePlan,
    and the φ seed shapes (so even the ``eval_shape`` trace is skipped).
    All plain dataclasses / ShapeDtypeStructs — pickles stably."""
    spec = runner.spec
    return {"input_specs": dict(spec.input_specs),
            "out_len": spec.out_len, "out_prec": spec.out_prec,
            "out_precs": dict(spec.out_precs),
            "change_plan": spec.change_plan, "solo": spec.solo,
            "seed_shapes": runner.seed_shape_spec()}


def body_spec_from_artifact(art: Dict) -> BodySpec:
    """A :class:`BodySpec` rebuilt from a persisted plan artifact.  The
    body is AOT-only: ``outs_fn`` raises if anything tries to trace it —
    with every staged step pre-installed from the executable cache it is
    never called, and a cache miss falls back to a real compile in
    :func:`build_service` instead of reaching this."""

    def outs_fn(inputs):
        raise RuntimeError(
            "AOT-only body: outs_fn rebuilt from a persisted plan artifact "
            "cannot be traced — serve from the executable cache, or "
            "rebuild with compile_query for a traceable body")

    return BodySpec(
        input_specs=dict(art["input_specs"]), out_len=art["out_len"],
        out_prec=art["out_prec"], outs_fn=outs_fn,
        out_precs=dict(art["out_precs"]), change_plan=art["change_plan"],
        root=None, jit=True, solo=art["solo"], step_cache={}, roots=())


class ServeLoop:
    """One served runner: AOT lifecycle + double-buffered chunk path +
    ring-admitted event path.  ``serve.*`` telemetry lands on the
    runner's metrics registry."""

    def __init__(self, runner: Runner, *,
                 exec_cache: Optional[ExecutableCache] = None,
                 query_fp: Optional[str] = None, device=None):
        self.runner = runner
        self.exec_cache = exec_cache
        self.query_fp = query_fp
        # local placement: commit chunks to the device ahead of dispatch
        # (the double buffer); mesh placement keeps the runner's own
        # sharded ingest placement
        self._device = (None if runner.policy.mesh is not None
                        else (device if device is not None
                              else jax.devices()[0]))
        m = self.metrics = runner.metrics
        self._m_call = m.histogram(
            "serve.call_seconds", log_buckets(1e-5, 10.0, per_decade=3),
            "end-to-end per-call serving latency (dispatch + device "
            "completion)", "s", log_scale=True)
        self._m_admit = m.histogram(
            "serve.admit_to_result_seconds",
            log_buckets(1e-5, 100.0, per_decade=2),
            "ring admission to sealed-result latency", "s", log_scale=True)
        self._m_first = m.gauge(
            "serve.first_result_seconds",
            "construction to first blocked result", "s")
        self._t_created = time.perf_counter()
        self._first_done = False
        self.aot_report: Dict[str, str] = {}
        self.ring: Optional[AdmissionRing] = None
        self.ingest: Optional[IngestRunner] = None
        self._admits: Dict[int, list] = {}

    # -- AOT lifecycle -------------------------------------------------------
    def warm(self, chunks: Optional[Dict] = None) -> Dict[str, str]:
        """AOT-prepare every staged step (load-or-compile+persist);
        returns ``{label: "loaded"|"compiled"}``."""
        self.aot_report = aot_compile(self.runner, self.exec_cache,
                                      chunks=chunks, query_fp=self.query_fp)
        return self.aot_report

    # -- chunk path ----------------------------------------------------------
    def _put(self, chunks: Dict[str, SnapshotGrid]) -> Dict[str, SnapshotGrid]:
        """Commit one request's grids to the serving device — an explicit
        (transfer-guard-legal) non-blocking H2D; issued for chunk k+1
        before chunk k's compute dispatch so the transfer overlaps."""
        if self._device is None:
            return chunks
        d = self._device
        return {name: SnapshotGrid(
                    value=_tm(lambda x: jax.device_put(x, d), g.value),
                    valid=jax.device_put(g.valid, d), t0=g.t0, prec=g.prec)
                for name, g in chunks.items()}

    @staticmethod
    def _block(out):
        for g in (out.values() if isinstance(out, dict) else (out,)):
            jax.block_until_ready(g.valid)
        return out

    def _observe(self, dt: float) -> None:
        if self.metrics.on:
            self._m_call.observe(dt)
            if not self._first_done:
                self._first_done = True
                self._m_first.set(time.perf_counter() - self._t_created)

    def step(self, chunks: Dict[str, SnapshotGrid], *, block: bool = True):
        """Serve one chunk (single-shot path: no lookahead to overlap)."""
        staged = self._put(chunks)
        t0 = time.perf_counter()
        out = self.runner.step(staged)
        if block:
            self._block(out)
        self._observe(time.perf_counter() - t0)
        return out

    def serve(self, chunk_source: Iterable[Dict[str, SnapshotGrid]], *,
              block: bool = True):
        """Generator over results, double-buffered: while chunk k
        computes (and the caller consumes its result), chunk k+1's
        buffers are already transferring.  With ``block`` (default) each
        yield is a completed device result and ``serve.call_seconds``
        measures honest end-to-end latency; ``block=False`` pipelines
        dispatch-deep and the caller owns synchronization."""
        it = iter(chunk_source)
        try:
            cur = self._put(next(it))
        except StopIteration:
            return
        live = True
        while live:
            try:
                nxt = self._put(next(it))  # k+1's H2D overlaps k's compute
            except StopIteration:
                nxt, live = None, False
            t0 = time.perf_counter()
            out = self.runner.step(cur)
            if block:
                self._block(out)
            self._observe(time.perf_counter() - t0)
            yield out
            cur = nxt

    # -- event path ----------------------------------------------------------
    def attach_events(self, *, lateness: int, policy: str = "revise",
                      capacity: int = 1024, shed: str = "newest",
                      horizon_chunks: Optional[int] = None,
                      watermark_keys=None) -> None:
        """Wire the event front end: a bounded admission ring feeding a
        disorder-tolerant :class:`IngestRunner` whose chunk execution
        goes through the same staged-put double buffer."""
        self.ring = AdmissionRing(capacity, shed=shed, metrics=self.metrics)
        self.ingest = IngestRunner(
            self.runner, lateness=lateness, policy=policy,
            horizon_chunks=horizon_chunks, watermark_keys=watermark_keys,
            stage=self._put)

    def _need_events(self):
        if self.ingest is None:
            raise RuntimeError(
                "event path not attached (call attach_events first)")

    def offer(self, name: str, ev, key: int = 0) -> bool:
        """Admit one event into the ring (False = shed)."""
        self._need_events()
        return self.ring.offer(name, ev, key=key)

    def heartbeat(self, t: int) -> None:
        self._need_events()
        self.ingest.heartbeat(t)

    def _observe_sealed(self, sealed) -> None:
        if not sealed or not self.metrics.on:
            return
        now = time.perf_counter()
        for sc in sealed:
            for t in self._admits.pop(sc.chunk, ()):
                self._m_admit.observe(now - t)

    def pump(self, max_events: Optional[int] = None) -> tuple:
        """Drain the ring into the ingest front end (FIFO) and seal +
        execute every watermark-passed chunk.  Returns
        ``(sealed, corrections)`` like :meth:`IngestRunner.poll`."""
        self._need_events()
        span = self.ingest.chunk_span
        for e in self.ring.drain(max_events):
            self.ingest.push(e.name, e.event, key=e.key)
            self._admits.setdefault(
                (e.event.end - 1) // span, []).append(e.t_admit)
        sealed, corrections = self.ingest.poll()
        self._observe_sealed(sealed)
        return sealed, corrections

    def finish(self) -> tuple:
        """End of stream: drain everything, flush the ingest front end."""
        self._need_events()
        span = self.ingest.chunk_span
        for e in self.ring.drain():
            self.ingest.push(e.name, e.event, key=e.key)
            self._admits.setdefault(
                (e.event.end - 1) // span, []).append(e.t_admit)
        sealed, corrections = self.ingest.flush()
        self._observe_sealed(sealed)
        return sealed, corrections


def build_service(query, *, out_len: int,
                  policy: Optional[ExecPolicy] = None,
                  n_keys: Optional[int] = None, segs_per_chunk: int = 1,
                  cache_dir: Optional[str] = None,
                  metrics: Optional[Metrics] = None,
                  jax_cache: bool = True) -> ServeLoop:
    """Build a warmed :class:`ServeLoop` for one query.

    With ``cache_dir`` the two persisted caches live under it:
    ``plans.pkl`` (plan artifacts by structural fingerprint — the
    cross-session :class:`SharedPlanCache`) and ``aot/`` (serialized step
    executables).  First process: compile, plan, AOT-compile, persist.
    Fresh process, warm caches: the runner is rebuilt from the plan
    artifact (no planning), seeds are primed from persisted shapes (no
    tracing) and every staged step loads from disk (no compiling) —
    ``loop.plan_source == "warm"`` and the tracer's compile record stays
    empty.  Any cache miss falls back to the cold path transparently.

    ``jax_cache`` additionally points jax's own persistent compilation
    cache under ``cache_dir`` so even cold XLA compiles warm across
    sessions (best-effort; no-op where unsupported).
    """
    node = getattr(query, "node", query)
    policy = policy if policy is not None else ExecPolicy(body="sparse")
    if policy.union:
        raise NotImplementedError(
            "build_service serves solo queries; build a union BodySpec "
            "runner and wrap it in ServeLoop directly")
    plan_cache = SharedPlanCache(
        persist=os.path.join(cache_dir, "plans.pkl") if cache_dir else None)
    exec_cache = (ExecutableCache(os.path.join(cache_dir, "aot"))
                  if cache_dir else None)
    if cache_dir and jax_cache:
        enable_jax_compilation_cache(os.path.join(cache_dir, "jax_cache"))
    root = plan_cache.intern(node)
    fp = ir.fingerprint(root)

    runner, how = None, "cold"
    art = plan_cache.plan_artifact(fp, out_len)
    if (art is not None and exec_cache is not None and art["solo"]
            and (not policy.sparse or art["change_plan"] is not None)):
        r = Runner(body_spec_from_artifact(art), policy, n_keys=n_keys,
                   segs_per_chunk=segs_per_chunk, metrics=metrics)
        r.prime_seed_shapes(art.get("seed_shapes"))
        if all(exec_cache.has(step_fingerprint(r, label, query_fp=fp))
               for label, _ in r.aot_keys()):
            runner, how = r, "warm"
    if runner is None:
        exe = qc.compile_query(root, out_len=out_len, pallas=False,
                               sparse=policy.sparse)
        runner = Runner(exe, policy, n_keys=n_keys,
                        segs_per_chunk=segs_per_chunk, metrics=metrics)
        plan_cache.store_artifact(fp, out_len, plan_artifact_of(runner))

    loop = ServeLoop(runner, exec_cache=exec_cache, query_fp=fp)
    loop.plan_source = how
    loop.warm()
    return loop
