"""Fixed-capacity FIFO admission ring for per-key event arrival.

The serving loop's front door: producers :meth:`offer` events, the loop
:meth:`drain`\\ s them (in admission order) into the
:class:`repro.ingest.IngestRunner`.  Capacity is fixed at construction —
the queue depth is bounded by design, and what happens at the boundary is
an explicit **shed policy** instead of an unbounded backlog:

``"newest"``
    Refuse the incoming event (tail drop).  Arrival order of admitted
    events is untouched — the FIFO invariant the property tests pin.
``"oldest"``
    Evict the oldest queued event to admit the new one (head drop) —
    freshness-first serving.
``"block"``
    Raise :class:`Backpressure`; the caller owns the wait/retry loop.

Every admission decision lands on the shared zero-sync metrics registry
(``serve.queue_depth`` gauge, ``serve.admitted`` / ``serve.shed_events``
counters) — host-side integer arithmetic only, nothing on the device
path.  Entries carry their admission timestamp so the loop can observe
admission→result latency when the chunk that covers them seals.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

from ..obs import Metrics

__all__ = ["AdmissionRing", "Backpressure", "RingEntry"]

_SHED = ("newest", "oldest", "block")


class Backpressure(RuntimeError):
    """Raised by ``shed='block'`` when the ring is full."""


@dataclasses.dataclass(frozen=True)
class RingEntry:
    """One admitted event: input name, the event, sub-stream key and the
    host admission timestamp (``time.perf_counter`` domain)."""

    name: str
    event: object
    key: int
    t_admit: float


class AdmissionRing:
    """Bounded FIFO over preallocated slots (head index + size; no
    allocation on the admit path)."""

    def __init__(self, capacity: int, *, shed: str = "newest",
                 metrics: Optional[Metrics] = None,
                 clock=time.perf_counter):
        if capacity < 1:
            raise ValueError("ring capacity must be >= 1")
        if shed not in _SHED:
            raise ValueError(f"unknown shed policy {shed!r} (one of {_SHED})")
        self.capacity = int(capacity)
        self.shed = shed
        self._clock = clock
        self._slots: List[Optional[RingEntry]] = [None] * self.capacity
        self._head = 0   # next entry to drain
        self._size = 0
        m = metrics if metrics is not None else Metrics()
        self.metrics = m
        self._m_depth = m.gauge(
            "serve.queue_depth", "events queued in the admission ring",
            "events")
        self._m_cap = m.gauge(
            "serve.ring_capacity", "admission ring capacity", "events")
        self._m_cap.set(self.capacity)
        self._m_admitted = m.counter(
            "serve.admitted", "events admitted into the ring", "events")
        self._m_shed = m.counter(
            "serve.shed_events",
            "events shed at capacity (policy=newest drops the arrival, "
            "policy=oldest evicts the head)", "events")

    def __len__(self) -> int:
        return self._size

    @property
    def depth(self) -> int:
        return self._size

    def offer(self, name: str, event, key: int = 0) -> bool:
        """Admit one event; returns whether it was admitted.  At capacity
        the shed policy decides (module docstring); ``shed='oldest'``
        admits by evicting, so it always returns True."""
        on = self.metrics.on
        if self._size == self.capacity:
            if self.shed == "block":
                raise Backpressure(
                    f"admission ring full ({self.capacity} events)")
            if on:
                self._m_shed.add(1)
            if self.shed == "newest":
                return False
            # oldest: evict the head to make room
            self._slots[self._head] = None
            self._head = (self._head + 1) % self.capacity
            self._size -= 1
        self._slots[(self._head + self._size) % self.capacity] = RingEntry(
            name=name, event=event, key=int(key), t_admit=self._clock())
        self._size += 1
        if on:
            self._m_admitted.add(1)
            self._m_depth.set(self._size)
        return True

    def drain(self, max_events: Optional[int] = None) -> List[RingEntry]:
        """Pop up to ``max_events`` entries (default: all) in admission
        order — the FIFO contract."""
        n = self._size if max_events is None else min(max_events, self._size)
        out = []
        for _ in range(n):
            out.append(self._slots[self._head])
            self._slots[self._head] = None
            self._head = (self._head + 1) % self.capacity
            self._size -= 1
        if self.metrics.on and out:
            self._m_depth.set(self._size)
        return out
