"""``python -m repro.serve`` — smoke-run the serving loop and gate it.

``--smoke`` builds the fraud demo query into a served runner (cold or
warm from ``--cache-dir``), serves a few chunks through the
double-buffered loop — the steady-state tail under
``jax.transfer_guard("disallow")`` — then audits the served runner with
the ``serving`` analysis pass and the tracer's retrace record.  Exit 1
on any error finding or retrace: this is the ``make lint-plans`` hook
that makes the serving invariants (every dispatched step AOT-installed,
steady step donation-clean, no per-request recompiles) gate every PR.

Findings land as ``repro.analysis/v1`` JSONL next to the lattice audit's
(default ``out/analysis_serve.jsonl``).
"""
from __future__ import annotations

import argparse
import sys

import numpy as np


def _fraud(win: int = 16):
    from ..core.frontend import TStream
    s = TStream.source("in", prec=1)
    mu = s.window(win).mean().shift(1)
    sd = s.window(win).stddev().shift(1)
    thr = mu.join(sd, lambda m, d: m + 3.0 * d)
    return s.join(thr, lambda x, t: x - t).where(lambda e: e > 0)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve",
        description="Serving-loop smoke + serving-pass gate.")
    ap.add_argument("--smoke", action="store_true",
                    help="small end-to-end loop (the CI gate)")
    ap.add_argument("--cache-dir", default="out/serve_cache",
                    help="persisted plan/executable cache directory "
                         "(default: out/serve_cache)")
    ap.add_argument("--chunks", type=int, default=6)
    ap.add_argument("--out-len", type=int, default=32)
    ap.add_argument("--out", default="out/analysis_serve.jsonl",
                    help="findings JSONL path")
    args = ap.parse_args(argv)
    if not args.smoke:
        ap.error("nothing to do (pass --smoke)")

    import time
    import jax

    from ..analysis.audit import audit_runner
    from ..analysis.findings import export_jsonl, verdict
    from ..analysis.passes import pass_serving
    from ..core.stream import SnapshotGrid
    from .loop import build_service

    t0 = time.perf_counter()
    svc = build_service(_fraud(), out_len=args.out_len, segs_per_chunk=2,
                        cache_dir=args.cache_dir)
    span = svc.runner.n_segs * svc.runner.spec.span
    rng = np.random.default_rng(3)

    def chunk(i):
        # host numpy on purpose: the loop's explicit (guard-legal)
        # device_put is the only H2D on the steady-state path
        v = rng.integers(0, 100, span).astype(np.float32)
        return {"in": SnapshotGrid(value=v, valid=np.ones(span, bool),
                                   t0=i * span, prec=1)}

    gen = svc.serve(chunk(i) for i in range(args.chunks))
    next(gen)
    t_first = time.perf_counter() - t0
    next(gen)  # second chunk: the steady-state variant stages/warms here
    with jax.transfer_guard("disallow"):
        served = 2 + sum(1 for _ in gen)

    findings = audit_runner(svc.runner, passes={"serving": pass_serving})
    tracer = svc.runner.metrics.tracer
    retraces = tracer.retraces()
    path = export_jsonl(findings, args.out)
    compiled = sum(1 for v in svc.aot_report.values() if v == "compiled")
    print(f"[serve --smoke] plan={svc.plan_source} "
          f"aot={{loaded: {len(svc.aot_report) - compiled}, "
          f"compiled: {compiled}}} chunks={served} "
          f"first_result={t_first * 1e3:.0f}ms "
          f"retraces={retraces or '{}'} "
          f"verdict={verdict(findings)} -> {path}")
    for f in findings:
        print(f"  [{f.severity:7s}] {f.pass_name}/{f.code} :: "
              f"{f.target or '-'} — {f.message}")
    bad = [f for f in findings if f.severity == "error"]
    return 1 if (bad or retraces) else 0


if __name__ == "__main__":
    sys.exit(main())
