"""Static hot-path auditor + temporal-plan verifier (``repro.analysis``).

TiLT's core claim is that a time-centric IR is *analyzable*: temporal
bounds and lineage are static, which is what makes optimization and
parallelization passes safe.  This package turns the stack's own hardest
invariants — zero device→host transfers per steady-state chunk, donated
state fully consumed, collectives never under divergent control, exactly
one compile per staging key, halo/dilation contracts covering the IR's
true demand — from runtime test assertions into **static proofs over
lowered jaxprs and planning artifacts**, audited across the entire
16-point ExecPolicy lattice and gated in CI.

Entry points:

* ``python -m repro.analysis`` / ``make lint-plans`` — CLI over the
  lattice; findings land in ``out/analysis.jsonl``.
* :func:`audit_runner` — audit one live runner (benchmarks embed the
  resulting :func:`verdict` next to their measurements).
* :data:`PASSES` — the registry; a new pass is a function
  ``AuditTarget -> [Finding]`` added here (see docs/architecture.md
  "Static analysis").
"""
from .audit import (PASSES, audit_lattice, audit_runner,
                    build_lattice_runner, lattice_policies)
from .findings import (SCHEMA, SEVERITIES, Finding, export_jsonl,
                       read_jsonl, validate_finding, verdict)
from .passes import AuditTarget, make_target

__all__ = ["PASSES", "audit_lattice", "audit_runner",
           "build_lattice_runner", "lattice_policies",
           "SCHEMA", "SEVERITIES", "Finding", "export_jsonl", "read_jsonl",
           "validate_finding", "verdict", "AuditTarget", "make_target"]
