"""Temporal-plan verifier: TiLT's lineage algebra, checked independently.

The planning layer (:mod:`repro.core.boundary` → :mod:`repro.core.plan`)
derives each query's backward halo contract once and everything downstream
— partition grids, carried tails, ChangePlan dilations, the fused sparse
kernel's affine scan windows — trusts it.  This pass re-derives the
per-input ``(lookback, lookahead)`` demand **from the IR itself**, by a
separate traversal with its own per-op edge rules (written from the op
semantics, not imported from boundary.py), then checks every planning
artifact against the independent result:

* ``InputSpec`` halos must *cover* the derived demand (undersized ⇒ the
  partitioned executors read garbage at segment boundaries — error);
  wider-than-demand halos are conservative rounding — reported as info.
* Grid alignment identities: ``t0 = −left_halo·prec`` and
  ``core·prec = out_len·out_prec`` for every input.
* ``ChangePlan`` dilations must cover the derived demand
  (:meth:`repro.core.plan.ChangePlan.check_covers`), and their affine
  lowering at the runner's geometry must cover the per-segment ranges
  recomputed from the derived demand — including the one-output-stride
  widening of the hold rule (:func:`repro.core.sparse.seg_ranges` /
  :func:`repro.core.sparse.affine_covers`).  An under-dilated plan means
  silently stale outputs; that must never depend on plan_change being
  right about itself.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from ..core import ir
from ..core import sparse as sparse_mod
from ..core.plan import seg_range_affine
from .findings import Finding

__all__ = ["derive_bounds", "pass_plan"]


def _arg_demand(n: ir.Node, a: ir.Node, lb: int, la: int) -> Tuple[int, int]:
    """What ``(lookback, lookahead)`` must argument ``a`` satisfy for
    consumer ``n`` to be known over ``[t−lb, t+la]``?  Re-written from
    each op's semantics (time units):

    * Map/Where read args at the consumer's tick times through the hold
      rule (latest arg tick ≤ τ), which reaches up to ``a.prec`` further
      back when the grids differ.
    * Shift(d) reads ``in[t−d]``: the whole demand translates by ``d``
      (clamped at 0 — a shift cannot create negative reach).
    * Reduce(window=W) folds ``(t−W, t]``: lookback grows by ``W``.
    * Interp(max_gap=g) searches valid neighbours within ``g``: lookback
      grows by ``g`` (+ hold padding), and linear mode also looks ahead
      ``g`` for the right neighbour.
    """
    if isinstance(n, (ir.Map, ir.Where)):
        pad = a.prec if a.prec != n.prec else 0
        return lb + pad, la
    if isinstance(n, ir.Shift):
        return max(lb + n.delta, 0), max(la - n.delta, 0)
    if isinstance(n, ir.Reduce):
        return lb + n.window, la
    if isinstance(n, ir.Interp):
        pad = a.prec if a.prec != n.prec else 0
        if n.mode == "linear":
            return lb + n.max_gap + pad, la + n.max_gap
        return lb + n.max_gap + pad, la
    raise TypeError(f"unknown IR node {type(n).__name__}")


def derive_bounds(roots) -> Dict[str, Tuple[int, int]]:
    """Per-input-name ``(lookback, lookahead)`` demand of a (multi-root)
    DAG, anchored at the shared output domain.

    Forward demand propagation with a dominance memo: a node is
    re-expanded only when a strictly larger demand arrives, so shared
    sub-DAGs don't explode.  Because every edge rule distributes over
    componentwise max, propagating merged demands path-by-path converges
    to the same fixpoint as merge-then-propagate — but through different
    code than boundary.py, which is the point.
    """
    best: Dict[int, Tuple[int, int]] = {}
    req: Dict[str, Tuple[int, int]] = {}
    stack = [(r, 0, 0) for r in roots]
    while stack:
        n, lb, la = stack.pop()
        cur = best.get(id(n), (-1, -1))
        if lb <= cur[0] and la <= cur[1]:
            continue
        lb, la = max(lb, cur[0]), max(la, cur[1])
        best[id(n)] = (lb, la)
        if isinstance(n, ir.Input):
            o = req.get(n.name, (0, 0))
            req[n.name] = (max(o[0], lb), max(o[1], la))
            continue
        for a in n.args:
            alb, ala = _arg_demand(n, a, lb, la)
            stack.append((a, alb, ala))
    return req


def pass_plan(target) -> List[Finding]:
    """Verify the target's planning artifacts against the independently
    derived demand (see module docstring)."""
    out = []
    r = target.runner
    spec = r.spec
    if not spec.roots:
        out.append(Finding(
            "info", "plan", "opaque-body",
            "BodySpec carries no IR roots: the temporal demand cannot be "
            "re-derived — only internal plan consistency was checked",
            policy=target.policy))
        req = {}
    else:
        req = derive_bounds(spec.roots)
        missing = sorted(set(req) - set(spec.input_specs))
        if missing:
            out.append(Finding(
                "error", "plan", "input-without-contract",
                f"IR inputs {missing} have no InputSpec halo contract — "
                "the chunked executors would never supply their halos",
                policy=target.policy))
    span = spec.span
    for name in sorted(spec.input_specs):
        s = spec.input_specs[name]
        if s.t0 % s.prec:
            out.append(Finding(
                "error", "plan", "grid-misaligned",
                f"input {name!r}: grid start t0={s.t0} is not a multiple "
                f"of prec={s.prec} — tick times fall off the grid",
                policy=target.policy, target=name))
        if s.core * s.prec != span:
            out.append(Finding(
                "error", "plan", "span-misaligned",
                f"input {name!r}: core·prec = {s.core * s.prec} != "
                f"segment span {span} — fresh ticks don't tile the chunk",
                policy=target.policy, target=name))
        if name not in req:
            continue
        lb, la = req[name]
        have_lb, have_la = s.contract_t()
        if have_lb < lb or have_la < la:
            out.append(Finding(
                "error", "plan", "halo-undersized",
                f"input {name!r}: halo contract serves (lookback, "
                f"lookahead) = ({have_lb}, {have_la}) time units but the "
                f"IR demands ({lb}, {la}) — partitioned execution reads "
                "garbage at segment boundaries",
                policy=target.policy, target=name,
                provenance=f"left_halo={s.left_halo},prec={s.prec}"))
        slack = (s.left_halo - -(-lb // s.prec),
                 s.right_halo - -(-la // s.prec))
        if max(slack) > 0:
            out.append(Finding(
                "info", "plan", "halo-overwide",
                f"input {name!r}: halo is {slack} ticks wider than the "
                "derived demand needs — conservative (correct), but "
                "every chunk carries the extra ticks",
                policy=target.policy, target=name))
    cp = spec.change_plan
    if cp is None:
        return out
    if (cp.out_len, cp.out_prec) != (spec.out_len, spec.out_prec):
        out.append(Finding(
            "error", "plan", "changeplan-grid-mismatch",
            f"ChangePlan grid ({cp.out_len}, {cp.out_prec}) != body grid "
            f"({spec.out_len}, {spec.out_prec})",
            policy=target.policy))
    for name, field, have, need in cp.check_covers(req):
        out.append(Finding(
            "error", "plan", "changeplan-under-dilated",
            f"input {name!r}: ChangePlan {field} = {have} does not cover "
            f"the derived demand {need} — changes inside the uncovered "
            "span leave stale outputs marked clean",
            policy=target.policy, target=name,
            provenance=f"{field}:have={have},need={need}"))
    # affine coverage at this runner's geometry: the windows the fused
    # change-detection kernel actually scans, vs the per-segment ranges
    # required by the *derived* demand (with the hold rule's one-output-
    # stride widening — seg_ranges owns that ±1 arithmetic)
    for name in sorted(spec.input_specs):
        if name not in cp.specs or name not in req:
            continue
        s, sp = spec.input_specs[name], cp.specs[name]
        lb, la = req[name]
        i_lo, i_hi1 = sparse_mod.seg_ranges(
            lb, la, s.prec, grid_t0=-s.left_halo * s.prec, out_t0=0,
            out_prec=spec.out_prec, seg_len=spec.out_len, n_segs=r.n_segs)
        try:
            affine = seg_range_affine(
                sp.lookback, sp.lookahead, s.prec,
                grid_t0=-s.left_halo * s.prec, out_t0=0,
                out_prec=spec.out_prec, seg_len=spec.out_len)
        except ValueError:
            out.append(Finding(
                "warning", "plan", "no-affine-lowering",
                f"input {name!r}: segment span not stride-aligned — the "
                "fused kernel cannot serve this input (general seg_ranges "
                "fallback)", policy=target.policy, target=name))
            continue
        ok = sparse_mod.affine_covers(affine, i_lo, i_hi1)
        if not bool(np.all(ok)):
            bad = np.nonzero(~ok)[0].tolist()
            out.append(Finding(
                "error", "plan", "dilation-misses-segments",
                f"input {name!r}: the kernel's affine scan window misses "
                f"required dirty ticks for segments {bad} — changes there "
                "never mark the segment dirty (silently stale outputs)",
                policy=target.policy, target=name,
                provenance=f"affine={affine}"))
    return out
