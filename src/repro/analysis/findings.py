"""Structured analysis findings + schema-versioned JSONL export.

Every pass in :mod:`repro.analysis` reports :class:`Finding` records — one
per violation (or notable observation) with enough provenance to locate it:
which pass fired, at which policy-lattice point, on which audit target
(staged step / chunk variant / input name), and where inside the lowered
jaxpr or planning artifact.  Severities gate CI:

* ``error``   — a proven violation of a hot-path invariant (a transfer,
  a dead donated leaf, a collective under divergent control, an
  under-captured staging key, an under-dilated change plan).
* ``warning`` — suspicious but not proven wrong (e.g. a donated leaf with
  no shape-matching output to alias into).
* ``info``    — observations (e.g. a halo wider than the derived demand:
  conservative, correct, but worth seeing).

The JSONL export mirrors the conventions of :mod:`repro.obs.export`
(schema field on every record, append-lines format, a validator for the
round-trip) under its own schema tag ``repro.analysis/v1``.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Iterable, List

__all__ = ["SCHEMA", "SEVERITIES", "Finding", "export_jsonl", "read_jsonl",
           "validate_finding", "verdict"]

SCHEMA = "repro.analysis/v1"
SEVERITIES = ("info", "warning", "error")
_RANK = {s: i for i, s in enumerate(SEVERITIES)}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analysis finding (see module docstring for severity semantics).

    ``pass_name`` serializes as ``"pass"`` (a Python keyword).  ``target``
    names the audited object inside the policy point (a staged-step label
    like ``sparse_fused(steady)``, a chunk variant, or an input name);
    ``provenance`` locates the evidence (a jaxpr eqn path like
    ``pjit[jaxpr]/cond[branches][1]/ppermute``, a pytree leaf path, or
    plan coordinates).
    """

    severity: str
    pass_name: str
    code: str
    message: str
    policy: str = ""
    target: str = ""
    provenance: str = ""

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(
                f"severity {self.severity!r} not in {SEVERITIES}")

    def to_json(self) -> Dict:
        return {"schema": SCHEMA, "severity": self.severity,
                "pass": self.pass_name, "code": self.code,
                "message": self.message, "policy": self.policy,
                "target": self.target, "provenance": self.provenance}

    @staticmethod
    def from_json(d: Dict) -> "Finding":
        return Finding(severity=d["severity"], pass_name=d["pass"],
                       code=d["code"], message=d["message"],
                       policy=d.get("policy", ""), target=d.get("target", ""),
                       provenance=d.get("provenance", ""))


def validate_finding(d: Dict) -> List[str]:
    """Schema problems of one JSON finding record (empty = valid)."""
    problems = []
    if d.get("schema") != SCHEMA:
        problems.append(f"schema is {d.get('schema')!r}, want {SCHEMA!r}")
    if d.get("severity") not in SEVERITIES:
        problems.append(f"severity {d.get('severity')!r} not in {SEVERITIES}")
    for field in ("pass", "code", "message"):
        if not isinstance(d.get(field), str) or not d.get(field):
            problems.append(f"missing/empty field {field!r}")
    return problems


def verdict(findings: Iterable[Finding]) -> str:
    """The worst severity present: ``clean`` / ``info`` / ``warning`` /
    ``error`` — the one-word audit result benchmarks embed next to their
    measurements."""
    worst = -1
    for f in findings:
        worst = max(worst, _RANK[f.severity])
    return "clean" if worst < 0 else SEVERITIES[worst]


def export_jsonl(findings: Iterable[Finding], path: str) -> str:
    """Write findings as JSON lines (one record per line, every record
    schema-tagged — the same append-friendly shape as
    :func:`repro.obs.export.export_jsonl`)."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as fh:
        for f in findings:
            fh.write(json.dumps(f.to_json(), sort_keys=True) + "\n")
    return path


def read_jsonl(path: str) -> List[Finding]:
    """Read back an :func:`export_jsonl` file, validating each record."""
    out = []
    with open(path) as fh:
        for i, line in enumerate(fh):
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            problems = validate_finding(d)
            if problems:
                raise ValueError(f"{path}:{i + 1}: {'; '.join(problems)}")
            out.append(Finding.from_json(d))
    return out
