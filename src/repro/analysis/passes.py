"""The static hot-path passes: transfer-freedom, donation-consumption,
collective-placement, recompile-hazard.

Each pass takes an :class:`AuditTarget` — a runner plus its lowerable audit
surface (the staged steps and whole-chunk functions ``engine/runner.py``
exposes) — and returns :class:`repro.analysis.findings.Finding` records.
The passes prove statically, from traced jaxprs, the invariants the
runtime tests assert dynamically:

* **transfer**   — the static complement of the ``jax.transfer_guard``
  tests: the whole-chunk jaxpr must consist of the staged ``pjit``
  dispatch plus metadata-only ops; any other eager eqn (an ``x[0]``
  strip lowering to slice/squeeze with host scalars, a host callback)
  is a per-chunk device→host sync waiting to happen.
* **donation**   — every leaf the staged step declares in
  ``donate_argnums`` must actually be consumed: read by some eqn of the
  traced body (or passed through to an output it aliases).  A donated
  invar no eqn reads is exactly the pre-PR7 dead ``prev`` class.
* **collective** — ``ppermute``/``psum``/``all_gather``/… nested under
  ``cond``/``while`` frames: divergent control means shards can disagree
  on whether the collective executes — deadlock.
* **recompile**  — the staging-cache key must move whenever the traced
  step's avals would: sibling runners perturbed one configuration degree
  of freedom at a time must land on distinct keys.  Runtime-observed
  retraces from the tracer merge into the same report.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax

from .findings import Finding
from .jaxprs import STAGED, walk

__all__ = ["AuditTarget", "make_target", "pass_transfers", "pass_donation",
           "pass_collectives", "pass_recompile", "pass_revision",
           "pass_serving", "COLLECTIVES"]

# cross-shard communication primitives (psum covers psum2 spellings)
COLLECTIVES = frozenset({
    "ppermute", "pshuffle", "psum", "psum2", "pmin", "pmax", "pmean",
    "all_gather", "all_to_all", "reduce_scatter", "pgather"})

# eager ops allowed outside the staged step: metadata-only, no buffer
# traffic (the runner's post-step K-axis strip is a reshape)
METADATA_OK = frozenset({"reshape", "transpose", "squeeze"})

# host-callback primitives: a device→host round-trip wherever they appear
CALLBACKS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "host_callback", "outside_call"})


@dataclasses.dataclass
class AuditTarget:
    """One policy-lattice point under audit: the runner, its staged steps
    (with concrete example args) and lazily traced jaxprs."""

    runner: object
    policy: str
    steps: List[Dict]
    chunk_variants: tuple
    _step_jaxprs: Dict = dataclasses.field(default_factory=dict)
    _chunk_jaxprs: Dict = dataclasses.field(default_factory=dict)

    def step_jaxpr(self, step: Dict):
        """The step traced as a *call* (wrapper lambda), so the staged
        dispatch shows up as a ``pjit`` eqn carrying ``donated_invars``
        and the traced body."""
        label = step["label"]
        if label not in self._step_jaxprs:
            fn = step["fn"]
            self._step_jaxprs[label] = jax.make_jaxpr(
                lambda *a: fn(*a))(*step["args"])
        return self._step_jaxprs[label]

    def chunk_jaxpr(self, variant: str):
        """The whole-chunk function (staged dispatch + eager post-step
        assembly) traced for one variant."""
        if variant not in self._chunk_jaxprs:
            fn, args = self.runner.chunk_fn(variant)
            self._chunk_jaxprs[variant] = jax.make_jaxpr(fn)(*args)
        return self._chunk_jaxprs[variant]


def make_target(runner, policy: Optional[str] = None) -> AuditTarget:
    """Build the audit surface of one runner (any policy point)."""
    variants = (("steady", "first") if runner.policy.sparse else ("dense",))
    return AuditTarget(
        runner=runner,
        policy=policy if policy is not None else runner.policy.describe(),
        steps=runner.staged_steps(), chunk_variants=variants)


def _leaf_paths(args) -> List[str]:
    flat, _ = jax.tree_util.tree_flatten_with_path(args)
    return [jax.tree_util.keystr(kp) for kp, _ in flat]


# ---------------------------------------------------------------------------
# transfer-freedom
# ---------------------------------------------------------------------------

def pass_transfers(target: AuditTarget) -> List[Finding]:
    """Flag anything on the chunk path that forces (or risks) a
    device→host sync in steady state — see module docstring."""
    out = []
    if not target.runner.spec.jit:
        out.append(Finding(
            "info", "transfer", "unjitted-body",
            "body compiled with jit=False: nothing is staged, the chunk "
            "path is eager by construction — transfer audit skipped",
            policy=target.policy))
        return out
    for variant in target.chunk_variants:
        jpr = target.chunk_jaxpr(variant)
        staged = 0
        for site in walk(jpr):
            prim = site.prim
            if prim in CALLBACKS:
                out.append(Finding(
                    "error", "transfer", "host-callback",
                    f"chunk variant {variant!r} binds host callback "
                    f"{prim!r}: a device→host round-trip on every chunk",
                    policy=target.policy, target=variant,
                    provenance=site.provenance()))
            if site.path:
                continue  # nested (inside the staged step): compiled code
            if prim in STAGED:
                staged += 1
                continue
            if prim in METADATA_OK:
                continue
            hint = ""
            if prim in ("dynamic_slice", "gather", "dynamic_update_slice",
                        "scatter", "squeeze", "slice"):
                hint = (" — the PR6 bug class: eager indexing binds "
                        "start-index scalars host→device on every chunk"
                        " (use a metadata-only reshape)")
            out.append(Finding(
                "error", "transfer", "eager-op-outside-staged-step",
                f"chunk variant {variant!r} binds eager op {prim!r} "
                f"outside the staged step{hint}",
                policy=target.policy, target=variant,
                provenance=site.provenance()))
        if staged != 1:
            out.append(Finding(
                "warning", "transfer", "staged-dispatch-count",
                f"chunk variant {variant!r} dispatches {staged} staged "
                "steps (expected exactly 1 per chunk)",
                policy=target.policy, target=variant))
    return out


# ---------------------------------------------------------------------------
# donation-consumption
# ---------------------------------------------------------------------------

def pass_donation(target: AuditTarget) -> List[Finding]:
    """Per donated leaf of every staged step: is it consumed?  Dead
    donated leaves (never read, never returned) are the pre-PR7 ``prev``
    class — donation silently buys nothing and the state pytree carries
    garbage.  Leaves with no shape/dtype-matching output cannot alias in
    place (XLA falls back to a copy) — reported as warnings."""
    out = []
    if not target.runner.spec.jit:
        return out
    for step in target.steps:
        if not step["donate"]:
            continue
        jpr = target.step_jaxpr(step)
        paths = _leaf_paths(step["args"])
        outer_pos = {v: i for i, v in enumerate(jpr.jaxpr.invars)}
        for site in walk(jpr):
            if site.path or site.prim not in STAGED:
                continue
            donated = site.eqn.params.get("donated_invars")
            inner = site.eqn.params.get("jaxpr")
            if donated is None or inner is None or not any(donated):
                continue
            ij = inner.jaxpr
            used = set()
            for eqn in ij.eqns:
                for v in eqn.invars:
                    if not hasattr(v, "val"):  # skip Literals
                        used.add(v)
            outset = {v for v in ij.outvars if not hasattr(v, "val")}
            out_avals: Dict[tuple, int] = {}
            for v in ij.outvars:
                a = getattr(v, "aval", None)
                if a is not None and hasattr(a, "shape"):
                    k = (tuple(a.shape), str(a.dtype))
                    out_avals[k] = out_avals.get(k, 0) + 1
            for i, flag in enumerate(donated):
                if not flag or i >= len(ij.invars):
                    continue
                var = ij.invars[i]
                pos = outer_pos.get(site.eqn.invars[i]
                                    if i < len(site.eqn.invars) else None)
                label = (paths[pos] if pos is not None and pos < len(paths)
                         else f"leaf[{i}]")
                if var not in used and var not in outset:
                    out.append(Finding(
                        "error", "donation", "donated-leaf-dead",
                        f"step {step['label']!r} donates leaf {label} but "
                        "no eqn of the traced body reads it and it is not "
                        "an output — dead state riding the donated pytree "
                        "(the pre-PR7 prev-snapshot class)",
                        policy=target.policy, target=step["label"],
                        provenance=label))
                    continue
                a = getattr(var, "aval", None)
                k = ((tuple(a.shape), str(a.dtype))
                     if a is not None and hasattr(a, "shape") else None)
                if k is not None and out_avals.get(k, 0) > 0:
                    out_avals[k] -= 1
                else:
                    out.append(Finding(
                        "warning", "donation", "donated-leaf-unaliased",
                        f"step {step['label']!r} donates leaf {label} "
                        f"(aval {k}) but no same-shaped output remains to "
                        "alias it into — XLA will copy instead of reusing "
                        "the buffer",
                        policy=target.policy, target=step["label"],
                        provenance=label))
    return out


# ---------------------------------------------------------------------------
# collective-placement
# ---------------------------------------------------------------------------

def pass_collectives(target: AuditTarget) -> List[Finding]:
    """Collectives under divergent control (``cond``/``while`` frames):
    shards that disagree on the branch/trip count deadlock in the
    collective.  ``scan`` is fine (static trip count, every shard runs
    every iteration)."""
    out = []
    for step in target.steps:
        jpr = target.step_jaxpr(step)
        for site in walk(jpr):
            if site.prim not in COLLECTIVES:
                continue
            frames = site.divergent_frames()
            if frames:
                out.append(Finding(
                    "error", "collective", "collective-under-divergence",
                    f"step {step['label']!r} runs collective "
                    f"{site.prim!r} under divergent control "
                    f"({'/'.join(f.label() for f in frames)}) — shards "
                    "taking different branches deadlock",
                    policy=target.policy, target=step["label"],
                    provenance=site.provenance()))
    return out


# ---------------------------------------------------------------------------
# recompile-hazard
# ---------------------------------------------------------------------------

def _probe_signature(runner) -> tuple:
    """The abstract signature the staged steps would trace against, from
    concrete audit args only — no tracing, so probing never touches the
    shared step cache or compile counters."""
    chunk_in = runner._ingest(runner.audit_example_chunks())
    tails, sparse, seeds = runner._audit_state(chunk_in)
    args = ((tails, sparse["dirty"], sparse["prev"], seeds, chunk_in)
            if runner.policy.sparse else (tails, chunk_in))
    flat, _ = jax.tree_util.tree_flatten_with_path(args)
    sig = tuple(
        (jax.tree_util.keystr(kp), tuple(x.shape), str(x.dtype),
         bool(getattr(x, "weak_type", False)))
        for kp, x in flat)
    return sig + (("__static", runner.policy.n_shards, runner.policy.axis,
                   runner.spec.jit),)


def _sibling(runner, *, n_keys=None, segs=None):
    """A probe runner differing in exactly one configuration DOF (shares
    the BodySpec — and hence the step cache — but never stages anything)."""
    return type(runner)(
        runner.spec, runner.policy,
        n_keys=(n_keys if n_keys is not None
                else (runner.n_keys if runner.policy.keyed else None)),
        segs_per_chunk=segs if segs is not None else runner.n_segs)


def pass_recompile(target: AuditTarget) -> List[Finding]:
    """Three recompile-hazard detectors in one report: runtime retraces
    the tracer already recorded, weak-type / host-scalar drift in the
    staged steps' argument trees, and the static DOF probe on the
    staging-cache key (see module docstring)."""
    out = []
    r = target.runner
    for d in r.metrics.tracer.retrace_findings():
        out.append(Finding(
            d["severity"], "recompile", d["code"], d["message"],
            policy=target.policy, provenance=str(d["provenance"])))
    # argument-tree lint: a weak-typed or host-scalar leaf retraces the
    # step the first time a differently-typed value arrives
    for step in target.steps:
        flat, _ = jax.tree_util.tree_flatten_with_path(step["args"])
        for kp, leaf in flat:
            label = jax.tree_util.keystr(kp)
            if not hasattr(leaf, "shape"):
                out.append(Finding(
                    "error", "recompile", "host-scalar-step-arg",
                    f"step {step['label']!r} arg leaf {label} is a host "
                    f"{type(leaf).__name__}: re-bound as a fresh constant "
                    "every chunk (a transfer) and a retrace when it drifts",
                    policy=target.policy, target=step["label"],
                    provenance=label))
            elif getattr(leaf, "weak_type", False):
                out.append(Finding(
                    "warning", "recompile", "weak-type-step-arg",
                    f"step {step['label']!r} arg leaf {label} is weakly "
                    "typed: a strongly-typed value at the same shape "
                    "retraces the step under the same staging key",
                    policy=target.policy, target=step["label"],
                    provenance=label))
    # static DOF probe: perturb one degree of freedom per sibling; the
    # traced signature moves, so the staging key must move too
    sig0 = _probe_signature(r)
    key0 = r._cache_key("probe")
    probes = [("segs_per_chunk", dict(segs=r.n_segs * 2))]
    if r.policy.keyed:
        probes.append(("n_keys", dict(n_keys=r.n_keys * 2)))
    for dof, kw in probes:
        try:
            sib = _sibling(r, **kw)
        except (ValueError, NotImplementedError):
            continue  # geometry constraint forbids this perturbation
        if (_probe_signature(sib) != sig0
                and sib._cache_key("probe") == key0):
            out.append(Finding(
                "error", "recompile", "staging-key-under-captures",
                f"perturbing {dof} changes the staged steps' traced "
                "signature but not the staging-cache key — two "
                "geometries share one cache slot, so the second "
                "silently retraces (or reuses the wrong executable)",
                policy=target.policy, target=dof,
                provenance=f"key={key0!r}"))
    return out


# ---------------------------------------------------------------------------
# revision-horizon coverage
# ---------------------------------------------------------------------------

def pass_revision(target: AuditTarget) -> List[Finding]:
    """Revision-enabled runners only: does the snapshot ring reach far
    enough back to revise every late event the declared lateness bound
    admits?  The required depth is pure ChangePlan arithmetic
    (:meth:`repro.core.plan.ChangePlan.revision_horizon_chunks`): a
    patched tick up to ``revise_bound`` behind the sealed frontier
    dirties outputs reaching ``lookahead + prec`` further back, so an
    undersized ring silently refuses (drops) in-bound late events —
    a liveness bug no runtime test hits until real disorder does."""
    out = []
    r = target.runner
    if getattr(r, "_rev_ring", None) is None:
        return out  # revision disabled: nothing to cover
    bound = r.revise_bound
    if bound is None:
        out.append(Finding(
            "info", "revision", "revision-bound-undeclared",
            "revision ring enabled without a declared lateness bound "
            "(enable_revision(revise_bound=...)) — horizon coverage "
            "cannot be checked statically",
            policy=target.policy))
        return out
    cp = r.spec.change_plan
    if cp is None:
        out.append(Finding(
            "warning", "revision", "revision-horizon-unverifiable",
            "revision ring enabled on a body without a ChangePlan: the "
            "required horizon depth cannot be derived — late-event "
            "coverage rests on the caller's sizing alone",
            policy=target.policy))
        return out
    chunk_span = r.n_segs * r.spec.span
    need = cp.revision_horizon_chunks(bound, chunk_span)
    if r.revision_horizon < need:
        out.append(Finding(
            "error", "revision", "revision-horizon-undersized",
            f"revision ring holds {r.revision_horizon} chunk snapshots "
            f"but a lateness bound of {bound} time units over "
            f"{chunk_span}-unit chunks needs {need} "
            "(ChangePlan.revision_horizon_chunks): in-bound late events "
            "will be refused as beyond-horizon",
            policy=target.policy,
            provenance=f"have={r.revision_horizon} need={need}"))
    else:
        out.append(Finding(
            "info", "revision", "revision-horizon-covered",
            f"revision ring depth {r.revision_horizon} covers the "
            f"declared lateness bound {bound} (need {need})",
            policy=target.policy))
    return out


# ---------------------------------------------------------------------------
# serving readiness
# ---------------------------------------------------------------------------

def pass_serving(target: AuditTarget) -> List[Finding]:
    """Served runners only (``repro.serve`` installs AOT executables and
    records them in ``Runner.aot_record``): every staged step the policy
    point dispatches must be backed by an installed AOT executable — a
    served request must never trace or compile — and the steady-state
    step must carry a non-empty donation contract, or the double-buffered
    async path re-allocates the carried state pytree on every chunk.
    Non-served runners (the lattice audit) have nothing to prove here.

    This pass reads runner bookkeeping only — it never traces, so it is
    safe on a runner whose step cache holds loaded executables (which
    ``jax.make_jaxpr`` cannot re-trace; run the jaxpr passes on a
    pre-AOT twin instead)."""
    out: List[Finding] = []
    r = target.runner
    aot = getattr(r, "aot_record", None)
    if not aot:
        return out  # not a served runner
    if not r.spec.jit:
        out.append(Finding(
            "error", "serving", "serving-unjitted",
            "served body has spec.jit=False — AOT executables need a "
            "jitted staged step", policy=target.policy))
        return out
    cache = r.spec.step_cache
    loaded = 0
    for label, key in r.aot_keys():
        rec = aot.get(key)
        if rec is None or key not in cache:
            out.append(Finding(
                "error", "serving", "serving-step-not-aot",
                f"staged step {label} reachable by this served policy "
                "point has no installed AOT executable — the first "
                "request would trace and compile in-band",
                policy=target.policy, target=label))
            continue
        loaded += rec["how"] == "loaded"
        if label in ("sparse_fused(steady)", "dense") and not rec["donate"]:
            out.append(Finding(
                "error", "serving", "serving-donation-missing",
                f"steady-state step {label} was AOT-installed with an "
                "empty donation contract — every chunk re-allocates the "
                "carried state instead of recycling it in place",
                policy=target.policy, target=label,
                provenance=f"how={rec['how']}"))
    if not any(f.severity == "error" for f in out):
        out.append(Finding(
            "info", "serving", "serving-aot-complete",
            f"{len(r.aot_keys())} staged steps AOT-installed "
            f"({loaded} loaded from the persisted cache, "
            f"{len(r.aot_keys()) - loaded} compiled ahead of time)",
            policy=target.policy))
    return out
