"""``python -m repro.analysis`` — audit the policy lattice from the shell.

Runs every registered pass over every point of the 16-point ExecPolicy
lattice (or a ``--policy``-filtered subset), prints a per-(policy, pass)
summary table plus one line per finding, writes the findings as
schema-versioned JSONL (default ``out/analysis.jsonl``), and exits
non-zero when findings at or above ``--fail-on`` exist — the
``make lint-plans`` CI gate.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import Counter

from .audit import PASSES, audit_lattice, lattice_policies
from .findings import SEVERITIES, export_jsonl, verdict


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static hot-path auditor + temporal-plan verifier "
                    "over the ExecPolicy lattice.")
    ap.add_argument("--fail-on", choices=list(SEVERITIES) + ["never"],
                    default="error",
                    help="exit 1 when findings at/above this severity "
                         "exist (default: error)")
    ap.add_argument("--json", action="store_true",
                    help="print findings as JSON lines to stdout instead "
                         "of the human table")
    ap.add_argument("--out", default="out/analysis.jsonl",
                    help="findings JSONL path (default: out/analysis.jsonl)")
    ap.add_argument("--policy", default=None,
                    help="substring filter on the policy label "
                         "(e.g. 'sparse×vmapped' or 'mesh')")
    ap.add_argument("--passes", default=None,
                    help="comma-separated pass subset "
                         f"(available: {','.join(PASSES)})")
    args = ap.parse_args(argv)

    passes = None
    if args.passes:
        unknown = [p for p in args.passes.split(",") if p not in PASSES]
        if unknown:
            ap.error(f"unknown passes {unknown}; available: {list(PASSES)}")
        passes = {p: PASSES[p] for p in args.passes.split(",")}
    policies = [p for p in lattice_policies()
                if args.policy is None or args.policy in p.describe()]
    if not policies:
        ap.error(f"--policy {args.policy!r} matches no lattice point")

    findings = audit_lattice(policies, passes=passes)
    path = export_jsonl(findings, args.out)

    if args.json:
        for f in findings:
            print(json.dumps(f.to_json(), sort_keys=True))
    else:
        names = list(passes if passes is not None else PASSES)
        print(f"audited {len(policies)} policy points × "
              f"{len(names)} passes ({', '.join(names)})")
        by = Counter((f.severity for f in findings))
        for f in findings:
            print(f"  [{f.severity:7s}] {f.pass_name}/{f.code} "
                  f"@ {f.policy or '-'} :: {f.target or '-'} — {f.message}")
        counts = " ".join(f"{s}={by.get(s, 0)}" for s in SEVERITIES)
        print(f"verdict: {verdict(findings)} ({counts}) → {path}")

    if args.fail_on == "never":
        return 0
    threshold = SEVERITIES.index(args.fail_on)
    bad = [f for f in findings if SEVERITIES.index(f.severity) >= threshold]
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
