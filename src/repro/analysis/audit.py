"""Pass manager: audit one runner, or the whole 16-point policy lattice.

``audit_runner`` runs every registered pass over one runner's audit
surface; ``audit_lattice`` builds a representative runner per
:class:`repro.engine.ExecPolicy` point — every combination of
body(dense|sparse) × keys(single|vmapped) × placement(local|mesh) ×
dag(solo|union), the same 16-point matrix ``tests/test_policy.py``
verifies bit-exact — and audits each.  The mesh points run on a 1-device
mesh (the sharding structure, ``shard_map`` eqns and collective placement
are all present in the traced jaxprs regardless of device count), so the
full lattice audits on any backend, including single-core CI.

The audit queries mirror the hot-path tests: a windowed-mean trend/join
query per solo point, plus a second band query for union points, compiled
``sparse=True`` so every point (dense bodies included) carries a
ChangePlan for the temporal-plan verifier.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

import numpy as np

from ..core import compile as qc
from ..core.frontend import TStream
from ..engine import ExecPolicy, Runner
from ..multiquery import union_runner
from .findings import Finding
from .passes import (AuditTarget, make_target, pass_collectives,
                     pass_donation, pass_recompile, pass_revision,
                     pass_serving, pass_transfers)
from .planverify import pass_plan

__all__ = ["PASSES", "audit_runner", "audit_lattice", "lattice_policies",
           "build_lattice_runner", "SEG", "SPC", "N_KEYS"]

# every registered pass, in report order
PASSES: Dict[str, Callable[[AuditTarget], List[Finding]]] = {
    "transfer": pass_transfers,
    "donation": pass_donation,
    "collective": pass_collectives,
    "recompile": pass_recompile,
    "plan": pass_plan,
    "revision": pass_revision,
    "serving": pass_serving,
}

# default audit geometry (small: the lattice audits in seconds on CPU)
SEG = 16     # output ticks per segment
SPC = 4      # segments per chunk
N_KEYS = 4   # keyed points


def audit_runner(runner: Runner, policy: Optional[str] = None,
                 passes: Optional[Dict] = None) -> List[Finding]:
    """Run every (or the given) passes over one runner."""
    target = make_target(runner, policy)
    out: List[Finding] = []
    for fn in (passes if passes is not None else PASSES).values():
        out.extend(fn(target))
    return out


# ---------------------------------------------------------------------------
# the policy lattice
# ---------------------------------------------------------------------------

def _trend(keyed: bool):
    s = TStream.source("in", prec=1, keyed=keyed)
    return (s.window(8).mean()
            .join(s.window(16).mean(), lambda a, b: a - b)
            .where(lambda d: d > 0))


def _bands(keyed: bool):
    s = TStream.source("in", prec=1, keyed=keyed)
    return s.window(16).mean().select(lambda m: m * 2.0)


def _mesh1():
    """A 1-device mesh: full sharding structure, runs anywhere."""
    import jax
    from jax.sharding import Mesh
    return Mesh(np.array(jax.devices()[:1]), ("data",))


def lattice_policies() -> List[ExecPolicy]:
    """All 16 points of body × keys × placement × dag."""
    mesh = _mesh1()
    pts = []
    for body in ("dense", "sparse"):
        for keys in ("single", "vmapped"):
            for placement in ("local", mesh):
                for dag in ("solo", "union"):
                    pts.append(ExecPolicy(body=body, keys=keys,
                                          placement=placement, dag=dag))
    return pts


def build_lattice_runner(policy: ExecPolicy, *, seg: int = SEG,
                         spc: int = SPC, n_keys: int = N_KEYS) -> Runner:
    """A representative runner at one policy point (the audit target the
    CLI and the lattice tests share).  Queries are compiled sparse so a
    ChangePlan is always present for the plan verifier; dense bodies
    simply don't consume it."""
    keyed = policy.keyed
    nk = n_keys if keyed else None
    if policy.union:
        return union_runner(
            {"trend": _trend(keyed), "bands": _bands(keyed)}, span=seg,
            policy=policy, n_keys=nk, segs_per_chunk=spc)
    exe = qc.compile_query(_trend(keyed).node, out_len=seg, pallas=False,
                           sparse=True)
    return Runner(exe, policy, n_keys=nk, segs_per_chunk=spc)


def audit_lattice(policies: Optional[List[ExecPolicy]] = None,
                  passes: Optional[Dict] = None) -> List[Finding]:
    """Audit every policy point (default: the full 16-point lattice)."""
    out: List[Finding] = []
    for policy in (policies if policies is not None else lattice_policies()):
        r = build_lattice_runner(policy)
        out.extend(audit_runner(r, passes=passes))
    return out
