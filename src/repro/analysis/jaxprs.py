"""Jaxpr walking for the static passes: every eqn, with control context.

``jax.make_jaxpr`` on the runner's chunk path yields a nested program: a
top-level jaxpr whose eqns include the staged ``pjit`` step, which in turn
carries the whole traced body, with further nesting under ``shard_map``,
``cond``/``switch`` branches, ``while``/``scan`` bodies and so on.  The
passes need to reason about *where* an eqn sits — outside the staged step
(eager, dispatched per chunk), under divergent control flow (a ``cond``
branch some shards may not take), inside a ``shard_map`` — so the walker
yields each eqn with its **path**: the stack of (primitive, param, index)
frames it is nested under.

No dependency on jax internals: sub-jaxprs are discovered structurally by
scanning ``eqn.params`` for values (or lists of values) that look like
jaxprs (have ``.eqns``/``.invars``, possibly behind a ``ClosedJaxpr``'s
``.jaxpr``), which is stable across the jax versions we target.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

__all__ = ["Frame", "Site", "walk", "inner_jaxpr"]

# primitives whose sub-jaxprs execute conditionally — different shards can
# take different branches / trip counts, which is why a collective inside
# is a deadlock hazard.  scan is deliberately absent: its trip count is
# static, every shard runs every iteration.
DIVERGENT = frozenset({"cond", "while"})

# the staged-dispatch boundary: eqns at or below one of these run inside
# the compiled executable, eqns outside are eager per-chunk work
STAGED = frozenset({"pjit", "xla_call", "jit"})


@dataclasses.dataclass(frozen=True)
class Frame:
    """One nesting level: ``eqn.primitive`` / params key / list index."""

    prim: str
    param: str
    index: int

    def label(self) -> str:
        return f"{self.prim}[{self.param}][{self.index}]"


@dataclasses.dataclass(frozen=True)
class Site:
    """One eqn plus the frame stack it is nested under."""

    eqn: object
    path: Tuple[Frame, ...]

    @property
    def prim(self) -> str:
        return self.eqn.primitive.name

    @property
    def in_staged(self) -> bool:
        """Inside a jitted (compiled, single-dispatch) region."""
        return any(f.prim in STAGED for f in self.path)

    def divergent_frames(self) -> Tuple[Frame, ...]:
        """The divergent-control frames above this eqn (empty = the eqn
        runs unconditionally on every shard)."""
        return tuple(f for f in self.path if f.prim in DIVERGENT)

    def provenance(self) -> str:
        return "/".join([f.label() for f in self.path] + [self.prim])


def _as_jaxpr(x):
    """The raw Jaxpr behind ``x`` (unwrapping ClosedJaxpr), or None."""
    j = getattr(x, "jaxpr", x)
    return j if (hasattr(j, "eqns") and hasattr(j, "invars")) else None


def inner_jaxpr(eqn):
    """The (first) sub-jaxpr of an eqn — e.g. a ``pjit`` eqn's traced
    body — or None."""
    for _, _, sub in _subjaxprs(eqn):
        return sub
    return None


def _subjaxprs(eqn):
    for pname in sorted(eqn.params):
        val = eqn.params[pname]
        vals = val if isinstance(val, (list, tuple)) else [val]
        for i, v in enumerate(vals):
            if _as_jaxpr(v) is not None:
                yield pname, i, v


def walk(jaxpr, path: Tuple[Frame, ...] = ()) -> Iterator[Site]:
    """Depth-first over every eqn of ``jaxpr`` (Jaxpr or ClosedJaxpr) and
    all nested sub-jaxprs, yielding a :class:`Site` per eqn."""
    j = _as_jaxpr(jaxpr)
    if j is None:
        raise TypeError(f"not a jaxpr: {type(jaxpr).__name__}")
    for eqn in j.eqns:
        yield Site(eqn=eqn, path=path)
        for pname, i, sub in _subjaxprs(eqn):
            yield from walk(
                sub, path + (Frame(eqn.primitive.name, pname, i),))
