"""repro.obs — zero-sync runtime telemetry for the execution stack.

Three pillars (see docs/architecture.md "Observability"):

* :mod:`repro.obs.metrics` — device-resident counters / gauges /
  histograms / labelled vectors behind one registry.  Accumulating never
  syncs; ``Metrics.snapshot()`` is the single device→host read.
* :mod:`repro.obs.trace` — wall-time span trees
  (``metrics.tracer.span("plan")``) and the per-policy-point recompile
  detector fed by the runner's ``step_cache`` misses.
* :mod:`repro.obs.export` — schema-versioned (``repro.obs/v1``) JSONL
  and Prometheus text sinks over snapshots, plus ``validate_snapshot``.
"""
from .metrics import (SCHEMA, Counter, Gauge, Histogram, Metrics,
                      VectorCounter, counter_delta, default, disabled,
                      log_buckets)
from .trace import Tracer
from .export import (export_jsonl, export_prometheus, read_jsonl,
                     validate_snapshot)

__all__ = [
    "SCHEMA", "Counter", "Gauge", "Histogram", "VectorCounter", "Metrics",
    "Tracer", "default", "disabled", "log_buckets", "counter_delta",
    "export_jsonl", "export_prometheus", "read_jsonl", "validate_snapshot",
]
