"""Device-resident runtime metrics (the accumulate-vs-read sync contract).

The engine needs to observe itself — dirty fractions, bucket picks, chunk
latencies, compile counts — without breaking the property PR 6 bought: a
steady-state chunk issues **zero device→host transfers**.  The registry
here is built around one contract:

* **Accumulating never syncs.**  Hot-path updates are either pure host
  arithmetic (Python ints, numpy bincounts — no device involvement at
  all) or *lazy device arithmetic*: a :class:`Counter` /
  :class:`VectorCounter` / :class:`Histogram` can hold a jax array as its
  device part, and updates just extend the device-side computation
  (``dev = dev + x``) or swap in a reference to a fresh device array
  produced by an already-jitted accumulator (:meth:`Counter.set_device`).
  Neither dispatches a device→host read.
* **Reading syncs, once, explicitly.**  :meth:`Metrics.snapshot` is the
  single device→host boundary: it resolves every device part to a host
  number and returns a plain-Python, schema-versioned dict
  (``SCHEMA``).  Exporters (:mod:`repro.obs.export`) consume snapshots,
  never live metrics.

Metric types
------------

``Counter``
    Monotonic count.  ``add()`` takes host numbers or jax scalars; the
    runner's fused accumulator instead calls ``set_device`` with the
    running device total (one jitted dispatch per chunk updates every
    device metric at once — see ``engine/runner.py``).
``Gauge``
    Last-set value (host or device).
``Histogram``
    Fixed-bucket distribution.  Host observations (``observe`` — e.g.
    wall-clock step latency) land in a numpy bincount; device
    observations arrive as a counts vector via ``set_device``.  Quantiles
    (p50/p90/p99) are estimated at snapshot time by interpolating the
    cumulative counts inside the hit bucket — log-linear for log-scale
    buckets (:func:`log_buckets`), linear otherwise.
``VectorCounter``
    A labelled vector of counts (e.g. capacity-bucket picks, one slot per
    ladder rung), host or device.

The module-level :func:`default` registry serves instrumentation points
that have no object to hang a registry on (one-shot ``sparse_run``, the
halo-exchange entry points); engine objects (``Runner``,
``MultiQuerySession``) own their registry so telemetry scopes to the
stream it describes.  :func:`disabled` turns every update into a no-op —
the before/after overhead measurement in ``benchmarks/fig_sparse.py``
uses it.
"""
from __future__ import annotations

import bisect
import contextlib
import math
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

__all__ = ["SCHEMA", "Counter", "Gauge", "Histogram", "VectorCounter",
           "Metrics", "default", "disabled", "log_buckets",
           "counter_delta"]

SCHEMA = "repro.obs/v1"

_ENABLED = [True]  # module-wide kill switch (see disabled())


@contextlib.contextmanager
def disabled():
    """Context manager: every metric update in scope is a no-op (the
    registry objects survive; their values simply don't move).  Used to
    measure instrumentation overhead."""
    _ENABLED.append(False)
    try:
        yield
    finally:
        _ENABLED.pop()


def _on() -> bool:
    return _ENABLED[-1]


def _to_host(x):
    """Resolve a possibly-device value to a host Python number (the one
    sync point, only ever reached from snapshot())."""
    if x is None:
        return 0
    a = np.asarray(x)
    return a.item() if a.ndim == 0 else a


def log_buckets(lo: float, hi: float, per_decade: int = 3
                ) -> List[float]:
    """Log-scale bucket upper edges covering [lo, hi] with ``per_decade``
    buckets per decade (plus the implicit +Inf overflow bucket)."""
    n = int(math.ceil(math.log10(hi / lo) * per_decade))
    return [lo * 10 ** (k / per_decade) for k in range(n + 1)]


class Counter:
    """Monotonic counter with a host part and an optional lazy device
    part.  ``value`` = host base + device accumulation (syncs)."""

    # device adds are deferred into a pending list (a reference append —
    # even an *eager* device ``+`` costs a full dispatch, ~tens of µs on
    # the CPU backend, which blows the overhead budget of sub-ms calls);
    # the list collapses into one batched device op per this many adds
    _COLLAPSE = 128

    def __init__(self, name: str, help: str = "", unit: str = ""):
        self.name, self.help, self.unit = name, help, unit
        self._base = 0
        self._dev = None
        self._pending: List = []

    def add(self, v=1) -> None:
        """Accumulate. Host numbers add into the base; jax arrays are
        queued for a lazy batched device sum (no sync, no dispatch)."""
        if not _on():
            return
        if isinstance(v, (int, float, np.integer, np.floating)):
            self._base += v
        else:
            self._pending.append(v)
            if len(self._pending) >= self._COLLAPSE:
                self._collapse()

    def _collapse(self) -> None:
        """Fold the pending device adds into the lazy device total —
        device-side arithmetic (amortized to one op per _COLLAPSE adds),
        still no device→host sync."""
        if not self._pending:
            return
        import jax.numpy as jnp
        try:
            tot = jnp.stack(self._pending).sum()
        except (ValueError, TypeError):  # mixed shapes/dtypes
            tot = self._pending[0]
            for x in self._pending[1:]:
                tot = tot + x
        self._dev = tot if self._dev is None else self._dev + tot
        self._pending = []

    def set_device(self, x) -> None:
        """Swap in the running device total (owned by a jitted
        accumulator — see engine/runner.py).  A reference assignment:
        no dispatch, no sync."""
        if _on():
            self._dev = x

    def fold_device(self) -> None:
        """Sync the device part into the host base and drop the
        reference — called off-path when the device accumulation chain
        is about to be replaced (e.g. a session rebuilding its runner)."""
        for x in self._pending:
            self._base += _to_host(x)
        self._pending = []
        if self._dev is not None:
            self._base += _to_host(self._dev)
            self._dev = None

    def reset(self) -> None:
        self._base, self._dev, self._pending = 0, None, []

    @property
    def value(self):
        """Current total (syncs the device part)."""
        return (self._base + _to_host(self._dev)
                + sum(_to_host(x) for x in self._pending))

    def to_snapshot(self) -> Dict:
        return {"value": self.value, "help": self.help, "unit": self.unit}


class Gauge:
    """Last-set value (host number or device scalar)."""

    def __init__(self, name: str, help: str = "", unit: str = ""):
        self.name, self.help, self.unit = name, help, unit
        self._v = 0

    def set(self, v) -> None:
        if _on():
            self._v = v

    def reset(self) -> None:
        self._v = 0

    @property
    def value(self):
        return _to_host(self._v)

    def to_snapshot(self) -> Dict:
        return {"value": self.value, "help": self.help, "unit": self.unit}


class VectorCounter:
    """A labelled vector of counts (one slot per label), host numpy base
    plus an optional device counts vector."""

    def __init__(self, name: str, labels: Sequence[str], help: str = "",
                 unit: str = ""):
        self.name, self.help, self.unit = name, help, unit
        self.labels = [str(x) for x in labels]
        self._base = np.zeros(len(self.labels), np.int64)
        self._dev = None

    def add(self, idx: int, v=1) -> None:
        if _on():
            self._base[idx] += v

    def set_device(self, counts) -> None:
        if _on():
            self._dev = counts

    def fold_device(self) -> None:
        if self._dev is not None:
            self._base = self._base + np.asarray(self._dev)
            self._dev = None

    def reset(self) -> None:
        self._base = np.zeros(len(self.labels), np.int64)
        self._dev = None

    @property
    def values(self) -> List[int]:
        tot = self._base if self._dev is None \
            else self._base + np.asarray(self._dev)
        return [int(x) for x in tot]

    def to_snapshot(self) -> Dict:
        return {"labels": list(self.labels), "values": self.values,
                "help": self.help, "unit": self.unit}


class Histogram:
    """Fixed-bucket histogram: ``edges`` are ascending upper bounds, with
    an implicit +Inf overflow bucket (``len(edges) + 1`` counts total).

    Host observations (:meth:`observe`) are a numpy bincount update —
    no device involvement.  Device distributions (e.g. the per-chunk
    dirty-fraction histogram the runner accumulates inside one jitted
    dispatch) arrive whole via :meth:`set_device`.  Quantiles interpolate
    inside the hit bucket: log-linearly when ``log_scale`` (latency
    buckets), linearly otherwise.
    """

    def __init__(self, name: str, edges: Sequence[float], help: str = "",
                 unit: str = "", log_scale: bool = False):
        if list(edges) != sorted(edges) or len(edges) < 1:
            raise ValueError(f"histogram {name}: edges must be ascending")
        self.name, self.help, self.unit = name, help, unit
        self.edges = [float(e) for e in edges]
        self.log_scale = log_scale
        self._counts = np.zeros(len(self.edges) + 1, np.int64)
        self._sum = 0.0
        self._dev = None  # device counts vector (len(edges) + 1)

    def observe(self, v: float) -> None:
        """Record one host-side observation (pure host arithmetic)."""
        if not _on():
            return
        self._counts[bisect.bisect_left(self.edges, v)] += 1
        self._sum += v

    def set_device(self, counts) -> None:
        """Swap in the running device counts vector (shape
        ``(len(edges) + 1,)``)."""
        if _on():
            self._dev = counts

    def fold_device(self) -> None:
        if self._dev is not None:
            self._counts = self._counts + np.asarray(self._dev)
            self._dev = None

    def reset(self) -> None:
        self._counts = np.zeros(len(self.edges) + 1, np.int64)
        self._sum = 0.0
        self._dev = None

    def counts(self) -> np.ndarray:
        return (self._counts if self._dev is None
                else self._counts + np.asarray(self._dev))

    def quantile(self, q: float, counts: Optional[np.ndarray] = None
                 ) -> Optional[float]:
        """Estimated q-quantile from the bucket counts (None when
        empty).  Overflow-bucket hits clamp to the top edge."""
        c = self.counts() if counts is None else counts
        total = int(c.sum())
        if total == 0:
            return None
        target = q * total
        cum = 0
        for i, n in enumerate(c):
            if n == 0:
                continue
            if cum + n >= target:
                frac = (target - cum) / n
                if i >= len(self.edges):          # overflow bucket
                    return self.edges[-1]
                hi = self.edges[i]
                lo = self.edges[i - 1] if i > 0 else (
                    hi / 10 if self.log_scale else 0.0)
                if self.log_scale and lo > 0:
                    return lo * (hi / lo) ** frac
                return lo + (hi - lo) * frac
            cum += n
        return self.edges[-1]

    def to_snapshot(self) -> Dict:
        c = self.counts()
        out = {"edges": list(self.edges), "counts": [int(x) for x in c],
               "count": int(c.sum()), "sum": float(self._sum),
               "help": self.help, "unit": self.unit}
        for q in (0.5, 0.9, 0.99):
            out[f"p{int(q * 100)}"] = self.quantile(q, c)
        return out


class Metrics:
    """A named registry of metrics plus an attached span tracer.

    ``counter`` / ``gauge`` / ``histogram`` / ``vector`` are
    get-or-create: instrumentation points just name the metric they want
    and shared registries (a session and the runner it builds) land in
    the same slot.  :meth:`snapshot` is the one device→host read.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._metrics: Dict[str, object] = {}
        self._collectors: Dict[str, Callable[[], None]] = {}
        self._warmup_hooks: Dict[str, Callable[[], None]] = {}
        from .trace import Tracer
        self.tracer = Tracer()

    @property
    def on(self) -> bool:
        return self.enabled and _on()

    def _get(self, cls, name, *args, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, *args, **kw)
        elif not isinstance(m, cls):
            raise ValueError(
                f"metric {name!r} already registered as "
                f"{type(m).__name__}, not {cls.__name__}")
        return m

    def get(self, name: str):
        """The registered metric object under ``name``, or None."""
        return self._metrics.get(name)

    def drop(self, name: str) -> None:
        """Forget a metric (e.g. before re-registering with a different
        shape — a runner rebuilt at a new geometry)."""
        self._metrics.pop(name, None)

    def counter(self, name: str, help: str = "", unit: str = "") -> Counter:
        return self._get(Counter, name, help, unit)

    def gauge(self, name: str, help: str = "", unit: str = "") -> Gauge:
        return self._get(Gauge, name, help, unit)

    def vector(self, name: str, labels: Sequence[str], help: str = "",
               unit: str = "") -> VectorCounter:
        return self._get(VectorCounter, name, labels, help, unit)

    def histogram(self, name: str, edges: Sequence[float], help: str = "",
                  unit: str = "", log_scale: bool = False) -> Histogram:
        return self._get(Histogram, name, edges, help, unit,
                         log_scale=log_scale)

    def register_collector(self, name: str, fn: Callable[[], None]) -> None:
        """Register a pre-snapshot hook (e.g. a runner pushing derived
        gauges).  Re-registering a name replaces the old hook — the
        session-rebuild path, where the new runner supersedes the old."""
        self._collectors[name] = fn

    def reset(self) -> None:
        for m in self._metrics.values():
            m.reset()
        self.tracer.reset()

    def register_warmup_reset(self, name: str,
                              fn: Callable[[], None]) -> None:
        """Register a :meth:`reset_after_warmup` hook (e.g. a runner
        re-basing its device accumulator).  Re-registering a name replaces
        the old hook, mirroring :meth:`register_collector`."""
        self._warmup_hooks[name] = fn

    def reset_after_warmup(self) -> None:
        """Re-base the registry at the end of warmup so long-lived
        services window percentiles past the compiling first chunks:
        every metric's measured values reset (the latency histogram in
        particular), then registered warmup hooks run so device-
        accumulator owners (``Runner._mstate``) drop their state and
        re-assert static gauges.

        The tracer is deliberately **not** reset: its per-key compile
        counts are exactly the warmup record the recompile detector needs
        — a post-warmup compile of an already-seen staging key must still
        show up as a retrace."""
        for m in self._metrics.values():
            m.reset()
        for fn in list(self._warmup_hooks.values()):
            fn()

    def snapshot(self) -> Dict:
        """Resolve every metric to host values: the single explicit
        device→host boundary.  Returns a schema-versioned plain dict
        (see :mod:`repro.obs.export` for the schema contract)."""
        for fn in list(self._collectors.values()):
            fn()
        snap = {"schema": SCHEMA, "ts": time.time(),
                "counters": {}, "gauges": {}, "histograms": {},
                "vectors": {}}
        for name, m in sorted(self._metrics.items()):
            kind = {Counter: "counters", Gauge: "gauges",
                    Histogram: "histograms",
                    VectorCounter: "vectors"}[type(m)]
            snap[kind][name] = m.to_snapshot()
        snap["spans"] = self.tracer.span_report()
        snap["compiles"] = self.tracer.compile_report()
        return snap


_DEFAULT: Optional[Metrics] = None


def default() -> Metrics:
    """The process-global registry, serving instrumentation points with
    no natural owner (one-shot entry points, halo exchange staging)."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = Metrics()
    return _DEFAULT


def counter_delta(before: Dict, after: Dict, name: str):
    """Counter difference between two snapshots (0 when absent in both)."""
    get = lambda s: s.get("counters", {}).get(name, {}).get("value", 0)  # noqa: E731
    return get(after) - get(before)
