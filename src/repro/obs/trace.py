"""Phase tracing: wall-time span trees and a recompile detector.

Spans answer "where does the wall time go" at phase granularity —
plan / compile / execute / refit — without a profiler run.  ``span()``
is a context manager; nesting builds slash-separated paths
(``session.rebuild/plan``), and each path aggregates count / total / max
seconds.  This is *host* wall time around dispatch boundaries: spans
never touch device values, so they are safe anywhere, including around
the transfer-guarded hot path.

The recompile detector rides the engine's own staging discipline: every
jit-cache miss in ``Runner``'s ``step_cache`` (one entry per (policy,
geometry) point) calls :meth:`Tracer.record_compile` with the cache key.
A key compiled **more than once** means the cache was dropped and
rebuilt — an unexpected retrace; :meth:`Tracer.retraces` surfaces
exactly those.  The runner additionally cross-checks jax's own cache via
``jitted._cache_size()`` at snapshot time (``runner.jit_entries`` gauge),
which catches shape-driven retraces *inside* one staged step.

Optional passthrough: with ``REPRO_OBS_JAX_TRACE=1``, spans also open
``jax.profiler.TraceAnnotation`` so they appear on the TensorBoard /
Perfetto timeline when a profiler trace is active.
"""
from __future__ import annotations

import contextlib
import os
import time
from typing import Dict, List

__all__ = ["Tracer"]


def _jax_annotation(name: str):
    if os.environ.get("REPRO_OBS_JAX_TRACE", "0") != "1":
        return contextlib.nullcontext()
    try:
        import jax
        return jax.profiler.TraceAnnotation(name)
    except Exception:
        return contextlib.nullcontext()


class Tracer:
    """Aggregating span recorder + per-key compile counter."""

    def __init__(self):
        self._stack: List[str] = []
        self._spans: Dict[str, Dict] = {}
        self._compiles: Dict[str, int] = {}
        self._aot: Dict[str, str] = {}

    @contextlib.contextmanager
    def span(self, name: str):
        """Time a phase.  Nested spans build ``outer/inner`` paths."""
        path = "/".join(self._stack + [name])
        self._stack.append(name)
        t0 = time.perf_counter()
        try:
            with _jax_annotation(path):
                yield
        finally:
            dt = time.perf_counter() - t0
            self._stack.pop()
            s = self._spans.setdefault(
                path, {"count": 0, "total_s": 0.0, "max_s": 0.0})
            s["count"] += 1
            s["total_s"] += dt
            s["max_s"] = max(s["max_s"], dt)

    def record_compile(self, key: str) -> None:
        """Note a jit-cache miss at a policy point (a staged compile)."""
        self._compiles[key] = self._compiles.get(key, 0) + 1

    def record_aot(self, key: str, how: str = "loaded") -> None:
        """Note an AOT executable installed under a staging key
        (``how``: ``"loaded"`` from a persisted cache or ``"compiled"``
        ahead of time).  The complement of :meth:`record_compile`: a warm
        serving start shows AOT loads here and *no* compile records — the
        tracer-verified zero-compile warm-start proof."""
        self._aot[key] = how

    def aot_installs(self) -> Dict[str, str]:
        return dict(self._aot)

    def compiles(self) -> Dict[str, int]:
        return dict(self._compiles)

    def retraces(self) -> Dict[str, int]:
        """Keys compiled more than once — unexpected retraces: the
        runner's step_cache holds exactly one step per key, so a second
        compile means the cache was dropped and the step re-staged."""
        return {k: n - 1 for k, n in self._compiles.items() if n > 1}

    def retrace_findings(self) -> List[Dict]:
        """The runtime retrace record in static-finding form: one entry
        per key compiled more than once, shaped like a
        ``repro.analysis`` finding payload (the recompile-hazard pass
        merges these with its static probe, so a runtime-observed retrace
        and a statically-proven under-keyed cache land in one report)."""
        return [{"severity": "error", "code": "runtime-retrace",
                 "message": (f"staging key {k!r} compiled {n + 1} times — "
                             "the step cache was dropped or under-keyed"),
                 "provenance": k}
                for k, n in sorted(self.retraces().items())]

    def span_report(self) -> Dict[str, Dict]:
        return {k: dict(v) for k, v in sorted(self._spans.items())}

    def compile_report(self) -> Dict:
        return {"counts": self.compiles(), "retraces": self.retraces(),
                "aot_installs": self.aot_installs()}

    def reset(self) -> None:
        self._spans.clear()
        self._compiles.clear()
        self._aot.clear()
        self._stack.clear()
