"""Snapshot exporters: JSONL stream and Prometheus text exposition.

Both consume the plain dict produced by ``Metrics.snapshot()`` — never a
live registry — so exporting is always off the hot path and the snapshot
schema is the exporters' only contract:

``repro.obs/v1`` snapshot schema::

    {
      "schema": "repro.obs/v1",
      "ts": <unix seconds, float>,
      "counters":   {name: {"value": num, "help": str, "unit": str}},
      "gauges":     {name: {"value": num, "help": str, "unit": str}},
      "histograms": {name: {"edges": [f...], "counts": [i...],   # len(edges)+1,
                            "count": i, "sum": f,                 # last = +Inf overflow
                            "p50": f|null, "p90": f|null, "p99": f|null,
                            "help": str, "unit": str}},
      "vectors":    {name: {"labels": [s...], "values": [i...],
                            "help": str, "unit": str}},
      "spans":      {path: {"count": i, "total_s": f, "max_s": f}},
      "compiles":   {"counts": {key: i}, "retraces": {key: i}},
    }

``export_jsonl`` appends one compact line per snapshot (a time series a
dashboard can tail); ``export_prometheus`` renders the Prometheus text
exposition format (histograms become cumulative ``_bucket{le=...}`` plus
``_sum``/``_count``, vectors become one labelled sample per slot).
``validate_snapshot`` is the schema smoke shared by tests and
``benchmarks/metrics_smoke.py``.
"""
from __future__ import annotations

import json
import math
import re
from typing import Dict, List

from .metrics import SCHEMA

__all__ = ["export_jsonl", "read_jsonl", "export_prometheus",
           "validate_snapshot"]


def export_jsonl(snap: Dict, path: str) -> None:
    """Append one snapshot as one JSON line."""
    with open(path, "a") as f:
        f.write(json.dumps(snap, sort_keys=True) + "\n")


def read_jsonl(path: str) -> List[Dict]:
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    return _NAME_RE.sub("_", name)


def _prom_num(v) -> str:
    if v is None:
        return "NaN"
    if isinstance(v, float) and math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return repr(v) if isinstance(v, float) else str(v)


def export_prometheus(snap: Dict) -> str:
    """Render a snapshot in the Prometheus text exposition format."""
    lines: List[str] = []

    def header(name, help, kind):
        if help:
            lines.append(f"# HELP {name} {help}")
        lines.append(f"# TYPE {name} {kind}")

    for name, m in snap.get("counters", {}).items():
        pn = _prom_name(name) + "_total"
        header(pn, m.get("help", ""), "counter")
        lines.append(f"{pn} {_prom_num(m['value'])}")
    for name, m in snap.get("gauges", {}).items():
        pn = _prom_name(name)
        header(pn, m.get("help", ""), "gauge")
        lines.append(f"{pn} {_prom_num(m['value'])}")
    for name, m in snap.get("vectors", {}).items():
        pn = _prom_name(name) + "_total"
        header(pn, m.get("help", ""), "counter")
        for label, v in zip(m["labels"], m["values"]):
            lines.append(f'{pn}{{slot="{label}"}} {v}')
    for name, m in snap.get("histograms", {}).items():
        pn = _prom_name(name)
        header(pn, m.get("help", ""), "histogram")
        cum = 0
        for edge, c in zip(m["edges"] + [float("inf")], m["counts"]):
            cum += c
            lines.append(f'{pn}_bucket{{le="{_prom_num(float(edge))}"}} {cum}')
        lines.append(f"{pn}_sum {_prom_num(m['sum'])}")
        lines.append(f"{pn}_count {m['count']}")
    for path, s in snap.get("spans", {}).items():
        pn = _prom_name("span_" + path)
        lines.append(f"{pn}_seconds_total {_prom_num(s['total_s'])}")
        lines.append(f"{pn}_count {s['count']}")
    for key, n in snap.get("compiles", {}).get("counts", {}).items():
        lines.append(f'compiles_total{{key="{_prom_name(key)}"}} {n}')
    return "\n".join(lines) + "\n"


def validate_snapshot(snap: Dict) -> List[str]:
    """Return schema problems (empty list == valid ``repro.obs/v1``)."""
    bad: List[str] = []
    if not isinstance(snap, dict):
        return ["snapshot is not a dict"]
    if snap.get("schema") != SCHEMA:
        bad.append(f"schema is {snap.get('schema')!r}, want {SCHEMA!r}")
    if not isinstance(snap.get("ts"), (int, float)):
        bad.append("ts missing or non-numeric")
    for sec in ("counters", "gauges", "histograms", "vectors", "spans"):
        if not isinstance(snap.get(sec), dict):
            bad.append(f"{sec} missing or not a dict")
    for name, m in snap.get("counters", {}).items():
        if not isinstance(m.get("value"), (int, float)):
            bad.append(f"counter {name}: value missing")
    for name, m in snap.get("gauges", {}).items():
        if not isinstance(m.get("value"), (int, float, list)):
            bad.append(f"gauge {name}: value missing")
    for name, m in snap.get("histograms", {}).items():
        edges, counts = m.get("edges"), m.get("counts")
        if not isinstance(edges, list) or not isinstance(counts, list):
            bad.append(f"histogram {name}: edges/counts missing")
            continue
        if len(counts) != len(edges) + 1:
            bad.append(f"histogram {name}: want {len(edges) + 1} counts "
                       f"(incl. overflow), got {len(counts)}")
        if edges != sorted(edges):
            bad.append(f"histogram {name}: edges not ascending")
        if m.get("count") != sum(counts):
            bad.append(f"histogram {name}: count != sum(counts)")
        for q in ("p50", "p90", "p99"):
            if q not in m:
                bad.append(f"histogram {name}: {q} missing")
    for name, m in snap.get("vectors", {}).items():
        if len(m.get("labels", [])) != len(m.get("values", ())):
            bad.append(f"vector {name}: labels/values length mismatch")
    comp = snap.get("compiles")
    if not isinstance(comp, dict) or "counts" not in comp \
            or "retraces" not in comp:
        bad.append("compiles missing counts/retraces")
    return bad
