"""Execution-policy sweep: the unified runner across the policy matrix.

One workload — a bursty piecewise-constant dashboard/fraud stream where
~2% of ticks change — driven through several points of the
``ExecPolicy(body × keys × placement × dag)`` space by the *same*
unified chunked runner (repro/engine/runner.py):

* ``dense×single×local×solo``   — the chunked baseline (StreamRunner path)
* ``sparse×single×local×solo``  — segment compaction (SparseStreamRunner)
* ``dense×vmapped×local×solo``  — K keyed sub-streams (KeyedEngine path)
* ``sparse×vmapped×local×solo`` — key compaction (mostly-idle keys skip)
* ``dense×single×local×union``  — N queries, shared union DAG (session)
* ``sparse×single×local×union`` — merged ChangePlan: clean chunks skip the
  whole union evaluation

Derived columns report throughput (events/s through the policy's work
axis), the measured compaction ratio for sparse points (read from the
runner's own telemetry registry, ``runner.metrics`` — see
:mod:`repro.obs`), and the speedup over the dense point with the same
keys/dag axes; sparse rows carry the full metrics snapshot (compaction,
per-chunk latency histogram, compile counts) under ``metrics``.  Mesh placements are
covered by the multidev tests and ``benchmarks/fig_halo_depth.py`` (this
container is 1 core; an in-process 8-device host mesh measures dispatch
overhead, not parallel speedup).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import compile as qc
from repro.core.frontend import TStream
from repro.core.stream import SnapshotGrid
from repro.engine import ExecPolicy, Runner, keyed_grid
from repro.multiquery import union_runner

from .common import row
from .fig_sparse import burst_stream

REPEATS = 3
K = 32          # keyed sub-streams (1 in 8 active)
RATE = 0.02     # change rate of active streams
SEGS_PER_CHUNK = 8


def _pow2_ticks(n_events: int) -> int:
    n = max(4096, min(n_events, 1 << 20))
    return 1 << (n.bit_length() - 1)


def _trend(s):
    return (s.window(32).mean()
            .join(s.window(64).mean(), lambda a, b: a - b)
            .where(lambda d: d > 0))


def _bands(s):
    return s.window(48).max().join(s, lambda hi, x: hi - x)


def _bench(mk_runner, grids, n_chunks):
    """min-of-REPEATS full-run wall time; returns the last timed runner so
    sparse points can read its measured telemetry (``runner.metrics``)."""
    r = mk_runner()
    out = r.run(grids, n_chunks)           # warmup (compile)
    leaf = out if isinstance(out, SnapshotGrid) else next(iter(out.values()))
    jax.block_until_ready(leaf.valid)
    best = []
    for _ in range(REPEATS):
        r = mk_runner()
        t0 = time.perf_counter()
        out = r.run(grids, n_chunks)
        leaf = (out if isinstance(out, SnapshotGrid)
                else next(iter(out.values())))
        jax.block_until_ready(leaf.valid)
        best.append(time.perf_counter() - t0)
    return min(best), r


def run(n_events: int = 1_000_000):
    N = _pow2_ticks(n_events)
    seg = max(128, N // 1024)
    n_chunks = N // (seg * SEGS_PER_CHUNK)
    single_vals = burst_stream(N, RATE, seed=3)
    keyed_vals = np.zeros((K, N), np.float32)
    for k in range(0, K, 8):               # 1 in 8 keys active
        keyed_vals[k] = burst_stream(N, RATE, seed=10 + k)
    g1 = {"in": SnapshotGrid(value=jax.numpy.asarray(single_vals),
                             valid=jax.numpy.ones(N, bool), t0=0, prec=1)}
    gk = {"in": keyed_grid(keyed_vals, np.ones((K, N), bool))}

    dense_dt = {}
    for keys, dag in (("single", "solo"), ("vmapped", "solo"),
                      ("single", "union")):
        keyed = keys == "vmapped"
        s = TStream.source("in", prec=1, keyed=keyed)
        grids, base_ev = (gk, K * N) if keyed else (g1, N)
        for body in ("dense", "sparse"):
            ev = base_ev
            policy = ExecPolicy(body=body, keys=keys, dag=dag)
            sparse = body == "sparse"
            if dag == "solo":
                exe = qc.compile_query(_trend(s).node, out_len=seg,
                                       pallas=False, sparse=sparse)

                def mk(exe=exe, policy=policy, keyed=keyed):
                    return Runner(exe, policy, n_keys=K if keyed else None,
                                  segs_per_chunk=SEGS_PER_CHUNK)
            else:
                queries = {"trend": _trend(s), "bands": _bands(s)}
                proto = union_runner(queries, seg, policy, pallas=False,
                                     segs_per_chunk=SEGS_PER_CHUNK)

                def mk(proto=proto, policy=policy):
                    proto.reset()
                    return proto
                ev = ev * len(queries)
            dt, r_last = _bench(mk, grids, n_chunks)
            label = f"figpolicy_{body}_{keys}_{dag}"
            derived = (f"{ev / dt / 1e6:.1f}Mev/s,"
                       f"policy={policy.describe()}")
            extra = dict(events=ev, chunks=n_chunks, seg_len=seg)
            if sparse:
                # compaction from the runner's telemetry registry (the
                # union proto resets per repeat, so the gauge covers the
                # last timed run only)
                snap = r_last.metrics.snapshot()
                compact = snap["gauges"]["runner.compact"]["value"]
                speedup = dense_dt[(keys, dag)] / dt
                derived += f",compact={compact:.3f},speedup={speedup:.2f}"
                extra.update(body="sparse", metrics=snap)
            else:
                dense_dt[(keys, dag)] = dt
                extra.update(body="dense")
            row(label, dt * 1e6, derived, **extra)


if __name__ == "__main__":
    run()
