"""Telemetry export smoke: validate the ``repro.obs/v1`` exporters.

Drives a small keyed sparse runner (the most heavily instrumented path:
device-resident dirty counters, bucket picks, dirty-fraction and latency
histograms, compile tracing), snapshots its registry and checks:

* the snapshot passes :func:`repro.obs.validate_snapshot` (schema smoke);
* ``export_jsonl`` → ``read_jsonl`` round-trips the snapshot bit-exactly
  and appends (two lines after two exports);
* ``export_prometheus`` renders the samples a scraper needs: counter
  ``_total``s, cumulative histogram ``_bucket{le=...}`` ending at
  ``+Inf``, ``_sum``/``_count``, gauges, and ``compiles_total`` keys.

Exits non-zero on any schema problem, so CI's ``bench-metrics`` job fails
loudly instead of uploading a malformed artifact.  The single row carries
the full snapshot under ``metrics`` (BENCH_metricssmoke.json is itself a
schema example).
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import jax
import numpy as np

from repro import obs
from repro.core import compile as qc
from repro.core.frontend import TStream
from repro.engine import ExecPolicy, Runner, keyed_grid

from .common import row, set_config

SEG = 64
SPC = 2
K = 8


def _query():
    s = TStream.source("in", prec=1, keyed=True)
    return (s.window(16).mean()
            .join(s.window(32).mean(), lambda a, b: a - b)
            .where(lambda d: d > 0))


def run(n_events: int = 100_000) -> None:
    span = SEG * SPC
    n_chunks = max(2, min(8, n_events // (K * span)))
    T = n_chunks * span

    exe = qc.compile_query(_query().node, out_len=SEG, pallas=False,
                           sparse=True)
    r = Runner(exe, ExecPolicy(body="sparse", keys="vmapped"), n_keys=K,
               segs_per_chunk=SPC)
    rng = np.random.default_rng(5)
    vals = np.broadcast_to(rng.integers(0, 100, (K, 1)).astype(np.float32),
                           (K, T)).copy()
    vals[:2] = rng.integers(0, 100, (2, T)).astype(np.float32)  # 2 active
    grids = {"in": keyed_grid(vals, np.ones((K, T), bool))}

    t0 = time.perf_counter()
    jax.block_until_ready(r.run(grids, n_chunks).valid)
    dt = time.perf_counter() - t0

    snap = r.metrics.snapshot()
    problems = obs.validate_snapshot(snap)

    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "metrics.jsonl")
        obs.export_jsonl(snap, path)
        obs.export_jsonl(r.metrics.snapshot(), path)
        back = obs.read_jsonl(path)
        jsonl_ok = (len(back) == 2
                    and back[0] == json.loads(json.dumps(snap))
                    and not obs.validate_snapshot(back[0]))

    text = obs.export_prometheus(snap)
    needed = ("runner_chunks_total", "runner_step_seconds_bucket",
              'le="+Inf"', "runner_step_seconds_count",
              "runner_step_seconds_sum", "runner_compact",
              "compiles_total")
    prom_ok = all(s in text for s in needed)

    # static audit of the very runner that produced the numbers: the
    # measurement row carries its own hot-path verdict (repro.analysis)
    from repro.analysis import audit_runner, verdict
    findings = audit_runner(r)
    av = verdict(findings)

    ok = not problems and jsonl_ok and prom_ok and av != "error"
    row("metrics_smoke", dt * 1e6,
        f"ok={int(ok)},jsonl_ok={int(jsonl_ok)},prom_ok={int(prom_ok)},"
        f"problems={len(problems)},chunks={n_chunks},audit={av}",
        events=K * T, keys=K, metrics=snap,
        audit={"verdict": av, "findings": [f.to_json() for f in findings]})
    set_config(schema=obs.SCHEMA, prom_lines=len(text.splitlines()))
    for p in problems:
        print(f"# schema problem: {p}")
    if not ok:
        raise SystemExit("metrics smoke failed: "
                         f"problems={problems}, jsonl_ok={jsonl_ok}, "
                         f"prom_ok={prom_ok}, audit={av}")


if __name__ == "__main__":
    run()
