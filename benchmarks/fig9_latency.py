"""Fig. 9: latency-bounded throughput.

The batch/snapshot-buffer size is the latency knob: smaller partitions mean
fresher results but more per-partition overhead.  The paper shows Trill
collapsing 18–227× at small batches while TiLT stays flat; we sweep the
TiLT partition length and the EventSPE micro-batch size over the same
10 … 1M range on the trend query.
"""
from __future__ import annotations

from repro.data import apps as A

from .common import row, time_spe, time_tilt

SIZES = (100, 1_000, 10_000, 100_000, 1_000_000)


def run(n_events: int = 1_000_000):
    app = A.make_app("trend")
    data = app.make_input(n_events, 17)
    for size in SIZES:
        tps, _ = time_tilt(app, data, n_events, part_len=size, repeats=1)
        sps, _ = time_spe(app, data, n_events, batch=size, repeats=1)
        row(f"fig9_trend_tilt_b{size}", 1e6 * size / tps,
            f"{tps/1e6:.2f}Mev/s")
        row(f"fig9_trend_spe_b{size}", 1e6 * size / sps,
            f"{sps/1e6:.2f}Mev/s")


if __name__ == "__main__":
    run()
