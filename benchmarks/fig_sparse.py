"""Change-rate × segment-size sweep: dense vs change-compressed execution.

Real-world streams are change-compressed: fraud and dashboard sources hold
their value for long spans and change in bursts (sessions, market moves),
so >90% of grid ticks carry no new information.  This sweep drives the
fraud-style windowed app (trailing mean + stddev → threshold → excess →
where) over piecewise-constant integer-valued streams whose *change rate*
(fraction of ticks whose value differs from the previous tick, arriving in
bursts of ``BURST`` ticks) ranges 1%…100%, and compares:

* ``dense``  — the fused one-shot execution (its best configuration), and
* ``sparse`` — :func:`repro.core.sparse.sparse_run` at several segment
  (chunk) sizes: only segments whose dilated lineage saw a change are
  computed, the rest hold.

Derived columns report throughput, the measured compaction ratio
(``compact`` = dirty segments / total segments) and the dense-vs-sparse
``speedup``.  Expected shape: big wins at 1% (the compaction bound times
the ``(seg+window)/seg`` halo overhead), break-even somewhere around
10–50%, and a constant-factor *loss* at 100% — dense mode remains the
right default for high-change streams (see repro/core/sparse.py).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compile as qc
from repro.core.frontend import TStream
from repro.core.parallel import partition_run
from repro.core.sparse import segment_mask, sparse_run
from repro.core.stream import SnapshotGrid

from .common import row

REPEATS = 3
RATES = (0.01, 0.10, 0.50, 1.00)
BURST = 128  # change-burst length (a fraud session / market move)


def _pow2_ticks(n_events: int) -> int:
    n = max(4096, min(n_events, 1 << 20))
    return 1 << (n.bit_length() - 1)


def burst_stream(n: int, rate: float, seed: int,
                 burst: int = BURST) -> np.ndarray:
    """Piecewise-constant integer-valued stream whose value changes on
    ~``rate`` of ticks, arriving in bursts of ``burst`` consecutive
    changes."""
    rng = np.random.default_rng(seed)
    change = np.zeros(n, bool)
    if rate >= 1.0:
        change[:] = True
    else:
        n_bursts = max(int(n * rate) // burst, 1)
        for s in rng.integers(0, max(n - burst, 1), n_bursts):
            change[s:s + burst] = True
    change[0] = True
    raw = np.floor(rng.random(n) * 100).astype(np.float32)
    idx = np.maximum.accumulate(np.where(change, np.arange(n), -1))
    return raw[idx]


def _fraud_query(window: int):
    s = TStream.source("in", prec=1)
    mu = s.window(window).mean().shift(1)
    sd = s.window(window).stddev().shift(1)
    thr = mu.join(sd, lambda m, d: m + 3.0 * d, name="thr")
    return (s.join(thr, lambda x, t: x - t, name="excess")
            .where(lambda e: e > 0, name="flag"))


def _bench(fn) -> float:
    jax.block_until_ready(fn().valid)  # warmup (compile)
    best = []
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        jax.block_until_ready(fn().valid)
        best.append(time.perf_counter() - t0)
    return min(best)


def run(n_events: int = 1_000_000):
    N = _pow2_ticks(n_events)
    window = min(64, N // 8)
    segs = sorted({max(128, N // 2048), max(256, N // 1024)})
    q = _fraud_query(window)
    exe_dense = qc.compile_query(q.node, out_len=N, pallas=False)
    # one sparse executable per segment size, shared across rates so the
    # bucketed jit caches stay warm exactly as in steady-state operation
    exe_sparse = {seg: qc.compile_query(q.node, out_len=seg, pallas=False,
                                        sparse=True) for seg in segs}

    for rate in RATES:
        vals = burst_stream(N, rate, seed=7)
        g = {"in": SnapshotGrid(value=jnp.asarray(vals),
                                valid=jnp.ones(N, bool), t0=0, prec=1)}
        dt_d = _bench(lambda: partition_run(exe_dense, g, 0, 1))
        r = int(rate * 100)
        row(f"figsparse_dense_r{r}", dt_d * 1e6,
            f"{N / dt_d / 1e6:.1f}Mev/s,mode=dense,rate={rate}",
            events=N, window=window)
        for seg in segs:
            exe_s = exe_sparse[seg]
            n_segs = N // seg
            dt_s = _bench(lambda: sparse_run(exe_s, g, 0, n_segs))
            n_dirty = int(np.asarray(
                segment_mask(exe_s, g, 0, n_segs)).sum())
            row(f"figsparse_sparse_r{r}_c{seg}", dt_s * 1e6,
                f"{N / dt_s / 1e6:.1f}Mev/s,mode=sparse,rate={rate},"
                f"compact={n_dirty / n_segs:.3f},speedup={dt_d / dt_s:.2f}",
                events=N, window=window, seg_len=seg,
                dirty_segments=n_dirty, total_segments=n_segs)


if __name__ == "__main__":
    run()
