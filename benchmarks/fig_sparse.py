"""Change-rate × scale sweep: dense vs change-compressed execution.

Real-world streams are change-compressed: fraud and dashboard sources hold
their value for long spans and change in bursts (sessions, market moves),
so >90% of grid ticks carry no new information.  Two sweeps drive the
fraud-style windowed app (trailing mean + stddev → threshold → excess →
where) and compare dense against sparse execution:

* **one-shot** — a single piecewise-constant stream whose *change rate*
  (fraction of ticks whose value differs from the previous tick, arriving
  in bursts of ``BURST`` ticks) ranges 1%…100%;
  :func:`repro.core.sparse.sparse_run` (the fused single-jit path: change
  detection, device-resident bucket pick and compute with zero host
  round-trips) against the fused dense one-shot.

* **scale** — the production shape sparse execution is built for: K keyed
  sub-streams (K grows with the event budget, up to 16384) through the
  chunked :class:`repro.engine.Runner`, where the change rate is the
  fraction of *active* keys (active keys change every tick, idle keys hold
  — key compaction is the dominant skip axis at scale).  Dense and sparse
  runners share the executable caches across repeats, exactly as in
  steady-state operation.

Derived columns report throughput, the measured compaction ratio
(``compact`` = dirty work units / total) and the dense-vs-sparse
``speedup`` — both read from the engine's own telemetry
(:mod:`repro.obs`: the ``sparse.*`` counters of the one-shot path, the
``runner.*`` registry of the chunked runners), not recomputed ad hoc;
sparse rows carry the full schema-versioned snapshot under ``metrics``.
The anchor sweep also times its sparse points with instrumentation
off (:func:`repro.obs.disabled`) and records the measured metrics
overhead in the section config (``metrics_overhead_pct``).  The
sparse↔dense crossover change rate, interpolated from the scale sweep,
lands in the section config (``scale_crossover_rate``) — see
docs/architecture.md for the body=sparse guidance it backs.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import compile as qc
from repro.core.frontend import TStream
from repro.core.parallel import partition_run
from repro.core.sparse import sparse_run
from repro.core.stream import SnapshotGrid
from repro.engine import ExecPolicy, Runner, keyed_grid

from .common import row, set_config

REPEATS = 3
# the metrics-on/off A/B pair compares two near-identical sub-ms timings,
# so it needs more best-of samples than the headline rows for min() to
# converge below the true overhead gap (3 repeats measured a *negative*
# overhead on noisy hosts)
OVERHEAD_REPEATS = 5
RATES = (0.01, 0.10, 0.50, 1.00)
BURST = 128  # change-burst length (a fraud session / market move)

SCALE_RATES = (0.01, 0.05, 0.10, 0.25, 0.50, 1.00)
SCALE_SEG = 64       # segment (out_len) of the chunked runners
SCALE_SPC = 2        # segments per chunk


def _pow2_ticks(n_events: int) -> int:
    n = max(4096, min(n_events, 1 << 20))
    return 1 << (n.bit_length() - 1)


def burst_stream(n: int, rate: float, seed: int,
                 burst: int = BURST) -> np.ndarray:
    """Piecewise-constant integer-valued stream whose value changes on
    ~``rate`` of ticks, arriving in bursts of ``burst`` consecutive
    changes."""
    rng = np.random.default_rng(seed)
    change = np.zeros(n, bool)
    if rate >= 1.0:
        change[:] = True
    else:
        n_bursts = max(int(n * rate) // burst, 1)
        for s in rng.integers(0, max(n - burst, 1), n_bursts):
            change[s:s + burst] = True
    change[0] = True
    raw = np.floor(rng.random(n) * 100).astype(np.float32)
    idx = np.maximum.accumulate(np.where(change, np.arange(n), -1))
    return raw[idx]


def _fraud_query(window: int, keyed: bool = False):
    s = TStream.source("in", prec=1, keyed=keyed)
    mu = s.window(window).mean().shift(1)
    sd = s.window(window).stddev().shift(1)
    thr = mu.join(sd, lambda m, d: m + 3.0 * d, name="thr")
    return (s.join(thr, lambda x, t: x - t, name="excess")
            .where(lambda e: e > 0, name="flag"))


def _bench(fn) -> float:
    jax.block_until_ready(fn().valid)  # warmup (compile)
    best = []
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        jax.block_until_ready(fn().valid)
        best.append(time.perf_counter() - t0)
    return min(best)


def _bench_loop(fn, inner: int = 20, repeats: int = REPEATS) -> float:
    """Per-call seconds averaged over ``inner`` back-to-back calls
    (min of ``repeats`` samples) — sub-ms calls need batched timing for
    the instrumentation-overhead comparison to beat scheduler noise."""
    jax.block_until_ready(fn().valid)  # warmup (compile)
    best = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(inner):
            out = fn()
        jax.block_until_ready(out.valid)
        best.append((time.perf_counter() - t0) / inner)
    return min(best)


def _bench_runner(mk_runner, grids, n_chunks):
    """min-of-REPEATS wall time of a fresh runner's full run (compiled
    steps shared via the executable's caches); returns the last timed
    runner so callers can read its measured telemetry
    (``runner.metrics.snapshot()``)."""
    r = mk_runner()
    jax.block_until_ready(r.run(grids, n_chunks).valid)  # warmup (compile)
    best = []
    for _ in range(REPEATS):
        r = mk_runner()
        t0 = time.perf_counter()
        jax.block_until_ready(r.run(grids, n_chunks).valid)
        best.append(time.perf_counter() - t0)
    return min(best), r


def _one_shot_sweep(n_events: int) -> None:
    # pinned at the 4k-tick anchor (the sweep's historical point) so every
    # BENCH_figsparse.json — smoke or production scale — carries the same
    # small-scale overhead row next to the scale sweep's crossover curve
    N = min(4096, _pow2_ticks(n_events))
    window = min(64, N // 8)
    seg = min(512, N // 8)
    q = _fraud_query(window)
    exe_dense = qc.compile_query(q.node, out_len=N, pallas=False)
    exe_s = qc.compile_query(q.node, out_len=seg, pallas=False, sparse=True)

    # the one-shot sparse path reports into the process-global registry;
    # scope it to this sweep so per-rate snapshot deltas are exact
    reg = obs.default()
    reg.reset()
    on_us = off_us = 0.0
    for rate in RATES:
        vals = burst_stream(N, rate, seed=7)
        g = {"in": SnapshotGrid(value=jnp.asarray(vals),
                                valid=jnp.ones(N, bool), t0=0, prec=1)}
        dt_d = _bench(lambda: partition_run(exe_dense, g, 0, 1))
        r = int(rate * 100)
        row(f"figsparse_dense_r{r}", dt_d * 1e6,
            f"{N / dt_d / 1e6:.1f}Mev/s,mode=dense,rate={rate}",
            events=N, window=window)
        n_segs = N // seg
        # instrumentation-off timing first (same compiled fn), then the
        # production path with metrics on — the anchor overhead measurement
        with obs.disabled():
            dt_off = _bench_loop(lambda: sparse_run(exe_s, g, 0, n_segs),
                                 repeats=OVERHEAD_REPEATS)
        snap0 = reg.snapshot()
        dt_s = _bench_loop(lambda: sparse_run(exe_s, g, 0, n_segs),
                           repeats=OVERHEAD_REPEATS)
        snap1 = reg.snapshot()
        runs = max(int(obs.counter_delta(snap0, snap1, "sparse.runs")), 1)
        n_dirty = int(obs.counter_delta(snap0, snap1,
                                        "sparse.dirty_segments")) // runs
        on_us += dt_s * 1e6
        off_us += dt_off * 1e6
        row(f"figsparse_sparse_r{r}_c{seg}", dt_s * 1e6,
            f"{N / dt_s / 1e6:.1f}Mev/s,mode=sparse,rate={rate},"
            f"compact={n_dirty / n_segs:.3f},speedup={dt_d / dt_s:.2f}",
            events=N, window=window, seg_len=seg,
            dirty_segments=n_dirty, total_segments=n_segs,
            metrics=snap1)
    # clamp the headline number at 0: a (noise-level) negative difference
    # means "unmeasurably small", not that instrumentation speeds calls up;
    # the raw signed value stays alongside for honesty
    raw_pct = (on_us - off_us) / off_us * 100
    set_config(metrics_on_us=round(on_us, 3), metrics_off_us=round(off_us, 3),
               metrics_overhead_pct=round(max(0.0, raw_pct), 2),
               metrics_overhead_raw_pct=round(raw_pct, 2),
               metrics_overhead_repeats=OVERHEAD_REPEATS)


def _scale_sweep(n_events: int) -> None:
    span = SCALE_SEG * SCALE_SPC
    # target ~20 chunks so the all-dirty first chunk (conservative stream
    # start: every key's initial dirty tail forces a full compute) amortizes
    # out of the steady-state compaction ratio
    k_target = max(16, min(16384, n_events // (20 * span)))
    K = 1 << (k_target - 1).bit_length()
    n_chunks = max(1, round(n_events / K / span))
    T = n_chunks * span
    events = K * T
    window = 64

    q = _fraud_query(window, keyed=True)
    exe_d = qc.compile_query(q.node, out_len=SCALE_SEG, pallas=False)
    exe_s = qc.compile_query(q.node, out_len=SCALE_SEG, pallas=False,
                             sparse=True)

    def mk_dense():
        return Runner(exe_d, ExecPolicy(body="dense", keys="vmapped"),
                      n_keys=K, segs_per_chunk=SCALE_SPC)

    def mk_sparse():
        return Runner(exe_s, ExecPolicy(body="sparse", keys="vmapped"),
                      n_keys=K, segs_per_chunk=SCALE_SPC)

    rng = np.random.default_rng(11)
    base = rng.integers(0, 100, size=(K, 1)).astype(np.float32)
    curve = []
    for rate in SCALE_RATES:
        vals = np.broadcast_to(base, (K, T)).copy()
        n_act = max(1, int(round(K * rate)))
        act = rng.choice(K, size=n_act, replace=False)
        vals[act] = rng.integers(0, 100,
                                 size=(n_act, T)).astype(np.float32)
        grids = {"in": keyed_grid(vals, np.ones((K, T), bool))}

        dt_d, _ = _bench_runner(mk_dense, grids, n_chunks)
        pct = int(rate * 100)
        row(f"figsparse_scale_dense_r{pct}", dt_d * 1e6,
            f"{events / dt_d / 1e6:.1f}Mev/s,mode=dense,rate={rate},"
            f"scale={events}",
            events=events, keys=K, chunks=n_chunks, seg_len=SCALE_SEG)
        dt_s, rs = _bench_runner(mk_sparse, grids, n_chunks)
        # compaction and per-chunk latency straight from the runner's own
        # telemetry (the last timed runner — fresh registry, warm caches)
        snap = rs.metrics.snapshot()
        compact = snap["gauges"]["runner.compact"]["value"]
        p50 = snap["histograms"]["runner.step_seconds"]["p50"]
        speedup = dt_d / dt_s
        curve.append((rate, speedup))
        row(f"figsparse_scale_sparse_r{pct}", dt_s * 1e6,
            f"{events / dt_s / 1e6:.1f}Mev/s,mode=sparse,rate={rate},"
            f"scale={events},compact={compact:.3f},speedup={speedup:.2f},"
            f"p50_chunk_us={p50 * 1e6:.1f}",
            events=events, keys=K, chunks=n_chunks, seg_len=SCALE_SEG,
            metrics=snap)

    cross = None
    for (r0, s0), (r1, s1) in zip(curve, curve[1:]):
        if s0 >= 1.0 > s1:
            cross = r0 + (r1 - r0) * (s0 - 1.0) / (s0 - s1)
            break
    set_config(scale_events=events, scale_keys=K,
               scale_crossover_rate=(round(cross, 4) if cross is not None
                                     else None),
               scale_sparse_wins_everywhere=cross is None
               and all(s >= 1.0 for _, s in curve))


def run(n_events: int = 1_000_000):
    _one_shot_sweep(n_events)
    _scale_sweep(n_events)


if __name__ == "__main__":
    run()
