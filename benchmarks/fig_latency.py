"""Serving latency: AOT-compiled steps, p50/p99 per call, cold vs warm.

The serving loop (:mod:`repro.serve`) attacks the two latencies the batch
benchmarks never see:

* **per-call tail latency** — every staged step is AOT-installed before
  the first request, so no request ever traces or compiles in-band, and
  chunk k+1's H2D transfer overlaps chunk k's compute (double buffer).
  We sweep the per-call batch (events per served chunk) over 1…1000 and
  report host-measured p50/p99 across a run of back-to-back calls, plus
  the tracer's compile/retrace record proving the steady state never
  recompiles.  Compare fig9: the partitioned one-shot path pays ~ms-scale
  dispatch per call at small batches; the served runner's AOT step keeps
  the p99 flat.

* **time-to-first-result** — a cold process pays plan + trace + XLA
  compile before result one; a warm process rebuilds the runner from the
  persisted plan artifact and loads serialized executables
  (``cold_first_result_s`` vs ``warm_first_result_s`` in the section
  config, measured at batch=100 with a fresh tmp cache so "cold" is
  honestly cold — including jax's own persistent compilation cache,
  which build_service points under the same tmp dir).
"""
from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from repro.core.frontend import TStream
from repro.core.stream import SnapshotGrid
from repro.serve import build_service

from .common import row, set_config

BATCHES = (1, 10, 100, 1_000)
WINDOW = 16
WARMUP_CALLS = 2
FIRST_RESULT_BATCH = 100


def _fraud(win: int = WINDOW):
    s = TStream.source("in", prec=1)
    mu = s.window(win).mean().shift(1)
    sd = s.window(win).stddev().shift(1)
    thr = mu.join(sd, lambda m, d: m + 3.0 * d)
    return s.join(thr, lambda x, t: x - t).where(lambda e: e > 0)


def _chunks(span: int, n: int, seed: int = 5):
    # host numpy: the loop's explicit device_put is the only H2D
    rng = np.random.default_rng(seed)
    for i in range(n):
        v = rng.integers(0, 100, span).astype(np.float32)
        yield {"in": SnapshotGrid(value=v, valid=np.ones(span, bool),
                                  t0=i * span, prec=1)}


def _serve_calls(svc, span: int, calls: int):
    """Per-call wall seconds (blocked results) over ``calls`` requests
    through the double-buffered generator, warmup calls dropped; also the
    number of compiles recorded *during* the timed calls (the
    tracer-verified zero-per-request-recompile proof)."""
    tracer = svc.runner.metrics.tracer
    gen = svc.serve(_chunks(span, calls + WARMUP_CALLS))
    for _ in range(WARMUP_CALLS):
        next(gen)
    c0 = sum(tracer.compiles().values())
    dts = np.empty(calls)
    for j in range(calls):
        t0 = time.perf_counter()
        next(gen)
        dts[j] = time.perf_counter() - t0
    gen.close()
    return dts, sum(tracer.compiles().values()) - c0


def _first_result(cache_dir: str, batch: int) -> float:
    """Construction → first blocked result, one fresh service."""
    t0 = time.perf_counter()
    svc = build_service(_fraud(), out_len=batch, segs_per_chunk=1,
                        cache_dir=cache_dir)
    next(svc.serve(_chunks(batch, 1)))
    return time.perf_counter() - t0, svc


def run(n_events: int = 1_000_000):
    tmp = tempfile.mkdtemp(prefix="figlat_")
    try:
        p99_b100 = None
        for batch in BATCHES:
            calls = int(np.clip(n_events // (batch * 200), 10, 200))
            svc = build_service(_fraud(), out_len=batch, segs_per_chunk=1,
                                cache_dir=f"{tmp}/b{batch}")
            dts, steady_compiles = _serve_calls(svc, batch, calls)
            assert steady_compiles == 0, steady_compiles
            tracer = svc.runner.metrics.tracer
            p50, p99 = np.percentile(dts, (50, 99))
            if batch == FIRST_RESULT_BATCH:
                p99_b100 = p99
            row(f"figlat_serve_b{batch}", p99 * 1e6,
                f"{batch / p50 / 1e6:.3f}Mev/s,batch={batch},"
                f"p50_us={p50 * 1e6:.1f},p99_us={p99 * 1e6:.1f},"
                f"calls={calls},steady_compiles={steady_compiles},"
                f"retraces={sum(tracer.retraces().values())}",
                metrics=svc.runner.metrics)

        # cold vs warm first-result: same fresh cache dir twice, two
        # "processes" (fresh runner + fresh jax cache dir under tmp)
        fr_dir = f"{tmp}/firstresult"
        t_cold, svc_c = _first_result(fr_dir, FIRST_RESULT_BATCH)
        assert svc_c.plan_source == "cold"
        t_warm, svc_w = _first_result(fr_dir, FIRST_RESULT_BATCH)
        assert svc_w.plan_source == "warm", svc_w.plan_source
        assert not svc_w.runner.metrics.tracer.compiles(), \
            svc_w.runner.metrics.tracer.compiles()
        row("figlat_first_result_cold", t_cold * 1e6,
            f"mode=cold,batch={FIRST_RESULT_BATCH},aot=compiled")
        row("figlat_first_result_warm", t_warm * 1e6,
            f"mode=warm,batch={FIRST_RESULT_BATCH},aot=loaded,"
            f"speedup={t_cold / t_warm:.1f}")
        set_config(window=WINDOW, warmup_calls=WARMUP_CALLS,
                   p99_batch100_us=round(float(p99_b100) * 1e6, 1),
                   cold_first_result_s=round(t_cold, 3),
                   warm_first_result_s=round(t_warm, 3),
                   warm_speedup=round(t_cold / t_warm, 1))
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    run()
