"""Hillclimb driver: recompile one cell with config overrides and diff the
terms against the baseline JSON (hypothesis → change → measure loop).

Usage:
  PYTHONPATH=src python -m benchmarks.hillclimb dbrx-132b train_4k \
      --set seq_parallel=true --set n_micro... --tag iterA
Writes out/hillclimb/<arch>_<shape>_<tag>.json and prints the delta table.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("arch")
    ap.add_argument("shape")
    ap.add_argument("--set", action="append", default=[])
    ap.add_argument("--tag", default="iter")
    ap.add_argument("--baseline-dir", default="out/dryrun")
    ap.add_argument("--full", action="store_true",
                    help="include the unrolled cost lowering (slow)")
    ap.add_argument("--micro", type=int, default=None)
    args = ap.parse_args()

    out = f"out/hillclimb/{args.arch}_{args.shape}_{args.tag}.json"
    os.makedirs("out/hillclimb", exist_ok=True)
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", args.arch, "--shape", args.shape, "--mesh", "single",
           "--json", out]
    if not args.full:
        cmd.append("--skip-unrolled")
    if args.micro:
        cmd += ["--micro", str(args.micro)]
    for kv in args.set:
        cmd += ["--set", kv]
    p = subprocess.run(cmd, capture_output=True, text=True)
    if p.returncode != 0:
        print(p.stdout[-2000:], p.stderr[-2000:])
        sys.exit(1)

    with open(out) as f:
        new = json.load(f)
    base_path = os.path.join(args.baseline_dir,
                             f"{args.arch}_{args.shape}_single.json")
    base = json.load(open(base_path)) if os.path.exists(base_path) else {}

    def row(name, b, n, fmt="{:.3f}"):
        delta = ""
        if isinstance(b, (int, float)) and isinstance(n, (int, float)) and b:
            delta = f"  ({(n - b) / b * +100:+.1f}%)"
        print(f"{name:28s} {fmt.format(b) if b or b==0 else '-':>12s} -> "
              f"{fmt.format(n) if n or n==0 else '-':>12s}{delta}")

    bm, nm = base.get("memory", {}), new.get("memory", {})
    print(f"== {args.arch} × {args.shape} [{args.tag}] "
          f"overrides={new.get('overrides')}")
    row("arg GB", bm.get("argument_size_in_bytes", 0) / 1e9,
        nm.get("argument_size_in_bytes", 0) / 1e9)
    row("temp GB", bm.get("temp_size_in_bytes", 0) / 1e9,
        nm.get("temp_size_in_bytes", 0) / 1e9)
    row("collective_s (scanned)", base.get("collective_s_scanned", 0),
        new.get("collective_s_scanned", 0), "{:.4f}")
    br, nr = base.get("roofline") or {}, new.get("roofline") or {}
    if br and nr:
        for k in ("compute_s", "memory_s", "collective_s",
                  "roofline_fraction"):
            row(k, br.get(k, 0), nr.get(k, 0), "{:.4f}")


if __name__ == "__main__":
    main()
